//! Compile-only stub of the `xla` crate's PJRT API surface.
//!
//! The offline build environment cannot fetch the real `xla` crate, but
//! `codag`'s `pjrt` feature must still *compile* so the feature-gated
//! runtime backend (`runtime::executor`, `tests/pjrt_roundtrip.rs`)
//! cannot rot unseen — CI builds `--features pjrt` against this stub.
//!
//! Every constructor that would touch PJRT fails at runtime
//! ([`PjRtClient::cpu`], [`HloModuleProto::from_text_file`]), so no
//! stubbed executable can ever be reached: callers observe the same
//! "runtime unavailable" behavior as the feature-off build and fall
//! back to the pure-Rust `cpu_expand` path. Swapping in the real crate
//! is a one-line change to the `xla` path dependency in
//! `rust/Cargo.toml` (see DESIGN.md §3).

/// Error type mirroring `xla::Error` far enough for `to_string()`.
#[derive(Debug)]
pub struct Error(String);

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

fn offline() -> Error {
    Error(
        "xla stub: built against rust/vendor/xla-stub (no PJRT); vendor the real `xla` \
         crate to enable execution"
            .to_string(),
    )
}

/// Stub PJRT client; [`PjRtClient::cpu`] always fails.
pub struct PjRtClient;

impl PjRtClient {
    /// Fails: no PJRT runtime is linked in the stub build.
    pub fn cpu() -> Result<PjRtClient, Error> {
        Err(offline())
    }

    /// Unreachable in practice (construction fails).
    pub fn platform_name(&self) -> String {
        "xla-stub".to_string()
    }

    /// Unreachable in practice (construction fails).
    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        Err(offline())
    }
}

/// Stub compiled executable.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    /// Unreachable in practice (no executable can be constructed).
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        Err(offline())
    }
}

/// Stub device buffer.
pub struct PjRtBuffer;

impl PjRtBuffer {
    /// Unreachable in practice.
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        Err(offline())
    }
}

/// Stub HLO module proto; [`HloModuleProto::from_text_file`] fails.
pub struct HloModuleProto;

impl HloModuleProto {
    /// Fails: the stub cannot parse HLO.
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto, Error> {
        Err(offline())
    }
}

/// Stub XLA computation wrapper.
pub struct XlaComputation;

impl XlaComputation {
    /// Wraps nothing (the proto cannot be constructed anyway).
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Stub literal value.
pub struct Literal;

impl Literal {
    /// Accepts any element slice (type-checks the call sites).
    pub fn vec1<T: Copy>(_v: &[T]) -> Literal {
        Literal
    }

    /// Unreachable in practice.
    pub fn to_tuple1(&self) -> Result<Literal, Error> {
        Err(offline())
    }

    /// Unreachable in practice.
    pub fn to_vec<T>(&self) -> Result<Vec<T>, Error> {
        Err(offline())
    }
}
