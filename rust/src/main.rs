//! `codag` — the CLI / leader entrypoint.
//!
//! Subcommands:
//!
//! ```text
//! codag gen        --dataset MC0 --size 16M --out mc0.bin
//! codag compress   --codec rlev2 --input mc0.bin --out mc0.codag [--chunk 131072] [--width 8]
//! codag pack       --data-dir DIR (--dataset MC0 [--size 16M] | --input raw.bin --name NAME) [--codec rlev2|auto] [--chunk 131072]
//! codag decompress --input mc0.codag --out mc0.bin [--workers 8] [--hybrid]
//! codag verify     <file.codag>   (offline integrity scrub: header, restart tables, per-chunk decode + checksum)
//! codag simulate   --dataset MC0 --codec rlev1 [--gpu a100] [--arch codag|baseline|prefetch|single|regbuf] [--size 4M]
//! codag report     <table3|table4|table5|fig2..fig8|ubench|ablation_decode|all> [--size 4M]
//! codag serve      --port 7311 [--data-dir DIR] [--datasets MC0,TPC] [--bind 127.0.0.1] [--codec rlev2] [--size 16M] [--shards 4] [--depth 64] [--workers 2] [--cache 64M] [--net-model evented|threads] [--paranoid]
//! codag serve      --dataset MC0 --codec rlev2 [--workers 8]   (legacy stdin mode: "<id> <offset> <len>" per line)
//! codag loadgen    --addr 127.0.0.1:7311 --dataset MC0 [--connections 4] [--requests 64] [--maxlen 256K] [--seed N] [--pipeline 1] [--deadline-ms 0] [--scrape] [--verify-frames]
//! codag loadgen    --addr 127.0.0.1:7311 --dataset MC0 --ablate-batch   (§V-F batching sweep, pipeline depths 1/8/32)
//! codag loadgen    --addr 127.0.0.1:7311 --dataset MC0 --probe-expired  (deadline-expiry smoke probe)
//! codag loadgen    --addr 127.0.0.1:7311 --shutdown   (drain the daemon and exit)
//! codag stat       --addr 127.0.0.1:7311   (scrape the daemon's metrics exposition, DESIGN.md §10)
//! ```
//!
//! Hand-rolled flag parsing: the offline build environment provides no
//! argument-parsing crates, and the surface is small.

use codag::bench_harness::{all_workloads, report::Experiment, Scale};
use codag::codecs::{CodecKind, CodecRegistry};
use codag::coordinator::{
    decompress_hybrid, decompress_parallel, DatasetSource, Registry, Request, Service,
    ServiceConfig,
};
use codag::data::Dataset;
use codag::decomp::codag_engine::Variant;
use codag::format::container::Container;
use codag::gpu_sim::{simulate_container, GpuConfig, Provisioning};
use codag::runtime::{default_artifacts_dir, Expander, SharedRuntime};
use codag::server::{daemon, loadgen};
use std::collections::HashMap;
use std::io::BufRead;
use std::process::ExitCode;
use std::sync::Arc;

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match run(&args) {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            ExitCode::FAILURE
        }
    }
}

/// Parse `--key value` flags after the subcommand.
fn flags(args: &[String]) -> HashMap<String, String> {
    let mut m = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        if let Some(k) = args[i].strip_prefix("--") {
            if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                m.insert(k.to_string(), args[i + 1].clone());
                i += 2;
            } else {
                m.insert(k.to_string(), "true".to_string());
                i += 1;
            }
        } else {
            i += 1;
        }
    }
    m
}

/// Parse sizes like "16M", "512K", "4096".
fn parse_size(s: &str) -> Result<usize, String> {
    let s = s.trim();
    let (num, mult) = match s.chars().last() {
        Some('K') | Some('k') => (&s[..s.len() - 1], 1024),
        Some('M') | Some('m') => (&s[..s.len() - 1], 1024 * 1024),
        Some('G') | Some('g') => (&s[..s.len() - 1], 1024 * 1024 * 1024),
        _ => (s, 1),
    };
    num.parse::<usize>().map(|v| v * mult).map_err(|e| format!("bad size '{s}': {e}"))
}

fn run(args: &[String]) -> Result<(), String> {
    let Some(cmd) = args.first() else {
        return Err(
            "usage: codag <gen|compress|pack|decompress|verify|simulate|report|serve|loadgen|stat> [flags]"
                .into(),
        );
    };
    let f = flags(&args[1..]);
    match cmd.as_str() {
        "gen" => cmd_gen(&f),
        "compress" => cmd_compress(&f),
        "pack" => cmd_pack(&f),
        "decompress" => cmd_decompress(&f),
        "verify" => cmd_verify(args.get(1).map(|s| s.as_str()), &f),
        "simulate" => cmd_simulate(&f),
        "report" => cmd_report(args.get(1).map(|s| s.as_str()).unwrap_or("all"), &f),
        "serve" => cmd_serve(&f),
        "loadgen" => cmd_loadgen(&f),
        "stat" => cmd_stat(&f),
        other => Err(format!("unknown command '{other}'")),
    }
}

fn get<'a>(f: &'a HashMap<String, String>, k: &str) -> Result<&'a str, String> {
    f.get(k).map(|s| s.as_str()).ok_or_else(|| format!("missing --{k}"))
}

/// Resolve a codec name (or alias) through the registry. The error
/// lists whatever is actually registered, so a new codec shows up here
/// without touching the CLI.
fn parse_codec(s: &str) -> Result<CodecKind, String> {
    CodecRegistry::by_name(s).map(|c| CodecKind(c.wire_id())).ok_or_else(|| {
        format!("unknown codec '{s}' (registered: {})", CodecRegistry::names().join(", "))
    })
}

fn cmd_gen(f: &HashMap<String, String>) -> Result<(), String> {
    let d = Dataset::parse(get(f, "dataset")?).ok_or("unknown dataset")?;
    let size = parse_size(f.get("size").map(String::as_str).unwrap_or("16M"))?;
    let out = get(f, "out")?;
    let data = d.generate(size);
    std::fs::write(out, &data).map_err(|e| e.to_string())?;
    println!("wrote {} bytes of {} to {out}", data.len(), d.name());
    Ok(())
}

fn cmd_compress(f: &HashMap<String, String>) -> Result<(), String> {
    let codec = parse_codec(get(f, "codec")?)?;
    let input = get(f, "input")?;
    let out = get(f, "out")?;
    let chunk = parse_size(f.get("chunk").map(String::as_str).unwrap_or("131072"))?;
    let data = std::fs::read(input).map_err(|e| e.to_string())?;
    let started = std::time::Instant::now();
    let container = match f.get("width") {
        Some(w) => {
            let width: u8 = w.parse().map_err(|_| "bad --width")?;
            compress_with_width(&data, codec, chunk, width).map_err(|e| e.to_string())?
        }
        None => Container::compress(&data, codec, chunk).map_err(|e| e.to_string())?,
    };
    std::fs::write(out, container.to_bytes()).map_err(|e| e.to_string())?;
    println!(
        "{input}: {} -> {} bytes (ratio {:.4}) in {:.2}s [{} chunks]",
        data.len(),
        container.compressed_len(),
        container.compression_ratio(),
        started.elapsed().as_secs_f64(),
        container.n_chunks()
    );
    Ok(())
}

/// `codag pack`: write a container file into a `--data-dir` that
/// `codag serve --data-dir` then serves file-backed (DESIGN.md §9).
/// The payload comes from `--input` (raw bytes on disk, named with
/// `--name`) or a generated paper dataset (`--dataset`, deterministic).
/// `--codec auto` trial-compresses a sample of each chunk through every
/// registered codec and keeps the per-chunk winner (container v3 when
/// the winners differ).
fn cmd_pack(f: &HashMap<String, String>) -> Result<(), String> {
    let dir = std::path::Path::new(get(f, "data-dir")?);
    let codec_arg = f.get("codec").map(String::as_str).unwrap_or("rlev2");
    let chunk = parse_size(f.get("chunk").map(String::as_str).unwrap_or("131072"))?;
    // Restart points are on by default (container v2, DESIGN.md §8);
    // `--restart-interval 0` packs without sub-block boundaries.
    let restart_interval = match f.get("restart-interval") {
        Some(s) => parse_size(s)?,
        None => codag::format::container::DEFAULT_RESTART_INTERVAL,
    };
    let (name, data) = if let Some(input) = f.get("input") {
        let name = get(f, "name")?.to_string();
        (name, std::fs::read(input).map_err(|e| e.to_string())?)
    } else {
        let d = Dataset::parse(get(f, "dataset")?).ok_or("unknown dataset")?;
        let size = parse_size(f.get("size").map(String::as_str).unwrap_or("16M"))?;
        (d.name().to_string(), d.generate(size))
    };
    let container = if codec_arg.eq_ignore_ascii_case("auto") {
        Container::compress_auto_with_restarts(&data, chunk, restart_interval)
    } else {
        let codec = parse_codec(codec_arg)?;
        Container::compress_with_restarts(&data, codec, chunk, restart_interval)
    }
    .map_err(|e| e.to_string())?;
    std::fs::create_dir_all(dir).map_err(|e| e.to_string())?;
    let path = dir.join(format!("{name}.codag"));
    std::fs::write(&path, container.to_bytes()).map_err(|e| e.to_string())?;
    let n_restarts: usize = container.restarts.iter().map(Vec::len).sum();
    println!(
        "packed {name}: {} -> {} bytes ({}, {} chunks, {n_restarts} restart points) into {}",
        data.len(),
        container.compressed_len(),
        codec_label(&container),
        container.n_chunks(),
        path.display()
    );
    Ok(())
}

/// Human label for a container's codec: the single codec name, or a
/// per-codec chunk tally for mixed (auto-packed) containers.
fn codec_label(container: &Container) -> String {
    if !container.is_mixed() {
        return container.codec.name().to_string();
    }
    let mut counts = vec![0usize; codag::codecs::N_CODECS];
    for i in 0..container.n_chunks() {
        if let Some(slot) = CodecRegistry::slot(container.chunk_codec(i)) {
            counts[slot] += 1;
        }
    }
    let parts: Vec<String> = CodecRegistry::names()
        .iter()
        .zip(&counts)
        .filter(|&(_, &n)| n > 0)
        .map(|(name, n)| format!("{name}x{n}"))
        .collect();
    format!("mixed[{}]", parts.join(" "))
}

/// Compress with a pinned RLE element width (restart points recorded at
/// the default interval, matching `Container::compress`).
fn compress_with_width(
    data: &[u8],
    codec: CodecKind,
    chunk: usize,
    width: u8,
) -> codag::Result<Container> {
    use codag::format::container::{ChunkEntry, DEFAULT_RESTART_INTERVAL};
    let mut index = Vec::new();
    let mut restarts = Vec::new();
    let mut checksums = Vec::new();
    let mut payload = Vec::new();
    for chunk_bytes in data.chunks(chunk) {
        let (comp, points) = codag::codecs::compress_chunk_with_restarts(
            codec,
            chunk_bytes,
            width,
            DEFAULT_RESTART_INTERVAL,
        )?;
        index.push(ChunkEntry {
            comp_off: payload.len() as u64,
            comp_len: comp.len() as u64,
            uncomp_len: chunk_bytes.len() as u64,
        });
        restarts.push(points);
        checksums.push(codag::format::hash::crc32c(chunk_bytes));
        payload.extend_from_slice(&comp);
    }
    Ok(Container {
        codec,
        chunk_codecs: Vec::new(),
        chunk_size: chunk,
        total_uncompressed: data.len() as u64,
        index,
        restarts,
        checksums,
        payload,
    })
}

fn cmd_decompress(f: &HashMap<String, String>) -> Result<(), String> {
    let input = get(f, "input")?;
    let out = get(f, "out")?;
    let workers: usize = match f.get("workers") {
        Some(s) => s.parse().map_err(|_| "bad --workers")?,
        None => std::thread::available_parallelism().map(|n| n.get()).unwrap_or(8),
    };
    let bytes = std::fs::read(input).map_err(|e| e.to_string())?;
    let container = Container::from_bytes(&bytes).map_err(|e| e.to_string())?;
    let started = std::time::Instant::now();
    let data = if f.contains_key("hybrid") {
        // Degrade gracefully only when PJRT is genuinely unavailable (a
        // build without the `pjrt` feature, or no artifacts on disk): the
        // run-record path still runs, expanded by the pure-Rust fallback.
        // A pjrt-enabled build with artifacts present must NOT mask load
        // errors (corrupt manifest, failed compile) as a silent CPU run.
        let artifacts = default_artifacts_dir();
        let rt = if cfg!(feature = "pjrt") && artifacts.join("manifest.txt").exists() {
            Some(SharedRuntime::load(&artifacts).map_err(|e| e.to_string())?)
        } else {
            eprintln!(
                "PJRT runtime unavailable (built without the `pjrt` feature, or no \
                 artifacts at {}); using the CPU expand fallback",
                artifacts.display()
            );
            None
        };
        let ex = match rt.as_ref() {
            Some(rt) => Expander::new(rt),
            None => Expander::cpu_only(),
        };
        let d = decompress_hybrid(&container, workers, &ex).map_err(|e| e.to_string())?;
        println!(
            "hybrid dispatch: {} PJRT / {} CPU-fallback chunks",
            ex.stats.pjrt.load(std::sync::atomic::Ordering::Relaxed),
            ex.stats.cpu_fallback.load(std::sync::atomic::Ordering::Relaxed)
        );
        d
    } else {
        decompress_parallel(&container, workers).map_err(|e| e.to_string())?
    };
    let secs = started.elapsed().as_secs_f64();
    std::fs::write(out, &data).map_err(|e| e.to_string())?;
    println!(
        "{input}: {} bytes in {:.3}s ({:.2} GB/s, {workers} workers)",
        data.len(),
        secs,
        data.len() as f64 / secs / 1e9
    );
    Ok(())
}

/// `codag verify <file.codag>`: offline integrity scrub. Parses the
/// container (structural guards + the v4 whole-header CRC), then
/// decodes every chunk — serially, and through the restart-point
/// stitcher when the chunk has a restart table — verifying each
/// decoded chunk against its packed content checksum. Mismatches are
/// reported per chunk and the command exits nonzero, so a cron job or
/// CI step can scrub packed data at rest.
fn cmd_verify(pos: Option<&str>, f: &HashMap<String, String>) -> Result<(), String> {
    let path = pos
        .filter(|p| !p.starts_with("--"))
        .map(str::to_string)
        .or_else(|| f.get("input").cloned())
        .ok_or("usage: codag verify <file.codag>")?;
    let bytes = std::fs::read(&path).map_err(|e| format!("{path}: {e}"))?;
    // Structural tier: header, index, restart/codec/checksum section
    // guards, and (v4) the whole-header CRC all run inside from_bytes.
    let container = Container::from_bytes(&bytes).map_err(|e| format!("{path}: {e}"))?;
    let with_checksums = container.chunk_checksum(0).is_some();
    if !with_checksums {
        eprintln!(
            "warning: {path} carries no content checksums (pre-v4 container) — \
             structural checks only"
        );
    }
    let mut bad = 0usize;
    let mut scratch = Vec::new();
    for i in 0..container.n_chunks() {
        // Serial decode verifies the content checksum internally.
        if let Err(e) = container.decompress_chunk_into(i, &mut scratch) {
            eprintln!("chunk {i}: serial decode: {e}");
            bad += 1;
            continue;
        }
        // Restart-table tier: the split path exercises every sub-block
        // boundary and re-verifies once at the stitch join.
        if !container.restart_table(i).is_empty() {
            if let Err(e) =
                codag::coordinator::decompress_chunk_split_into(&container, i, 2, &mut scratch)
            {
                eprintln!("chunk {i}: split decode: {e}");
                bad += 1;
            }
        }
    }
    if bad > 0 {
        return Err(format!("{path}: {bad} of {} chunks FAILED verification", container.n_chunks()));
    }
    println!(
        "{path}: OK — {} chunks verified ({}, {} bytes uncompressed{})",
        container.n_chunks(),
        codec_label(&container),
        container.total_uncompressed,
        if with_checksums { ", content checksums checked" } else { ", no content checksums" }
    );
    Ok(())
}

fn cmd_simulate(f: &HashMap<String, String>) -> Result<(), String> {
    let d = Dataset::parse(get(f, "dataset")?).ok_or("unknown dataset")?;
    let codec = parse_codec(get(f, "codec")?)?;
    let gpu = GpuConfig::by_name(f.get("gpu").map(String::as_str).unwrap_or("a100"))
        .ok_or("unknown gpu (a100|v100)")?;
    let size = parse_size(f.get("size").map(String::as_str).unwrap_or("4M"))?;
    let chunks: usize = f.get("chunks").map(|s| s.parse().unwrap_or(16)).unwrap_or(16);
    let prov = match f.get("arch").map(String::as_str).unwrap_or("codag") {
        "codag" => Provisioning::Codag(Variant::Codag),
        "baseline" => Provisioning::Baseline,
        "prefetch" => Provisioning::Codag(Variant::CodagPrefetch),
        "single" => Provisioning::Codag(Variant::SingleThreadDecode),
        "regbuf" => Provisioning::Codag(Variant::RegisterBuffer),
        other => return Err(format!("unknown arch '{other}'")),
    };
    let data = d.generate(size);
    let container =
        codag::bench_harness::compress_dataset(&data, d, codec).map_err(|e| e.to_string())?;
    let m = simulate_container(&gpu, prov, &container, chunks).map_err(|e| e.to_string())?;
    println!(
        "{} {} {} on {}: {:.2} GB/s  (cycles={} comp%={:.1} mem%={:.1})",
        prov.label(),
        codec.name(),
        d.name(),
        gpu.name,
        m.throughput_gbps(&gpu),
        m.cycles,
        m.compute_pct(&gpu),
        m.memory_pct(&gpu)
    );
    for (r, p) in m.stall_distribution() {
        println!("  stall {:16} {:5.1}%", r.label(), p);
    }
    Ok(())
}

fn cmd_report(which: &str, f: &HashMap<String, String>) -> Result<(), String> {
    let mut scale = Scale::default();
    if let Some(s) = f.get("size") {
        scale.dataset_bytes = parse_size(s)?;
    }
    if let Some(c) = f.get("chunks") {
        scale.sim_chunks = c.parse().map_err(|_| "bad --chunks")?;
    }
    if which == "all" || which.starts_with("--") {
        let report = codag::bench_harness::report::run_all(scale).map_err(|e| e.to_string())?;
        println!("{report}");
        return Ok(());
    }
    let e = Experiment::parse(which).ok_or_else(|| format!("unknown experiment '{which}'"))?;
    let workloads = all_workloads(scale).map_err(|e| e.to_string())?;
    println!("{}", e.run(&workloads, scale).map_err(|e| e.to_string())?);
    Ok(())
}

fn cmd_serve(f: &HashMap<String, String>) -> Result<(), String> {
    if f.contains_key("port") {
        return cmd_serve_daemon(f);
    }
    let d = Dataset::parse(get(f, "dataset")?).ok_or("unknown dataset")?;
    let codec = parse_codec(f.get("codec").map(String::as_str).unwrap_or("rlev2"))?;
    let size = parse_size(f.get("size").map(String::as_str).unwrap_or("16M"))?;
    let workers: usize = f.get("workers").map(|s| s.parse().unwrap_or(8)).unwrap_or(8);
    let data = d.generate(size);
    let container =
        codag::bench_harness::compress_dataset(&data, d, codec).map_err(|e| e.to_string())?;
    let mut registry = Registry::new();
    registry.insert(d.name(), container);
    let svc = Service::new(&registry, None, ServiceConfig { workers, hybrid: false, paranoid: false });
    eprintln!(
        "serving {} ({} bytes, {}): '<id> <offset> <len>' per line on stdin",
        d.name(),
        data.len(),
        codec.name()
    );
    let stdin = std::io::stdin();
    for line in stdin.lock().lines() {
        let line = line.map_err(|e| e.to_string())?;
        let parts: Vec<&str> = line.split_whitespace().collect();
        if parts.len() != 3 {
            eprintln!("want: <id> <offset> <len>");
            continue;
        }
        let req = Request {
            id: parts[0].parse().map_err(|_| "bad id")?,
            dataset: d.name().to_string(),
            offset: parts[1].parse().map_err(|_| "bad offset")?,
            len: parts[2].parse().map_err(|_| "bad len")?,
        };
        let (responses, stats) = svc.serve_batch(&[req]);
        let r = &responses[0];
        match &r.data {
            Ok(bytes) => println!(
                "id={} {} bytes in {}us (p50 {}us)",
                r.id,
                bytes.len(),
                r.latency.as_micros(),
                stats.percentile_us(50.0)
            ),
            Err(e) => println!("id={} error: {e}", r.id),
        }
    }
    Ok(())
}

/// `codag serve --port …`: the long-lived TCP daemon (server::daemon).
fn cmd_serve_daemon(f: &HashMap<String, String>) -> Result<(), String> {
    let port: u16 = get(f, "port")?.parse().map_err(|_| "bad --port")?;
    let codec = parse_codec(f.get("codec").map(String::as_str).unwrap_or("rlev2"))?;
    let size = parse_size(f.get("size").map(String::as_str).unwrap_or("16M"))?;
    let mut registry = Registry::new();
    // File-backed datasets: every <name>.codag in --data-dir is opened
    // (header + index validated, payload stays on disk) and served
    // under its file stem.
    if let Some(dir) = f.get("data-dir") {
        let loaded = codag::server::store::load_dir(dir).map_err(|e| e.to_string())?;
        if loaded.is_empty() {
            return Err(format!("no .codag container files in {dir}"));
        }
        for (name, fd) in loaded {
            eprintln!(
                "loaded {name} from {}: {} bytes uncompressed ({}, {} chunks, lazy payload)",
                fd.path().display(),
                fd.total_uncompressed(),
                fd.codec().name(),
                fd.n_chunks()
            );
            registry.insert_source(name, DatasetSource::File(fd));
        }
    }
    // Synthetic datasets (generated + compressed at startup) stay
    // available behind --datasets for smoke tests; the legacy singular
    // --dataset spelling is accepted too. With neither flag and no
    // --data-dir, default to MC0 (back-compat).
    let synth = f.get("datasets").or_else(|| f.get("dataset")).map(String::as_str);
    let synth = match (synth, f.contains_key("data-dir")) {
        (Some(list), _) => list,
        (None, false) => "MC0",
        (None, true) => "",
    };
    for name in synth.split(',').filter(|s| !s.is_empty()) {
        let d = Dataset::parse(name).ok_or_else(|| format!("unknown dataset '{name}'"))?;
        let data = d.generate(size);
        let container =
            codag::bench_harness::compress_dataset(&data, d, codec).map_err(|e| e.to_string())?;
        eprintln!(
            "loaded {}: {} -> {} bytes ({}, {} chunks)",
            d.name(),
            data.len(),
            container.compressed_len(),
            codec.name(),
            container.n_chunks()
        );
        registry.insert(d.name(), container);
    }
    if registry.names().is_empty() {
        return Err("no datasets loaded (check --datasets / --data-dir)".into());
    }
    let mut config = daemon::DaemonConfig::default();
    if let Some(s) = f.get("shards") {
        config.shards = s.parse().map_err(|_| "bad --shards")?;
    }
    if let Some(s) = f.get("depth") {
        config.queue_depth = s.parse().map_err(|_| "bad --depth")?;
    }
    if let Some(s) = f.get("workers") {
        config.workers_per_shard = s.parse().map_err(|_| "bad --workers")?;
    }
    if let Some(s) = f.get("cache") {
        config.cache_bytes = parse_size(s)?;
    }
    if let Some(s) = f.get("net-model") {
        config.net_model = daemon::NetModel::parse(s)
            .ok_or_else(|| format!("bad --net-model '{s}' (want evented|threads)"))?;
    }
    // Re-verify content checksums even on cache hits (defends against
    // in-memory corruption at the cost of one CRC pass per hit).
    config.paranoid = f.contains_key("paranoid");
    // Loopback by default: the wire protocol has no auth (Shutdown is a
    // single unauthenticated frame), so exposing it wider is opt-in.
    let bind = f.get("bind").map(String::as_str).unwrap_or("127.0.0.1");
    // Bare IPv6 literals need brackets before the port.
    let addr = if bind.contains(':') && !bind.starts_with('[') {
        format!("[{bind}]:{port}")
    } else {
        format!("{bind}:{port}")
    };
    // A per-shard budget below the chunk size can never hold a chunk:
    // warn rather than run a structurally dead cache that still counts
    // misses.
    if config.cache_bytes > 0 {
        let max_chunk = registry
            .names()
            .iter()
            .filter_map(|n| registry.get(n).ok().map(|c| c.chunk_size()))
            .max()
            .unwrap_or(0);
        if config.cache_bytes / config.shards.max(1) < max_chunk {
            eprintln!(
                "warning: --cache {} over {} shards gives {} bytes/shard, below the {} byte \
                 chunk size — no chunk will ever be cached (use --cache 0 to disable, or \
                 raise the budget)",
                config.cache_bytes,
                config.shards.max(1),
                config.cache_bytes / config.shards.max(1),
                max_chunk
            );
        }
    }
    let handle =
        daemon::start(Arc::new(registry), config, &addr).map_err(|e| e.to_string())?;
    eprintln!(
        "codag-serve listening on {} ({} shards, depth {}, {} workers/shard, cache {} MiB, \
         {} net front)",
        handle.addr(),
        config.shards,
        config.queue_depth,
        config.workers_per_shard,
        config.cache_bytes / (1024 * 1024),
        match config.net_model {
            daemon::NetModel::Evented => "evented",
            daemon::NetModel::Threads => "threaded",
        }
    );
    eprintln!("stop with: codag loadgen --addr 127.0.0.1:{port} --shutdown");
    let cache = handle.cache_arc();
    // Grab the registry before `wait` consumes the handle: the shutdown
    // summary's percentiles come from the daemon-wide request histogram
    // (DESIGN.md §10) when recording is compiled in, falling back to
    // the reservoir estimate otherwise.
    let metrics = handle.metrics_arc();
    let stats = handle.wait().map_err(|e| e.to_string())?;
    let hist = metrics.request_us();
    let (p50, p99) = if codag::obs::ENABLED && hist.count() > 0 {
        (hist.percentile_us(50.0), hist.percentile_us(99.0))
    } else {
        (stats.percentile_us(50.0), stats.percentile_us(99.0))
    };
    eprintln!(
        "served {} requests, {} bytes: p50={p50}us p99={p99}us cache hits={} misses={} \
         evictions={} admit-declines={} ghost-hits={} checksum-mismatches={}",
        stats.count(),
        stats.total_bytes(),
        stats.cache_hits(),
        stats.cache_misses(),
        cache.evictions(),
        cache.admit_declines(),
        cache.ghost_hits(),
        stats.integrity_failures()
    );
    let per_codec = stats
        .codec_bytes_all()
        .iter()
        .map(|(name, bytes)| format!("{name}={bytes}"))
        .collect::<Vec<_>>()
        .join(" ");
    eprintln!("decoded bytes by codec: {per_codec}");
    Ok(())
}

/// `codag stat --addr …`: scrape a live daemon's metrics exposition
/// (the wire `Metrics` request) and print it verbatim — per-dataset
/// counters, stage histograms, and the slowlog (DESIGN.md §10).
fn cmd_stat(f: &HashMap<String, String>) -> Result<(), String> {
    let addr = get(f, "addr")?;
    let text = loadgen::metrics(addr).map_err(|e| e.to_string())?;
    print!("{text}");
    Ok(())
}

/// `codag loadgen`: hammer a daemon (or `--shutdown` to stop one).
fn cmd_loadgen(f: &HashMap<String, String>) -> Result<(), String> {
    let addr = get(f, "addr")?.to_string();
    if f.contains_key("shutdown") {
        loadgen::shutdown(&addr).map_err(|e| e.to_string())?;
        println!("shutdown acknowledged by {addr}");
        return Ok(());
    }
    let mut cfg = loadgen::LoadgenConfig { addr, ..Default::default() };
    if let Some(d) = f.get("dataset") {
        // Canonicalize known paper datasets (serve registers them under
        // Dataset::name(), e.g. "MC0") so `--dataset mc0` matches; any
        // other name goes on the wire verbatim.
        cfg.dataset = match Dataset::parse(d) {
            Some(known) => known.name().to_string(),
            None => d.clone(),
        };
    }
    if f.contains_key("probe-expired") {
        loadgen::probe_expired(&cfg.addr, &cfg.dataset).map_err(|e| e.to_string())?;
        println!("deadline-expiry probe: got Expired as required");
        return Ok(());
    }
    if let Some(s) = f.get("connections") {
        cfg.connections = s.parse().map_err(|_| "bad --connections")?;
    }
    if let Some(s) = f.get("requests") {
        cfg.requests = s.parse().map_err(|_| "bad --requests")?;
    }
    if let Some(s) = f.get("maxlen") {
        cfg.max_len = parse_size(s)? as u64;
    }
    if let Some(s) = f.get("seed") {
        cfg.seed = s.parse().map_err(|_| "bad --seed")?;
    }
    if let Some(s) = f.get("pipeline") {
        cfg.pipeline = s.parse().map_err(|_| "bad --pipeline")?;
    }
    if let Some(s) = f.get("deadline-ms") {
        cfg.deadline_ms = s.parse().map_err(|_| "bad --deadline-ms")?;
    }
    cfg.scrape = f.contains_key("scrape");
    cfg.verify_frames = f.contains_key("verify-frames");
    if f.contains_key("ablate-batch") {
        // §V-F through the daemon: sweep pipeline depths {1, 8, 32}
        // (the shard workers' effective batch size) and emit the
        // EXPERIMENTS.md §4 table.
        let table = loadgen::run_ablation(&cfg).map_err(|e| e.to_string())?;
        print!("{table}");
        return Ok(());
    }
    let report = loadgen::run(&cfg).map_err(|e| e.to_string())?;
    print!("{report}");
    if cfg.scrape {
        match &report.mid_run_metrics {
            Some(text) => print!("{text}"),
            None => return Err("every mid-run metrics scrape failed".into()),
        }
    }
    // Exit nonzero when nothing succeeded so CI smoke steps that gate
    // on this command actually verify a served request.
    if report.ok == 0 {
        return Err("no successful requests".into());
    }
    Ok(())
}
