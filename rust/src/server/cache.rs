//! Sharded LRU cache of hot *decompressed* chunks, with ghost-LRU
//! admission.
//!
//! Keyed by `(dataset, chunk index)` with a byte-budget capacity split
//! evenly across shards: ranged requests that repeatedly touch the same
//! 128 KiB chunk skip re-inflation entirely. Values are `Arc<[u8]>`
//! built once from the decoding worker's scratch buffer, so retaining
//! a chunk never duplicates the decoded buffer afterwards (responses
//! copy only the requested span out of the cached chunk). Recency is a
//! per-shard logical clock; eviction
//! removes the least-recently-touched entry until the shard is back
//! under budget.
//!
//! **Admission** ([`ChunkCache::admit`]) is second-chance on key
//! history: each shard keeps a bounded FIFO *ghost* of key hashes it
//! has recently seen (first touches and evicted residents). A key is
//! admitted only when it is already in the ghost — so a one-pass cold
//! scan records every key once and inserts nothing, leaving the
//! resident hot set untouched, while anything re-requested (or
//! recently evicted) is admitted on its second touch (DESIGN.md §6.2).
//! `insert` itself stays unconditional: admission is the caller
//! protocol (the decode path asks `admit` before paying the `Arc`
//! copy). Hit/miss/eviction/ghost counters are atomics, surfaced
//! through `LatencyStats` and the wire `Stat` payload by the daemon.

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// FNV-1a over `bytes` (stable across runs/platforms — used for shard
/// selection by both the cache and the daemon's queue router).
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

/// Ghost entries retained per shard. Sized for ~32× the resident chunk
/// count at default budgets (64 MiB / 4 shards / 128 KiB chunks ≈ 128
/// resident entries per shard), so second touches survive long cold
/// scans between them; memory cost is ~32 bytes per entry (generation-
/// tagged FIFO deque + membership map, deque hard-bounded at 2× the
/// cap).
const GHOST_CAP_PER_SHARD: usize = 4096;

#[derive(Debug)]
struct Entry {
    data: Arc<[u8]>,
    stamp: u64,
}

#[derive(Debug, Default)]
struct Shard {
    /// dataset → chunk index → entry (two levels so lookups by `&str`
    /// never allocate a key).
    per_dataset: HashMap<String, HashMap<usize, Entry>>,
    bytes: u64,
    clock: u64,
    /// FIFO of `(key hash, generation)` ghost entries; oldest live
    /// entries fall off past [`GHOST_CAP_PER_SHARD`]. Entries consumed
    /// by [`Shard::ghost_take`] go stale in place (membership lives in
    /// `ghost_members`) and are reclaimed when they reach the front —
    /// both ghost operations are O(1) amortized, since they run under
    /// the shard lock on the cache-miss decode path. The generation
    /// tag makes stale detection exact: a popped entry only evicts the
    /// key if the membership still carries the same generation, so a
    /// key re-remembered after a take cannot lose its *live* entry to
    /// its own stale leftover.
    ghost: VecDeque<(u64, u64)>,
    /// Live ghost membership: key → generation of its one live deque
    /// entry (the deque may additionally hold stale entries, bounded
    /// at 2× the cap by `ghost_remember`).
    ghost_members: HashMap<u64, u64>,
    /// Monotonic generation counter for ghost entries.
    ghost_gen: u64,
}

impl Shard {
    /// Record a key hash in the ghost (no-op if already present).
    fn ghost_remember(&mut self, key: u64) {
        if self.ghost_members.contains_key(&key) {
            return;
        }
        self.ghost_gen += 1;
        self.ghost_members.insert(key, self.ghost_gen);
        self.ghost.push_back((key, self.ghost_gen));
        // FIFO-evict remembered keys past the cap; stale entries hit
        // on the way out are reclaimed for free (their generation no
        // longer matches). The 2× deque bound compacts stale buildup
        // from take/re-remember cycles even while the live set stays
        // small.
        while self.ghost_members.len() > GHOST_CAP_PER_SHARD
            || self.ghost.len() > 2 * GHOST_CAP_PER_SHARD
        {
            match self.ghost.pop_front() {
                Some((k, gen)) => {
                    if self.ghost_members.get(&k) == Some(&gen) {
                        self.ghost_members.remove(&k);
                    }
                }
                None => break,
            }
        }
    }

    /// Remove `key` from the ghost, reporting whether it was present
    /// (a second touch — the admission signal). O(1): only membership
    /// is dropped; the deque entry goes stale and is reclaimed later.
    fn ghost_take(&mut self, key: u64) -> bool {
        self.ghost_members.remove(&key).is_some()
    }

    fn evict_one(&mut self) -> u64 {
        // O(entries) scan; shards hold at most budget/chunk_size
        // entries (a few hundred at defaults), and eviction only runs
        // on insert overflow. The victim key is borrowed during the
        // scan and cloned exactly once.
        let mut victim: Option<(u64, &String, usize)> = None;
        for (ds, chunks) in &self.per_dataset {
            for (&ci, e) in chunks {
                if victim.map_or(true, |(stamp, _, _)| e.stamp < stamp) {
                    victim = Some((e.stamp, ds, ci));
                }
            }
        }
        let Some((_, ds, ci)) = victim else { return 0 };
        let ds = ds.clone();
        let mut freed = 0;
        if let Some(chunks) = self.per_dataset.get_mut(&ds) {
            if let Some(e) = chunks.remove(&ci) {
                freed = e.data.len() as u64;
                self.bytes -= freed;
            }
            if chunks.is_empty() {
                self.per_dataset.remove(&ds);
            }
        }
        // Second chance: an evicted resident goes straight into the
        // ghost, so a re-request readmits it without a decline cycle.
        self.ghost_remember(key_hash(&ds, ci));
        freed
    }
}

/// Stable hash of a `(dataset, chunk)` cache key (shard selection and
/// ghost identity both use it).
fn key_hash(dataset: &str, chunk: usize) -> u64 {
    fnv1a(dataset.as_bytes()) ^ (chunk as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)
}

/// Sharded byte-budgeted LRU of decompressed chunks.
#[derive(Debug)]
pub struct ChunkCache {
    shards: Vec<Mutex<Shard>>,
    shard_budget: u64,
    hits: AtomicU64,
    misses: AtomicU64,
    evictions: AtomicU64,
    ghost_hits: AtomicU64,
    admit_declines: AtomicU64,
}

impl ChunkCache {
    /// Cache with `budget_bytes` total capacity split across `shards`
    /// locks. A zero budget disables caching (every insert is dropped;
    /// every get is a miss).
    pub fn new(budget_bytes: usize, shards: usize) -> ChunkCache {
        let n = shards.max(1);
        ChunkCache {
            shards: (0..n).map(|_| Mutex::new(Shard::default())).collect(),
            shard_budget: (budget_bytes / n) as u64,
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
            ghost_hits: AtomicU64::new(0),
            admit_declines: AtomicU64::new(0),
        }
    }

    fn shard_for(&self, dataset: &str, chunk: usize) -> usize {
        (key_hash(dataset, chunk) % self.shards.len() as u64) as usize
    }

    /// Look up a decompressed chunk, refreshing its recency. Counts a
    /// hit or a miss.
    ///
    /// Integrity note (DESIGN.md §13): entries were content-verified at
    /// fill time (every cache miss decodes through a checksum-checking
    /// path), so hits are served without re-hashing. A daemon started
    /// with `--paranoid` re-verifies each hit against the packed
    /// checksum in the service layer, catching in-memory corruption of
    /// resident entries.
    pub fn get(&self, dataset: &str, chunk: usize) -> Option<Arc<[u8]>> {
        let si = self.shard_for(dataset, chunk);
        let mut shard = self.shards[si].lock().unwrap();
        shard.clock += 1;
        let stamp = shard.clock;
        let found = shard
            .per_dataset
            .get_mut(dataset)
            .and_then(|chunks| chunks.get_mut(&chunk))
            .map(|e| {
                e.stamp = stamp;
                e.data.clone()
            });
        drop(shard);
        match found {
            Some(data) => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                Some(data)
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    /// Could a chunk of `len` bytes ever be cached? (Pure budget
    /// check; admission policy is [`ChunkCache::admit`].)
    pub fn accepts(&self, len: usize) -> bool {
        len > 0 && len as u64 <= self.shard_budget
    }

    /// Ghost-LRU admission decision for a chunk about to be inserted.
    /// Returns `true` when the insert should proceed (the caller then
    /// pays the `Arc` build and calls [`ChunkCache::insert`]):
    ///
    /// * the chunk is already resident (refresh/replace path), or
    /// * its key is in the ghost — a second touch (counted as a ghost
    ///   hit; the key is consumed from the ghost).
    ///
    /// A first touch records the key in the ghost and declines
    /// (counted), so a one-pass cold scan cannot evict the hot set.
    /// Chunks the budget can never hold decline without ghost traffic.
    pub fn admit(&self, dataset: &str, chunk: usize, len: usize) -> bool {
        if !self.accepts(len) {
            return false;
        }
        let key = key_hash(dataset, chunk);
        let si = self.shard_for(dataset, chunk);
        let mut shard = self.shards[si].lock().unwrap();
        if shard.per_dataset.get(dataset).is_some_and(|c| c.contains_key(&chunk)) {
            return true;
        }
        if shard.ghost_take(key) {
            drop(shard);
            self.ghost_hits.fetch_add(1, Ordering::Relaxed);
            true
        } else {
            shard.ghost_remember(key);
            drop(shard);
            self.admit_declines.fetch_add(1, Ordering::Relaxed);
            false
        }
    }

    /// Insert a decompressed chunk, evicting least-recently-used
    /// entries until the shard fits its budget. Chunks larger than one
    /// shard's budget (and empty chunks) are not cached.
    pub fn insert(&self, dataset: &str, chunk: usize, data: Arc<[u8]>) {
        let len = data.len() as u64;
        if len == 0 || len > self.shard_budget {
            return;
        }
        let si = self.shard_for(dataset, chunk);
        let mut shard = self.shards[si].lock().unwrap();
        shard.clock += 1;
        let stamp = shard.clock;
        let old = shard
            .per_dataset
            .entry(dataset.to_string())
            .or_default()
            .insert(chunk, Entry { data, stamp });
        if let Some(old) = old {
            shard.bytes -= old.data.len() as u64;
        }
        shard.bytes += len;
        while shard.bytes > self.shard_budget {
            if shard.evict_one() == 0 {
                break; // defensive: nothing evictable
            }
            self.evictions.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Cache hits since construction.
    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    /// Cache misses since construction.
    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    /// Evicted entries since construction.
    pub fn evictions(&self) -> u64 {
        self.evictions.load(Ordering::Relaxed)
    }

    /// Admissions granted because the key was in the ghost (second
    /// touch) since construction.
    pub fn ghost_hits(&self) -> u64 {
        self.ghost_hits.load(Ordering::Relaxed)
    }

    /// Admissions declined (first touch of a key) since construction.
    pub fn admit_declines(&self) -> u64 {
        self.admit_declines.load(Ordering::Relaxed)
    }

    /// Bytes currently resident across all shards.
    pub fn resident_bytes(&self) -> u64 {
        self.shards.iter().map(|s| s.lock().unwrap().bytes).sum()
    }

    /// Entries currently resident across all shards.
    pub fn entries(&self) -> usize {
        self.shards
            .iter()
            .map(|s| {
                s.lock().unwrap().per_dataset.values().map(|c| c.len()).sum::<usize>()
            })
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn chunk(fill: u8, len: usize) -> Arc<[u8]> {
        Arc::from(vec![fill; len])
    }

    #[test]
    fn hit_and_miss_counting() {
        let c = ChunkCache::new(1 << 20, 1);
        assert!(c.get("a", 0).is_none());
        assert_eq!((c.hits(), c.misses()), (0, 1));
        c.insert("a", 0, chunk(7, 100));
        let got = c.get("a", 0).unwrap();
        assert_eq!(&got[..], &[7u8; 100][..]);
        assert_eq!((c.hits(), c.misses()), (1, 1));
        // Same chunk index under a different dataset is distinct.
        assert!(c.get("b", 0).is_none());
        assert_eq!(c.entries(), 1);
        assert_eq!(c.resident_bytes(), 100);
    }

    #[test]
    fn lru_eviction_respects_budget_and_recency() {
        // Budget fits exactly two 100-byte chunks (single shard).
        let c = ChunkCache::new(200, 1);
        c.insert("a", 0, chunk(1, 100));
        c.insert("a", 1, chunk(2, 100));
        // Touch chunk 0 so chunk 1 is the LRU victim.
        assert!(c.get("a", 0).is_some());
        c.insert("a", 2, chunk(3, 100));
        assert_eq!(c.evictions(), 1);
        assert_eq!(c.resident_bytes(), 200);
        assert!(c.get("a", 0).is_some(), "recently-touched survives");
        assert!(c.get("a", 1).is_none(), "LRU evicted");
        assert!(c.get("a", 2).is_some());
    }

    #[test]
    fn oversized_and_zero_budget_inserts_dropped() {
        let c = ChunkCache::new(100, 1);
        c.insert("a", 0, chunk(1, 101));
        assert_eq!(c.entries(), 0);
        let disabled = ChunkCache::new(0, 4);
        disabled.insert("a", 0, chunk(1, 10));
        assert_eq!(disabled.entries(), 0);
        assert!(disabled.get("a", 0).is_none());
    }

    #[test]
    fn accepts_mirrors_insert_policy() {
        let c = ChunkCache::new(100, 1);
        assert!(c.accepts(100));
        assert!(!c.accepts(101));
        assert!(!c.accepts(0));
        assert!(!ChunkCache::new(0, 1).accepts(1));
    }

    #[test]
    fn replacement_updates_accounting() {
        let c = ChunkCache::new(1000, 1);
        c.insert("a", 0, chunk(1, 100));
        c.insert("a", 0, chunk(2, 300));
        assert_eq!(c.entries(), 1);
        assert_eq!(c.resident_bytes(), 300);
        assert_eq!(c.get("a", 0).unwrap()[0], 2);
    }

    #[test]
    fn admission_declines_first_touch_admits_second() {
        let c = ChunkCache::new(1 << 20, 1);
        // First touch: declined, key recorded in the ghost.
        assert!(!c.admit("a", 0, 100));
        assert_eq!((c.admit_declines(), c.ghost_hits()), (1, 0));
        assert_eq!(c.entries(), 0);
        // Second touch: ghost hit, admitted.
        assert!(c.admit("a", 0, 100));
        assert_eq!((c.admit_declines(), c.ghost_hits()), (1, 1));
        c.insert("a", 0, chunk(7, 100));
        // Resident key: re-admission is free (refresh path).
        assert!(c.admit("a", 0, 100));
        assert_eq!((c.admit_declines(), c.ghost_hits()), (1, 1));
        // A different key starts its own first-touch cycle.
        assert!(!c.admit("a", 1, 100));
        assert_eq!(c.admit_declines(), 2);
    }

    #[test]
    fn cold_scan_cannot_evict_hot_set() {
        // Hot set: two admitted 100-byte chunks filling the budget.
        let c = ChunkCache::new(200, 1);
        for ci in 0..2 {
            assert!(!c.admit("hot", ci, 100));
            assert!(c.admit("hot", ci, 100));
            c.insert("hot", ci, chunk(1, 100));
        }
        assert_eq!(c.entries(), 2);
        // One-pass cold scan over 50 distinct keys: every admit is a
        // declined first touch, nothing is inserted, nothing evicted.
        for ci in 0..50 {
            assert!(!c.admit("scan", ci, 100));
        }
        assert_eq!(c.entries(), 2);
        assert_eq!(c.evictions(), 0);
        assert!(c.get("hot", 0).is_some() && c.get("hot", 1).is_some());
    }

    #[test]
    fn evicted_resident_readmits_via_ghost() {
        let c = ChunkCache::new(200, 1);
        for ci in 0..2 {
            assert!(!c.admit("a", ci, 100));
            assert!(c.admit("a", ci, 100));
            c.insert("a", ci, chunk(ci as u8, 100));
        }
        // Admit a third chunk (two touches) — evicts the LRU resident.
        assert!(!c.admit("a", 2, 100));
        assert!(c.admit("a", 2, 100));
        c.insert("a", 2, chunk(2, 100));
        assert_eq!(c.evictions(), 1);
        assert!(c.get("a", 0).is_none(), "chunk 0 was the LRU victim");
        // The evicted key went straight to the ghost: one admit call
        // readmits it (no first-touch decline cycle).
        let declines = c.admit_declines();
        assert!(c.admit("a", 0, 100), "evicted resident must readmit immediately");
        assert_eq!(c.admit_declines(), declines);
    }

    #[test]
    fn ghost_at_cap_pops_stale_entries_without_evicting_live_twins() {
        // Key A is remembered, consumed (its deque entry goes stale),
        // then re-remembered behind key B. When a flood pushes the
        // ghost membership past its cap, the FIFO must reclaim A's
        // *stale* front entry without stripping A's live membership
        // (generation tags make the distinction exact); B, the oldest
        // live entry, is the one evicted.
        let c = ChunkCache::new(1 << 30, 1);
        assert!(!c.admit("a", 0, 100)); // remember A
        assert!(c.admit("a", 0, 100)); // take A: deque entry now stale
        assert!(!c.admit("a", 1, 100)); // remember B
        assert!(!c.admit("a", 0, 100)); // re-remember A (live, behind B)
        // Flood with distinct keys until membership exceeds the cap.
        for ci in 2..(2 + GHOST_CAP_PER_SHARD - 1) {
            assert!(!c.admit("a", ci, 100));
        }
        // A must still be a second-touch admit; with naive stale
        // handling its membership would have been stripped when the
        // stale front entry was popped.
        assert!(c.admit("a", 0, 100), "live re-remembered key must survive its stale twin");
        // B was the oldest live entry and was FIFO-evicted at cap.
        assert!(!c.admit("a", 1, 100), "oldest live key is the one the cap evicts");
    }

    #[test]
    fn oversized_admit_declines_without_ghost_traffic() {
        let c = ChunkCache::new(100, 1);
        assert!(!c.admit("a", 0, 101));
        assert!(!c.admit("a", 0, 101), "oversized keys never reach the ghost");
        assert_eq!((c.ghost_hits(), c.admit_declines()), (0, 0));
        assert!(!ChunkCache::new(0, 1).admit("a", 0, 1));
    }

    #[test]
    fn fnv1a_stable() {
        // Pinned values keep shard placement stable across builds.
        assert_eq!(fnv1a(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a(b"a"), 0xaf63_dc4c_8601_ec8c);
    }
}
