//! Sharded LRU cache of hot *decompressed* chunks.
//!
//! Keyed by `(dataset, chunk index)` with a byte-budget capacity split
//! evenly across shards: ranged requests that repeatedly touch the same
//! 128 KiB chunk skip re-inflation entirely. Values are `Arc<[u8]>`
//! built once from the decoding worker's scratch buffer, so retaining
//! a chunk never duplicates the decoded buffer afterwards (responses
//! copy only the requested span out of the cached chunk). Recency is a
//! per-shard logical clock; eviction
//! removes the least-recently-touched entry until the shard is back
//! under budget. Hit/miss/eviction counters are atomics, surfaced
//! through `LatencyStats` by the daemon (DESIGN.md §6.2).

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// FNV-1a over `bytes` (stable across runs/platforms — used for shard
/// selection by both the cache and the daemon's queue router).
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

#[derive(Debug)]
struct Entry {
    data: Arc<[u8]>,
    stamp: u64,
}

#[derive(Debug, Default)]
struct Shard {
    /// dataset → chunk index → entry (two levels so lookups by `&str`
    /// never allocate a key).
    per_dataset: HashMap<String, HashMap<usize, Entry>>,
    bytes: u64,
    clock: u64,
}

impl Shard {
    fn evict_one(&mut self) -> u64 {
        // O(entries) scan; shards hold at most budget/chunk_size
        // entries (a few hundred at defaults), and eviction only runs
        // on insert overflow. The victim key is borrowed during the
        // scan and cloned exactly once.
        let mut victim: Option<(u64, &String, usize)> = None;
        for (ds, chunks) in &self.per_dataset {
            for (&ci, e) in chunks {
                if victim.map_or(true, |(stamp, _, _)| e.stamp < stamp) {
                    victim = Some((e.stamp, ds, ci));
                }
            }
        }
        let Some((_, ds, ci)) = victim else { return 0 };
        let ds = ds.clone();
        let mut freed = 0;
        if let Some(chunks) = self.per_dataset.get_mut(&ds) {
            if let Some(e) = chunks.remove(&ci) {
                freed = e.data.len() as u64;
                self.bytes -= freed;
            }
            if chunks.is_empty() {
                self.per_dataset.remove(&ds);
            }
        }
        freed
    }
}

/// Sharded byte-budgeted LRU of decompressed chunks.
#[derive(Debug)]
pub struct ChunkCache {
    shards: Vec<Mutex<Shard>>,
    shard_budget: u64,
    hits: AtomicU64,
    misses: AtomicU64,
    evictions: AtomicU64,
}

impl ChunkCache {
    /// Cache with `budget_bytes` total capacity split across `shards`
    /// locks. A zero budget disables caching (every insert is dropped;
    /// every get is a miss).
    pub fn new(budget_bytes: usize, shards: usize) -> ChunkCache {
        let n = shards.max(1);
        ChunkCache {
            shards: (0..n).map(|_| Mutex::new(Shard::default())).collect(),
            shard_budget: (budget_bytes / n) as u64,
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
        }
    }

    fn shard_for(&self, dataset: &str, chunk: usize) -> usize {
        let h = fnv1a(dataset.as_bytes()) ^ (chunk as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        (h % self.shards.len() as u64) as usize
    }

    /// Look up a decompressed chunk, refreshing its recency. Counts a
    /// hit or a miss.
    pub fn get(&self, dataset: &str, chunk: usize) -> Option<Arc<[u8]>> {
        let si = self.shard_for(dataset, chunk);
        let mut shard = self.shards[si].lock().unwrap();
        shard.clock += 1;
        let stamp = shard.clock;
        let found = shard
            .per_dataset
            .get_mut(dataset)
            .and_then(|chunks| chunks.get_mut(&chunk))
            .map(|e| {
                e.stamp = stamp;
                e.data.clone()
            });
        drop(shard);
        match found {
            Some(data) => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                Some(data)
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    /// Would a chunk of `len` bytes be cached? (Callers use this to
    /// skip the `Arc`-wrap + copy on the decode path when the cache
    /// would drop the chunk anyway.)
    pub fn accepts(&self, len: usize) -> bool {
        len > 0 && len as u64 <= self.shard_budget
    }

    /// Insert a decompressed chunk, evicting least-recently-used
    /// entries until the shard fits its budget. Chunks larger than one
    /// shard's budget (and empty chunks) are not cached.
    pub fn insert(&self, dataset: &str, chunk: usize, data: Arc<[u8]>) {
        let len = data.len() as u64;
        if len == 0 || len > self.shard_budget {
            return;
        }
        let si = self.shard_for(dataset, chunk);
        let mut shard = self.shards[si].lock().unwrap();
        shard.clock += 1;
        let stamp = shard.clock;
        let old = shard
            .per_dataset
            .entry(dataset.to_string())
            .or_default()
            .insert(chunk, Entry { data, stamp });
        if let Some(old) = old {
            shard.bytes -= old.data.len() as u64;
        }
        shard.bytes += len;
        while shard.bytes > self.shard_budget {
            if shard.evict_one() == 0 {
                break; // defensive: nothing evictable
            }
            self.evictions.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Cache hits since construction.
    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    /// Cache misses since construction.
    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    /// Evicted entries since construction.
    pub fn evictions(&self) -> u64 {
        self.evictions.load(Ordering::Relaxed)
    }

    /// Bytes currently resident across all shards.
    pub fn resident_bytes(&self) -> u64 {
        self.shards.iter().map(|s| s.lock().unwrap().bytes).sum()
    }

    /// Entries currently resident across all shards.
    pub fn entries(&self) -> usize {
        self.shards
            .iter()
            .map(|s| {
                s.lock().unwrap().per_dataset.values().map(|c| c.len()).sum::<usize>()
            })
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn chunk(fill: u8, len: usize) -> Arc<[u8]> {
        Arc::from(vec![fill; len])
    }

    #[test]
    fn hit_and_miss_counting() {
        let c = ChunkCache::new(1 << 20, 1);
        assert!(c.get("a", 0).is_none());
        assert_eq!((c.hits(), c.misses()), (0, 1));
        c.insert("a", 0, chunk(7, 100));
        let got = c.get("a", 0).unwrap();
        assert_eq!(&got[..], &[7u8; 100][..]);
        assert_eq!((c.hits(), c.misses()), (1, 1));
        // Same chunk index under a different dataset is distinct.
        assert!(c.get("b", 0).is_none());
        assert_eq!(c.entries(), 1);
        assert_eq!(c.resident_bytes(), 100);
    }

    #[test]
    fn lru_eviction_respects_budget_and_recency() {
        // Budget fits exactly two 100-byte chunks (single shard).
        let c = ChunkCache::new(200, 1);
        c.insert("a", 0, chunk(1, 100));
        c.insert("a", 1, chunk(2, 100));
        // Touch chunk 0 so chunk 1 is the LRU victim.
        assert!(c.get("a", 0).is_some());
        c.insert("a", 2, chunk(3, 100));
        assert_eq!(c.evictions(), 1);
        assert_eq!(c.resident_bytes(), 200);
        assert!(c.get("a", 0).is_some(), "recently-touched survives");
        assert!(c.get("a", 1).is_none(), "LRU evicted");
        assert!(c.get("a", 2).is_some());
    }

    #[test]
    fn oversized_and_zero_budget_inserts_dropped() {
        let c = ChunkCache::new(100, 1);
        c.insert("a", 0, chunk(1, 101));
        assert_eq!(c.entries(), 0);
        let disabled = ChunkCache::new(0, 4);
        disabled.insert("a", 0, chunk(1, 10));
        assert_eq!(disabled.entries(), 0);
        assert!(disabled.get("a", 0).is_none());
    }

    #[test]
    fn accepts_mirrors_insert_policy() {
        let c = ChunkCache::new(100, 1);
        assert!(c.accepts(100));
        assert!(!c.accepts(101));
        assert!(!c.accepts(0));
        assert!(!ChunkCache::new(0, 1).accepts(1));
    }

    #[test]
    fn replacement_updates_accounting() {
        let c = ChunkCache::new(1000, 1);
        c.insert("a", 0, chunk(1, 100));
        c.insert("a", 0, chunk(2, 300));
        assert_eq!(c.entries(), 1);
        assert_eq!(c.resident_bytes(), 300);
        assert_eq!(c.get("a", 0).unwrap()[0], 2);
    }

    #[test]
    fn fnv1a_stable() {
        // Pinned values keep shard placement stable across builds.
        assert_eq!(fnv1a(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a(b"a"), 0xaf63_dc4c_8601_ec8c);
    }
}
