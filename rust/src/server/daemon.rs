//! The long-lived `codag-serve` daemon.
//!
//! Architecture (DESIGN.md §6, §11): one shard-worker decode pool
//! behind either of two interchangeable network fronts.
//!
//! ```text
//! evented (default, unix — DESIGN.md §11)
//!   TcpListener + every connection socket, nonblocking, owned by ONE
//!   poll-based net-loop thread
//!     ├─ readable ⇒ FrameReader → decode_request → admit()
//!     │    └─ submission ring per shard (bounded SPSC; `try_push`
//!     │       full ⇒ immediate `Busy` response)
//!     └─ completion rings ⇒ per-connection write queues, flushed as
//!        one vectored write (28-byte stack header + shared payload)
//!        with partial-write resumption
//!
//! threads (`--net-model threads`, any platform)
//!   TcpListener (non-blocking accept loop)
//!     └─ per-connection reader thread ── FrameReader → admit()
//!          ├─ bounded sync-channel shard queue (`try_send` full ⇒
//!          │  immediate `Busy` response)
//!          └─ per-connection writer thread (response channel → socket)
//!
//! shard worker threads (one per shard, long-lived, front-agnostic)
//!   └─ own a reused `Service` (+ shared `ChunkCache`); drain their
//!      job source in FIFO order, opportunistically batching up to
//!      `DaemonConfig::batch` requests per `serve_batch` call
//! ```
//!
//! Both fronts run the same [`admit`] decision function, so the
//! admission contract — per-connection in-flight response and byte
//! budgets, per-shard queue depth, `Busy` instead of buffering — is
//! identical by construction; `rust/tests/net_evented.rs` pins
//! byte-identity between them.
//!
//! All requests for one dataset hash to one shard, so per-dataset FIFO
//! order is preserved end to end. Shutdown is a shared token: the net
//! front stops admitting, shard workers drain what was admitted and
//! exit, in-flight responses flush, and
//! [`DaemonHandle::join`]/[`DaemonHandle::wait`] joins every thread.

use crate::coordinator::router::{DatasetSource, Request};
use crate::coordinator::service::{Payload, Service, ServiceConfig};
use crate::coordinator::stats::LatencyStats;
use crate::coordinator::Registry;
use crate::obs::{
    expo, now_if_enabled, DatasetMetrics, MetricsRegistry, SlowEntry, SlowLog, Stage, SLOWLOG_CAP,
};
use crate::server::cache::{fnv1a, ChunkCache};
#[cfg(unix)]
use crate::server::net::{
    self,
    ring::{Pop, Ring},
    Waker,
};
use crate::server::proto::{
    decode_request_versioned, write_response_parts_crc, FrameReader, ReadEvent, Status,
    WireRequest, FLAG_FRAME_CRC, WIRE_VERSION,
};
use crate::{Error, Result};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::thread::{self, JoinHandle};
use std::time::{Duration, Instant};

/// Which network front multiplexes the sockets.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum NetModel {
    /// One poll-based event-loop thread owning every connection
    /// (unix; silently falls back to `Threads` elsewhere).
    #[default]
    Evented,
    /// Two OS threads (reader + writer) per connection — the legacy
    /// model, kept for differential testing (`--net-model threads`).
    Threads,
}

impl NetModel {
    /// Parse a `--net-model` CLI value.
    pub fn parse(s: &str) -> Option<NetModel> {
        match s {
            "evented" => Some(NetModel::Evented),
            "threads" | "threaded" => Some(NetModel::Threads),
            _ => None,
        }
    }
}

/// Daemon tuning knobs.
#[derive(Debug, Clone, Copy)]
pub struct DaemonConfig {
    /// Shard queues / long-lived shard worker threads.
    pub shards: usize,
    /// Admission limit: queued requests per shard before `Busy`
    /// (sync-channel bound or submission-ring capacity).
    pub queue_depth: usize,
    /// Decode workers inside each shard's `Service`.
    pub workers_per_shard: usize,
    /// Max requests folded into one `serve_batch` call.
    pub batch: usize,
    /// Unwritten responses allowed per connection before requests get
    /// `Busy`: a client that pipelines requests without reading
    /// responses cannot make the daemon buffer payloads without bound
    /// (a 4× hard cap closes the connection outright — see
    /// `conn_hard_cap`).
    pub max_inflight_per_conn: usize,
    /// Unwritten response *payload bytes* allowed per connection before
    /// Gets are refused with `Busy` (one oversized request is always
    /// admitted when nothing is outstanding, so the bound is this
    /// budget plus one frame).
    pub max_inflight_bytes_per_conn: usize,
    /// Concurrent connections accepted; excess connects are closed
    /// immediately.
    pub max_connections: usize,
    /// Total decompressed-chunk cache budget (0 disables the cache).
    pub cache_bytes: usize,
    /// Read-timeout / poll granularity at which blocked threads check
    /// the shutdown token.
    pub poll_interval: Duration,
    /// Socket write timeout (threads) / write-stall bound (evented): a
    /// stuck peer cannot wedge shutdown.
    pub write_timeout: Duration,
    /// Network front (see [`NetModel`]).
    pub net_model: NetModel,
    /// Re-verify content checksums on chunk-cache hits too
    /// (`--paranoid`): guards against in-memory corruption at the cost
    /// of a CRC pass per hit. Misses are always verified at decode.
    pub paranoid: bool,
}

impl Default for DaemonConfig {
    fn default() -> Self {
        DaemonConfig {
            shards: 4,
            queue_depth: 64,
            workers_per_shard: 2,
            batch: 32,
            max_inflight_per_conn: 64,
            max_inflight_bytes_per_conn: 64 * 1024 * 1024,
            max_connections: 1024,
            cache_bytes: 64 * 1024 * 1024,
            poll_interval: Duration::from_millis(50),
            write_timeout: Duration::from_secs(5),
            net_model: NetModel::default(),
            paranoid: false,
        }
    }
}

/// One response travelling back to its connection — over the writer
/// channel (threads) or a completion ring (evented). Carries the byte
/// charge taken at admission (debited once written; 0 for
/// reader-generated error/metadata responses) and the protocol version
/// to stamp on the wire (echoing the requester's version — a v1 client
/// rejects v2-stamped replies). The payload is a [`Payload`], so a
/// cache-hit span rides as a shared `Arc<[u8]>` slice all the way to
/// the socket write.
pub(crate) struct Outbound {
    pub(crate) id: u64,
    pub(crate) status: Status,
    pub(crate) version: u16,
    pub(crate) payload: Payload,
    pub(crate) charge: u64,
    /// The originating request set [`FLAG_FRAME_CRC`]: append a CRC32C
    /// trailer over header + payload to the response frame (v3 only).
    pub(crate) frame_crc: bool,
    /// Per-dataset metrics for shard-produced replies: the write side
    /// times the socket write into the `response_write` stage and
    /// decrements the in-flight gauge charged at admission. `None` for
    /// reader-generated error/metadata responses.
    pub(crate) obs: Option<Arc<DatasetMetrics>>,
}

/// Send a reader-generated response (no byte charge) down the threaded
/// writer channel.
fn send_reply(
    tx: &mpsc::Sender<Outbound>,
    version: u16,
    frame_crc: bool,
    id: u64,
    status: Status,
    payload: Vec<u8>,
) {
    let _ = tx.send(Outbound {
        id,
        status,
        version,
        payload: Payload::Owned(payload),
        charge: 0,
        frame_crc,
        obs: None,
    });
}

/// Shared observability handles threaded through the daemon's threads
/// (DESIGN.md §10): the per-dataset stage registry and the slowlog the
/// wire `Metrics` request renders.
#[derive(Clone)]
pub(crate) struct Obs {
    pub(crate) metrics: Arc<MetricsRegistry>,
    pub(crate) slowlog: Arc<SlowLog>,
}

/// One finished response on a completion ring, routed back to its
/// connection by the opaque token the net loop minted at admission
/// (slot index + generation, so a reused slot never receives a dead
/// connection's response).
#[cfg(unix)]
pub(crate) struct Completion {
    pub(crate) token: u64,
    pub(crate) out: Outbound,
}

/// Where a shard worker delivers a finished response: the threaded
/// per-connection writer channel, or the evented completion ring (plus
/// the waker that pops the net loop out of `poll`).
pub(crate) enum ReplySink {
    Channel(mpsc::Sender<Outbound>),
    #[cfg(unix)]
    Ring {
        token: u64,
        ring: Arc<Ring<Completion>>,
        waker: Arc<Waker>,
    },
}

impl ReplySink {
    /// Deliver one response. Both arms share drop semantics: a
    /// destination that no longer exists (disconnected channel, closed
    /// ring) swallows the response, releasing its in-flight gauge.
    pub(crate) fn send(&self, out: Outbound, obs: &Obs) {
        match self {
            ReplySink::Channel(tx) => {
                // A disconnected receiver means the connection's writer
                // exited; it already debited nothing for this response,
                // and its conn-local counters died with the connection.
                if let Err(e) = tx.send(out) {
                    if let Some(dm) = e.0.obs {
                        dm.inflight.dec();
                    }
                }
            }
            #[cfg(unix)]
            ReplySink::Ring { token, ring, waker } => {
                let nm = obs.metrics.net();
                // Gauge before push: `Gauge::dec` saturates at zero, so
                // the inc must precede the net loop's pop-side dec.
                nm.completion_ring_depth.inc();
                match ring.push_blocking(Completion { token: *token, out }) {
                    Ok(()) => waker.wake(),
                    Err(comp) => {
                        // Ring closed: the net loop has exited, the
                        // response has no destination.
                        nm.completion_ring_depth.dec();
                        if let Some(dm) = comp.out.obs {
                            dm.inflight.dec();
                        }
                    }
                }
            }
        }
    }
}

/// One admitted request, owned by a shard queue. `charge` is the byte
/// span debited from the connection's in-flight byte budget when the
/// response hits the socket; `deadline` (from the wire `deadline_ms`,
/// measured from frame decode) is checked at dequeue and between batch
/// items so an expired request never occupies a decode slot.
pub(crate) struct Job {
    pub(crate) req: Request,
    pub(crate) reply: ReplySink,
    pub(crate) received: Instant,
    pub(crate) charge: u64,
    pub(crate) deadline: Option<Instant>,
    /// Protocol version of the originating frame (echoed in the reply).
    pub(crate) version: u16,
    /// The request opted into a response frame-CRC trailer (v3).
    pub(crate) frame_crc: bool,
    /// Dataset metrics handle, resolved once at admission (`None` when
    /// recording is compiled out).
    pub(crate) dm: Option<Arc<DatasetMetrics>>,
}

/// Outcome of one [`JobSource`] fetch.
enum Fetch {
    Job(Job),
    Timeout,
    /// Producer gone (channel disconnected / ring closed) and the queue
    /// fully drained: the shard worker's exit signal.
    Closed,
}

/// Where a shard worker pulls admitted jobs from: the threaded bounded
/// sync channel, or the evented submission ring. Both drain completely
/// before reporting closure, so admitted work is never dropped at
/// shutdown.
enum JobSource {
    Channel(Receiver<Job>),
    #[cfg(unix)]
    Ring(Arc<Ring<Job>>),
}

impl JobSource {
    fn recv_timeout(&self, timeout: Duration, obs: &Obs) -> Fetch {
        match self {
            JobSource::Channel(rx) => match rx.recv_timeout(timeout) {
                Ok(j) => Fetch::Job(j),
                Err(RecvTimeoutError::Timeout) => Fetch::Timeout,
                Err(RecvTimeoutError::Disconnected) => Fetch::Closed,
            },
            #[cfg(unix)]
            JobSource::Ring(ring) => match ring.pop_timeout(timeout) {
                Pop::Item(j) => {
                    obs.metrics.net().submission_ring_depth.dec();
                    Fetch::Job(j)
                }
                Pop::Timeout => Fetch::Timeout,
                Pop::Closed => Fetch::Closed,
            },
        }
    }

    /// Non-blocking fetch for opportunistic batching.
    fn try_recv(&self, obs: &Obs) -> Option<Job> {
        match self {
            JobSource::Channel(rx) => rx.try_recv().ok(),
            #[cfg(unix)]
            JobSource::Ring(ring) => {
                let j = ring.try_pop();
                if j.is_some() {
                    obs.metrics.net().submission_ring_depth.dec();
                }
                j
            }
        }
    }
}

/// Absolute ceiling on unwritten responses per connection (small error
/// responses included): past this the connection is closed instead of
/// buffered. The floor keeps bursty-but-honest pipelining clients off
/// the ceiling when `max_inflight_per_conn` is configured very low.
pub(crate) fn conn_hard_cap(config: &DaemonConfig) -> usize {
    config.max_inflight_per_conn.max(1).saturating_mul(4).max(256)
}

/// Running daemon: address, shutdown token, and every thread handle.
pub struct DaemonHandle {
    addr: SocketAddr,
    shutdown: Arc<AtomicBool>,
    /// The socket-owning thread: the accept loop (threads model) or the
    /// net event loop (evented).
    accept: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
    stats: Arc<Mutex<LatencyStats>>,
    cache: Arc<ChunkCache>,
    metrics: Arc<MetricsRegistry>,
    slowlog: Arc<SlowLog>,
    poll_interval: Duration,
}

impl DaemonHandle {
    /// Bound address (useful with port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// The shared decompressed-chunk cache (hit/miss counters).
    pub fn cache(&self) -> &ChunkCache {
        &self.cache
    }

    /// Owned handle on the shared cache — outlives `join`/`wait`, so
    /// callers can report admission/ghost counters after shutdown.
    pub fn cache_arc(&self) -> Arc<ChunkCache> {
        self.cache.clone()
    }

    /// Snapshot of serving stats with cache counters folded in. The
    /// latency lock is held across the cache-counter reads so both
    /// halves of the snapshot come from one point in time — a scrape
    /// can never see cache hit/miss totals from after a batch merge it
    /// did not also see.
    pub fn stats(&self) -> LatencyStats {
        let guard = self.stats.lock().unwrap();
        let mut s = guard.clone();
        s.add_cache_counts(self.cache.hits(), self.cache.misses());
        drop(guard);
        s
    }

    /// The daemon's metrics registry (per-dataset stage histograms).
    pub fn metrics(&self) -> &MetricsRegistry {
        &self.metrics
    }

    /// Owned handle on the metrics registry — grab before
    /// [`wait`](Self::wait)/[`join`](Self::join) (both consume the
    /// handle) to report the shutdown summary from the histogram.
    pub fn metrics_arc(&self) -> Arc<MetricsRegistry> {
        self.metrics.clone()
    }

    /// Snapshot of the slowlog, slowest request first.
    pub fn slowlog(&self) -> Vec<SlowEntry> {
        self.slowlog.snapshot()
    }

    /// Trip the shutdown token (idempotent; threads drain and exit).
    pub fn shutdown(&self) {
        self.shutdown.store(true, Ordering::SeqCst);
    }

    /// True once shutdown has been requested (locally or over the wire).
    pub fn is_shutting_down(&self) -> bool {
        self.shutdown.load(Ordering::SeqCst)
    }

    /// Shut down now and join every thread.
    pub fn join(mut self) -> Result<LatencyStats> {
        self.shutdown();
        self.join_threads()
    }

    /// Block until shutdown is requested (e.g. a wire `Shutdown`
    /// frame), then join every thread.
    pub fn wait(mut self) -> Result<LatencyStats> {
        while !self.shutdown.load(Ordering::SeqCst) {
            thread::sleep(self.poll_interval);
        }
        self.join_threads()
    }

    fn join_threads(&mut self) -> Result<LatencyStats> {
        // Order matters: the socket-owning thread flushes and closes
        // every connection, and its exit drops the last job-source
        // producers (queue senders / ring closure), which lets shard
        // workers drain and observe disconnect. Every thread is joined
        // even if an earlier one panicked — shutdown is total; the
        // first failure is reported after.
        let mut first_err: Option<Error> = None;
        if let Some(h) = self.accept.take() {
            if h.join().is_err() {
                first_err.get_or_insert(Error::Runtime("net front thread panicked".into()));
            }
        }
        for h in self.workers.drain(..) {
            if h.join().is_err() {
                first_err.get_or_insert(Error::Runtime("shard worker panicked".into()));
            }
        }
        match first_err {
            Some(e) => Err(e),
            None => Ok(self.stats()),
        }
    }
}

/// Bind `addr` (e.g. `"127.0.0.1:0"`) and start serving `registry`.
pub fn start(
    registry: Arc<Registry>,
    config: DaemonConfig,
    addr: &str,
) -> Result<DaemonHandle> {
    let listener = TcpListener::bind(addr)?;
    let local_addr = listener.local_addr()?;
    listener.set_nonblocking(true)?;
    let shutdown = Arc::new(AtomicBool::new(false));
    let n_shards = config.shards.max(1);
    let cache = Arc::new(ChunkCache::new(config.cache_bytes, n_shards));
    let stats = Arc::new(Mutex::new(LatencyStats::new()));
    let obs = Obs {
        metrics: Arc::new(MetricsRegistry::new()),
        slowlog: Arc::new(SlowLog::new(SLOWLOG_CAP)),
    };
    // File-backed sources time their positioned reads themselves
    // (`file_read` stage) — hand each its dataset handle up front so
    // the hot path never resolves by name.
    if crate::obs::ENABLED {
        for (name, src) in registry.sources() {
            if let DatasetSource::File(f) = src {
                f.attach_metrics(obs.metrics.dataset(name));
            }
        }
    }
    #[cfg(unix)]
    let (accept, workers) = match config.net_model {
        NetModel::Evented => spawn_evented(
            listener,
            registry,
            cache.clone(),
            stats.clone(),
            obs.clone(),
            shutdown.clone(),
            config,
        )?,
        NetModel::Threads => spawn_threaded(
            listener,
            registry,
            cache.clone(),
            stats.clone(),
            obs.clone(),
            shutdown.clone(),
            config,
        )?,
    };
    // Off unix there is no poll shim: both models run the threaded
    // front (same wire behavior, different scaling).
    #[cfg(not(unix))]
    let (accept, workers) = spawn_threaded(
        listener,
        registry,
        cache.clone(),
        stats.clone(),
        obs.clone(),
        shutdown.clone(),
        config,
    )?;
    Ok(DaemonHandle {
        addr: local_addr,
        shutdown,
        accept: Some(accept),
        workers,
        stats,
        cache,
        metrics: obs.metrics,
        slowlog: obs.slowlog,
        poll_interval: config.poll_interval,
    })
}

/// Spawn shard workers fed by bounded sync channels plus the threaded
/// accept loop (two threads per connection).
fn spawn_threaded(
    listener: TcpListener,
    registry: Arc<Registry>,
    cache: Arc<ChunkCache>,
    stats: Arc<Mutex<LatencyStats>>,
    obs: Obs,
    shutdown: Arc<AtomicBool>,
    config: DaemonConfig,
) -> Result<(JoinHandle<()>, Vec<JoinHandle<()>>)> {
    let n_shards = config.shards.max(1);
    let mut senders = Vec::with_capacity(n_shards);
    let mut workers = Vec::with_capacity(n_shards);
    for si in 0..n_shards {
        let (tx, rx) = mpsc::sync_channel::<Job>(config.queue_depth.max(1));
        senders.push(tx);
        let reg = registry.clone();
        let cache = cache.clone();
        let stats = stats.clone();
        let obs = obs.clone();
        let handle = thread::Builder::new()
            .name(format!("codag-shard-{si}"))
            .spawn(move || {
                shard_loop(&reg, &cache, config, JobSource::Channel(rx), &stats, &obs)
            })?;
        workers.push(handle);
    }
    // The accept thread owns the long-lived queue senders (each
    // connection gets its own clone); when it and the readers it joins
    // exit, every sender is dropped and workers see disconnect after
    // draining — the drain half of graceful shutdown.
    let accept = thread::Builder::new().name("codag-accept".into()).spawn(move || {
        accept_loop(listener, registry, cache, senders, shutdown, config, obs)
    })?;
    Ok((accept, workers))
}

/// Spawn shard workers fed by submission rings plus the single
/// net-event-loop thread that owns every socket (DESIGN.md §11).
#[cfg(unix)]
fn spawn_evented(
    listener: TcpListener,
    registry: Arc<Registry>,
    cache: Arc<ChunkCache>,
    stats: Arc<Mutex<LatencyStats>>,
    obs: Obs,
    shutdown: Arc<AtomicBool>,
    config: DaemonConfig,
) -> Result<(JoinHandle<()>, Vec<JoinHandle<()>>)> {
    let n_shards = config.shards.max(1);
    // Submission capacity = the threaded model's sync-channel bound, so
    // ring-full hits at exactly the queue depth `Busy` always hit at.
    // Completion rings get headroom for a full queue plus one in-flight
    // batch, keeping the worker's blocking push a cold path.
    let submission: Vec<Arc<Ring<Job>>> = (0..n_shards)
        .map(|_| Arc::new(Ring::new(config.queue_depth.max(1))))
        .collect();
    let completion: Vec<Arc<Ring<Completion>>> = (0..n_shards)
        .map(|_| Arc::new(Ring::new(config.queue_depth.saturating_add(config.batch).max(8))))
        .collect();
    let waker = Arc::new(Waker::new()?);
    let mut workers = Vec::with_capacity(n_shards);
    for si in 0..n_shards {
        let source = JobSource::Ring(submission[si].clone());
        let reg = registry.clone();
        let cache = cache.clone();
        let stats = stats.clone();
        let obs = obs.clone();
        let handle = thread::Builder::new()
            .name(format!("codag-shard-{si}"))
            .spawn(move || shard_loop(&reg, &cache, config, source, &stats, &obs))?;
        workers.push(handle);
    }
    let nl = net::NetLoop {
        listener,
        registry,
        cache,
        submission,
        completion,
        waker,
        shutdown,
        config,
        obs,
    };
    let accept =
        thread::Builder::new().name("codag-net".into()).spawn(move || net::net_loop(nl))?;
    Ok((accept, workers))
}

fn accept_loop(
    listener: TcpListener,
    registry: Arc<Registry>,
    cache: Arc<ChunkCache>,
    senders: Vec<SyncSender<Job>>,
    shutdown: Arc<AtomicBool>,
    config: DaemonConfig,
    obs: Obs,
) {
    let mut conns: Vec<JoinHandle<()>> = Vec::new();
    while !shutdown.load(Ordering::SeqCst) {
        // Reap finished connection threads every tick so a
        // burst-then-idle pattern does not retain dead handles.
        if conns.iter().any(|c| c.is_finished()) {
            let mut live = Vec::with_capacity(conns.len());
            for c in conns.drain(..) {
                if c.is_finished() {
                    let _ = c.join();
                } else {
                    live.push(c);
                }
            }
            conns = live;
        }
        match listener.accept() {
            Ok((stream, _peer)) => {
                // Hard connection cap: each connection costs a reader
                // and a writer thread, so excess connects are refused
                // (closed) rather than accumulated.
                if conns.len() >= config.max_connections.max(1) {
                    drop(stream);
                    continue;
                }
                let reg = registry.clone();
                let cch = cache.clone();
                // Per-connection sender clones: no shared reference, so
                // dropping them (reader exit) is all the bookkeeping
                // shutdown needs.
                let snd: Vec<SyncSender<Job>> = senders.clone();
                let sd = shutdown.clone();
                let obs = obs.clone();
                match thread::Builder::new()
                    .name("codag-conn".into())
                    .spawn(move || connection_loop(stream, &reg, &cch, &snd, &sd, config, &obs))
                {
                    Ok(h) => conns.push(h),
                    Err(e) => eprintln!("codag-serve: connection spawn failed: {e}"),
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                thread::sleep(Duration::from_millis(5));
            }
            Err(_) => thread::sleep(Duration::from_millis(5)),
        }
    }
    for c in conns {
        let _ = c.join();
    }
}

/// RAII step-down for the `connections_open` gauge: a connection thread
/// has several exit paths (setup failure, EOF, protocol error, hard
/// cap), and every one of them must release the slot it counted.
struct OpenConnGuard(Arc<MetricsRegistry>);

impl Drop for OpenConnGuard {
    fn drop(&mut self) {
        self.0.net().connections_open.dec();
    }
}

fn connection_loop(
    mut stream: TcpStream,
    registry: &Registry,
    cache: &ChunkCache,
    senders: &[SyncSender<Job>],
    shutdown: &AtomicBool,
    config: DaemonConfig,
    obs: &Obs,
) {
    obs.metrics.net().connections_open.inc();
    let _open = OpenConnGuard(obs.metrics.clone());
    // Accepted sockets may inherit the listener's non-blocking flag on
    // some platforms — force blocking + read timeout so this thread
    // sleeps in `read` and still polls the shutdown token; write
    // timeouts keep a stuck peer from wedging shutdown.
    if stream.set_nonblocking(false).is_err()
        || stream.set_read_timeout(Some(config.poll_interval)).is_err()
    {
        return;
    }
    let _ = stream.set_write_timeout(Some(config.write_timeout));
    // Request/response framing writes header and payload separately:
    // without NODELAY, Nagle + delayed ACK can stall every exchange.
    let _ = stream.set_nodelay(true);
    let Ok(mut wstream) = stream.try_clone() else { return };
    let (tx, rx) = mpsc::channel::<Outbound>();
    // Unwritten responses on this connection (every request yields
    // exactly one response: the reader charges the counter per decoded
    // frame, the writer debits it per frame written), plus the byte
    // charge of admitted-but-unwritten payloads. Together they bound
    // the response-side buffering the shard queues cannot see.
    let inflight = Arc::new(AtomicUsize::new(0));
    let inflight_bytes = Arc::new(AtomicU64::new(0));
    let writer = {
        let inflight = inflight.clone();
        let inflight_bytes = inflight_bytes.clone();
        thread::Builder::new().name("codag-conn-writer".into()).spawn(move || {
            while let Ok(out) = rx.recv() {
                let t0 = now_if_enabled().filter(|_| out.obs.is_some());
                let ok = write_response_parts_crc(
                    &mut wstream,
                    out.version,
                    out.status,
                    out.id,
                    out.payload.as_slice(),
                    out.frame_crc,
                )
                .is_ok();
                if let Some(dm) = &out.obs {
                    if let Some(t0) = t0 {
                        dm.stage(Stage::ResponseWrite).record(t0.elapsed());
                    }
                    // Balanced against the inc at admission: the request
                    // is no longer in flight once its frame hits (or
                    // fails to hit) the socket.
                    dm.inflight.dec();
                }
                inflight.fetch_sub(1, Ordering::SeqCst);
                inflight_bytes.fetch_sub(out.charge, Ordering::SeqCst);
                if !ok {
                    break; // peer gone or stuck; remaining responses drop
                }
            }
        })
    };
    let Ok(writer) = writer else { return };
    // Request-sized cap: a hostile length prefix must not pre-allocate
    // a response-sized buffer per connection.
    let mut reader = FrameReader::for_requests();
    loop {
        // Check the token every iteration, not only on read timeouts: a
        // client pipelining frames faster than poll_interval must not
        // keep this thread (and therefore shutdown joins) alive. A dead
        // writer (peer stopped reading; write timeout fired) is equally
        // fatal — admitting more work would just decode into a dropped
        // channel.
        if shutdown.load(Ordering::SeqCst) || writer.is_finished() {
            break;
        }
        match reader.poll(&mut stream) {
            Ok(ReadEvent::WouldBlock) => {}
            Ok(ReadEvent::Eof) => break,
            Ok(ReadEvent::Frame(body)) => match decode_request_versioned(&body) {
                Ok((req, version, flags)) => {
                    // Charge this request's (single) response up front.
                    let outstanding = inflight.fetch_add(1, Ordering::SeqCst);
                    if outstanding >= conn_hard_cap(&config)
                        && !matches!(req, WireRequest::Shutdown { .. })
                    {
                        // The client is pipelining without reading even
                        // small responses: close instead of buffering
                        // (the unsent response's charge is returned).
                        inflight.fetch_sub(1, Ordering::SeqCst);
                        break;
                    }
                    if !handle_request(
                        req,
                        version,
                        flags,
                        registry,
                        cache,
                        senders,
                        &tx,
                        outstanding,
                        &inflight_bytes,
                        shutdown,
                        config,
                        obs,
                    ) {
                        break;
                    }
                }
                Err(e) => {
                    // Framing is no longer trustworthy: respond (echo
                    // the id and version when the body was long enough
                    // to carry them — a strict v1 client can only
                    // decode a v1-stamped error), close.
                    inflight.fetch_add(1, Ordering::SeqCst);
                    let id = crate::server::proto::request_id_hint(&body);
                    let version = crate::server::proto::request_version_hint(&body);
                    send_reply(
                        &tx,
                        version,
                        false,
                        id,
                        Status::BadRequest,
                        e.to_string().into_bytes(),
                    );
                    break;
                }
            },
            Err(e) => {
                // Corrupt = the peer broke framing (oversized prefix,
                // mid-frame close): client fault. Anything else is a
                // transport failure on our side.
                let status = match &e {
                    Error::Corrupt(_) => Status::BadRequest,
                    _ => Status::Internal,
                };
                inflight.fetch_add(1, Ordering::SeqCst);
                send_reply(&tx, WIRE_VERSION, false, 0, status, e.to_string().into_bytes());
                break;
            }
        }
    }
    drop(tx); // writer drains in-flight responses, then exits
    let _ = writer.join();
}

/// A fully-specified admitted request, produced by [`admit`]: the
/// caller charges `charge` to the connection's byte budget, wraps this
/// in a [`Job`] with its reply route, and pushes it at shard `si`.
pub(crate) struct JobSpec {
    pub(crate) req: Request,
    pub(crate) received: Instant,
    pub(crate) charge: u64,
    pub(crate) deadline: Option<Instant>,
    pub(crate) version: u16,
    /// The request opted into a response frame-CRC trailer (v3).
    pub(crate) frame_crc: bool,
    pub(crate) dm: Option<Arc<DatasetMetrics>>,
    /// Admission-stage clock start (recorded by the caller once the
    /// queue push succeeds, so the stage covers the push too).
    pub(crate) t_adm: Option<Instant>,
    /// Target shard: `fnv1a(dataset) % n_shards`.
    pub(crate) si: usize,
}

/// The admission decision for one decoded request — every policy check
/// both network fronts share, with queue-push mechanics left to the
/// caller. Keeping this a pure function of (request, connection
/// counters, daemon state) is what makes the two fronts byte-identical:
/// there is one copy of the contract.
///
/// `outstanding`/`bytes_now` are the connection's unwritten-response
/// count and admitted-but-unwritten payload bytes at the moment the
/// frame was charged.
#[allow(clippy::too_many_arguments)]
pub(crate) fn admit(
    req: WireRequest,
    version: u16,
    flags: u64,
    registry: &Registry,
    cache: &ChunkCache,
    n_shards: usize,
    outstanding: usize,
    bytes_now: u64,
    shutdown: &AtomicBool,
    config: &DaemonConfig,
    obs: &Obs,
) -> Admit {
    // Backpressure half 2: a pipelining client that does not read its
    // responses stops being served once its unwritten-response budget
    // is spent (Shutdown stays exempt so a draining admin always gets
    // through; the hard cap bounds even Busy floods).
    let over_budget = outstanding >= config.max_inflight_per_conn.max(1);
    match req {
        WireRequest::Shutdown { id } => {
            Admit::Shutdown { id, payload: b"shutting down".to_vec() }
        }
        WireRequest::Metrics { id } => {
            if over_budget {
                return Admit::Reply {
                    id,
                    status: Status::Busy,
                    payload: b"connection in-flight limit".to_vec(),
                };
            }
            let text = expo::render(&obs.metrics, &obs.slowlog);
            Admit::Reply { id, status: Status::Ok, payload: text.into_bytes() }
        }
        WireRequest::Stat { id, dataset } => {
            if over_budget {
                return Admit::Reply {
                    id,
                    status: Status::Busy,
                    payload: b"connection in-flight limit".to_vec(),
                };
            }
            match registry.get(&dataset) {
                Ok(c) => {
                    // 64-byte v2 Stat payload: dataset dimensions, then
                    // the daemon-wide cache counters. A v1 requester
                    // gets exactly the 24-byte payload its strict
                    // decoder expects.
                    let mut payload = Vec::with_capacity(64);
                    payload.extend_from_slice(&c.total_uncompressed().to_le_bytes());
                    payload.extend_from_slice(&(c.chunk_size() as u64).to_le_bytes());
                    payload.extend_from_slice(&(c.n_chunks() as u64).to_le_bytes());
                    if version >= 2 {
                        payload.extend_from_slice(&cache.hits().to_le_bytes());
                        payload.extend_from_slice(&cache.misses().to_le_bytes());
                        payload.extend_from_slice(&cache.evictions().to_le_bytes());
                        payload.extend_from_slice(&cache.admit_declines().to_le_bytes());
                        payload.extend_from_slice(&cache.ghost_hits().to_le_bytes());
                    }
                    Admit::Reply { id, status: Status::Ok, payload }
                }
                Err(e) => Admit::Reply {
                    id,
                    status: Status::NotFound,
                    payload: e.to_string().into_bytes(),
                },
            }
        }
        WireRequest::Get { id, dataset, offset, len, deadline_ms } => {
            // Admission-stage clock: started before any checks so the
            // stage covers the full admission cost.
            let t_adm = now_if_enabled();
            if over_budget {
                return Admit::Reply {
                    id,
                    status: Status::Busy,
                    payload: b"connection in-flight limit".to_vec(),
                };
            }
            if shutdown.load(Ordering::SeqCst) {
                return Admit::Reply {
                    id,
                    status: Status::ShuttingDown,
                    payload: b"daemon is draining".to_vec(),
                };
            }
            let Ok(container) = registry.get(&dataset) else {
                return Admit::Reply {
                    id,
                    status: Status::NotFound,
                    payload: format!("dataset '{dataset}' not registered").into_bytes(),
                };
            };
            // Resolved only after the registry lookup succeeds: hostile
            // dataset names must not mint registry entries (unbounded
            // label cardinality).
            let dm = t_adm.map(|_| obs.metrics.dataset(&dataset));
            // Reject ranges whose response could not be framed (body
            // capped at MAX_FRAME_LEN) before any decode work is done —
            // otherwise the write side would fail the oversized frame
            // and drop the connection without an error response.
            let span = {
                let remaining = container.total_uncompressed().saturating_sub(offset);
                if len == 0 {
                    remaining
                } else {
                    len.min(remaining)
                }
            };
            if span > (crate::server::proto::MAX_FRAME_LEN as u64).saturating_sub(64) {
                return Admit::Reply {
                    id,
                    status: Status::BadRequest,
                    payload: format!("range of {span} bytes exceeds the max response frame")
                        .into_bytes(),
                };
            }
            // Byte half of the connection budget: admitted payload
            // bytes not yet written to the socket. One request is
            // always admitted when nothing is outstanding, so the true
            // bound is the budget plus one frame.
            if bytes_now > 0
                && bytes_now.saturating_add(span) > config.max_inflight_bytes_per_conn as u64
            {
                if let Some(m) = &dm {
                    m.busy.inc();
                }
                return Admit::Reply {
                    id,
                    status: Status::Busy,
                    payload: b"connection byte budget exhausted".to_vec(),
                };
            }
            // All requests for one dataset land on one shard: FIFO per
            // dataset is preserved through the bounded queue.
            let si = (fnv1a(dataset.as_bytes()) % n_shards.max(1) as u64) as usize;
            let received = Instant::now();
            // Relative wire deadline, anchored at frame decode (no
            // client/daemon clock sync needed); 0 = none.
            let deadline = if deadline_ms > 0 {
                received.checked_add(Duration::from_millis(deadline_ms))
            } else {
                None
            };
            Admit::Enqueue(JobSpec {
                req: Request { id, dataset, offset, len },
                received,
                charge: span,
                deadline,
                version,
                frame_crc: flags & FLAG_FRAME_CRC != 0,
                dm,
                t_adm,
                si,
            })
        }
    }
}

/// What [`admit`] decided for one request.
pub(crate) enum Admit {
    /// Answer immediately with this response (no byte charge).
    Reply { id: u64, status: Status, payload: Vec<u8> },
    /// Admitted for decode: charge the byte budget and push to a shard.
    Enqueue(JobSpec),
    /// A shutdown frame: ack with `Ok`, trip the token, stop reading.
    Shutdown { id: u64, payload: Vec<u8> },
}

/// Dispatch one decoded request on the threaded front; returns false to
/// close the connection. `outstanding` is the connection's
/// unwritten-response count at the moment this request was charged (the
/// reader increments it, the writer decrements it as frames reach the
/// socket).
#[allow(clippy::too_many_arguments)]
fn handle_request(
    req: WireRequest,
    version: u16,
    flags: u64,
    registry: &Registry,
    cache: &ChunkCache,
    senders: &[SyncSender<Job>],
    tx: &mpsc::Sender<Outbound>,
    outstanding: usize,
    inflight_bytes: &AtomicU64,
    shutdown: &AtomicBool,
    config: DaemonConfig,
    obs: &Obs,
) -> bool {
    let bytes_now = inflight_bytes.load(Ordering::SeqCst);
    // Reader-generated replies honour the frame-CRC opt-in too: the
    // client asked for wire integrity on everything it gets back.
    let frame_crc = flags & FLAG_FRAME_CRC != 0;
    match admit(
        req,
        version,
        flags,
        registry,
        cache,
        senders.len(),
        outstanding,
        bytes_now,
        shutdown,
        &config,
        obs,
    ) {
        Admit::Shutdown { id, payload } => {
            send_reply(tx, version, frame_crc, id, Status::Ok, payload);
            shutdown.store(true, Ordering::SeqCst);
            false
        }
        Admit::Reply { id, status, payload } => {
            send_reply(tx, version, frame_crc, id, status, payload);
            true
        }
        Admit::Enqueue(spec) => {
            let si = spec.si;
            let t_adm = spec.t_adm;
            let dm = spec.dm.clone();
            inflight_bytes.fetch_add(spec.charge, Ordering::SeqCst);
            let job = Job {
                req: spec.req,
                reply: ReplySink::Channel(tx.clone()),
                received: spec.received,
                charge: spec.charge,
                deadline: spec.deadline,
                version: spec.version,
                frame_crc: spec.frame_crc,
                dm: spec.dm,
            };
            match senders[si].try_send(job) {
                Ok(()) => {
                    if let (Some(t0), Some(m)) = (t_adm, &dm) {
                        m.requests.inc();
                        m.inflight.inc();
                        m.stage(Stage::Admission).record(t0.elapsed());
                    }
                }
                Err(TrySendError::Full(job)) => {
                    inflight_bytes.fetch_sub(job.charge, Ordering::SeqCst);
                    if let Some(m) = &dm {
                        m.busy.inc();
                    }
                    // Backpressure half 1: explicit Busy, never queue
                    // growth.
                    send_reply(
                        tx,
                        job.version,
                        job.frame_crc,
                        job.req.id,
                        Status::Busy,
                        format!("shard {si} queue at admission limit").into_bytes(),
                    );
                }
                Err(TrySendError::Disconnected(job)) => {
                    inflight_bytes.fetch_sub(job.charge, Ordering::SeqCst);
                    send_reply(
                        tx,
                        job.version,
                        job.frame_crc,
                        job.req.id,
                        Status::ShuttingDown,
                        b"daemon is shutting down".to_vec(),
                    );
                }
            }
            true
        }
    }
}

/// Map a decode error onto a wire status.
fn status_for(e: &Error) -> Status {
    match e {
        // An unregistered codec id in a container is indistinguishable
        // from corruption to the client: same wire status, the typed
        // error only matters server-side.
        Error::Corrupt(_) | Error::UnknownCodec(_) => Status::Corrupt,
        // Content-checksum failure gets its own status: the stream
        // parsed but the decoded bytes are provably wrong, which is
        // actionable (re-pack / restore from replica) in a way generic
        // corruption is not.
        Error::ChecksumMismatch(_) => Status::ChecksumMismatch,
        Error::Invalid(_) => Status::BadRequest,
        Error::Io(_) | Error::Runtime(_) => Status::Internal,
    }
}

/// Reply metadata for one live batch item, carried alongside the owned
/// `Request` handed to `serve_batch_shared_with`.
struct ReplyMeta {
    reply: ReplySink,
    received: Instant,
    charge: u64,
    version: u16,
    frame_crc: bool,
    dm: Option<Arc<DatasetMetrics>>,
    /// Queue wait in µs (admission → dequeue), kept so the slowlog
    /// entry's stage offsets are cumulative from `received`.
    wait_us: u64,
}

fn shard_loop(
    registry: &Registry,
    cache: &ChunkCache,
    config: DaemonConfig,
    source: JobSource,
    stats: &Mutex<LatencyStats>,
    obs: &Obs,
) {
    // One Service per shard, constructed once and reused for every
    // batch (plan/cache wiring is long-lived; decode parallelism
    // inside serve_batch uses scoped threads per batch, and
    // single-item batches decode inline with no spawn at all). A zero
    // cache budget means no cache: don't pay per-chunk lock+miss
    // traffic for a disabled cache.
    let svc_cfg = ServiceConfig {
        workers: config.workers_per_shard.max(1),
        hybrid: false,
        paranoid: config.paranoid,
    };
    let service = Service::new(registry, None, svc_cfg).with_metrics(obs.metrics.clone());
    let service = if config.cache_bytes > 0 { service.with_cache(cache) } else { service };
    loop {
        let first = match source.recv_timeout(config.poll_interval, obs) {
            Fetch::Job(j) => j,
            Fetch::Timeout => continue,
            // Producers gone (threaded: senders dropped; evented: ring
            // closed) and the queue fully drained: graceful exit.
            Fetch::Closed => break,
        };
        let mut jobs = vec![first];
        while jobs.len() < config.batch.max(1) {
            match source.try_recv(obs) {
                Some(j) => jobs.push(j),
                None => break,
            }
        }
        // Deadline check #1, at dequeue: a job whose deadline lapsed in
        // the queue is answered `Expired` right here and never enters
        // the decode batch — an expired request must not consume a
        // decode slot. The admission byte charge still rides the
        // response so the connection budget is returned on write.
        let now = Instant::now();
        let mut live = Vec::with_capacity(jobs.len());
        for j in jobs {
            // Queue wait (admission → dequeue) is recorded for every
            // dequeued job, expired ones included — expiry is exactly
            // the tail this stage exists to expose.
            let wait_us = now.saturating_duration_since(j.received).as_micros() as u64;
            if let Some(m) = &j.dm {
                m.stage(Stage::QueueWait).record_us(wait_us);
            }
            if j.deadline.is_some_and(|d| now >= d) {
                if let Some(m) = &j.dm {
                    m.expired.inc();
                }
                let out = Outbound {
                    id: j.req.id,
                    status: Status::Expired,
                    version: j.version,
                    payload: Payload::Owned(b"deadline expired while queued".to_vec()),
                    charge: j.charge,
                    frame_crc: j.frame_crc,
                    obs: j.dm,
                };
                j.reply.send(out, obs);
            } else {
                live.push((j, wait_us));
            }
        }
        if live.is_empty() {
            continue;
        }
        // Hand the owned Requests straight to serve_batch (no per-job
        // clone on the hot path); reply metadata rides alongside. The
        // per-request codec is resolved once here, not per response in
        // the reply loop below.
        let mut requests = Vec::with_capacity(live.len());
        let mut replies = Vec::with_capacity(live.len());
        let mut deadlines = Vec::with_capacity(live.len());
        let mut codecs = Vec::with_capacity(live.len());
        for (j, wait_us) in live {
            // Attribute by the first chunk the request touches: for
            // mixed v3 containers the header codec may not be the codec
            // that actually decodes this range.
            codecs.push(
                registry
                    .get(&j.req.dataset)
                    .map(|s| {
                        let cs = s.chunk_size().max(1) as u64;
                        s.chunk_codec((j.req.offset / cs) as usize)
                    })
                    .ok(),
            );
            requests.push(j.req);
            deadlines.push(j.deadline);
            replies.push(ReplyMeta {
                reply: j.reply,
                received: j.received,
                charge: j.charge,
                version: j.version,
                frame_crc: j.frame_crc,
                dm: j.dm,
                wait_us,
            });
        }
        // Deadline check #2, between batch items: the service consults
        // this probe before decoding each of a request's chunks, so a
        // deadline lapsing mid-batch stops burning decode work. The
        // shared variant keeps cache-hit spans as `Arc<[u8]>` slices —
        // the zero-copy half of the evented front's vectored writes
        // (the threaded writer shares the same payload type).
        let (responses, _) = service.serve_batch_shared_with(&requests, |ri| {
            deadlines[ri].is_some_and(|d| Instant::now() >= d)
        });
        // Record into a batch-local recorder and take the shared lock
        // once per batch, not once per response — shards must not
        // serialize on the stats mutex in the reply hot path.
        let mut batch_stats = LatencyStats::new();
        for (ri, (meta, resp)) in replies.into_iter().zip(responses).enumerate() {
            let out = match resp.data {
                Ok(payload) => {
                    let total = meta.received.elapsed();
                    // Admission-to-reply latency (includes queue wait —
                    // the quantity backpressure tuning moves).
                    batch_stats.record(total, payload.len() as u64);
                    // Per-codec decoded-byte attribution (shutdown
                    // summary observability for the codec hot paths).
                    if let Some(codec) = codecs[ri] {
                        batch_stats.add_codec_bytes(codec, payload.len() as u64);
                    }
                    if crate::obs::ENABLED && meta.dm.is_some() {
                        let total_us = total.as_micros() as u64;
                        obs.metrics.request_us().record_us(total_us);
                        // Cumulative stage offsets from receipt: wait,
                        // wait + service-side decode, full round trip.
                        // Each later offset clamps to total_us so the
                        // entry is monotone even under clock jitter.
                        let decode_at = meta
                            .wait_us
                            .saturating_add(resp.latency.as_micros() as u64)
                            .min(total_us);
                        obs.slowlog.offer(SlowEntry {
                            id: resp.id,
                            dataset: requests[ri].dataset.clone(),
                            total_us,
                            stages: vec![
                                (Stage::QueueWait, meta.wait_us.min(total_us)),
                                (Stage::DecodeSerial, decode_at),
                                (Stage::ResponseWrite, total_us),
                            ],
                        });
                    }
                    Outbound {
                        id: resp.id,
                        status: Status::Ok,
                        version: meta.version,
                        payload,
                        charge: meta.charge,
                        frame_crc: meta.frame_crc,
                        obs: meta.dm,
                    }
                }
                Err(Error::Runtime(msg))
                    if msg == crate::coordinator::service::DEADLINE_EXPIRED =>
                {
                    if let Some(m) = &meta.dm {
                        m.expired.inc();
                    }
                    Outbound {
                        id: resp.id,
                        status: Status::Expired,
                        version: meta.version,
                        payload: Payload::Owned(msg.into_bytes()),
                        charge: meta.charge,
                        frame_crc: meta.frame_crc,
                        obs: meta.dm,
                    }
                }
                Err(e) => {
                    // Content-checksum failures feed the shutdown
                    // summary's integrity line alongside the per-dataset
                    // obs counter (which the service layer bumps).
                    if matches!(&e, Error::ChecksumMismatch(_)) {
                        batch_stats.add_integrity_failures(1);
                    }
                    Outbound {
                        id: resp.id,
                        status: status_for(&e),
                        version: meta.version,
                        payload: Payload::Owned(e.to_string().into_bytes()),
                        charge: meta.charge,
                        frame_crc: meta.frame_crc,
                        obs: meta.dm,
                    }
                }
            };
            meta.reply.send(out, obs);
        }
        if batch_stats.count() > 0 || batch_stats.integrity_failures() > 0 {
            stats.lock().unwrap().merge(&batch_stats);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn idle_daemon_starts_and_joins() {
        // Default = evented on unix: the net loop must come up and tear
        // down cleanly with zero connections.
        let registry = Arc::new(Registry::new());
        let handle =
            start(registry, DaemonConfig::default(), "127.0.0.1:0").expect("bind loopback");
        assert_ne!(handle.addr().port(), 0);
        assert!(!handle.is_shutting_down());
        let stats = handle.join().expect("clean join");
        assert_eq!(stats.count(), 0);
    }

    #[test]
    fn idle_daemon_starts_and_joins_threaded() {
        let registry = Arc::new(Registry::new());
        let config = DaemonConfig { net_model: NetModel::Threads, ..DaemonConfig::default() };
        let handle = start(registry, config, "127.0.0.1:0").expect("bind loopback");
        let stats = handle.join().expect("clean join");
        assert_eq!(stats.count(), 0);
    }

    #[test]
    fn net_model_parses_cli_values() {
        assert_eq!(NetModel::parse("evented"), Some(NetModel::Evented));
        assert_eq!(NetModel::parse("threads"), Some(NetModel::Threads));
        assert_eq!(NetModel::parse("threaded"), Some(NetModel::Threads));
        assert_eq!(NetModel::parse("epoll"), None);
        assert_eq!(NetModel::default(), NetModel::Evented);
    }
}
