//! The long-lived `codag-serve` daemon.
//!
//! Architecture (DESIGN.md §6):
//!
//! ```text
//! TcpListener (non-blocking accept loop)
//!   └─ per-connection reader thread ── FrameReader → decode_request
//!        ├─ admission: hash(dataset) → shard queue (bounded sync
//!        │  channel; `try_send` full ⇒ immediate `Busy` response) and
//!        │  a per-connection in-flight response budget (pipelining
//!        │  without reading ⇒ `Busy`) — never unbounded buffering on
//!        │  either side
//!        └─ per-connection writer thread (response channel → socket,
//!           debits the in-flight budget as responses are written)
//! shard worker threads (one per shard, long-lived)
//!   └─ own a reused `Service` (+ shared `ChunkCache`); drain their
//!      queue in FIFO order, opportunistically batching up to
//!      `DaemonConfig::batch` requests per `serve_batch` call
//! ```
//!
//! All requests for one dataset hash to one shard, so per-dataset FIFO
//! order is preserved end to end. Shutdown is a shared token: the
//! accept loop stops, reader threads notice on their next read timeout,
//! queue senders drop, shard workers drain what was admitted and exit,
//! and [`DaemonHandle::join`]/[`DaemonHandle::wait`] joins every thread.

use crate::coordinator::router::{DatasetSource, Request};
use crate::coordinator::service::{Service, ServiceConfig};
use crate::coordinator::stats::LatencyStats;
use crate::coordinator::Registry;
use crate::obs::{
    expo, now_if_enabled, DatasetMetrics, MetricsRegistry, SlowEntry, SlowLog, Stage, SLOWLOG_CAP,
};
use crate::server::cache::{fnv1a, ChunkCache};
use crate::server::proto::{
    decode_request_versioned, write_response_versioned, FrameReader, ReadEvent, Status,
    WireRequest, WireResponse, WIRE_VERSION,
};
use crate::{Error, Result};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::thread::{self, JoinHandle};
use std::time::{Duration, Instant};

/// Daemon tuning knobs.
#[derive(Debug, Clone, Copy)]
pub struct DaemonConfig {
    /// Shard queues / long-lived shard worker threads.
    pub shards: usize,
    /// Admission limit: queued requests per shard before `Busy`.
    pub queue_depth: usize,
    /// Decode workers inside each shard's `Service`.
    pub workers_per_shard: usize,
    /// Max requests folded into one `serve_batch` call.
    pub batch: usize,
    /// Unwritten responses allowed per connection before requests get
    /// `Busy`: a client that pipelines requests without reading
    /// responses cannot make the daemon buffer payloads without bound
    /// (a 4× hard cap closes the connection outright — see
    /// `conn_hard_cap`).
    pub max_inflight_per_conn: usize,
    /// Unwritten response *payload bytes* allowed per connection before
    /// Gets are refused with `Busy` (one oversized request is always
    /// admitted when nothing is outstanding, so the bound is this
    /// budget plus one frame).
    pub max_inflight_bytes_per_conn: usize,
    /// Concurrent connections accepted; excess connects are closed
    /// immediately (each connection costs two threads).
    pub max_connections: usize,
    /// Total decompressed-chunk cache budget (0 disables the cache).
    pub cache_bytes: usize,
    /// Read-timeout granularity at which blocked threads poll the
    /// shutdown token.
    pub poll_interval: Duration,
    /// Socket write timeout (a stuck peer cannot wedge shutdown).
    pub write_timeout: Duration,
}

impl Default for DaemonConfig {
    fn default() -> Self {
        DaemonConfig {
            shards: 4,
            queue_depth: 64,
            workers_per_shard: 2,
            batch: 32,
            max_inflight_per_conn: 64,
            max_inflight_bytes_per_conn: 64 * 1024 * 1024,
            max_connections: 1024,
            cache_bytes: 64 * 1024 * 1024,
            poll_interval: Duration::from_millis(50),
            write_timeout: Duration::from_secs(5),
        }
    }
}

/// One response travelling to a connection's writer thread, carrying
/// the byte charge taken at admission (debited once written; 0 for
/// reader-generated error/metadata responses) and the protocol version
/// to stamp on the wire (echoing the requester's version — a v1 client
/// rejects v2-stamped replies).
struct Outbound {
    resp: WireResponse,
    charge: u64,
    version: u16,
    /// Per-dataset metrics for shard-produced replies: the writer times
    /// the socket write into the `response_write` stage and decrements
    /// the in-flight gauge charged at admission. `None` for
    /// reader-generated error/metadata responses.
    obs: Option<Arc<DatasetMetrics>>,
}

/// Send a reader-generated response (no byte charge).
fn send_reply(tx: &mpsc::Sender<Outbound>, version: u16, resp: WireResponse) {
    let _ = tx.send(Outbound { resp, charge: 0, version, obs: None });
}

/// Shared observability handles threaded through the daemon's threads
/// (DESIGN.md §10): the per-dataset stage registry and the slowlog the
/// wire `Metrics` request renders.
#[derive(Clone)]
struct Obs {
    metrics: Arc<MetricsRegistry>,
    slowlog: Arc<SlowLog>,
}

/// One admitted request, owned by a shard queue. `charge` is the byte
/// span debited from the connection's in-flight byte budget when the
/// response hits the socket; `deadline` (from the wire `deadline_ms`,
/// measured from frame decode) is checked at dequeue and between batch
/// items so an expired request never occupies a decode slot.
struct Job {
    req: Request,
    reply: mpsc::Sender<Outbound>,
    received: Instant,
    charge: u64,
    deadline: Option<Instant>,
    /// Protocol version of the originating frame (echoed in the reply).
    version: u16,
    /// Dataset metrics handle, resolved once at admission (`None` when
    /// recording is compiled out).
    dm: Option<Arc<DatasetMetrics>>,
}

/// Absolute ceiling on unwritten responses per connection (small error
/// responses included): past this the connection is closed instead of
/// buffered. The floor keeps bursty-but-honest pipelining clients off
/// the ceiling when `max_inflight_per_conn` is configured very low.
fn conn_hard_cap(config: &DaemonConfig) -> usize {
    config.max_inflight_per_conn.max(1).saturating_mul(4).max(256)
}

/// Running daemon: address, shutdown token, and every thread handle.
pub struct DaemonHandle {
    addr: SocketAddr,
    shutdown: Arc<AtomicBool>,
    accept: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
    stats: Arc<Mutex<LatencyStats>>,
    cache: Arc<ChunkCache>,
    metrics: Arc<MetricsRegistry>,
    slowlog: Arc<SlowLog>,
    poll_interval: Duration,
}

impl DaemonHandle {
    /// Bound address (useful with port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// The shared decompressed-chunk cache (hit/miss counters).
    pub fn cache(&self) -> &ChunkCache {
        &self.cache
    }

    /// Owned handle on the shared cache — outlives `join`/`wait`, so
    /// callers can report admission/ghost counters after shutdown.
    pub fn cache_arc(&self) -> Arc<ChunkCache> {
        self.cache.clone()
    }

    /// Snapshot of serving stats with cache counters folded in. The
    /// latency lock is held across the cache-counter reads so both
    /// halves of the snapshot come from one point in time — a scrape
    /// can never see cache hit/miss totals from after a batch merge it
    /// did not also see.
    pub fn stats(&self) -> LatencyStats {
        let guard = self.stats.lock().unwrap();
        let mut s = guard.clone();
        s.add_cache_counts(self.cache.hits(), self.cache.misses());
        drop(guard);
        s
    }

    /// The daemon's metrics registry (per-dataset stage histograms).
    pub fn metrics(&self) -> &MetricsRegistry {
        &self.metrics
    }

    /// Owned handle on the metrics registry — grab before
    /// [`wait`](Self::wait)/[`join`](Self::join) (both consume the
    /// handle) to report the shutdown summary from the histogram.
    pub fn metrics_arc(&self) -> Arc<MetricsRegistry> {
        self.metrics.clone()
    }

    /// Snapshot of the slowlog, slowest request first.
    pub fn slowlog(&self) -> Vec<SlowEntry> {
        self.slowlog.snapshot()
    }

    /// Trip the shutdown token (idempotent; threads drain and exit).
    pub fn shutdown(&self) {
        self.shutdown.store(true, Ordering::SeqCst);
    }

    /// True once shutdown has been requested (locally or over the wire).
    pub fn is_shutting_down(&self) -> bool {
        self.shutdown.load(Ordering::SeqCst)
    }

    /// Shut down now and join every thread.
    pub fn join(mut self) -> Result<LatencyStats> {
        self.shutdown();
        self.join_threads()
    }

    /// Block until shutdown is requested (e.g. a wire `Shutdown`
    /// frame), then join every thread.
    pub fn wait(mut self) -> Result<LatencyStats> {
        while !self.shutdown.load(Ordering::SeqCst) {
            thread::sleep(self.poll_interval);
        }
        self.join_threads()
    }

    fn join_threads(&mut self) -> Result<LatencyStats> {
        // Order matters: the accept thread joins reader/writer threads,
        // whose exit drops the last queue senders, which lets shard
        // workers drain and observe disconnect. Every thread is joined
        // even if an earlier one panicked — shutdown is total; the
        // first failure is reported after.
        let mut first_err: Option<Error> = None;
        if let Some(h) = self.accept.take() {
            if h.join().is_err() {
                first_err.get_or_insert(Error::Runtime("accept thread panicked".into()));
            }
        }
        for h in self.workers.drain(..) {
            if h.join().is_err() {
                first_err.get_or_insert(Error::Runtime("shard worker panicked".into()));
            }
        }
        match first_err {
            Some(e) => Err(e),
            None => Ok(self.stats()),
        }
    }
}

/// Bind `addr` (e.g. `"127.0.0.1:0"`) and start serving `registry`.
pub fn start(
    registry: Arc<Registry>,
    config: DaemonConfig,
    addr: &str,
) -> Result<DaemonHandle> {
    let listener = TcpListener::bind(addr)?;
    let local_addr = listener.local_addr()?;
    listener.set_nonblocking(true)?;
    let shutdown = Arc::new(AtomicBool::new(false));
    let n_shards = config.shards.max(1);
    let cache = Arc::new(ChunkCache::new(config.cache_bytes, n_shards));
    let stats = Arc::new(Mutex::new(LatencyStats::new()));
    let obs = Obs {
        metrics: Arc::new(MetricsRegistry::new()),
        slowlog: Arc::new(SlowLog::new(SLOWLOG_CAP)),
    };
    // File-backed sources time their positioned reads themselves
    // (`file_read` stage) — hand each its dataset handle up front so
    // the hot path never resolves by name.
    if crate::obs::ENABLED {
        for (name, src) in registry.sources() {
            if let DatasetSource::File(f) = src {
                f.attach_metrics(obs.metrics.dataset(name));
            }
        }
    }
    let mut senders = Vec::with_capacity(n_shards);
    let mut workers = Vec::with_capacity(n_shards);
    for si in 0..n_shards {
        let (tx, rx) = mpsc::sync_channel::<Job>(config.queue_depth.max(1));
        senders.push(tx);
        let reg = registry.clone();
        let cache = cache.clone();
        let stats = stats.clone();
        let obs = obs.clone();
        let handle = thread::Builder::new()
            .name(format!("codag-shard-{si}"))
            .spawn(move || shard_loop(&reg, &cache, config, rx, &stats, &obs))?;
        workers.push(handle);
    }
    // The accept thread owns the long-lived queue senders (each
    // connection gets its own clone); when it and the readers it joins
    // exit, every sender is dropped and workers see disconnect after
    // draining — the drain half of graceful shutdown.
    let accept = {
        let reg = registry.clone();
        let sd = shutdown.clone();
        let cache = cache.clone();
        let obs_a = obs.clone();
        thread::Builder::new()
            .name("codag-accept".into())
            .spawn(move || accept_loop(listener, reg, cache, senders, sd, config, obs_a))?
    };
    Ok(DaemonHandle {
        addr: local_addr,
        shutdown,
        accept: Some(accept),
        workers,
        stats,
        cache,
        metrics: obs.metrics,
        slowlog: obs.slowlog,
        poll_interval: config.poll_interval,
    })
}

fn accept_loop(
    listener: TcpListener,
    registry: Arc<Registry>,
    cache: Arc<ChunkCache>,
    senders: Vec<SyncSender<Job>>,
    shutdown: Arc<AtomicBool>,
    config: DaemonConfig,
    obs: Obs,
) {
    let mut conns: Vec<JoinHandle<()>> = Vec::new();
    while !shutdown.load(Ordering::SeqCst) {
        // Reap finished connection threads every tick so a
        // burst-then-idle pattern does not retain dead handles.
        if conns.iter().any(|c| c.is_finished()) {
            let mut live = Vec::with_capacity(conns.len());
            for c in conns.drain(..) {
                if c.is_finished() {
                    let _ = c.join();
                } else {
                    live.push(c);
                }
            }
            conns = live;
        }
        match listener.accept() {
            Ok((stream, _peer)) => {
                // Hard connection cap: each connection costs a reader
                // and a writer thread, so excess connects are refused
                // (closed) rather than accumulated.
                if conns.len() >= config.max_connections.max(1) {
                    drop(stream);
                    continue;
                }
                let reg = registry.clone();
                let cch = cache.clone();
                // Per-connection sender clones: no shared reference, so
                // dropping them (reader exit) is all the bookkeeping
                // shutdown needs.
                let snd: Vec<SyncSender<Job>> = senders.clone();
                let sd = shutdown.clone();
                let obs = obs.clone();
                match thread::Builder::new()
                    .name("codag-conn".into())
                    .spawn(move || connection_loop(stream, &reg, &cch, &snd, &sd, config, &obs))
                {
                    Ok(h) => conns.push(h),
                    Err(e) => eprintln!("codag-serve: connection spawn failed: {e}"),
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                thread::sleep(Duration::from_millis(5));
            }
            Err(_) => thread::sleep(Duration::from_millis(5)),
        }
    }
    for c in conns {
        let _ = c.join();
    }
}

fn connection_loop(
    mut stream: TcpStream,
    registry: &Registry,
    cache: &ChunkCache,
    senders: &[SyncSender<Job>],
    shutdown: &AtomicBool,
    config: DaemonConfig,
    obs: &Obs,
) {
    // Accepted sockets may inherit the listener's non-blocking flag on
    // some platforms — force blocking + read timeout so this thread
    // sleeps in `read` and still polls the shutdown token; write
    // timeouts keep a stuck peer from wedging shutdown.
    if stream.set_nonblocking(false).is_err()
        || stream.set_read_timeout(Some(config.poll_interval)).is_err()
    {
        return;
    }
    let _ = stream.set_write_timeout(Some(config.write_timeout));
    // Request/response framing writes header and payload separately:
    // without NODELAY, Nagle + delayed ACK can stall every exchange.
    let _ = stream.set_nodelay(true);
    let Ok(mut wstream) = stream.try_clone() else { return };
    let (tx, rx) = mpsc::channel::<Outbound>();
    // Unwritten responses on this connection (every request yields
    // exactly one response: the reader charges the counter per decoded
    // frame, the writer debits it per frame written), plus the byte
    // charge of admitted-but-unwritten payloads. Together they bound
    // the response-side buffering the shard queues cannot see.
    let inflight = Arc::new(AtomicUsize::new(0));
    let inflight_bytes = Arc::new(AtomicU64::new(0));
    let writer = {
        let inflight = inflight.clone();
        let inflight_bytes = inflight_bytes.clone();
        thread::Builder::new().name("codag-conn-writer".into()).spawn(move || {
            while let Ok(out) = rx.recv() {
                let t0 = now_if_enabled().filter(|_| out.obs.is_some());
                let ok = write_response_versioned(&mut wstream, &out.resp, out.version).is_ok();
                if let Some(dm) = &out.obs {
                    if let Some(t0) = t0 {
                        dm.stage(Stage::ResponseWrite).record(t0.elapsed());
                    }
                    // Balanced against the inc at admission: the request
                    // is no longer in flight once its frame hits (or
                    // fails to hit) the socket.
                    dm.inflight.dec();
                }
                inflight.fetch_sub(1, Ordering::SeqCst);
                inflight_bytes.fetch_sub(out.charge, Ordering::SeqCst);
                if !ok {
                    break; // peer gone or stuck; remaining responses drop
                }
            }
        })
    };
    let Ok(writer) = writer else { return };
    // Request-sized cap: a hostile length prefix must not pre-allocate
    // a response-sized buffer per connection.
    let mut reader = FrameReader::for_requests();
    loop {
        // Check the token every iteration, not only on read timeouts: a
        // client pipelining frames faster than poll_interval must not
        // keep this thread (and therefore shutdown joins) alive. A dead
        // writer (peer stopped reading; write timeout fired) is equally
        // fatal — admitting more work would just decode into a dropped
        // channel.
        if shutdown.load(Ordering::SeqCst) || writer.is_finished() {
            break;
        }
        match reader.poll(&mut stream) {
            Ok(ReadEvent::WouldBlock) => {}
            Ok(ReadEvent::Eof) => break,
            Ok(ReadEvent::Frame(body)) => match decode_request_versioned(&body) {
                Ok((req, version)) => {
                    // Charge this request's (single) response up front.
                    let outstanding = inflight.fetch_add(1, Ordering::SeqCst);
                    if outstanding >= conn_hard_cap(&config)
                        && !matches!(req, WireRequest::Shutdown { .. })
                    {
                        // The client is pipelining without reading even
                        // small responses: close instead of buffering
                        // (the unsent response's charge is returned).
                        inflight.fetch_sub(1, Ordering::SeqCst);
                        break;
                    }
                    if !handle_request(
                        req,
                        version,
                        registry,
                        cache,
                        senders,
                        &tx,
                        outstanding,
                        &inflight_bytes,
                        shutdown,
                        config,
                        obs,
                    ) {
                        break;
                    }
                }
                Err(e) => {
                    // Framing is no longer trustworthy: respond (echo
                    // the id and version when the body was long enough
                    // to carry them — a strict v1 client can only
                    // decode a v1-stamped error), close.
                    inflight.fetch_add(1, Ordering::SeqCst);
                    let id = crate::server::proto::request_id_hint(&body);
                    let version = crate::server::proto::request_version_hint(&body);
                    send_reply(
                        &tx,
                        version,
                        WireResponse::error(id, Status::BadRequest, e.to_string()),
                    );
                    break;
                }
            },
            Err(e) => {
                // Corrupt = the peer broke framing (oversized prefix,
                // mid-frame close): client fault. Anything else is a
                // transport failure on our side.
                let status = match &e {
                    Error::Corrupt(_) => Status::BadRequest,
                    _ => Status::Internal,
                };
                inflight.fetch_add(1, Ordering::SeqCst);
                send_reply(&tx, WIRE_VERSION, WireResponse::error(0, status, e.to_string()));
                break;
            }
        }
    }
    drop(tx); // writer drains in-flight responses, then exits
    let _ = writer.join();
}

/// Dispatch one decoded request; returns false to close the connection.
/// `outstanding` is the connection's unwritten-response count at the
/// moment this request was charged (the reader increments it, the
/// writer decrements it as frames reach the socket).
#[allow(clippy::too_many_arguments)]
fn handle_request(
    req: WireRequest,
    version: u16,
    registry: &Registry,
    cache: &ChunkCache,
    senders: &[SyncSender<Job>],
    tx: &mpsc::Sender<Outbound>,
    outstanding: usize,
    inflight_bytes: &AtomicU64,
    shutdown: &AtomicBool,
    config: DaemonConfig,
    obs: &Obs,
) -> bool {
    // Backpressure half 2: a pipelining client that does not read its
    // responses stops being served once its unwritten-response budget
    // is spent (Shutdown stays exempt so a draining admin always gets
    // through; the reader's hard cap bounds even Busy floods).
    let over_budget = outstanding >= config.max_inflight_per_conn.max(1);
    match req {
        WireRequest::Shutdown { id } => {
            send_reply(
                tx,
                version,
                WireResponse { id, status: Status::Ok, payload: b"shutting down".to_vec() },
            );
            shutdown.store(true, Ordering::SeqCst);
            false
        }
        WireRequest::Metrics { id } => {
            let resp = if over_budget {
                WireResponse::error(id, Status::Busy, "connection in-flight limit")
            } else {
                let text = expo::render(&obs.metrics, &obs.slowlog);
                WireResponse { id, status: Status::Ok, payload: text.into_bytes() }
            };
            send_reply(tx, version, resp);
            true
        }
        WireRequest::Stat { id, dataset } => {
            let resp = if over_budget {
                WireResponse::error(id, Status::Busy, "connection in-flight limit")
            } else {
                match registry.get(&dataset) {
                    Ok(c) => {
                        // 64-byte v2 Stat payload: dataset dimensions,
                        // then the daemon-wide cache counters. A v1
                        // requester gets exactly the 24-byte payload
                        // its strict decoder expects.
                        let mut payload = Vec::with_capacity(64);
                        payload.extend_from_slice(&c.total_uncompressed().to_le_bytes());
                        payload.extend_from_slice(&(c.chunk_size() as u64).to_le_bytes());
                        payload.extend_from_slice(&(c.n_chunks() as u64).to_le_bytes());
                        if version >= 2 {
                            payload.extend_from_slice(&cache.hits().to_le_bytes());
                            payload.extend_from_slice(&cache.misses().to_le_bytes());
                            payload.extend_from_slice(&cache.evictions().to_le_bytes());
                            payload.extend_from_slice(&cache.admit_declines().to_le_bytes());
                            payload.extend_from_slice(&cache.ghost_hits().to_le_bytes());
                        }
                        WireResponse { id, status: Status::Ok, payload }
                    }
                    Err(e) => WireResponse::error(id, Status::NotFound, e.to_string()),
                }
            };
            send_reply(tx, version, resp);
            true
        }
        WireRequest::Get { id, dataset, offset, len, deadline_ms } => {
            // Admission-stage clock: started before any checks so the
            // stage covers the full reader-side admission cost.
            let t_adm = now_if_enabled();
            if over_budget {
                send_reply(
                    tx,
                    version,
                    WireResponse::error(id, Status::Busy, "connection in-flight limit"),
                );
                return true;
            }
            if shutdown.load(Ordering::SeqCst) {
                send_reply(
                    tx,
                    version,
                    WireResponse::error(id, Status::ShuttingDown, "daemon is draining"),
                );
                return true;
            }
            let Ok(container) = registry.get(&dataset) else {
                send_reply(
                    tx,
                    version,
                    WireResponse::error(
                        id,
                        Status::NotFound,
                        format!("dataset '{dataset}' not registered"),
                    ),
                );
                return true;
            };
            // Resolved only after the registry lookup succeeds: hostile
            // dataset names must not mint registry entries (unbounded
            // label cardinality).
            let dm = t_adm.map(|_| obs.metrics.dataset(&dataset));
            // Reject ranges whose response could not be framed (body
            // capped at MAX_FRAME_LEN) before any decode work is done —
            // otherwise the writer would fail the oversized frame and
            // drop the connection without an error response.
            let span = {
                let remaining = container.total_uncompressed().saturating_sub(offset);
                if len == 0 {
                    remaining
                } else {
                    len.min(remaining)
                }
            };
            if span > (crate::server::proto::MAX_FRAME_LEN as u64).saturating_sub(64) {
                send_reply(
                    tx,
                    version,
                    WireResponse::error(
                        id,
                        Status::BadRequest,
                        format!("range of {span} bytes exceeds the max response frame"),
                    ),
                );
                return true;
            }
            // Byte half of the connection budget: admitted payload
            // bytes not yet written to the socket. One request is
            // always admitted when nothing is outstanding, so the true
            // bound is the budget plus one frame.
            let bytes_now = inflight_bytes.load(Ordering::SeqCst);
            if bytes_now > 0
                && bytes_now.saturating_add(span) > config.max_inflight_bytes_per_conn as u64
            {
                if let Some(m) = &dm {
                    m.busy.inc();
                }
                send_reply(
                    tx,
                    version,
                    WireResponse::error(id, Status::Busy, "connection byte budget exhausted"),
                );
                return true;
            }
            inflight_bytes.fetch_add(span, Ordering::SeqCst);
            // All requests for one dataset land on one shard: FIFO per
            // dataset is preserved through the bounded queue.
            let si = (fnv1a(dataset.as_bytes()) % senders.len() as u64) as usize;
            let received = Instant::now();
            // Relative wire deadline, anchored at frame decode (no
            // client/daemon clock sync needed); 0 = none.
            let deadline = if deadline_ms > 0 {
                received.checked_add(Duration::from_millis(deadline_ms))
            } else {
                None
            };
            let job = Job {
                req: Request { id, dataset, offset, len },
                reply: tx.clone(),
                received,
                charge: span,
                deadline,
                version,
                dm: dm.clone(),
            };
            match senders[si].try_send(job) {
                Ok(()) => {
                    if let (Some(t0), Some(m)) = (t_adm, &dm) {
                        m.requests.inc();
                        m.inflight.inc();
                        m.stage(Stage::Admission).record(t0.elapsed());
                    }
                }
                Err(TrySendError::Full(job)) => {
                    inflight_bytes.fetch_sub(job.charge, Ordering::SeqCst);
                    if let Some(m) = &dm {
                        m.busy.inc();
                    }
                    // Backpressure half 1: explicit Busy, never queue
                    // growth.
                    send_reply(
                        tx,
                        job.version,
                        WireResponse::error(
                            job.req.id,
                            Status::Busy,
                            format!("shard {si} queue at admission limit"),
                        ),
                    );
                }
                Err(TrySendError::Disconnected(job)) => {
                    inflight_bytes.fetch_sub(job.charge, Ordering::SeqCst);
                    send_reply(
                        tx,
                        job.version,
                        WireResponse::error(
                            job.req.id,
                            Status::ShuttingDown,
                            "daemon is shutting down",
                        ),
                    );
                }
            }
            true
        }
    }
}

/// Map a decode error onto a wire status.
fn status_for(e: &Error) -> Status {
    match e {
        Error::Corrupt(_) => Status::Corrupt,
        Error::Invalid(_) => Status::BadRequest,
        Error::Io(_) | Error::Runtime(_) => Status::Internal,
    }
}

/// Reply metadata for one live batch item, carried alongside the owned
/// `Request` handed to `serve_batch_with`.
struct ReplyMeta {
    reply: mpsc::Sender<Outbound>,
    received: Instant,
    charge: u64,
    version: u16,
    dm: Option<Arc<DatasetMetrics>>,
    /// Queue wait in µs (admission → dequeue), kept so the slowlog
    /// entry's stage offsets are cumulative from `received`.
    wait_us: u64,
}

fn shard_loop(
    registry: &Registry,
    cache: &ChunkCache,
    config: DaemonConfig,
    rx: Receiver<Job>,
    stats: &Mutex<LatencyStats>,
    obs: &Obs,
) {
    // One Service per shard, constructed once and reused for every
    // batch (plan/cache wiring is long-lived; decode parallelism
    // inside serve_batch uses scoped threads per batch, and
    // single-item batches decode inline with no spawn at all). A zero
    // cache budget means no cache: don't pay per-chunk lock+miss
    // traffic for a disabled cache.
    let svc_cfg = ServiceConfig { workers: config.workers_per_shard.max(1), hybrid: false };
    let service = Service::new(registry, None, svc_cfg).with_metrics(obs.metrics.clone());
    let service = if config.cache_bytes > 0 { service.with_cache(cache) } else { service };
    loop {
        let first = match rx.recv_timeout(config.poll_interval) {
            Ok(j) => j,
            Err(RecvTimeoutError::Timeout) => continue,
            // All senders dropped (accept loop + readers exited) and
            // the queue is fully drained: graceful exit.
            Err(RecvTimeoutError::Disconnected) => break,
        };
        let mut jobs = vec![first];
        while jobs.len() < config.batch.max(1) {
            match rx.try_recv() {
                Ok(j) => jobs.push(j),
                Err(_) => break,
            }
        }
        // Deadline check #1, at dequeue: a job whose deadline lapsed in
        // the queue is answered `Expired` right here and never enters
        // the decode batch — an expired request must not consume a
        // decode slot. The admission byte charge still rides the
        // response so the connection budget is returned on write.
        let now = Instant::now();
        let mut live = Vec::with_capacity(jobs.len());
        for j in jobs {
            // Queue wait (admission → dequeue) is recorded for every
            // dequeued job, expired ones included — expiry is exactly
            // the tail this stage exists to expose.
            let wait_us = now.saturating_duration_since(j.received).as_micros() as u64;
            if let Some(m) = &j.dm {
                m.stage(Stage::QueueWait).record_us(wait_us);
            }
            if j.deadline.is_some_and(|d| now >= d) {
                if let Some(m) = &j.dm {
                    m.expired.inc();
                }
                let resp = WireResponse::error(
                    j.req.id,
                    Status::Expired,
                    "deadline expired while queued",
                );
                let _ = j.reply.send(Outbound {
                    resp,
                    charge: j.charge,
                    version: j.version,
                    obs: j.dm,
                });
            } else {
                live.push((j, wait_us));
            }
        }
        if live.is_empty() {
            continue;
        }
        // Hand the owned Requests straight to serve_batch (no per-job
        // clone on the hot path); reply metadata rides alongside. The
        // per-request codec is resolved once here, not per response in
        // the reply loop below.
        let mut requests = Vec::with_capacity(live.len());
        let mut replies = Vec::with_capacity(live.len());
        let mut deadlines = Vec::with_capacity(live.len());
        let mut codecs = Vec::with_capacity(live.len());
        for (j, wait_us) in live {
            codecs.push(registry.get(&j.req.dataset).map(|s| s.codec()).ok());
            requests.push(j.req);
            deadlines.push(j.deadline);
            replies.push(ReplyMeta {
                reply: j.reply,
                received: j.received,
                charge: j.charge,
                version: j.version,
                dm: j.dm,
                wait_us,
            });
        }
        // Deadline check #2, between batch items: the service consults
        // this probe before decoding each of a request's chunks, so a
        // deadline lapsing mid-batch stops burning decode work.
        let (responses, _) = service.serve_batch_with(&requests, |ri| {
            deadlines[ri].is_some_and(|d| Instant::now() >= d)
        });
        // Record into a batch-local recorder and take the shared lock
        // once per batch, not once per response — shards must not
        // serialize on the stats mutex in the reply hot path.
        let mut batch_stats = LatencyStats::new();
        for (ri, (meta, resp)) in replies.into_iter().zip(responses).enumerate() {
            let wire = match resp.data {
                Ok(bytes) => {
                    let total = meta.received.elapsed();
                    // Admission-to-reply latency (includes queue wait —
                    // the quantity backpressure tuning moves).
                    batch_stats.record(total, bytes.len() as u64);
                    // Per-codec decoded-byte attribution (shutdown
                    // summary observability for the codec hot paths).
                    if let Some(codec) = codecs[ri] {
                        batch_stats.add_codec_bytes(codec, bytes.len() as u64);
                    }
                    if crate::obs::ENABLED && meta.dm.is_some() {
                        let total_us = total.as_micros() as u64;
                        obs.metrics.request_us().record_us(total_us);
                        // Cumulative stage offsets from receipt: wait,
                        // wait + service-side decode, full round trip.
                        // Each later offset clamps to total_us so the
                        // entry is monotone even under clock jitter.
                        let decode_at = meta
                            .wait_us
                            .saturating_add(resp.latency.as_micros() as u64)
                            .min(total_us);
                        obs.slowlog.offer(SlowEntry {
                            id: resp.id,
                            dataset: requests[ri].dataset.clone(),
                            total_us,
                            stages: vec![
                                (Stage::QueueWait, meta.wait_us.min(total_us)),
                                (Stage::DecodeSerial, decode_at),
                                (Stage::ResponseWrite, total_us),
                            ],
                        });
                    }
                    WireResponse { id: resp.id, status: Status::Ok, payload: bytes }
                }
                Err(Error::Runtime(msg))
                    if msg == crate::coordinator::service::DEADLINE_EXPIRED =>
                {
                    if let Some(m) = &meta.dm {
                        m.expired.inc();
                    }
                    WireResponse::error(resp.id, Status::Expired, msg)
                }
                Err(e) => WireResponse::error(resp.id, status_for(&e), e.to_string()),
            };
            let _ = meta.reply.send(Outbound {
                resp: wire,
                charge: meta.charge,
                version: meta.version,
                obs: meta.dm,
            });
        }
        if batch_stats.count() > 0 {
            stats.lock().unwrap().merge(&batch_stats);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn idle_daemon_starts_and_joins() {
        let registry = Arc::new(Registry::new());
        let handle =
            start(registry, DaemonConfig::default(), "127.0.0.1:0").expect("bind loopback");
        assert_ne!(handle.addr().port(), 0);
        assert!(!handle.is_shutting_down());
        let stats = handle.join().expect("clean join");
        assert_eq!(stats.count(), 0);
    }
}
