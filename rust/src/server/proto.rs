//! The `codag-serve` wire protocol: length-prefixed little-endian
//! frames over TCP.
//!
//! Every frame on the wire is a `u32` little-endian body length
//! followed by the body; bodies are capped at [`MAX_FRAME_LEN`] so a
//! corrupt or hostile peer cannot force an unbounded allocation. The
//! byte layouts below are frozen in DESIGN.md §6 and pinned by the unit
//! suite in this module.
//!
//! Request body (v3; a v2 body is identical minus the trailing `flags`
//! field, a v1 body additionally drops `deadline_ms` — both are still
//! accepted — see [`decode_request`]):
//!
//! ```text
//! magic:       u32 = 0xC0DA_5E01
//! version:     u16 = 3
//! kind:        u8          (1 = Get, 2 = Stat, 3 = Shutdown, 4 = Metrics)
//! name_len:    u8          (dataset name bytes; 0 for Shutdown)
//! id:          u64         (caller-assigned, echoed in the response)
//! offset:      u64         (uncompressed byte offset; Get only, else 0)
//! len:         u64         (uncompressed byte length, 0 = to end; Get only)
//! deadline_ms: u64         (relative deadline in ms, 0 = none; Get only)
//! flags:       u64         (v3+; bit 0 = FLAG_FRAME_CRC, rest reserved 0)
//! name:        name_len bytes of UTF-8
//! ```
//!
//! Response body (layout unchanged since v1 apart from the version
//! field, the v2-only `Expired` and v3-only `ChecksumMismatch`
//! statuses, and the v3 opt-in frame-CRC trailer):
//!
//! ```text
//! magic:       u32 = 0xC0DA_5E01
//! version:     u16 = 3
//! status:      u8       (see `Status`)
//! reserved:    u8 = 0
//! id:          u64      (echoed request id)
//! payload_len: u64      (== payload bytes, trailer excluded)
//! payload:     data on Ok, UTF-8 error text otherwise
//! frame_crc:   u32      (only when the request set FLAG_FRAME_CRC:
//!                        CRC32C over the 24-byte header + payload)
//! ```
//!
//! The trailer is covered by the frame length prefix (body length is
//! `24 + payload_len + 4` when present) but *not* by `payload_len`, so
//! the header layout stays frozen; v1/v2 requesters never receive one.
//!
//! A v2 `Stat` response payload is 64 bytes: `total_uncompressed: u64`,
//! `chunk_size: u64`, `n_chunks: u64`, then the daemon-wide chunk-cache
//! counters `hits`, `misses`, `evictions`, `admit_declines`,
//! `ghost_hits` (all u64 little-endian). A v1 requester gets exactly
//! the 24-byte prefix its strict decoder expects (the daemon echoes
//! both the version stamp and the payload shape of the request's
//! protocol version).

use crate::format::hash::crc32c_extend;
use crate::{corrupt, invalid, Error, Result};
use std::io::{ErrorKind, Read, Write};

/// Magic number opening every request and response body.
pub const WIRE_MAGIC: u32 = 0xC0DA_5E01;
/// Protocol version; bumped on any layout change (see DESIGN.md §6).
/// v2 added the `deadline_ms` request field, the `Expired` status, and
/// the extended `Stat` payload; v3 added the request `flags` field
/// (opt-in response frame CRC) and the `ChecksumMismatch` status. v1
/// and v2 frames are still accepted.
pub const WIRE_VERSION: u16 = 3;
/// Oldest protocol version [`decode_request`]/[`decode_response`]
/// still accept.
pub const WIRE_VERSION_MIN: u16 = 1;
/// Request flag (v3+): the client asks for a CRC32C trailer on every
/// response frame to this request, covering the 24-byte response
/// header and the payload (`loadgen --verify-frames` end-to-end wire
/// integrity). All other flag bits are reserved and must be 0.
pub const FLAG_FRAME_CRC: u64 = 1;
/// Upper bound on one frame body (guards allocation on decode).
pub const MAX_FRAME_LEN: u32 = 256 * 1024 * 1024;
/// Server-side bound on *inbound request* frames. Requests are at most
/// 48 + 255 bytes, so the daemon reads with this cap instead of
/// [`MAX_FRAME_LEN`] — a hostile length prefix must not make the
/// server pre-allocate a response-sized buffer.
pub const MAX_REQUEST_FRAME_LEN: u32 = 4096;
/// Upper bound on a dataset name (it is length-prefixed with a u8).
pub const MAX_NAME_LEN: usize = 255;

/// Response status codes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Status {
    /// Request served; payload is the decompressed bytes.
    Ok,
    /// Dataset is not registered on this daemon.
    NotFound,
    /// Malformed request (bad range, bad frame, bad name).
    BadRequest,
    /// Backpressure: the shard queue is past its admission limit, or
    /// this connection's unwritten-response / byte budget is spent
    /// (drain responses before retrying — see DESIGN.md §6.3; the
    /// payload names the exact cause).
    Busy,
    /// The stored chunk failed to decode.
    Corrupt,
    /// Internal daemon error.
    Internal,
    /// Daemon is draining; no new work accepted.
    ShuttingDown,
    /// The request's deadline passed before decode work started (v2;
    /// never sent in reply to a v1 frame, which cannot carry a
    /// deadline).
    Expired,
    /// The chunk decoded cleanly but its bytes failed content-checksum
    /// verification against the checksum recorded at pack time (v3;
    /// maps from `Error::ChecksumMismatch`). Distinct from `Corrupt`:
    /// the stream parsed, the *content* is provably wrong.
    ChecksumMismatch,
}

impl Status {
    /// Wire discriminant.
    pub fn as_u8(self) -> u8 {
        match self {
            Status::Ok => 0,
            Status::NotFound => 1,
            Status::BadRequest => 2,
            Status::Busy => 3,
            Status::Corrupt => 4,
            Status::Internal => 5,
            Status::ShuttingDown => 6,
            Status::Expired => 7,
            Status::ChecksumMismatch => 8,
        }
    }

    /// Parse a wire discriminant.
    pub fn from_u8(v: u8) -> Option<Status> {
        Some(match v {
            0 => Status::Ok,
            1 => Status::NotFound,
            2 => Status::BadRequest,
            3 => Status::Busy,
            4 => Status::Corrupt,
            5 => Status::Internal,
            6 => Status::ShuttingDown,
            7 => Status::Expired,
            8 => Status::ChecksumMismatch,
            _ => return None,
        })
    }

    /// Human-readable label.
    pub fn label(self) -> &'static str {
        match self {
            Status::Ok => "ok",
            Status::NotFound => "not-found",
            Status::BadRequest => "bad-request",
            Status::Busy => "busy",
            Status::Corrupt => "corrupt",
            Status::Internal => "internal",
            Status::ShuttingDown => "shutting-down",
            Status::Expired => "expired",
            Status::ChecksumMismatch => "checksum-mismatch",
        }
    }
}

/// A decoded request frame.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum WireRequest {
    /// Decompress `[offset, offset+len)` of `dataset` (`len == 0` = to end).
    Get {
        /// Caller-assigned id, echoed back.
        id: u64,
        /// Registered dataset name.
        dataset: String,
        /// Uncompressed byte offset.
        offset: u64,
        /// Uncompressed byte length (0 = to end).
        len: u64,
        /// Relative deadline in milliseconds, measured by the daemon
        /// from the moment it decodes the frame; 0 = no deadline. A
        /// request still queued past its deadline is answered
        /// [`Status::Expired`] instead of being decoded.
        deadline_ms: u64,
    },
    /// Query dataset metadata (total length, chunk size, chunk count).
    Stat {
        /// Caller-assigned id, echoed back.
        id: u64,
        /// Registered dataset name.
        dataset: String,
    },
    /// Ask the daemon to drain and exit.
    Shutdown {
        /// Caller-assigned id, echoed back.
        id: u64,
    },
    /// Scrape the daemon's metrics: the `Ok` payload is the UTF-8 text
    /// exposition rendered by `obs::expo::render` (DESIGN.md §10). The
    /// request layout is the common header with kind 4 and an empty
    /// dataset name — wire-compatible with v1 and v2 framing.
    Metrics {
        /// Caller-assigned id, echoed back.
        id: u64,
    },
}

/// A response frame.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct WireResponse {
    /// Echoed request id.
    pub id: u64,
    /// Outcome.
    pub status: Status,
    /// Decompressed bytes on `Ok`, UTF-8 error text otherwise.
    pub payload: Vec<u8>,
}

impl WireResponse {
    /// Convenience constructor for error responses.
    pub fn error(id: u64, status: Status, msg: impl Into<String>) -> WireResponse {
        WireResponse { id, status, payload: msg.into().into_bytes() }
    }
}

const REQ_KIND_GET: u8 = 1;
const REQ_KIND_STAT: u8 = 2;
const REQ_KIND_SHUTDOWN: u8 = 3;
const REQ_KIND_METRICS: u8 = 4;

/// Encode a request into a v3 frame body with no flags set (no length
/// prefix; pair with [`write_frame`]).
pub fn encode_request(req: &WireRequest) -> Result<Vec<u8>> {
    encode_request_flags(req, 0)
}

/// [`encode_request`] with explicit v3 request flags (bit 0 =
/// [`FLAG_FRAME_CRC`]; all other bits reserved, must be 0).
pub fn encode_request_flags(req: &WireRequest, flags: u64) -> Result<Vec<u8>> {
    let (kind, id, dataset, offset, len, deadline_ms) = match req {
        WireRequest::Get { id, dataset, offset, len, deadline_ms } => {
            (REQ_KIND_GET, *id, dataset.as_str(), *offset, *len, *deadline_ms)
        }
        WireRequest::Stat { id, dataset } => (REQ_KIND_STAT, *id, dataset.as_str(), 0, 0, 0),
        WireRequest::Shutdown { id } => (REQ_KIND_SHUTDOWN, *id, "", 0, 0, 0),
        WireRequest::Metrics { id } => (REQ_KIND_METRICS, *id, "", 0, 0, 0),
    };
    let name = dataset.as_bytes();
    if name.len() > MAX_NAME_LEN {
        return Err(invalid(format!("dataset name too long ({} bytes)", name.len())));
    }
    let mut out = Vec::with_capacity(48 + name.len());
    out.extend_from_slice(&WIRE_MAGIC.to_le_bytes());
    out.extend_from_slice(&WIRE_VERSION.to_le_bytes());
    out.push(kind);
    out.push(name.len() as u8);
    out.extend_from_slice(&id.to_le_bytes());
    out.extend_from_slice(&offset.to_le_bytes());
    out.extend_from_slice(&len.to_le_bytes());
    out.extend_from_slice(&deadline_ms.to_le_bytes());
    out.extend_from_slice(&flags.to_le_bytes());
    out.extend_from_slice(name);
    Ok(out)
}

/// Decode a request frame body. Accepts protocol v3 (48-byte header
/// with `flags`), v2 (40-byte header with `deadline_ms`; flags default
/// to 0) and the v1 compat layout (32-byte header; the deadline
/// defaults to 0 = none).
pub fn decode_request(body: &[u8]) -> Result<WireRequest> {
    decode_request_versioned(body).map(|(req, _, _)| req)
}

/// [`decode_request`] plus the frame's protocol version and v3 flags,
/// so the daemon can stamp each response with the version its requester
/// actually speaks (a v1 client rejects v2-stamped replies) and honour
/// the frame-CRC opt-in.
pub fn decode_request_versioned(body: &[u8]) -> Result<(WireRequest, u16, u64)> {
    let mut rd = Rd::new(body);
    let magic = rd.u32()?;
    if magic != WIRE_MAGIC {
        return Err(corrupt(format!("bad request magic {magic:#010x}")));
    }
    let version = rd.u16()?;
    if !(WIRE_VERSION_MIN..=WIRE_VERSION).contains(&version) {
        return Err(corrupt(format!("unsupported protocol version {version}")));
    }
    let kind = rd.u8()?;
    let name_len = rd.u8()? as usize;
    let id = rd.u64()?;
    let offset = rd.u64()?;
    let len = rd.u64()?;
    let deadline_ms = if version >= 2 { rd.u64()? } else { 0 };
    let flags = if version >= 3 { rd.u64()? } else { 0 };
    let name = rd.bytes(name_len)?;
    let dataset = std::str::from_utf8(name)
        .map_err(|_| corrupt("dataset name is not UTF-8"))?
        .to_string();
    rd.done()?;
    let req = match kind {
        REQ_KIND_GET => WireRequest::Get { id, dataset, offset, len, deadline_ms },
        REQ_KIND_STAT => WireRequest::Stat { id, dataset },
        REQ_KIND_SHUTDOWN => WireRequest::Shutdown { id },
        REQ_KIND_METRICS => WireRequest::Metrics { id },
        other => return Err(corrupt(format!("unknown request kind {other}"))),
    };
    Ok((req, version, flags))
}

/// Encode a response into a frame body (no length prefix).
pub fn encode_response(resp: &WireResponse) -> Vec<u8> {
    let mut out = Vec::with_capacity(24 + resp.payload.len());
    out.extend_from_slice(&WIRE_MAGIC.to_le_bytes());
    out.extend_from_slice(&WIRE_VERSION.to_le_bytes());
    out.push(resp.status.as_u8());
    out.push(0); // reserved
    out.extend_from_slice(&resp.id.to_le_bytes());
    out.extend_from_slice(&(resp.payload.len() as u64).to_le_bytes());
    out.extend_from_slice(&resp.payload);
    out
}

/// Decode a response frame body (verifying and stripping a v3 frame-CRC
/// trailer when present — a bad trailer is [`Error::ChecksumMismatch`]).
pub fn decode_response(body: &[u8]) -> Result<WireResponse> {
    decode_response_ext(body).map(|(resp, _)| resp)
}

/// [`decode_response`] plus the verified frame CRC when the body
/// carried a v3 trailer (`None` otherwise) — `loadgen --verify-frames`
/// uses the presence bit to prove the daemon actually honoured
/// [`FLAG_FRAME_CRC`] rather than silently ignoring it.
pub fn decode_response_ext(body: &[u8]) -> Result<(WireResponse, Option<u32>)> {
    let mut rd = Rd::new(body);
    let magic = rd.u32()?;
    if magic != WIRE_MAGIC {
        return Err(corrupt(format!("bad response magic {magic:#010x}")));
    }
    let version = rd.u16()?;
    if !(WIRE_VERSION_MIN..=WIRE_VERSION).contains(&version) {
        return Err(corrupt(format!("unsupported protocol version {version}")));
    }
    let status_byte = rd.u8()?;
    let status = Status::from_u8(status_byte)
        .ok_or_else(|| corrupt(format!("unknown status {status_byte}")))?;
    let _reserved = rd.u8()?;
    let id = rd.u64()?;
    let payload_len = rd.u64()? as usize;
    let payload = rd.bytes(payload_len)?.to_vec();
    // Exactly 4 bytes past the payload on a v3 frame is the opt-in
    // frame-CRC trailer; anything else still errors as trailing bytes.
    let frame_crc = if version >= 3 && rd.remaining() == 4 {
        let covered = &body[..24 + payload_len];
        let want = rd.u32()?;
        let got = crc32c_extend(0, covered);
        if got != want {
            return Err(Error::ChecksumMismatch(format!(
                "response frame id {id}: crc32c {got:08x}, trailer {want:08x}"
            )));
        }
        Some(want)
    } else {
        None
    };
    rd.done()?;
    Ok((WireResponse { id, status, payload }, frame_crc))
}

/// Write a response as one frame *without copying the payload*: length
/// prefix and 24-byte header in one stack buffer, then the payload
/// slice straight from the response. Byte-identical to
/// `write_frame(w, &encode_response(resp))` (pinned by a unit test) —
/// this is the daemon's reply hot path, where the extra
/// `encode_response` memcpy of a multi-MiB payload matters.
pub fn write_response(w: &mut impl Write, resp: &WireResponse) -> Result<()> {
    write_response_versioned(w, resp, WIRE_VERSION)
}

/// [`write_response`] stamped with an explicit protocol version: the
/// daemon echoes the version of the request it is answering (v1
/// clients require v1-stamped replies; the response byte layout is
/// otherwise identical across versions).
pub fn write_response_versioned(
    w: &mut impl Write,
    resp: &WireResponse,
    version: u16,
) -> Result<()> {
    write_response_parts(w, version, resp.status, resp.id, &resp.payload)
}

/// Build the 28-byte stack head of a response frame: the u32 length
/// prefix followed by the frozen 24-byte response header. The evented
/// writer queues this head beside a borrowed payload and issues both as
/// one vectored write with no assembly buffer (DESIGN.md §11);
/// [`write_response_versioned`] shares it so both net models emit
/// byte-identical frames. Errors when the frame would exceed
/// [`MAX_FRAME_LEN`].
pub fn response_head(version: u16, status: Status, id: u64, payload_len: u64) -> Result<[u8; 28]> {
    response_head_ext(version, status, id, payload_len, 0)
}

/// [`response_head`] with `trailer_len` extra body bytes budgeted into
/// the length prefix (4 when the frame carries a v3 CRC trailer, 0
/// otherwise). `payload_len` in the frozen header never includes the
/// trailer.
pub fn response_head_ext(
    version: u16,
    status: Status,
    id: u64,
    payload_len: u64,
    trailer_len: u64,
) -> Result<[u8; 28]> {
    let body_len = 24u64 + payload_len + trailer_len;
    if body_len > MAX_FRAME_LEN as u64 {
        return Err(invalid(format!("response frame too large ({body_len} bytes)")));
    }
    let mut head = [0u8; 28];
    head[0..4].copy_from_slice(&(body_len as u32).to_le_bytes());
    head[4..8].copy_from_slice(&WIRE_MAGIC.to_le_bytes());
    head[8..10].copy_from_slice(&version.to_le_bytes());
    head[10] = status.as_u8();
    head[11] = 0; // reserved
    head[12..20].copy_from_slice(&id.to_le_bytes());
    head[20..28].copy_from_slice(&payload_len.to_le_bytes());
    Ok(head)
}

/// The v3 frame-CRC trailer for a response whose stack head was built
/// by [`response_head_ext`]: CRC32C over the 24-byte body header
/// (`head[4..]` — the length prefix is not body) chained over the
/// payload, as little-endian bytes ready to append to the frame.
pub fn response_frame_crc(head: &[u8; 28], payload: &[u8]) -> [u8; 4] {
    let crc = crc32c_extend(crc32c_extend(0, &head[4..]), payload);
    crc.to_le_bytes()
}

/// Write one response frame from borrowed parts (head + payload, no
/// intermediate copy). This is [`write_response_versioned`] without
/// requiring the payload to live in a `WireResponse`-owned `Vec` — the
/// threaded writer calls it with `Payload::as_slice()` so shared cache
/// spans go to the socket uncopied.
pub fn write_response_parts(
    w: &mut impl Write,
    version: u16,
    status: Status,
    id: u64,
    payload: &[u8],
) -> Result<()> {
    write_response_parts_crc(w, version, status, id, payload, false)
}

/// [`write_response_parts`] with an optional v3 frame-CRC trailer: when
/// `with_crc` is set the length prefix budgets 4 extra bytes and the
/// CRC32C of (header + payload) follows the payload — the threaded
/// writer's half of the [`FLAG_FRAME_CRC`] contract.
pub fn write_response_parts_crc(
    w: &mut impl Write,
    version: u16,
    status: Status,
    id: u64,
    payload: &[u8],
    with_crc: bool,
) -> Result<()> {
    let trailer_len = if with_crc { 4 } else { 0 };
    let head = response_head_ext(version, status, id, payload.len() as u64, trailer_len)?;
    w.write_all(&head)?;
    w.write_all(payload)?;
    if with_crc {
        w.write_all(&response_frame_crc(&head, payload))?;
    }
    Ok(())
}

/// Best-effort request-id extraction for error responses: returns the
/// id field whenever the body is long enough to contain one (magic and
/// version are deliberately not checked — this exists so `BadRequest`
/// responses to malformed-but-framed requests can still be correlated
/// by id; a body too short to carry an id yields 0).
pub fn request_id_hint(body: &[u8]) -> u64 {
    match body.get(8..16) {
        Some(s) => u64::from_le_bytes([s[0], s[1], s[2], s[3], s[4], s[5], s[6], s[7]]),
        None => 0,
    }
}

/// Best-effort protocol-version extraction for error responses to
/// malformed frames (symmetric to [`request_id_hint`]): when the
/// version field survives and names a supported version, error replies
/// are stamped with it so a strict v1 client can still decode the
/// `BadRequest` it caused; anything else falls back to
/// [`WIRE_VERSION`].
pub fn request_version_hint(body: &[u8]) -> u16 {
    match body.get(4..6) {
        Some(s) => {
            let v = u16::from_le_bytes([s[0], s[1]]);
            if (WIRE_VERSION_MIN..=WIRE_VERSION).contains(&v) {
                v
            } else {
                WIRE_VERSION
            }
        }
        None => WIRE_VERSION,
    }
}

/// Write one frame (length prefix + body).
pub fn write_frame(w: &mut impl Write, body: &[u8]) -> Result<()> {
    if body.len() as u64 > MAX_FRAME_LEN as u64 {
        return Err(invalid(format!("frame body too large ({} bytes)", body.len())));
    }
    w.write_all(&(body.len() as u32).to_le_bytes())?;
    w.write_all(body)?;
    Ok(())
}

/// What [`FrameReader::poll`] observed on the stream.
#[derive(Debug)]
pub enum ReadEvent {
    /// One complete frame body.
    Frame(Vec<u8>),
    /// Clean end of stream (no partial frame buffered).
    Eof,
    /// The read timed out / would block; caller may check its shutdown
    /// token and poll again.
    WouldBlock,
}

/// Incremental frame reassembly over a (possibly timeout-equipped)
/// byte stream. The length prefix and the body are read with exact
/// sizes — the reader never consumes bytes past the current frame and
/// the body lands directly in its final buffer (no intermediate copy
/// on the receive hot path). Partial reads never lose data: progress
/// persists in the reader between `poll` calls. The frame cap bounds
/// the buffer allocated per length prefix: use [`FrameReader::new`]
/// (cap [`MAX_FRAME_LEN`]) for reading responses and
/// [`FrameReader::for_requests`] (cap [`MAX_REQUEST_FRAME_LEN`]) on
/// the server side.
#[derive(Debug)]
pub struct FrameReader {
    cap: u32,
    head: [u8; 4],
    head_filled: usize,
    /// Allocated once the length prefix is complete.
    body: Option<Vec<u8>>,
    body_filled: usize,
}

impl Default for FrameReader {
    fn default() -> FrameReader {
        FrameReader::new()
    }
}

impl FrameReader {
    /// Reader for response-sized frames (cap [`MAX_FRAME_LEN`]).
    pub fn new() -> FrameReader {
        FrameReader::with_cap(MAX_FRAME_LEN)
    }

    /// Server-side reader for request frames: a hostile length prefix
    /// can only force a [`MAX_REQUEST_FRAME_LEN`] allocation.
    pub fn for_requests() -> FrameReader {
        FrameReader::with_cap(MAX_REQUEST_FRAME_LEN)
    }

    /// Reader with an explicit frame cap.
    pub fn with_cap(cap: u32) -> FrameReader {
        FrameReader { cap, head: [0; 4], head_filled: 0, body: None, body_filled: 0 }
    }

    /// Pull the next frame. Returns [`ReadEvent::WouldBlock`] when the
    /// underlying read times out so callers can poll a shutdown token.
    pub fn poll(&mut self, r: &mut impl Read) -> Result<ReadEvent> {
        loop {
            if self.body.is_none() && self.head_filled == 4 {
                let len = u32::from_le_bytes(self.head);
                if len > self.cap {
                    return Err(corrupt(format!(
                        "frame length {len} exceeds cap {}",
                        self.cap
                    )));
                }
                self.body = Some(vec![0u8; len as usize]);
                self.body_filled = 0;
            }
            if let Some(body) = &mut self.body {
                if self.body_filled == body.len() {
                    let frame = self.body.take().expect("checked above");
                    self.head_filled = 0;
                    self.body_filled = 0;
                    return Ok(ReadEvent::Frame(frame));
                }
                match r.read(&mut body[self.body_filled..]) {
                    Ok(0) => return Err(corrupt("connection closed mid-frame")),
                    Ok(n) => self.body_filled += n,
                    Err(e)
                        if e.kind() == ErrorKind::WouldBlock
                            || e.kind() == ErrorKind::TimedOut =>
                    {
                        return Ok(ReadEvent::WouldBlock);
                    }
                    Err(e) if e.kind() == ErrorKind::Interrupted => {}
                    Err(e) => return Err(Error::from(e)),
                }
                continue;
            }
            match r.read(&mut self.head[self.head_filled..4]) {
                Ok(0) => {
                    return if self.head_filled == 0 {
                        Ok(ReadEvent::Eof)
                    } else {
                        Err(corrupt("connection closed mid-frame"))
                    };
                }
                Ok(n) => self.head_filled += n,
                Err(e)
                    if e.kind() == ErrorKind::WouldBlock || e.kind() == ErrorKind::TimedOut =>
                {
                    return Ok(ReadEvent::WouldBlock);
                }
                Err(e) if e.kind() == ErrorKind::Interrupted => {}
                Err(e) => return Err(Error::from(e)),
            }
        }
    }
}

/// Blocking convenience: read the next frame body, `Ok(None)` on clean
/// EOF. (On a blocking socket `WouldBlock` never surfaces; on one with
/// a read timeout this spins until a frame or EOF arrives.)
///
/// `fr` must be the connection's persistent reader: one `read` can
/// deliver bytes of several coalesced frames, and those bytes live in
/// the `FrameReader`'s buffer between calls — a fresh reader per call
/// would silently drop them and desync the stream.
pub fn read_frame_blocking(fr: &mut FrameReader, r: &mut impl Read) -> Result<Option<Vec<u8>>> {
    loop {
        match fr.poll(r)? {
            ReadEvent::Frame(f) => return Ok(Some(f)),
            ReadEvent::Eof => return Ok(None),
            ReadEvent::WouldBlock => continue,
        }
    }
}

/// Bounds-checked little-endian reader over a frame body.
struct Rd<'a> {
    b: &'a [u8],
    off: usize,
}

impl<'a> Rd<'a> {
    fn new(b: &'a [u8]) -> Rd<'a> {
        Rd { b, off: 0 }
    }

    fn bytes(&mut self, n: usize) -> Result<&'a [u8]> {
        let end = self
            .off
            .checked_add(n)
            .filter(|&e| e <= self.b.len())
            .ok_or_else(|| corrupt("truncated frame"))?;
        let s = &self.b[self.off..end];
        self.off = end;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.bytes(1)?[0])
    }

    fn u16(&mut self) -> Result<u16> {
        let s = self.bytes(2)?;
        Ok(u16::from_le_bytes([s[0], s[1]]))
    }

    fn u32(&mut self) -> Result<u32> {
        let s = self.bytes(4)?;
        Ok(u32::from_le_bytes([s[0], s[1], s[2], s[3]]))
    }

    fn u64(&mut self) -> Result<u64> {
        let s = self.bytes(8)?;
        Ok(u64::from_le_bytes([s[0], s[1], s[2], s[3], s[4], s[5], s[6], s[7]]))
    }

    fn remaining(&self) -> usize {
        self.b.len() - self.off
    }

    fn done(&self) -> Result<()> {
        if self.off == self.b.len() {
            Ok(())
        } else {
            Err(corrupt(format!("{} trailing bytes after frame", self.b.len() - self.off)))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_roundtrip_all_kinds() {
        let reqs = [
            WireRequest::Get {
                id: 7,
                dataset: "MC0".into(),
                offset: 1024,
                len: 4096,
                deadline_ms: 250,
            },
            WireRequest::Get {
                id: u64::MAX,
                dataset: "x".into(),
                offset: 0,
                len: 0,
                deadline_ms: 0,
            },
            WireRequest::Stat { id: 3, dataset: "TPC".into() },
            WireRequest::Shutdown { id: 0 },
            WireRequest::Metrics { id: 12 },
        ];
        for req in &reqs {
            let body = encode_request(req).unwrap();
            assert_eq!(&decode_request(&body).unwrap(), req);
        }
    }

    #[test]
    fn response_roundtrip_all_statuses() {
        for v in 0..=8u8 {
            let status = Status::from_u8(v).unwrap();
            assert_eq!(status.as_u8(), v);
            let resp = WireResponse { id: 42, status, payload: vec![1, 2, 3, v] };
            let body = encode_response(&resp);
            assert_eq!(decode_response(&body).unwrap(), resp);
        }
        assert!(Status::from_u8(9).is_none());
        assert_eq!(Status::Expired.as_u8(), 7);
        assert_eq!(Status::ChecksumMismatch.as_u8(), 8);
    }

    #[test]
    fn request_header_layout_pinned() {
        // Byte-layout pin: DESIGN.md §6 freezes these offsets (v3).
        let req = WireRequest::Get {
            id: 0x1122_3344_5566_7788,
            dataset: "ab".into(),
            offset: 0x0102_0304_0506_0708,
            len: 0x1112_1314_1516_1718,
            deadline_ms: 0x2122_2324_2526_2728,
        };
        let body = encode_request_flags(&req, FLAG_FRAME_CRC).unwrap();
        assert_eq!(body.len(), 48 + 2);
        assert_eq!(&body[0..4], &WIRE_MAGIC.to_le_bytes());
        assert_eq!(&body[4..6], &3u16.to_le_bytes());
        assert_eq!(body[6], 1); // kind = Get
        assert_eq!(body[7], 2); // name_len
        assert_eq!(&body[8..16], &0x1122_3344_5566_7788u64.to_le_bytes());
        assert_eq!(&body[16..24], &0x0102_0304_0506_0708u64.to_le_bytes());
        assert_eq!(&body[24..32], &0x1112_1314_1516_1718u64.to_le_bytes());
        assert_eq!(&body[32..40], &0x2122_2324_2526_2728u64.to_le_bytes());
        assert_eq!(&body[40..48], &FLAG_FRAME_CRC.to_le_bytes());
        assert_eq!(&body[48..], b"ab");
        // The default encoder emits the same layout with flags 0, and
        // the versioned decoder surfaces both flag words.
        let plain = encode_request(&req).unwrap();
        assert_eq!(&plain[40..48], &0u64.to_le_bytes());
        assert_eq!(decode_request_versioned(&body).unwrap(), (req.clone(), 3, FLAG_FRAME_CRC));
        assert_eq!(decode_request_versioned(&plain).unwrap(), (req, 3, 0));
    }

    /// Hand-build a v1 request body (32-byte header, no deadline).
    fn encode_request_v1(kind: u8, id: u64, dataset: &str, offset: u64, len: u64) -> Vec<u8> {
        let name = dataset.as_bytes();
        let mut out = Vec::with_capacity(32 + name.len());
        out.extend_from_slice(&WIRE_MAGIC.to_le_bytes());
        out.extend_from_slice(&1u16.to_le_bytes());
        out.push(kind);
        out.push(name.len() as u8);
        out.extend_from_slice(&id.to_le_bytes());
        out.extend_from_slice(&offset.to_le_bytes());
        out.extend_from_slice(&len.to_le_bytes());
        out.extend_from_slice(name);
        out
    }

    #[test]
    fn v1_request_frames_still_accepted() {
        // The v1 compat path: a 32-byte-header Get decodes with
        // deadline 0; Stat and Shutdown decode identically.
        let body = encode_request_v1(1, 9, "MC0", 128, 256);
        assert_eq!(
            decode_request(&body).unwrap(),
            WireRequest::Get { id: 9, dataset: "MC0".into(), offset: 128, len: 256, deadline_ms: 0 }
        );
        let body = encode_request_v1(2, 3, "d", 0, 0);
        let want = WireRequest::Stat { id: 3, dataset: "d".into() };
        assert_eq!(decode_request(&body).unwrap(), want);
        let body = encode_request_v1(3, 4, "", 0, 0);
        assert_eq!(decode_request(&body).unwrap(), WireRequest::Shutdown { id: 4 });
        // Metrics (kind 4) rides the same header, so a v1 frame works.
        let body = encode_request_v1(4, 5, "", 0, 0);
        assert_eq!(decode_request(&body).unwrap(), WireRequest::Metrics { id: 5 });
        // v1 truncations still all error.
        let good = encode_request_v1(1, 9, "MC0", 128, 256);
        for cut in 0..good.len() {
            assert!(decode_request(&good[..cut]).is_err(), "v1 cut at {cut}");
        }
        // Versions outside [min, current] are rejected.
        let mut bad = encode_request_v1(1, 9, "MC0", 128, 256);
        bad[4] = 0;
        assert!(decode_request(&bad).is_err());
        bad[4] = 4;
        assert!(decode_request(&bad).is_err());
    }

    /// Hand-build a v2 request body (40-byte header, no flags) — the
    /// layout-pinned interop frame a pre-v3 client still emits.
    fn encode_request_v2(
        kind: u8,
        id: u64,
        dataset: &str,
        offset: u64,
        len: u64,
        deadline_ms: u64,
    ) -> Vec<u8> {
        let name = dataset.as_bytes();
        let mut out = Vec::with_capacity(40 + name.len());
        out.extend_from_slice(&WIRE_MAGIC.to_le_bytes());
        out.extend_from_slice(&2u16.to_le_bytes());
        out.push(kind);
        out.push(name.len() as u8);
        out.extend_from_slice(&id.to_le_bytes());
        out.extend_from_slice(&offset.to_le_bytes());
        out.extend_from_slice(&len.to_le_bytes());
        out.extend_from_slice(&deadline_ms.to_le_bytes());
        out.extend_from_slice(name);
        out
    }

    #[test]
    fn v2_request_frames_still_accepted() {
        // The v2 compat path: a 40-byte-header Get keeps its deadline
        // and decodes with flags 0 (no frame CRC can be requested).
        let body = encode_request_v2(1, 9, "MC0", 128, 256, 750);
        assert_eq!(
            decode_request_versioned(&body).unwrap(),
            (
                WireRequest::Get {
                    id: 9,
                    dataset: "MC0".into(),
                    offset: 128,
                    len: 256,
                    deadline_ms: 750
                },
                2,
                0
            )
        );
        // v2 truncations still all error.
        for cut in 0..body.len() {
            assert!(decode_request(&body[..cut]).is_err(), "v2 cut at {cut}");
        }
    }

    #[test]
    fn metrics_request_kind_pinned() {
        // Kind discriminant 4 is frozen (DESIGN.md §10): a scrape
        // client built against this version must interoperate with any
        // later daemon.
        let body = encode_request(&WireRequest::Metrics { id: 6 }).unwrap();
        assert_eq!(body[6], 4); // kind = Metrics
        assert_eq!(body[7], 0); // name_len: no dataset label
        assert_eq!(&body[8..16], &6u64.to_le_bytes());
        assert_eq!(decode_request(&body).unwrap(), WireRequest::Metrics { id: 6 });
    }

    #[test]
    fn response_header_layout_pinned() {
        let body = encode_response(&WireResponse {
            id: 9,
            status: Status::Busy,
            payload: b"full".to_vec(),
        });
        assert_eq!(body.len(), 24 + 4);
        assert_eq!(&body[0..4], &WIRE_MAGIC.to_le_bytes());
        assert_eq!(&body[4..6], &WIRE_VERSION.to_le_bytes());
        assert_eq!(body[6], Status::Busy.as_u8());
        assert_eq!(body[7], 0);
        assert_eq!(&body[8..16], &9u64.to_le_bytes());
        assert_eq!(&body[16..24], &4u64.to_le_bytes());
        assert_eq!(&body[24..], b"full");
    }

    #[test]
    fn decode_rejects_malformed() {
        let good = encode_request(&WireRequest::Stat { id: 1, dataset: "d".into() }).unwrap();
        // Bad magic.
        let mut bad = good.clone();
        bad[0] ^= 0xFF;
        assert!(decode_request(&bad).is_err());
        // Bad version.
        let mut bad = good.clone();
        bad[4] = 0xEE;
        assert!(decode_request(&bad).is_err());
        // Unknown kind.
        let mut bad = good.clone();
        bad[6] = 99;
        assert!(decode_request(&bad).is_err());
        // Truncations at every length must error, never panic.
        for cut in 0..good.len() {
            assert!(decode_request(&good[..cut]).is_err(), "cut at {cut}");
        }
        // Trailing garbage.
        let mut bad = good.clone();
        bad.push(0);
        assert!(decode_request(&bad).is_err());
        // Response payload_len mismatch.
        let mut resp =
            encode_response(&WireResponse { id: 1, status: Status::Ok, payload: vec![7; 8] });
        resp.truncate(resp.len() - 1);
        assert!(decode_response(&resp).is_err());
    }

    #[test]
    fn write_response_matches_encode_response() {
        for payload in [Vec::new(), vec![7u8; 3], vec![0xAB; 1000]] {
            let resp = WireResponse { id: 11, status: Status::Ok, payload };
            let mut via_encode = Vec::new();
            write_frame(&mut via_encode, &encode_response(&resp)).unwrap();
            let mut via_direct = Vec::new();
            write_response(&mut via_direct, &resp).unwrap();
            assert_eq!(via_direct, via_encode);
        }
    }

    #[test]
    fn write_response_versioned_stamps_and_roundtrips() {
        // The daemon echoes the requester's version; both stamps must
        // decode, differing only in the version field.
        let resp = WireResponse { id: 5, status: Status::Ok, payload: vec![9; 16] };
        for version in [1u16, 2, 3] {
            let mut wire = Vec::new();
            write_response_versioned(&mut wire, &resp, version).unwrap();
            // Skip the u32 length prefix; version lives at body[4..6].
            assert_eq!(&wire[8..10], &version.to_le_bytes());
            assert_eq!(decode_response(&wire[4..]).unwrap(), resp);
        }
    }

    #[test]
    fn response_head_matches_framed_encode_response() {
        // The vectored-write head must be byte-for-byte the first 28
        // bytes of the classic framed encoding for every status and
        // both protocol stamps — the evented path reuses frozen bytes,
        // it does not define new ones.
        for v in 0..=8u8 {
            let status = Status::from_u8(v).unwrap();
            for version in [1u16, 2, 3] {
                let resp = WireResponse { id: 77, status, payload: vec![v; 13] };
                let mut framed = Vec::new();
                framed.extend_from_slice(&(24u32 + 13).to_le_bytes());
                let mut body = encode_response(&resp);
                body[4..6].copy_from_slice(&version.to_le_bytes());
                framed.extend_from_slice(&body);
                let head = response_head(version, status, 77, 13).unwrap();
                assert_eq!(&head[..], &framed[..28]);
                let mut parts = Vec::new();
                write_response_parts(&mut parts, version, status, 77, &resp.payload).unwrap();
                assert_eq!(parts, framed);
            }
        }
        // The frame cap still applies at head-build time.
        assert!(response_head(2, Status::Ok, 1, MAX_FRAME_LEN as u64).is_err());
    }

    #[test]
    fn decode_request_versioned_reports_the_frame_version() {
        let v3 = encode_request(&WireRequest::Shutdown { id: 1 }).unwrap();
        assert_eq!(decode_request_versioned(&v3).unwrap().1, 3);
        let v2 = encode_request_v2(3, 1, "", 0, 0, 0);
        assert_eq!(decode_request_versioned(&v2).unwrap().1, 2);
        let v1 = encode_request_v1(3, 1, "", 0, 0);
        assert_eq!(decode_request_versioned(&v1).unwrap().1, 1);
    }

    #[test]
    fn response_frame_crc_roundtrips_and_catches_corruption() {
        let payload = vec![0xA5u8; 64];
        let mut framed = Vec::new();
        write_response_parts_crc(&mut framed, 3, Status::Ok, 21, &payload, true).unwrap();
        // Length prefix budgets the 4-byte trailer; payload_len does not.
        assert_eq!(&framed[0..4], &(24u32 + 64 + 4).to_le_bytes());
        assert_eq!(&framed[20..28], &64u64.to_le_bytes());
        let (resp, crc) = decode_response_ext(&framed[4..]).unwrap();
        assert_eq!(resp, WireResponse { id: 21, status: Status::Ok, payload: payload.clone() });
        assert!(crc.is_some(), "verified trailer must be surfaced");
        // decode_response strips the trailer transparently.
        assert_eq!(decode_response(&framed[4..]).unwrap().payload, payload);
        // Any flipped bit in header or payload must fail typed.
        for at in [4usize, 12, 30, 60] {
            let mut bad = framed.clone();
            bad[4 + at] ^= 0x01;
            match decode_response_ext(&bad[4..]) {
                Err(Error::ChecksumMismatch(_)) => {}
                Err(_) => {} // header flips may fail magic/status first
                Ok(_) => panic!("flip at body offset {at} went undetected"),
            }
        }
        // A flipped payload byte specifically is a ChecksumMismatch.
        let mut bad = framed.clone();
        bad[4 + 24] ^= 0x01;
        assert!(matches!(decode_response_ext(&bad[4..]), Err(Error::ChecksumMismatch(_))));
        // Without the trailer the same frame decodes with crc None.
        let mut plain = Vec::new();
        write_response_parts_crc(&mut plain, 3, Status::Ok, 21, &payload, false).unwrap();
        assert_eq!(decode_response_ext(&plain[4..]).unwrap().1, None);
        // A v2-stamped body must never grow a trailer: 4 extra bytes on
        // a v2 frame are trailing garbage, not a CRC.
        let mut v2 = Vec::new();
        write_response_parts_crc(&mut v2, 2, Status::Ok, 21, &payload, false).unwrap();
        let mut v2_body = v2[4..].to_vec();
        v2_body.extend_from_slice(&[0u8; 4]);
        assert!(matches!(decode_response_ext(&v2_body), Err(Error::Corrupt(_))));
    }

    #[test]
    fn request_id_hint_survives_malformed_kind() {
        // A well-framed request with a bad kind byte still yields its
        // id for error correlation.
        let mut body =
            encode_request(&WireRequest::Stat { id: 42, dataset: "d".into() }).unwrap();
        body[6] = 99; // unknown kind
        assert!(decode_request(&body).is_err());
        assert_eq!(request_id_hint(&body), 42);
        assert_eq!(request_id_hint(b"short"), 0);
    }

    #[test]
    fn request_version_hint_recovers_supported_versions_only() {
        let mut v1 = encode_request_v1(1, 1, "d", 0, 0);
        v1[6] = 99; // malformed kind; version field intact
        assert_eq!(request_version_hint(&v1), 1);
        let v2 = encode_request_v2(3, 1, "", 0, 0, 0);
        assert_eq!(request_version_hint(&v2), 2);
        let v3 = encode_request(&WireRequest::Shutdown { id: 1 }).unwrap();
        assert_eq!(request_version_hint(&v3), 3);
        // Garbage or unsupported versions fall back to the current one.
        let mut bad = v1.clone();
        bad[4] = 0x7F;
        assert_eq!(request_version_hint(&bad), WIRE_VERSION);
        assert_eq!(request_version_hint(b"abc"), WIRE_VERSION);
    }

    #[test]
    fn encode_rejects_oversized_name() {
        let req = WireRequest::Stat { id: 1, dataset: "n".repeat(300) };
        assert!(encode_request(&req).is_err());
    }

    /// A reader that delivers at most `chunk` bytes per read, to
    /// exercise reassembly across partial reads.
    struct Dribble<'a> {
        data: &'a [u8],
        off: usize,
        chunk: usize,
    }

    impl Read for Dribble<'_> {
        fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
            let n = self.chunk.min(buf.len()).min(self.data.len() - self.off);
            buf[..n].copy_from_slice(&self.data[self.off..self.off + n]);
            self.off += n;
            Ok(n)
        }
    }

    #[test]
    fn frame_reader_reassembles_split_frames() {
        let mut wire = Vec::new();
        let bodies: Vec<Vec<u8>> = vec![
            encode_request(&WireRequest::Get {
                id: 1,
                dataset: "MC0".into(),
                offset: 10,
                len: 20,
                deadline_ms: 0,
            })
            .unwrap(),
            encode_request(&WireRequest::Shutdown { id: 2 }).unwrap(),
        ];
        for b in &bodies {
            write_frame(&mut wire, b).unwrap();
        }
        for chunk in [1usize, 3, 7, 64] {
            let mut r = Dribble { data: &wire, off: 0, chunk };
            let mut fr = FrameReader::new();
            let mut got = Vec::new();
            loop {
                match fr.poll(&mut r).unwrap() {
                    ReadEvent::Frame(f) => got.push(f),
                    ReadEvent::Eof => break,
                    ReadEvent::WouldBlock => unreachable!(),
                }
            }
            assert_eq!(got, bodies, "chunk size {chunk}");
        }
    }

    #[test]
    fn read_frame_blocking_handles_coalesced_frames() {
        // Two frames arriving in one read must both be returned across
        // successive calls with a persistent reader.
        let mut wire = Vec::new();
        write_frame(&mut wire, b"first").unwrap();
        write_frame(&mut wire, b"second").unwrap();
        let mut cur = std::io::Cursor::new(&wire);
        let mut fr = FrameReader::new();
        assert_eq!(read_frame_blocking(&mut fr, &mut cur).unwrap().unwrap(), b"first");
        assert_eq!(read_frame_blocking(&mut fr, &mut cur).unwrap().unwrap(), b"second");
        assert!(read_frame_blocking(&mut fr, &mut cur).unwrap().is_none());
    }

    #[test]
    fn request_reader_caps_hostile_length_prefix() {
        // A server-side reader must refuse a response-sized length
        // prefix outright (no pre-allocation for hostile prefixes).
        let mut wire = Vec::new();
        wire.extend_from_slice(&(MAX_REQUEST_FRAME_LEN + 1).to_le_bytes());
        let mut fr = FrameReader::for_requests();
        let mut cur = std::io::Cursor::new(&wire);
        assert!(fr.poll(&mut cur).is_err());
        // Every legal request fits under the request cap.
        let widest = encode_request(&WireRequest::Get {
            id: u64::MAX,
            dataset: "n".repeat(MAX_NAME_LEN),
            offset: u64::MAX,
            len: u64::MAX,
            deadline_ms: u64::MAX,
        })
        .unwrap();
        assert!((widest.len() as u32) <= MAX_REQUEST_FRAME_LEN);
        let mut wire = Vec::new();
        write_frame(&mut wire, &widest).unwrap();
        let mut fr = FrameReader::for_requests();
        let mut cur = std::io::Cursor::new(&wire);
        assert!(matches!(fr.poll(&mut cur).unwrap(), ReadEvent::Frame(f) if f == widest));
    }

    #[test]
    fn frame_reader_rejects_oversized_and_truncated() {
        // Length prefix over the cap.
        let mut wire = Vec::new();
        wire.extend_from_slice(&(MAX_FRAME_LEN + 1).to_le_bytes());
        let mut fr = FrameReader::new();
        let mut cur = std::io::Cursor::new(&wire);
        assert!(fr.poll(&mut cur).is_err());
        // EOF mid-frame.
        let mut wire = Vec::new();
        write_frame(&mut wire, b"hello").unwrap();
        wire.truncate(wire.len() - 2);
        let mut fr = FrameReader::new();
        let mut cur = std::io::Cursor::new(&wire);
        assert!(fr.poll(&mut cur).is_err());
    }
}
