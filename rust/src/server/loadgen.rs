//! `codag loadgen` — hammer a running daemon and report latency.
//!
//! Opens N connections, each issuing seeded-random ranged reads against
//! one dataset (optionally pipelined `pipeline` deep), and merges
//! per-connection [`LatencyStats`] into a p50/p90/p99 + throughput
//! report. `Busy` (backpressure) and `Expired` (deadline) responses are
//! counted separately from failures so admission-limit and deadline
//! sweeps read directly off the report.
//!
//! Two extra drivers ride on the same client: [`run_ablation`] sweeps
//! client pipeline depths {1, 8, 32} — the knob that drives the
//! daemon's opportunistic shard batching — and emits the §V-F
//! batching-ablation table for EXPERIMENTS.md, and [`probe_expired`]
//! deterministically exercises the deadline-expiry path (queue a few
//! full-range reads, then a 1 ms-deadline read that must come back
//! [`Status::Expired`]).
//!
//! Up to [`MAX_CLIENT_THREADS`] connections each get their own blocking
//! driver thread. Above that (`--connections 256`, `1024`, …) the
//! client switches to a multiplexed mode on unix: a few driver threads
//! share the connections over nonblocking sockets and the same
//! `poll(2)` shim the daemon's evented front uses, so the *client* is
//! not the scaling bottleneck when probing connection counts the
//! thread-per-connection model could never reach. Request streams are
//! identical in both modes — same per-connection seeds, ids, and range
//! sequences — so reports are comparable across the switch. (Mind the
//! process fd limit: 1024 connections need `ulimit -n` headroom.)

use crate::coordinator::stats::LatencyStats;
use crate::data::Rng;
use crate::server::proto::{
    decode_response, decode_response_ext, encode_request, encode_request_flags,
    read_frame_blocking, write_frame, FrameReader, Status, WireRequest, WireResponse,
    FLAG_FRAME_CRC,
};
use crate::{corrupt, invalid, Error, Result};
use std::collections::HashMap;
use std::net::TcpStream;
use std::time::{Duration, Instant};

/// Largest connection count driven thread-per-connection; above this
/// the client multiplexes (see the module docs).
pub const MAX_CLIENT_THREADS: usize = 32;

/// Driver threads used by the multiplexed client.
const MUX_DRIVERS: usize = 8;

/// Load-generator knobs.
#[derive(Debug, Clone)]
pub struct LoadgenConfig {
    /// Daemon address, e.g. `127.0.0.1:7311`.
    pub addr: String,
    /// Registered dataset to read (paper names, e.g. `MC0`).
    pub dataset: String,
    /// Concurrent connections.
    pub connections: usize,
    /// Requests per connection.
    pub requests: usize,
    /// Largest random range per request in bytes (0 = whole dataset).
    pub max_len: u64,
    /// RNG seed (per-connection streams derive from it).
    pub seed: u64,
    /// Requests kept in flight per connection (1 = synchronous RPC).
    /// Deeper pipelines let the daemon's shard workers fold more
    /// requests into one `serve_batch` call — the §V-F batching knob.
    pub pipeline: usize,
    /// Relative deadline attached to every Get (ms; 0 = none).
    pub deadline_ms: u64,
    /// Scrape the daemon's metrics exposition mid-run (the wire
    /// `Metrics` request) and carry the last sample in the report —
    /// proves the scrape path is non-disruptive under load.
    pub scrape: bool,
    /// Request the v3 frame-CRC trailer on every Get and verify it on
    /// every response: a response without a valid trailer counts as a
    /// failure. End-to-end wire-integrity proof (`--verify-frames`).
    pub verify_frames: bool,
}

impl Default for LoadgenConfig {
    fn default() -> Self {
        LoadgenConfig {
            addr: "127.0.0.1:7311".into(),
            dataset: "MC0".into(),
            connections: 4,
            requests: 64,
            max_len: 256 * 1024,
            seed: 0xC0DA_6,
            pipeline: 1,
            deadline_ms: 0,
            scrape: false,
            verify_frames: false,
        }
    }
}

/// Outcome of one loadgen run.
#[derive(Debug)]
pub struct LoadgenReport {
    /// Latency/throughput over all `Ok` responses.
    pub stats: LatencyStats,
    /// Requests sent.
    pub sent: u64,
    /// `Ok` responses.
    pub ok: u64,
    /// `Busy` responses (admission-limit backpressure).
    pub busy: u64,
    /// `Expired` responses (the request's deadline lapsed in queue).
    pub expired: u64,
    /// Everything else: error statuses, mismatched ids, and exchanges
    /// aborted by a dying connection.
    pub failed: u64,
    /// Connections that died mid-run (their remaining requests were
    /// never attempted; completed measurements are kept).
    pub conn_failures: u64,
    /// Responses whose v3 frame-CRC trailer was present and valid
    /// (`LoadgenConfig::verify_frames`; 0 when verification was off).
    pub frames_verified: u64,
    /// Wall-clock for the whole run.
    pub wall: Duration,
    /// Last metrics exposition sampled while load was in flight
    /// (`LoadgenConfig::scrape`; `None` when scraping was off or every
    /// scrape failed).
    pub mid_run_metrics: Option<String>,
}

impl std::fmt::Display for LoadgenReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        writeln!(
            f,
            "requests: sent={} ok={} busy={} expired={} failed={} conn-failures={}",
            self.sent, self.ok, self.busy, self.expired, self.failed, self.conn_failures
        )?;
        if self.frames_verified > 0 {
            writeln!(f, "integrity: {} response frame CRCs verified", self.frames_verified)?;
        }
        writeln!(
            f,
            "latency:  p50={}us p90={}us p99={}us mean={:.0}us",
            self.stats.percentile_us(50.0),
            self.stats.percentile_us(90.0),
            self.stats.percentile_us(99.0),
            self.stats.mean_us()
        )?;
        writeln!(
            f,
            "payload:  {} bytes in {:.2}s ({:.3} GB/s)",
            self.stats.total_bytes(),
            self.wall.as_secs_f64(),
            self.stats.throughput_gbps(self.wall)
        )
    }
}

/// An open client connection: socket plus its persistent frame
/// reassembly buffer (coalesced frames must survive between reads).
struct Conn {
    stream: TcpStream,
    reader: FrameReader,
}

impl Conn {
    fn open(addr: &str) -> Result<Conn> {
        let stream = TcpStream::connect(addr)?;
        // Synchronous request/response over two writes per frame:
        // disable Nagle so latency numbers measure the daemon, not
        // delayed-ACK stalls.
        let _ = stream.set_nodelay(true);
        Ok(Conn { stream, reader: FrameReader::new() })
    }
}

/// One blocking request/response exchange on an open connection.
fn rpc(conn: &mut Conn, req: &WireRequest) -> Result<WireResponse> {
    let body = encode_request(req)?;
    write_frame(&mut conn.stream, &body)?;
    let frame = read_frame_blocking(&mut conn.reader, &mut conn.stream)?
        .ok_or_else(|| corrupt("daemon closed the connection mid-exchange"))?;
    decode_response(&frame)
}

/// Query `(total_uncompressed, chunk_size, n_chunks)` for a dataset.
/// The v2 payload carries daemon-wide cache counters after the first
/// 24 bytes (see [`stat_full`]); this convenience keeps the v1 view.
pub fn stat(addr: &str, dataset: &str) -> Result<(u64, u64, u64)> {
    let s = stat_full(addr, dataset)?;
    Ok((s.total_uncompressed, s.chunk_size, s.n_chunks))
}

/// Decoded v2 `Stat` response (24-byte v1 prefix + cache counters).
#[derive(Debug, Clone, Copy, Default)]
pub struct StatReport {
    /// Total uncompressed dataset length.
    pub total_uncompressed: u64,
    /// Nominal uncompressed chunk size.
    pub chunk_size: u64,
    /// Chunk count.
    pub n_chunks: u64,
    /// Daemon-wide chunk-cache hits (0 when the daemon predates v2).
    pub cache_hits: u64,
    /// Daemon-wide chunk-cache misses.
    pub cache_misses: u64,
    /// Daemon-wide chunk-cache evictions.
    pub cache_evictions: u64,
    /// Admissions declined (first touch of a key; ghost-LRU).
    pub cache_admit_declines: u64,
    /// Admissions granted via the ghost (second touch of a key).
    pub cache_ghost_hits: u64,
}

/// Query a dataset's `Stat`, including the v2 cache counters. Accepts
/// a bare 24-byte v1 payload (counters stay 0) so mixed-version
/// deployments keep working.
pub fn stat_full(addr: &str, dataset: &str) -> Result<StatReport> {
    let mut conn = Conn::open(addr)?;
    let resp = rpc(&mut conn, &WireRequest::Stat { id: 0, dataset: dataset.into() })?;
    if resp.status != Status::Ok {
        return Err(Error::Runtime(format!(
            "stat {dataset}: {} ({})",
            resp.status.label(),
            String::from_utf8_lossy(&resp.payload)
        )));
    }
    if resp.payload.len() < 24 {
        return Err(corrupt(format!("stat payload is {} bytes, want >= 24", resp.payload.len())));
    }
    let rd = |i: usize| {
        let mut b = [0u8; 8];
        b.copy_from_slice(&resp.payload[i..i + 8]);
        u64::from_le_bytes(b)
    };
    let opt = |i: usize| if resp.payload.len() >= i + 8 { rd(i) } else { 0 };
    Ok(StatReport {
        total_uncompressed: rd(0),
        chunk_size: rd(8),
        n_chunks: rd(16),
        cache_hits: opt(24),
        cache_misses: opt(32),
        cache_evictions: opt(40),
        cache_admit_declines: opt(48),
        cache_ghost_hits: opt(56),
    })
}

/// Scrape the daemon's metrics exposition (wire `Metrics` request):
/// returns the UTF-8 text rendered by `obs::expo::render`. Works over
/// one short-lived connection — the scrape path a monitoring agent
/// would use.
pub fn metrics(addr: &str) -> Result<String> {
    let mut conn = Conn::open(addr)?;
    let resp = rpc(&mut conn, &WireRequest::Metrics { id: 0 })?;
    if resp.status != Status::Ok {
        return Err(Error::Runtime(format!(
            "metrics scrape: {} ({})",
            resp.status.label(),
            String::from_utf8_lossy(&resp.payload)
        )));
    }
    String::from_utf8(resp.payload).map_err(|_| corrupt("metrics exposition is not UTF-8"))
}

/// Ask the daemon to drain and exit.
pub fn shutdown(addr: &str) -> Result<()> {
    let mut conn = Conn::open(addr)?;
    let resp = rpc(&mut conn, &WireRequest::Shutdown { id: 0 })?;
    if resp.status != Status::Ok {
        return Err(Error::Runtime(format!("shutdown refused: {}", resp.status.label())));
    }
    Ok(())
}

/// Run the load, merging every connection's stats.
pub fn run(cfg: &LoadgenConfig) -> Result<LoadgenReport> {
    if cfg.connections == 0 || cfg.requests == 0 {
        return Err(invalid("loadgen needs at least one connection and one request"));
    }
    let (total, _chunk, _n) = stat(&cfg.addr, &cfg.dataset)?;
    if total == 0 {
        return Err(invalid(format!("dataset '{}' is empty", cfg.dataset)));
    }
    let t0 = Instant::now();
    let mut report = LoadgenReport {
        stats: LatencyStats::new(),
        sent: 0,
        ok: 0,
        busy: 0,
        expired: 0,
        failed: 0,
        conn_failures: 0,
        frames_verified: 0,
        wall: Duration::ZERO,
        mid_run_metrics: None,
    };
    // Concurrent scraper (--scrape): samples the metrics exposition on
    // its own connection while load is in flight, proving a monitoring
    // agent can scrape a busy daemon. The last sample (taken after the
    // load threads finish) rides the report.
    let scrape_done = std::sync::atomic::AtomicBool::new(false);
    let mid_metrics: std::sync::Mutex<Option<String>> = std::sync::Mutex::new(None);
    let results: Vec<ConnOutcome> = std::thread::scope(|s| {
        let scraper = cfg.scrape.then(|| {
            s.spawn(|| loop {
                if let Ok(text) = metrics(&cfg.addr) {
                    *mid_metrics.lock().unwrap() = Some(text);
                }
                if scrape_done.load(std::sync::atomic::Ordering::SeqCst) {
                    break;
                }
                std::thread::sleep(Duration::from_millis(20));
            })
        });
        let drivers = mux_drivers(cfg.connections);
        let results: Vec<ConnOutcome> = if drivers > 0 {
            // Multiplexed mode: each driver thread owns connections
            // `di, di + drivers, …` (round-robin keeps slices balanced
            // for any count). A panicking driver forfeits its whole
            // slice as connection failures.
            let handles: Vec<_> = (0..drivers)
                .map(|di| s.spawn(move || mux_drive(cfg, di, drivers, total)))
                .collect();
            handles
                .into_iter()
                .enumerate()
                .flat_map(|(di, h)| {
                    h.join().unwrap_or_else(|_| {
                        eprintln!("loadgen: multiplexed driver thread panicked");
                        let slice_len = (di..cfg.connections).step_by(drivers).count();
                        (0..slice_len)
                            .map(|_| ConnOutcome { died: true, ..ConnOutcome::default() })
                            .collect()
                    })
                })
                .collect()
        } else {
            let handles: Vec<_> = (0..cfg.connections)
                .map(|ci| s.spawn(move || connection_run(cfg, ci as u64, total)))
                .collect();
            handles
                .into_iter()
                .map(|h| {
                    h.join().unwrap_or_else(|_| {
                        eprintln!("loadgen: connection thread panicked");
                        ConnOutcome { died: true, ..ConnOutcome::default() }
                    })
                })
                .collect()
        };
        scrape_done.store(true, std::sync::atomic::Ordering::SeqCst);
        if let Some(h) = scraper {
            let _ = h.join();
        }
        results
    });
    // A dead connection loses its remaining requests, not the whole
    // run's measurements.
    for r in results {
        report.stats.merge(&r.stats);
        report.ok += r.ok;
        report.busy += r.busy;
        report.expired += r.expired;
        report.failed += r.failed;
        report.sent += r.ok + r.busy + r.expired + r.failed;
        report.conn_failures += u64::from(r.died);
        report.frames_verified += r.frames_verified;
    }
    report.wall = t0.elapsed();
    report.mid_run_metrics = mid_metrics.into_inner().unwrap();
    if report.sent == 0 && report.conn_failures > 0 {
        return Err(Error::Runtime("every loadgen connection failed".into()));
    }
    Ok(report)
}

/// One connection's results (partial if the connection died mid-run).
#[derive(Debug, Default)]
struct ConnOutcome {
    stats: LatencyStats,
    ok: u64,
    busy: u64,
    expired: u64,
    failed: u64,
    frames_verified: u64,
    died: bool,
}

/// Encode one Get, requesting the frame-CRC trailer when the run
/// verifies frames.
fn encode_for(cfg: &LoadgenConfig, req: &WireRequest) -> Result<Vec<u8>> {
    if cfg.verify_frames {
        encode_request_flags(req, FLAG_FRAME_CRC)
    } else {
        encode_request(req)
    }
}

/// Decode one response frame, enforcing the CRC trailer when the run
/// verifies frames: a missing trailer (daemon ignored the opt-in) or a
/// mismatching one (`decode_response_ext` errors) kills the exchange.
fn decode_for(
    cfg: &LoadgenConfig,
    frame: &[u8],
    frames_verified: &mut u64,
) -> Result<WireResponse> {
    if !cfg.verify_frames {
        return decode_response(frame);
    }
    let (resp, crc) = decode_response_ext(frame)?;
    if crc.is_none() {
        return Err(corrupt(format!("response {} is missing the requested frame CRC", resp.id)));
    }
    *frames_verified += 1;
    Ok(resp)
}

/// Drive one connection, keeping up to `cfg.pipeline` requests in
/// flight. Responses can arrive out of request order (`Busy`/`Expired`
/// replies come from the reader/dequeue path, `Ok` from shard
/// workers), so outstanding sends are matched back by id.
fn connection_run(cfg: &LoadgenConfig, conn_idx: u64, total: u64) -> ConnOutcome {
    let mut out = ConnOutcome::default();
    let mut conn = match Conn::open(&cfg.addr) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("loadgen: connection {conn_idx} failed to connect: {e}");
            out.died = true;
            return out;
        }
    };
    let mut rng = Rng::new(cfg.seed ^ (conn_idx.wrapping_mul(0x9E37_79B9_7F4A_7C15)));
    let depth = cfg.pipeline.max(1) as u64;
    let requests = cfg.requests as u64;
    let mut outstanding: HashMap<u64, Instant> = HashMap::new();
    let mut next = 0u64;
    let mut done = 0u64;
    while done < requests {
        // Fill the pipeline window.
        while next < requests && (outstanding.len() as u64) < depth {
            let offset = rng.below(total);
            let span =
                if cfg.max_len == 0 { total - offset } else { cfg.max_len.min(total - offset) };
            let len = 1 + rng.below(span.max(1));
            let id = (conn_idx << 32) | next;
            let req = WireRequest::Get {
                id,
                dataset: cfg.dataset.clone(),
                offset,
                len,
                deadline_ms: cfg.deadline_ms,
            };
            let sent = encode_for(cfg, &req)
                .and_then(|body| write_frame(&mut conn.stream, &body))
                .is_ok();
            if !sent {
                eprintln!("loadgen: connection {conn_idx} died after {done} responses");
                // The failed send plus every in-flight request counts
                // as attempted, so `sent` reconciles with daemon-side
                // counters (mirrors the read-failure path below).
                out.failed += outstanding.len() as u64 + 1;
                out.died = true;
                return out;
            }
            outstanding.insert(id, Instant::now());
            next += 1;
        }
        let resp = match read_frame_blocking(&mut conn.reader, &mut conn.stream)
            .and_then(|f| {
                f.ok_or_else(|| corrupt("daemon closed the connection mid-exchange"))
            })
            .and_then(|frame| decode_for(cfg, &frame, &mut out.frames_verified))
        {
            Ok(resp) => resp,
            Err(e) => {
                eprintln!("loadgen: connection {conn_idx} died after {done} responses: {e}");
                // Aborted exchanges still count as attempts so `sent`
                // reconciles with daemon-side counters.
                out.failed += outstanding.len() as u64;
                out.died = true;
                return out;
            }
        };
        let Some(started) = outstanding.remove(&resp.id) else {
            out.failed += 1;
            continue;
        };
        done += 1;
        match resp.status {
            Status::Ok => {
                out.stats.record(started.elapsed(), resp.payload.len() as u64);
                out.ok += 1;
            }
            Status::Busy => out.busy += 1,
            Status::Expired => out.expired += 1,
            _ => out.failed += 1,
        }
    }
    out
}

/// Driver threads for the multiplexed client; 0 means stay
/// thread-per-connection (few connections, or no `poll(2)` shim on
/// this platform).
fn mux_drivers(connections: usize) -> usize {
    if cfg!(unix) && connections > MAX_CLIENT_THREADS {
        MUX_DRIVERS.min(connections)
    } else {
        0
    }
}

/// One multiplexed driver: owns connections `di, di + drivers, …` as
/// nonblocking sockets polled together through the same shim the
/// daemon's evented front uses. Every connection runs the request
/// stream [`connection_run`] would give it — same seed, ids, pipeline
/// window, and outcome accounting — but sends are staged into a write
/// buffer with a partial-write cursor and responses are matched back
/// by id out of one shared poll loop, so 1024 connections cost this
/// process eight threads instead of a thousand.
#[cfg(unix)]
fn mux_drive(cfg: &LoadgenConfig, di: usize, drivers: usize, total: u64) -> Vec<ConnOutcome> {
    use crate::server::net::sys::{self, PollFd};
    use crate::server::proto::ReadEvent;
    use std::io::{ErrorKind, Write};
    use std::os::fd::AsRawFd;

    /// One multiplexed connection's in-flight state.
    struct Mux {
        conn_idx: u64,
        stream: TcpStream,
        reader: FrameReader,
        rng: Rng,
        /// Staged request frames; bytes below `sent_off` are on the
        /// wire already (partial-write cursor).
        outbuf: Vec<u8>,
        sent_off: usize,
        outstanding: HashMap<u64, Instant>,
        next: u64,
        done: u64,
        out: ConnOutcome,
    }

    /// Retire a dying connection, charging its in-flight exchanges as
    /// failures (mirrors [`connection_run`]'s read-failure path).
    fn kill(finished: &mut Vec<ConnOutcome>, mut c: Mux, why: &str) {
        eprintln!("loadgen: connection {} died after {} responses: {why}", c.conn_idx, c.done);
        c.out.failed += c.outstanding.len() as u64;
        c.out.died = true;
        finished.push(c.out);
    }

    let requests = cfg.requests as u64;
    let depth = cfg.pipeline.max(1) as u64;
    let mut finished: Vec<ConnOutcome> = Vec::new();
    let mut conns: Vec<Mux> = Vec::new();
    for ci in (di..cfg.connections).step_by(drivers.max(1)) {
        let conn_idx = ci as u64;
        let opened = TcpStream::connect(&cfg.addr).and_then(|s| {
            let _ = s.set_nodelay(true);
            s.set_nonblocking(true)?;
            Ok(s)
        });
        match opened {
            Ok(stream) => conns.push(Mux {
                conn_idx,
                stream,
                reader: FrameReader::new(),
                rng: Rng::new(cfg.seed ^ (conn_idx.wrapping_mul(0x9E37_79B9_7F4A_7C15))),
                outbuf: Vec::new(),
                sent_off: 0,
                outstanding: HashMap::new(),
                next: 0,
                done: 0,
                out: ConnOutcome::default(),
            }),
            Err(e) => {
                eprintln!("loadgen: connection {conn_idx} failed to connect: {e}");
                finished.push(ConnOutcome { died: true, ..ConnOutcome::default() });
            }
        }
    }

    let mut pollfds: Vec<PollFd> = Vec::new();
    while !conns.is_empty() {
        // Advance every connection as far as its socket allows: top up
        // the pipeline window once the previous staging fully drained,
        // flush staged bytes, then drain decodable responses.
        let mut i = 0;
        while i < conns.len() {
            let c = &mut conns[i];
            let mut dead: Option<String> = None;
            if c.sent_off == c.outbuf.len() {
                c.outbuf.clear();
                c.sent_off = 0;
                while c.next < requests && (c.outstanding.len() as u64) < depth {
                    let offset = c.rng.below(total);
                    let span = if cfg.max_len == 0 {
                        total - offset
                    } else {
                        cfg.max_len.min(total - offset)
                    };
                    let len = 1 + c.rng.below(span.max(1));
                    let id = (c.conn_idx << 32) | c.next;
                    let req = WireRequest::Get {
                        id,
                        dataset: cfg.dataset.clone(),
                        offset,
                        len,
                        deadline_ms: cfg.deadline_ms,
                    };
                    match encode_for(cfg, &req) {
                        Ok(body) => {
                            c.outbuf.extend_from_slice(&(body.len() as u32).to_le_bytes());
                            c.outbuf.extend_from_slice(&body);
                            c.outstanding.insert(id, Instant::now());
                            c.next += 1;
                        }
                        Err(e) => {
                            dead = Some(format!("encode failed: {e}"));
                            break;
                        }
                    }
                }
            }
            while dead.is_none() && c.sent_off < c.outbuf.len() {
                match c.stream.write(&c.outbuf[c.sent_off..]) {
                    Ok(0) => dead = Some("socket wrote zero bytes".into()),
                    Ok(n) => c.sent_off += n,
                    Err(ref e) if e.kind() == ErrorKind::WouldBlock => break,
                    Err(ref e) if e.kind() == ErrorKind::Interrupted => {}
                    Err(e) => dead = Some(format!("send failed: {e}")),
                }
            }
            while dead.is_none() && c.done < requests {
                match c.reader.poll(&mut c.stream) {
                    Ok(ReadEvent::WouldBlock) => break,
                    Ok(ReadEvent::Eof) => {
                        dead = Some("daemon closed the connection mid-exchange".into());
                    }
                    Ok(ReadEvent::Frame(frame)) => match decode_for(
                        cfg,
                        &frame,
                        &mut c.out.frames_verified,
                    ) {
                        Ok(resp) => {
                            let Some(started) = c.outstanding.remove(&resp.id) else {
                                c.out.failed += 1;
                                continue;
                            };
                            c.done += 1;
                            match resp.status {
                                Status::Ok => {
                                    c.out
                                        .stats
                                        .record(started.elapsed(), resp.payload.len() as u64);
                                    c.out.ok += 1;
                                }
                                Status::Busy => c.out.busy += 1,
                                Status::Expired => c.out.expired += 1,
                                _ => c.out.failed += 1,
                            }
                        }
                        Err(e) => dead = Some(format!("bad response frame: {e}")),
                    },
                    Err(e) => dead = Some(format!("read failed: {e}")),
                }
            }
            if let Some(why) = dead {
                let c = conns.swap_remove(i);
                kill(&mut finished, c, &why);
                continue; // swapped-in connection now occupies slot i
            }
            if conns[i].done == requests {
                let c = conns.swap_remove(i);
                finished.push(c.out);
                continue;
            }
            i += 1;
        }
        if conns.is_empty() {
            break;
        }
        // Sleep until any socket is readable — or writable, for the
        // ones with staged bytes the kernel pushed back on. The
        // timeout only bounds the wait when nothing happens.
        pollfds.clear();
        for c in &conns {
            let mut events = sys::POLLIN;
            if c.sent_off < c.outbuf.len() {
                events |= sys::POLLOUT;
            }
            pollfds.push(PollFd::new(c.stream.as_raw_fd(), events));
        }
        if let Err(e) = sys::poll_fds(&mut pollfds, Duration::from_millis(100)) {
            eprintln!("loadgen: poll failed: {e}");
            for c in conns.drain(..) {
                kill(&mut finished, c, "poll failed");
            }
        }
    }
    finished
}

/// Unreachable on non-unix: [`mux_drivers`] returns 0 there, keeping
/// every connection on its own blocking thread.
#[cfg(not(unix))]
fn mux_drive(_cfg: &LoadgenConfig, _di: usize, _drivers: usize, _total: u64) -> Vec<ConnOutcome> {
    unreachable!("multiplexed loadgen client is unix-only")
}

/// Pipeline depths swept by [`run_ablation`] (paper §V-F: batch sizes
/// {1, 8, 32} through the daemon path — the client pipeline depth is
/// what feeds the shard workers' opportunistic batching).
pub const ABLATION_DEPTHS: [usize; 3] = [1, 8, 32];

/// Sweep [`ABLATION_DEPTHS`] against a live daemon and render the
/// §V-F batching-ablation markdown table (EXPERIMENTS.md §4). Each
/// depth reruns the same seeded workload, so rows differ only in
/// pipelining.
pub fn run_ablation(cfg: &LoadgenConfig) -> Result<String> {
    let mut out = String::new();
    out.push_str(
        "| pipeline depth | sent | ok | busy | expired | p50 (us) | p99 (us) | GB/s |\n\
         |---|---|---|---|---|---|---|---|\n",
    );
    for depth in ABLATION_DEPTHS {
        let mut c = cfg.clone();
        c.pipeline = depth;
        let rep = run(&c)?;
        out.push_str(&format!(
            "| {} | {} | {} | {} | {} | {} | {} | {:.3} |\n",
            depth,
            rep.sent,
            rep.ok,
            rep.busy,
            rep.expired,
            rep.stats.percentile_us(50.0),
            rep.stats.percentile_us(99.0),
            rep.stats.throughput_gbps(rep.wall)
        ));
    }
    Ok(out)
}

/// Deterministically exercise the deadline-expiry path against a live
/// daemon: queue `HEAD` full-range reads on one connection, then a
/// read with a 1 ms deadline. Same connection + same dataset ⇒ same
/// shard FIFO, so the deadline job sits behind the full decodes and
/// must come back [`Status::Expired`]. Errors if it does not (the CI
/// smoke gate for the deadline path).
pub fn probe_expired(addr: &str, dataset: &str) -> Result<()> {
    // Enough queued decode work that 1 ms is safely stale by the time
    // the probe job is reached, even for the fastest RLE datasets
    // (pair with an uncached single-worker daemon for a strict gate).
    const HEAD: u64 = 16;
    let (total, _chunk, _n) = stat(addr, dataset)?;
    if total == 0 {
        return Err(invalid(format!("dataset '{dataset}' is empty")));
    }
    let mut conn = Conn::open(addr)?;
    // Head reads are capped at 2 MiB so all HEAD + 1 spans (34 MiB)
    // stay strictly inside the daemon's default 64 MiB per-connection
    // byte budget even on paper-scale datasets — a Busy head would
    // dequeue instantly and weaken the queue delay the probe relies
    // on, and a Busy *probe* would fail it outright.
    let head_len = total.min(2 * 1024 * 1024);
    for id in 0..HEAD {
        let body = encode_request(&WireRequest::Get {
            id,
            dataset: dataset.into(),
            offset: 0,
            len: head_len,
            deadline_ms: 0,
        })?;
        write_frame(&mut conn.stream, &body)?;
    }
    let probe_id = HEAD;
    let body = encode_request(&WireRequest::Get {
        id: probe_id,
        dataset: dataset.into(),
        offset: 0,
        len: head_len,
        deadline_ms: 1,
    })?;
    write_frame(&mut conn.stream, &body)?;
    let mut probe_status = None;
    for _ in 0..=HEAD {
        let frame = read_frame_blocking(&mut conn.reader, &mut conn.stream)?
            .ok_or_else(|| corrupt("daemon closed the connection mid-probe"))?;
        let resp = decode_response(&frame)?;
        if resp.id == probe_id {
            probe_status = Some(resp.status);
        } else if !matches!(resp.status, Status::Ok | Status::Busy) {
            // Busy heads are tolerated (they only reduce queue delay);
            // anything else is a real failure.
            return Err(Error::Runtime(format!(
                "probe head request {} failed: {}",
                resp.id,
                resp.status.label()
            )));
        }
    }
    match probe_status {
        Some(Status::Expired) => Ok(()),
        Some(other) => Err(Error::Runtime(format!(
            "deadline probe expected Expired, got {}",
            other.label()
        ))),
        None => Err(corrupt("deadline probe got no response")),
    }
}
