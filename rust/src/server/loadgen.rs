//! `codag loadgen` — hammer a running daemon and report latency.
//!
//! Opens N connections, each issuing seeded-random ranged reads against
//! one dataset, and merges per-connection [`LatencyStats`] into a
//! p50/p90/p99 + throughput report. `Busy` responses (backpressure) are
//! counted separately from failures so admission-limit sweeps read
//! directly off the report.

use crate::coordinator::stats::LatencyStats;
use crate::data::Rng;
use crate::server::proto::{
    decode_response, encode_request, read_frame_blocking, write_frame, FrameReader, Status,
    WireRequest, WireResponse,
};
use crate::{corrupt, invalid, Error, Result};
use std::net::TcpStream;
use std::time::{Duration, Instant};

/// Load-generator knobs.
#[derive(Debug, Clone)]
pub struct LoadgenConfig {
    /// Daemon address, e.g. `127.0.0.1:7311`.
    pub addr: String,
    /// Registered dataset to read (paper names, e.g. `MC0`).
    pub dataset: String,
    /// Concurrent connections.
    pub connections: usize,
    /// Requests per connection.
    pub requests: usize,
    /// Largest random range per request in bytes (0 = whole dataset).
    pub max_len: u64,
    /// RNG seed (per-connection streams derive from it).
    pub seed: u64,
}

impl Default for LoadgenConfig {
    fn default() -> Self {
        LoadgenConfig {
            addr: "127.0.0.1:7311".into(),
            dataset: "MC0".into(),
            connections: 4,
            requests: 64,
            max_len: 256 * 1024,
            seed: 0xC0DA_6,
        }
    }
}

/// Outcome of one loadgen run.
#[derive(Debug)]
pub struct LoadgenReport {
    /// Latency/throughput over all `Ok` responses.
    pub stats: LatencyStats,
    /// Requests sent.
    pub sent: u64,
    /// `Ok` responses.
    pub ok: u64,
    /// `Busy` responses (admission-limit backpressure).
    pub busy: u64,
    /// Everything else: error statuses, mismatched ids, and exchanges
    /// aborted by a dying connection.
    pub failed: u64,
    /// Connections that died mid-run (their remaining requests were
    /// never attempted; completed measurements are kept).
    pub conn_failures: u64,
    /// Wall-clock for the whole run.
    pub wall: Duration,
}

impl std::fmt::Display for LoadgenReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        writeln!(
            f,
            "requests: sent={} ok={} busy={} failed={} conn-failures={}",
            self.sent, self.ok, self.busy, self.failed, self.conn_failures
        )?;
        writeln!(
            f,
            "latency:  p50={}us p90={}us p99={}us mean={:.0}us",
            self.stats.percentile_us(50.0),
            self.stats.percentile_us(90.0),
            self.stats.percentile_us(99.0),
            self.stats.mean_us()
        )?;
        writeln!(
            f,
            "payload:  {} bytes in {:.2}s ({:.3} GB/s)",
            self.stats.total_bytes(),
            self.wall.as_secs_f64(),
            self.stats.throughput_gbps(self.wall)
        )
    }
}

/// An open client connection: socket plus its persistent frame
/// reassembly buffer (coalesced frames must survive between reads).
struct Conn {
    stream: TcpStream,
    reader: FrameReader,
}

impl Conn {
    fn open(addr: &str) -> Result<Conn> {
        let stream = TcpStream::connect(addr)?;
        // Synchronous request/response over two writes per frame:
        // disable Nagle so latency numbers measure the daemon, not
        // delayed-ACK stalls.
        let _ = stream.set_nodelay(true);
        Ok(Conn { stream, reader: FrameReader::new() })
    }
}

/// One blocking request/response exchange on an open connection.
fn rpc(conn: &mut Conn, req: &WireRequest) -> Result<WireResponse> {
    let body = encode_request(req)?;
    write_frame(&mut conn.stream, &body)?;
    let frame = read_frame_blocking(&mut conn.reader, &mut conn.stream)?
        .ok_or_else(|| corrupt("daemon closed the connection mid-exchange"))?;
    decode_response(&frame)
}

/// Query `(total_uncompressed, chunk_size, n_chunks)` for a dataset.
pub fn stat(addr: &str, dataset: &str) -> Result<(u64, u64, u64)> {
    let mut conn = Conn::open(addr)?;
    let resp = rpc(&mut conn, &WireRequest::Stat { id: 0, dataset: dataset.into() })?;
    if resp.status != Status::Ok {
        return Err(Error::Runtime(format!(
            "stat {dataset}: {} ({})",
            resp.status.label(),
            String::from_utf8_lossy(&resp.payload)
        )));
    }
    if resp.payload.len() != 24 {
        return Err(corrupt(format!("stat payload is {} bytes, want 24", resp.payload.len())));
    }
    let rd = |i: usize| {
        let mut b = [0u8; 8];
        b.copy_from_slice(&resp.payload[i..i + 8]);
        u64::from_le_bytes(b)
    };
    Ok((rd(0), rd(8), rd(16)))
}

/// Ask the daemon to drain and exit.
pub fn shutdown(addr: &str) -> Result<()> {
    let mut conn = Conn::open(addr)?;
    let resp = rpc(&mut conn, &WireRequest::Shutdown { id: 0 })?;
    if resp.status != Status::Ok {
        return Err(Error::Runtime(format!("shutdown refused: {}", resp.status.label())));
    }
    Ok(())
}

/// Run the load, merging every connection's stats.
pub fn run(cfg: &LoadgenConfig) -> Result<LoadgenReport> {
    if cfg.connections == 0 || cfg.requests == 0 {
        return Err(invalid("loadgen needs at least one connection and one request"));
    }
    let (total, _chunk, _n) = stat(&cfg.addr, &cfg.dataset)?;
    if total == 0 {
        return Err(invalid(format!("dataset '{}' is empty", cfg.dataset)));
    }
    let t0 = Instant::now();
    let mut report = LoadgenReport {
        stats: LatencyStats::new(),
        sent: 0,
        ok: 0,
        busy: 0,
        failed: 0,
        conn_failures: 0,
        wall: Duration::ZERO,
    };
    let results: Vec<ConnOutcome> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..cfg.connections)
            .map(|ci| s.spawn(move || connection_run(cfg, ci as u64, total)))
            .collect();
        handles
            .into_iter()
            .map(|h| {
                h.join().unwrap_or_else(|_| {
                    eprintln!("loadgen: connection thread panicked");
                    ConnOutcome { died: true, ..ConnOutcome::default() }
                })
            })
            .collect()
    });
    // A dead connection loses its remaining requests, not the whole
    // run's measurements.
    for r in results {
        report.stats.merge(&r.stats);
        report.ok += r.ok;
        report.busy += r.busy;
        report.failed += r.failed;
        report.sent += r.ok + r.busy + r.failed;
        report.conn_failures += u64::from(r.died);
    }
    report.wall = t0.elapsed();
    if report.sent == 0 && report.conn_failures > 0 {
        return Err(Error::Runtime("every loadgen connection failed".into()));
    }
    Ok(report)
}

/// One connection's results (partial if the connection died mid-run).
#[derive(Debug, Default)]
struct ConnOutcome {
    stats: LatencyStats,
    ok: u64,
    busy: u64,
    failed: u64,
    died: bool,
}

fn connection_run(cfg: &LoadgenConfig, conn_idx: u64, total: u64) -> ConnOutcome {
    let mut out = ConnOutcome::default();
    let mut conn = match Conn::open(&cfg.addr) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("loadgen: connection {conn_idx} failed to connect: {e}");
            out.died = true;
            return out;
        }
    };
    let mut rng = Rng::new(cfg.seed ^ (conn_idx.wrapping_mul(0x9E37_79B9_7F4A_7C15)));
    for r in 0..cfg.requests as u64 {
        let offset = rng.below(total);
        let span = if cfg.max_len == 0 { total - offset } else { cfg.max_len.min(total - offset) };
        let len = 1 + rng.below(span.max(1));
        let id = (conn_idx << 32) | r;
        let started = Instant::now();
        let resp = match rpc(
            &mut conn,
            &WireRequest::Get { id, dataset: cfg.dataset.clone(), offset, len },
        ) {
            Ok(resp) => resp,
            Err(e) => {
                eprintln!("loadgen: connection {conn_idx} died after {r} requests: {e}");
                // The aborted exchange still counts as an attempt so
                // `sent` reconciles with daemon-side counters.
                out.failed += 1;
                out.died = true;
                break;
            }
        };
        match resp.status {
            Status::Ok if resp.id == id => {
                out.stats.record(started.elapsed(), resp.payload.len() as u64);
                out.ok += 1;
            }
            Status::Busy => out.busy += 1,
            _ => out.failed += 1,
        }
    }
    out
}
