//! The serving layer: a long-lived decompression daemon (std-only).
//!
//! CODAG frames decompression as a component of data-analytics serving
//! pipelines (§I, §V-F); this module gives the L3 chunk engine a real
//! request path so batching, caching and admission control are
//! measurable system properties rather than bench artifacts:
//!
//! * [`proto`] — length-prefixed little-endian wire protocol
//!   (request/response framing, status codes; layout frozen in
//!   DESIGN.md §6 and pinned by unit tests).
//! * [`daemon`] — `TcpListener` daemon: per-dataset shard queues over
//!   long-lived `Service` workers, bounded admission with explicit
//!   `Busy` backpressure, and token-based graceful shutdown that joins
//!   every thread. Two network fronts share that decode pool: the
//!   default poll-based event loop in [`net`] (one thread multiplexing
//!   every socket) and the legacy two-threads-per-connection model
//!   (`--net-model threads`), kept for differential testing.
//! * [`net`] — the evented front (unix): `poll(2)` shim, fixed-size
//!   submission/completion rings, and the event loop with zero-copy
//!   vectored response writes (DESIGN.md §11).
//! * [`cache`] — sharded byte-budgeted LRU of hot *decompressed*
//!   chunks keyed by `(dataset, chunk index)`, with ghost-LRU
//!   admission (second-chance on key history).
//! * [`store`] — file-backed datasets: `codag pack`-written container
//!   files opened with header/index validation and lazy per-chunk
//!   payload reads (`codag serve --data-dir`, DESIGN.md §9).
//! * [`loadgen`] — client that hammers a running daemon and reports
//!   p50/p90/p99 latency and throughput; also the §V-F batching
//!   ablation driver (`codag loadgen --ablate-batch`) and the
//!   deadline-expiry probe.
//!
//! Driven end-to-end over loopback TCP by
//! `rust/tests/server_integration.rs` and
//! `rust/tests/store_integration.rs`, and from the CLI via
//! `codag serve --port …` / `codag loadgen`.

pub mod cache;
pub mod daemon;
pub mod loadgen;
#[cfg(unix)]
pub mod net;
pub mod proto;
pub mod store;

pub use cache::ChunkCache;
pub use daemon::{start, DaemonConfig, DaemonHandle, NetModel};
pub use loadgen::{LoadgenConfig, LoadgenReport};
pub use proto::{Status, WireRequest, WireResponse};
pub use store::{load_dir, FileDataset};
