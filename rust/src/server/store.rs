//! File-backed datasets: the on-disk side of the serving path.
//!
//! Production corpora live as container files on disk (DESIGN.md §9),
//! not as buffers synthesized at daemon startup. A [`FileDataset`]
//! opens one `codag pack`-written container file, validates the header
//! and chunk index up front, and then fetches *compressed chunks
//! lazily* — the payload section is never resident in memory, only the
//! chunks a request actually touches are read (std-only: positioned
//! reads behind a file lock, no mmap). [`load_dir`] scans a
//! `--data-dir` for `<name>.codag` files and is what `codag serve
//! --data-dir` feeds into the [`Registry`](crate::coordinator::Registry)
//! as [`DatasetSource::File`](crate::coordinator::router::DatasetSource)
//! entries.
//!
//! Error taxonomy (pinned by the unit suite): a malformed file —
//! truncated header/index, bad magic/version, an index entry
//! pointing outside the payload, inconsistent uncompressed sizes —
//! is `Error::Corrupt`; a cleanly stored codec id the registry does
//! not know is the typed `Error::UnknownCodec`; an out-of-range chunk
//! request is `Error::Invalid`; filesystem failures are `Error::Io`.
//! Nothing panics on hostile files.

use crate::codecs::{CodecKind, RestartPoint};
use crate::format::container::{
    fnv1a64, validate_restart_table, ChunkEntry, FNV_OFFSET, MAGIC, RESTART_ENTRY_LEN,
    VERSION_CHECKSUM, VERSION_MIXED, VERSION_V1,
};
use crate::format::hash::crc32c_extend;
use crate::obs::{now_if_enabled, DatasetMetrics, Stage, StitchTimers};
use crate::{corrupt, invalid, Error, Result};
use std::fs::File;
use std::io::{Read, Seek, SeekFrom};
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex, OnceLock};

/// Fixed container header length (magic + version + codec + chunk_size
/// + total_uncompressed + n_chunks; see DESIGN.md §2).
const HEADER_LEN: u64 = 36;
/// Bytes per chunk index entry (comp_off, comp_len, uncomp_len).
const ENTRY_LEN: u64 = 24;

/// One container file opened for serving: parsed header + chunk index,
/// with compressed chunk payloads fetched lazily per request.
#[derive(Debug)]
pub struct FileDataset {
    path: PathBuf,
    /// Positioned reads go through one lock; chunk fetches are short
    /// (seek + read of one compressed chunk) and mostly page-cache
    /// hits, so a plain mutex beats per-shard file handles in
    /// complexity at this scale.
    file: Mutex<File>,
    codec: CodecKind,
    chunk_size: usize,
    total_uncompressed: u64,
    index: Vec<ChunkEntry>,
    /// Per-chunk restart tables (empty per chunk for v1 files). Parsed
    /// and checksum-verified eagerly at open, like the index: the
    /// serving path never re-reads them per request.
    restarts: Vec<Vec<RestartPoint>>,
    /// Per-chunk codecs for mixed v3 files; empty for uniform files,
    /// where every chunk uses `codec`.
    chunk_codecs: Vec<CodecKind>,
    /// Per-chunk CRC-32C of the uncompressed bytes (v4 files; empty for
    /// v1–v3). Decode paths verify against it on every read.
    checksums: Vec<u32>,
    /// File offset where the payload section starts.
    payload_off: u64,
    /// Payload section length (file length minus header and index).
    payload_len: u64,
    /// Reusable compressed-side read buffers (checked out per decode,
    /// capacity warm): the daemon's steady state allocates no
    /// per-request Vec on the file path, mirroring the output-side
    /// scratch pool in `coordinator::Service` (DESIGN.md §7.3).
    comp_pool: Mutex<Vec<Vec<u8>>>,
    /// Per-dataset metrics handle, attached once by the daemon at
    /// startup (`attach_metrics`); when set, `read_chunk_into` times
    /// each positioned read into the `file_read` stage histogram.
    metrics: OnceLock<Arc<DatasetMetrics>>,
}

/// Compressed-side buffers retained per dataset (a bound on idle
/// memory; shard workers are few, so checkout contention is nil).
const COMP_POOL_CAP: usize = 8;

impl FileDataset {
    /// Open and validate a container file; the payload stays on disk.
    pub fn open(path: impl AsRef<Path>) -> Result<FileDataset> {
        let path = path.as_ref().to_path_buf();
        let mut file = File::open(&path)?;
        let file_len = file.metadata()?.len();
        let mut head = [0u8; HEADER_LEN as usize];
        read_exact_or_corrupt(&mut file, &mut head, "container header")?;
        let magic = u32::from_le_bytes(head[0..4].try_into().unwrap());
        if magic != MAGIC {
            return Err(corrupt(format!("{}: bad magic 0x{magic:08X}", path.display())));
        }
        let version = u32::from_le_bytes(head[4..8].try_into().unwrap());
        if !(VERSION_V1..=VERSION_CHECKSUM).contains(&version) {
            return Err(corrupt(format!(
                "{}: unsupported container version {version}",
                path.display()
            )));
        }
        let codec_raw = u32::from_le_bytes(head[8..12].try_into().unwrap());
        let codec = CodecKind::from_u32(codec_raw).ok_or(Error::UnknownCodec(codec_raw))?;
        let chunk_size = u64::from_le_bytes(head[12..20].try_into().unwrap());
        let total_uncompressed = u64::from_le_bytes(head[20..28].try_into().unwrap());
        let n_chunks = u64::from_le_bytes(head[28..36].try_into().unwrap());
        // The index must fit inside the file before anything is
        // allocated for it — a hostile n_chunks cannot force a large
        // allocation.
        let index_len = n_chunks
            .checked_mul(ENTRY_LEN)
            .filter(|&l| l <= file_len.saturating_sub(HEADER_LEN))
            .ok_or_else(|| corrupt(format!("{}: index larger than file", path.display())))?;
        if n_chunks > 0 && chunk_size == 0 {
            return Err(corrupt(format!("{}: zero chunk_size with chunks", path.display())));
        }
        let mut index_bytes = vec![0u8; index_len as usize];
        read_exact_or_corrupt(&mut file, &mut index_bytes, "chunk index")?;
        // v4 whole-meta CRC: fold every metadata byte as it streams by,
        // so the check below covers the header, index, and every section
        // (including their stored guards) without buffering the file.
        let mut meta_crc = crc32c_extend(0, &head);
        meta_crc = crc32c_extend(meta_crc, &index_bytes);
        // v2: restart section (per-chunk tables + FNV guard) sits
        // between the index and the payload; stream it with a running
        // checksum so hostile counts never force a large allocation.
        let mut restarts = Vec::with_capacity(n_chunks as usize);
        let mut section_len = 0u64;
        if version != VERSION_V1 {
            let mut sum = FNV_OFFSET;
            for i in 0..n_chunks {
                let mut cnt = [0u8; 4];
                read_exact_or_corrupt(&mut file, &mut cnt, "restart section")?;
                sum = fnv1a64(sum, &cnt);
                meta_crc = crc32c_extend(meta_crc, &cnt);
                let count = u32::from_le_bytes(cnt) as u64;
                // Same alloc-cap discipline as n_chunks: the table must
                // fit in the file before anything is reserved for it.
                let table_len = count
                    .checked_mul(RESTART_ENTRY_LEN as u64)
                    .filter(|&l| l <= file_len.saturating_sub(HEADER_LEN + index_len))
                    .ok_or_else(|| {
                        corrupt(format!(
                            "{}: chunk {i} restart table larger than file",
                            path.display()
                        ))
                    })?;
                let mut table_bytes = vec![0u8; table_len as usize];
                read_exact_or_corrupt(&mut file, &mut table_bytes, "restart section")?;
                sum = fnv1a64(sum, &table_bytes);
                meta_crc = crc32c_extend(meta_crc, &table_bytes);
                let mut table = Vec::with_capacity(count as usize);
                for e in table_bytes.chunks_exact(RESTART_ENTRY_LEN) {
                    table.push(RestartPoint {
                        bit_pos: u64::from_le_bytes(e[0..8].try_into().unwrap()),
                        out_off: u64::from_le_bytes(e[8..16].try_into().unwrap()),
                    });
                }
                restarts.push(table);
                section_len += 4 + table_len;
            }
            let mut stored = [0u8; 8];
            read_exact_or_corrupt(&mut file, &mut stored, "restart checksum")?;
            meta_crc = crc32c_extend(meta_crc, &stored);
            let stored = u64::from_le_bytes(stored);
            if sum != stored {
                return Err(corrupt(format!(
                    "{}: restart section checksum mismatch \
                     (computed {sum:016x}, stored {stored:016x})",
                    path.display()
                )));
            }
            section_len += 8;
        } else {
            restarts.resize_with(n_chunks as usize, Vec::new);
        }
        // v3/v4: per-chunk codec section (FNV-guarded, like the restart
        // section). The allocation is bounded by the index cap above
        // (4 bytes per chunk < 24). Checksum verifies first so bit rot
        // is Corrupt; only a cleanly stored unregistered id becomes the
        // typed UnknownCodec.
        let mut chunk_codecs = Vec::new();
        if version == VERSION_MIXED || version == VERSION_CHECKSUM {
            let mut id_bytes = vec![0u8; n_chunks as usize * 4];
            read_exact_or_corrupt(&mut file, &mut id_bytes, "codec section")?;
            let sum = fnv1a64(FNV_OFFSET, &id_bytes);
            let mut stored = [0u8; 8];
            read_exact_or_corrupt(&mut file, &mut stored, "codec checksum")?;
            meta_crc = crc32c_extend(meta_crc, &id_bytes);
            meta_crc = crc32c_extend(meta_crc, &stored);
            let stored = u64::from_le_bytes(stored);
            if sum != stored {
                return Err(corrupt(format!(
                    "{}: codec section checksum mismatch \
                     (computed {sum:016x}, stored {stored:016x})",
                    path.display()
                )));
            }
            chunk_codecs.reserve(n_chunks as usize);
            for e in id_bytes.chunks_exact(4) {
                let id = u32::from_le_bytes(e.try_into().unwrap());
                chunk_codecs.push(CodecKind::from_u32(id).ok_or(Error::UnknownCodec(id))?);
            }
            if n_chunks > 0 && chunk_codecs.first() != Some(&codec) {
                return Err(corrupt(format!(
                    "{}: header codec disagrees with chunk 0's codec",
                    path.display()
                )));
            }
            // v4 writes the section even when uniform; collapse it back
            // so per-chunk dispatch stays the cheap fallback path.
            if chunk_codecs.iter().all(|&k| k == codec) {
                chunk_codecs.clear();
            }
            section_len += n_chunks * 4 + 8;
        }
        // v4: content checksum section (per-chunk CRC-32C, FNV-guarded),
        // then the whole-meta CRC — verified here, *before* the index
        // below is trusted to drive positioned reads.
        let mut checksums = Vec::new();
        if version == VERSION_CHECKSUM {
            let mut sum_bytes = vec![0u8; n_chunks as usize * 4];
            read_exact_or_corrupt(&mut file, &mut sum_bytes, "checksum section")?;
            let sum = fnv1a64(FNV_OFFSET, &sum_bytes);
            let mut stored = [0u8; 8];
            read_exact_or_corrupt(&mut file, &mut stored, "checksum guard")?;
            meta_crc = crc32c_extend(meta_crc, &sum_bytes);
            meta_crc = crc32c_extend(meta_crc, &stored);
            let stored = u64::from_le_bytes(stored);
            if sum != stored {
                return Err(corrupt(format!(
                    "{}: checksum section guard mismatch \
                     (computed {sum:016x}, stored {stored:016x})",
                    path.display()
                )));
            }
            checksums.reserve(n_chunks as usize);
            for e in sum_bytes.chunks_exact(4) {
                checksums.push(u32::from_le_bytes(e.try_into().unwrap()));
            }
            let mut stored_meta = [0u8; 4];
            read_exact_or_corrupt(&mut file, &mut stored_meta, "meta checksum")?;
            let stored_meta = u32::from_le_bytes(stored_meta);
            if meta_crc != stored_meta {
                return Err(corrupt(format!(
                    "{}: metadata crc32c mismatch \
                     (computed {meta_crc:08x}, stored {stored_meta:08x})",
                    path.display()
                )));
            }
            section_len += n_chunks * 4 + 8 + 4;
        }
        let payload_off = HEADER_LEN + index_len + section_len;
        let payload_len = file_len.checked_sub(payload_off).ok_or_else(|| {
            corrupt(format!("{}: restart section extends past file", path.display()))
        })?;
        let mut index = Vec::with_capacity(n_chunks as usize);
        let mut uncomp_sum = 0u64;
        for (i, e) in index_bytes.chunks_exact(ENTRY_LEN as usize).enumerate() {
            let entry = ChunkEntry {
                comp_off: u64::from_le_bytes(e[0..8].try_into().unwrap()),
                comp_len: u64::from_le_bytes(e[8..16].try_into().unwrap()),
                uncomp_len: u64::from_le_bytes(e[16..24].try_into().unwrap()),
            };
            let end = entry
                .comp_off
                .checked_add(entry.comp_len)
                .ok_or_else(|| corrupt(format!("{}: chunk {i} index overflow", path.display())))?;
            if end > payload_len {
                return Err(corrupt(format!(
                    "{}: chunk {i} extends past the payload section",
                    path.display()
                )));
            }
            if entry.uncomp_len > chunk_size {
                return Err(corrupt(format!(
                    "{}: chunk {i} uncompressed length {} exceeds chunk size {}",
                    path.display(),
                    entry.uncomp_len,
                    chunk_size
                )));
            }
            uncomp_sum = uncomp_sum.checked_add(entry.uncomp_len).ok_or_else(|| {
                corrupt(format!("{}: uncompressed total overflow", path.display()))
            })?;
            index.push(entry);
        }
        if uncomp_sum != total_uncompressed {
            return Err(corrupt(format!(
                "{}: index sums to {uncomp_sum} uncompressed bytes, header says {total_uncompressed}",
                path.display()
            )));
        }
        for (i, (table, e)) in restarts.iter().zip(&index).enumerate() {
            validate_restart_table(table, e).map_err(|err| {
                corrupt(format!("{}: chunk {i} restart table invalid: {err}", path.display()))
            })?;
        }
        Ok(FileDataset {
            path,
            file: Mutex::new(file),
            codec,
            chunk_size: chunk_size as usize,
            total_uncompressed,
            index,
            restarts,
            chunk_codecs,
            checksums,
            payload_off,
            payload_len,
            comp_pool: Mutex::new(Vec::new()),
            metrics: OnceLock::new(),
        })
    }

    /// Attach the dataset's metrics handle (daemon startup; later
    /// attaches are ignored — the handle is write-once).
    pub fn attach_metrics(&self, m: Arc<DatasetMetrics>) {
        let _ = self.metrics.set(m);
    }

    /// Backing file path.
    pub fn path(&self) -> &Path {
        &self.path
    }

    /// The header codec (for a mixed v3 file: chunk 0's codec — use
    /// [`chunk_codec`](Self::chunk_codec) for per-chunk dispatch).
    pub fn codec(&self) -> CodecKind {
        self.codec
    }

    /// The codec chunk `i` was compressed with (`codec()` for uniform
    /// files).
    pub fn chunk_codec(&self, i: usize) -> CodecKind {
        self.chunk_codecs.get(i).copied().unwrap_or(self.codec)
    }

    /// Nominal uncompressed chunk size.
    pub fn chunk_size(&self) -> usize {
        self.chunk_size
    }

    /// Total uncompressed length.
    pub fn total_uncompressed(&self) -> u64 {
        self.total_uncompressed
    }

    /// Per-chunk index (validated at open).
    pub fn index(&self) -> &[ChunkEntry] {
        &self.index
    }

    /// The restart table of chunk `i` (empty for v1 files or chunks
    /// without recorded sub-block boundaries).
    pub fn restart_table(&self, i: usize) -> &[RestartPoint] {
        self.restarts.get(i).map(Vec::as_slice).unwrap_or(&[])
    }

    /// The packed CRC-32C of chunk `i`'s uncompressed bytes (v4 files;
    /// `None` for v1–v3, which carry no content checksums).
    pub fn chunk_checksum(&self, i: usize) -> Option<u32> {
        self.checksums.get(i).copied()
    }

    /// Number of chunks.
    pub fn n_chunks(&self) -> usize {
        self.index.len()
    }

    /// Compressed payload bytes on disk.
    pub fn compressed_len(&self) -> u64 {
        self.payload_len
    }

    /// Fetch the compressed bytes of chunk `i` into `buf` (cleared
    /// first, capacity reused). This is the lazy read: one seek + one
    /// exact read of the chunk span.
    pub fn read_chunk_into(&self, i: usize, buf: &mut Vec<u8>) -> Result<()> {
        let e = *self
            .index
            .get(i)
            .ok_or_else(|| invalid(format!("chunk {i} out of range (have {})", self.index.len())))?;
        buf.clear();
        buf.resize(e.comp_len as usize, 0);
        let t0 = now_if_enabled().filter(|_| self.metrics.get().is_some());
        let mut file = self.file.lock().unwrap();
        file.seek(SeekFrom::Start(self.payload_off + e.comp_off))?;
        read_exact_or_corrupt(&mut *file, buf, "compressed chunk (file shrank after open?)")?;
        drop(file);
        if let (Some(t0), Some(m)) = (t0, self.metrics.get()) {
            m.stage(Stage::FileRead).record(t0.elapsed());
        }
        Ok(())
    }

    /// Decompress chunk `i` into a caller-owned buffer (cleared first,
    /// capacity reused) — the file-backed twin of
    /// [`Container::decompress_chunk_into`](crate::format::container::Container::decompress_chunk_into).
    /// The compressed bytes land in a pooled buffer, so the steady
    /// state is allocation-free on both sides of the decode.
    pub fn decompress_chunk_into(&self, i: usize, out: &mut Vec<u8>) -> Result<()> {
        let mut comp = self.comp_pool.lock().unwrap().pop().unwrap_or_default();
        let decoded = self.decompress_pooled(i, &mut comp, out);
        comp.clear();
        let mut pool = self.comp_pool.lock().unwrap();
        if pool.len() < COMP_POOL_CAP {
            pool.push(comp);
        }
        decoded
    }

    /// Restart-point split decode of chunk `i` across `n_workers`
    /// threads — the file-backed twin of
    /// [`decompress_chunk_split_into`](crate::coordinator::engine::decompress_chunk_split_into).
    /// An empty restart table degrades to serial sub-block decode.
    pub fn decompress_chunk_split_into(
        &self,
        i: usize,
        n_workers: usize,
        out: &mut Vec<u8>,
    ) -> Result<()> {
        self.decompress_chunk_split_obs_into(i, n_workers, out, None)
    }

    /// [`decompress_chunk_split_into`](Self::decompress_chunk_split_into)
    /// with optional stitch fan-out/join timing (DESIGN.md §10).
    pub fn decompress_chunk_split_obs_into(
        &self,
        i: usize,
        n_workers: usize,
        out: &mut Vec<u8>,
        obs: Option<StitchTimers<'_>>,
    ) -> Result<()> {
        let mut comp = self.comp_pool.lock().unwrap().pop().unwrap_or_default();
        let decoded = (|| {
            self.read_chunk_into(i, &mut comp)?;
            out.clear();
            out.resize(self.index[i].uncomp_len as usize, 0);
            crate::coordinator::engine::decode_chunk_parallel_obs(
                self.chunk_codec(i),
                &comp,
                self.restart_table(i),
                out,
                n_workers,
                obs,
            )?;
            // Content verification at the stitch join, over the whole
            // chunk extent (DESIGN.md §13).
            crate::format::container::Container::verify_chunk_content(&self.checksums, i, out)
        })();
        comp.clear();
        let mut pool = self.comp_pool.lock().unwrap();
        if pool.len() < COMP_POOL_CAP {
            pool.push(comp);
        }
        decoded
    }

    fn decompress_pooled(&self, i: usize, comp: &mut Vec<u8>, out: &mut Vec<u8>) -> Result<()> {
        self.read_chunk_into(i, comp)?;
        let want = self.index[i].uncomp_len as usize;
        out.clear();
        out.reserve(want);
        let mut sink = crate::decomp::ByteSink { out: std::mem::take(out) };
        let decoded = crate::codecs::decode_into(self.chunk_codec(i), &comp[..], &mut sink);
        *out = sink.into_bytes();
        decoded?;
        if out.len() != want {
            return Err(corrupt(format!(
                "{}: chunk {i} decompressed {} bytes, index says {want}",
                self.path.display(),
                out.len()
            )));
        }
        crate::format::container::Container::verify_chunk_content(&self.checksums, i, out)
    }
}

/// `read_exact` that maps a short read to `Corrupt` (truncated file)
/// instead of a generic I/O error, keeping the error taxonomy typed.
fn read_exact_or_corrupt(r: &mut impl Read, buf: &mut [u8], what: &str) -> Result<()> {
    r.read_exact(buf).map_err(|e| {
        if e.kind() == std::io::ErrorKind::UnexpectedEof {
            corrupt(format!("truncated {what}"))
        } else {
            Error::from(e)
        }
    })
}

/// Scan `dir` for `<name>.codag` container files and open each one,
/// sorted by name (deterministic registration order). An unreadable
/// directory is `Io`; a malformed file is `Corrupt` naming the file.
pub fn load_dir(dir: impl AsRef<Path>) -> Result<Vec<(String, FileDataset)>> {
    let dir = dir.as_ref();
    let mut paths: Vec<PathBuf> = Vec::new();
    for entry in std::fs::read_dir(dir)? {
        let path = entry?.path();
        if path.extension().and_then(|e| e.to_str()) == Some("codag") {
            paths.push(path);
        }
    }
    paths.sort();
    let mut out = Vec::with_capacity(paths.len());
    for path in paths {
        let name = path
            .file_stem()
            .and_then(|s| s.to_str())
            .ok_or_else(|| invalid(format!("non-UTF-8 dataset file name: {}", path.display())))?
            .to_string();
        out.push((name, FileDataset::open(&path)?));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::format::container::Container;
    use std::sync::atomic::{AtomicU64, Ordering};

    /// Unique temp path per test (the suite runs in one process).
    fn tmp_path(tag: &str) -> PathBuf {
        static N: AtomicU64 = AtomicU64::new(0);
        let n = N.fetch_add(1, Ordering::Relaxed);
        std::env::temp_dir().join(format!("codag-store-{}-{tag}-{n}", std::process::id()))
    }

    fn sample_data() -> Vec<u8> {
        let mut v = Vec::new();
        for i in 0..3000u32 {
            let b = (i % 11) as u8;
            for _ in 0..(i % 7 + 1) {
                v.push(b);
            }
        }
        v
    }

    fn write_sample(tag: &str, codec: CodecKind) -> (PathBuf, Vec<u8>, Container) {
        let data = sample_data();
        let c = Container::compress(&data, codec, 4096).unwrap();
        let path = tmp_path(tag).with_extension("codag");
        std::fs::write(&path, c.to_bytes()).unwrap();
        (path, data, c)
    }

    #[test]
    fn open_serves_byte_identical_chunks() {
        for codec in [CodecKind::RleV1, CodecKind::RleV2, CodecKind::Deflate] {
            let (path, data, c) = write_sample("roundtrip", codec);
            let fd = FileDataset::open(&path).unwrap();
            assert_eq!(fd.codec(), codec);
            assert_eq!(fd.chunk_size(), 4096);
            assert_eq!(fd.total_uncompressed(), data.len() as u64);
            assert_eq!(fd.n_chunks(), c.n_chunks());
            let mut comp = Vec::new();
            let mut out = Vec::new();
            let mut all = Vec::new();
            for i in 0..fd.n_chunks() {
                // Lazy compressed fetch matches the in-memory payload.
                fd.read_chunk_into(i, &mut comp).unwrap();
                assert_eq!(comp, c.chunk_bytes(i).unwrap(), "chunk {i}");
                fd.decompress_chunk_into(i, &mut out).unwrap();
                all.extend_from_slice(&out);
            }
            assert_eq!(all, data, "{codec:?}");
            std::fs::remove_file(&path).ok();
        }
    }

    #[test]
    fn out_of_range_chunk_is_invalid_not_panic() {
        let (path, _, c) = write_sample("range", CodecKind::RleV1);
        let fd = FileDataset::open(&path).unwrap();
        let mut buf = Vec::new();
        let err = fd.read_chunk_into(c.n_chunks() + 3, &mut buf).unwrap_err();
        assert!(matches!(err, Error::Invalid(_)), "{err}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn truncated_file_is_corrupt_at_every_cut() {
        let (path, _, c) = write_sample("trunc", CodecKind::RleV2);
        let bytes = c.to_bytes();
        // Cuts through the header and the index must fail at open; cuts
        // through the payload must fail at open (index past payload) —
        // never panic, never misreport as Io.
        let header_and_index = (HEADER_LEN + ENTRY_LEN * c.n_chunks() as u64) as usize;
        for cut in [0, 4, 12, 35, header_and_index - 1, header_and_index + 1, bytes.len() - 1] {
            std::fs::write(&path, &bytes[..cut]).unwrap();
            let err = FileDataset::open(&path).unwrap_err();
            assert!(matches!(err, Error::Corrupt(_)), "cut {cut}: {err}");
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn corrupt_header_fields_are_corrupt_errors() {
        let (path, _, c) = write_sample("header", CodecKind::Deflate);
        let good = c.to_bytes();
        // (offset, value) mutations: magic, version, codec, hostile
        // n_chunks, index entry past payload, inconsistent uncomp_len.
        let mut cases: Vec<Vec<u8>> = Vec::new();
        let mut m = good.clone();
        m[0] ^= 0xFF; // magic
        cases.push(m);
        let mut m = good.clone();
        m[4] = 0xEE; // version
        cases.push(m);
        let mut m = good.clone();
        m[28..36].copy_from_slice(&u64::MAX.to_le_bytes()); // n_chunks
        cases.push(m);
        let mut m = good.clone();
        m[36..44].copy_from_slice(&u64::MAX.to_le_bytes()); // chunk 0 comp_off
        cases.push(m);
        let mut m = good.clone();
        m[52..60].copy_from_slice(&u64::MAX.to_le_bytes()); // chunk 0 uncomp_len
        cases.push(m);
        for (i, bad) in cases.into_iter().enumerate() {
            std::fs::write(&path, &bad).unwrap();
            let err = FileDataset::open(&path).unwrap_err();
            assert!(matches!(err, Error::Corrupt(_)), "case {i}: {err}");
        }
        // An unregistered codec id is the typed error, not Corrupt.
        let mut m = good.clone();
        m[8] = 0x7F;
        std::fs::write(&path, &m).unwrap();
        let err = FileDataset::open(&path).unwrap_err();
        assert!(matches!(err, Error::UnknownCodec(0x7F)), "{err}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn mixed_v3_file_serves_per_chunk_codecs() {
        // Build a mixed container by hand (chunk 0 RLE v1, chunk 1
        // DEFLATE, ...) and serve it from disk: the lazy store must
        // dispatch each chunk through its own codec, serially and via
        // the parallel stitch path.
        let data = sample_data();
        let chunk_size = 4096usize;
        let kinds = [CodecKind::RleV1, CodecKind::Deflate, CodecKind::Lzss];
        let mut index = Vec::new();
        let mut restarts = Vec::new();
        let mut chunk_codecs = Vec::new();
        let mut payload = Vec::new();
        for (i, chunk) in data.chunks(chunk_size).enumerate() {
            let kind = kinds[i % kinds.len()];
            let (comp, points) =
                crate::codecs::compress_chunk_restarts(kind, chunk, 512).unwrap();
            index.push(ChunkEntry {
                comp_off: payload.len() as u64,
                comp_len: comp.len() as u64,
                uncomp_len: chunk.len() as u64,
            });
            restarts.push(points);
            chunk_codecs.push(kind);
            payload.extend_from_slice(&comp);
        }
        let c = Container {
            codec: chunk_codecs[0],
            chunk_size,
            total_uncompressed: data.len() as u64,
            index,
            restarts,
            chunk_codecs: chunk_codecs.clone(),
            // No checksums: this file must serialize as a legacy v3.
            checksums: Vec::new(),
            payload,
        };
        let path = tmp_path("mixed-v3").with_extension("codag");
        std::fs::write(&path, c.to_bytes()).unwrap();
        let fd = FileDataset::open(&path).unwrap();
        let mut out = Vec::new();
        let mut all = Vec::new();
        for i in 0..fd.n_chunks() {
            assert_eq!(fd.chunk_codec(i), chunk_codecs[i], "chunk {i}");
            fd.decompress_chunk_into(i, &mut out).unwrap();
            all.extend_from_slice(&out);
        }
        assert_eq!(all, data);
        let mut split = Vec::new();
        for i in 0..fd.n_chunks() {
            fd.decompress_chunk_into(i, &mut out).unwrap();
            fd.decompress_chunk_split_into(i, 4, &mut split).unwrap();
            assert_eq!(split, out, "chunk {i} split decode diverged");
        }
        // Codec-section corruption is caught at open.
        let bytes = c.to_bytes();
        let restart_len: usize =
            c.restarts.iter().map(|t| 4 + t.len() * RESTART_ENTRY_LEN).sum::<usize>() + 8;
        let codec_start = HEADER_LEN as usize + ENTRY_LEN as usize * c.n_chunks() + restart_len;
        for off in (codec_start..codec_start + c.n_chunks() * 4 + 8).step_by(3) {
            let mut bad = bytes.clone();
            bad[off] ^= 0x01;
            std::fs::write(&path, &bad).unwrap();
            assert!(FileDataset::open(&path).is_err(), "flip at {off} went undetected");
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn v2_restart_tables_match_in_memory_container() {
        let data = sample_data();
        let c = Container::compress_with_restarts(&data, CodecKind::RleV2, 4096, 512).unwrap();
        assert!(c.restarts.iter().any(|t| !t.is_empty()));
        let path = tmp_path("v2-tables").with_extension("codag");
        std::fs::write(&path, c.to_bytes()).unwrap();
        let fd = FileDataset::open(&path).unwrap();
        for i in 0..c.n_chunks() {
            assert_eq!(fd.restart_table(i), c.restart_table(i), "chunk {i}");
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn v1_file_opens_with_empty_restarts() {
        let data = sample_data();
        let c = Container::compress(&data, CodecKind::RleV1, 4096).unwrap();
        // Rewrite as v1: header + index + payload, version patched.
        let mut v1 = c.to_bytes()[..(HEADER_LEN + ENTRY_LEN * c.n_chunks() as u64) as usize]
            .to_vec();
        v1[4..8].copy_from_slice(&VERSION_V1.to_le_bytes());
        v1.extend_from_slice(&c.payload);
        let path = tmp_path("v1-compat").with_extension("codag");
        std::fs::write(&path, &v1).unwrap();
        let fd = FileDataset::open(&path).unwrap();
        assert!((0..fd.n_chunks()).all(|i| fd.restart_table(i).is_empty()));
        let mut out = Vec::new();
        let mut all = Vec::new();
        for i in 0..fd.n_chunks() {
            fd.decompress_chunk_into(i, &mut out).unwrap();
            all.extend_from_slice(&out);
        }
        assert_eq!(all, data);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn hostile_restart_count_is_alloc_capped() {
        let (path, _, c) = write_sample("hostile-count", CodecKind::RleV1);
        let mut bytes = c.to_bytes();
        // First chunk's n_restarts field sits right after the index;
        // claim a table far larger than the file.
        let off = (HEADER_LEN + ENTRY_LEN * c.n_chunks() as u64) as usize;
        bytes[off..off + 4].copy_from_slice(&u32::MAX.to_le_bytes());
        std::fs::write(&path, &bytes).unwrap();
        let err = FileDataset::open(&path).unwrap_err();
        assert!(matches!(err, Error::Corrupt(_)), "{err}");
        assert!(err.to_string().contains("restart table larger"), "{err}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn corrupt_restart_section_rejected_at_open() {
        let data = sample_data();
        let c = Container::compress_with_restarts(&data, CodecKind::RleV2, 4096, 512).unwrap();
        let bytes = c.to_bytes();
        let section_start = (HEADER_LEN + ENTRY_LEN * c.n_chunks() as u64) as usize;
        let section_len: usize = c
            .restarts
            .iter()
            .map(|t| 4 + t.len() * RESTART_ENTRY_LEN)
            .sum::<usize>()
            + 8;
        let path = tmp_path("bad-restarts").with_extension("codag");
        // Sample a spread of section bytes (counts, entries, checksum).
        for off in (section_start..section_start + section_len).step_by(5) {
            let mut bad = bytes.clone();
            bad[off] ^= 0x01;
            std::fs::write(&path, &bad).unwrap();
            let err = FileDataset::open(&path).unwrap_err();
            assert!(matches!(err, Error::Corrupt(_)), "flip at {off}: {err}");
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn split_decode_from_file_matches_serial() {
        let data = sample_data();
        let c = Container::compress_with_restarts(&data, CodecKind::Deflate, 4096, 512).unwrap();
        let path = tmp_path("split-file").with_extension("codag");
        std::fs::write(&path, c.to_bytes()).unwrap();
        let fd = FileDataset::open(&path).unwrap();
        let mut serial = Vec::new();
        let mut split = Vec::new();
        for i in 0..fd.n_chunks() {
            fd.decompress_chunk_into(i, &mut serial).unwrap();
            for workers in [1, 2, 8] {
                fd.decompress_chunk_split_into(i, workers, &mut split).unwrap();
                assert_eq!(split, serial, "chunk {i} workers {workers}");
            }
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn v4_file_exposes_chunk_checksums() {
        let (path, data, c) = write_sample("v4-sums", CodecKind::RleV2);
        let fd = FileDataset::open(&path).unwrap();
        for (i, chunk) in data.chunks(4096).enumerate() {
            assert_eq!(fd.chunk_checksum(i), c.chunk_checksum(i), "chunk {i}");
            assert_eq!(
                fd.chunk_checksum(i),
                Some(crate::format::hash::crc32c(chunk)),
                "chunk {i}"
            );
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn legacy_v2_file_opens_with_checksums_absent() {
        let data = sample_data();
        let mut c = Container::compress(&data, CodecKind::RleV2, 4096).unwrap();
        c.checksums.clear();
        let path = tmp_path("legacy-v2").with_extension("codag");
        std::fs::write(&path, c.to_bytes()).unwrap();
        let fd = FileDataset::open(&path).unwrap();
        assert!(fd.chunk_checksum(0).is_none());
        let mut out = Vec::new();
        let mut all = Vec::new();
        for i in 0..fd.n_chunks() {
            fd.decompress_chunk_into(i, &mut out).unwrap();
            all.extend_from_slice(&out);
        }
        assert_eq!(all, data);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn v4_metadata_flips_rejected_at_open() {
        // Sampled flips across the whole v4 metadata region — index,
        // restart section, codec section, checksum section, meta CRC —
        // must all fail open (FNV guards or the whole-meta CRC).
        let data = sample_data();
        let c = Container::compress_with_restarts(&data, CodecKind::RleV2, 4096, 512).unwrap();
        let bytes = c.to_bytes();
        let payload_start = bytes.len() - c.payload.len();
        let path = tmp_path("v4-meta-flips").with_extension("codag");
        for off in (36..payload_start).step_by(7).chain([payload_start - 1]) {
            let mut bad = bytes.clone();
            bad[off] ^= 0x01;
            std::fs::write(&path, &bad).unwrap();
            assert!(FileDataset::open(&path).is_err(), "flip at {off} went undetected");
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn v4_payload_corruption_is_checksum_mismatch_on_read() {
        // Corrupt a payload byte whose flip still decodes "successfully"
        // or not — either way the file-backed read must never return
        // wrong bytes: serial and split decode both verify content.
        let data = sample_data();
        let c = Container::compress_with_restarts(&data, CodecKind::RleV2, 4096, 512).unwrap();
        let bytes = c.to_bytes();
        let payload_start = bytes.len() - c.payload.len();
        let path = tmp_path("v4-payload").with_extension("codag");
        let mut out = Vec::new();
        for off in (payload_start..bytes.len()).step_by(11) {
            let mut bad = bytes.clone();
            bad[off] ^= 0x01;
            std::fs::write(&path, &bad).unwrap();
            let fd = FileDataset::open(&path).unwrap();
            let chunk = c
                .index
                .iter()
                .position(|e| {
                    let lo = payload_start + e.comp_off as usize;
                    (lo..lo + e.comp_len as usize).contains(&off)
                })
                .unwrap();
            let serial = fd.decompress_chunk_into(chunk, &mut out);
            match serial {
                Err(_) => {}
                Ok(()) => assert_eq!(
                    out,
                    &data[chunk * 4096..(chunk * 4096 + out.len()).min(data.len())],
                    "payload flip at {off} served wrong bytes (serial)"
                ),
            }
            let split = fd.decompress_chunk_split_into(chunk, 4, &mut out);
            match split {
                Err(_) => {}
                Ok(()) => assert_eq!(
                    out,
                    &data[chunk * 4096..(chunk * 4096 + out.len()).min(data.len())],
                    "payload flip at {off} served wrong bytes (split)"
                ),
            }
        }
        // And a guaranteed-garbage case must surface the typed error:
        // lying about the checksum itself is caught by the FNV/meta
        // guards, so instead corrupt a long run's fill byte (changes
        // content, keeps the stream decodable for RLE).
        let mut c2 = Container::compress(&data, CodecKind::RleV1, 4096).unwrap();
        // RleV1 literal/run structure: flip a byte deep inside chunk 0's
        // compressed stream; if that makes decode error, walk forward
        // until one decodes to wrong bytes.
        let e0 = c2.index[0];
        let mut typed_seen = false;
        for off in 0..e0.comp_len as usize {
            let mut tampered = c2.payload.clone();
            tampered[e0.comp_off as usize + off] ^= 0x40;
            std::mem::swap(&mut c2.payload, &mut tampered);
            let bytes2 = c2.to_bytes();
            std::mem::swap(&mut c2.payload, &mut tampered);
            std::fs::write(&path, &bytes2).unwrap();
            let fd = FileDataset::open(&path).unwrap();
            if let Err(Error::ChecksumMismatch(_)) = fd.decompress_chunk_into(0, &mut out) {
                typed_seen = true;
                break;
            }
        }
        assert!(typed_seen, "no payload flip surfaced a typed ChecksumMismatch");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn missing_file_is_io() {
        let err = FileDataset::open(tmp_path("missing")).unwrap_err();
        assert!(matches!(err, Error::Io(_)), "{err}");
    }

    #[test]
    fn load_dir_scans_and_sorts() {
        let dir = tmp_path("dir");
        std::fs::create_dir_all(&dir).unwrap();
        let data = sample_data();
        for name in ["zeta", "alpha"] {
            let c = Container::compress(&data, CodecKind::RleV1, 4096).unwrap();
            std::fs::write(dir.join(format!("{name}.codag")), c.to_bytes()).unwrap();
        }
        // Non-container files are ignored.
        std::fs::write(dir.join("notes.txt"), b"ignored").unwrap();
        let loaded = load_dir(&dir).unwrap();
        let names: Vec<&str> = loaded.iter().map(|(n, _)| n.as_str()).collect();
        assert_eq!(names, ["alpha", "zeta"]);
        assert_eq!(loaded[0].1.total_uncompressed(), data.len() as u64);
        std::fs::remove_dir_all(&dir).ok();
    }
}
