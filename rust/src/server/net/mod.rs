//! Event-driven network front (unix-only): nonblocking sockets behind
//! a single poll-based event loop, decoupled from the shard decode
//! pool by fixed-size submission/completion rings (DESIGN.md §11).
//!
//! The threaded front spends two OS threads per connection; CODAG's
//! answer to many independent streams is one small scheduler
//! multiplexing all of them. This module is that scheduler, built
//! std-only in three layers:
//!
//! * [`sys`] — minimal FFI shim over `poll(2)` (`repr(C)` pollfd +
//!   event-bit helpers); the only platform code, kept inside this
//!   module.
//! * [`ring`] — bounded lock-light SPSC rings carrying admitted jobs
//!   to shard workers and finished responses back; `Full` on push is
//!   the evented `Busy` site, preserving the threaded backpressure
//!   contract bit-for-bit.
//! * [`event_loop`] — the loop itself: owns every connection socket,
//!   drives the incremental `FrameReader` on readable events, and
//!   flushes responses as one vectored write of a stack-built header
//!   plus the (possibly cache-shared) payload, with partial-write
//!   resumption for slow readers.

pub mod event_loop;
pub mod ring;
pub mod sys;

pub use event_loop::Waker;
pub(crate) use event_loop::{run as net_loop, NetLoop};
