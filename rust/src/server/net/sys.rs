//! Minimal FFI shim over `poll(2)` — the one platform call the evented
//! front needs beyond what std exposes.
//!
//! The crate's no-external-deps discipline rules out the `libc` crate,
//! but on unix std itself links the platform C library, so declaring
//! the `poll` symbol here resolves against the exact same library std
//! already uses. The `pollfd` layout and event bits below are fixed by
//! POSIX and identical across the unix targets we build for; the only
//! platform wrinkle is the `nfds_t` width (unsigned long on Linux,
//! unsigned int elsewhere).

use std::io;
use std::os::fd::RawFd;
use std::time::Duration;

/// POSIX `struct pollfd`: `int fd; short events; short revents;`.
#[repr(C)]
#[derive(Debug, Clone, Copy)]
pub struct PollFd {
    pub fd: RawFd,
    pub events: i16,
    pub revents: i16,
}

/// Data may be read without blocking.
pub const POLLIN: i16 = 0x001;
/// Data may be written without blocking.
pub const POLLOUT: i16 = 0x004;
/// Error condition (always polled, regardless of `events`).
pub const POLLERR: i16 = 0x008;
/// Peer hung up (always polled).
pub const POLLHUP: i16 = 0x010;
/// `fd` is not an open descriptor (always polled).
pub const POLLNVAL: i16 = 0x020;

impl PollFd {
    pub fn new(fd: RawFd, events: i16) -> PollFd {
        PollFd { fd, events, revents: 0 }
    }

    /// The kernel reported any condition at all on this fd.
    pub fn ready(&self) -> bool {
        self.revents != 0
    }

    /// A read will make progress: data, EOF (`POLLHUP` delivers
    /// buffered bytes then 0), or an error a read will surface.
    pub fn readable(&self) -> bool {
        self.revents & (POLLIN | POLLHUP | POLLERR) != 0
    }

    /// A write will make progress (or surface its error).
    pub fn writable(&self) -> bool {
        self.revents & (POLLOUT | POLLERR | POLLHUP) != 0
    }

    /// The descriptor is unusable; no read/write will recover it.
    pub fn failed(&self) -> bool {
        self.revents & (POLLERR | POLLNVAL) != 0
    }
}

#[cfg(target_os = "linux")]
type NfdsT = core::ffi::c_ulong;
#[cfg(not(target_os = "linux"))]
type NfdsT = core::ffi::c_uint;

extern "C" {
    fn poll(fds: *mut PollFd, nfds: NfdsT, timeout: core::ffi::c_int) -> core::ffi::c_int;
}

/// Wait until at least one fd in `fds` is ready or `timeout` elapses.
/// Returns the number of ready fds (0 = timeout). `EINTR` is retried
/// internally so callers never see a spurious error from a signal.
pub fn poll_fds(fds: &mut [PollFd], timeout: Duration) -> io::Result<usize> {
    let ms = i32::try_from(timeout.as_millis()).unwrap_or(i32::MAX);
    loop {
        // SAFETY: `PollFd` is `repr(C)` with the POSIX `pollfd` layout;
        // the pointer and length come from a live mutable slice, and
        // poll(2) writes only within `fds[..nfds]`.
        let rc = unsafe { poll(fds.as_mut_ptr(), fds.len() as NfdsT, ms) };
        if rc >= 0 {
            return Ok(rc as usize);
        }
        let err = io::Error::last_os_error();
        if err.kind() != io::ErrorKind::Interrupted {
            return Err(err);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;
    use std::os::fd::AsRawFd;
    use std::os::unix::net::UnixStream;

    #[test]
    fn poll_reports_readable_after_write() {
        let (mut a, b) = UnixStream::pair().unwrap();
        let mut fds = [PollFd::new(b.as_raw_fd(), POLLIN)];
        // Nothing to read yet: times out with zero ready fds.
        let n = poll_fds(&mut fds, Duration::from_millis(10)).unwrap();
        assert_eq!(n, 0);
        assert!(!fds[0].ready());
        a.write_all(&[7]).unwrap();
        fds[0].revents = 0;
        let n = poll_fds(&mut fds, Duration::from_millis(1000)).unwrap();
        assert_eq!(n, 1);
        assert!(fds[0].readable());
        assert!(!fds[0].failed());
    }

    #[test]
    fn poll_reports_hup_on_peer_drop() {
        let (a, b) = UnixStream::pair().unwrap();
        drop(a);
        let mut fds = [PollFd::new(b.as_raw_fd(), POLLIN)];
        let n = poll_fds(&mut fds, Duration::from_millis(1000)).unwrap();
        assert_eq!(n, 1);
        // Hang-up surfaces as readable (the read then returns 0/EOF).
        assert!(fds[0].readable());
    }

    #[test]
    fn poll_reports_writable_socket() {
        let (a, _b) = UnixStream::pair().unwrap();
        let mut fds = [PollFd::new(a.as_raw_fd(), POLLOUT)];
        let n = poll_fds(&mut fds, Duration::from_millis(1000)).unwrap();
        assert_eq!(n, 1);
        assert!(fds[0].writable());
    }
}
