//! The poll-based event loop: one thread owning every connection
//! socket (DESIGN.md §11).
//!
//! This is CODAG's many-independent-streams discipline applied at the
//! network tier: instead of dedicating a reader and a writer thread to
//! each connection, a single scheduler multiplexes all of them over
//! `poll(2)`, with fixed-size rings decoupling socket I/O from the
//! shard decode pool (the virtqueue/completion-queue idiom). Per
//! iteration:
//!
//! 1. `poll` on the listener, the [`Waker`] pipe, and every connection
//!    (`POLLIN` unless the connection is draining, `POLLOUT` iff its
//!    write queue is non-empty).
//! 2. Readable connections run the incremental `FrameReader`; each
//!    complete frame goes through the same `admit` decision function as
//!    the threaded model, then `try_push` onto the shard's submission
//!    ring (`Full` ⇒ `Busy`, byte-identical backpressure).
//! 3. Completion rings are drained; responses land on per-connection
//!    write queues as a 28-byte stack-built head plus the payload —
//!    shared cache spans ride as `Payload::Shared` (`Arc<[u8]>`), no
//!    assembly buffer anywhere.
//! 4. Every non-empty write queue is flushed until `WouldBlock`: one
//!    vectored write of head + payload, with a byte cursor resuming
//!    partial writes for slow readers.
//! 5. Finished connections are reaped: transport errors, drained
//!    (EOF/error/hard-cap) connections with nothing left in flight, and
//!    writers stalled past `write_timeout`.
//!
//! Shutdown ordering: the loop observes the token, stops accepting,
//! closes the submission rings (workers drain what was admitted, then
//! exit), marks every connection draining, flushes all in-flight
//! responses, and exits once the last connection closes — then closes
//! the completion rings so a worker mid-push for a dead connection
//! unblocks (its completion drops, like a send on a disconnected
//! channel).

use crate::coordinator::service::Payload;
use crate::coordinator::Registry;
use crate::obs::{now_if_enabled, DatasetMetrics, Stage};
use crate::server::cache::ChunkCache;
use crate::server::daemon::{
    admit, conn_hard_cap, Admit, Completion, DaemonConfig, Job, Obs, Outbound, ReplySink,
};
use crate::server::net::ring::{PushError, Ring};
use crate::server::net::sys::{self, PollFd};
use crate::server::proto::{
    decode_request_versioned, request_id_hint, request_version_hint, response_frame_crc,
    response_head_ext, FrameReader, ReadEvent, Status, WireRequest, FLAG_FRAME_CRC, WIRE_VERSION,
};
use crate::Error;
use std::collections::VecDeque;
use std::io::{self, IoSlice, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::os::fd::{AsRawFd, RawFd};
use std::os::unix::net::UnixStream;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

/// Length of the stacked length-prefix + response header
/// (`proto::response_head`).
const HEAD_LEN: usize = 28;

/// Wakes the net loop out of `poll` when a shard worker publishes a
/// completion: a byte written to a socketpair whose read end sits in
/// the poll set. Writes are non-blocking and best-effort — a full pipe
/// means wakeups are already pending, which is all a wakeup means.
#[derive(Debug)]
pub struct Waker {
    tx: UnixStream,
    rx: UnixStream,
}

impl Waker {
    pub fn new() -> io::Result<Waker> {
        let (tx, rx) = UnixStream::pair()?;
        tx.set_nonblocking(true)?;
        rx.set_nonblocking(true)?;
        Ok(Waker { tx, rx })
    }

    /// Nudge the poll loop (any thread).
    pub fn wake(&self) {
        // WouldBlock = the pipe already carries pending wakeups.
        let _ = (&self.tx).write(&[1u8]);
    }

    /// The fd the loop registers for `POLLIN`.
    pub fn fd(&self) -> RawFd {
        self.rx.as_raw_fd()
    }

    /// Swallow all pending wakeup bytes (loop thread only).
    pub fn drain(&self) {
        let mut buf = [0u8; 64];
        while matches!((&self.rx).read(&mut buf), Ok(n) if n > 0) {}
    }
}

/// One queued response frame: the stack-built head plus the payload it
/// borrows (shared cache span or owned error text), with the write
/// cursor held by the connection.
struct PendingWrite {
    head: [u8; HEAD_LEN],
    payload: Payload,
    /// v3 frame-CRC trailer (requested via [`FLAG_FRAME_CRC`]): 4 LE
    /// CRC32C bytes over body header + payload, written after the
    /// payload. `None` when the requester didn't opt in.
    trailer: Option<[u8; 4]>,
    /// Byte-budget charge taken at admission, returned once the frame
    /// is fully written (0 for error/metadata replies).
    charge: u64,
    dm: Option<Arc<DatasetMetrics>>,
    /// Set when the flusher first touches this frame; the
    /// `response_write` stage spans first write attempt → frame
    /// complete, mirroring the threaded writer's per-response timing.
    t0: Option<Instant>,
}

/// Per-connection state owned by the loop. The counters mirror the
/// threaded model's `inflight` / `inflight_bytes` atomics exactly —
/// they just don't need to be atomic, because one thread owns them.
struct Conn {
    stream: TcpStream,
    /// Generation tag baked into completion tokens: a completion for a
    /// closed connection whose slot was reused must not be delivered
    /// to the newcomer.
    gen: u32,
    reader: FrameReader,
    wq: VecDeque<PendingWrite>,
    /// Bytes of `wq.front()` already written (across head + payload).
    written: usize,
    /// Unwritten responses charged to this connection (every decoded
    /// frame yields exactly one response).
    outstanding: usize,
    /// Admitted-but-unwritten payload bytes (the byte budget).
    bytes: u64,
    /// Reads stopped (EOF, protocol error, hard cap, or daemon
    /// shutdown); the connection closes once `outstanding` responses
    /// have flushed.
    draining: bool,
    /// Transport failure: close without flushing.
    dead: bool,
    /// Last write progress; guards against a peer that stops reading.
    last_progress: Instant,
}

impl Conn {
    fn token(&self, idx: usize) -> u64 {
        ((self.gen as u64) << 32) | idx as u64
    }

    /// Queue a response frame. The head is built once, here; an
    /// oversized frame is impossible for admitted work (the span was
    /// checked against `MAX_FRAME_LEN` at admission), so a failure
    /// here is an internal inconsistency and kills the connection
    /// rather than desyncing its stream.
    fn enqueue(&mut self, out: Outbound) {
        let trailer_len = if out.frame_crc { 4 } else { 0 };
        match response_head_ext(out.version, out.status, out.id, out.payload.len() as u64, trailer_len)
        {
            Ok(head) => {
                // Computed once here on the loop thread; the CRC spans
                // body header + payload, exactly what the threaded
                // writer's `write_response_parts_crc` emits.
                let trailer =
                    out.frame_crc.then(|| response_frame_crc(&head, out.payload.as_slice()));
                if self.wq.is_empty() {
                    // The stall guard measures from when the queue
                    // became non-empty, not from the last frame ages
                    // ago.
                    self.last_progress = Instant::now();
                }
                self.wq.push_back(PendingWrite {
                    head,
                    payload: out.payload,
                    trailer,
                    charge: out.charge,
                    dm: out.obs,
                    t0: None,
                });
            }
            Err(_) => {
                if let Some(dm) = out.obs {
                    dm.inflight.dec();
                }
                self.dead = true;
            }
        }
    }

    fn enqueue_reply(
        &mut self,
        version: u16,
        frame_crc: bool,
        id: u64,
        status: Status,
        payload: Vec<u8>,
    ) {
        self.enqueue(Outbound {
            id,
            status,
            version,
            payload: Payload::Owned(payload),
            charge: 0,
            frame_crc,
            obs: None,
        });
    }
}

/// Everything the loop needs, bundled so the per-frame path isn't a
/// dozen-argument function.
pub(crate) struct NetLoop {
    pub listener: TcpListener,
    pub registry: Arc<Registry>,
    pub cache: Arc<ChunkCache>,
    pub submission: Vec<Arc<Ring<Job>>>,
    pub completion: Vec<Arc<Ring<Completion>>>,
    pub waker: Arc<Waker>,
    pub shutdown: Arc<AtomicBool>,
    pub config: DaemonConfig,
    pub obs: Obs,
}

pub(crate) fn run(nl: NetLoop) {
    let mut slots: Vec<Option<Conn>> = Vec::new();
    let mut next_gen: u32 = 1;
    let mut draining_all = false;
    let mut pollfds: Vec<PollFd> = Vec::new();
    // Slot index behind each conn pollfd (parallel to `pollfds[base..]`).
    let mut poll_slots: Vec<usize> = Vec::new();
    loop {
        if !draining_all && nl.shutdown.load(Ordering::SeqCst) {
            draining_all = true;
            // Stop admitting: workers drain what's queued, then exit.
            for r in &nl.submission {
                r.close();
            }
            for c in slots.iter_mut().flatten() {
                c.draining = true;
            }
        }
        if draining_all && slots.iter().all(Option::is_none) {
            break;
        }

        pollfds.clear();
        poll_slots.clear();
        let listen_at = if draining_all {
            None
        } else {
            pollfds.push(PollFd::new(nl.listener.as_raw_fd(), sys::POLLIN));
            Some(pollfds.len() - 1)
        };
        pollfds.push(PollFd::new(nl.waker.fd(), sys::POLLIN));
        let waker_at = pollfds.len() - 1;
        let base = pollfds.len();
        for (idx, slot) in slots.iter().enumerate() {
            if let Some(c) = slot {
                let mut events = 0i16;
                if !c.draining {
                    events |= sys::POLLIN;
                }
                if !c.wq.is_empty() {
                    events |= sys::POLLOUT;
                }
                // events == 0 is legal: POLLERR/POLLHUP/POLLNVAL are
                // always reported, which is exactly what a draining
                // connection with an empty queue still cares about.
                pollfds.push(PollFd::new(c.stream.as_raw_fd(), events));
                poll_slots.push(idx);
            }
        }

        let n_ready = match sys::poll_fds(&mut pollfds, nl.config.poll_interval) {
            Ok(n) => n,
            Err(_) => {
                // poll itself failing (e.g. transient ENOMEM) must not
                // spin the loop hot.
                thread::sleep(Duration::from_millis(1));
                0
            }
        };
        // Iteration-processing clock: only iterations with ready
        // events are recorded — idle 50 ms ticks would drown the
        // signal the net_loop histogram exists for.
        let t_iter = if n_ready > 0 { now_if_enabled() } else { None };
        if pollfds[waker_at].ready() {
            nl.waker.drain();
        }

        // 1. Readable connections: frames → admit → rings / replies.
        for (pi, &idx) in poll_slots.iter().enumerate() {
            let pf = pollfds[base + pi];
            if !pf.ready() {
                continue;
            }
            let Some(conn) = slots[idx].as_mut() else { continue };
            if pf.failed() {
                conn.dead = true;
                continue;
            }
            if pf.readable() && !conn.draining {
                read_conn(&nl, conn, idx);
            }
        }

        // 2. Shard completions → per-connection write queues.
        drain_completions(&nl, &mut slots);

        // 3. Flush everything with bytes pending, straight away: a
        //    response queued this iteration usually fits the socket
        //    buffer, so it goes out now instead of waiting one poll
        //    round for POLLOUT.
        for slot in slots.iter_mut() {
            if let Some(conn) = slot {
                if !conn.dead && !conn.wq.is_empty() && flush_conn(conn).is_err() {
                    conn.dead = true;
                }
            }
        }

        // 4. Accept (after processing, so the open-connection count the
        //    cap check sees is current).
        if let Some(li) = listen_at {
            if pollfds[li].ready() {
                accept_ready(&nl, &mut slots, &mut next_gen);
            }
        }

        // 5. Reap.
        for slot in slots.iter_mut() {
            let done = match slot {
                Some(c) => {
                    let stalled = !c.wq.is_empty()
                        && c.last_progress.elapsed() > nl.config.write_timeout;
                    c.dead || stalled || (c.draining && c.outstanding == 0 && c.wq.is_empty())
                }
                None => false,
            };
            if done {
                close_conn(slot, &nl.obs);
            }
        }

        if let Some(t0) = t_iter {
            nl.obs.metrics.net().net_loop_us.record(t0.elapsed());
        }
    }
    // All connections are gone; unblock any worker still pushing a
    // completion for one of them. A push on a closed ring hands the
    // completion back and the worker drops it — the ring analogue of
    // `let _ = tx.send(..)` on a disconnected channel. Completions that
    // made it in before the close are drained here so their in-flight
    // gauge charges are released rather than dropped silently.
    for r in &nl.completion {
        r.close();
        while let Some(comp) = r.try_pop() {
            nl.obs.metrics.net().completion_ring_depth.dec();
            if let Some(dm) = comp.out.obs {
                dm.inflight.dec();
            }
        }
    }
}

fn accept_ready(nl: &NetLoop, slots: &mut Vec<Option<Conn>>, next_gen: &mut u32) {
    loop {
        match nl.listener.accept() {
            Ok((stream, _peer)) => {
                let open = slots.iter().filter(|s| s.is_some()).count();
                if open >= nl.config.max_connections.max(1) {
                    // Hard cap, same policy as the threaded accept
                    // loop: refuse (close) rather than accumulate.
                    drop(stream);
                    continue;
                }
                if stream.set_nonblocking(true).is_err() {
                    continue;
                }
                // Header and payload are separate writes on the slow
                // (non-vectored resume) path: NODELAY, as everywhere
                // else in the daemon.
                let _ = stream.set_nodelay(true);
                let gen = *next_gen;
                *next_gen = next_gen.wrapping_add(1);
                let conn = Conn {
                    stream,
                    gen,
                    reader: FrameReader::for_requests(),
                    wq: VecDeque::new(),
                    written: 0,
                    outstanding: 0,
                    bytes: 0,
                    draining: false,
                    dead: false,
                    last_progress: Instant::now(),
                };
                match slots.iter_mut().position(Option::is_none) {
                    Some(i) => slots[i] = Some(conn),
                    None => slots.push(Some(conn)),
                }
                nl.obs.metrics.net().connections_open.inc();
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
            Err(_) => break,
        }
    }
}

/// Pull every frame currently buffered on a readable connection. The
/// kernel's receive buffer bounds how much one call can consume, and
/// the hard cap bounds how many responses it can queue, so one noisy
/// connection cannot monopolize an iteration.
fn read_conn(nl: &NetLoop, conn: &mut Conn, idx: usize) {
    loop {
        match conn.reader.poll(&mut conn.stream) {
            Ok(ReadEvent::WouldBlock) => break,
            Ok(ReadEvent::Eof) => {
                // Mirror the threaded reader's EOF path: stop reading,
                // flush everything already admitted, then close.
                conn.draining = true;
                break;
            }
            Ok(ReadEvent::Frame(body)) => {
                if !handle_frame(nl, conn, idx, body) {
                    conn.draining = true;
                    break;
                }
            }
            Err(e) => {
                // Broken framing (oversized prefix, mid-frame close) is
                // the client's fault; anything else is transport. Same
                // classification as the threaded reader.
                let status = match &e {
                    Error::Corrupt(_) => Status::BadRequest,
                    _ => Status::Internal,
                };
                conn.outstanding += 1;
                conn.enqueue_reply(WIRE_VERSION, false, 0, status, e.to_string().into_bytes());
                conn.draining = true;
                break;
            }
        }
    }
}

/// One decoded frame through the shared admission path. Returns false
/// when the connection must start draining (shutdown frame, hard cap,
/// or protocol error).
fn handle_frame(nl: &NetLoop, conn: &mut Conn, idx: usize, body: Vec<u8>) -> bool {
    let (req, version, flags) = match decode_request_versioned(&body) {
        Ok(rv) => rv,
        Err(e) => {
            conn.outstanding += 1;
            let id = request_id_hint(&body);
            let version = request_version_hint(&body);
            conn.enqueue_reply(version, false, id, Status::BadRequest, e.to_string().into_bytes());
            return false;
        }
    };
    // Reader-generated replies honour the frame-CRC opt-in too, so a
    // `--verify-frames` client can trust Stat/Metrics/Busy responses.
    let frame_crc = flags & FLAG_FRAME_CRC != 0;
    // Charge the (single) response up front, exactly like the threaded
    // reader's `inflight.fetch_add`.
    let outstanding = conn.outstanding;
    conn.outstanding += 1;
    if outstanding >= conn_hard_cap(&nl.config) && !matches!(req, WireRequest::Shutdown { .. }) {
        // Pipelining without reading even small responses: close
        // (the uncharged response is returned), flushing what's queued.
        conn.outstanding -= 1;
        return false;
    }
    match admit(
        req,
        version,
        flags,
        &nl.registry,
        &nl.cache,
        nl.submission.len(),
        outstanding,
        conn.bytes,
        &nl.shutdown,
        &nl.config,
        &nl.obs,
    ) {
        Admit::Shutdown { id, payload } => {
            conn.enqueue_reply(version, frame_crc, id, Status::Ok, payload);
            nl.shutdown.store(true, Ordering::SeqCst);
            false
        }
        Admit::Reply { id, status, payload } => {
            conn.enqueue_reply(version, frame_crc, id, status, payload);
            true
        }
        Admit::Enqueue(spec) => {
            let si = spec.si;
            let t_adm = spec.t_adm;
            let dm = spec.dm.clone();
            conn.bytes = conn.bytes.saturating_add(spec.charge);
            let job = Job {
                req: spec.req,
                reply: ReplySink::Ring {
                    token: conn.token(idx),
                    ring: Arc::clone(&nl.completion[si]),
                    waker: Arc::clone(&nl.waker),
                },
                received: spec.received,
                charge: spec.charge,
                deadline: spec.deadline,
                version: spec.version,
                frame_crc: spec.frame_crc,
                dm: spec.dm,
            };
            // Gauge before push: `Gauge::dec` saturates at zero, so the
            // inc must be visible before the shard worker's pop-side
            // dec can possibly run.
            nl.obs.metrics.net().submission_ring_depth.inc();
            match nl.submission[si].try_push(job) {
                Ok(()) => {
                    if let (Some(t0), Some(m)) = (t_adm, &dm) {
                        m.requests.inc();
                        m.inflight.inc();
                        m.stage(Stage::Admission).record(t0.elapsed());
                    }
                }
                Err(PushError::Full(job)) => {
                    // The ring-full Busy site — byte-for-byte the
                    // threaded model's `TrySendError::Full` arm.
                    nl.obs.metrics.net().submission_ring_depth.dec();
                    conn.bytes = conn.bytes.saturating_sub(job.charge);
                    if let Some(m) = &dm {
                        m.busy.inc();
                    }
                    conn.enqueue_reply(
                        job.version,
                        job.frame_crc,
                        job.req.id,
                        Status::Busy,
                        format!("shard {si} queue at admission limit").into_bytes(),
                    );
                }
                Err(PushError::Closed(job)) => {
                    nl.obs.metrics.net().submission_ring_depth.dec();
                    conn.bytes = conn.bytes.saturating_sub(job.charge);
                    conn.enqueue_reply(
                        job.version,
                        job.frame_crc,
                        job.req.id,
                        Status::ShuttingDown,
                        b"daemon is shutting down".to_vec(),
                    );
                }
            }
            true
        }
    }
}

fn drain_completions(nl: &NetLoop, slots: &mut [Option<Conn>]) {
    for ring in &nl.completion {
        while let Some(comp) = ring.try_pop() {
            nl.obs.metrics.net().completion_ring_depth.dec();
            let idx = (comp.token & u32::MAX as u64) as usize;
            let gen = (comp.token >> 32) as u32;
            match slots.get_mut(idx).and_then(Option::as_mut) {
                Some(conn) if conn.gen == gen => conn.enqueue(comp.out),
                // The connection closed while its request decoded: the
                // response has nowhere to go; release the in-flight
                // gauge it charged at admission.
                _ => {
                    if let Some(dm) = comp.out.obs {
                        dm.inflight.dec();
                    }
                }
            }
        }
    }
}

/// Write queued frames until the socket would block. The front frame's
/// progress lives in `conn.written`, a cursor across the 28-byte head,
/// the payload, and the optional 4-byte CRC trailer: while any head
/// bytes remain, head tail + payload + trailer go out as one vectored
/// write; once the head is down, the remainder resumes from whichever
/// region the cursor sits in.
fn flush_conn(conn: &mut Conn) -> io::Result<()> {
    loop {
        let (total, plen) = {
            let Some(front) = conn.wq.front_mut() else { return Ok(()) };
            if front.t0.is_none() && front.dm.is_some() {
                front.t0 = now_if_enabled();
            }
            let plen = front.payload.len();
            (HEAD_LEN + plen + front.trailer.map_or(0, |t| t.len()), plen)
        };
        while conn.written < total {
            let res = {
                let front = conn.wq.front().expect("checked above");
                let payload = front.payload.as_slice();
                let trailer: &[u8] = front.trailer.as_ref().map_or(&[], |t| &t[..]);
                if conn.written < HEAD_LEN {
                    let bufs = [
                        IoSlice::new(&front.head[conn.written..]),
                        IoSlice::new(payload),
                        IoSlice::new(trailer),
                    ];
                    conn.stream.write_vectored(&bufs)
                } else if conn.written < HEAD_LEN + plen {
                    let bufs = [
                        IoSlice::new(&payload[conn.written - HEAD_LEN..]),
                        IoSlice::new(trailer),
                    ];
                    conn.stream.write_vectored(&bufs)
                } else {
                    conn.stream.write(&trailer[conn.written - HEAD_LEN - plen..])
                }
            };
            match res {
                Ok(0) => {
                    return Err(io::Error::new(
                        io::ErrorKind::WriteZero,
                        "socket accepted zero bytes",
                    ));
                }
                Ok(n) => {
                    conn.written += n;
                    conn.last_progress = Instant::now();
                }
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => return Ok(()),
                Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
                Err(e) => return Err(e),
            }
        }
        let pw = conn.wq.pop_front().expect("frame just completed");
        conn.written = 0;
        if let Some(dm) = &pw.dm {
            if let Some(t0) = pw.t0 {
                dm.stage(Stage::ResponseWrite).record(t0.elapsed());
            }
            // Balanced against the inc at admission, same point in the
            // response lifecycle as the threaded writer.
            dm.inflight.dec();
        }
        conn.outstanding = conn.outstanding.saturating_sub(1);
        conn.bytes = conn.bytes.saturating_sub(pw.charge);
    }
}

/// Drop a connection and release everything it still holds: queued
/// responses return their in-flight gauge charges (their byte charges
/// die with the connection state), and the open-connections gauge
/// steps down.
fn close_conn(slot: &mut Option<Conn>, obs: &Obs) {
    if let Some(conn) = slot.take() {
        for pw in conn.wq {
            if let Some(dm) = pw.dm {
                dm.inflight.dec();
            }
        }
        obs.metrics.net().connections_open.dec();
    }
}
