//! Fixed-size submission/completion rings between the net loop and the
//! shard decode workers (DESIGN.md §11).
//!
//! Each ring is strictly SPSC — exactly one producer thread and one
//! consumer thread, which is what the daemon wiring guarantees: the net
//! loop is the sole producer of every submission ring and the sole
//! consumer of every completion ring; each shard thread is the sole
//! consumer of its submission ring and sole producer of its completion
//! ring. Under that discipline the hot path is lock-light: capacity
//! checks are two atomic loads on monotonic head/tail counters, and the
//! per-slot `Mutex<Option<T>>` is only ever taken uncontended (it
//! exists to make the value hand-off safe without `unsafe` cells, and
//! turns any accidental discipline violation into a stall rather than
//! undefined behavior).
//!
//! Backpressure and shutdown semantics mirror the bounded
//! `mpsc::sync_channel` the threaded net model uses, so the daemon's
//! admission contract is preserved verbatim:
//!
//! * [`Ring::try_push`] on a full ring returns [`PushError::Full`] —
//!   the net loop's `Busy` site, exactly like `try_send`.
//! * [`Ring::close`] + drain: a closed ring keeps yielding queued items
//!   until empty, then [`Pop::Closed`] — like senders dropping on a
//!   `sync_channel`, so admitted work is never lost at shutdown.
//! * [`Ring::push_blocking`] parks until space or close — the shard
//!   side of completion delivery, like the blocking `Sender::send`.

use std::sync::atomic::{
    AtomicBool, AtomicUsize,
    Ordering::{Acquire, Release},
};
use std::sync::Mutex;
use std::thread::{self, Thread};
use std::time::Duration;

/// Why a [`Ring::try_push`] was refused; the value comes back in both
/// cases so the caller can answer `Busy`/`ShuttingDown` with it.
#[derive(Debug)]
pub enum PushError<T> {
    /// The ring is at capacity (the admission-limit `Busy` site).
    Full(T),
    /// The ring was closed; no more items will ever be accepted.
    Closed(T),
}

/// Outcome of a [`Ring::pop_timeout`].
#[derive(Debug)]
pub enum Pop<T> {
    Item(T),
    Timeout,
    /// Closed *and* fully drained (queued items are always delivered
    /// before this is reported).
    Closed,
}

/// Bounded SPSC ring. See the module docs for the ownership discipline.
#[derive(Debug)]
pub struct Ring<T> {
    slots: Vec<Mutex<Option<T>>>,
    /// Monotonic pop count; `head % slots.len()` is the next slot out.
    head: AtomicUsize,
    /// Monotonic push count; `tail % slots.len()` is the next slot in.
    tail: AtomicUsize,
    closed: AtomicBool,
    /// Parked consumer waiting for an item (at most one — SPSC).
    pop_waiter: Mutex<Option<Thread>>,
    /// Parked producer waiting for space (at most one — SPSC).
    push_waiter: Mutex<Option<Thread>>,
}

/// Backstop park bound: waiters also re-check their condition at this
/// interval, so a lost wakeup can only ever cost one short nap, never a
/// hang.
const PARK_BACKSTOP: Duration = Duration::from_millis(50);

impl<T> Ring<T> {
    pub fn new(capacity: usize) -> Ring<T> {
        let capacity = capacity.max(1);
        Ring {
            slots: (0..capacity).map(|_| Mutex::new(None)).collect(),
            head: AtomicUsize::new(0),
            tail: AtomicUsize::new(0),
            closed: AtomicBool::new(false),
            pop_waiter: Mutex::new(None),
            push_waiter: Mutex::new(None),
        }
    }

    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    /// Queued items right now (approximate under concurrency; exact
    /// from either endpoint thread). Feeds the ring-depth gauges.
    pub fn len(&self) -> usize {
        self.tail.load(Acquire).wrapping_sub(self.head.load(Acquire))
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn is_closed(&self) -> bool {
        self.closed.load(Acquire)
    }

    /// Non-blocking push (producer thread only).
    pub fn try_push(&self, value: T) -> Result<(), PushError<T>> {
        if self.closed.load(Acquire) {
            return Err(PushError::Closed(value));
        }
        let head = self.head.load(Acquire);
        let tail = self.tail.load(Acquire);
        if tail.wrapping_sub(head) >= self.slots.len() {
            return Err(PushError::Full(value));
        }
        *self.slots[tail % self.slots.len()].lock().unwrap() = Some(value);
        // Publish after the slot is filled: the consumer acquires
        // `tail` and can then safely take the slot.
        self.tail.store(tail.wrapping_add(1), Release);
        Self::wake(&self.pop_waiter);
        Ok(())
    }

    /// Blocking push (producer thread only): parks until space frees up
    /// or the ring closes; `Err(value)` on close.
    pub fn push_blocking(&self, value: T) -> Result<(), T> {
        let mut value = value;
        loop {
            match self.try_push(value) {
                Ok(()) => return Ok(()),
                Err(PushError::Closed(v)) => return Err(v),
                Err(PushError::Full(v)) => value = v,
            }
            *self.push_waiter.lock().unwrap() = Some(thread::current());
            // Re-check between registration and park: a pop (or close)
            // landing in that window already consumed our wakeup.
            if self.len() < self.slots.len() || self.closed.load(Acquire) {
                self.push_waiter.lock().unwrap().take();
                continue;
            }
            thread::park_timeout(PARK_BACKSTOP);
            self.push_waiter.lock().unwrap().take();
        }
    }

    /// Non-blocking pop (consumer thread only). Keeps yielding queued
    /// items after close until the ring is drained.
    pub fn try_pop(&self) -> Option<T> {
        let head = self.head.load(Acquire);
        let tail = self.tail.load(Acquire);
        if head == tail {
            return None;
        }
        let value = self.slots[head % self.slots.len()].lock().unwrap().take();
        debug_assert!(value.is_some(), "SPSC discipline violated: empty published slot");
        self.head.store(head.wrapping_add(1), Release);
        Self::wake(&self.push_waiter);
        value
    }

    /// Pop with a bounded wait (consumer thread only): an item if one
    /// arrives within `timeout`, [`Pop::Closed`] only once the ring is
    /// closed *and* drained.
    pub fn pop_timeout(&self, timeout: Duration) -> Pop<T> {
        if let Some(v) = self.try_pop() {
            return Pop::Item(v);
        }
        if self.closed.load(Acquire) {
            // One more look: a final push may have raced the close.
            return match self.try_pop() {
                Some(v) => Pop::Item(v),
                None => Pop::Closed,
            };
        }
        *self.pop_waiter.lock().unwrap() = Some(thread::current());
        // Same lost-wakeup window as push_blocking: re-check after
        // registering, then park.
        if self.is_empty() && !self.closed.load(Acquire) {
            thread::park_timeout(timeout.min(PARK_BACKSTOP));
        }
        self.pop_waiter.lock().unwrap().take();
        match self.try_pop() {
            Some(v) => Pop::Item(v),
            None if self.closed.load(Acquire) => Pop::Closed,
            None => Pop::Timeout,
        }
    }

    /// Close the ring: pushes start failing, queued items stay poppable,
    /// and both parked sides wake so nobody sleeps through shutdown.
    pub fn close(&self) {
        self.closed.store(true, Release);
        Self::wake(&self.pop_waiter);
        Self::wake(&self.push_waiter);
    }

    fn wake(waiter: &Mutex<Option<Thread>>) {
        if let Some(t) = waiter.lock().unwrap().take() {
            t.unpark();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn fifo_push_pop_and_full() {
        let r: Ring<u32> = Ring::new(2);
        assert_eq!(r.capacity(), 2);
        assert!(r.is_empty());
        r.try_push(1).unwrap();
        r.try_push(2).unwrap();
        assert_eq!(r.len(), 2);
        match r.try_push(3) {
            Err(PushError::Full(3)) => {}
            other => panic!("expected Full(3), got {other:?}"),
        }
        assert_eq!(r.try_pop(), Some(1));
        r.try_push(3).unwrap();
        assert_eq!(r.try_pop(), Some(2));
        assert_eq!(r.try_pop(), Some(3));
        assert_eq!(r.try_pop(), None);
    }

    #[test]
    fn zero_capacity_clamps_to_one() {
        let r: Ring<u8> = Ring::new(0);
        assert_eq!(r.capacity(), 1);
        r.try_push(9).unwrap();
        assert!(matches!(r.try_push(10), Err(PushError::Full(10))));
    }

    #[test]
    fn close_drains_queued_items_then_reports_closed() {
        let r: Ring<u32> = Ring::new(4);
        r.try_push(1).unwrap();
        r.try_push(2).unwrap();
        r.close();
        assert!(matches!(r.try_push(3), Err(PushError::Closed(3))));
        // Queued work survives the close — the drain half of graceful
        // shutdown.
        assert!(matches!(r.pop_timeout(Duration::from_millis(10)), Pop::Item(1)));
        assert!(matches!(r.pop_timeout(Duration::from_millis(10)), Pop::Item(2)));
        assert!(matches!(r.pop_timeout(Duration::from_millis(10)), Pop::Closed));
    }

    #[test]
    fn pop_timeout_times_out_when_open_and_empty() {
        let r: Ring<u32> = Ring::new(1);
        assert!(matches!(r.pop_timeout(Duration::from_millis(10)), Pop::Timeout));
    }

    #[test]
    fn push_blocking_unblocks_when_consumer_pops() {
        let r: Arc<Ring<u32>> = Arc::new(Ring::new(1));
        r.try_push(1).unwrap();
        let producer = {
            let r = Arc::clone(&r);
            thread::spawn(move || r.push_blocking(2))
        };
        thread::sleep(Duration::from_millis(20));
        assert_eq!(r.try_pop(), Some(1));
        producer.join().unwrap().unwrap();
        assert_eq!(r.try_pop(), Some(2));
    }

    #[test]
    fn push_blocking_errors_out_on_close() {
        let r: Arc<Ring<u32>> = Arc::new(Ring::new(1));
        r.try_push(1).unwrap();
        let producer = {
            let r = Arc::clone(&r);
            thread::spawn(move || r.push_blocking(2))
        };
        thread::sleep(Duration::from_millis(20));
        r.close();
        assert_eq!(producer.join().unwrap(), Err(2));
    }

    #[test]
    fn pop_timeout_wakes_on_push_from_producer_thread() {
        let r: Arc<Ring<u32>> = Arc::new(Ring::new(4));
        let consumer = {
            let r = Arc::clone(&r);
            thread::spawn(move || {
                // Generous bound: the wake should land in microseconds.
                match r.pop_timeout(Duration::from_secs(5)) {
                    Pop::Item(v) => v,
                    other => panic!("expected item, got {other:?}"),
                }
            })
        };
        thread::sleep(Duration::from_millis(20));
        r.try_push(42).unwrap();
        assert_eq!(consumer.join().unwrap(), 42);
    }

    #[test]
    fn spsc_stress_preserves_order_and_count() {
        const N: usize = 10_000;
        let r: Arc<Ring<usize>> = Arc::new(Ring::new(8));
        let producer = {
            let r = Arc::clone(&r);
            thread::spawn(move || {
                for i in 0..N {
                    r.push_blocking(i).unwrap();
                }
                r.close();
            })
        };
        let mut got = Vec::with_capacity(N);
        loop {
            match r.pop_timeout(Duration::from_millis(100)) {
                Pop::Item(v) => got.push(v),
                Pop::Timeout => {}
                Pop::Closed => break,
            }
        }
        producer.join().unwrap();
        assert_eq!(got.len(), N);
        assert!(got.iter().enumerate().all(|(i, &v)| i == v), "FIFO order");
    }
}
