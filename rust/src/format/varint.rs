//! ORC-style base-128 varints and zigzag encoding.
//!
//! ORC integer RLE (v1 and v2) stores base values as unsigned LEB128
//! varints; signed columns are zigzag-mapped first so small magnitudes
//! stay short. These are the `fetch_bits`-adjacent primitives every
//! integer codec path shares.

use crate::{corrupt, Result};

/// Append `v` to `out` as an unsigned LEB128 varint (1–10 bytes).
#[inline]
pub fn write_uvarint(out: &mut Vec<u8>, mut v: u64) {
    loop {
        let byte = (v & 0x7F) as u8;
        v >>= 7;
        if v == 0 {
            out.push(byte);
            break;
        }
        out.push(byte | 0x80);
    }
}

/// Read an unsigned LEB128 varint from `data[*pos..]`, advancing `*pos`.
#[inline]
pub fn read_uvarint(data: &[u8], pos: &mut usize) -> Result<u64> {
    let mut v: u64 = 0;
    let mut shift = 0u32;
    loop {
        let b = *data.get(*pos).ok_or_else(|| corrupt("varint: eof"))?;
        *pos += 1;
        if shift >= 64 {
            return Err(corrupt("varint: overflow (>10 bytes)"));
        }
        // The 10th byte may only carry the single remaining bit of a u64.
        if shift == 63 && (b & 0x7E) != 0 {
            return Err(corrupt("varint: value exceeds u64"));
        }
        v |= ((b & 0x7F) as u64) << shift;
        if b & 0x80 == 0 {
            return Ok(v);
        }
        shift += 7;
    }
}

/// Zigzag-map a signed value to unsigned (ORC signed varint convention).
#[inline]
pub fn zigzag(v: i64) -> u64 {
    ((v << 1) ^ (v >> 63)) as u64
}

/// Inverse of [`zigzag`].
#[inline]
pub fn unzigzag(v: u64) -> i64 {
    ((v >> 1) as i64) ^ -((v & 1) as i64)
}

/// Append `v` as a zigzag-ed signed varint.
#[inline]
pub fn write_svarint(out: &mut Vec<u8>, v: i64) {
    write_uvarint(out, zigzag(v));
}

/// Read a zigzag-ed signed varint.
#[inline]
pub fn read_svarint(data: &[u8], pos: &mut usize) -> Result<i64> {
    read_uvarint(data, pos).map(unzigzag)
}

/// Number of bytes `v` takes as an unsigned varint.
#[inline]
pub fn uvarint_len(mut v: u64) -> usize {
    let mut n = 1;
    while v >= 0x80 {
        v >>= 7;
        n += 1;
    }
    n
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uvarint_roundtrip_boundaries() {
        let cases = [
            0u64,
            1,
            127,
            128,
            16383,
            16384,
            u32::MAX as u64,
            u64::MAX - 1,
            u64::MAX,
        ];
        for &v in &cases {
            let mut buf = Vec::new();
            write_uvarint(&mut buf, v);
            assert_eq!(buf.len(), uvarint_len(v));
            let mut pos = 0;
            assert_eq!(read_uvarint(&buf, &mut pos).unwrap(), v, "value {v}");
            assert_eq!(pos, buf.len());
        }
    }

    #[test]
    fn svarint_roundtrip() {
        for &v in &[0i64, -1, 1, -64, 63, i64::MIN, i64::MAX, -123456789] {
            let mut buf = Vec::new();
            write_svarint(&mut buf, v);
            let mut pos = 0;
            assert_eq!(read_svarint(&buf, &mut pos).unwrap(), v);
        }
    }

    #[test]
    fn zigzag_small_magnitudes_are_small() {
        assert_eq!(zigzag(0), 0);
        assert_eq!(zigzag(-1), 1);
        assert_eq!(zigzag(1), 2);
        assert_eq!(zigzag(-2), 3);
        assert_eq!(unzigzag(zigzag(i64::MIN)), i64::MIN);
    }

    #[test]
    fn truncated_varint_is_corrupt() {
        let buf = [0x80u8, 0x80];
        let mut pos = 0;
        assert!(read_uvarint(&buf, &mut pos).is_err());
    }

    #[test]
    fn overlong_varint_is_corrupt() {
        let buf = [0xFFu8; 11];
        let mut pos = 0;
        assert!(read_uvarint(&buf, &mut pos).is_err());
    }
}
