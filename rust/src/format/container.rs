//! The chunked container format (paper §II-B).
//!
//! Modern compressed data formats (ORC, Parquet) divide the uncompressed
//! input into fixed-size chunks, compress each independently, and record
//! per-chunk offsets so a decompressor can assign chunks to parallel
//! processing units. This module implements that container: a small
//! header, a chunk index, and the concatenated compressed chunks.
//!
//! Layout (all integers little-endian):
//!
//! ```text
//! magic: u32 = 0xC0DA_6001
//! version: u32
//! codec: u32 (CodecKind discriminant)
//! chunk_size: u64        (uncompressed bytes per chunk, last may be short)
//! total_uncompressed: u64
//! n_chunks: u64
//! index: n_chunks × { comp_off: u64, comp_len: u64, uncomp_len: u64 }
//! payload bytes
//! ```
//!
//! The 128 KiB default matches the paper's evaluation (§V-B).

use crate::codecs::{compress_chunk, CodecKind};
use crate::{corrupt, invalid, Result};

/// Container magic number ("C0DAG" v1).
pub const MAGIC: u32 = 0xC0DA_6001;
/// Current container version.
pub const VERSION: u32 = 1;
/// Default chunk size used throughout the paper's evaluation.
pub const DEFAULT_CHUNK_SIZE: usize = 128 * 1024;

/// Index entry for one compressed chunk.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ChunkEntry {
    /// Offset of the chunk within the payload section.
    pub comp_off: u64,
    /// Compressed length in bytes.
    pub comp_len: u64,
    /// Uncompressed length in bytes (== chunk_size except the tail chunk).
    pub uncomp_len: u64,
}

/// A parsed (or freshly built) container.
#[derive(Debug, Clone)]
pub struct Container {
    /// Codec every chunk was compressed with.
    pub codec: CodecKind,
    /// Nominal uncompressed chunk size.
    pub chunk_size: usize,
    /// Total uncompressed length.
    pub total_uncompressed: u64,
    /// Per-chunk index.
    pub index: Vec<ChunkEntry>,
    /// Concatenated compressed chunk payloads.
    pub payload: Vec<u8>,
}

impl Container {
    /// Compress `data` into a container with `chunk_size`-byte chunks.
    pub fn compress(data: &[u8], codec: CodecKind, chunk_size: usize) -> Result<Container> {
        if chunk_size == 0 {
            return Err(invalid("chunk_size must be > 0"));
        }
        let mut index = Vec::new();
        let mut payload = Vec::new();
        for chunk in data.chunks(chunk_size) {
            let comp = compress_chunk(codec, chunk)?;
            index.push(ChunkEntry {
                comp_off: payload.len() as u64,
                comp_len: comp.len() as u64,
                uncomp_len: chunk.len() as u64,
            });
            payload.extend_from_slice(&comp);
        }
        Ok(Container {
            codec,
            chunk_size,
            total_uncompressed: data.len() as u64,
            index,
            payload,
        })
    }

    /// Number of chunks.
    pub fn n_chunks(&self) -> usize {
        self.index.len()
    }

    /// Compressed payload size in bytes (excluding header/index).
    pub fn compressed_len(&self) -> usize {
        self.payload.len()
    }

    /// Compression ratio as the paper reports it:
    /// compressed bytes / uncompressed bytes (smaller is better; >1 means
    /// the encoding expanded the data, e.g. TPT under RLE v1).
    pub fn compression_ratio(&self) -> f64 {
        if self.total_uncompressed == 0 {
            return 1.0;
        }
        self.payload.len() as f64 / self.total_uncompressed as f64
    }

    /// Borrow the compressed bytes of chunk `i`.
    pub fn chunk_bytes(&self, i: usize) -> Result<&[u8]> {
        let e = self.index.get(i).ok_or_else(|| invalid(format!("chunk {i} out of range")))?;
        let lo = e.comp_off as usize;
        let hi = lo + e.comp_len as usize;
        self.payload
            .get(lo..hi)
            .ok_or_else(|| corrupt(format!("chunk {i} index out of payload bounds")))
    }

    /// Decompress a single chunk.
    pub fn decompress_chunk(&self, i: usize) -> Result<Vec<u8>> {
        let mut out = Vec::new();
        self.decompress_chunk_into(i, &mut out)?;
        Ok(out)
    }

    /// Decompress chunk `i` into a caller-owned buffer (cleared first),
    /// reusing its capacity — the steady-state server path: workers
    /// decode every request into one long-lived scratch buffer instead
    /// of allocating a fresh `Vec` per chunk (DESIGN.md §7).
    ///
    /// On error the buffer contents are unspecified (cleared or
    /// partially decoded) but the buffer itself remains reusable.
    pub fn decompress_chunk_into(&self, i: usize, out: &mut Vec<u8>) -> Result<()> {
        let e = self.index[i];
        let bytes = self.chunk_bytes(i)?;
        out.clear();
        out.reserve(e.uncomp_len as usize);
        let mut sink = crate::decomp::ByteSink { out: std::mem::take(out) };
        let decoded = crate::codecs::decode_into(self.codec, bytes, &mut sink);
        *out = sink.into_bytes();
        decoded?;
        if out.len() != e.uncomp_len as usize {
            return Err(corrupt(format!(
                "chunk {i}: decompressed {} bytes, index says {}",
                out.len(),
                e.uncomp_len
            )));
        }
        Ok(())
    }

    /// Decompress every chunk sequentially (correctness reference path;
    /// the parallel engines live in [`crate::coordinator`]).
    pub fn decompress_all(&self) -> Result<Vec<u8>> {
        let mut out = Vec::with_capacity(self.total_uncompressed as usize);
        for i in 0..self.n_chunks() {
            out.extend_from_slice(&self.decompress_chunk(i)?);
        }
        Ok(out)
    }

    /// Serialize to bytes.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(48 + self.index.len() * 24 + self.payload.len());
        out.extend_from_slice(&MAGIC.to_le_bytes());
        out.extend_from_slice(&VERSION.to_le_bytes());
        out.extend_from_slice(&(self.codec as u32).to_le_bytes());
        out.extend_from_slice(&(self.chunk_size as u64).to_le_bytes());
        out.extend_from_slice(&self.total_uncompressed.to_le_bytes());
        out.extend_from_slice(&(self.index.len() as u64).to_le_bytes());
        for e in &self.index {
            out.extend_from_slice(&e.comp_off.to_le_bytes());
            out.extend_from_slice(&e.comp_len.to_le_bytes());
            out.extend_from_slice(&e.uncomp_len.to_le_bytes());
        }
        out.extend_from_slice(&self.payload);
        out
    }

    /// Parse a container from bytes.
    pub fn from_bytes(data: &[u8]) -> Result<Container> {
        let mut pos = 0usize;
        let take_u32 = |data: &[u8], pos: &mut usize| -> Result<u32> {
            let b = data.get(*pos..*pos + 4).ok_or_else(|| corrupt("container: truncated header"))?;
            *pos += 4;
            Ok(u32::from_le_bytes(b.try_into().unwrap()))
        };
        let magic = take_u32(data, &mut pos)?;
        if magic != MAGIC {
            return Err(corrupt(format!("bad magic 0x{magic:08X}")));
        }
        let version = take_u32(data, &mut pos)?;
        if version != VERSION {
            return Err(corrupt(format!("unsupported version {version}")));
        }
        let codec_raw = take_u32(data, &mut pos)?;
        let codec = CodecKind::from_u32(codec_raw)
            .ok_or_else(|| corrupt(format!("unknown codec {codec_raw}")))?;
        let take_u64 = |data: &[u8], pos: &mut usize| -> Result<u64> {
            let b = data.get(*pos..*pos + 8).ok_or_else(|| corrupt("container: truncated header"))?;
            *pos += 8;
            Ok(u64::from_le_bytes(b.try_into().unwrap()))
        };
        let chunk_size = take_u64(data, &mut pos)? as usize;
        let total_uncompressed = take_u64(data, &mut pos)?;
        let n_chunks = take_u64(data, &mut pos)? as usize;
        // Sanity cap: the index must fit in the remaining bytes.
        if n_chunks.saturating_mul(24) > data.len().saturating_sub(pos) {
            return Err(corrupt("container: index larger than file"));
        }
        let mut index = Vec::with_capacity(n_chunks);
        for _ in 0..n_chunks {
            index.push(ChunkEntry {
                comp_off: take_u64(data, &mut pos)?,
                comp_len: take_u64(data, &mut pos)?,
                uncomp_len: take_u64(data, &mut pos)?,
            });
        }
        let payload = data[pos..].to_vec();
        // Validate index bounds against payload.
        for (i, e) in index.iter().enumerate() {
            let end = e.comp_off.checked_add(e.comp_len).ok_or_else(|| corrupt("index overflow"))?;
            if end as usize > payload.len() {
                return Err(corrupt(format!("chunk {i} extends past payload")));
            }
        }
        Ok(Container { codec, chunk_size, total_uncompressed, index, payload })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_data() -> Vec<u8> {
        // Runs + literals so every codec has something to chew on.
        let mut v = Vec::new();
        for i in 0..2000u32 {
            let b = (i % 7) as u8;
            for _ in 0..(i % 13 + 1) {
                v.push(b);
            }
        }
        v
    }

    #[test]
    fn roundtrip_all_codecs() {
        let data = sample_data();
        for codec in [CodecKind::RleV1, CodecKind::RleV2, CodecKind::Deflate] {
            let c = Container::compress(&data, codec, 4096).unwrap();
            assert_eq!(c.decompress_all().unwrap(), data, "{codec:?}");
        }
    }

    #[test]
    fn serialization_roundtrip() {
        let data = sample_data();
        let c = Container::compress(&data, CodecKind::Deflate, 4096).unwrap();
        let bytes = c.to_bytes();
        let c2 = Container::from_bytes(&bytes).unwrap();
        assert_eq!(c2.codec, CodecKind::Deflate);
        assert_eq!(c2.n_chunks(), c.n_chunks());
        assert_eq!(c2.decompress_all().unwrap(), data);
    }

    #[test]
    fn tail_chunk_is_short() {
        let data = vec![42u8; 10_000];
        let c = Container::compress(&data, CodecKind::RleV1, 4096).unwrap();
        assert_eq!(c.n_chunks(), 3);
        assert_eq!(c.index[2].uncomp_len, 10_000 - 2 * 4096);
        assert_eq!(c.decompress_all().unwrap(), data);
    }

    #[test]
    fn empty_input() {
        let c = Container::compress(&[], CodecKind::Deflate, 4096).unwrap();
        assert_eq!(c.n_chunks(), 0);
        assert_eq!(c.decompress_all().unwrap(), Vec::<u8>::new());
        let c2 = Container::from_bytes(&c.to_bytes()).unwrap();
        assert_eq!(c2.total_uncompressed, 0);
    }

    #[test]
    fn bad_magic_rejected() {
        let data = vec![0u8; 64];
        assert!(Container::from_bytes(&data).is_err());
    }

    #[test]
    fn truncated_index_rejected() {
        let data = sample_data();
        let c = Container::compress(&data, CodecKind::RleV1, 4096).unwrap();
        let bytes = c.to_bytes();
        assert!(Container::from_bytes(&bytes[..40]).is_err());
    }

    #[test]
    fn corrupt_index_bounds_rejected() {
        let data = sample_data();
        let c = Container::compress(&data, CodecKind::RleV1, 4096).unwrap();
        let mut bytes = c.to_bytes();
        // comp_len of chunk 0 lives at offset 36+8; blow it up.
        let off = 36 + 8;
        bytes[off..off + 8].copy_from_slice(&u64::MAX.to_le_bytes());
        assert!(Container::from_bytes(&bytes).is_err());
    }
}
