//! The chunked container format (paper §II-B).
//!
//! Modern compressed data formats (ORC, Parquet) divide the uncompressed
//! input into fixed-size chunks, compress each independently, and record
//! per-chunk offsets so a decompressor can assign chunks to parallel
//! processing units. This module implements that container: a small
//! header, a chunk index, and the concatenated compressed chunks.
//!
//! Layout (all integers little-endian):
//!
//! ```text
//! magic: u32 = 0xC0DA_6001
//! version: u32           (1, 2, 3, or 4)
//! codec: u32 (CodecKind wire id; v3/v4: chunk 0's codec)
//! chunk_size: u64        (uncompressed bytes per chunk, last may be short)
//! total_uncompressed: u64
//! n_chunks: u64
//! index: n_chunks × { comp_off: u64, comp_len: u64, uncomp_len: u64 }
//! -- v2+ only: restart section --
//! per chunk: { n_restarts: u32, n_restarts × { bit_pos: u64, out_off: u64 } }
//! checksum: u64          (FNV-1a 64 over every restart-section byte above)
//! -- end v2 section --
//! -- v3 (mixed) / v4 (always): codec section --
//! n_chunks × u32        (per-chunk CodecKind wire ids)
//! checksum: u64          (FNV-1a 64 over the codec ids above)
//! -- end codec section --
//! -- v4 only: content checksum section --
//! n_chunks × u32        (CRC-32C of each chunk's *uncompressed* bytes)
//! checksum: u64          (FNV-1a 64 over the checksums above)
//! meta_crc: u32          (CRC-32C over every file byte before this field)
//! -- end v4 section --
//! payload bytes
//! ```
//!
//! v2 (DESIGN.md §8) appends a **restart table** per chunk: pack-time
//! sub-block boundaries `(bit_pos, out_off)` — bit position into the
//! chunk's compressed stream, byte offset into its uncompressed output —
//! recorded roughly every [`DEFAULT_RESTART_INTERVAL`] output bytes, so
//! the serving tier can split one chunk across workers
//! ([`crate::coordinator::engine::decode_chunk_parallel`]). The implicit
//! starting point `(0, 0)` is never stored. The section is guarded by a
//! trailing FNV-1a checksum: any single-byte corruption of a restart
//! table is detected at parse time rather than surfacing as a decode
//! divergence. v1 files parse unchanged with empty restart tables.
//!
//! v3 lifts the one-codec-per-container assumption: `codag pack --codec
//! auto` trial-compresses a bounded sample of every chunk through each
//! registered codec and records the per-chunk winner. A container whose
//! chunks all agree still serializes as plain v2 (byte-identical to a
//! forced pack), so mixed files are the only ones paying the extra
//! section; the header codec field holds chunk 0's codec for v3 so old
//! tooling reading only the header sees a registered id. The codec
//! section carries its own FNV-1a guard. Codec ids the registry does
//! not know fail parse with the typed
//! [`UnknownCodec`](crate::Error::UnknownCodec).
//!
//! v4 is the integrity tier (DESIGN.md §13): every fresh pack records a
//! CRC-32C of each chunk's **uncompressed** bytes, so decode paths can
//! prove the bytes they produced are the bytes that were packed — even
//! when a corrupted stream happens to decode "successfully" (the
//! measured dead-bit sets of the bit-flip sweeps). v4 always carries the
//! codec section (uniform files repeat the header codec; the parser
//! collapses that back to an empty `chunk_codecs`, so re-serialization
//! is byte-identical) and closes its metadata with a whole-meta CRC-32C
//! that [`FileDataset`](crate::server::store::FileDataset) verifies
//! before trusting the index. v1–v3 files parse forever with checksums
//! absent — and are then served without content verification.
//!
//! The 128 KiB default matches the paper's evaluation (§V-B).

use crate::codecs::{compress_chunk_restarts, CodecKind, CodecRegistry, RestartPoint};
use crate::format::hash::crc32c;
use crate::{corrupt, invalid, Error, Result};

/// Container magic number ("C0DAG" v1).
pub const MAGIC: u32 = 0xC0DA_6001;
/// Uniform container version without content checksums (still readable;
/// no longer written by [`Container::to_bytes`] for fresh packs).
pub const VERSION: u32 = 2;
/// First container version, still readable (no restart section).
pub const VERSION_V1: u32 = 1;
/// Mixed-codec container version: v2 plus a per-chunk codec section.
pub const VERSION_MIXED: u32 = 3;
/// Integrity-tier container version: codec section (always) plus
/// per-chunk CRC-32C content checksums and a whole-meta CRC-32C.
/// Written by every compress path.
pub const VERSION_CHECKSUM: u32 = 4;
/// Bytes of each chunk sampled by [`Container::compress_auto`]'s codec
/// trials (the whole chunk when it is smaller).
pub const AUTO_SAMPLE_BYTES: usize = 16 * 1024;
/// Default chunk size used throughout the paper's evaluation.
pub const DEFAULT_CHUNK_SIZE: usize = 128 * 1024;
/// Default restart interval: one sub-block boundary roughly every this
/// many uncompressed bytes (8 sub-blocks per default 128 KiB chunk).
pub const DEFAULT_RESTART_INTERVAL: usize = 16 * 1024;
/// Serialized size of one restart point (`bit_pos` + `out_off`).
pub(crate) const RESTART_ENTRY_LEN: usize = 16;

/// FNV-1a 64-bit running hash (offset basis seed). Guards the v2
/// restart section: every input byte both XORs into and multiplies the
/// state, so any single-byte change yields a different digest.
pub(crate) const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
/// Fold `bytes` into an FNV-1a 64 `state` (seed with [`FNV_OFFSET`]).
pub(crate) fn fnv1a64(mut state: u64, bytes: &[u8]) -> u64 {
    for &b in bytes {
        state ^= b as u64;
        state = state.wrapping_mul(0x100_0000_01b3);
    }
    state
}

/// Index entry for one compressed chunk.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ChunkEntry {
    /// Offset of the chunk within the payload section.
    pub comp_off: u64,
    /// Compressed length in bytes.
    pub comp_len: u64,
    /// Uncompressed length in bytes (== chunk_size except the tail chunk).
    pub uncomp_len: u64,
}

/// A parsed (or freshly built) container.
#[derive(Debug, Clone)]
pub struct Container {
    /// Codec every chunk was compressed with (for a mixed v3 container:
    /// chunk 0's codec — use [`Container::chunk_codec`] instead).
    pub codec: CodecKind,
    /// Nominal uncompressed chunk size.
    pub chunk_size: usize,
    /// Total uncompressed length.
    pub total_uncompressed: u64,
    /// Per-chunk index.
    pub index: Vec<ChunkEntry>,
    /// Per-chunk restart tables (parallel to `index`; empty for v1
    /// files or chunks too small for a sub-block boundary).
    pub restarts: Vec<Vec<RestartPoint>>,
    /// Per-chunk codecs (parallel to `index`) for mixed v3 containers;
    /// empty for uniform containers, where every chunk uses `codec`.
    pub chunk_codecs: Vec<CodecKind>,
    /// Per-chunk CRC-32C of the *uncompressed* bytes (parallel to
    /// `index`). Non-empty for v4 containers — decode paths verify
    /// against it; empty for v1–v3, where no content verification is
    /// possible.
    pub checksums: Vec<u32>,
    /// Concatenated compressed chunk payloads.
    pub payload: Vec<u8>,
}

impl Container {
    /// Compress `data` into a container with `chunk_size`-byte chunks,
    /// recording restart points every [`DEFAULT_RESTART_INTERVAL`]
    /// output bytes.
    pub fn compress(data: &[u8], codec: CodecKind, chunk_size: usize) -> Result<Container> {
        Self::compress_with_restarts(data, codec, chunk_size, DEFAULT_RESTART_INTERVAL)
    }

    /// Compress with an explicit restart interval (`0` disables restart
    /// points; chunks no larger than the interval get none either way).
    pub fn compress_with_restarts(
        data: &[u8],
        codec: CodecKind,
        chunk_size: usize,
        restart_interval: usize,
    ) -> Result<Container> {
        if chunk_size == 0 {
            return Err(invalid("chunk_size must be > 0"));
        }
        let mut index = Vec::new();
        let mut restarts = Vec::new();
        let mut checksums = Vec::new();
        let mut payload = Vec::new();
        for chunk in data.chunks(chunk_size) {
            let (comp, points) = compress_chunk_restarts(codec, chunk, restart_interval)?;
            index.push(ChunkEntry {
                comp_off: payload.len() as u64,
                comp_len: comp.len() as u64,
                uncomp_len: chunk.len() as u64,
            });
            restarts.push(points);
            checksums.push(crc32c(chunk));
            payload.extend_from_slice(&comp);
        }
        Ok(Container {
            codec,
            chunk_size,
            total_uncompressed: data.len() as u64,
            index,
            restarts,
            chunk_codecs: Vec::new(),
            checksums,
            payload,
        })
    }

    /// Compress `data` picking the best codec for every chunk (the
    /// `codag pack --codec auto` path), recording restart points every
    /// [`DEFAULT_RESTART_INTERVAL`] output bytes.
    pub fn compress_auto(data: &[u8], chunk_size: usize) -> Result<Container> {
        Self::compress_auto_with_restarts(data, chunk_size, DEFAULT_RESTART_INTERVAL)
    }

    /// Per-chunk codec selection with an explicit restart interval:
    /// every registered codec trial-compresses the first
    /// [`AUTO_SAMPLE_BYTES`] of each chunk and the strictly smallest
    /// output wins (ties break toward registry order). When every chunk
    /// picks the same winner the result is a plain uniform container —
    /// byte-identical to a forced `--codec <winner>` pack.
    pub fn compress_auto_with_restarts(
        data: &[u8],
        chunk_size: usize,
        restart_interval: usize,
    ) -> Result<Container> {
        if chunk_size == 0 {
            return Err(invalid("chunk_size must be > 0"));
        }
        let mut index = Vec::new();
        let mut restarts = Vec::new();
        let mut chunk_codecs = Vec::new();
        let mut checksums = Vec::new();
        let mut payload = Vec::new();
        for chunk in data.chunks(chunk_size) {
            let kind = select_codec(chunk)?;
            let (comp, points) = compress_chunk_restarts(kind, chunk, restart_interval)?;
            index.push(ChunkEntry {
                comp_off: payload.len() as u64,
                comp_len: comp.len() as u64,
                uncomp_len: chunk.len() as u64,
            });
            restarts.push(points);
            chunk_codecs.push(kind);
            checksums.push(crc32c(chunk));
            payload.extend_from_slice(&comp);
        }
        let codec = chunk_codecs.first().copied().unwrap_or(CodecKind::Deflate);
        if chunk_codecs.iter().all(|&k| k == codec) {
            chunk_codecs.clear();
        }
        Ok(Container {
            codec,
            chunk_size,
            total_uncompressed: data.len() as u64,
            index,
            restarts,
            chunk_codecs,
            checksums,
            payload,
        })
    }

    /// The codec chunk `i` was compressed with (`codec` for uniform
    /// containers).
    pub fn chunk_codec(&self, i: usize) -> CodecKind {
        self.chunk_codecs.get(i).copied().unwrap_or(self.codec)
    }

    /// True when chunks disagree on codec (serializes as v3).
    pub fn is_mixed(&self) -> bool {
        self.chunk_codecs.iter().any(|&k| k != self.codec)
    }

    /// The restart table of chunk `i` (empty when the chunk has no
    /// recorded sub-block boundaries).
    pub fn restart_table(&self, i: usize) -> &[RestartPoint] {
        self.restarts.get(i).map(Vec::as_slice).unwrap_or(&[])
    }

    /// The packed CRC-32C of chunk `i`'s uncompressed bytes, when this
    /// container carries content checksums (v4; `None` for v1–v3).
    pub fn chunk_checksum(&self, i: usize) -> Option<u32> {
        self.checksums.get(i).copied()
    }

    /// Verify `out` (the decoded bytes of chunk `i`) against the packed
    /// content checksum; a no-op for containers without checksums. The
    /// shared gate behind every decode path — serial, split-stitch
    /// (called once over the stitched extent), and file-backed.
    pub(crate) fn verify_chunk_content(
        checksums: &[u32],
        i: usize,
        out: &[u8],
    ) -> Result<()> {
        let Some(&want) = checksums.get(i) else { return Ok(()) };
        let got = crc32c(out);
        if got != want {
            return Err(Error::ChecksumMismatch(format!(
                "chunk {i}: decoded content crc32c {got:08x}, packed {want:08x}"
            )));
        }
        Ok(())
    }

    /// Number of chunks.
    pub fn n_chunks(&self) -> usize {
        self.index.len()
    }

    /// Compressed payload size in bytes (excluding header/index).
    pub fn compressed_len(&self) -> usize {
        self.payload.len()
    }

    /// Compression ratio as the paper reports it:
    /// compressed bytes / uncompressed bytes (smaller is better; >1 means
    /// the encoding expanded the data, e.g. TPT under RLE v1).
    pub fn compression_ratio(&self) -> f64 {
        if self.total_uncompressed == 0 {
            return 1.0;
        }
        self.payload.len() as f64 / self.total_uncompressed as f64
    }

    /// Borrow the compressed bytes of chunk `i`.
    pub fn chunk_bytes(&self, i: usize) -> Result<&[u8]> {
        let e = self.index.get(i).ok_or_else(|| invalid(format!("chunk {i} out of range")))?;
        let lo = e.comp_off as usize;
        let hi = lo + e.comp_len as usize;
        self.payload
            .get(lo..hi)
            .ok_or_else(|| corrupt(format!("chunk {i} index out of payload bounds")))
    }

    /// Decompress a single chunk.
    pub fn decompress_chunk(&self, i: usize) -> Result<Vec<u8>> {
        let mut out = Vec::new();
        self.decompress_chunk_into(i, &mut out)?;
        Ok(out)
    }

    /// Decompress chunk `i` into a caller-owned buffer (cleared first),
    /// reusing its capacity — the steady-state server path: workers
    /// decode every request into one long-lived scratch buffer instead
    /// of allocating a fresh `Vec` per chunk (DESIGN.md §7).
    ///
    /// On error the buffer contents are unspecified (cleared or
    /// partially decoded) but the buffer itself remains reusable.
    pub fn decompress_chunk_into(&self, i: usize, out: &mut Vec<u8>) -> Result<()> {
        let e = self.index[i];
        let bytes = self.chunk_bytes(i)?;
        out.clear();
        out.reserve(e.uncomp_len as usize);
        let mut sink = crate::decomp::ByteSink { out: std::mem::take(out) };
        let decoded = crate::codecs::decode_into(self.chunk_codec(i), bytes, &mut sink);
        *out = sink.into_bytes();
        decoded?;
        if out.len() != e.uncomp_len as usize {
            return Err(corrupt(format!(
                "chunk {i}: decompressed {} bytes, index says {}",
                out.len(),
                e.uncomp_len
            )));
        }
        Self::verify_chunk_content(&self.checksums, i, out)
    }

    /// Decompress every chunk sequentially (correctness reference path;
    /// the parallel engines live in [`crate::coordinator`]).
    pub fn decompress_all(&self) -> Result<Vec<u8>> {
        let mut out = Vec::with_capacity(self.total_uncompressed as usize);
        for i in 0..self.n_chunks() {
            out.extend_from_slice(&self.decompress_chunk(i)?);
        }
        Ok(out)
    }

    /// Serialize to bytes. Containers carrying content checksums (every
    /// fresh compress) write v4; checksum-less containers (parsed from
    /// old files) keep their legacy shape — v2 uniform / v3 mixed — so
    /// parse → serialize is byte-identical at every version.
    pub fn to_bytes(&self) -> Vec<u8> {
        let has_sums = !self.checksums.is_empty();
        let mixed = self.is_mixed();
        let version = if has_sums {
            VERSION_CHECKSUM
        } else if mixed {
            VERSION_MIXED
        } else {
            VERSION
        };
        let mut out = Vec::with_capacity(48 + self.index.len() * 24 + self.payload.len());
        out.extend_from_slice(&MAGIC.to_le_bytes());
        out.extend_from_slice(&version.to_le_bytes());
        out.extend_from_slice(&self.chunk_codec(0).0.to_le_bytes());
        out.extend_from_slice(&(self.chunk_size as u64).to_le_bytes());
        out.extend_from_slice(&self.total_uncompressed.to_le_bytes());
        out.extend_from_slice(&(self.index.len() as u64).to_le_bytes());
        for e in &self.index {
            out.extend_from_slice(&e.comp_off.to_le_bytes());
            out.extend_from_slice(&e.comp_len.to_le_bytes());
            out.extend_from_slice(&e.uncomp_len.to_le_bytes());
        }
        // Restart section: one table per chunk (a missing tail table —
        // e.g. a hand-built struct — serializes as zero restarts), then
        // the FNV-1a guard over every section byte.
        let section_start = out.len();
        for i in 0..self.index.len() {
            let table = self.restart_table(i);
            out.extend_from_slice(&(table.len() as u32).to_le_bytes());
            for p in table {
                out.extend_from_slice(&p.bit_pos.to_le_bytes());
                out.extend_from_slice(&p.out_off.to_le_bytes());
            }
        }
        let sum = fnv1a64(FNV_OFFSET, &out[section_start..]);
        out.extend_from_slice(&sum.to_le_bytes());
        // Codec section: one wire id per chunk, FNV-guarded like the
        // restart section so a flipped id surfaces at parse time. v3
        // writes it only when mixed; v4 always (uniform files repeat
        // the header codec, which the parser collapses back).
        if mixed || has_sums {
            let codec_start = out.len();
            for i in 0..self.index.len() {
                out.extend_from_slice(&self.chunk_codec(i).0.to_le_bytes());
            }
            let sum = fnv1a64(FNV_OFFSET, &out[codec_start..]);
            out.extend_from_slice(&sum.to_le_bytes());
        }
        // v4 content checksum section: per-chunk CRC-32C of the
        // uncompressed bytes (a missing tail entry — hand-built struct —
        // serializes as 0, like a missing restart table), FNV-guarded,
        // then the whole-meta CRC-32C over every byte written so far.
        if has_sums {
            let sum_start = out.len();
            for i in 0..self.index.len() {
                let s = self.checksums.get(i).copied().unwrap_or(0);
                out.extend_from_slice(&s.to_le_bytes());
            }
            let sum = fnv1a64(FNV_OFFSET, &out[sum_start..]);
            out.extend_from_slice(&sum.to_le_bytes());
            let meta = crc32c(&out);
            out.extend_from_slice(&meta.to_le_bytes());
        }
        out.extend_from_slice(&self.payload);
        out
    }

    /// Parse a container from bytes.
    pub fn from_bytes(data: &[u8]) -> Result<Container> {
        let mut pos = 0usize;
        let take_u32 = |data: &[u8], pos: &mut usize| -> Result<u32> {
            let b = data.get(*pos..*pos + 4).ok_or_else(|| corrupt("container: truncated header"))?;
            *pos += 4;
            Ok(u32::from_le_bytes(b.try_into().unwrap()))
        };
        let magic = take_u32(data, &mut pos)?;
        if magic != MAGIC {
            return Err(corrupt(format!("bad magic 0x{magic:08X}")));
        }
        let version = take_u32(data, &mut pos)?;
        if !(VERSION_V1..=VERSION_CHECKSUM).contains(&version) {
            return Err(corrupt(format!("unsupported version {version}")));
        }
        let codec_raw = take_u32(data, &mut pos)?;
        let codec = CodecKind::from_u32(codec_raw).ok_or(Error::UnknownCodec(codec_raw))?;
        let take_u64 = |data: &[u8], pos: &mut usize| -> Result<u64> {
            let b = data.get(*pos..*pos + 8).ok_or_else(|| corrupt("container: truncated header"))?;
            *pos += 8;
            Ok(u64::from_le_bytes(b.try_into().unwrap()))
        };
        let chunk_size = take_u64(data, &mut pos)? as usize;
        let total_uncompressed = take_u64(data, &mut pos)?;
        let n_chunks = take_u64(data, &mut pos)? as usize;
        // Sanity cap: the index must fit in the remaining bytes.
        if n_chunks.saturating_mul(24) > data.len().saturating_sub(pos) {
            return Err(corrupt("container: index larger than file"));
        }
        let mut index = Vec::with_capacity(n_chunks);
        for _ in 0..n_chunks {
            index.push(ChunkEntry {
                comp_off: take_u64(data, &mut pos)?,
                comp_len: take_u64(data, &mut pos)?,
                uncomp_len: take_u64(data, &mut pos)?,
            });
        }
        // v2: restart section between index and payload, FNV-guarded.
        let restarts = if version == VERSION_V1 {
            vec![Vec::new(); n_chunks]
        } else {
            let section_start = pos;
            let mut restarts = Vec::with_capacity(n_chunks);
            for i in 0..n_chunks {
                let b = data
                    .get(pos..pos + 4)
                    .ok_or_else(|| corrupt("container: truncated restart section"))?;
                pos += 4;
                let count = u32::from_le_bytes(b.try_into().unwrap()) as usize;
                // Alloc cap (same idea as the index cap): the table must
                // fit in the remaining bytes before reserving for it.
                if count.saturating_mul(RESTART_ENTRY_LEN) > data.len().saturating_sub(pos) {
                    return Err(corrupt(format!(
                        "container: chunk {i} restart table larger than file"
                    )));
                }
                let mut table = Vec::with_capacity(count);
                for _ in 0..count {
                    table.push(RestartPoint {
                        bit_pos: take_u64(data, &mut pos)?,
                        out_off: take_u64(data, &mut pos)?,
                    });
                }
                restarts.push(table);
            }
            let sum = fnv1a64(FNV_OFFSET, &data[section_start..pos]);
            let stored = take_u64(data, &mut pos)
                .map_err(|_| corrupt("container: truncated restart checksum"))?;
            if sum != stored {
                return Err(corrupt(format!(
                    "container: restart section checksum mismatch \
                     (computed {sum:016x}, stored {stored:016x})"
                )));
            }
            restarts
        };
        // v3/v4: per-chunk codec section, FNV-guarded. Checksum first,
        // so bit rot reads as Corrupt; only a *cleanly stored* id the
        // registry does not know becomes the typed UnknownCodec.
        let chunk_codecs = if version == VERSION_MIXED || version == VERSION_CHECKSUM {
            let section_start = pos;
            let mut ids = Vec::with_capacity(n_chunks);
            for _ in 0..n_chunks {
                ids.push(
                    take_u32(data, &mut pos)
                        .map_err(|_| corrupt("container: truncated codec section"))?,
                );
            }
            let sum = fnv1a64(FNV_OFFSET, &data[section_start..pos]);
            let stored = take_u64(data, &mut pos)
                .map_err(|_| corrupt("container: truncated codec checksum"))?;
            if sum != stored {
                return Err(corrupt(format!(
                    "container: codec section checksum mismatch \
                     (computed {sum:016x}, stored {stored:016x})"
                )));
            }
            let mut codecs = Vec::with_capacity(n_chunks);
            for id in ids {
                codecs.push(CodecKind::from_u32(id).ok_or(Error::UnknownCodec(id))?);
            }
            if n_chunks > 0 && codecs.first() != Some(&codec) {
                return Err(corrupt(
                    "container: header codec disagrees with chunk 0's codec",
                ));
            }
            // v4 writes the section even for uniform files; collapse it
            // back so `is_mixed()` and re-serialization stay faithful.
            if codecs.iter().all(|&k| k == codec) {
                codecs.clear();
            }
            codecs
        } else {
            Vec::new()
        };
        // v4: content checksum section (per-chunk CRC-32C of the
        // uncompressed bytes, FNV-guarded), then the whole-meta CRC-32C
        // over every byte before it — verified before trusting any of
        // the metadata parsed above.
        let checksums = if version == VERSION_CHECKSUM {
            let section_start = pos;
            let mut sums = Vec::with_capacity(n_chunks);
            for _ in 0..n_chunks {
                sums.push(
                    take_u32(data, &mut pos)
                        .map_err(|_| corrupt("container: truncated checksum section"))?,
                );
            }
            let sum = fnv1a64(FNV_OFFSET, &data[section_start..pos]);
            let stored = take_u64(data, &mut pos)
                .map_err(|_| corrupt("container: truncated checksum guard"))?;
            if sum != stored {
                return Err(corrupt(format!(
                    "container: checksum section guard mismatch \
                     (computed {sum:016x}, stored {stored:016x})"
                )));
            }
            let meta = crc32c(&data[..pos]);
            let stored = data
                .get(pos..pos + 4)
                .map(|b| u32::from_le_bytes(b.try_into().unwrap()))
                .ok_or_else(|| corrupt("container: truncated meta checksum"))?;
            pos += 4;
            if meta != stored {
                return Err(corrupt(format!(
                    "container: metadata crc32c mismatch \
                     (computed {meta:08x}, stored {stored:08x})"
                )));
            }
            sums
        } else {
            Vec::new()
        };
        let payload = data[pos..].to_vec();
        // Validate index bounds against payload.
        for (i, e) in index.iter().enumerate() {
            let end = e.comp_off.checked_add(e.comp_len).ok_or_else(|| corrupt("index overflow"))?;
            if end as usize > payload.len() {
                return Err(corrupt(format!("chunk {i} extends past payload")));
            }
        }
        // Structural validation of restart tables: monotone, in-range
        // boundaries. The checksum catches bit rot; this catches a
        // well-formed-but-lying table before it reaches the stitcher.
        for (i, (table, e)) in restarts.iter().zip(&index).enumerate() {
            validate_restart_table(table, e).map_err(|err| {
                corrupt(format!("container: chunk {i} restart table invalid: {err}"))
            })?;
        }
        Ok(Container {
            codec,
            chunk_size,
            total_uncompressed,
            index,
            restarts,
            chunk_codecs,
            checksums,
            payload,
        })
    }
}

/// Pick the codec for one chunk: every registered codec trial-compresses
/// the first [`AUTO_SAMPLE_BYTES`] of it and the strictly smallest
/// output wins; a tie keeps the earlier registry slot. A codec that
/// cannot encode the sample (none today) simply drops out of the trial.
fn select_codec(chunk: &[u8]) -> Result<CodecKind> {
    let sample = &chunk[..chunk.len().min(AUTO_SAMPLE_BYTES)];
    let mut best: Option<(usize, CodecKind)> = None;
    for c in CodecRegistry::codecs() {
        let Ok(comp) = c.compress_auto(sample) else { continue };
        if best.map_or(true, |(len, _)| comp.len() < len) {
            best = Some((comp.len(), CodecKind(c.wire_id())));
        }
    }
    best.map(|(_, kind)| kind).ok_or_else(|| invalid("no registered codec accepted the chunk"))
}

/// Check a restart table against its chunk's index entry: strictly
/// increasing `bit_pos` and `out_off`, offsets inside the chunk (never
/// 0 or ≥ `uncomp_len` — the implicit start point is not stored), bit
/// positions inside the compressed stream.
pub(crate) fn validate_restart_table(table: &[RestartPoint], e: &ChunkEntry) -> Result<()> {
    let mut prev_bit = 0u64;
    let mut prev_off = 0u64;
    for p in table {
        if p.bit_pos <= prev_bit {
            return Err(corrupt(format!("bit_pos {} not increasing", p.bit_pos)));
        }
        if p.bit_pos > e.comp_len.saturating_mul(8) {
            return Err(corrupt(format!(
                "bit_pos {} outside compressed stream ({} bytes)",
                p.bit_pos, e.comp_len
            )));
        }
        if p.out_off <= prev_off || p.out_off >= e.uncomp_len {
            return Err(corrupt(format!(
                "out_off {} outside chunk ({} bytes) or not increasing",
                p.out_off, e.uncomp_len
            )));
        }
        prev_bit = p.bit_pos;
        prev_off = p.out_off;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_data() -> Vec<u8> {
        // Runs + literals so every codec has something to chew on.
        let mut v = Vec::new();
        for i in 0..2000u32 {
            let b = (i % 7) as u8;
            for _ in 0..(i % 13 + 1) {
                v.push(b);
            }
        }
        v
    }

    #[test]
    fn roundtrip_all_codecs() {
        let data = sample_data();
        for codec in CodecKind::all() {
            let c = Container::compress(&data, codec, 4096).unwrap();
            assert_eq!(c.decompress_all().unwrap(), data, "{codec:?}");
        }
    }

    #[test]
    fn serialization_roundtrip() {
        let data = sample_data();
        let c = Container::compress(&data, CodecKind::Deflate, 4096).unwrap();
        let bytes = c.to_bytes();
        let c2 = Container::from_bytes(&bytes).unwrap();
        assert_eq!(c2.codec, CodecKind::Deflate);
        assert_eq!(c2.n_chunks(), c.n_chunks());
        assert_eq!(c2.decompress_all().unwrap(), data);
    }

    #[test]
    fn tail_chunk_is_short() {
        let data = vec![42u8; 10_000];
        let c = Container::compress(&data, CodecKind::RleV1, 4096).unwrap();
        assert_eq!(c.n_chunks(), 3);
        assert_eq!(c.index[2].uncomp_len, 10_000 - 2 * 4096);
        assert_eq!(c.decompress_all().unwrap(), data);
    }

    #[test]
    fn empty_input() {
        let c = Container::compress(&[], CodecKind::Deflate, 4096).unwrap();
        assert_eq!(c.n_chunks(), 0);
        assert_eq!(c.decompress_all().unwrap(), Vec::<u8>::new());
        let c2 = Container::from_bytes(&c.to_bytes()).unwrap();
        assert_eq!(c2.total_uncompressed, 0);
    }

    #[test]
    fn bad_magic_rejected() {
        let data = vec![0u8; 64];
        assert!(Container::from_bytes(&data).is_err());
    }

    #[test]
    fn truncated_index_rejected() {
        let data = sample_data();
        let c = Container::compress(&data, CodecKind::RleV1, 4096).unwrap();
        let bytes = c.to_bytes();
        assert!(Container::from_bytes(&bytes[..40]).is_err());
    }

    #[test]
    fn corrupt_index_bounds_rejected() {
        let data = sample_data();
        let c = Container::compress(&data, CodecKind::RleV1, 4096).unwrap();
        let mut bytes = c.to_bytes();
        // comp_len of chunk 0 lives at offset 36+8; blow it up.
        let off = 36 + 8;
        bytes[off..off + 8].copy_from_slice(&u64::MAX.to_le_bytes());
        assert!(Container::from_bytes(&bytes).is_err());
    }

    #[test]
    fn restart_tables_survive_serialization() {
        let data = sample_data();
        for codec in CodecKind::all() {
            let c = Container::compress_with_restarts(&data, codec, 8192, 512).unwrap();
            assert!(
                c.restarts.iter().any(|t| !t.is_empty()),
                "{codec:?}: expected restart points at interval 512"
            );
            let c2 = Container::from_bytes(&c.to_bytes()).unwrap();
            assert_eq!(c2.restarts, c.restarts, "{codec:?}");
            assert_eq!(c2.decompress_all().unwrap(), data, "{codec:?}");
        }
    }

    #[test]
    fn zero_interval_disables_restarts() {
        let data = sample_data();
        let c = Container::compress_with_restarts(&data, CodecKind::RleV2, 4096, 0).unwrap();
        assert!(c.restarts.iter().all(Vec::is_empty));
        assert_eq!(c.decompress_all().unwrap(), data);
    }

    /// Rewrite a serialized container as version 1: keep header + index,
    /// drop the restart section, patch the version field.
    fn as_v1_bytes(c: &Container) -> Vec<u8> {
        let mut out = c.to_bytes()[..36 + c.index.len() * 24].to_vec();
        out[4..8].copy_from_slice(&VERSION_V1.to_le_bytes());
        out.extend_from_slice(&c.payload);
        out
    }

    #[test]
    fn v1_container_parses_with_empty_restarts() {
        let data = sample_data();
        let c = Container::compress(&data, CodecKind::RleV2, 4096).unwrap();
        let v1 = Container::from_bytes(&as_v1_bytes(&c)).unwrap();
        assert_eq!(v1.restarts.len(), c.n_chunks());
        assert!(v1.restarts.iter().all(Vec::is_empty));
        assert_eq!(v1.decompress_all().unwrap(), data);
    }

    #[test]
    fn corrupt_restart_section_rejected() {
        let data = sample_data();
        let c = Container::compress_with_restarts(&data, CodecKind::RleV1, 4096, 256).unwrap();
        let bytes = c.to_bytes();
        let section_start = 36 + c.index.len() * 24;
        let section_len: usize =
            c.restarts.iter().map(|t| 4 + t.len() * RESTART_ENTRY_LEN).sum::<usize>() + 8;
        // Every byte of the restart section (counts, entries, checksum)
        // must be load-bearing: flipping any one of them fails parse.
        for off in section_start..section_start + section_len {
            let mut bad = bytes.clone();
            bad[off] ^= 0x01;
            assert!(
                Container::from_bytes(&bad).is_err(),
                "flip at restart-section byte {off} went undetected"
            );
        }
    }

    #[test]
    fn truncated_restart_section_rejected() {
        let data = sample_data();
        let c = Container::compress_with_restarts(&data, CodecKind::RleV1, 4096, 256).unwrap();
        let bytes = c.to_bytes();
        let section_start = 36 + c.index.len() * 24;
        for cut in [section_start, section_start + 2, section_start + 11] {
            assert!(Container::from_bytes(&bytes[..cut]).is_err());
        }
    }

    #[test]
    fn doctored_restart_table_rejected() {
        let data = sample_data();
        let c = Container::compress_with_restarts(&data, CodecKind::RleV2, 4096, 256).unwrap();
        let i = c.restarts.iter().position(|t| t.len() >= 2).unwrap();
        // Re-serialize with a structurally invalid (but checksummed)
        // table: out of order, zero, and out-of-range boundaries.
        let break_table = |f: &dyn Fn(&mut Vec<RestartPoint>)| {
            let mut bad = c.clone();
            f(&mut bad.restarts[i]);
            Container::from_bytes(&bad.to_bytes())
        };
        assert!(break_table(&|t| t.swap(0, 1)).is_err());
        assert!(break_table(&|t| t[0].bit_pos = 0).is_err());
        assert!(break_table(&|t| t[0].out_off = 0).is_err());
        assert!(break_table(&|t| t[1].out_off = u64::MAX).is_err());
        assert!(break_table(&|t| t[1].bit_pos = u64::MAX).is_err());
    }

    /// Two chunks forced onto different codecs — the deterministic way
    /// to exercise the mixed v3 path regardless of what `--codec auto`
    /// would pick.
    fn mixed_sample() -> (Vec<u8>, Container) {
        let data = sample_data();
        let chunk_size = 4096usize;
        let kinds = [CodecKind::RleV1, CodecKind::Deflate];
        let mut index = Vec::new();
        let mut restarts = Vec::new();
        let mut chunk_codecs = Vec::new();
        let mut checksums = Vec::new();
        let mut payload = Vec::new();
        for (i, chunk) in data.chunks(chunk_size).enumerate() {
            let kind = kinds[i % kinds.len()];
            let (comp, points) = compress_chunk_restarts(kind, chunk, 512).unwrap();
            index.push(ChunkEntry {
                comp_off: payload.len() as u64,
                comp_len: comp.len() as u64,
                uncomp_len: chunk.len() as u64,
            });
            restarts.push(points);
            chunk_codecs.push(kind);
            checksums.push(crc32c(chunk));
            payload.extend_from_slice(&comp);
        }
        let c = Container {
            codec: chunk_codecs[0],
            chunk_size,
            total_uncompressed: data.len() as u64,
            index,
            restarts,
            chunk_codecs,
            checksums,
            payload,
        };
        (data, c)
    }

    /// The same container as a legacy (pre-integrity) pack would have
    /// produced: checksums dropped, so `to_bytes` emits v2/v3.
    fn without_checksums(c: &Container) -> Container {
        let mut c = c.clone();
        c.checksums.clear();
        c
    }

    #[test]
    fn mixed_container_serializes_as_v3_and_roundtrips() {
        let (data, c) = mixed_sample();
        let c = without_checksums(&c);
        assert!(c.is_mixed());
        let bytes = c.to_bytes();
        assert_eq!(u32::from_le_bytes(bytes[4..8].try_into().unwrap()), VERSION_MIXED);
        // Header codec field carries chunk 0's codec.
        assert_eq!(u32::from_le_bytes(bytes[8..12].try_into().unwrap()), c.chunk_codec(0).0);
        let c2 = Container::from_bytes(&bytes).unwrap();
        assert_eq!(c2.chunk_codecs, c.chunk_codecs);
        assert_eq!(c2.restarts, c.restarts);
        assert!(c2.checksums.is_empty());
        assert_eq!(c2.decompress_all().unwrap(), data);
    }

    #[test]
    fn mixed_container_with_checksums_serializes_as_v4_and_roundtrips() {
        let (data, c) = mixed_sample();
        assert!(c.is_mixed());
        let bytes = c.to_bytes();
        assert_eq!(u32::from_le_bytes(bytes[4..8].try_into().unwrap()), VERSION_CHECKSUM);
        let c2 = Container::from_bytes(&bytes).unwrap();
        assert_eq!(c2.chunk_codecs, c.chunk_codecs);
        assert_eq!(c2.restarts, c.restarts);
        assert_eq!(c2.checksums, c.checksums);
        assert_eq!(c2.decompress_all().unwrap(), data);
        // Parse → serialize is byte-identical.
        assert_eq!(c2.to_bytes(), bytes);
    }

    #[test]
    fn codec_section_byte_flips_detected() {
        let (_, c) = mixed_sample();
        let bytes = c.to_bytes();
        let restart_len: usize =
            c.restarts.iter().map(|t| 4 + t.len() * RESTART_ENTRY_LEN).sum::<usize>() + 8;
        let codec_start = 36 + c.index.len() * 24 + restart_len;
        let codec_len = c.n_chunks() * 4 + 8;
        for off in codec_start..codec_start + codec_len {
            let mut bad = bytes.clone();
            bad[off] ^= 0x01;
            assert!(
                Container::from_bytes(&bad).is_err(),
                "flip at codec-section byte {off} went undetected"
            );
        }
        for cut in [codec_start, codec_start + 2, codec_start + codec_len - 1] {
            assert!(Container::from_bytes(&bytes[..cut]).is_err());
        }
    }

    #[test]
    fn unknown_codec_ids_are_typed() {
        let data = sample_data();
        let c = Container::compress(&data, CodecKind::RleV2, 4096).unwrap();
        let mut bytes = c.to_bytes();
        bytes[8..12].copy_from_slice(&0x7Fu32.to_le_bytes());
        assert_eq!(Container::from_bytes(&bytes).err(), Some(Error::UnknownCodec(0x7F)));
        // A cleanly checksummed v3 codec section with an unregistered id
        // is also the typed error, not a generic parse failure.
        let (_, mut mixed) = mixed_sample();
        mixed.chunk_codecs[1] = CodecKind(0x7F);
        assert_eq!(
            Container::from_bytes(&mixed.to_bytes()).err(),
            Some(Error::UnknownCodec(0x7F))
        );
    }

    #[test]
    fn auto_pack_roundtrips_and_never_loses_to_forced() {
        let mut data = Vec::new();
        // Chunk-sized stretches with very different character so the
        // trial has real choices to make: long runs, structured text,
        // incompressible noise.
        data.extend(std::iter::repeat(7u8).take(4096));
        data.extend("the quick brown fox jumps over the lazy dog. ".bytes().cycle().take(4096));
        let mut x = 99u64;
        data.extend((0..4096).map(|_| {
            x = x.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            (x >> 56) as u8
        }));
        // Interval 0 so trial size == final size: the auto payload can
        // then never exceed any forced single-codec payload.
        let auto = Container::compress_auto_with_restarts(&data, 4096, 0).unwrap();
        assert_eq!(auto.decompress_all().unwrap(), data);
        let reparsed = Container::from_bytes(&auto.to_bytes()).unwrap();
        assert_eq!(reparsed.decompress_all().unwrap(), data);
        assert_eq!(reparsed.chunk_codecs, auto.chunk_codecs);
        for kind in CodecKind::all() {
            let forced = Container::compress_with_restarts(&data, kind, 4096, 0).unwrap();
            assert!(
                auto.compressed_len() <= forced.compressed_len(),
                "auto {} > forced {} under {}",
                auto.compressed_len(),
                forced.compressed_len(),
                kind.name()
            );
        }
    }

    #[test]
    fn uniform_auto_pack_collapses_and_matches_forced() {
        // Every chunk is the same long run: one codec wins everywhere,
        // so the container must collapse to a uniform file (empty
        // chunk_codecs), byte-identical to forcing that codec. Both are
        // v4 now — fresh packs always carry content checksums.
        let data = vec![42u8; 16384];
        let auto = Container::compress_auto(&data, 4096).unwrap();
        assert!(auto.chunk_codecs.is_empty());
        assert!(!auto.is_mixed());
        let bytes = auto.to_bytes();
        assert_eq!(u32::from_le_bytes(bytes[4..8].try_into().unwrap()), VERSION_CHECKSUM);
        let forced = Container::compress(&data, auto.codec, 4096).unwrap();
        assert_eq!(bytes, forced.to_bytes());
        // Legacy shape: the same containers minus checksums still
        // collapse to plain v2, byte-identical to each other.
        let legacy = without_checksums(&auto).to_bytes();
        assert_eq!(u32::from_le_bytes(legacy[4..8].try_into().unwrap()), VERSION);
        assert_eq!(legacy, without_checksums(&forced).to_bytes());
    }

    #[test]
    fn v4_roundtrip_preserves_checksums_and_reserializes_identically() {
        let data = sample_data();
        for codec in CodecKind::all() {
            let c = Container::compress(&data, codec, 4096).unwrap();
            assert_eq!(c.checksums.len(), c.n_chunks(), "{codec:?}");
            for (i, chunk) in data.chunks(4096).enumerate() {
                assert_eq!(c.chunk_checksum(i), Some(crc32c(chunk)), "{codec:?} chunk {i}");
            }
            let bytes = c.to_bytes();
            assert_eq!(
                u32::from_le_bytes(bytes[4..8].try_into().unwrap()),
                VERSION_CHECKSUM,
                "{codec:?}"
            );
            let c2 = Container::from_bytes(&bytes).unwrap();
            assert_eq!(c2.checksums, c.checksums, "{codec:?}");
            assert!(c2.chunk_codecs.is_empty(), "{codec:?}: uniform must collapse");
            assert_eq!(c2.to_bytes(), bytes, "{codec:?}");
            assert_eq!(c2.decompress_all().unwrap(), data, "{codec:?}");
        }
    }

    #[test]
    fn legacy_v2_bytes_parse_with_checksums_absent() {
        let data = sample_data();
        let c = Container::compress(&data, CodecKind::RleV2, 4096).unwrap();
        let legacy = without_checksums(&c).to_bytes();
        assert_eq!(u32::from_le_bytes(legacy[4..8].try_into().unwrap()), VERSION);
        let parsed = Container::from_bytes(&legacy).unwrap();
        assert!(parsed.checksums.is_empty());
        assert!(parsed.chunk_checksum(0).is_none());
        // No checksums → no verification possible, but decode still works
        // and re-serialization keeps the legacy v2 shape byte-identically.
        assert_eq!(parsed.decompress_all().unwrap(), data);
        assert_eq!(parsed.to_bytes(), legacy);
    }

    #[test]
    fn v4_metadata_byte_flips_detected() {
        // The whole-meta CRC (plus the magic/version/codec/FNV guards in
        // front of it) makes every byte of the v4 metadata load-bearing:
        // flipping any single bit before the payload must fail parse.
        let data = sample_data();
        let c = Container::compress_with_restarts(&data, CodecKind::RleV1, 4096, 256).unwrap();
        let bytes = c.to_bytes();
        let payload_start = bytes.len() - c.payload.len();
        for off in 0..payload_start {
            let mut bad = bytes.clone();
            bad[off] ^= 0x01;
            assert!(
                Container::from_bytes(&bad).is_err(),
                "flip at metadata byte {off} went undetected"
            );
        }
    }

    #[test]
    fn v4_payload_byte_flips_never_yield_wrong_bytes() {
        // Payload bytes are outside the meta CRC (they are verified per
        // chunk at decode time): parse may succeed, but a decode that
        // returns Ok must return the *exact* packed bytes. A flip that
        // lands in format slack (bit-pack padding, an equivalent match
        // encoding) legitimately decodes to the identical payload — the
        // integrity contract is "never silently *wrong*", not "every
        // slack bit is load-bearing".
        let mut data = Vec::new();
        for i in 0..512u32 {
            data.extend_from_slice(&[(i % 5) as u8; 3]);
        }
        for codec in CodecKind::all() {
            let c = Container::compress(&data, codec, 512).unwrap();
            let bytes = c.to_bytes();
            let payload_start = bytes.len() - c.payload.len();
            for off in payload_start..bytes.len() {
                let mut bad = bytes.clone();
                bad[off] ^= 0x01;
                let Ok(parsed) = Container::from_bytes(&bad) else { continue };
                match parsed.decompress_all() {
                    Err(_) => {}
                    Ok(out) => assert_eq!(
                        out, data,
                        "{codec:?}: payload flip at byte {off} served wrong bytes"
                    ),
                }
            }
        }
    }

    #[test]
    fn checksum_mismatch_is_typed() {
        let data = sample_data();
        let mut c = Container::compress(&data, CodecKind::RleV2, 4096).unwrap();
        // Lie about chunk 0's content checksum (struct-level, so every
        // guard upstream of content verification stays valid).
        c.checksums[0] ^= 0xDEAD_BEEF;
        match c.decompress_chunk(0) {
            Err(Error::ChecksumMismatch(_)) => {}
            other => panic!("expected ChecksumMismatch, got {other:?}"),
        }
    }
}
