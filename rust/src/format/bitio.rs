//! Bit-granular readers and writers.
//!
//! Two conventions coexist in the codecs we reproduce:
//!
//! * **DEFLATE (RFC 1951)** packs bits LSB-first within each byte:
//!   [`LsbBitReader`] / [`LsbBitWriter`].
//! * **ORC RLE v2** packs values MSB-first / big-endian across bytes:
//!   [`MsbBitReader`] / [`MsbBitWriter`].
//!
//! Both readers operate over a borrowed `&[u8]` with an explicit cursor so
//! the CODAG `input_stream` abstraction (see [`crate::decomp`]) can wrap
//! them and account cache-line refills.

use crate::{corrupt, Result};

/// LSB-first bit reader (DEFLATE convention).
///
/// Maintains a 64-bit accumulator refilled from the byte stream; `fetch`
/// consumes bits, `peek` does not. Peeking past the end of the stream
/// returns zero bits (DEFLATE decoders rely on this to decode the final
/// code of a stream), but *consuming* past the end is an error.
#[derive(Debug, Clone)]
pub struct LsbBitReader<'a> {
    data: &'a [u8],
    /// Next byte index to load into the accumulator.
    pos: usize,
    /// Bit accumulator; lowest bit = next bit of the stream.
    acc: u64,
    /// Number of valid bits in `acc`.
    nbits: u32,
    /// Total bits consumed so far (for symbol-length statistics).
    consumed_bits: u64,
}

impl<'a> LsbBitReader<'a> {
    /// Create a reader over `data`.
    pub fn new(data: &'a [u8]) -> Self {
        LsbBitReader { data, pos: 0, acc: 0, nbits: 0, consumed_bits: 0 }
    }

    /// Total number of bits consumed so far.
    #[inline]
    pub fn consumed_bits(&self) -> u64 {
        self.consumed_bits
    }

    /// Byte offset of the next byte that would be loaded (coarse progress).
    #[inline]
    pub fn byte_pos(&self) -> usize {
        self.pos - (self.nbits as usize + 7) / 8
    }

    /// True when every bit has been consumed.
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.nbits == 0 && self.pos >= self.data.len()
    }

    #[inline]
    fn refill(&mut self) {
        while self.nbits <= 56 && self.pos < self.data.len() {
            self.acc |= (self.data[self.pos] as u64) << self.nbits;
            self.pos += 1;
            self.nbits += 8;
        }
    }

    /// Peek at the next `n` (≤ 57) bits without consuming them.
    /// Bits past the end of the stream read as zero.
    #[inline]
    pub fn peek_bits(&mut self, n: u32) -> u64 {
        debug_assert!(n <= 57);
        self.refill();
        self.acc & ((1u64 << n) - 1)
    }

    /// Consume and return the next `n` (≤ 57) bits.
    #[inline]
    pub fn fetch_bits(&mut self, n: u32) -> Result<u64> {
        debug_assert!(n <= 57);
        self.refill();
        if self.nbits < n {
            return Err(corrupt(format!(
                "bit stream exhausted: wanted {n} bits, {} available",
                self.nbits
            )));
        }
        let v = self.acc & ((1u64 << n) - 1);
        self.acc >>= n;
        self.nbits -= n;
        self.consumed_bits += n as u64;
        Ok(v)
    }

    /// Drop `n` bits that were previously peeked (must be available).
    #[inline]
    pub fn skip_bits(&mut self, n: u32) -> Result<()> {
        self.fetch_bits(n).map(|_| ())
    }

    /// Discard bits up to the next byte boundary (DEFLATE stored blocks).
    #[inline]
    pub fn align_byte(&mut self) {
        let drop = self.nbits % 8;
        self.acc >>= drop;
        self.nbits -= drop;
        self.consumed_bits += drop as u64;
    }

    /// Read `len` bytes after aligning to a byte boundary.
    pub fn read_aligned_bytes(&mut self, len: usize) -> Result<Vec<u8>> {
        self.align_byte();
        let mut out = Vec::with_capacity(len);
        for _ in 0..len {
            out.push(self.fetch_bits(8)? as u8);
        }
        Ok(out)
    }
}

/// LSB-first bit writer (DEFLATE convention).
#[derive(Debug, Default, Clone)]
pub struct LsbBitWriter {
    out: Vec<u8>,
    acc: u64,
    nbits: u32,
}

impl LsbBitWriter {
    /// Create an empty writer.
    pub fn new() -> Self {
        Self::default()
    }

    /// Append the low `n` (≤ 57) bits of `v`.
    #[inline]
    pub fn put_bits(&mut self, v: u64, n: u32) {
        debug_assert!(n <= 57);
        debug_assert!(n == 64 || v < (1u64 << n.max(1)) || n == 0);
        self.acc |= v << self.nbits;
        self.nbits += n;
        while self.nbits >= 8 {
            self.out.push((self.acc & 0xFF) as u8);
            self.acc >>= 8;
            self.nbits -= 8;
        }
    }

    /// Zero-pad to the next byte boundary.
    pub fn align_byte(&mut self) {
        if self.nbits > 0 {
            self.out.push((self.acc & 0xFF) as u8);
            self.acc = 0;
            self.nbits = 0;
        }
    }

    /// Append raw bytes (caller must be byte-aligned).
    pub fn put_aligned_bytes(&mut self, bytes: &[u8]) {
        debug_assert_eq!(self.nbits, 0, "put_aligned_bytes requires byte alignment");
        self.out.extend_from_slice(bytes);
    }

    /// Flush and return the underlying buffer.
    pub fn finish(mut self) -> Vec<u8> {
        self.align_byte();
        self.out
    }

    /// Bits written so far.
    pub fn bit_len(&self) -> u64 {
        self.out.len() as u64 * 8 + self.nbits as u64
    }
}

/// MSB-first (big-endian) bit reader — ORC RLE v2 convention.
///
/// Keeps a 64-bit accumulator so the common case (packed widths ≤ 56)
/// is a shift+mask instead of a per-byte loop (§Perf L3).
#[derive(Debug, Clone)]
pub struct MsbBitReader<'a> {
    data: &'a [u8],
    /// Next byte to load into the accumulator.
    pos: usize,
    /// Pending bits, right-aligned (the low `nbits` bits of `acc`).
    acc: u64,
    nbits: u32,
}

impl<'a> MsbBitReader<'a> {
    /// Create a reader over `data`.
    pub fn new(data: &'a [u8]) -> Self {
        MsbBitReader { data, pos: 0, acc: 0, nbits: 0 }
    }

    /// Byte offset of consumed input (rounded up if mid-byte).
    pub fn byte_pos(&self) -> usize {
        let consumed_bits = self.pos as u64 * 8 - self.nbits as u64;
        ((consumed_bits + 7) / 8) as usize
    }

    #[inline]
    fn refill(&mut self) {
        while self.nbits <= 56 && self.pos < self.data.len() {
            self.acc = (self.acc << 8) | self.data[self.pos] as u64;
            self.pos += 1;
            self.nbits += 8;
        }
    }

    /// Read one full byte (must be byte-aligned).
    pub fn read_byte(&mut self) -> Result<u8> {
        debug_assert_eq!(self.nbits % 8, 0);
        self.read_bits(8).map(|v| v as u8)
    }

    /// Read `n` (≤ 64) bits MSB-first.
    #[inline]
    pub fn read_bits(&mut self, n: u32) -> Result<u64> {
        debug_assert!(n <= 64);
        if n == 0 {
            return Ok(0);
        }
        self.refill();
        if n <= self.nbits {
            self.nbits -= n;
            let v = (self.acc >> self.nbits) & mask64(n);
            return Ok(v);
        }
        // Wide read (57..=64 bits) or end of stream.
        if self.pos >= self.data.len() {
            return Err(corrupt("msb reader: bit stream exhausted"));
        }
        let have = self.nbits;
        let hi = (self.acc & mask64(have)) << (n - have);
        self.acc = 0;
        self.nbits = 0;
        let lo = self.read_bits(n - have)?;
        Ok(hi | lo)
    }

    /// Skip to the next byte boundary.
    pub fn align_byte(&mut self) {
        let drop = self.nbits % 8;
        self.nbits -= drop;
    }
}

/// Low-`n` bit mask (n in 1..=64).
#[inline]
fn mask64(n: u32) -> u64 {
    if n >= 64 {
        u64::MAX
    } else {
        (1u64 << n) - 1
    }
}

/// MSB-first (big-endian) bit writer — ORC RLE v2 convention.
#[derive(Debug, Default, Clone)]
pub struct MsbBitWriter {
    out: Vec<u8>,
    cur: u8,
    /// Bits already used in `cur` (filled from the top).
    used: u32,
}

impl MsbBitWriter {
    /// Create an empty writer.
    pub fn new() -> Self {
        Self::default()
    }

    /// Append one full byte (must be byte-aligned).
    pub fn put_byte(&mut self, b: u8) {
        debug_assert_eq!(self.used, 0);
        self.out.push(b);
    }

    /// Append the low `n` (≤ 64) bits of `v`, MSB-first.
    pub fn put_bits(&mut self, v: u64, n: u32) {
        debug_assert!(n <= 64);
        let mut left = n;
        while left > 0 {
            let room = 8 - self.used;
            let take = left.min(room);
            let bits = ((v >> (left - take)) & ((1u64 << take) - 1)) as u8;
            self.cur |= bits << (room - take);
            self.used += take;
            if self.used == 8 {
                self.out.push(self.cur);
                self.cur = 0;
                self.used = 0;
            }
            left -= take;
        }
    }

    /// Zero-pad to a byte boundary.
    pub fn align_byte(&mut self) {
        if self.used > 0 {
            self.out.push(self.cur);
            self.cur = 0;
            self.used = 0;
        }
    }

    /// Flush and return the buffer.
    pub fn finish(mut self) -> Vec<u8> {
        self.align_byte();
        self.out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lsb_roundtrip_mixed_widths() {
        let mut w = LsbBitWriter::new();
        let fields: &[(u64, u32)] = &[(0b1, 1), (0b1011, 4), (0x3FF, 10), (0, 3), (0x1FFFF, 17)];
        for &(v, n) in fields {
            w.put_bits(v, n);
        }
        let bytes = w.finish();
        let mut r = LsbBitReader::new(&bytes);
        for &(v, n) in fields {
            assert_eq!(r.fetch_bits(n).unwrap(), v);
        }
    }

    #[test]
    fn lsb_peek_does_not_consume() {
        let mut w = LsbBitWriter::new();
        w.put_bits(0xAB, 8);
        w.put_bits(0xCD, 8);
        let bytes = w.finish();
        let mut r = LsbBitReader::new(&bytes);
        assert_eq!(r.peek_bits(8), 0xAB);
        assert_eq!(r.peek_bits(16), 0xCDAB);
        assert_eq!(r.fetch_bits(8).unwrap(), 0xAB);
        assert_eq!(r.fetch_bits(8).unwrap(), 0xCD);
    }

    #[test]
    fn lsb_peek_past_end_is_zero_but_fetch_errors() {
        let bytes = [0xFFu8];
        let mut r = LsbBitReader::new(&bytes);
        assert_eq!(r.peek_bits(16), 0x00FF);
        assert_eq!(r.fetch_bits(8).unwrap(), 0xFF);
        assert!(r.fetch_bits(1).is_err());
    }

    #[test]
    fn lsb_align_and_aligned_bytes() {
        let mut w = LsbBitWriter::new();
        w.put_bits(0b101, 3);
        w.align_byte();
        w.put_aligned_bytes(&[1, 2, 3]);
        let bytes = w.finish();
        let mut r = LsbBitReader::new(&bytes);
        assert_eq!(r.fetch_bits(3).unwrap(), 0b101);
        assert_eq!(r.read_aligned_bytes(3).unwrap(), vec![1, 2, 3]);
    }

    #[test]
    fn msb_roundtrip_mixed_widths() {
        let mut w = MsbBitWriter::new();
        let fields: &[(u64, u32)] = &[(0b101, 3), (0xFFFF, 16), (1, 1), (0x123456789A, 40)];
        for &(v, n) in fields {
            w.put_bits(v, n);
        }
        let bytes = w.finish();
        let mut r = MsbBitReader::new(&bytes);
        for &(v, n) in fields {
            assert_eq!(r.read_bits(n).unwrap(), v);
        }
    }

    #[test]
    fn msb_bigendian_byte_order() {
        // 0xABCD written as 16 bits must serialize as [0xAB, 0xCD].
        let mut w = MsbBitWriter::new();
        w.put_bits(0xABCD, 16);
        assert_eq!(w.finish(), vec![0xAB, 0xCD]);
    }

    #[test]
    fn msb_eof_detection() {
        let bytes = [0xFFu8];
        let mut r = MsbBitReader::new(&bytes);
        assert_eq!(r.read_bits(4).unwrap(), 0xF);
        assert!(r.read_bits(8).is_err());
    }

    #[test]
    fn consumed_bits_tracks() {
        let bytes = [0xFFu8; 8];
        let mut r = LsbBitReader::new(&bytes);
        r.fetch_bits(5).unwrap();
        r.fetch_bits(11).unwrap();
        assert_eq!(r.consumed_bits(), 16);
    }
}
