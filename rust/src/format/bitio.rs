//! Bit-granular readers and writers.
//!
//! Two conventions coexist in the codecs we reproduce:
//!
//! * **DEFLATE (RFC 1951)** packs bits LSB-first within each byte:
//!   [`LsbBitReader`] / [`LsbBitWriter`].
//! * **ORC RLE v2** packs values MSB-first / big-endian across bytes:
//!   [`MsbBitReader`] / [`MsbBitWriter`].
//!
//! Both readers operate over a borrowed `&[u8]` with an explicit cursor so
//! the CODAG `input_stream` abstraction (see [`crate::decomp`]) can wrap
//! them and account cache-line refills.

use crate::{corrupt, Result};

/// LSB-first bit reader (DEFLATE convention).
///
/// Maintains a 64-bit accumulator refilled from the byte stream; `fetch`
/// consumes bits, `peek` does not. Peeking past the end of the stream
/// returns zero bits (DEFLATE decoders rely on this to decode the final
/// code of a stream), but *consuming* past the end is an error.
#[derive(Debug, Clone)]
pub struct LsbBitReader<'a> {
    data: &'a [u8],
    /// Next byte index to load into the accumulator.
    pos: usize,
    /// Bit accumulator; lowest bit = next bit of the stream.
    acc: u64,
    /// Number of valid bits in `acc`.
    nbits: u32,
    /// Total bits consumed so far (for symbol-length statistics).
    consumed_bits: u64,
}

impl<'a> LsbBitReader<'a> {
    /// Create a reader over `data`.
    pub fn new(data: &'a [u8]) -> Self {
        LsbBitReader { data, pos: 0, acc: 0, nbits: 0, consumed_bits: 0 }
    }

    /// Total number of bits consumed so far.
    #[inline]
    pub fn consumed_bits(&self) -> u64 {
        self.consumed_bits
    }

    /// Byte offset of the next byte that would be loaded (coarse progress).
    #[inline]
    pub fn byte_pos(&self) -> usize {
        self.pos - (self.nbits as usize + 7) / 8
    }

    /// True when every bit has been consumed.
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.nbits == 0 && self.pos >= self.data.len()
    }

    #[inline]
    fn refill(&mut self) {
        while self.nbits <= 56 && self.pos < self.data.len() {
            self.acc |= (self.data[self.pos] as u64) << self.nbits;
            self.pos += 1;
            self.nbits += 8;
        }
    }

    /// Peek at the next `n` (≤ 57) bits without consuming them.
    /// Bits past the end of the stream read as zero.
    #[inline]
    pub fn peek_bits(&mut self, n: u32) -> u64 {
        debug_assert!(n <= 57);
        self.refill();
        self.acc & ((1u64 << n) - 1)
    }

    /// Consume and return the next `n` (≤ 57) bits.
    #[inline]
    pub fn fetch_bits(&mut self, n: u32) -> Result<u64> {
        debug_assert!(n <= 57);
        self.refill();
        if self.nbits < n {
            return Err(corrupt(format!(
                "bit stream exhausted: wanted {n} bits, {} available",
                self.nbits
            )));
        }
        let v = self.acc & ((1u64 << n) - 1);
        self.acc >>= n;
        self.nbits -= n;
        self.consumed_bits += n as u64;
        Ok(v)
    }

    /// Drop `n` bits that were previously peeked (must be available).
    #[inline]
    pub fn skip_bits(&mut self, n: u32) -> Result<()> {
        self.fetch_bits(n).map(|_| ())
    }

    /// Consume `n` (≤ 57) bits previously observed through
    /// [`peek_bits`](Self::peek_bits) without re-reading them — the
    /// bulk half of the peek+consume decode loop: one wide peek yields
    /// a Huffman symbol *and* its extra bits, then a single `consume`
    /// retires them all. Errors (like `fetch_bits`) when fewer than `n`
    /// real bits remain, so zero-padded peek bits can never be
    /// silently consumed past the end of the stream.
    #[inline]
    pub fn consume_bits(&mut self, n: u32) -> Result<()> {
        debug_assert!(n <= 57);
        if self.nbits < n {
            self.refill();
            if self.nbits < n {
                return Err(corrupt(format!(
                    "bit stream exhausted: wanted {n} bits, {} available",
                    self.nbits
                )));
            }
        }
        self.acc >>= n;
        self.nbits -= n;
        self.consumed_bits += n as u64;
        Ok(())
    }

    /// Discard bits up to the next byte boundary (DEFLATE stored blocks).
    #[inline]
    pub fn align_byte(&mut self) {
        let drop = self.nbits % 8;
        self.acc >>= drop;
        self.nbits -= drop;
        self.consumed_bits += drop as u64;
    }

    /// Borrow `len` bytes directly from the underlying buffer after
    /// aligning to a byte boundary — the zero-copy read DEFLATE stored
    /// blocks feed straight into `OutputStream::write_slice`. The
    /// accumulator is discarded and re-seeded past the slice, and
    /// `consumed_bits`/`byte_pos` advance exactly as if the bytes had
    /// been fetched 8 bits at a time.
    pub fn read_aligned_slice(&mut self, len: usize) -> Result<&'a [u8]> {
        self.align_byte();
        debug_assert_eq!(self.nbits % 8, 0);
        let cur = self.byte_pos();
        let end = cur
            .checked_add(len)
            .filter(|&e| e <= self.data.len())
            .ok_or_else(|| {
                corrupt(format!(
                    "bit stream exhausted: wanted {len} aligned bytes, {} available",
                    self.data.len() - cur
                ))
            })?;
        let s = &self.data[cur..end];
        self.pos = end;
        self.acc = 0;
        self.nbits = 0;
        self.consumed_bits += len as u64 * 8;
        Ok(s)
    }

    /// Read `len` bytes after aligning to a byte boundary.
    pub fn read_aligned_bytes(&mut self, len: usize) -> Result<Vec<u8>> {
        self.read_aligned_slice(len).map(|s| s.to_vec())
    }

    /// Create a reader positioned `bit_off` bits into `data` — the
    /// seekable construction container-v2 restart points need. The
    /// reader is rooted at the containing byte and the sub-byte
    /// remainder is consumed, so `consumed_bits()` counts from that
    /// byte boundary: callers recover the absolute stop position as
    /// `(bit_off / 8) * 8 + consumed_bits()`.
    pub fn at_bit_offset(data: &'a [u8], bit_off: u64) -> Result<Self> {
        let rem = (bit_off % 8) as u32;
        let past_end = bit_off / 8 > data.len() as u64
            || (rem > 0 && bit_off / 8 >= data.len() as u64);
        if past_end {
            return Err(corrupt(format!(
                "restart point at bit {bit_off} is past the {}-byte stream",
                data.len()
            )));
        }
        let byte = (bit_off / 8) as usize;
        let mut r = LsbBitReader::new(&data[byte..]);
        if rem > 0 {
            r.fetch_bits(rem)?;
        }
        Ok(r)
    }
}

/// LSB-first bit writer (DEFLATE convention).
#[derive(Debug, Default, Clone)]
pub struct LsbBitWriter {
    out: Vec<u8>,
    acc: u64,
    nbits: u32,
}

impl LsbBitWriter {
    /// Create an empty writer.
    pub fn new() -> Self {
        Self::default()
    }

    /// Append the low `n` (≤ 57) bits of `v`.
    #[inline]
    pub fn put_bits(&mut self, v: u64, n: u32) {
        debug_assert!(n <= 57);
        debug_assert!(n == 64 || v < (1u64 << n.max(1)) || n == 0);
        self.acc |= v << self.nbits;
        self.nbits += n;
        while self.nbits >= 8 {
            self.out.push((self.acc & 0xFF) as u8);
            self.acc >>= 8;
            self.nbits -= 8;
        }
    }

    /// Zero-pad to the next byte boundary.
    pub fn align_byte(&mut self) {
        if self.nbits > 0 {
            self.out.push((self.acc & 0xFF) as u8);
            self.acc = 0;
            self.nbits = 0;
        }
    }

    /// Append raw bytes (caller must be byte-aligned).
    pub fn put_aligned_bytes(&mut self, bytes: &[u8]) {
        debug_assert_eq!(self.nbits, 0, "put_aligned_bytes requires byte alignment");
        self.out.extend_from_slice(bytes);
    }

    /// Flush and return the underlying buffer.
    pub fn finish(mut self) -> Vec<u8> {
        self.align_byte();
        self.out
    }

    /// Bits written so far.
    pub fn bit_len(&self) -> u64 {
        self.out.len() as u64 * 8 + self.nbits as u64
    }
}

/// MSB-first (big-endian) bit reader — ORC RLE v2 convention.
///
/// Keeps a 64-bit accumulator so the common case (packed widths ≤ 56)
/// is a shift+mask instead of a per-byte loop (§Perf L3).
#[derive(Debug, Clone)]
pub struct MsbBitReader<'a> {
    data: &'a [u8],
    /// Next byte to load into the accumulator.
    pos: usize,
    /// Pending bits, right-aligned (the low `nbits` bits of `acc`).
    acc: u64,
    nbits: u32,
}

impl<'a> MsbBitReader<'a> {
    /// Create a reader over `data`.
    pub fn new(data: &'a [u8]) -> Self {
        MsbBitReader { data, pos: 0, acc: 0, nbits: 0 }
    }

    /// Byte offset of consumed input (rounded up if mid-byte).
    pub fn byte_pos(&self) -> usize {
        let consumed_bits = self.pos as u64 * 8 - self.nbits as u64;
        ((consumed_bits + 7) / 8) as usize
    }

    #[inline]
    fn refill(&mut self) {
        while self.nbits <= 56 && self.pos < self.data.len() {
            self.acc = (self.acc << 8) | self.data[self.pos] as u64;
            self.pos += 1;
            self.nbits += 8;
        }
    }

    /// Read one full byte (must be byte-aligned).
    pub fn read_byte(&mut self) -> Result<u8> {
        debug_assert_eq!(self.nbits % 8, 0);
        self.read_bits(8).map(|v| v as u8)
    }

    /// Read `n` (≤ 64) bits MSB-first.
    #[inline]
    pub fn read_bits(&mut self, n: u32) -> Result<u64> {
        debug_assert!(n <= 64);
        if n == 0 {
            return Ok(0);
        }
        self.refill();
        if n <= self.nbits {
            self.nbits -= n;
            let v = (self.acc >> self.nbits) & mask64(n);
            return Ok(v);
        }
        // Wide read (57..=64 bits) or end of stream.
        if self.pos >= self.data.len() {
            return Err(corrupt("msb reader: bit stream exhausted"));
        }
        let have = self.nbits;
        let hi = (self.acc & mask64(have)) << (n - have);
        self.acc = 0;
        self.nbits = 0;
        let lo = self.read_bits(n - have)?;
        Ok(hi | lo)
    }

    /// Skip to the next byte boundary.
    pub fn align_byte(&mut self) {
        let drop = self.nbits % 8;
        self.nbits -= drop;
    }

    /// Bulk-unpack `out.len()` fields of `width` (1..=64) bits MSB-first
    /// into `out` — the wide-lane half of the RLE v2 decode hot path
    /// (DESIGN.md §7.4). Semantically identical to calling
    /// [`read_bits`](Self::read_bits) once per element, including the
    /// `byte_pos` accounting, but the inner loop loads the input eight
    /// bytes at a time and drains `⌊nbits/width⌋` elements per load with
    /// no per-element bounds checks. Width classes:
    ///
    /// * `1..=56` — word-at-a-time: one aligned 8-byte load refills the
    ///   accumulator, then a branch-free shift+mask loop emits every
    ///   element the accumulator holds.
    /// * `57..=64` — falls back to per-element `read_bits` (each element
    ///   needs a two-load assembly; only width 64 is reachable through
    ///   the ORC closest-fixed-bits table).
    ///
    /// On error (stream exhausted mid-group) the reader is left mid-
    /// stream and `out` partially written; callers propagate the error
    /// without committing the reader, so the error class is the only
    /// observable — identical to the scalar loop's.
    pub fn unpack_into(&mut self, width: u32, out: &mut [u64]) -> Result<()> {
        debug_assert!((1..=64).contains(&width));
        if width > 56 {
            for o in out.iter_mut() {
                *o = self.read_bits(width)?;
            }
            return Ok(());
        }
        let mask = mask64(width);
        let n = out.len();
        let mut i = 0usize;
        while i < n {
            if self.nbits < width {
                if self.pos + 8 <= self.data.len() {
                    // Word refill: append as many whole bytes as fit.
                    // The accumulator's bits above `nbits` are garbage
                    // (read_bits never looks at them), so shifting them
                    // out is free.
                    let w8 = u64::from_be_bytes(
                        self.data[self.pos..self.pos + 8].try_into().expect("8-byte window"),
                    );
                    if self.nbits == 0 {
                        self.acc = w8;
                        self.nbits = 64;
                        self.pos += 8;
                    } else {
                        let take = (64 - self.nbits) / 8; // 1..=7 whole bytes
                        self.acc = (self.acc << (take * 8)) | (w8 >> (64 - take * 8));
                        self.nbits += take * 8;
                        self.pos += take as usize;
                    }
                } else {
                    // Tail: byte-granular refill, then the same error
                    // the scalar reader raises at exhaustion.
                    self.refill();
                    if self.nbits < width {
                        return Err(corrupt("msb reader: bit stream exhausted"));
                    }
                }
            }
            // Drain every element the accumulator holds (branch-free
            // shift+mask per element).
            let m = ((self.nbits / width) as usize).min(n - i);
            for o in &mut out[i..i + m] {
                self.nbits -= width;
                *o = (self.acc >> self.nbits) & mask;
            }
            i += m;
        }
        Ok(())
    }
}

/// Low-`n` bit mask (n in 1..=64).
#[inline]
fn mask64(n: u32) -> u64 {
    if n >= 64 {
        u64::MAX
    } else {
        (1u64 << n) - 1
    }
}

/// MSB-first (big-endian) bit writer — ORC RLE v2 convention.
#[derive(Debug, Default, Clone)]
pub struct MsbBitWriter {
    out: Vec<u8>,
    cur: u8,
    /// Bits already used in `cur` (filled from the top).
    used: u32,
}

impl MsbBitWriter {
    /// Create an empty writer.
    pub fn new() -> Self {
        Self::default()
    }

    /// Append one full byte (must be byte-aligned).
    pub fn put_byte(&mut self, b: u8) {
        debug_assert_eq!(self.used, 0);
        self.out.push(b);
    }

    /// Append the low `n` (≤ 64) bits of `v`, MSB-first.
    pub fn put_bits(&mut self, v: u64, n: u32) {
        debug_assert!(n <= 64);
        let mut left = n;
        while left > 0 {
            let room = 8 - self.used;
            let take = left.min(room);
            let bits = ((v >> (left - take)) & ((1u64 << take) - 1)) as u8;
            self.cur |= bits << (room - take);
            self.used += take;
            if self.used == 8 {
                self.out.push(self.cur);
                self.cur = 0;
                self.used = 0;
            }
            left -= take;
        }
    }

    /// Zero-pad to a byte boundary.
    pub fn align_byte(&mut self) {
        if self.used > 0 {
            self.out.push(self.cur);
            self.cur = 0;
            self.used = 0;
        }
    }

    /// Bulk-pack the low `width` (1..=64) bits of every value in `vals`,
    /// MSB-first — the encoder-side twin of
    /// [`MsbBitReader::unpack_into`]. Byte-identical to calling
    /// [`put_bits`](Self::put_bits) once per value; the fast path (byte-
    /// aligned writer, width ≤ 56) stages bits in a 64-bit accumulator
    /// and flushes whole bytes with one big-endian store instead of the
    /// per-bit-field loop.
    pub fn pack_from(&mut self, width: u32, vals: &[u64]) {
        debug_assert!((1..=64).contains(&width));
        if width > 56 || self.used != 0 {
            for &v in vals {
                self.put_bits(v, width);
            }
            return;
        }
        let mask = mask64(width);
        let mut acc = 0u64;
        let mut nbits = 0u32;
        for &v in vals {
            if nbits + width > 64 {
                // Flush the top whole bytes (nbits > 8 here since
                // width <= 56), keeping the low `nbits % 8` bits staged.
                let flush = (nbits / 8) as usize;
                let top = acc << (64 - nbits);
                self.out.extend_from_slice(&top.to_be_bytes()[..flush]);
                nbits -= flush as u32 * 8;
            }
            acc = (acc << width) | (v & mask);
            nbits += width;
        }
        // Tail: whole bytes first, then the sub-byte remainder through
        // the scalar path so `used`/`cur` stay coherent.
        if nbits > 0 {
            let flush = (nbits / 8) as usize;
            let top = acc << (64 - nbits);
            self.out.extend_from_slice(&top.to_be_bytes()[..flush]);
            let rem = nbits % 8;
            if rem > 0 {
                self.put_bits(acc & ((1u64 << rem) - 1), rem);
            }
        }
    }

    /// Flush and return the buffer.
    pub fn finish(mut self) -> Vec<u8> {
        self.align_byte();
        self.out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lsb_roundtrip_mixed_widths() {
        let mut w = LsbBitWriter::new();
        let fields: &[(u64, u32)] = &[(0b1, 1), (0b1011, 4), (0x3FF, 10), (0, 3), (0x1FFFF, 17)];
        for &(v, n) in fields {
            w.put_bits(v, n);
        }
        let bytes = w.finish();
        let mut r = LsbBitReader::new(&bytes);
        for &(v, n) in fields {
            assert_eq!(r.fetch_bits(n).unwrap(), v);
        }
    }

    #[test]
    fn lsb_peek_does_not_consume() {
        let mut w = LsbBitWriter::new();
        w.put_bits(0xAB, 8);
        w.put_bits(0xCD, 8);
        let bytes = w.finish();
        let mut r = LsbBitReader::new(&bytes);
        assert_eq!(r.peek_bits(8), 0xAB);
        assert_eq!(r.peek_bits(16), 0xCDAB);
        assert_eq!(r.fetch_bits(8).unwrap(), 0xAB);
        assert_eq!(r.fetch_bits(8).unwrap(), 0xCD);
    }

    #[test]
    fn lsb_peek_past_end_is_zero_but_fetch_errors() {
        let bytes = [0xFFu8];
        let mut r = LsbBitReader::new(&bytes);
        assert_eq!(r.peek_bits(16), 0x00FF);
        assert_eq!(r.fetch_bits(8).unwrap(), 0xFF);
        assert!(r.fetch_bits(1).is_err());
    }

    #[test]
    fn lsb_align_and_aligned_bytes() {
        let mut w = LsbBitWriter::new();
        w.put_bits(0b101, 3);
        w.align_byte();
        w.put_aligned_bytes(&[1, 2, 3]);
        let bytes = w.finish();
        let mut r = LsbBitReader::new(&bytes);
        assert_eq!(r.fetch_bits(3).unwrap(), 0b101);
        assert_eq!(r.read_aligned_bytes(3).unwrap(), vec![1, 2, 3]);
    }

    #[test]
    fn msb_roundtrip_mixed_widths() {
        let mut w = MsbBitWriter::new();
        let fields: &[(u64, u32)] = &[(0b101, 3), (0xFFFF, 16), (1, 1), (0x123456789A, 40)];
        for &(v, n) in fields {
            w.put_bits(v, n);
        }
        let bytes = w.finish();
        let mut r = MsbBitReader::new(&bytes);
        for &(v, n) in fields {
            assert_eq!(r.read_bits(n).unwrap(), v);
        }
    }

    #[test]
    fn msb_bigendian_byte_order() {
        // 0xABCD written as 16 bits must serialize as [0xAB, 0xCD].
        let mut w = MsbBitWriter::new();
        w.put_bits(0xABCD, 16);
        assert_eq!(w.finish(), vec![0xAB, 0xCD]);
    }

    #[test]
    fn msb_eof_detection() {
        let bytes = [0xFFu8];
        let mut r = MsbBitReader::new(&bytes);
        assert_eq!(r.read_bits(4).unwrap(), 0xF);
        assert!(r.read_bits(8).is_err());
    }

    #[test]
    fn consumed_bits_tracks() {
        let bytes = [0xFFu8; 8];
        let mut r = LsbBitReader::new(&bytes);
        r.fetch_bits(5).unwrap();
        r.fetch_bits(11).unwrap();
        assert_eq!(r.consumed_bits(), 16);
    }

    /// Tiny deterministic generator for the differential reader sweeps.
    fn lcg(x: &mut u64) -> u64 {
        *x = x.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
        *x >> 11
    }

    #[test]
    fn bulk_peek_consume_pins_scalar_reader_accounting() {
        // Satellite gate: under the bulk peek+consume API, the bits
        // observed and the `consumed_bits`/`byte_pos` accounting must
        // match a reader driven one `fetch_bits(1)` at a time, on
        // random streams and random field widths.
        for seed in 0..20u64 {
            let mut x = 0x9E37_79B9 ^ seed;
            let bytes: Vec<u8> = (0..257).map(|_| lcg(&mut x) as u8).collect();
            let total_bits = bytes.len() as u64 * 8;
            let mut bulk = LsbBitReader::new(&bytes);
            let mut scalar = LsbBitReader::new(&bytes);
            let mut consumed = 0u64;
            loop {
                let n = 1 + (lcg(&mut x) % 24) as u32;
                if consumed + n as u64 > total_bits {
                    // Past the end the bulk API must refuse too.
                    assert!(bulk.consume_bits(n).is_err());
                    break;
                }
                let word = bulk.peek_bits(57);
                bulk.consume_bits(n).unwrap();
                let mut want = 0u64;
                for i in 0..n {
                    want |= scalar.fetch_bits(1).unwrap() << i;
                }
                assert_eq!(word & ((1u64 << n) - 1), want, "seed {seed} n {n}");
                consumed += n as u64;
                assert_eq!(bulk.consumed_bits(), consumed, "seed {seed}");
                assert_eq!(bulk.consumed_bits(), scalar.consumed_bits(), "seed {seed}");
                assert_eq!(bulk.byte_pos(), scalar.byte_pos(), "seed {seed}");
                assert_eq!(bulk.byte_pos(), (consumed / 8) as usize, "seed {seed}");
            }
        }
    }

    #[test]
    fn consume_bits_errors_at_end_like_fetch() {
        let bytes = [0xAAu8, 0x55];
        let mut r = LsbBitReader::new(&bytes);
        assert_eq!(r.peek_bits(57) & 0xFFFF, 0x55AA);
        r.consume_bits(12).unwrap();
        // 4 real bits left; zero-padded peek must not enable consuming 5.
        assert!(r.consume_bits(5).is_err());
        assert_eq!(r.consumed_bits(), 12, "failed consume must not advance");
        r.consume_bits(4).unwrap();
        assert!(r.is_empty());
    }

    #[test]
    fn unpack_into_matches_scalar_read_bits_all_widths() {
        // Tentpole gate: for every width 1..=64, bulk unpack over a
        // random stream must yield the same values AND the same
        // byte_pos accounting as the per-element scalar reader, at
        // every group length (incl. lengths straddling the 8-byte
        // refill boundary and a trailing partial byte).
        let mut x = 0xDEAD_BEEFu64;
        let bytes: Vec<u8> = (0..519).map(|_| lcg(&mut x) as u8).collect();
        for width in 1..=64u32 {
            for n in [0usize, 1, 2, 3, 7, 8, 9, 31, 57, 63] {
                if n as u64 * width as u64 > bytes.len() as u64 * 8 {
                    continue;
                }
                let mut bulk = MsbBitReader::new(&bytes);
                let mut scalar = MsbBitReader::new(&bytes);
                // Start both readers at an unaligned offset to cover
                // leftover-accumulator entry states.
                let lead = (width + 3) % 17;
                if lead > 0 {
                    assert_eq!(bulk.read_bits(lead).unwrap(), scalar.read_bits(lead).unwrap());
                }
                let mut out = vec![0u64; n];
                bulk.unpack_into(width, &mut out).unwrap();
                for (k, &got) in out.iter().enumerate() {
                    let want = scalar.read_bits(width).unwrap();
                    assert_eq!(got, want, "w{width} n{n} elem {k}");
                }
                assert_eq!(bulk.byte_pos(), scalar.byte_pos(), "w{width} n{n}");
                // Both readers keep decoding identically afterwards.
                assert_eq!(
                    bulk.read_bits(13).unwrap(),
                    scalar.read_bits(13).unwrap(),
                    "w{width} n{n}: post-group divergence"
                );
            }
        }
    }

    #[test]
    fn unpack_into_errors_at_exhaustion_like_scalar() {
        for width in [1u32, 3, 7, 24, 33, 56, 64] {
            let nbytes = 7usize; // 56 bits: never a multiple of 8 groups for most widths
            let bytes = vec![0xA5u8; nbytes];
            let fit = (nbytes as u64 * 8 / width as u64) as usize;
            let mut r = MsbBitReader::new(&bytes);
            let mut out = vec![0u64; fit + 1];
            assert!(r.unpack_into(width, &mut out).is_err(), "w{width} must exhaust");
            let mut r = MsbBitReader::new(&bytes);
            let mut out = vec![0u64; fit];
            r.unpack_into(width, &mut out).unwrap();
        }
    }

    #[test]
    fn pack_from_matches_put_bits_loop() {
        let mut x = 0x1234_5678u64;
        for width in 1..=64u32 {
            for n in [0usize, 1, 2, 7, 8, 9, 63, 130] {
                let vals: Vec<u64> = (0..n).map(|_| lcg(&mut x)).collect();
                let mask = mask64(width);
                let mut bulk = MsbBitWriter::new();
                bulk.pack_from(width, &vals);
                let mut scalar = MsbBitWriter::new();
                for &v in &vals {
                    scalar.put_bits(v & mask, width);
                }
                assert_eq!(bulk.finish(), scalar.finish(), "w{width} n{n}");
            }
        }
        // Unaligned writer entry falls back to the scalar path but must
        // still produce identical bytes.
        let mut bulk = MsbBitWriter::new();
        bulk.put_bits(0b101, 3);
        bulk.pack_from(11, &[0x5A3, 0x7FF, 0x001]);
        let mut scalar = MsbBitWriter::new();
        scalar.put_bits(0b101, 3);
        for v in [0x5A3u64, 0x7FF, 0x001] {
            scalar.put_bits(v, 11);
        }
        assert_eq!(bulk.finish(), scalar.finish());
    }

    #[test]
    fn pack_then_unpack_roundtrip() {
        let mut x = 0x9E1u64;
        for width in [1u32, 2, 5, 8, 13, 24, 26, 32, 40, 48, 56, 64] {
            let vals: Vec<u64> = (0..100).map(|_| lcg(&mut x) & mask64(width)).collect();
            let mut w = MsbBitWriter::new();
            w.pack_from(width, &vals);
            let bytes = w.finish();
            let mut r = MsbBitReader::new(&bytes);
            let mut out = vec![0u64; vals.len()];
            r.unpack_into(width, &mut out).unwrap();
            assert_eq!(out, vals, "w{width}");
        }
    }

    #[test]
    fn read_aligned_slice_matches_bytes_and_accounting() {
        let mut w = LsbBitWriter::new();
        w.put_bits(0b1101, 4);
        w.align_byte();
        w.put_aligned_bytes(&[9, 8, 7, 6, 5]);
        let bytes = w.finish();
        let mut a = LsbBitReader::new(&bytes);
        let mut b = LsbBitReader::new(&bytes);
        a.fetch_bits(4).unwrap();
        b.fetch_bits(4).unwrap();
        let slice = a.read_aligned_slice(3).unwrap().to_vec();
        let vec = b.read_aligned_bytes(3).unwrap();
        assert_eq!(slice, vec);
        assert_eq!(a.consumed_bits(), b.consumed_bits());
        assert_eq!(a.byte_pos(), b.byte_pos());
        // Remaining bytes still readable, and over-length reads error.
        assert_eq!(a.read_aligned_slice(2).unwrap(), &[6, 5]);
        assert!(a.read_aligned_slice(1).is_err());
    }
}
