//! Byte/bit stream substrate and the chunked container format.
//!
//! This module implements the serialization primitives every codec in the
//! paper depends on:
//!
//! * [`bitio`] — LSB-first bit reader/writer (DEFLATE) and MSB-first
//!   big-endian bit packing (ORC RLE v2 `DIRECT`/`PATCHED_BASE`).
//! * [`varint`] — ORC base-128 varints with zigzag for signed values.
//! * [`container`] — the chunked data format from §II-B: fixed-size
//!   uncompressed chunks (128 KiB by default), independently compressed,
//!   with an index of compressed offsets so chunks can be decompressed in
//!   parallel — the property both CODAG and the RAPIDS baseline exploit.
//! * [`hash`] — CRC-32C content checksums for the integrity tier
//!   (per-chunk uncompressed-payload checksums in container v4, the
//!   whole-meta checksum `FileDataset::open` verifies, and the proto v3
//!   response frame checksum).

pub mod bitio;
pub mod container;
pub mod hash;
pub mod varint;
