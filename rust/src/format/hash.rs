//! Content hashing for the integrity tier (DESIGN.md §13).
//!
//! CRC-32C (Castagnoli, reflected polynomial `0x82F63B78`) over a
//! slice-by-8 table — the strongest error-detection/speed trade-off
//! available std-only: the polynomial's published Hamming-distance
//! profile guarantees detection of any single burst ≤ 32 bits and all
//! 1–2 bit errors at every payload size this container produces, which
//! is exactly the fault model of the bit-flip sweeps. The 8 × 256 table
//! is derived once at first use (`OnceLock`) so cold binaries (the CLI
//! one-shots) pay the ~8 KiB build only when a checksum is actually
//! touched.
//!
//! `crc32c` here must stay byte-for-byte compatible with the Python
//! port in `rust/tests/golden/gen_golden.py` (`crc32c`): the v4
//! container fixtures pin both against each other, and both are pinned
//! to the published check value `crc32c(b"123456789") == 0xE3069283`.

use std::sync::OnceLock;

/// Reflected CRC-32C polynomial (Castagnoli).
const POLY: u32 = 0x82F6_3B78;

/// Slice-by-8 lookup tables: `TABLES[0]` is the classic byte-at-a-time
/// table; `TABLES[k]` advances a byte `k` positions further through the
/// polynomial, letting the hot loop fold 8 input bytes per iteration.
fn tables() -> &'static [[u32; 256]; 8] {
    static TABLES: OnceLock<Box<[[u32; 256]; 8]>> = OnceLock::new();
    TABLES.get_or_init(|| {
        let mut t = Box::new([[0u32; 256]; 8]);
        for i in 0..256u32 {
            let mut crc = i;
            for _ in 0..8 {
                crc = if crc & 1 != 0 { (crc >> 1) ^ POLY } else { crc >> 1 };
            }
            t[0][i as usize] = crc;
        }
        for k in 1..8 {
            for i in 0..256 {
                let prev = t[k - 1][i];
                t[k][i] = (prev >> 8) ^ t[0][(prev & 0xFF) as usize];
            }
        }
        t
    })
}

/// CRC-32C of `data` (init/xor-out `0xFFFF_FFFF`, reflected).
pub fn crc32c(data: &[u8]) -> u32 {
    crc32c_extend(0, data)
}

/// Streaming form: extend a running CRC-32C with more bytes.
///
/// `crc32c_extend(crc32c(a), b) == crc32c(a ++ b)` — `FileDataset::open`
/// uses this to fold the header, index, and sections into the whole-meta
/// checksum as it streams them, without buffering the file.
pub fn crc32c_extend(state: u32, data: &[u8]) -> u32 {
    let t = tables();
    let mut crc = !state;
    let mut chunks = data.chunks_exact(8);
    for w in &mut chunks {
        // Fold the first 4 bytes into the running CRC, then look all 8
        // bytes up in their distance-matched tables.
        let lo = crc ^ u32::from_le_bytes([w[0], w[1], w[2], w[3]]);
        crc = t[7][(lo & 0xFF) as usize]
            ^ t[6][((lo >> 8) & 0xFF) as usize]
            ^ t[5][((lo >> 16) & 0xFF) as usize]
            ^ t[4][((lo >> 24) & 0xFF) as usize]
            ^ t[3][w[4] as usize]
            ^ t[2][w[5] as usize]
            ^ t[1][w[6] as usize]
            ^ t[0][w[7] as usize];
    }
    for &b in chunks.remainder() {
        crc = (crc >> 8) ^ t[0][((crc ^ b as u32) & 0xFF) as usize];
    }
    !crc
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn published_check_value() {
        // The canonical CRC-32C check vector (RFC 3720 appendix et al.).
        assert_eq!(crc32c(b"123456789"), 0xE306_9283);
    }

    #[test]
    fn known_vectors() {
        // Cross-implementation anchors (verified against the Python
        // table-driven port in gen_golden.py).
        assert_eq!(crc32c(b""), 0);
        assert_eq!(crc32c(b"a"), 0xC1D0_4330);
        assert_eq!(crc32c(&[0u8; 32]), 0x8A91_36AA);
        assert_eq!(crc32c(&[0xFFu8; 32]), 0x62A8_AB43);
    }

    #[test]
    fn extend_matches_one_shot_at_every_split() {
        let data: Vec<u8> = (0..257u32).map(|i| (i * 131 % 251) as u8).collect();
        let whole = crc32c(&data);
        for split in 0..data.len() {
            let (a, b) = data.split_at(split);
            assert_eq!(crc32c_extend(crc32c(a), b), whole, "split at {split}");
        }
    }

    #[test]
    fn slice_by_8_matches_byte_at_a_time() {
        // Oracle: the textbook single-table loop over the same table.
        let t = tables();
        let mut data = Vec::new();
        let mut x = 0x9E37_79B9u32;
        for _ in 0..1025 {
            x = x.wrapping_mul(0x0019_660D).wrapping_add(0x3C6E_F35F);
            data.push((x >> 24) as u8);
        }
        for len in [0usize, 1, 7, 8, 9, 63, 64, 65, 1025] {
            let mut crc = !0u32;
            for &b in &data[..len] {
                crc = (crc >> 8) ^ t[0][((crc ^ b as u32) & 0xFF) as usize];
            }
            assert_eq!(crc32c(&data[..len]), !crc, "len {len}");
        }
    }

    #[test]
    fn single_bit_flips_always_change_the_crc() {
        // The fault model of the container flip sweeps, asserted
        // directly: CRC-32C detects every 1-bit error.
        let data: Vec<u8> = (0..96u8).collect();
        let base = crc32c(&data);
        for i in 0..data.len() {
            for bit in 0..8 {
                let mut bad = data.clone();
                bad[i] ^= 1 << bit;
                assert_ne!(crc32c(&bad), base, "byte {i} bit {bit}");
            }
        }
    }
}
