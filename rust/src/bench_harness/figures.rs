//! Figure reproductions: the characterization figures (2, 3, 4, 5, 6),
//! the throughput/speedup figures (7, 8), and the §IV-D/§V-E ablations.

use crate::bench_harness::{fmt_row, geomean, Scale, Workload};
use crate::codecs::CodecKind;
use crate::data::Dataset;
use crate::decomp::codag_engine::Variant;
use crate::gpu_sim::{
    simulate_container, GpuConfig, Provisioning, SimMetrics, StallReason,
};
use crate::Result;

/// Simulate one (workload, codec, provisioning, gpu) cell.
///
/// Asymmetric sampling: CODAG needs ~64 chunks to fill an SM's warp
/// slots, but the baseline is at steady state with its 2 (RLE) or 16
/// (Deflate) resident units after a handful of chunks — simulating more
/// only multiplies wall-clock without changing the rate. 12 chunks keep
/// the tail contribution < 10%.
pub fn sim_cell(
    w: &Workload,
    kind: CodecKind,
    prov: Provisioning,
    cfg: &GpuConfig,
    scale: Scale,
) -> Result<SimMetrics> {
    let chunks = match prov {
        Provisioning::Baseline => scale.sim_chunks.min(12),
        _ => scale.sim_chunks,
    };
    simulate_container(cfg, prov, w.container(kind), chunks)
}

/// Fig 2: baseline RLE v1 — peak-throughput % and stall distribution
/// (MC0 and TPC, as in the paper).
pub fn fig2(workloads: &[Workload], scale: Scale) -> Result<String> {
    characterization_figure(
        "Fig 2 — RAPIDS baseline, RLE v1: throughput % and stall distribution",
        workloads,
        CodecKind::RleV1,
        Provisioning::Baseline,
        scale,
    )
}

/// Fig 3: baseline Deflate — throughput % and compute-pipe utilization.
pub fn fig3(workloads: &[Workload], scale: Scale) -> Result<String> {
    let cfg = GpuConfig::a100();
    let mut s = String::from(
        "Fig 3 — RAPIDS baseline, Deflate: throughput % and pipe utilization\n",
    );
    let widths = [8usize, 9, 9, 9, 9, 9];
    s.push_str(&fmt_row(
        &["Dataset", "Comp%", "Mem%", "ALU%", "FMA%", "LSU%"].map(String::from),
        &widths,
    ));
    s.push('\n');
    for w in pick(workloads, &[Dataset::Mc0, Dataset::Tpc]) {
        let m = sim_cell(w, CodecKind::Deflate, Provisioning::Baseline, &cfg, scale)?;
        s.push_str(&fmt_row(
            &[
                w.dataset.name().to_string(),
                format!("{:.1}", m.compute_pct(&cfg)),
                format!("{:.1}", m.memory_pct(&cfg)),
                format!("{:.1}", m.alu_pct(&cfg)),
                format!("{:.1}", m.fma_pct(&cfg)),
                format!("{:.1}", m.lsu_pct(&cfg)),
            ],
            &widths,
        ));
        s.push('\n');
    }
    Ok(s)
}

/// Fig 4: the issue-slot timeline comparison on the toy SM.
pub fn fig4() -> String {
    let cmp = crate::gpu_sim::timeline::fig4();
    crate::gpu_sim::timeline::render(&cmp)
}

/// Fig 5: SB / MPT stall comparison, CODAG vs baseline (MC0, TPC).
pub fn fig5(workloads: &[Workload], scale: Scale) -> Result<String> {
    let cfg = GpuConfig::a100();
    let mut s =
        String::from("Fig 5 — Stalled instructions: SB (barrier) and MPT, CODAG vs baseline\n");
    let widths = [8usize, 16, 8, 8];
    s.push_str(&fmt_row(&["Dataset", "Arch", "SB%", "MPT%"].map(String::from), &widths));
    s.push('\n');
    for w in pick(workloads, &[Dataset::Mc0, Dataset::Tpc]) {
        for prov in [Provisioning::Baseline, Provisioning::Codag(Variant::Codag)] {
            let m = sim_cell(w, CodecKind::RleV1, prov, &cfg, scale)?;
            s.push_str(&fmt_row(
                &[
                    w.dataset.name().to_string(),
                    prov.label().to_string(),
                    format!("{:.1}", m.stall_pct(StallReason::Barrier)),
                    format!("{:.1}", m.stall_pct(StallReason::MathPipeThrottle)),
                ],
                &widths,
            ));
            s.push('\n');
        }
    }
    Ok(s)
}

/// Fig 6: compute/memory peak-throughput %, CODAG vs baseline.
pub fn fig6(workloads: &[Workload], scale: Scale) -> Result<String> {
    let cfg = GpuConfig::a100();
    let mut s = String::from("Fig 6 — Compute/memory peak throughput %, CODAG vs baseline\n");
    let widths = [8usize, 16, 9, 9];
    s.push_str(&fmt_row(&["Dataset", "Arch", "Comp%", "Mem%"].map(String::from), &widths));
    s.push('\n');
    for w in pick(workloads, &[Dataset::Mc0, Dataset::Tpc]) {
        for prov in [Provisioning::Baseline, Provisioning::Codag(Variant::Codag)] {
            let m = sim_cell(w, CodecKind::RleV1, prov, &cfg, scale)?;
            s.push_str(&fmt_row(
                &[
                    w.dataset.name().to_string(),
                    prov.label().to_string(),
                    format!("{:.1}", m.compute_pct(&cfg)),
                    format!("{:.1}", m.memory_pct(&cfg)),
                ],
                &widths,
            ));
            s.push('\n');
        }
    }
    Ok(s)
}

/// One Fig 7 cell: throughput in GB/s.
#[derive(Debug, Clone, Copy)]
pub struct Fig7Cell {
    /// Dataset.
    pub dataset: Dataset,
    /// Codec.
    pub codec: CodecKind,
    /// CODAG GB/s.
    pub codag: f64,
    /// Baseline GB/s.
    pub baseline: f64,
}

/// Compute Fig 7 cells for a subset of codecs (tests use one codec;
/// the full figure passes `CodecKind::all()`).
pub fn fig7_cells_for(
    workloads: &[Workload],
    scale: Scale,
    cfg: &GpuConfig,
    kinds: &[CodecKind],
) -> Result<Vec<Fig7Cell>> {
    let mut cells = Vec::new();
    for &kind in kinds {
        for w in workloads {
            let c = sim_cell(w, kind, Provisioning::Codag(Variant::Codag), cfg, scale)?;
            let b = sim_cell(w, kind, Provisioning::Baseline, cfg, scale)?;
            cells.push(Fig7Cell {
                dataset: w.dataset,
                codec: kind,
                codag: c.throughput_gbps(cfg),
                baseline: b.throughput_gbps(cfg),
            });
        }
    }
    Ok(cells)
}

/// Compute the full Fig 7 matrix (7 datasets × 3 codecs × 2 archs).
pub fn fig7_cells(workloads: &[Workload], scale: Scale, cfg: &GpuConfig) -> Result<Vec<Fig7Cell>> {
    fig7_cells_for(workloads, scale, cfg, &CodecKind::all())
}

/// Render Fig 7 (per-dataset throughput + geomeans).
pub fn fig7(workloads: &[Workload], scale: Scale) -> Result<String> {
    let cfg = GpuConfig::a100();
    let cells = fig7_cells(workloads, scale, &cfg)?;
    let mut s = String::from("Fig 7 — Decompression throughput on A100 (GB/s)\n");
    let widths = [9usize, 8, 10, 10, 9];
    s.push_str(&fmt_row(
        &["Codec", "Dataset", "CODAG", "RAPIDS", "Speedup"].map(String::from),
        &widths,
    ));
    s.push('\n');
    for kind in CodecKind::all() {
        let mut codag_v = Vec::new();
        let mut base_v = Vec::new();
        for c in cells.iter().filter(|c| c.codec == kind) {
            s.push_str(&fmt_row(
                &[
                    kind.name().to_string(),
                    c.dataset.name().to_string(),
                    format!("{:.2}", c.codag),
                    format!("{:.2}", c.baseline),
                    format!("{:.2}x", c.codag / c.baseline.max(1e-9)),
                ],
                &widths,
            ));
            s.push('\n');
            codag_v.push(c.codag);
            base_v.push(c.baseline);
        }
        s.push_str(&fmt_row(
            &[
                kind.name().to_string(),
                "geomean".to_string(),
                format!("{:.2}", geomean(&codag_v)),
                format!("{:.2}", geomean(&base_v)),
                format!("{:.2}x", geomean(&codag_v) / geomean(&base_v).max(1e-9)),
            ],
            &widths,
        ));
        s.push('\n');
    }
    s.push_str("paper geomeans: CODAG 38.07/26.87/51.96 GB/s, RAPIDS 2.83/4.72/44.18 GB/s\n");
    Ok(s)
}

/// Fig 8: speedups (CODAG, CODAG+prefetch on A100; CODAG on V100),
/// geomean over datasets, per codec.
pub fn fig8(workloads: &[Workload], scale: Scale) -> Result<String> {
    let a100 = GpuConfig::a100();
    let v100 = GpuConfig::v100();
    let mut s = String::from("Fig 8 — Geomean speedup over RAPIDS baseline\n");
    let widths = [9usize, 14, 18, 12];
    s.push_str(&fmt_row(
        &["Codec", "CODAG@A100", "CODAG+pf@A100", "CODAG@V100"].map(String::from),
        &widths,
    ));
    s.push('\n');
    let mut rendered = Vec::new();
    for kind in CodecKind::all() {
        let mut su_codag = Vec::new();
        let mut su_pf = Vec::new();
        let mut su_v100 = Vec::new();
        for w in workloads {
            let b_a = sim_cell(w, kind, Provisioning::Baseline, &a100, scale)?;
            let c_a = sim_cell(w, kind, Provisioning::Codag(Variant::Codag), &a100, scale)?;
            let p_a =
                sim_cell(w, kind, Provisioning::Codag(Variant::CodagPrefetch), &a100, scale)?;
            let b_v = sim_cell(w, kind, Provisioning::Baseline, &v100, scale)?;
            let c_v = sim_cell(w, kind, Provisioning::Codag(Variant::Codag), &v100, scale)?;
            su_codag.push(c_a.throughput_gbps(&a100) / b_a.throughput_gbps(&a100).max(1e-9));
            su_pf.push(p_a.throughput_gbps(&a100) / b_a.throughput_gbps(&a100).max(1e-9));
            su_v100.push(c_v.throughput_gbps(&v100) / b_v.throughput_gbps(&v100).max(1e-9));
        }
        let row = (geomean(&su_codag), geomean(&su_pf), geomean(&su_v100));
        s.push_str(&fmt_row(
            &[
                kind.name().to_string(),
                format!("{:.2}x", row.0),
                format!("{:.2}x", row.1),
                format!("{:.2}x", row.2),
            ],
            &widths,
        ));
        s.push('\n');
        rendered.push(row);
    }
    s.push_str("paper: RLEv1 13.46/7.10/11.19, RLEv2 5.69/4.33/4.39, Deflate 1.18/1.02/1.10\n");
    Ok(s)
}

/// §IV-D micro-benchmark: all-thread vs single-thread ALU throughput.
pub fn ubench() -> String {
    let cfg = GpuConfig::a100();
    let rows = crate::gpu_sim::ubench::run_sweep(&cfg, &[1, 10, 100, 1000, 10_000, 100_000]);
    let mut s = String::from("§IV-D ubench — ALU throughput %, single- vs all-thread decode\n");
    let widths = [12usize, 12, 12, 8];
    s.push_str(&fmt_row(
        &["ops/access", "single%", "all%", "diff"].map(String::from),
        &widths,
    ));
    s.push('\n');
    for r in rows {
        s.push_str(&fmt_row(
            &[
                format!("{}", r.ops_per_access),
                format!("{:.2}", r.single_thread_pct),
                format!("{:.2}", r.all_thread_pct),
                format!("{:.3}", (r.single_thread_pct - r.all_thread_pct).abs()),
            ],
            &widths,
        ));
        s.push('\n');
    }
    s.push_str("paper: difference never exceeds 0.1%\n");
    s
}

/// §V-E ablation: all-thread vs single-thread decode, end-to-end.
pub fn ablation_decode(workloads: &[Workload], scale: Scale) -> Result<String> {
    let cfg = GpuConfig::a100();
    let mut s =
        String::from("§V-E — All-thread vs single-thread decoding (geomean speedup)\n");
    let widths = [9usize, 14];
    s.push_str(&fmt_row(&["Codec", "all/single"].map(String::from), &widths));
    s.push('\n');
    for kind in [CodecKind::RleV1, CodecKind::Deflate] {
        let mut ratios = Vec::new();
        for w in workloads {
            let all = sim_cell(w, kind, Provisioning::Codag(Variant::Codag), &cfg, scale)?;
            let single =
                sim_cell(w, kind, Provisioning::Codag(Variant::SingleThreadDecode), &cfg, scale)?;
            ratios
                .push(all.throughput_gbps(&cfg) / single.throughput_gbps(&cfg).max(1e-9));
        }
        s.push_str(&fmt_row(
            &[kind.name().to_string(), format!("{:.2}x", geomean(&ratios))],
            &widths,
        ));
        s.push('\n');
    }
    s.push_str("paper: 1.17x (RLE v1), 1.19x (Deflate)\n");
    Ok(s)
}

fn pick<'a>(workloads: &'a [Workload], which: &[Dataset]) -> Vec<&'a Workload> {
    workloads.iter().filter(|w| which.contains(&w.dataset)).collect()
}

fn characterization_figure(
    title: &str,
    workloads: &[Workload],
    kind: CodecKind,
    prov: Provisioning,
    scale: Scale,
) -> Result<String> {
    let cfg = GpuConfig::a100();
    let mut s = format!("{title}\n");
    let widths = [8usize, 9, 9, 14, 9, 14, 9];
    s.push_str(&fmt_row(
        &["Dataset", "Comp%", "Mem%", "Barrier%", "Wait%", "BranchRes%", "MPT%"]
            .map(String::from),
        &widths,
    ));
    s.push('\n');
    for w in pick(workloads, &[Dataset::Mc0, Dataset::Tpc]) {
        let m = sim_cell(w, kind, prov, &cfg, scale)?;
        s.push_str(&fmt_row(
            &[
                w.dataset.name().to_string(),
                format!("{:.1}", m.compute_pct(&cfg)),
                format!("{:.1}", m.memory_pct(&cfg)),
                format!("{:.1}", m.stall_pct(StallReason::Barrier)),
                format!("{:.1}", m.stall_pct(StallReason::Wait)),
                format!("{:.1}", m.stall_pct(StallReason::BranchResolve)),
                format!("{:.1}", m.stall_pct(StallReason::MathPipeThrottle)),
            ],
            &widths,
        ));
        s.push('\n');
    }
    Ok(s)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench_harness::Workload;

    #[test]
    fn fig7_codag_wins_rle_on_runny_data() {
        let scale = Scale { dataset_bytes: 512 * 1024, sim_chunks: 4 };
        let ws = vec![Workload::build(Dataset::Mc0, scale).unwrap()];
        let cells =
            fig7_cells_for(&ws, scale, &GpuConfig::a100(), &[CodecKind::RleV1]).unwrap();
        let mc0_v1 = &cells[0];
        assert!(mc0_v1.codag > mc0_v1.baseline, "{mc0_v1:?}");
    }

    #[test]
    fn figures_render() {
        let scale = Scale { dataset_bytes: 256 * 1024, sim_chunks: 2 };
        let ws = vec![
            Workload::build(Dataset::Mc0, scale).unwrap(),
            Workload::build(Dataset::Tpc, scale).unwrap(),
        ];
        assert!(fig2(&ws, scale).unwrap().contains("MC0"));
        assert!(fig5(&ws, scale).unwrap().contains("CODAG"));
        assert!(fig6(&ws, scale).unwrap().contains("Comp%"));
        assert!(fig4().contains("CODAG"));
    }
}
