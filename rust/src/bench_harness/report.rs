//! The all-in-one report runner (`codag report all`, the
//! `reproduce_paper` example, and EXPERIMENTS.md generation).

use crate::bench_harness::{all_workloads, figures, tables, Scale, Workload};
use crate::Result;

/// Experiment selector.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Experiment {
    /// Table III (testbed).
    Table3,
    /// Table IV (datasets).
    Table4,
    /// Table V (ratios, symbol lengths).
    Table5,
    /// Fig 2 (baseline RLE v1 characterization).
    Fig2,
    /// Fig 3 (baseline Deflate characterization).
    Fig3,
    /// Fig 4 (issue timeline toy).
    Fig4,
    /// Fig 5 (SB/MPT comparison).
    Fig5,
    /// Fig 6 (compute/memory throughput comparison).
    Fig6,
    /// Fig 7 (throughput).
    Fig7,
    /// Fig 8 (speedups incl. prefetch + V100).
    Fig8,
    /// §IV-D micro-benchmark.
    Ubench,
    /// §V-E decode-mode ablation.
    AblationDecode,
}

impl Experiment {
    /// All experiments in paper order.
    pub fn all() -> Vec<Experiment> {
        use Experiment::*;
        vec![
            Table3, Table4, Table5, Fig2, Fig3, Fig4, Fig5, Fig6, Fig7, Fig8, Ubench,
            AblationDecode,
        ]
    }

    /// Parse a CLI name like "fig7" or "table5".
    pub fn parse(s: &str) -> Option<Experiment> {
        use Experiment::*;
        match s.to_ascii_lowercase().as_str() {
            "table3" => Some(Table3),
            "table4" => Some(Table4),
            "table5" => Some(Table5),
            "fig2" => Some(Fig2),
            "fig3" => Some(Fig3),
            "fig4" => Some(Fig4),
            "fig5" => Some(Fig5),
            "fig6" => Some(Fig6),
            "fig7" => Some(Fig7),
            "fig8" => Some(Fig8),
            "ubench" => Some(Ubench),
            "ablation_decode" | "ablation-decode" => Some(AblationDecode),
            _ => None,
        }
    }

    /// Run one experiment against shared workloads.
    pub fn run(&self, workloads: &[Workload], scale: Scale) -> Result<String> {
        use Experiment::*;
        Ok(match self {
            Table3 => tables::table3(),
            Table4 => tables::table4(workloads),
            Table5 => tables::table5(workloads)?,
            Fig2 => figures::fig2(workloads, scale)?,
            Fig3 => figures::fig3(workloads, scale)?,
            Fig4 => figures::fig4(),
            Fig5 => figures::fig5(workloads, scale)?,
            Fig6 => figures::fig6(workloads, scale)?,
            Fig7 => figures::fig7(workloads, scale)?,
            Fig8 => figures::fig8(workloads, scale)?,
            Ubench => figures::ubench(),
            AblationDecode => figures::ablation_decode(workloads, scale)?,
        })
    }
}

/// Run every experiment and return the combined report.
pub fn run_all(scale: Scale) -> Result<String> {
    let workloads = all_workloads(scale)?;
    let mut out = String::new();
    for e in Experiment::all() {
        out.push_str(&e.run(&workloads, scale)?);
        out.push('\n');
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_experiments() {
        assert_eq!(Experiment::parse("fig7"), Some(Experiment::Fig7));
        assert_eq!(Experiment::parse("TABLE5"), Some(Experiment::Table5));
        assert_eq!(Experiment::parse("fig99"), None);
        assert_eq!(Experiment::all().len(), 12);
    }
}
