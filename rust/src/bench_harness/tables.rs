//! Table reproductions: Table III (testbed), Table IV (datasets),
//! Table V (compression ratios + average symbol lengths).

use crate::bench_harness::{fmt_row, Workload};
use crate::codecs::{avg_symbol_len, CodecKind};
use crate::gpu_sim::GpuConfig;
use crate::Result;

/// Table III: the (simulated) testbed configuration.
pub fn table3() -> String {
    let mut s = String::from("Table III — Configuration (simulated testbed)\n");
    s.push_str("  CPU     host worker pool (std::thread, shared-cursor units)\n");
    s.push_str("  Memory  host RAM\n");
    for cfg in [GpuConfig::v100(), GpuConfig::a100()] {
        s.push_str(&format!(
            "  GPU     {} (simulated): {} SMs x {} schedulers, {} warp slots/SM, {:.2} GHz, {:.0} GB/s HBM\n",
            cfg.name,
            cfg.num_sms,
            cfg.schedulers_per_sm,
            cfg.warp_slots_per_sm,
            cfg.clock_ghz,
            cfg.mem_bw_gbps,
        ));
    }
    s
}

/// Table IV: the evaluation datasets (paper sizes + generated sizes).
pub fn table4(workloads: &[Workload]) -> String {
    let widths = [8usize, 14, 8, 12, 14];
    let mut s = String::from("Table IV — Evaluation datasets\n");
    s.push_str(&fmt_row(
        &["Dataset", "Category", "DType", "Paper(GB)", "Generated(B)"]
            .map(String::from),
        &widths,
    ));
    s.push('\n');
    for w in workloads {
        let d = w.dataset;
        s.push_str(&fmt_row(
            &[
                d.name().to_string(),
                d.category().to_string(),
                d.dtype().to_string(),
                format!("{:.2}", d.paper_size_gb()),
                format!("{}", w.data.len()),
            ],
            &widths,
        ));
        s.push('\n');
    }
    s
}

/// Paper Table V reference values (compression ratios), for the
/// side-by-side comparison EXPERIMENTS.md records.
pub fn paper_table5_ratio(d: crate::data::Dataset, kind: CodecKind) -> f64 {
    use crate::data::Dataset::*;
    match (d, kind) {
        (Mc0, CodecKind::RleV1) => 0.023,
        (Mc0, CodecKind::RleV2) => 0.022,
        (Mc0, CodecKind::Deflate) => 0.017,
        (Mc3, CodecKind::RleV1) => 0.038,
        (Mc3, CodecKind::RleV2) => 0.039,
        (Mc3, CodecKind::Deflate) => 0.015,
        (Tpc, CodecKind::RleV1) => 0.867,
        (Tpc, CodecKind::RleV2) => 0.637,
        (Tpc, CodecKind::Deflate) => 0.119,
        (Tpt, CodecKind::RleV1) => 1.41,
        (Tpt, CodecKind::RleV2) => 0.99,
        (Tpt, CodecKind::Deflate) => 0.042,
        (Cd2, CodecKind::RleV1) => 0.286,
        (Cd2, CodecKind::RleV2) => 0.308,
        (Cd2, CodecKind::Deflate) => 0.625,
        (Tc2, CodecKind::RleV1) => 0.087,
        (Tc2, CodecKind::RleV2) => 0.075,
        (Tc2, CodecKind::Deflate) => 0.0172,
        (Hrg, CodecKind::RleV1) => 0.975,
        (Hrg, CodecKind::RleV2) => 0.972,
        (Hrg, CodecKind::Deflate) => 0.305,
        // Codecs the paper did not evaluate (LZSS) have no reference
        // column; NaN renders as "-" in the side-by-side.
        _ => f64::NAN,
    }
}

/// The three codecs the paper's Table V evaluates, in column order.
const PAPER_CODECS: [CodecKind; 3] = [CodecKind::RleV1, CodecKind::RleV2, CodecKind::Deflate];

/// One Table V row: measured ratios + avg symbol lengths vs paper.
#[derive(Debug, Clone)]
pub struct Table5Row {
    /// Dataset name.
    pub dataset: &'static str,
    /// (measured, paper) ratio per codec in [v1, v2, deflate] order.
    pub ratios: [(f64, f64); 3],
    /// Measured LZSS ratio (no paper reference: LZSS is this repo's
    /// GPULZ-style addition, not a paper Table V column).
    pub ratio_lzss: f64,
    /// Average symbol length (elements) for RLE v1 and Deflate.
    pub sym_len_v1: f64,
    /// Average symbol length (bytes) for Deflate.
    pub sym_len_deflate: f64,
}

/// Compute Table V for the given workloads.
pub fn table5_rows(workloads: &[Workload]) -> Result<Vec<Table5Row>> {
    let mut rows = Vec::new();
    for w in workloads {
        let mut ratios = [(0.0, 0.0); 3];
        for (i, kind) in PAPER_CODECS.into_iter().enumerate() {
            ratios[i] = (w.ratio(kind), paper_table5_ratio(w.dataset, kind));
        }
        // Avg symbol length over the first few chunks (stable enough).
        let sym = |kind: CodecKind| -> Result<f64> {
            let c = w.container(kind);
            let n = c.n_chunks().min(4);
            let mut acc = 0.0;
            for i in 0..n {
                acc += avg_symbol_len(kind, c.chunk_bytes(i)?)?;
            }
            Ok(acc / n.max(1) as f64)
        };
        rows.push(Table5Row {
            dataset: w.dataset.name(),
            ratios,
            ratio_lzss: w.ratio(CodecKind::Lzss),
            sym_len_v1: sym(CodecKind::RleV1)?,
            sym_len_deflate: sym(CodecKind::Deflate)?,
        });
    }
    Ok(rows)
}

/// Render Table V.
pub fn table5(workloads: &[Workload]) -> Result<String> {
    let rows = table5_rows(workloads)?;
    let widths = [8usize, 16, 16, 16, 10, 12, 12];
    let mut s = String::from(
        "Table V — Compression ratios (measured | paper) and avg symbol length\n",
    );
    s.push_str(&fmt_row(
        &["Dataset", "RLEv1", "RLEv2", "Deflate", "LZSS", "SymV1", "SymDefl"]
            .map(String::from),
        &widths,
    ));
    s.push('\n');
    for r in rows {
        s.push_str(&fmt_row(
            &[
                r.dataset.to_string(),
                format!("{:.3}|{:.3}", r.ratios[0].0, r.ratios[0].1),
                format!("{:.3}|{:.3}", r.ratios[1].0, r.ratios[1].1),
                format!("{:.3}|{:.3}", r.ratios[2].0, r.ratios[2].1),
                format!("{:.3}|-", r.ratio_lzss),
                format!("{:.1}", r.sym_len_v1),
                format!("{:.1}", r.sym_len_deflate),
            ],
            &widths,
        ));
        s.push('\n');
    }
    Ok(s)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench_harness::Scale;
    use crate::data::Dataset;

    #[test]
    fn table3_mentions_both_gpus() {
        let t = table3();
        assert!(t.contains("A100") && t.contains("V100"));
    }

    #[test]
    fn table5_shape_matches_paper_regimes() {
        let scale = Scale { dataset_bytes: 512 * 1024, sim_chunks: 4 };
        let ws = vec![
            Workload::build(Dataset::Mc0, scale).unwrap(),
            Workload::build(Dataset::Hrg, scale).unwrap(),
        ];
        let rows = table5_rows(&ws).unwrap();
        // MC0: all codecs < 0.1; HRG: RLE ~1, deflate < 0.55.
        assert!(rows[0].ratios[0].0 < 0.1);
        assert!(rows[1].ratios[0].0 > 0.9);
        assert!(rows[1].ratios[2].0 < 0.55);
        // Long runs in MC0, none in HRG.
        assert!(rows[0].sym_len_v1 > 10.0);
        assert!(rows[1].sym_len_v1 < 1.5);
    }
}
