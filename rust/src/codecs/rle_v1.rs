//! ORC Run-Length Encoding version 1 (§II-A).
//!
//! Two ORC RLE v1 flavors, selected by the chunk's element width:
//!
//! * **Byte RLE** (width 1, used for `char` columns like TPT/HRG): a
//!   control byte `c`; `c < 128` encodes a run of `c + 3` copies of the
//!   next byte (runs of 3–130); `c >= 128` encodes `256 - c` literal
//!   bytes (1–128).
//! * **Integer RLE v1** (widths 2/4/8): the same control-byte scheme
//!   where a run additionally carries a signed single-byte *delta* and a
//!   zigzag-varint base value — a run decodes to
//!   `base, base+delta, base+2·delta, …`; literal groups are sequences
//!   of zigzag varints.
//!
//! Decoding maps directly onto the CODAG Table II primitives: a run is
//! one `write_run(init, len, delta)`, a literal group is `len` unit runs.

use crate::codecs::{
    bytes_to_elems, check_rle_chunk_header, decode_rle_sub_block, read_rle_header,
    write_rle_header, Codec, RestartPoint, RestartRec,
};
use crate::decomp::{InputStream, OutputStream, SymbolKind};
use crate::format::varint::{self, uvarint_len};
use crate::{corrupt, Result};

/// The registry entry for ORC RLE v1 (wire id 1).
pub struct RleV1Codec;

impl Codec for RleV1Codec {
    fn name(&self) -> &'static str {
        "rlev1"
    }
    fn wire_id(&self) -> u32 {
        1
    }
    fn aliases(&self) -> &'static [&'static str] {
        &["rle1", "rle_v1"]
    }
    fn is_rle(&self) -> bool {
        true
    }
    fn block_width(&self) -> u32 {
        1024
    }
    fn compress(&self, chunk: &[u8], width: u8) -> Result<Vec<u8>> {
        compress(chunk, width)
    }
    fn compress_with_restarts(
        &self,
        chunk: &[u8],
        width: u8,
        interval: usize,
    ) -> Result<(Vec<u8>, Vec<RestartPoint>)> {
        compress_with_restarts(chunk, width, interval)
    }
    fn decompress_into(&self, comp: &[u8], out: &mut dyn OutputStream) -> Result<()> {
        let mut input = InputStream::new(comp);
        decode(&mut input, out)
    }
    fn decode_sub_block(
        &self,
        comp: &[u8],
        bit_pos: u64,
        _terminal: bool,
        out: &mut [u8],
    ) -> Result<u64> {
        decode_rle_sub_block(comp, bit_pos, out, |input, width, budget, sink| {
            decode_elems(input, width, budget, sink)
        })
    }
    fn check_chunk_header(&self, comp: &[u8], uncomp_len: u64) -> Result<()> {
        check_rle_chunk_header(comp, uncomp_len)
    }
}

/// Maximum run length (`control + 3` with a 7-bit control).
pub const MAX_RUN: usize = 130;
/// Minimum encodable run length.
pub const MIN_RUN: usize = 3;
/// Maximum literal-group length.
pub const MAX_LITERALS: usize = 128;

/// Compress `chunk` (raw little-endian bytes) as `width`-byte elements.
pub fn compress(chunk: &[u8], width: u8) -> Result<Vec<u8>> {
    compress_with_restarts(chunk, width, 0).map(|(out, _)| out)
}

/// Compress recording restart points at control-unit boundaries roughly
/// every `interval` output bytes. Recording is passive: the stream is
/// byte-identical to [`compress`] for every interval.
pub fn compress_with_restarts(
    chunk: &[u8],
    width: u8,
    interval: usize,
) -> Result<(Vec<u8>, Vec<RestartPoint>)> {
    let elems = bytes_to_elems(chunk, width)?;
    let mut out = Vec::with_capacity(chunk.len() / 2 + 16);
    write_rle_header(&mut out, width, elems.len() as u64);
    let mut rec = RestartRec::new(interval, chunk.len() as u64, width);
    if width == 1 {
        compress_bytes(&elems, &mut out, &mut rec);
    } else {
        compress_ints(&elems, &mut out, &mut rec);
    }
    Ok((out, rec.points))
}

/// Byte RLE: runs have delta 0 and no varints.
fn compress_bytes(elems: &[u64], out: &mut Vec<u8>, rec: &mut RestartRec) {
    let mut i = 0usize;
    let n = elems.len();
    let mut lit_start = 0usize;
    while i < n {
        // Length of the equal-run starting at i.
        let mut j = i + 1;
        while j < n && j - i < MAX_RUN && elems[j] == elems[i] {
            j += 1;
        }
        let run = j - i;
        if run >= MIN_RUN {
            flush_byte_literals(elems, lit_start, i, out, rec);
            out.push((run - MIN_RUN) as u8);
            out.push(elems[i] as u8);
            i = j;
            lit_start = i;
            rec.offer(out.len(), i as u64);
        } else {
            i += 1;
        }
    }
    flush_byte_literals(elems, lit_start, n, out, rec);
}

fn flush_byte_literals(
    elems: &[u64],
    mut start: usize,
    end: usize,
    out: &mut Vec<u8>,
    rec: &mut RestartRec,
) {
    while start < end {
        let n = (end - start).min(MAX_LITERALS);
        out.push((256 - n as i32) as u8);
        for k in start..start + n {
            out.push(elems[k] as u8);
        }
        start += n;
        rec.offer(out.len(), start as u64);
    }
}

/// Integer RLE v1: runs carry an i8 delta + zigzag varint base.
fn compress_ints(elems: &[u64], out: &mut Vec<u8>, rec: &mut RestartRec) {
    let mut i = 0usize;
    let n = elems.len();
    let mut lit_start = 0usize;
    while i < n {
        // Detect a constant-delta run with delta representable as i8.
        let mut run = 1usize;
        if i + 1 < n {
            let delta = elems[i + 1].wrapping_sub(elems[i]) as i64;
            if (-128..=127).contains(&delta) {
                let mut j = i + 1;
                while j < n
                    && j - i < MAX_RUN
                    && elems[j].wrapping_sub(elems[j - 1]) as i64 == delta
                {
                    j += 1;
                }
                run = j - i;
            }
        }
        if run >= MIN_RUN {
            let delta = elems[i + 1].wrapping_sub(elems[i]) as i64;
            flush_int_literals(elems, lit_start, i, out, rec);
            out.push((run - MIN_RUN) as u8);
            out.push(delta as i8 as u8);
            varint::write_svarint(out, elems[i] as i64);
            i += run;
            lit_start = i;
            rec.offer(out.len(), i as u64);
        } else {
            i += 1;
        }
    }
    flush_int_literals(elems, lit_start, n, out, rec);
}

fn flush_int_literals(
    elems: &[u64],
    mut start: usize,
    end: usize,
    out: &mut Vec<u8>,
    rec: &mut RestartRec,
) {
    while start < end {
        let n = (end - start).min(MAX_LITERALS);
        out.push((256 - n as i32) as u8);
        for k in start..start + n {
            varint::write_svarint(out, elems[k] as i64);
        }
        start += n;
        rec.offer(out.len(), start as u64);
    }
}

/// Decode an RLE v1 chunk into `out`.
pub fn decode<O: OutputStream + ?Sized>(input: &mut InputStream<'_>, out: &mut O) -> Result<()> {
    let (width, n_elems) = read_rle_header(input)?;
    decode_elems(input, width, n_elems, out)
}

/// Decode exactly `n_elems` elements starting at the cursor — the body
/// of [`decode`], reused by the sub-block restart path
/// ([`crate::codecs::decode_sub_block`]) which positions the cursor at a
/// restart point and bounds the element budget to one sub-block.
pub(crate) fn decode_elems<O: OutputStream + ?Sized>(
    input: &mut InputStream<'_>,
    width: u8,
    n_elems: u64,
    out: &mut O,
) -> Result<()> {
    let mut produced = 0u64;
    while produced < n_elems {
        let ctrl = input.fetch_byte()?;
        if ctrl < 128 {
            // Run of ctrl + 3.
            let len = ctrl as u64 + MIN_RUN as u64;
            if produced + len > n_elems {
                return Err(corrupt("rle_v1: run overruns chunk"));
            }
            // Decode-cost model (GPU leader-thread instruction counts):
            // control-byte branch + input-buffer management (~2 fetch_bits
            // calls at ~12 instrs each) + run setup; varint parsing costs
            // ~10 dependent instrs per byte (load, mask, shift, or,
            // continuation branch).
            let (init, delta, ops) = if width == 1 {
                let b = input.fetch_byte()?;
                (b as u64, 0i64, 300u32)
            } else {
                let delta = input.fetch_byte()? as i8 as i64;
                let base = input.fetch_svarint()?;
                (base as u64, delta, 350 + 40 * uvarint_len(varint::zigzag(base)) as u32)
            };
            out.on_symbol(SymbolKind::RleRun, ops, input.bytes_consumed());
            out.write_run(init, len, delta, width)?;
            produced += len;
        } else {
            // Literal group of 256 - ctrl values.
            let len = 256 - ctrl as u64;
            if produced + len > n_elems {
                return Err(corrupt("rle_v1: literal group overruns chunk"));
            }
            // The group control byte is one decoded descriptor (the
            // baseline broadcasts it once, then the block copies the
            // literals collectively).
            out.on_symbol(SymbolKind::RleLiteralGroup, 280, input.bytes_consumed());
            if width == 1 {
                // Byte literals need no per-element decode: the group
                // is one contiguous input range, borrowed and emitted
                // as a single batched slice write (~2 ops of
                // bookkeeping per element amortized over word copies).
                // Symbol accounting stays per element — same costs and
                // input positions as the scalar loop — so Table V
                // symbol statistics and trace decode ops are unchanged.
                let base = input.bytes_consumed();
                let bytes = input.fetch_bytes(len as usize)?;
                for i in 0..len {
                    out.on_symbol(SymbolKind::RleLiteral, 4, base + i + 1);
                }
                out.write_slice(bytes)?;
            } else {
                // Integer literals: decode the group's varints into a
                // stack element buffer and emit one batched
                // `write_elems` (DESIGN.md §7.4) instead of a
                // `write_run` round-trip per element. Symbol accounting
                // stays per element with unchanged costs and positions.
                let mut elems = [0u64; MAX_LITERALS];
                let elems = &mut elems[..len as usize];
                for e in elems.iter_mut() {
                    let v = input.fetch_svarint()?;
                    let ops = 120 + 40 * uvarint_len(varint::zigzag(v)) as u32;
                    out.on_symbol(SymbolKind::RleLiteral, ops, input.bytes_consumed());
                    *e = v as u64;
                }
                out.write_elems(elems, width)?;
            }
            produced += len;
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codecs::{compress_chunk_with, decompress_chunk, CodecKind};

    fn roundtrip(data: &[u8], width: u8) -> usize {
        let comp = compress(data, width).unwrap();
        let out = decompress_chunk(CodecKind::RleV1, &comp, data.len()).unwrap();
        assert_eq!(out, data, "width {width}");
        comp.len()
    }

    #[test]
    fn byte_rle_runs() {
        let mut data = Vec::new();
        for (b, n) in [(5u8, 200usize), (9, 3), (1, 1), (2, 1), (7, 130)] {
            data.extend(std::iter::repeat(b).take(n));
        }
        let clen = roundtrip(&data, 1);
        assert!(clen < 20, "runs should compress tightly, got {clen}");
    }

    #[test]
    fn byte_rle_all_literals() {
        // Strictly alternating bytes: no run ever reaches length 3.
        let data: Vec<u8> = (0..1000).map(|i| (i % 2) as u8).collect();
        let clen = roundtrip(&data, 1);
        // 1 control byte per 128 literals -> slight expansion over raw.
        assert!(clen > 1000 && clen < 1020);
    }

    #[test]
    fn byte_literal_groups_match_scalar_sink() {
        // The batched slice path for width-1 literal groups must stay
        // byte-identical to the per-byte oracle.
        use crate::decomp::{ByteSink, ScalarSink};
        let data: Vec<u8> = (0..1000).map(|i| (i * 7 % 5) as u8).collect();
        let comp = compress(&data, 1).unwrap();
        let mut batched = ByteSink::new();
        crate::codecs::decode_into(CodecKind::RleV1, &comp, &mut batched).unwrap();
        let mut scalar = ScalarSink::new();
        crate::codecs::decode_into(CodecKind::RleV1, &comp, &mut scalar).unwrap();
        assert_eq!(batched.out, data);
        assert_eq!(batched.out, scalar.out);
    }

    #[test]
    fn int_literal_groups_match_scalar_sink_and_run_recorder() {
        // Batched `write_elems` emission for widths 2/4/8 literal
        // groups must stay byte-identical to the per-element oracle and
        // record-identical (width-faithful) for the expand path.
        use crate::decomp::{ByteSink, RunRecorder, ScalarSink};
        for width in [2u8, 4, 8] {
            let w = width as usize;
            let mut data = Vec::new();
            let mut x = 0xFEEDu64;
            for _ in 0..700 {
                x = x.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
                data.extend_from_slice(&x.to_le_bytes()[..w]);
            }
            let comp = compress(&data, width).unwrap();
            let mut batched = ByteSink::new();
            crate::codecs::decode_into(CodecKind::RleV1, &comp, &mut batched).unwrap();
            let mut scalar = ScalarSink::new();
            crate::codecs::decode_into(CodecKind::RleV1, &comp, &mut scalar).unwrap();
            assert_eq!(batched.out, data, "w{width}");
            assert_eq!(batched.out, scalar.out, "w{width}");
            let mut rec = RunRecorder::new();
            crate::codecs::decode_into(CodecKind::RleV1, &comp, &mut rec).unwrap();
            assert_eq!(rec.width, width, "w{width}");
            assert_eq!(crate::runtime::cpu_expand(&rec.runs, rec.width).unwrap(), data);
        }
    }

    #[test]
    fn int_rle_delta_runs() {
        // 0,1,2,...  is a single delta-1 run (chunked at MAX_RUN).
        let mut data = Vec::new();
        for i in 0..1000u32 {
            data.extend_from_slice(&i.to_le_bytes());
        }
        let clen = roundtrip(&data, 4);
        assert!(clen < 80, "arithmetic sequence should compress, got {clen}");
    }

    #[test]
    fn int_rle_negative_values_and_deltas() {
        let mut data = Vec::new();
        let mut v: i64 = 500;
        for i in 0..600 {
            data.extend_from_slice(&v.to_le_bytes());
            v -= if i % 200 == 0 { 1 } else { 3 };
        }
        roundtrip(&data, 8);
    }

    #[test]
    fn int_rle_random_literals() {
        let mut x = 0x12345678u64;
        let mut data = Vec::new();
        for _ in 0..500 {
            x = x.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            data.extend_from_slice(&x.to_le_bytes());
        }
        roundtrip(&data, 8);
    }

    #[test]
    fn widths_2_and_4() {
        let mut data = Vec::new();
        for i in 0..512u16 {
            data.extend_from_slice(&(i / 7).to_le_bytes());
        }
        roundtrip(&data, 2);
        let mut data4 = Vec::new();
        for i in 0..512u32 {
            data4.extend_from_slice(&(i.wrapping_mul(977)).to_le_bytes());
        }
        roundtrip(&data4, 4);
    }

    #[test]
    fn empty_chunk() {
        let comp = compress(&[], 1).unwrap();
        let out = decompress_chunk(CodecKind::RleV1, &comp, 0).unwrap();
        assert!(out.is_empty());
    }

    #[test]
    fn run_boundary_lengths() {
        // Exactly MIN_RUN, MAX_RUN, MAX_RUN+1 runs.
        for n in [MIN_RUN, MAX_RUN, MAX_RUN + 1, 2 * MAX_RUN] {
            let data = vec![0xABu8; n];
            roundtrip(&data, 1);
        }
    }

    #[test]
    fn truncated_stream_is_corrupt() {
        let data = vec![7u8; 100];
        let comp = compress(&data, 1).unwrap();
        for cut in [comp.len() - 1, 3, 2] {
            assert!(
                decompress_chunk(CodecKind::RleV1, &comp[..cut], 100).is_err(),
                "cut at {cut} must fail"
            );
        }
    }

    #[test]
    fn run_overrunning_header_count_is_corrupt() {
        // Header says 2 elements but stream encodes a 3-run.
        let mut comp = Vec::new();
        write_rle_header(&mut comp, 1, 2);
        comp.push(0); // run len 3
        comp.push(42);
        assert!(decompress_chunk(CodecKind::RleV1, &comp, 2).is_err());
    }

    #[test]
    fn auto_width_prefers_wide_elements_for_u64_data() {
        let mut data = Vec::new();
        for _ in 0..1024u64 {
            data.extend_from_slice(&0xDEAD_BEEF_0000_0001u64.to_le_bytes());
        }
        let comp = compress_chunk_with(CodecKind::RleV1, &data, 8).unwrap();
        let comp1 = compress_chunk_with(CodecKind::RleV1, &data, 1).unwrap();
        assert!(comp.len() < comp1.len());
    }
}
