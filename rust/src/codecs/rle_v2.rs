//! ORC Run-Length Encoding version 2 (§II-A).
//!
//! RLE v2 augments RLE with delta encoding and bit-packing to capture
//! more patterns. A chunk is a sequence of *groups*, each starting with
//! a header whose top two bits select the sub-encoding:
//!
//! * `00` **SHORT_REPEAT** — 3–10 repeats of one value stored in 1–8
//!   big-endian bytes.
//! * `01` **DIRECT** — 1–512 values bit-packed MSB-first at a fixed
//!   width from the closest-fixed-bits table.
//! * `10` **PATCHED_BASE** — like DIRECT but values are offsets from a
//!   base (the group minimum) packed at the 90th-percentile width, with
//!   a patch list restoring the high bits of the few outliers.
//! * `11` **DELTA** — a base value, a first delta, and (unless the run
//!   has a fixed delta) the remaining deltas bit-packed; encodes
//!   monotonic sequences.
//!
//! Values are zigzag-mapped i64s, matching ORC's signed-integer RLE v2.
//! One documented deviation from the on-disk ORC format: PATCHED_BASE
//! stores its base as a zigzag big-endian integer rather than ORC's
//! sign-magnitude (round-trips identically; simplifies the bit path).

use crate::codecs::{
    bytes_to_elems, check_rle_chunk_header, decode_rle_sub_block, read_rle_header,
    write_rle_header, Codec, RestartPoint, RestartRec,
};
use crate::decomp::{InputStream, OutputStream, SymbolKind};
use crate::format::bitio::MsbBitWriter;
use crate::format::varint::{unzigzag, zigzag};
use crate::{corrupt, Result};

/// The registry entry for ORC RLE v2 (wire id 2).
pub struct RleV2Codec;

impl Codec for RleV2Codec {
    fn name(&self) -> &'static str {
        "rlev2"
    }
    fn wire_id(&self) -> u32 {
        2
    }
    fn aliases(&self) -> &'static [&'static str] {
        &["rle2", "rle_v2"]
    }
    fn is_rle(&self) -> bool {
        true
    }
    fn block_width(&self) -> u32 {
        1024
    }
    fn compress(&self, chunk: &[u8], width: u8) -> Result<Vec<u8>> {
        compress(chunk, width)
    }
    fn compress_with_restarts(
        &self,
        chunk: &[u8],
        width: u8,
        interval: usize,
    ) -> Result<(Vec<u8>, Vec<RestartPoint>)> {
        compress_with_restarts(chunk, width, interval)
    }
    fn decompress_into(&self, comp: &[u8], out: &mut dyn OutputStream) -> Result<()> {
        let mut input = InputStream::new(comp);
        decode(&mut input, out)
    }
    fn decode_sub_block(
        &self,
        comp: &[u8],
        bit_pos: u64,
        _terminal: bool,
        out: &mut [u8],
    ) -> Result<u64> {
        decode_rle_sub_block(comp, bit_pos, out, |input, width, budget, sink| {
            decode_elems(input, width, budget, sink)
        })
    }
    fn check_chunk_header(&self, comp: &[u8], uncomp_len: u64) -> Result<()> {
        check_rle_chunk_header(comp, uncomp_len)
    }
}

/// Maximum values per DIRECT/PATCHED/DELTA group.
pub const MAX_GROUP: usize = 512;
/// SHORT_REPEAT length bounds.
pub const SR_MIN: usize = 3;
/// SHORT_REPEAT maximum repeat count.
pub const SR_MAX: usize = 10;

/// Sub-encoding discriminants (header bits 7–6).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SubEncoding {
    /// 3–10 repeats of a single value.
    ShortRepeat = 0,
    /// Fixed-width bit-packed values.
    Direct = 1,
    /// Base + reduced values + patch list.
    PatchedBase = 2,
    /// Base + deltas.
    Delta = 3,
}

/// Decode the 5-bit closest-fixed-bits width code (DIRECT/PATCHED).
#[inline]
pub fn decode_width(code: u8) -> u32 {
    match code {
        0..=23 => code as u32 + 1,
        24 => 26,
        25 => 28,
        26 => 30,
        27 => 32,
        28 => 40,
        29 => 48,
        30 => 56,
        _ => 64,
    }
}

/// Encode a bit width to the smallest 5-bit code covering it.
#[inline]
pub fn encode_width(bits: u32) -> u8 {
    match bits {
        0..=24 => bits.max(1) as u8 - 1,
        25..=26 => 24,
        27..=28 => 25,
        29..=30 => 26,
        31..=32 => 27,
        33..=40 => 28,
        41..=48 => 29,
        49..=56 => 30,
        _ => 31,
    }
}

/// Delta-group width code: 0 means "fixed delta, no packed deltas".
#[inline]
fn decode_delta_width(code: u8) -> u32 {
    if code == 0 {
        0
    } else {
        decode_width(code)
    }
}

/// Bits needed to represent `v`.
#[inline]
fn bits_for(v: u64) -> u32 {
    (64 - v.leading_zeros()).max(1)
}

// ---------------------------------------------------------------------
// Encoder
// ---------------------------------------------------------------------

/// Compress `chunk` (little-endian bytes) as `width`-byte elements.
pub fn compress(chunk: &[u8], width: u8) -> Result<Vec<u8>> {
    compress_with_restarts(chunk, width, 0).map(|(out, _)| out)
}

/// Compress recording restart points at group boundaries roughly every
/// `interval` output bytes. Recording is passive: the stream is
/// byte-identical to [`compress`] for every interval.
pub fn compress_with_restarts(
    chunk: &[u8],
    width: u8,
    interval: usize,
) -> Result<(Vec<u8>, Vec<RestartPoint>)> {
    let elems = bytes_to_elems(chunk, width)?;
    // Work on sign-extended i64 views for widths < 8 so negative i8/i32
    // columns zigzag compactly; the bit pattern is restored on decode by
    // masking to the element width.
    let vals: Vec<i64> = elems
        .iter()
        .map(|&e| sign_extend(e, width))
        .collect();
    let mut out = Vec::with_capacity(chunk.len() / 2 + 16);
    write_rle_header(&mut out, width, vals.len() as u64);
    let mut rec = RestartRec::new(interval, chunk.len() as u64, width);
    let mut i = 0usize;
    while i < vals.len() {
        i += emit_group(&vals[i..], &mut out);
        rec.offer(out.len(), i as u64);
    }
    Ok((out, rec.points))
}

/// Sign-extend the low `width` bytes of `e`.
#[inline]
fn sign_extend(e: u64, width: u8) -> i64 {
    match width {
        1 => e as u8 as i8 as i64,
        2 => e as u16 as i16 as i64,
        4 => e as u32 as i32 as i64,
        _ => e as i64,
    }
}

/// Emit one group for the prefix of `vals`; returns values consumed.
fn emit_group(vals: &[i64], out: &mut Vec<u8>) -> usize {
    debug_assert!(!vals.is_empty());
    // 1. Equal run?
    let eq = run_len_equal(vals).min(MAX_GROUP);
    if (SR_MIN..=SR_MAX).contains(&eq) {
        emit_short_repeat(vals[0], eq, out);
        return eq;
    }
    if eq > SR_MAX {
        emit_delta_fixed(vals[0], 0, eq, out);
        return eq;
    }
    // 2. Constant-delta run?
    let cd = run_len_const_delta(vals).min(MAX_GROUP);
    if cd >= 4 {
        let delta = vals[1].wrapping_sub(vals[0]);
        emit_delta_fixed(vals[0], delta, cd, out);
        return cd;
    }
    // 3. Monotonic prefix worth a packed DELTA group?
    let mono = monotonic_len(vals).min(MAX_GROUP);
    if mono >= 8 {
        let take = mono;
        if delta_packed_bits(&vals[..take]) * 2 < direct_bits(&vals[..take]) {
            emit_delta_packed(&vals[..take], out);
            return take;
        }
    }
    // 4. Literal segment: up to the next run start (or MAX_GROUP), then
    //    DIRECT or PATCHED_BASE.
    let mut end = 1usize;
    while end < vals.len() && end < MAX_GROUP {
        // Stop the literal segment when a profitable run begins.
        if run_len_equal(&vals[end..]) >= SR_MIN || run_len_const_delta(&vals[end..]) >= 4 {
            break;
        }
        end += 1;
    }
    let seg = &vals[..end];
    if let Some(plan) = plan_patched(seg) {
        emit_patched(seg, &plan, out);
    } else {
        emit_direct(seg, out);
    }
    end
}

fn run_len_equal(vals: &[i64]) -> usize {
    let mut n = 1;
    while n < vals.len() && vals[n] == vals[0] {
        n += 1;
    }
    n
}

fn run_len_const_delta(vals: &[i64]) -> usize {
    if vals.len() < 2 {
        return vals.len();
    }
    let d = vals[1].wrapping_sub(vals[0]);
    let mut n = 2;
    while n < vals.len() && vals[n].wrapping_sub(vals[n - 1]) == d {
        n += 1;
    }
    n
}

fn monotonic_len(vals: &[i64]) -> usize {
    if vals.len() < 2 {
        return vals.len();
    }
    let up = vals[1] >= vals[0];
    let mut n = 2;
    while n < vals.len() && ((vals[n] >= vals[n - 1]) == up) {
        n += 1;
    }
    n
}

fn direct_bits(vals: &[i64]) -> u64 {
    let w = vals.iter().map(|&v| bits_for(zigzag(v))).max().unwrap_or(1);
    decode_width(encode_width(w)) as u64 * vals.len() as u64
}

fn delta_packed_bits(vals: &[i64]) -> u64 {
    let w = vals
        .windows(2)
        .map(|p| bits_for(p[1].wrapping_sub(p[0]).unsigned_abs()))
        .max()
        .unwrap_or(1);
    decode_width(encode_width(w)) as u64 * (vals.len() as u64 - 1)
}

fn emit_short_repeat(v: i64, count: usize, out: &mut Vec<u8>) {
    let zz = zigzag(v);
    let nbytes = ((bits_for(zz) + 7) / 8).max(1) as usize;
    out.push(((SubEncoding::ShortRepeat as u8) << 6)
        | (((nbytes - 1) as u8) << 3)
        | ((count - SR_MIN) as u8));
    for i in (0..nbytes).rev() {
        out.push((zz >> (i * 8)) as u8);
    }
}

/// Write a DIRECT/PATCHED/DELTA 2-byte header: tag(2) wc(5) len-1(9).
fn push_group_header(tag: SubEncoding, width_code: u8, len: usize, out: &mut Vec<u8>) {
    debug_assert!((1..=MAX_GROUP).contains(&len));
    let l = (len - 1) as u16;
    out.push(((tag as u8) << 6) | (width_code << 1) | ((l >> 8) as u8));
    out.push((l & 0xFF) as u8);
}

fn emit_delta_fixed(base: i64, delta: i64, len: usize, out: &mut Vec<u8>) {
    push_group_header(SubEncoding::Delta, 0, len, out);
    let mut tmp = Vec::new();
    crate::format::varint::write_svarint(&mut tmp, base);
    crate::format::varint::write_svarint(&mut tmp, delta);
    out.extend_from_slice(&tmp);
}

fn emit_delta_packed(vals: &[i64], out: &mut Vec<u8>) {
    debug_assert!(vals.len() >= 2);
    let deltas: Vec<u64> = vals
        .windows(2)
        .map(|p| p[1].wrapping_sub(p[0]).unsigned_abs())
        .collect();
    let w = deltas.iter().skip(1).map(|&d| bits_for(d)).max().unwrap_or(1);
    let wc = encode_width(w);
    debug_assert!(wc != 0 || w <= 1);
    let wc = wc.max(1); // width code 0 is reserved for fixed-delta
    push_group_header(SubEncoding::Delta, wc, vals.len(), out);
    crate::format::varint::write_svarint(out, vals[0]);
    crate::format::varint::write_svarint(out, vals[1].wrapping_sub(vals[0]));
    let mut bw = MsbBitWriter::new();
    bw.pack_from(decode_width(wc), &deltas[1..]);
    out.extend_from_slice(&bw.finish());
}

fn emit_direct(vals: &[i64], out: &mut Vec<u8>) {
    debug_assert!(vals.len() <= MAX_GROUP);
    let w = vals.iter().map(|&v| bits_for(zigzag(v))).max().unwrap_or(1);
    let wc = encode_width(w);
    push_group_header(SubEncoding::Direct, wc, vals.len(), out);
    let mut zz = [0u64; MAX_GROUP];
    for (z, &v) in zz.iter_mut().zip(vals) {
        *z = zigzag(v);
    }
    let mut bw = MsbBitWriter::new();
    bw.pack_from(decode_width(wc), &zz[..vals.len()]);
    out.extend_from_slice(&bw.finish());
}

/// PATCHED_BASE plan: packing width, patch width, and outlier positions.
struct PatchPlan {
    base: i64,
    /// Width (bits) the reduced values are packed at (90th percentile).
    width: u32,
    /// Patch width in bits (high bits of outliers).
    patch_width: u32,
    /// (gap-encoded) outlier index list.
    patches: Vec<(u8, u64)>,
}

/// Decide whether `vals` benefits from PATCHED_BASE; build the plan if so.
fn plan_patched(vals: &[i64]) -> Option<PatchPlan> {
    if vals.len() < 20 {
        return None;
    }
    let base = *vals.iter().min().unwrap();
    // Reduced values must fit u64 (they do: i64 range spans < 2^64).
    let reduced: Vec<u64> = vals.iter().map(|&v| (v as i128 - base as i128) as u64).collect();
    let mut widths: Vec<u32> = reduced.iter().map(|&r| bits_for(r)).collect();
    widths.sort_unstable();
    let w100 = *widths.last().unwrap();
    let w90 = widths[(widths.len() * 9 / 10).min(widths.len() - 1)];
    let w90 = decode_width(encode_width(w90));
    if w100 <= w90 {
        return None; // no outliers; DIRECT is as good
    }
    let patch_width = decode_width(encode_width(w100 - w90));
    // Build the gap-encoded patch list (8-bit gaps, dummy entries for
    // gaps > 255 like ORC).
    let mut patches: Vec<(u8, u64)> = Vec::new();
    let mut last = 0usize;
    for (i, &r) in reduced.iter().enumerate() {
        let high = r >> w90;
        if high != 0 {
            let mut gap = i - last;
            while gap > 255 {
                patches.push((255, 0));
                gap -= 255;
            }
            patches.push((gap as u8, high));
            last = i;
        }
    }
    if patches.is_empty() || patches.len() > 31 {
        return None;
    }
    // Profitable only if the narrower packing pays for the patch list.
    let direct_cost = decode_width(encode_width(w100)) as u64 * vals.len() as u64;
    let patched_cost = w90 as u64 * vals.len() as u64
        + patches.len() as u64 * (8 + patch_width as u64)
        + 8 * 8;
    if patched_cost >= direct_cost {
        return None;
    }
    Some(PatchPlan { base, width: w90, patch_width, patches })
}

fn emit_patched(vals: &[i64], plan: &PatchPlan, out: &mut Vec<u8>) {
    let wc = encode_width(plan.width);
    push_group_header(SubEncoding::PatchedBase, wc, vals.len(), out);
    let base_zz = zigzag(plan.base);
    let bw_bytes = ((bits_for(base_zz) + 7) / 8).max(1) as usize;
    let pwc = encode_width(plan.patch_width);
    out.push((((bw_bytes - 1) as u8) << 5) | pwc);
    // Patch gap width fixed at 8 bits (code 7 = 8 bits in the 3-bit
    // field); patch list length in the low 5 bits.
    out.push((7u8 << 5) | (plan.patches.len() as u8));
    for i in (0..bw_bytes).rev() {
        out.push((base_zz >> (i * 8)) as u8);
    }
    let width = decode_width(wc);
    debug_assert!(vals.len() <= MAX_GROUP);
    let mut reduced = [0u64; MAX_GROUP];
    for (r, &v) in reduced.iter_mut().zip(vals) {
        *r = (v as i128 - plan.base as i128) as u64;
    }
    let mut packer = MsbBitWriter::new();
    packer.pack_from(width, &reduced[..vals.len()]);
    out.extend_from_slice(&packer.finish());
    let pw = decode_width(pwc);
    let mut packer = MsbBitWriter::new();
    for &(gap, high) in &plan.patches {
        packer.put_bits(gap as u64, 8);
        packer.put_bits(high, pw);
    }
    out.extend_from_slice(&packer.finish());
}

// ---------------------------------------------------------------------
// Decoder
// ---------------------------------------------------------------------

/// Convert a bit count into the rounded-up byte position the MSB reader
/// reports after consuming it — used to reconstruct per-element
/// `on_symbol` input positions analytically, so the bulk-unpacked
/// decode reports the exact positions the element-at-a-time loop did.
#[inline]
fn bits_to_pos(bits: u64) -> u64 {
    (bits + 7) / 8
}

/// Decode an RLE v2 chunk into `out`.
pub fn decode<O: OutputStream + ?Sized>(input: &mut InputStream<'_>, out: &mut O) -> Result<()> {
    let (width, n_elems) = read_rle_header(input)?;
    decode_elems(input, width, n_elems, out)
}

/// Decode exactly `n_elems` elements starting at the cursor — the body
/// of [`decode`], reused by the sub-block restart path
/// ([`crate::codecs::decode_sub_block`]) which positions the cursor at a
/// restart point and bounds the element budget to one sub-block.
pub(crate) fn decode_elems<O: OutputStream + ?Sized>(
    input: &mut InputStream<'_>,
    width: u8,
    n_elems: u64,
    out: &mut O,
) -> Result<()> {
    let mask = if width == 8 { u64::MAX } else { (1u64 << (width as u32 * 8)) - 1 };
    let mut produced = 0u64;
    while produced < n_elems {
        let first = input.fetch_byte()?;
        let tag = first >> 6;
        let n = match tag {
            0 => decode_short_repeat(first, input, out, width, mask, n_elems - produced)?,
            1 => decode_direct(first, input, out, width, mask, n_elems - produced)?,
            2 => decode_patched(first, input, out, width, mask, n_elems - produced)?,
            _ => decode_delta(first, input, out, width, mask, n_elems - produced)?,
        };
        produced += n;
    }
    Ok(())
}

fn decode_short_repeat<O: OutputStream + ?Sized>(
    first: u8,
    input: &mut InputStream<'_>,
    out: &mut O,
    width: u8,
    mask: u64,
    budget: u64,
) -> Result<u64> {
    let nbytes = ((first >> 3) & 0x7) as usize + 1;
    let count = (first & 0x7) as u64 + SR_MIN as u64;
    if count > budget {
        return Err(corrupt("rle_v2: short-repeat overruns chunk"));
    }
    let mut zz = 0u64;
    for _ in 0..nbytes {
        zz = (zz << 8) | input.fetch_byte()? as u64;
    }
    let v = unzigzag(zz) as u64 & mask;
    out.on_symbol(SymbolKind::RleRun, 380 + 10 * nbytes as u32, input.bytes_consumed());
    out.write_run(v, count, 0, width)?;
    Ok(count)
}

/// Parse the common `wc(5) len(9)` tail of a group header.
fn parse_header_tail(first: u8, input: &mut InputStream<'_>) -> Result<(u8, usize)> {
    let wc = (first >> 1) & 0x1F;
    let len_hi = (first & 1) as usize;
    let len_lo = input.fetch_byte()? as usize;
    Ok((wc, (len_hi << 8 | len_lo) + 1))
}

fn decode_direct<O: OutputStream + ?Sized>(
    first: u8,
    input: &mut InputStream<'_>,
    out: &mut O,
    width: u8,
    mask: u64,
    budget: u64,
) -> Result<u64> {
    let (wc, len) = parse_header_tail(first, input)?;
    if len as u64 > budget {
        return Err(corrupt("rle_v2: direct group overruns chunk"));
    }
    let w = decode_width(wc);
    out.on_symbol(SymbolKind::RleV2Header, 400, input.bytes_consumed());
    // Bulk path: one wide-lane unpack fills the whole group, the zigzag
    // unmap runs over the element buffer, and a single `write_elems`
    // serializes it. Per-element symbol accounting (costs, input
    // positions) is reconstructed analytically and is unchanged from
    // the element-at-a-time loop.
    let mut elems = [0u64; MAX_GROUP];
    let elems = &mut elems[..len];
    let mut r = input.msb_reader();
    r.unpack_into(w, elems)?;
    let base_pos = input.bytes_consumed();
    for (i, e) in elems.iter_mut().enumerate() {
        *e = unzigzag(*e) as u64 & mask;
        out.on_symbol(
            SymbolKind::RleLiteral,
            90 + w / 2,
            base_pos + bits_to_pos((i as u64 + 1) * w as u64),
        );
    }
    out.write_elems(elems, width)?;
    input.commit_msb(&r);
    Ok(len as u64)
}

fn decode_patched<O: OutputStream + ?Sized>(
    first: u8,
    input: &mut InputStream<'_>,
    out: &mut O,
    width: u8,
    mask: u64,
    budget: u64,
) -> Result<u64> {
    let (wc, len) = parse_header_tail(first, input)?;
    if len as u64 > budget {
        return Err(corrupt("rle_v2: patched group overruns chunk"));
    }
    let b3 = input.fetch_byte()?;
    let bw_bytes = ((b3 >> 5) & 0x7) as usize + 1;
    let pwc = b3 & 0x1F;
    let b4 = input.fetch_byte()?;
    let pgw = ((b4 >> 5) & 0x7) as u32 + 1;
    let pll = (b4 & 0x1F) as usize;
    let mut base_zz = 0u64;
    for _ in 0..bw_bytes {
        base_zz = (base_zz << 8) | input.fetch_byte()? as u64;
    }
    let base = unzigzag(base_zz);
    let w = decode_width(wc);
    out.on_symbol(SymbolKind::RleV2Header, 700, input.bytes_consumed());
    // Bulk-unpack the reduced values into the group element buffer.
    let mut elems = [0u64; MAX_GROUP];
    let elems = &mut elems[..len];
    {
        let mut r = input.msb_reader();
        r.unpack_into(w, elems)?;
        input.commit_msb(&r);
    }
    // Apply the patch list over the element buffer.
    let pw = decode_width(pwc);
    {
        let mut r = input.msb_reader();
        let mut idx = 0usize;
        for _ in 0..pll {
            let gap = r.read_bits(pgw)? as usize;
            let high = r.read_bits(pw)?;
            idx += gap;
            if high != 0 {
                if idx >= elems.len() {
                    return Err(corrupt("rle_v2: patch index out of range"));
                }
                // w == 64 leaves no headroom for patch bits: the shift
                // would be out of range, and the reference decoder port
                // treats such patches as no-ops (bits beyond 64 drop).
                if w < 64 {
                    elems[idx] |= high << w;
                }
            }
        }
        input.commit_msb(&r);
    }
    // Base-add over the buffer, then one batched element write.
    let pos = input.bytes_consumed();
    for e in elems.iter_mut() {
        *e = (base as i128 + *e as i128) as u64 & mask;
        out.on_symbol(SymbolKind::RleLiteral, 110 + w / 2, pos);
    }
    out.write_elems(elems, width)?;
    Ok(len as u64)
}

fn decode_delta<O: OutputStream + ?Sized>(
    first: u8,
    input: &mut InputStream<'_>,
    out: &mut O,
    width: u8,
    mask: u64,
    budget: u64,
) -> Result<u64> {
    let (wc, len) = parse_header_tail(first, input)?;
    if len as u64 > budget {
        return Err(corrupt("rle_v2: delta group overruns chunk"));
    }
    let base = input.fetch_svarint()?;
    let d1 = input.fetch_svarint()?;
    let w = decode_delta_width(wc);
    if w == 0 {
        // Fixed-delta run: a single write_run covers the whole group.
        out.on_symbol(SymbolKind::RleRun, 450, input.bytes_consumed());
        out.write_run(base as u64 & mask, len as u64, d1, width)?;
        return Ok(len as u64);
    }
    if len < 2 {
        return Err(corrupt("rle_v2: packed delta group shorter than 2"));
    }
    out.on_symbol(SymbolKind::RleV2Header, 450, input.bytes_consumed());
    // Bulk path: unpack the packed deltas into the tail of the group
    // element buffer, run the prefix-sum transform in place, and emit
    // the whole group with one `write_elems`.
    let mut elems = [0u64; MAX_GROUP];
    let elems = &mut elems[..len];
    elems[0] = base as u64 & mask;
    let mut prev = base.wrapping_add(d1);
    out.on_symbol(SymbolKind::RleLiteral, 60, input.bytes_consumed());
    elems[1] = prev as u64 & mask;
    let sign: i64 = if d1 < 0 { -1 } else { 1 };
    let mut r = input.msb_reader();
    r.unpack_into(w, &mut elems[2..])?;
    let base_pos = input.bytes_consumed();
    for i in 2..len {
        // Wrapping throughout (ORC's integer overflow semantics): a
        // width-64 delta can be i64::MIN, whose negation only exists
        // under wrapping multiplication.
        prev = prev.wrapping_add(sign.wrapping_mul(elems[i] as i64));
        elems[i] = prev as u64 & mask;
        out.on_symbol(
            SymbolKind::RleLiteral,
            90 + w / 2,
            base_pos + bits_to_pos((i as u64 - 1) * w as u64),
        );
    }
    out.write_elems(elems, width)?;
    input.commit_msb(&r);
    Ok(len as u64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codecs::{decompress_chunk, CodecKind};

    fn roundtrip(data: &[u8], width: u8) -> usize {
        let comp = compress(data, width).unwrap();
        let out = decompress_chunk(CodecKind::RleV2, &comp, data.len()).unwrap();
        assert_eq!(out, data, "width {width}");
        comp.len()
    }

    fn as_bytes_u64(vals: &[u64]) -> Vec<u8> {
        vals.iter().flat_map(|v| v.to_le_bytes()).collect()
    }

    fn as_bytes_i64(vals: &[i64]) -> Vec<u8> {
        vals.iter().flat_map(|v| v.to_le_bytes()).collect()
    }

    #[test]
    fn width_table_roundtrip() {
        for bits in 1..=64u32 {
            let code = encode_width(bits);
            assert!(decode_width(code) >= bits, "bits {bits}");
        }
        assert_eq!(decode_width(encode_width(1)), 1);
        assert_eq!(decode_width(encode_width(24)), 24);
        assert_eq!(decode_width(encode_width(25)), 26);
        assert_eq!(decode_width(encode_width(33)), 40);
        assert_eq!(decode_width(encode_width(64)), 64);
    }

    #[test]
    fn short_repeat_exact() {
        for n in SR_MIN..=SR_MAX {
            let data = as_bytes_u64(&vec![0xABCDu64; n]);
            let clen = roundtrip(&data, 8);
            // header + 2 value bytes + chunk header
            assert!(clen <= 8, "n={n} clen={clen}");
        }
    }

    #[test]
    fn long_equal_run_uses_fixed_delta() {
        let data = as_bytes_u64(&vec![7u64; 5000]);
        let clen = roundtrip(&data, 8);
        // 5000/512 = 10 groups x ~4 bytes.
        assert!(clen < 64, "clen={clen}");
    }

    #[test]
    fn arithmetic_sequence_fixed_delta() {
        let vals: Vec<i64> = (0..2000).map(|i| 1000 - 3 * i).collect();
        let data = as_bytes_i64(&vals);
        let clen = roundtrip(&data, 8);
        assert!(clen < 48, "clen={clen}");
    }

    #[test]
    fn monotonic_packed_delta() {
        // Monotonic with small varying deltas.
        let mut v = 0i64;
        let mut x = 99u64;
        let vals: Vec<i64> = (0..1000)
            .map(|_| {
                x = x.wrapping_mul(6364136223846793005).wrapping_add(1);
                v += (x >> 60) as i64; // deltas 0..15
                v
            })
            .collect();
        let data = as_bytes_i64(&vals);
        let clen = roundtrip(&data, 8);
        // Packed deltas at <=8 bits vs 8-byte raw values.
        assert!(clen < data.len() / 4, "clen={clen}");
    }

    #[test]
    fn random_values_direct() {
        let mut x = 42u64;
        let vals: Vec<i64> = (0..700)
            .map(|_| {
                x = x.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
                (x >> 32) as i64 - (1 << 30)
            })
            .collect();
        let data = as_bytes_i64(&vals);
        roundtrip(&data, 8);
    }

    #[test]
    fn power_law_outliers_use_patched_base() {
        // Mostly small values with a few huge outliers: PATCHED_BASE.
        let mut x = 7u64;
        let vals: Vec<i64> = (0..512)
            .map(|i| {
                x = x.wrapping_mul(6364136223846793005).wrapping_add(1);
                if i % 100 == 50 {
                    1 << 45
                } else {
                    (x % 1000) as i64
                }
            })
            .collect();
        let data = as_bytes_i64(&vals);
        let comp = compress(&data, 8).unwrap();
        // Contains at least one PATCHED_BASE group (tag bits 10).
        let has_patched = comp[4..].iter().any(|&b| b >> 6 == 2);
        assert!(has_patched, "expected a patched-base group");
        let out = decompress_chunk(CodecKind::RleV2, &comp, data.len()).unwrap();
        assert_eq!(out, data);
        assert!(comp.len() < data.len() / 3);
    }

    #[test]
    fn negative_and_extreme_values() {
        let vals = vec![i64::MIN, i64::MAX, -1, 0, 1, i64::MIN + 1, i64::MAX - 1, -42];
        let data = as_bytes_i64(&vals);
        roundtrip(&data, 8);
    }

    #[test]
    fn narrow_widths() {
        // i8-ish data in width 1.
        let data: Vec<u8> = (0..3000).map(|i| ((i * 7) % 11) as u8).collect();
        roundtrip(&data, 1);
        // u16 data with runs.
        let mut d2 = Vec::new();
        for i in 0..1500u16 {
            d2.extend_from_slice(&(i / 100).to_le_bytes());
        }
        roundtrip(&d2, 2);
        // i32 negative data.
        let mut d4 = Vec::new();
        for i in 0..800i32 {
            d4.extend_from_slice(&(-i * 3).to_le_bytes());
        }
        roundtrip(&d4, 4);
    }

    #[test]
    fn empty_chunk() {
        let comp = compress(&[], 8).unwrap();
        assert_eq!(decompress_chunk(CodecKind::RleV2, &comp, 0).unwrap(), Vec::<u8>::new());
    }

    #[test]
    fn width1_groups_match_scalar_sink() {
        // Width-1 batched slice emission (direct / patched / packed
        // delta) must stay byte-identical to the per-byte oracle.
        use crate::decomp::{ByteSink, ScalarSink};
        let mut data: Vec<u8> = Vec::new();
        for i in 0..600u32 {
            data.push((i * 7 % 11) as u8); // literal-ish -> DIRECT
        }
        data.extend(std::iter::repeat(3u8).take(100)); // long run -> DELTA w=0
        let mut v = 0u8;
        let mut x = 17u64;
        for _ in 0..400 {
            x = x.wrapping_mul(6364136223846793005).wrapping_add(1);
            v = v.wrapping_add((x >> 61) as u8); // monotonic -> packed DELTA
            data.push(v);
        }
        let comp = compress(&data, 1).unwrap();
        let mut batched = ByteSink::new();
        crate::codecs::decode_into(CodecKind::RleV2, &comp, &mut batched).unwrap();
        let mut scalar = ScalarSink::new();
        crate::codecs::decode_into(CodecKind::RleV2, &comp, &mut scalar).unwrap();
        assert_eq!(batched.out, data);
        assert_eq!(batched.out, scalar.out);
    }

    #[test]
    fn all_width_groups_match_scalar_sink_and_run_recorder() {
        // The bulk path (unpack_into + write_elems) must stay byte-
        // identical to the per-byte oracle AND record-identical to the
        // per-element run path at every width, for direct, patched, and
        // packed-delta groups.
        use crate::decomp::{ByteSink, RunRecorder, ScalarSink};
        for width in [1u8, 2, 4, 8] {
            let w = width as usize;
            let mut data: Vec<u8> = Vec::new();
            let mut x = 5u64;
            let push = |data: &mut Vec<u8>, v: i64| {
                data.extend_from_slice(&v.to_le_bytes()[..w]);
            };
            // Literal-ish values -> DIRECT.
            for i in 0..300i64 {
                push(&mut data, (i * 37) % 97 - 48);
            }
            // Small values + periodic outliers -> PATCHED_BASE.
            for i in 0..512i64 {
                x = x.wrapping_mul(6364136223846793005).wrapping_add(1);
                let v = if i % 64 == 13 {
                    100 + (1 << (w as i64 * 8 - 2))
                } else {
                    (x % 13) as i64
                };
                push(&mut data, v);
            }
            // Monotonic small-delta values -> packed DELTA.
            let mut v = 0i64;
            for _ in 0..400 {
                x = x.wrapping_mul(6364136223846793005).wrapping_add(1);
                v += (x >> 61) as i64;
                push(&mut data, v);
            }
            let comp = compress(&data, width).unwrap();
            let mut batched = ByteSink::new();
            crate::codecs::decode_into(CodecKind::RleV2, &comp, &mut batched).unwrap();
            let mut scalar = ScalarSink::new();
            crate::codecs::decode_into(CodecKind::RleV2, &comp, &mut scalar).unwrap();
            assert_eq!(batched.out, data, "w{width}: roundtrip");
            assert_eq!(batched.out, scalar.out, "w{width}: batched/scalar divergence");
            // Run records keep the element width and expand back.
            let mut rec = RunRecorder::new();
            crate::codecs::decode_into(CodecKind::RleV2, &comp, &mut rec).unwrap();
            assert_eq!(rec.width, width, "w{width}: run record width");
            assert_eq!(
                crate::runtime::cpu_expand(&rec.runs, rec.width).unwrap(),
                data,
                "w{width}: run records re-expand"
            );
        }
    }

    #[test]
    fn direct_w64_extremes_roundtrip() {
        // Max-width (64-bit) DIRECT group: zigzag of the i64 extremes
        // needs every bit, driving unpack_into's wide class.
        let vals = vec![i64::MIN, i64::MAX, -1, 0, 1, i64::MIN >> 1, i64::MAX >> 1];
        let data = as_bytes_i64(&vals);
        let comp = compress(&data, 8).unwrap();
        // Must be a single DIRECT group at width code 31 (64 bits).
        // (Chunk header is 3 bytes here: width, reserved, uvarint(7).)
        assert_eq!(comp[3] >> 6, SubEncoding::Direct as u8, "expected DIRECT");
        assert_eq!((comp[3] >> 1) & 0x1F, 31, "expected width code 31 (64 bits)");
        let out = decompress_chunk(CodecKind::RleV2, &comp, data.len()).unwrap();
        assert_eq!(out, data);
    }

    #[test]
    fn truncated_groups_are_corrupt() {
        let data = as_bytes_u64(&(0..600).map(|i| i * i).collect::<Vec<u64>>());
        let comp = compress(&data, 8).unwrap();
        for cut in [comp.len() - 1, comp.len() / 2, 5, 4, 3] {
            assert!(decompress_chunk(CodecKind::RleV2, &comp[..cut], data.len()).is_err());
        }
    }

    #[test]
    fn group_boundary_512() {
        for n in [511usize, 512, 513, 1024, 1025] {
            let mut x = 3u64;
            let vals: Vec<i64> = (0..n)
                .map(|_| {
                    x = x.wrapping_mul(2862933555777941757).wrapping_add(3037000493);
                    (x >> 40) as i64
                })
                .collect();
            roundtrip(&as_bytes_i64(&vals), 8);
        }
    }
}
