//! LZ77 match finding for the DEFLATE encoder.
//!
//! Hash-chain matcher in the zlib style: 3-byte hashes index a head
//! table, collisions chain through `prev`, and a lazy one-step evaluation
//! defers emitting a match when the next position matches longer. Window
//! 32 KiB, match lengths 3–258 — the RFC 1951 limits.

/// Minimum DEFLATE match length.
pub const MIN_MATCH: usize = 3;
/// Maximum DEFLATE match length.
pub const MAX_MATCH: usize = 258;
/// Maximum backward distance.
pub const MAX_DIST: usize = 32 * 1024;

const HASH_BITS: u32 = 15;
const HASH_SIZE: usize = 1 << HASH_BITS;
/// Cap on chain walks per position (zlib level-9 uses 4096 but pairs it
/// with good/nice cutoffs; 256 with the cutoffs below gives level-9-ish
/// ratios at a fraction of the worst-case cost on tiny alphabets).
const MAX_CHAIN: usize = 256;
/// Stop searching when a match at least this long is found.
const NICE_LENGTH: usize = 192;
/// Once a match of at least this length is in hand, quarter the
/// remaining chain budget (zlib's `good_match` heuristic).
const GOOD_LENGTH: usize = 32;

/// One LZ77 token.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Token {
    /// A literal byte.
    Literal(u8),
    /// A back-reference: copy `len` bytes from `dist` bytes back.
    Match { len: u16, dist: u16 },
}

#[inline]
fn hash3(data: &[u8], i: usize) -> usize {
    let v = (data[i] as u32) | ((data[i + 1] as u32) << 8) | ((data[i + 2] as u32) << 16);
    (v.wrapping_mul(0x9E37_79B1) >> (32 - HASH_BITS)) as usize
}

/// Length of the common prefix of `data[a..]` and `data[b..]`, capped.
#[inline]
fn match_len(data: &[u8], a: usize, b: usize, cap: usize) -> usize {
    let max = cap.min(data.len() - b);
    let mut n = 0;
    // 8-byte strides on the hot path.
    while n + 8 <= max {
        let x = u64::from_le_bytes(data[a + n..a + n + 8].try_into().unwrap());
        let y = u64::from_le_bytes(data[b + n..b + n + 8].try_into().unwrap());
        let diff = x ^ y;
        if diff != 0 {
            return n + (diff.trailing_zeros() / 8) as usize;
        }
        n += 8;
    }
    while n < max && data[a + n] == data[b + n] {
        n += 1;
    }
    n
}

/// Hash-chain match finder over one input buffer.
pub struct Matcher {
    head: Vec<i32>,
    prev: Vec<i32>,
}

impl Matcher {
    /// New matcher sized for `input_len` bytes.
    pub fn new(input_len: usize) -> Self {
        Matcher { head: vec![-1; HASH_SIZE], prev: vec![-1; input_len] }
    }

    #[inline]
    fn insert(&mut self, data: &[u8], i: usize) {
        if i + MIN_MATCH <= data.len() {
            let h = hash3(data, i);
            self.prev[i] = self.head[h];
            self.head[h] = i as i32;
        }
    }

    /// Best match at position `i`, if any.
    #[inline]
    fn best_match(&self, data: &[u8], i: usize) -> Option<(usize, usize)> {
        if i + MIN_MATCH > data.len() {
            return None;
        }
        let h = hash3(data, i);
        let mut cand = self.head[h];
        let mut best_len = MIN_MATCH - 1;
        let mut best_dist = 0usize;
        let mut chain = MAX_CHAIN;
        let limit = i.saturating_sub(MAX_DIST);
        while cand >= 0 && chain > 0 {
            let c = cand as usize;
            if c < limit {
                break;
            }
            let l = match_len(data, c, i, MAX_MATCH);
            if l > best_len {
                best_len = l;
                best_dist = i - c;
                if l >= NICE_LENGTH {
                    break;
                }
                if l >= GOOD_LENGTH {
                    chain = chain.min(MAX_CHAIN / 4);
                }
            }
            cand = self.prev[c];
            chain -= 1;
        }
        if best_len >= MIN_MATCH {
            Some((best_len, best_dist))
        } else {
            None
        }
    }
}

/// Tokenize `data` with greedy + one-step-lazy matching.
pub fn tokenize(data: &[u8]) -> Vec<Token> {
    let mut tokens = Vec::with_capacity(data.len() / 3 + 8);
    if data.is_empty() {
        return tokens;
    }
    let mut m = Matcher::new(data.len());
    let mut i = 0usize;
    while i < data.len() {
        let cur = m.best_match(data, i);
        match cur {
            None => {
                tokens.push(Token::Literal(data[i]));
                m.insert(data, i);
                i += 1;
            }
            Some((len, dist)) => {
                // Lazy evaluation: if i+1 has a strictly longer match,
                // emit data[i] as a literal instead.
                m.insert(data, i);
                let next = if len < NICE_LENGTH && i + 1 < data.len() {
                    m.best_match(data, i + 1)
                } else {
                    None
                };
                if let Some((nlen, _)) = next {
                    if nlen > len {
                        tokens.push(Token::Literal(data[i]));
                        i += 1;
                        continue;
                    }
                }
                tokens.push(Token::Match { len: len as u16, dist: dist as u16 });
                // Insert the covered positions into the hash chains.
                for k in i + 1..(i + len).min(data.len()) {
                    m.insert(data, k);
                }
                i += len;
            }
        }
    }
    tokens
}

/// Reconstruct bytes from tokens (testing aid / oracle).
pub fn detokenize(tokens: &[Token]) -> Vec<u8> {
    let mut out = Vec::new();
    for t in tokens {
        match *t {
            Token::Literal(b) => out.push(b),
            Token::Match { len, dist } => {
                let start = out.len() - dist as usize;
                for k in 0..len as usize {
                    let b = out[start + k];
                    out.push(b);
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(data: &[u8]) -> Vec<Token> {
        let toks = tokenize(data);
        assert_eq!(detokenize(&toks), data);
        toks
    }

    #[test]
    fn empty_and_tiny() {
        assert!(tokenize(&[]).is_empty());
        roundtrip(b"a");
        roundtrip(b"ab");
        roundtrip(b"abc");
    }

    #[test]
    fn repeated_text_finds_matches() {
        let data = b"the quick brown fox. the quick brown fox! the quick brown fox?";
        let toks = roundtrip(data);
        assert!(toks.iter().any(|t| matches!(t, Token::Match { len, .. } if *len >= 18)));
    }

    #[test]
    fn rle_style_overlap_match() {
        // "aaaa..." should produce a dist-1 overlapping match.
        let data = vec![b'a'; 300];
        let toks = roundtrip(&data);
        assert!(toks.len() <= 4, "run should compress to literal+match(es): {}", toks.len());
        assert!(toks.iter().any(|t| matches!(t, Token::Match { dist: 1, .. })));
    }

    #[test]
    fn max_match_length_respected() {
        let data = vec![7u8; 10_000];
        for t in roundtrip(&data) {
            if let Token::Match { len, .. } = t {
                assert!(len as usize <= MAX_MATCH);
            }
        }
    }

    #[test]
    fn distance_never_exceeds_window() {
        // Two identical blocks separated by > 32 KiB of noise.
        let mut data = b"unique-prefix-0123456789".to_vec();
        let mut x = 1u64;
        for _ in 0..MAX_DIST + 100 {
            x = x.wrapping_mul(6364136223846793005).wrapping_add(1);
            data.push((x >> 56) as u8);
        }
        data.extend_from_slice(b"unique-prefix-0123456789");
        for t in roundtrip(&data) {
            if let Token::Match { dist, .. } = t {
                assert!(dist as usize <= MAX_DIST);
            }
        }
    }

    #[test]
    fn random_data_mostly_literals() {
        let mut x = 9u64;
        let data: Vec<u8> = (0..4096)
            .map(|_| {
                x = x.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
                (x >> 56) as u8
            })
            .collect();
        let toks = roundtrip(&data);
        let lits = toks.iter().filter(|t| matches!(t, Token::Literal(_))).count();
        assert!(lits * 10 >= toks.len() * 8, "random data should be literal-heavy");
    }

    #[test]
    fn genome_like_text() {
        let mut x = 5u64;
        let alphabet = b"ACGT";
        let data: Vec<u8> = (0..8192)
            .map(|_| {
                x = x.wrapping_mul(2862933555777941757).wrapping_add(3037000493);
                alphabet[(x >> 62) as usize]
            })
            .collect();
        let toks = roundtrip(&data);
        // 2-bit alphabet: matches abound even in random sequence.
        assert!(toks.len() < data.len());
    }
}
