//! Canonical Huffman coding for DEFLATE (RFC 1951 §3.2.2).
//!
//! DEFLATE transmits only *code lengths*; both sides derive the same
//! canonical codes. This module provides:
//!
//! * [`CanonicalCodes`] — encoder side: lengths → (code, len) pairs with
//!   DEFLATE's bit-reversed transmission order.
//! * [`HuffmanDecoder`] — decoder side: the count/offset decoding
//!   structure (as in Mark Adler's `puff`), augmented with a one-level
//!   fast lookup table for short codes (the decode hot path).
//! * [`build_lengths`] — length-limited code construction for the
//!   encoder: Huffman frequencies → lengths capped at 15 bits with a
//!   Kraft-sum repair pass (the zlib `gen_bitlen` overflow strategy).

use crate::codecs::deflate::inflate::{DIST_BASE, DIST_EXTRA, LENGTH_BASE, LENGTH_EXTRA};
use crate::format::bitio::LsbBitReader;
use crate::{corrupt, Result};

/// Maximum code length DEFLATE allows.
pub const MAX_BITS: usize = 15;
/// Bits covered by the fast lookup table (trade table size vs hit rate).
pub const FAST_BITS: u32 = 9;

/// What a table's symbols *mean* in the DEFLATE stream — lets the fast
/// table pre-resolve each symbol to its final (kind, base, extra-bit
/// count) at build time, so the decode hot loop never touches the
/// secondary `LENGTH_BASE`/`DIST_BASE`/`*_EXTRA` arrays (the
/// single-lookup-table fold of Rivera et al.).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TableRole {
    /// Symbols are opaque (code-length codes): base = the symbol itself.
    Plain,
    /// Literal/length alphabet: 0–255 literals, 256 end-of-block,
    /// 257–285 match lengths, 286+ invalid.
    LitLen,
    /// Distance alphabet: 0–29 distances, 30+ invalid.
    Dist,
}

/// Kinds carried by a resolved fast-table entry (see [`resolved_kind`]).
pub const KIND_LITERAL: u32 = 0;
/// End-of-block symbol (lit/len 256).
pub const KIND_END: u32 = 1;
/// Match half: a length base (lit/len 257–285) or a distance base.
pub const KIND_MATCH: u32 = 2;
/// A symbol the role declares illegal (lit/len > 285, distance > 29).
pub const KIND_INVALID: u32 = 3;

/// Resolved fast-table entry layout (u32; 0 ⇒ miss, i.e. the code is
/// longer than [`FAST_BITS`] and the caller takes the canonical walk):
///
/// ```text
/// bits  0..=3   code length in bits (1..=FAST_BITS; never 0 in a hit)
/// bits  4..=8   extra-bit count to read after the code
/// bits  9..=10  kind (KIND_*)
/// bits 16..=31  base value (literal byte, LENGTH_BASE, DIST_BASE, or
///               the symbol itself for Plain tables)
/// ```
#[inline]
pub fn resolved_len(e: u32) -> u32 {
    e & 0xF
}
/// Extra-bit count of a resolved entry.
#[inline]
pub fn resolved_extra(e: u32) -> u32 {
    (e >> 4) & 0x1F
}
/// Kind of a resolved entry (one of the `KIND_*` constants).
#[inline]
pub fn resolved_kind(e: u32) -> u32 {
    (e >> 9) & 0x3
}
/// Base value of a resolved entry.
#[inline]
pub fn resolved_base(e: u32) -> u32 {
    e >> 16
}

/// Resolve a lit/len symbol to `(kind, base, extra)` — the mapping the
/// fast table bakes in at build time; the slow path (codes past
/// [`FAST_BITS`]) applies it per decoded symbol.
#[inline]
pub fn resolve_litlen(sym: u16) -> (u32, u32, u32) {
    match sym {
        0..=255 => (KIND_LITERAL, sym as u32, 0),
        256 => (KIND_END, 0, 0),
        257..=285 => {
            let i = (sym - 257) as usize;
            (KIND_MATCH, LENGTH_BASE[i] as u32, LENGTH_EXTRA[i] as u32)
        }
        _ => (KIND_INVALID, 0, 0),
    }
}

/// Resolve a distance symbol to `(kind, base, extra)`.
#[inline]
pub fn resolve_dist(sym: u16) -> (u32, u32, u32) {
    if (sym as usize) < DIST_BASE.len() {
        (KIND_MATCH, DIST_BASE[sym as usize] as u32, DIST_EXTRA[sym as usize] as u32)
    } else {
        (KIND_INVALID, 0, 0)
    }
}

/// Pack a resolved entry (see the layout above).
#[inline]
fn pack_resolved(len: u32, kind: u32, base: u32, extra: u32) -> u32 {
    debug_assert!((1..=FAST_BITS).contains(&len));
    debug_assert!(extra <= 31 && kind <= 3 && base <= 0xFFFF);
    len | (extra << 4) | (kind << 9) | (base << 16)
}

/// Encoder-side canonical code table.
#[derive(Debug, Clone)]
pub struct CanonicalCodes {
    /// Per-symbol code, already bit-reversed for LSB-first emission.
    pub codes: Vec<u16>,
    /// Per-symbol length in bits (0 = symbol unused).
    pub lens: Vec<u8>,
}

impl CanonicalCodes {
    /// Build canonical codes from per-symbol lengths.
    pub fn from_lengths(lens: &[u8]) -> Result<CanonicalCodes> {
        let mut bl_count = [0u32; MAX_BITS + 1];
        for &l in lens {
            if l as usize > MAX_BITS {
                return Err(corrupt("huffman: code length > 15"));
            }
            bl_count[l as usize] += 1;
        }
        bl_count[0] = 0;
        let mut next_code = [0u16; MAX_BITS + 1];
        let mut code = 0u32;
        for bits in 1..=MAX_BITS {
            code = (code + bl_count[bits - 1]) << 1;
            if code > (1 << bits) {
                return Err(corrupt("huffman: over-subscribed code lengths"));
            }
            next_code[bits] = code as u16;
        }
        let mut codes = vec![0u16; lens.len()];
        for (sym, &l) in lens.iter().enumerate() {
            if l > 0 {
                let c = next_code[l as usize];
                next_code[l as usize] += 1;
                codes[sym] = reverse_bits(c, l as u32);
            }
        }
        Ok(CanonicalCodes { codes, lens: lens.to_vec() })
    }
}

/// Reverse the low `n` bits of `v` (DEFLATE codes transmit MSB-first
/// within an LSB-first bit stream).
#[inline]
pub fn reverse_bits(v: u16, n: u32) -> u16 {
    v.reverse_bits() >> (16 - n)
}

/// Decoder-side structure: fast table for codes ≤ FAST_BITS, canonical
/// count/offset walk for longer codes.
#[derive(Debug, Clone)]
pub struct HuffmanDecoder {
    /// fast[bits] = (symbol << 4) | code_len, or u16::MAX when the code is
    /// longer than FAST_BITS.
    fast: Vec<u16>,
    /// Role-resolved fast table: `resolved[bits]` packs (kind, base,
    /// extra-bit count, code length) per the layout at the top of this
    /// module, 0 on miss. Built alongside `fast` so `inflate_block`'s
    /// hot loop decodes a symbol *and* its secondary-table metadata
    /// from one lookup.
    resolved: Vec<u32>,
    /// Number of codes of each length.
    count: [u16; MAX_BITS + 1],
    /// Symbols sorted by (length, symbol) — canonical order.
    symbols: Vec<u16>,
    /// First canonical code value of each length (non-reversed).
    first_code: [u32; MAX_BITS + 1],
    /// Index into `symbols` of the first symbol of each length.
    first_sym: [u32; MAX_BITS + 1],
    /// Longest code length present.
    max_len: u32,
}

impl HuffmanDecoder {
    /// Build a decoder from per-symbol code lengths with the
    /// [`TableRole::Plain`] resolution (base = symbol).
    ///
    /// Rejects over-subscribed length sets. Incomplete sets are accepted
    /// — DEFLATE's fixed distance table only assigns 30 of 32 5-bit codes
    /// — and decoding a bit pattern that falls in a gap errors out, the
    /// same contract zlib's inflate implements.
    pub fn from_lengths(lens: &[u8]) -> Result<HuffmanDecoder> {
        Self::from_lengths_role(lens, TableRole::Plain)
    }

    /// [`from_lengths`](Self::from_lengths) with an explicit
    /// [`TableRole`] controlling how fast-table entries pre-resolve
    /// their symbols (the DEFLATE decoder builds its lit/len tables
    /// with [`TableRole::LitLen`] and distance tables with
    /// [`TableRole::Dist`]).
    pub fn from_lengths_role(lens: &[u8], role: TableRole) -> Result<HuffmanDecoder> {
        let mut count = [0u16; MAX_BITS + 1];
        for &l in lens {
            if l as usize > MAX_BITS {
                return Err(corrupt("huffman: code length > 15"));
            }
            count[l as usize] += 1;
        }
        count[0] = 0;
        let total: u32 = lens.iter().filter(|&&l| l > 0).count() as u32;
        if total == 0 {
            return Err(corrupt("huffman: empty code"));
        }
        // Kraft check (over-subscription only).
        let mut left = 1i64;
        for bits in 1..=MAX_BITS {
            left <<= 1;
            left -= count[bits] as i64;
            if left < 0 {
                return Err(corrupt("huffman: over-subscribed lengths"));
            }
        }
        // Canonical ordering.
        let mut first_code = [0u32; MAX_BITS + 1];
        let mut first_sym = [0u32; MAX_BITS + 1];
        let mut code = 0u32;
        let mut sym_base = 0u32;
        let mut max_len = 0u32;
        for bits in 1..=MAX_BITS {
            code = (code + count[bits - 1] as u32) << 1;
            first_code[bits] = code;
            first_sym[bits] = sym_base;
            sym_base += count[bits] as u32;
            if count[bits] > 0 {
                max_len = bits as u32;
            }
        }
        let mut offs = [0u32; MAX_BITS + 1];
        for bits in 1..=MAX_BITS {
            offs[bits] = first_sym[bits];
        }
        let mut symbols = vec![0u16; total as usize];
        for (sym, &l) in lens.iter().enumerate() {
            if l > 0 {
                symbols[offs[l as usize] as usize] = sym as u16;
                offs[l as usize] += 1;
            }
        }
        // Fast tables: the generic (symbol, len) entries and the
        // role-resolved (kind, base, extra, len) entries, filled from
        // the same canonical codes in one pass.
        let mut fast = vec![u16::MAX; 1 << FAST_BITS];
        let mut resolved = vec![0u32; 1 << FAST_BITS];
        {
            let codes = CanonicalCodes::from_lengths(lens)?;
            for (sym, (&rc, &l)) in codes.codes.iter().zip(codes.lens.iter()).enumerate() {
                let l = l as u32;
                if l == 0 || l > FAST_BITS {
                    continue;
                }
                let (kind, base, extra) = match role {
                    TableRole::Plain => (KIND_LITERAL, sym as u32, 0),
                    TableRole::LitLen => resolve_litlen(sym as u16),
                    TableRole::Dist => resolve_dist(sym as u16),
                };
                let entry = pack_resolved(l, kind, base, extra);
                // Fill every table slot whose low `l` bits equal the code.
                let step = 1u32 << l;
                let mut idx = rc as u32;
                while idx < (1 << FAST_BITS) {
                    fast[idx as usize] = ((sym as u16) << 4) | l as u16;
                    resolved[idx as usize] = entry;
                    idx += step;
                }
            }
        }
        Ok(HuffmanDecoder { fast, resolved, count, symbols, first_code, first_sym, max_len })
    }

    /// One-lookup resolved decode from a pre-peeked LSB-first window:
    /// returns the packed (kind, base, extra, len) entry for the next
    /// code, or 0 when the code is longer than [`FAST_BITS`] (caller
    /// falls back to [`decode_word`](Self::decode_word) + the
    /// `resolve_*` mapping). Nothing is consumed.
    #[inline]
    pub fn lookup_resolved(&self, word: u64) -> u32 {
        self.resolved[(word & ((1u64 << FAST_BITS) - 1)) as usize]
    }

    /// Decode one symbol from a pre-peeked LSB-first bit window (the
    /// low bits of `word` are the next bits of the stream). Returns
    /// `(symbol, code length in bits)` without consuming anything —
    /// the caller retires the bits (plus any extra bits it read from
    /// the same window) with one `LsbBitReader::consume_bits` call.
    ///
    /// `word` must hold at least [`MAX_BITS`] valid bits or be
    /// zero-padded past the end of the stream; a symbol "decoded" from
    /// padding is rejected when the caller's `consume_bits` overruns
    /// the real stream, so truncation detection is unchanged.
    #[inline]
    pub fn decode_word(&self, word: u64) -> Result<(u16, u32)> {
        let e = self.fast[(word & ((1u64 << FAST_BITS) - 1)) as usize];
        if e != u16::MAX {
            return Ok((e >> 4, (e & 0xF) as u32));
        }
        // Slow path (codes longer than FAST_BITS): walk lengths
        // FAST_BITS..=max_len using the canonical count/offset
        // structure, rebuilding the code MSB-first from the window.
        let mut code: u32 = 0;
        for i in 0..FAST_BITS {
            code = (code << 1) | ((word >> i) & 1) as u32;
        }
        let mut len = FAST_BITS;
        loop {
            // Codes of length `len`: range [first_code, first_code+count).
            let fc = self.first_code[len as usize];
            let cnt = self.count[len as usize] as u32;
            if code >= fc && code < fc + cnt {
                let idx = self.first_sym[len as usize] + (code - fc);
                return Ok((self.symbols[idx as usize], len));
            }
            if len >= self.max_len {
                return Err(corrupt("huffman: invalid code"));
            }
            code = (code << 1) | ((word >> len) & 1) as u32;
            len += 1;
        }
    }

    /// Decode one symbol from `r` (peek+consume convenience wrapper
    /// around [`decode_word`](Self::decode_word)).
    #[inline]
    pub fn decode(&self, r: &mut LsbBitReader<'_>) -> Result<u16> {
        let (sym, len) = self.decode_word(r.peek_bits(57))?;
        r.consume_bits(len)?;
        Ok(sym)
    }
}

/// Build length-limited Huffman code lengths from symbol frequencies.
///
/// Standard Huffman construction, then an exact Kraft repair: lengths are
/// clamped to `max_bits` and the Kraft sum (tracked in units of
/// `2^-max_bits`) is restored to exactly `2^max_bits` — a *complete*
/// prefix code, which [`HuffmanDecoder`] requires. Returns per-symbol
/// lengths (0 = unused symbol).
pub fn build_lengths(freqs: &[u32], max_bits: usize) -> Vec<u8> {
    let n = freqs.len();
    let used: Vec<usize> = (0..n).filter(|&i| freqs[i] > 0).collect();
    let mut lens = vec![0u8; n];
    match used.len() {
        0 => return lens,
        1 => {
            lens[used[0]] = 1;
            return lens;
        }
        _ => {}
    }
    // Heap-free O(n log n) Huffman via two sorted queues.
    #[derive(Clone, Copy)]
    struct Node {
        freq: u64,
        /// Child arena indices; leaves have sym >= 0.
        left: i32,
        right: i32,
        sym: i32,
    }
    let mut arena: Vec<Node> = used
        .iter()
        .map(|&i| Node { freq: freqs[i] as u64, left: -1, right: -1, sym: i as i32 })
        .collect();
    arena.sort_by_key(|nd| nd.freq);
    let mut leaves: std::collections::VecDeque<usize> = (0..arena.len()).collect();
    let mut internals: std::collections::VecDeque<usize> = std::collections::VecDeque::new();
    fn take_min(
        arena: &[Node],
        leaves: &mut std::collections::VecDeque<usize>,
        internals: &mut std::collections::VecDeque<usize>,
    ) -> usize {
        match (leaves.front(), internals.front()) {
            (Some(&l), Some(&i)) => {
                if arena[l].freq <= arena[i].freq {
                    leaves.pop_front().unwrap()
                } else {
                    internals.pop_front().unwrap()
                }
            }
            (Some(_), None) => leaves.pop_front().unwrap(),
            (None, Some(_)) => internals.pop_front().unwrap(),
            (None, None) => unreachable!(),
        }
    }
    let mut root = 0usize;
    while leaves.len() + internals.len() > 1 {
        let a = take_min(&arena, &mut leaves, &mut internals);
        let b = take_min(&arena, &mut leaves, &mut internals);
        arena.push(Node {
            freq: arena[a].freq + arena[b].freq,
            left: a as i32,
            right: b as i32,
            sym: -1,
        });
        root = arena.len() - 1;
        internals.push_back(root);
    }
    // Depth-assign, clamping to max_bits.
    let mut stack = vec![(root, 0u32)];
    while let Some((idx, depth)) = stack.pop() {
        let nd = arena[idx];
        if nd.sym >= 0 {
            lens[nd.sym as usize] = depth.clamp(1, max_bits as u32) as u8;
        } else {
            stack.push((nd.left as usize, depth + 1));
            stack.push((nd.right as usize, depth + 1));
        }
    }
    // Exact Kraft repair in units of 2^-max_bits. Target K == 2^max_bits.
    let unit = |l: u8| 1u64 << (max_bits - l as usize);
    let target = 1u64 << max_bits;
    let mut k: u64 = used.iter().map(|&i| unit(lens[i])).sum();
    // Overshoot: demote (lengthen) the least-frequent symbol that is the
    // deepest below max_bits. Each demotion halves its contribution.
    while k > target {
        let &sym = used
            .iter()
            .filter(|&&i| (lens[i] as usize) < max_bits)
            .min_by_key(|&&i| (std::cmp::Reverse(lens[i]), freqs[i]))
            .expect("kraft overshoot implies a demotable symbol");
        k -= unit(lens[sym]) / 2;
        lens[sym] += 1;
    }
    // Undershoot: promote (shorten) the deepest symbol whose doubled
    // contribution still fits; prefer frequent symbols at equal depth.
    while k < target {
        let gap = target - k;
        let &sym = used
            .iter()
            .filter(|&&i| lens[i] > 1 && unit(lens[i]) <= gap)
            .max_by_key(|&&i| (lens[i], freqs[i]))
            .expect("dyadic gap always admits a promotion");
        k += unit(lens[sym]);
        lens[sym] -= 1;
    }
    lens
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::format::bitio::LsbBitWriter;

    fn encode_decode(lens: &[u8], seq: &[u16]) {
        let codes = CanonicalCodes::from_lengths(lens).unwrap();
        let mut w = LsbBitWriter::new();
        for &s in seq {
            w.put_bits(codes.codes[s as usize] as u64, codes.lens[s as usize] as u32);
        }
        let bytes = w.finish();
        let dec = HuffmanDecoder::from_lengths(lens).unwrap();
        let mut r = LsbBitReader::new(&bytes);
        for &s in seq {
            assert_eq!(dec.decode(&mut r).unwrap(), s);
        }
    }

    #[test]
    fn fixed_literal_table_roundtrip() {
        // The DEFLATE fixed literal/length code.
        let mut lens = vec![8u8; 144];
        lens.extend(vec![9u8; 112]);
        lens.extend(vec![7u8; 24]);
        lens.extend(vec![8u8; 8]);
        let seq: Vec<u16> = (0..288).step_by(7).collect();
        encode_decode(&lens, &seq);
    }

    #[test]
    fn max_depth_15_bit_codes_decode_via_word_path() {
        // Complete canonical set with two 15-bit codes (Kraft sum
        // exactly 1): lengths 1..=15 plus a second 15. Every symbol
        // past length FAST_BITS exercises the slow path of
        // decode_word, which the fast-path rewrite must not regress.
        let mut lens: Vec<u8> = (1..=15).collect();
        lens.push(15);
        let seq: Vec<u16> = (0..16).chain((9..16).rev()).collect();
        encode_decode(&lens, &seq);
        // decode_word reports the exact code length for a deep symbol
        // (codes are stored bit-reversed, i.e. stream order, so the
        // code value is itself the low bits of the peek window).
        let dec = HuffmanDecoder::from_lengths(&lens).unwrap();
        let codes = CanonicalCodes::from_lengths(&lens).unwrap();
        for sym in [9usize, 14, 15] {
            let (got, len) = dec.decode_word(codes.codes[sym] as u64).unwrap();
            assert_eq!((got, len), (sym as u16, codes.lens[sym] as u32));
            assert!(len > FAST_BITS, "symbol {sym} must exercise the slow path");
        }
    }

    #[test]
    fn resolved_lut_agrees_with_decode_word_plus_secondary_tables() {
        use crate::codecs::deflate::inflate::{fixed_dist_decoder, fixed_lit_decoder};
        // Every 9-bit window over the fixed tables: a resolved hit must
        // carry exactly what decode_word + resolve_* would compute, and
        // a miss must mean the code is longer than FAST_BITS.
        let lit = fixed_lit_decoder();
        let dist = fixed_dist_decoder();
        let lit_resolve: fn(u16) -> (u32, u32, u32) = resolve_litlen;
        let dist_resolve: fn(u16) -> (u32, u32, u32) = resolve_dist;
        for word in 0u64..(1 << FAST_BITS) {
            for (dec, resolve) in [(&lit, lit_resolve), (&dist, dist_resolve)] {
                let e = dec.lookup_resolved(word);
                match dec.decode_word(word) {
                    Ok((sym, len)) if len <= FAST_BITS => {
                        assert_ne!(e, 0, "word {word:#b}: hit expected");
                        let (kind, base, extra) = resolve(sym);
                        assert_eq!(resolved_len(e), len, "word {word:#b}");
                        assert_eq!(resolved_kind(e), kind, "word {word:#b}");
                        assert_eq!(resolved_base(e), base, "word {word:#b}");
                        assert_eq!(resolved_extra(e), extra, "word {word:#b}");
                    }
                    _ => assert_eq!(e, 0, "word {word:#b}: miss expected"),
                }
            }
        }
        // The fixed table's invalid symbols (286/287, 8-bit codes) must
        // be marked invalid *in the LUT*.
        let mut lens = vec![8u8; 144];
        lens.extend(vec![9u8; 112]);
        lens.extend(vec![7u8; 24]);
        lens.extend(vec![8u8; 8]);
        let codes = CanonicalCodes::from_lengths(&lens).unwrap();
        for sym in [286usize, 287] {
            let e = lit.lookup_resolved(codes.codes[sym] as u64);
            assert_eq!(resolved_kind(e), KIND_INVALID, "sym {sym}");
        }
    }

    #[test]
    fn resolved_length_codes_match_base_and_extra_tables() {
        use crate::codecs::deflate::inflate::{LENGTH_BASE, LENGTH_EXTRA};
        for sym in 257u16..=285 {
            let (kind, base, extra) = resolve_litlen(sym);
            assert_eq!(kind, KIND_MATCH);
            assert_eq!(base, LENGTH_BASE[(sym - 257) as usize] as u32);
            assert_eq!(extra, LENGTH_EXTRA[(sym - 257) as usize] as u32);
        }
        assert_eq!(resolve_litlen(42).0, KIND_LITERAL);
        assert_eq!(resolve_litlen(256).0, KIND_END);
        assert_eq!(resolve_litlen(286).0, KIND_INVALID);
        assert_eq!(resolve_dist(29).0, KIND_MATCH);
        assert_eq!(resolve_dist(30).0, KIND_INVALID);
    }

    #[test]
    fn long_codes_use_slow_path() {
        // A skewed tree with codes longer than FAST_BITS.
        let freqs: Vec<u32> = (0..24).map(|i| 1u32 << i.min(20)).collect();
        let lens = build_lengths(&freqs, MAX_BITS);
        assert!(lens.iter().any(|&l| l as u32 > FAST_BITS));
        let seq: Vec<u16> = (0..24).collect();
        encode_decode(&lens, &seq);
    }

    #[test]
    fn build_lengths_kraft_valid() {
        for trial in 0..50u64 {
            let mut x = trial * 2654435761 + 1;
            let n = 10 + (trial as usize % 276);
            let freqs: Vec<u32> = (0..n)
                .map(|_| {
                    x = x.wrapping_mul(6364136223846793005).wrapping_add(1);
                    ((x >> 33) % 10000) as u32
                })
                .collect();
            let lens = build_lengths(&freqs, MAX_BITS);
            let mut kraft = 0f64;
            for (i, &l) in lens.iter().enumerate() {
                assert_eq!(l == 0, freqs[i] == 0, "sym {i}");
                assert!(l as usize <= MAX_BITS);
                if l > 0 {
                    kraft += (2f64).powi(-(l as i32));
                }
            }
            if freqs.iter().filter(|&&f| f > 0).count() > 1 {
                assert!(kraft <= 1.0 + 1e-9, "kraft {kraft}");
                // Decoder must accept them.
                HuffmanDecoder::from_lengths(&lens).unwrap();
            }
        }
    }

    #[test]
    fn more_frequent_symbols_get_shorter_codes() {
        let freqs = [1000u32, 1, 500, 1, 250];
        let lens = build_lengths(&freqs, MAX_BITS);
        assert!(lens[0] <= lens[2]);
        assert!(lens[2] <= lens[4]);
        assert!(lens[4] <= lens[1]);
    }

    #[test]
    fn oversubscribed_rejected() {
        let lens = [1u8, 1, 1];
        assert!(HuffmanDecoder::from_lengths(&lens).is_err());
        assert!(CanonicalCodes::from_lengths(&lens).is_err());
    }

    #[test]
    fn incomplete_code_accepted_but_gap_errors_at_decode() {
        // Three 2-bit codes (00,01,10) leave the pattern 11 unassigned —
        // the shape of DEFLATE's fixed distance table.
        let lens = [2u8, 2, 2];
        let dec = HuffmanDecoder::from_lengths(&lens).unwrap();
        // Pattern 11 (LSB-first: 0b11) must be rejected.
        let bytes = [0xFFu8, 0xFF];
        let mut r = LsbBitReader::new(&bytes);
        assert!(dec.decode(&mut r).is_err());
        // Valid pattern 00 decodes to symbol 0.
        let bytes = [0x00u8];
        let mut r = LsbBitReader::new(&bytes);
        assert_eq!(dec.decode(&mut r).unwrap(), 0);
    }

    #[test]
    fn single_symbol_code_accepted() {
        // DEFLATE distance trees may have a single 1-bit code.
        let lens = [1u8];
        let dec = HuffmanDecoder::from_lengths(&lens).unwrap();
        let mut w = LsbBitWriter::new();
        w.put_bits(0, 1);
        let bytes = w.finish();
        let mut r = LsbBitReader::new(&bytes);
        assert_eq!(dec.decode(&mut r).unwrap(), 0);
    }

    #[test]
    fn deep_gap_detected() {
        // 1/2 + 1/4 + 1/8 = 7/8: the all-ones 3-bit pattern is a gap.
        let lens = [1u8, 2, 3, 0];
        let dec = HuffmanDecoder::from_lengths(&lens).unwrap();
        let bytes = [0xFFu8, 0xFF];
        let mut r = LsbBitReader::new(&bytes);
        assert!(dec.decode(&mut r).is_err());
    }

    #[test]
    fn reverse_bits_works() {
        assert_eq!(reverse_bits(0b1, 1), 0b1);
        assert_eq!(reverse_bits(0b110, 3), 0b011);
        assert_eq!(reverse_bits(0b10000000, 8), 0b00000001);
    }
}
