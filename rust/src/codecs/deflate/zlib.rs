//! zlib framing (RFC 1950) around the DEFLATE stream.
//!
//! The paper compresses its Deflate corpus "with the zlib library at
//! compression level 9" (§V-B); this module provides the same on-wire
//! format: a 2-byte header (CMF/FLG), the raw DEFLATE stream, and the
//! Adler-32 checksum of the uncompressed data — implemented from
//! scratch like everything else.

use crate::codecs::deflate;
use crate::decomp::ByteSink;
use crate::{corrupt, Result};

/// Adler-32 modulus.
const MOD_ADLER: u32 = 65_521;

/// Compute the Adler-32 checksum of `data` (RFC 1950 §8).
pub fn adler32(data: &[u8]) -> u32 {
    let mut a: u32 = 1;
    let mut b: u32 = 0;
    // Process in blocks small enough that u32 sums cannot overflow
    // (NMAX = 5552 from the reference implementation).
    for block in data.chunks(5552) {
        for &byte in block {
            a += byte as u32;
            b += a;
        }
        a %= MOD_ADLER;
        b %= MOD_ADLER;
    }
    (b << 16) | a
}

/// Compress `data` into a zlib stream (CMF/FLG + DEFLATE + Adler-32).
pub fn compress(data: &[u8]) -> Result<Vec<u8>> {
    let body = deflate::compress(data)?;
    let mut out = Vec::with_capacity(body.len() + 6);
    // CMF: CM=8 (deflate), CINFO=7 (32K window). FLG: check bits so that
    // (CMF*256 + FLG) % 31 == 0, FLEVEL=3 (maximum, we run level-9-ish
    // effort), FDICT=0.
    let cmf: u8 = 0x78;
    let mut flg: u8 = 3 << 6;
    let rem = ((cmf as u16) << 8 | flg as u16) % 31;
    if rem != 0 {
        flg += (31 - rem) as u8;
    }
    out.push(cmf);
    out.push(flg);
    out.extend_from_slice(&body);
    out.extend_from_slice(&adler32(data).to_be_bytes());
    Ok(out)
}

/// Decompress a zlib stream, verifying the Adler-32 checksum.
pub fn decompress(stream: &[u8]) -> Result<Vec<u8>> {
    if stream.len() < 6 {
        return Err(corrupt("zlib: stream shorter than header + checksum"));
    }
    let cmf = stream[0];
    let flg = stream[1];
    if cmf & 0x0F != 8 {
        return Err(corrupt(format!("zlib: unsupported method {}", cmf & 0x0F)));
    }
    if ((cmf as u16) << 8 | flg as u16) % 31 != 0 {
        return Err(corrupt("zlib: header check bits invalid"));
    }
    if flg & 0x20 != 0 {
        return Err(corrupt("zlib: preset dictionaries not supported"));
    }
    let body = &stream[2..stream.len() - 4];
    let mut sink = ByteSink::new();
    deflate::inflate::inflate(body, &mut sink)?;
    let out = sink.into_bytes();
    let want = u32::from_be_bytes(stream[stream.len() - 4..].try_into().unwrap());
    let got = adler32(&out);
    if want != got {
        return Err(corrupt(format!(
            "zlib: adler32 mismatch (stored {want:08x}, computed {got:08x})"
        )));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn adler32_known_vectors() {
        // RFC 1950 examples / zlib test values.
        assert_eq!(adler32(b""), 1);
        assert_eq!(adler32(b"a"), 0x0062_0062);
        assert_eq!(adler32(b"abc"), 0x024d_0127);
        assert_eq!(adler32(b"Wikipedia"), 0x11E6_0398);
    }

    #[test]
    fn roundtrip() {
        let data = b"zlib framing around our own deflate ".repeat(100);
        let z = compress(&data).unwrap();
        assert_eq!(z[0] & 0x0F, 8);
        assert_eq!(decompress(&z).unwrap(), data);
    }

    #[test]
    fn empty_roundtrip() {
        let z = compress(&[]).unwrap();
        assert_eq!(decompress(&z).unwrap(), Vec::<u8>::new());
    }

    #[test]
    fn checksum_detects_payload_corruption() {
        let data = vec![7u8; 10_000];
        let mut z = compress(&data).unwrap();
        // Flip a literal deep in the stream: inflate may succeed but the
        // checksum must catch it.
        let mid = z.len() / 2;
        z[mid] ^= 0x10;
        assert!(decompress(&z).is_err());
    }

    #[test]
    fn bad_header_rejected() {
        let data = b"x".repeat(64);
        let mut z = compress(&data).unwrap();
        z[0] = 0x79; // wrong CINFO/check
        assert!(decompress(&z).is_err());
        let mut z2 = compress(&data).unwrap();
        z2[1] |= 0x20; // FDICT set
        assert!(decompress(&z2).is_err());
    }

    #[test]
    fn truncated_rejected() {
        let z = compress(b"hello hello hello").unwrap();
        for cut in [0, 1, 5, z.len() - 1] {
            assert!(decompress(&z[..cut]).is_err());
        }
    }
}
