//! DEFLATE decoder (RFC 1951), written against the CODAG stream
//! abstractions: literals go through `write_slice` (consecutive
//! literals are batched), back-references through `memcpy(offset, len)`
//! — the Table II primitives the paper lists for dictionary-based
//! encodings, in their batched slice-oriented form (DESIGN.md §7).
//!
//! The symbol loop is built around a single wide `peek_bits(57)`: one
//! refill yields the literal/length Huffman code, its extra bits, the
//! distance code, and the distance extra bits (≤ 48 bits worst case),
//! which are then retired with at most two `consume_bits` calls — the
//! dense decode loop CODAG §IV argues the throughput comes from,
//! instead of a bit-fetch round trip per field.

use crate::codecs::deflate::huffman::{
    resolve_dist, resolve_litlen, resolved_base, resolved_extra, resolved_kind, resolved_len,
    HuffmanDecoder, TableRole, KIND_END, KIND_INVALID, KIND_LITERAL,
};
use crate::decomp::{OutputStream, SymbolKind};
use crate::format::bitio::LsbBitReader;
use crate::{corrupt, Result};

/// Length-code base values (codes 257–285).
pub const LENGTH_BASE: [u16; 29] = [
    3, 4, 5, 6, 7, 8, 9, 10, 11, 13, 15, 17, 19, 23, 27, 31, 35, 43, 51, 59, 67, 83, 99, 115,
    131, 163, 195, 227, 258,
];
/// Length-code extra bits.
pub const LENGTH_EXTRA: [u8; 29] = [
    0, 0, 0, 0, 0, 0, 0, 0, 1, 1, 1, 1, 2, 2, 2, 2, 3, 3, 3, 3, 4, 4, 4, 4, 5, 5, 5, 5, 0,
];
/// Distance-code base values (codes 0–29).
pub const DIST_BASE: [u16; 30] = [
    1, 2, 3, 4, 5, 7, 9, 13, 17, 25, 33, 49, 65, 97, 129, 193, 257, 385, 513, 769, 1025, 1537,
    2049, 3073, 4097, 6145, 8193, 12289, 16385, 24577,
];
/// Distance-code extra bits.
pub const DIST_EXTRA: [u8; 30] = [
    0, 0, 0, 0, 1, 1, 2, 2, 3, 3, 4, 4, 5, 5, 6, 6, 7, 7, 8, 8, 9, 9, 10, 10, 11, 11, 12, 12,
    13, 13,
];
/// Order in which code-length-code lengths are transmitted.
pub const CLC_ORDER: [usize; 19] = [16, 17, 18, 0, 8, 7, 9, 6, 10, 5, 11, 4, 12, 3, 13, 2, 14, 1, 15];

/// Build the fixed literal/length decoder (RFC 1951 §3.2.6).
pub fn fixed_lit_decoder() -> HuffmanDecoder {
    let mut lens = vec![8u8; 144];
    lens.extend(std::iter::repeat(9u8).take(112));
    lens.extend(std::iter::repeat(7u8).take(24));
    lens.extend(std::iter::repeat(8u8).take(8));
    HuffmanDecoder::from_lengths_role(&lens, TableRole::LitLen).expect("fixed table is valid")
}

/// Build the fixed distance decoder.
pub fn fixed_dist_decoder() -> HuffmanDecoder {
    HuffmanDecoder::from_lengths_role(&[5u8; 30], TableRole::Dist).expect("fixed table is valid")
}

/// Decode the dynamic-block Huffman tables (RFC 1951 §3.2.7).
fn read_dynamic_tables(r: &mut LsbBitReader<'_>) -> Result<(HuffmanDecoder, HuffmanDecoder)> {
    let hlit = r.fetch_bits(5)? as usize + 257;
    let hdist = r.fetch_bits(5)? as usize + 1;
    let hclen = r.fetch_bits(4)? as usize + 4;
    if hlit > 286 || hdist > 30 {
        return Err(corrupt(format!("deflate: bad table sizes hlit={hlit} hdist={hdist}")));
    }
    let mut clc_lens = [0u8; 19];
    for &idx in CLC_ORDER.iter().take(hclen) {
        clc_lens[idx] = r.fetch_bits(3)? as u8;
    }
    let clc = HuffmanDecoder::from_lengths(&clc_lens)?;
    // Decode the hlit + hdist code lengths with the CLC.
    let total = hlit + hdist;
    let mut lens = Vec::with_capacity(total);
    while lens.len() < total {
        let sym = clc.decode(r)?;
        match sym {
            0..=15 => lens.push(sym as u8),
            16 => {
                let &last = lens.last().ok_or_else(|| corrupt("deflate: repeat with no prior length"))?;
                let n = 3 + r.fetch_bits(2)? as usize;
                lens.extend(std::iter::repeat(last).take(n));
            }
            17 => {
                let n = 3 + r.fetch_bits(3)? as usize;
                lens.extend(std::iter::repeat(0u8).take(n));
            }
            18 => {
                let n = 11 + r.fetch_bits(7)? as usize;
                lens.extend(std::iter::repeat(0u8).take(n));
            }
            _ => return Err(corrupt("deflate: bad code-length symbol")),
        }
    }
    if lens.len() != total {
        return Err(corrupt("deflate: code-length run overflows table"));
    }
    if lens[256] == 0 {
        return Err(corrupt("deflate: end-of-block symbol has no code"));
    }
    let lit = HuffmanDecoder::from_lengths_role(&lens[..hlit], TableRole::LitLen)?;
    let dist_lens = &lens[hlit..];
    // All-zero distance table means the block has no matches; RFC allows
    // a single zero-length code. Use a dummy 1-symbol decoder.
    let dist = if dist_lens.iter().all(|&l| l == 0) {
        HuffmanDecoder::from_lengths_role(&[1u8], TableRole::Dist)?
    } else {
        HuffmanDecoder::from_lengths_role(dist_lens, TableRole::Dist)?
    };
    Ok((lit, dist))
}

/// Inflate one DEFLATE bit stream into `out`.
pub fn inflate<O: OutputStream + ?Sized>(data: &[u8], out: &mut O) -> Result<()> {
    let mut r = LsbBitReader::new(data);
    loop {
        let bfinal = r.fetch_bits(1)?;
        let btype = r.fetch_bits(2)?;
        match btype {
            0 => inflate_stored(&mut r, out)?,
            1 => {
                let lit = fixed_lit_decoder();
                let dist = fixed_dist_decoder();
                out.on_symbol(SymbolKind::DeflateHeader, 250, (r.consumed_bits() + 7) / 8);
                inflate_block(&mut r, &lit, &dist, out)?;
            }
            2 => {
                let (lit, dist) = read_dynamic_tables(&mut r)?;
                // Dynamic table construction is a real decode cost the
                // paper's Deflate analysis attributes to the leader
                // thread (§III): count it as a header symbol.
                out.on_symbol(SymbolKind::DeflateHeader, 3000, (r.consumed_bits() + 7) / 8);
                inflate_block(&mut r, &lit, &dist, out)?;
            }
            _ => return Err(corrupt("deflate: reserved block type")),
        }
        if bfinal == 1 {
            return Ok(());
        }
    }
}

/// Inflate from a restart point until exactly `expect` output bytes are
/// produced, returning the absolute bit position where decode stopped.
///
/// `bit_pos` is a container-v2 restart offset (bits from the start of
/// `data`); `bit_pos == 0` decodes from the stream head. The caller is
/// expected to bound `out` to the sub-block (a `SliceSink`), so any
/// back-reference escaping the sub-block fails there. Block boundaries
/// inside the range are followed normally; the decode is `Corrupt` if a
/// block overshoots `expect` (restart offsets must land on block
/// boundaries by construction) or if BFINAL terminates the stream
/// before `expect` bytes exist.
///
/// `terminal` marks the chunk's last sub-block: the sub-block must then
/// end on the stream's BFINAL block — and a non-terminal sub-block must
/// *not* — so a split decode agrees with serial decode about where the
/// stream ends. Without this, one BFINAL bit flip would truncate serial
/// output while every bounded sub-block still decoded cleanly (the
/// differential contract of DESIGN.md §7.5 forbids that divergence).
pub fn inflate_sub_block<O: OutputStream + ?Sized>(
    data: &[u8],
    bit_pos: u64,
    expect: usize,
    terminal: bool,
    out: &mut O,
) -> Result<u64> {
    let mut r = LsbBitReader::at_bit_offset(data, bit_pos)?;
    let base_bits = (bit_pos / 8) * 8;
    loop {
        let bfinal = r.fetch_bits(1)?;
        let btype = r.fetch_bits(2)?;
        match btype {
            0 => inflate_stored(&mut r, out)?,
            1 => {
                let lit = fixed_lit_decoder();
                let dist = fixed_dist_decoder();
                out.on_symbol(SymbolKind::DeflateHeader, 250, (r.consumed_bits() + 7) / 8);
                inflate_block(&mut r, &lit, &dist, out)?;
            }
            2 => {
                let (lit, dist) = read_dynamic_tables(&mut r)?;
                out.on_symbol(SymbolKind::DeflateHeader, 3000, (r.consumed_bits() + 7) / 8);
                inflate_block(&mut r, &lit, &dist, out)?;
            }
            _ => return Err(corrupt("deflate: reserved block type")),
        }
        let produced = out.bytes_written();
        if produced > expect as u64 {
            return Err(corrupt(format!(
                "deflate: sub-block overshoots restart boundary ({produced} > {expect} bytes)"
            )));
        }
        if produced == expect as u64 {
            if terminal != (bfinal == 1) {
                return Err(corrupt(format!(
                    "deflate: sub-block boundary disagrees with BFINAL \
                     (terminal={terminal}, bfinal={bfinal})"
                )));
            }
            return Ok(base_bits + r.consumed_bits() as u64);
        }
        if bfinal == 1 {
            return Err(corrupt(format!(
                "deflate: final block before sub-block filled ({produced} of {expect} bytes)"
            )));
        }
    }
}

fn inflate_stored<O: OutputStream + ?Sized>(r: &mut LsbBitReader<'_>, out: &mut O) -> Result<()> {
    r.align_byte();
    let len = r.fetch_bits(16)? as usize;
    let nlen = r.fetch_bits(16)? as usize;
    if len != (!nlen & 0xFFFF) {
        return Err(corrupt("deflate: stored block LEN/NLEN mismatch"));
    }
    out.on_symbol(SymbolKind::DeflateHeader, 10, (r.consumed_bits() + 7) / 8);
    // A stored block is one contiguous byte range of the input: borrow
    // it and emit a single batched slice write.
    let bytes = r.read_aligned_slice(len)?;
    out.write_slice(bytes)?;
    out.on_symbol(SymbolKind::DeflateLiteral, 3 * len as u32, (r.consumed_bits() + 7) / 8);
    Ok(())
}

/// Literal batch size: consecutive literals are staged here and flushed
/// through one `write_slice` per batch (or at a match / end of block).
const LIT_BATCH: usize = 512;

/// Low-`n` bit mask of a peeked word (n ≤ 13 here, so no shift overflow).
#[inline]
fn extra_mask(n: u32) -> u64 {
    (1u64 << n) - 1
}

fn inflate_block<O: OutputStream + ?Sized>(
    r: &mut LsbBitReader<'_>,
    lit: &HuffmanDecoder,
    dist: &HuffmanDecoder,
    out: &mut O,
) -> Result<()> {
    let mut lits = [0u8; LIT_BATCH];
    let mut n_lits = 0usize;
    loop {
        // One wide peek covers the worst-case symbol: lit/len code (15)
        // + length extra (5) + distance code (15) + distance extra (13)
        // = 48 bits ≤ 57. Bits past the end of the stream peek as zero;
        // consume_bits rejects any symbol that would overrun them.
        let word = r.peek_bits(57);
        // Single-lookup decode: the role-resolved fast table yields
        // (kind, base, extra-bit count, code length) in one hit, so the
        // common case never consults LENGTH_BASE/LENGTH_EXTRA. Codes
        // longer than FAST_BITS take the canonical walk and resolve the
        // symbol the same way.
        let e = lit.lookup_resolved(word);
        let (kind, base, lextra, used) = if e != 0 {
            (resolved_kind(e), resolved_base(e), resolved_extra(e), resolved_len(e))
        } else {
            let (sym, used) = lit.decode_word(word)?;
            let (kind, base, lextra) = resolve_litlen(sym);
            (kind, base, lextra, used)
        };
        if kind == KIND_LITERAL {
            r.consume_bits(used)?;
            out.on_symbol(SymbolKind::DeflateLiteral, 60, (r.consumed_bits() + 7) / 8);
            lits[n_lits] = base as u8;
            n_lits += 1;
            if n_lits == LIT_BATCH {
                out.write_slice(&lits)?;
                n_lits = 0;
            }
            continue;
        }
        // Any non-literal ends the current literal run.
        if n_lits > 0 {
            out.write_slice(&lits[..n_lits])?;
            n_lits = 0;
        }
        if kind == KIND_END {
            r.consume_bits(used)?;
            return Ok(());
        }
        if kind == KIND_INVALID {
            return Err(corrupt("deflate: bad literal/length symbol"));
        }
        let len = base as u64 + ((word >> used) & extra_mask(lextra));
        r.consume_bits(used + lextra)?;
        // The distance code and its extra bits are still in the same
        // peeked word, shifted past the length half.
        let dword = word >> (used + lextra);
        let de = dist.lookup_resolved(dword);
        let (dkind, dbase, dextra, dused) = if de != 0 {
            (resolved_kind(de), resolved_base(de), resolved_extra(de), resolved_len(de))
        } else {
            let (dsym, dused) = dist.decode_word(dword)?;
            let (dkind, dbase, dextra) = resolve_dist(dsym);
            (dkind, dbase, dextra, dused)
        };
        if dkind == KIND_INVALID {
            return Err(corrupt("deflate: bad distance symbol"));
        }
        let d = dbase as u64 + ((dword >> dused) & extra_mask(dextra));
        r.consume_bits(dused + dextra)?;
        // Two Huffman lookups + extra-bit decodes + copy setup: the
        // arithmetic-heavy decode the paper profiles (§III).
        out.on_symbol(SymbolKind::DeflateMatch, 160, (r.consumed_bits() + 7) / 8);
        out.memcpy(d, len)?;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::decomp::ByteSink;

    #[test]
    fn stored_block_roundtrip() {
        // Hand-built stored block: BFINAL=1 BTYPE=00, aligned, LEN, NLEN.
        let payload = b"hello stored";
        let mut raw = vec![0b0000_0001u8]; // bfinal=1, btype=00
        raw.extend_from_slice(&(payload.len() as u16).to_le_bytes());
        raw.extend_from_slice(&(!(payload.len() as u16)).to_le_bytes());
        raw.extend_from_slice(payload);
        let mut sink = ByteSink::new();
        inflate(&raw, &mut sink).unwrap();
        assert_eq!(sink.out, payload);
    }

    #[test]
    fn stored_block_nlen_mismatch() {
        let mut raw = vec![0b0000_0001u8];
        raw.extend_from_slice(&5u16.to_le_bytes());
        raw.extend_from_slice(&1234u16.to_le_bytes());
        raw.extend_from_slice(b"hello");
        let mut sink = ByteSink::new();
        assert!(inflate(&raw, &mut sink).is_err());
    }

    #[test]
    fn reserved_block_type_rejected() {
        let raw = [0b0000_0111u8]; // bfinal=1, btype=11
        let mut sink = ByteSink::new();
        assert!(inflate(&raw, &mut sink).is_err());
    }

    #[test]
    fn truncated_stream_rejected() {
        let raw = [0b0000_0101u8]; // fixed block, then nothing
        let mut sink = ByteSink::new();
        assert!(inflate(&raw, &mut sink).is_err());
    }

    #[test]
    fn literal_batches_flush_across_boundary() {
        // More than LIT_BATCH consecutive literals in one fixed-Huffman
        // block: the staged batch must flush mid-run and the tail must
        // flush at end-of-block, byte-identical to the payload.
        use crate::codecs::deflate::huffman::CanonicalCodes;
        use crate::format::bitio::LsbBitWriter;
        let payload: Vec<u8> = (0..LIT_BATCH + 37).map(|i| (i % 251) as u8).collect();
        let mut lens = vec![8u8; 144];
        lens.extend(std::iter::repeat(9u8).take(112));
        lens.extend(std::iter::repeat(7u8).take(24));
        lens.extend(std::iter::repeat(8u8).take(8));
        let codes = CanonicalCodes::from_lengths(&lens).unwrap();
        let mut w = LsbBitWriter::new();
        w.put_bits(1, 1); // BFINAL
        w.put_bits(1, 2); // BTYPE = fixed
        for &b in &payload {
            w.put_bits(codes.codes[b as usize] as u64, codes.lens[b as usize] as u32);
        }
        w.put_bits(codes.codes[256] as u64, codes.lens[256] as u32);
        let raw = w.finish();
        let mut sink = ByteSink::new();
        inflate(&raw, &mut sink).unwrap();
        assert_eq!(sink.out, payload);
        // And the batched sink agrees with the scalar oracle.
        let mut scalar = crate::decomp::ScalarSink::new();
        inflate(&raw, &mut scalar).unwrap();
        assert_eq!(scalar.out, payload);
    }

    // Full encoder<->decoder roundtrips live in deflate::tests.
}
