//! DEFLATE decoder (RFC 1951), written against the CODAG stream
//! abstractions: literals go through `write_byte`, back-references
//! through `memcpy(offset, len)` — exactly the Table II primitives the
//! paper lists for dictionary-based encodings.

use crate::codecs::deflate::huffman::HuffmanDecoder;
use crate::decomp::{OutputStream, SymbolKind};
use crate::format::bitio::LsbBitReader;
use crate::{corrupt, Result};

/// Length-code base values (codes 257–285).
pub const LENGTH_BASE: [u16; 29] = [
    3, 4, 5, 6, 7, 8, 9, 10, 11, 13, 15, 17, 19, 23, 27, 31, 35, 43, 51, 59, 67, 83, 99, 115,
    131, 163, 195, 227, 258,
];
/// Length-code extra bits.
pub const LENGTH_EXTRA: [u8; 29] = [
    0, 0, 0, 0, 0, 0, 0, 0, 1, 1, 1, 1, 2, 2, 2, 2, 3, 3, 3, 3, 4, 4, 4, 4, 5, 5, 5, 5, 0,
];
/// Distance-code base values (codes 0–29).
pub const DIST_BASE: [u16; 30] = [
    1, 2, 3, 4, 5, 7, 9, 13, 17, 25, 33, 49, 65, 97, 129, 193, 257, 385, 513, 769, 1025, 1537,
    2049, 3073, 4097, 6145, 8193, 12289, 16385, 24577,
];
/// Distance-code extra bits.
pub const DIST_EXTRA: [u8; 30] = [
    0, 0, 0, 0, 1, 1, 2, 2, 3, 3, 4, 4, 5, 5, 6, 6, 7, 7, 8, 8, 9, 9, 10, 10, 11, 11, 12, 12,
    13, 13,
];
/// Order in which code-length-code lengths are transmitted.
pub const CLC_ORDER: [usize; 19] = [16, 17, 18, 0, 8, 7, 9, 6, 10, 5, 11, 4, 12, 3, 13, 2, 14, 1, 15];

/// Build the fixed literal/length decoder (RFC 1951 §3.2.6).
pub fn fixed_lit_decoder() -> HuffmanDecoder {
    let mut lens = vec![8u8; 144];
    lens.extend(std::iter::repeat(9u8).take(112));
    lens.extend(std::iter::repeat(7u8).take(24));
    lens.extend(std::iter::repeat(8u8).take(8));
    HuffmanDecoder::from_lengths(&lens).expect("fixed table is valid")
}

/// Build the fixed distance decoder.
pub fn fixed_dist_decoder() -> HuffmanDecoder {
    HuffmanDecoder::from_lengths(&[5u8; 30]).expect("fixed table is valid")
}

/// Decode the dynamic-block Huffman tables (RFC 1951 §3.2.7).
fn read_dynamic_tables(r: &mut LsbBitReader<'_>) -> Result<(HuffmanDecoder, HuffmanDecoder)> {
    let hlit = r.fetch_bits(5)? as usize + 257;
    let hdist = r.fetch_bits(5)? as usize + 1;
    let hclen = r.fetch_bits(4)? as usize + 4;
    if hlit > 286 || hdist > 30 {
        return Err(corrupt(format!("deflate: bad table sizes hlit={hlit} hdist={hdist}")));
    }
    let mut clc_lens = [0u8; 19];
    for &idx in CLC_ORDER.iter().take(hclen) {
        clc_lens[idx] = r.fetch_bits(3)? as u8;
    }
    let clc = HuffmanDecoder::from_lengths(&clc_lens)?;
    // Decode the hlit + hdist code lengths with the CLC.
    let total = hlit + hdist;
    let mut lens = Vec::with_capacity(total);
    while lens.len() < total {
        let sym = clc.decode(r)?;
        match sym {
            0..=15 => lens.push(sym as u8),
            16 => {
                let &last = lens.last().ok_or_else(|| corrupt("deflate: repeat with no prior length"))?;
                let n = 3 + r.fetch_bits(2)? as usize;
                lens.extend(std::iter::repeat(last).take(n));
            }
            17 => {
                let n = 3 + r.fetch_bits(3)? as usize;
                lens.extend(std::iter::repeat(0u8).take(n));
            }
            18 => {
                let n = 11 + r.fetch_bits(7)? as usize;
                lens.extend(std::iter::repeat(0u8).take(n));
            }
            _ => return Err(corrupt("deflate: bad code-length symbol")),
        }
    }
    if lens.len() != total {
        return Err(corrupt("deflate: code-length run overflows table"));
    }
    if lens[256] == 0 {
        return Err(corrupt("deflate: end-of-block symbol has no code"));
    }
    let lit = HuffmanDecoder::from_lengths(&lens[..hlit])?;
    let dist_lens = &lens[hlit..];
    // All-zero distance table means the block has no matches; RFC allows
    // a single zero-length code. Use a dummy 1-symbol decoder.
    let dist = if dist_lens.iter().all(|&l| l == 0) {
        HuffmanDecoder::from_lengths(&[1u8])?
    } else {
        HuffmanDecoder::from_lengths(dist_lens)?
    };
    Ok((lit, dist))
}

/// Inflate one DEFLATE bit stream into `out`.
pub fn inflate<O: OutputStream>(data: &[u8], out: &mut O) -> Result<()> {
    let mut r = LsbBitReader::new(data);
    loop {
        let bfinal = r.fetch_bits(1)?;
        let btype = r.fetch_bits(2)?;
        match btype {
            0 => inflate_stored(&mut r, out)?,
            1 => {
                let lit = fixed_lit_decoder();
                let dist = fixed_dist_decoder();
                out.on_symbol(SymbolKind::DeflateHeader, 250, (r.consumed_bits() + 7) / 8);
                inflate_block(&mut r, &lit, &dist, out)?;
            }
            2 => {
                let (lit, dist) = read_dynamic_tables(&mut r)?;
                // Dynamic table construction is a real decode cost the
                // paper's Deflate analysis attributes to the leader
                // thread (§III): count it as a header symbol.
                out.on_symbol(SymbolKind::DeflateHeader, 3000, (r.consumed_bits() + 7) / 8);
                inflate_block(&mut r, &lit, &dist, out)?;
            }
            _ => return Err(corrupt("deflate: reserved block type")),
        }
        if bfinal == 1 {
            return Ok(());
        }
    }
}

fn inflate_stored<O: OutputStream>(r: &mut LsbBitReader<'_>, out: &mut O) -> Result<()> {
    r.align_byte();
    let len = r.fetch_bits(16)? as usize;
    let nlen = r.fetch_bits(16)? as usize;
    if len != (!nlen & 0xFFFF) {
        return Err(corrupt("deflate: stored block LEN/NLEN mismatch"));
    }
    out.on_symbol(SymbolKind::DeflateHeader, 10, (r.consumed_bits() + 7) / 8);
    for _ in 0..len {
        let b = r.fetch_bits(8)? as u8;
        out.write_byte(b)?;
    }
    out.on_symbol(SymbolKind::DeflateLiteral, 3 * len as u32, (r.consumed_bits() + 7) / 8);
    Ok(())
}

fn inflate_block<O: OutputStream>(
    r: &mut LsbBitReader<'_>,
    lit: &HuffmanDecoder,
    dist: &HuffmanDecoder,
    out: &mut O,
) -> Result<()> {
    loop {
        let sym = lit.decode(r)?;
        match sym {
            0..=255 => {
                out.on_symbol(SymbolKind::DeflateLiteral, 60, (r.consumed_bits() + 7) / 8);
                out.write_byte(sym as u8)?;
            }
            256 => return Ok(()),
            257..=285 => {
                let li = (sym - 257) as usize;
                let len =
                    LENGTH_BASE[li] as u64 + r.fetch_bits(LENGTH_EXTRA[li] as u32)?;
                let dsym = dist.decode(r)? as usize;
                if dsym >= 30 {
                    return Err(corrupt("deflate: bad distance symbol"));
                }
                let d = DIST_BASE[dsym] as u64 + r.fetch_bits(DIST_EXTRA[dsym] as u32)?;
                // Two Huffman walks + extra-bit fetches + copy setup:
                // the arithmetic-heavy decode the paper profiles (§III).
                out.on_symbol(SymbolKind::DeflateMatch, 160, (r.consumed_bits() + 7) / 8);
                out.memcpy(d, len)?;
            }
            _ => return Err(corrupt("deflate: bad literal/length symbol")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::decomp::ByteSink;

    #[test]
    fn stored_block_roundtrip() {
        // Hand-built stored block: BFINAL=1 BTYPE=00, aligned, LEN, NLEN.
        let payload = b"hello stored";
        let mut raw = vec![0b0000_0001u8]; // bfinal=1, btype=00
        raw.extend_from_slice(&(payload.len() as u16).to_le_bytes());
        raw.extend_from_slice(&(!(payload.len() as u16)).to_le_bytes());
        raw.extend_from_slice(payload);
        let mut sink = ByteSink::new();
        inflate(&raw, &mut sink).unwrap();
        assert_eq!(sink.out, payload);
    }

    #[test]
    fn stored_block_nlen_mismatch() {
        let mut raw = vec![0b0000_0001u8];
        raw.extend_from_slice(&5u16.to_le_bytes());
        raw.extend_from_slice(&1234u16.to_le_bytes());
        raw.extend_from_slice(b"hello");
        let mut sink = ByteSink::new();
        assert!(inflate(&raw, &mut sink).is_err());
    }

    #[test]
    fn reserved_block_type_rejected() {
        let raw = [0b0000_0111u8]; // bfinal=1, btype=11
        let mut sink = ByteSink::new();
        assert!(inflate(&raw, &mut sink).is_err());
    }

    #[test]
    fn truncated_stream_rejected() {
        let raw = [0b0000_0101u8]; // fixed block, then nothing
        let mut sink = ByteSink::new();
        assert!(inflate(&raw, &mut sink).is_err());
    }

    // Full encoder<->decoder roundtrips live in deflate::tests.
}
