//! DEFLATE encoder: LZ77 tokens → fixed or dynamic Huffman blocks.
//!
//! Mirrors zlib level-9 structure: tokenize with the lazy hash-chain
//! matcher, gather symbol frequencies, then emit whichever of
//! {stored, fixed, dynamic} is smallest for the block. Dynamic blocks
//! serialize their code lengths with the 16/17/18 run-length meta-code.

use crate::codecs::deflate::huffman::{build_lengths, CanonicalCodes, MAX_BITS};
use crate::codecs::deflate::inflate::{
    CLC_ORDER, DIST_BASE, DIST_EXTRA, LENGTH_BASE, LENGTH_EXTRA,
};
use crate::codecs::deflate::lz77::{tokenize, Token};
use crate::format::bitio::LsbBitWriter;
use crate::Result;

/// Map a match length (3–258) to (code index 0–28, extra bits value).
#[inline]
fn length_code(len: u16) -> (usize, u16) {
    debug_assert!((3..=258).contains(&len));
    // Linear scan is fine: 29 entries, called once per match token.
    let mut i = 28;
    while LENGTH_BASE[i] > len {
        i -= 1;
    }
    (i, len - LENGTH_BASE[i])
}

/// Map a distance (1–32768) to (code index 0–29, extra bits value).
#[inline]
fn dist_code(dist: u16) -> (usize, u16) {
    debug_assert!(dist >= 1);
    let mut i = 29;
    while DIST_BASE[i] > dist {
        i -= 1;
    }
    (i, dist - DIST_BASE[i])
}

/// Compress `data` into a single-member DEFLATE stream.
pub fn deflate(data: &[u8]) -> Result<Vec<u8>> {
    let tokens = tokenize(data);
    let mut w = LsbBitWriter::new();
    emit_block(&tokens, data, true, &mut w)?;
    Ok(w.finish())
}

/// Compress `data` closing a block every `interval` bytes and recording
/// the bit position of each boundary as a restart point (container v2).
///
/// Each sub-block is tokenized independently, so no back-reference
/// crosses a boundary and decode can resume at any recorded `bit_pos`.
/// BFINAL is set only on the last block: the result is one valid RFC
/// 1951 stream serial decoders consume unchanged (at a small ratio cost
/// versus [`deflate`] from the lost cross-boundary matches).
/// `interval == 0`, or data short enough for a single sub-block, falls
/// back to [`deflate`] byte-identically with no restart points.
pub fn deflate_with_restarts(
    data: &[u8],
    interval: usize,
) -> Result<(Vec<u8>, Vec<crate::codecs::RestartPoint>)> {
    if interval == 0 || data.len() <= interval {
        return Ok((deflate(data)?, Vec::new()));
    }
    let mut w = LsbBitWriter::new();
    let mut points = Vec::with_capacity(data.len() / interval);
    let n_blocks = (data.len() + interval - 1) / interval;
    for (bi, sub) in data.chunks(interval).enumerate() {
        if bi > 0 {
            points.push(crate::codecs::RestartPoint {
                bit_pos: w.bit_len(),
                out_off: (bi * interval) as u64,
            });
        }
        let tokens = tokenize(sub);
        emit_block(&tokens, sub, bi + 1 == n_blocks, &mut w)?;
    }
    Ok((w.finish(), points))
}

/// Frequencies of literal/length and distance symbols for `tokens`.
fn frequencies(tokens: &[Token]) -> (Vec<u32>, Vec<u32>) {
    let mut lit = vec![0u32; 286];
    let mut dist = vec![0u32; 30];
    for t in tokens {
        match *t {
            Token::Literal(b) => lit[b as usize] += 1,
            Token::Match { len, dist: d } => {
                lit[257 + length_code(len).0] += 1;
                dist[dist_code(d).0] += 1;
            }
        }
    }
    lit[256] += 1; // end-of-block
    (lit, dist)
}

/// Cost in bits of coding `tokens` with the given code lengths.
fn token_cost(tokens: &[Token], lit_lens: &[u8], dist_lens: &[u8]) -> u64 {
    let mut bits = lit_lens[256] as u64;
    for t in tokens {
        match *t {
            Token::Literal(b) => bits += lit_lens[b as usize] as u64,
            Token::Match { len, dist: d } => {
                let (lc, _) = length_code(len);
                let (dc, _) = dist_code(d);
                bits += lit_lens[257 + lc] as u64
                    + LENGTH_EXTRA[lc] as u64
                    + dist_lens[dc] as u64
                    + DIST_EXTRA[dc] as u64;
            }
        }
    }
    bits
}

/// Fixed-table code lengths.
fn fixed_lens() -> (Vec<u8>, Vec<u8>) {
    let mut lit = vec![8u8; 144];
    lit.extend(std::iter::repeat(9u8).take(112));
    lit.extend(std::iter::repeat(7u8).take(24));
    lit.extend(std::iter::repeat(8u8).take(8));
    (lit, vec![5u8; 30])
}

/// RLE-compress code lengths with symbols 16/17/18; returns (sym, extra).
fn rle_code_lengths(lens: &[u8]) -> Vec<(u8, u8)> {
    let mut out = Vec::new();
    let mut i = 0usize;
    while i < lens.len() {
        let v = lens[i];
        let mut run = 1usize;
        while i + run < lens.len() && lens[i + run] == v {
            run += 1;
        }
        if v == 0 {
            let mut left = run;
            while left >= 11 {
                let n = left.min(138);
                out.push((18, (n - 11) as u8));
                left -= n;
            }
            if left >= 3 {
                out.push((17, (left - 3) as u8));
                left = 0;
            }
            for _ in 0..left {
                out.push((0, 0));
            }
        } else {
            out.push((v, 0));
            let mut left = run - 1;
            while left >= 3 {
                let n = left.min(6);
                out.push((16, (n - 3) as u8));
                left -= n;
            }
            for _ in 0..left {
                out.push((v, 0));
            }
        }
        i += run;
    }
    out
}

/// Emit one block (stored / fixed / dynamic, whichever is smallest).
fn emit_block(tokens: &[Token], raw: &[u8], bfinal: bool, w: &mut LsbBitWriter) -> Result<()> {
    let (lit_freq, dist_freq) = frequencies(tokens);
    let dyn_lit_lens = build_lengths(&lit_freq, MAX_BITS);
    let mut dyn_dist_lens = build_lengths(&dist_freq, MAX_BITS);
    // RFC: at least one distance code must be writable; a zero table is
    // legal but zlib emits one length-1 code — do the same for parity.
    if dyn_dist_lens.iter().all(|&l| l == 0) {
        dyn_dist_lens[0] = 1;
    }
    let (fix_lit_lens, fix_dist_lens) = fixed_lens();

    let fixed_cost = 3 + token_cost(tokens, &fix_lit_lens, &fix_dist_lens);
    let (header_bits, clc_plan) = dynamic_header_cost(&dyn_lit_lens, &dyn_dist_lens);
    let dyn_cost = 3 + header_bits + token_cost(tokens, &dyn_lit_lens, &dyn_dist_lens);
    let stored_cost = 3 + 32 + 8 * raw.len() as u64 + 7; // + alignment

    w.put_bits(bfinal as u64, 1);
    if stored_cost < fixed_cost && stored_cost < dyn_cost && raw.len() <= 0xFFFF {
        w.put_bits(0, 2);
        w.align_byte();
        w.put_aligned_bytes(&(raw.len() as u16).to_le_bytes());
        w.put_aligned_bytes(&(!(raw.len() as u16)).to_le_bytes());
        w.put_aligned_bytes(raw);
        return Ok(());
    }
    if fixed_cost <= dyn_cost {
        w.put_bits(1, 2);
        let lit = CanonicalCodes::from_lengths(&fix_lit_lens)?;
        let dist = CanonicalCodes::from_lengths(&fix_dist_lens)?;
        emit_tokens(tokens, &lit, &dist, w);
    } else {
        w.put_bits(2, 2);
        emit_dynamic_header(&dyn_lit_lens, &dyn_dist_lens, &clc_plan, w)?;
        let lit = CanonicalCodes::from_lengths(&dyn_lit_lens)?;
        let dist = CanonicalCodes::from_lengths(&dyn_dist_lens)?;
        emit_tokens(tokens, &lit, &dist, w);
    }
    Ok(())
}

/// Pre-computed dynamic header plan (shared between cost + emission).
struct ClcPlan {
    hlit: usize,
    hdist: usize,
    hclen: usize,
    rle: Vec<(u8, u8)>,
    clc_lens: [u8; 19],
}

fn dynamic_header_cost(lit_lens: &[u8], dist_lens: &[u8]) -> (u64, ClcPlan) {
    let hlit = (257..=286)
        .rev()
        .find(|&n| n == 257 || lit_lens[n - 1] != 0)
        .unwrap_or(257)
        .max(257);
    let hdist = (1..=30).rev().find(|&n| n == 1 || dist_lens[n - 1] != 0).unwrap_or(1);
    let mut all = Vec::with_capacity(hlit + hdist);
    all.extend_from_slice(&lit_lens[..hlit]);
    all.extend_from_slice(&dist_lens[..hdist]);
    let rle = rle_code_lengths(&all);
    let mut clc_freq = vec![0u32; 19];
    for &(s, _) in &rle {
        clc_freq[s as usize] += 1;
    }
    let clc_lens_v = build_lengths(&clc_freq, 7);
    let mut clc_lens = [0u8; 19];
    clc_lens.copy_from_slice(&clc_lens_v);
    let hclen = (4..=19)
        .rev()
        .find(|&n| n == 4 || clc_lens[CLC_ORDER[n - 1]] != 0)
        .unwrap_or(4);
    let mut bits = 5 + 5 + 4 + 3 * hclen as u64;
    for &(s, _) in &rle {
        bits += clc_lens[s as usize] as u64
            + match s {
                16 => 2,
                17 => 3,
                18 => 7,
                _ => 0,
            };
    }
    (bits, ClcPlan { hlit, hdist, hclen, rle, clc_lens })
}

fn emit_dynamic_header(
    _lit_lens: &[u8],
    _dist_lens: &[u8],
    plan: &ClcPlan,
    w: &mut LsbBitWriter,
) -> Result<()> {
    w.put_bits((plan.hlit - 257) as u64, 5);
    w.put_bits((plan.hdist - 1) as u64, 5);
    w.put_bits((plan.hclen - 4) as u64, 4);
    for &idx in CLC_ORDER.iter().take(plan.hclen) {
        w.put_bits(plan.clc_lens[idx] as u64, 3);
    }
    let clc = CanonicalCodes::from_lengths(&plan.clc_lens)?;
    for &(s, extra) in &plan.rle {
        w.put_bits(clc.codes[s as usize] as u64, clc.lens[s as usize] as u32);
        match s {
            16 => w.put_bits(extra as u64, 2),
            17 => w.put_bits(extra as u64, 3),
            18 => w.put_bits(extra as u64, 7),
            _ => {}
        }
    }
    Ok(())
}

fn emit_tokens(tokens: &[Token], lit: &CanonicalCodes, dist: &CanonicalCodes, w: &mut LsbBitWriter) {
    for t in tokens {
        match *t {
            Token::Literal(b) => {
                w.put_bits(lit.codes[b as usize] as u64, lit.lens[b as usize] as u32)
            }
            Token::Match { len, dist: d } => {
                let (lc, lex) = length_code(len);
                let sym = 257 + lc;
                w.put_bits(lit.codes[sym] as u64, lit.lens[sym] as u32);
                w.put_bits(lex as u64, LENGTH_EXTRA[lc] as u32);
                let (dc, dex) = dist_code(d);
                w.put_bits(dist.codes[dc] as u64, dist.lens[dc] as u32);
                w.put_bits(dex as u64, DIST_EXTRA[dc] as u32);
            }
        }
    }
    w.put_bits(lit.codes[256] as u64, lit.lens[256] as u32);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn length_code_boundaries() {
        assert_eq!(length_code(3), (0, 0));
        assert_eq!(length_code(10), (7, 0));
        assert_eq!(length_code(11), (8, 0));
        assert_eq!(length_code(12), (8, 1));
        assert_eq!(length_code(258), (28, 0));
    }

    #[test]
    fn dist_code_boundaries() {
        assert_eq!(dist_code(1), (0, 0));
        assert_eq!(dist_code(4), (3, 0));
        assert_eq!(dist_code(5), (4, 0));
        assert_eq!(dist_code(6), (4, 1));
        assert_eq!(dist_code(24577), (29, 0));
        assert_eq!(dist_code(32768), (29, 8191));
    }

    #[test]
    fn rle_code_lengths_reconstructs() {
        let lens = [0u8, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 5, 5, 5, 5, 5, 7, 0, 0, 0, 2];
        let rle = rle_code_lengths(&lens);
        // Expand back.
        let mut back: Vec<u8> = Vec::new();
        for &(s, e) in &rle {
            match s {
                16 => {
                    let last = *back.last().unwrap();
                    back.extend(std::iter::repeat(last).take(3 + e as usize));
                }
                17 => back.extend(std::iter::repeat(0u8).take(3 + e as usize)),
                18 => back.extend(std::iter::repeat(0u8).take(11 + e as usize)),
                v => back.push(v),
            }
        }
        assert_eq!(back, lens);
    }
}
