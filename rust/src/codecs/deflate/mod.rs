//! DEFLATE (RFC 1951) from scratch: LZ77 + Huffman (§II-A).
//!
//! The paper compresses with `zlib -9` and decompresses with the RAPIDS
//! `gpuinflate` kernel; here both sides are ours. The decoder is written
//! against the CODAG Table I/II stream abstractions so it runs unchanged
//! under the CPU path, the tracing engines, and (its write phase) maps
//! onto the `memcpy` writing primitive of Algorithm 2.

pub mod encoder;
pub mod huffman;
pub mod inflate;
pub mod lz77;
pub mod zlib;

pub use inflate::inflate_sub_block;

use crate::codecs::{Codec, RestartPoint};
use crate::decomp::{InputStream, OutputStream, SliceSink};
use crate::{corrupt, Result};

/// The registry entry for DEFLATE (wire id 3).
pub struct DeflateCodec;

impl Codec for DeflateCodec {
    fn name(&self) -> &'static str {
        "deflate"
    }
    fn wire_id(&self) -> u32 {
        3
    }
    fn aliases(&self) -> &'static [&'static str] {
        &["zlib"]
    }
    fn block_width(&self) -> u32 {
        128
    }
    fn compress(&self, chunk: &[u8], _width: u8) -> Result<Vec<u8>> {
        compress(chunk)
    }
    fn compress_with_restarts(
        &self,
        chunk: &[u8],
        _width: u8,
        interval: usize,
    ) -> Result<(Vec<u8>, Vec<RestartPoint>)> {
        compress_with_restarts(chunk, interval)
    }
    fn decompress_into(&self, comp: &[u8], out: &mut dyn OutputStream) -> Result<()> {
        let mut input = InputStream::new(comp);
        decode(&mut input, out)
    }
    fn decode_sub_block(
        &self,
        comp: &[u8],
        bit_pos: u64,
        terminal: bool,
        out: &mut [u8],
    ) -> Result<u64> {
        let expect = out.len() as u64;
        let mut sink = SliceSink::new(out);
        let end = inflate_sub_block(comp, bit_pos, expect, terminal, &mut sink)?;
        if sink.bytes_written() != expect {
            return Err(corrupt(format!(
                "sub-block produced {} bytes, expected {expect}",
                sink.bytes_written()
            )));
        }
        Ok(end)
    }
}

/// Compress a chunk into a raw DEFLATE stream.
pub fn compress(chunk: &[u8]) -> Result<Vec<u8>> {
    encoder::deflate(chunk)
}

/// Compress a chunk closing a block every `interval` output bytes and
/// recording container-v2 restart points at the boundaries.
pub fn compress_with_restarts(
    chunk: &[u8],
    interval: usize,
) -> Result<(Vec<u8>, Vec<RestartPoint>)> {
    encoder::deflate_with_restarts(chunk, interval)
}

/// Decode a DEFLATE chunk into `out`.
pub fn decode<O: OutputStream + ?Sized>(input: &mut InputStream<'_>, out: &mut O) -> Result<()> {
    // The bit reader borrows from the input's current position; DEFLATE
    // consumes the whole chunk.
    let data = input.fetch_bytes(input.remaining())?;
    inflate::inflate(data, out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codecs::{decompress_chunk, CodecKind};

    fn roundtrip(data: &[u8]) -> usize {
        let comp = compress(data).unwrap();
        let out = decompress_chunk(CodecKind::Deflate, &comp, data.len()).unwrap();
        assert_eq!(out, data);
        comp.len()
    }

    #[test]
    fn empty_input() {
        roundtrip(&[]);
    }

    #[test]
    fn short_strings() {
        for s in ["a", "ab", "abc", "hello world", "aaaaaaa"] {
            roundtrip(s.as_bytes());
        }
    }

    #[test]
    fn repeated_text_compresses() {
        let data = "the quick brown fox jumps over the lazy dog. ".repeat(200);
        let clen = roundtrip(data.as_bytes());
        assert!(clen < data.len() / 10, "clen={clen} of {}", data.len());
    }

    #[test]
    fn constant_run_compresses_extremely() {
        let data = vec![0u8; 100_000];
        let clen = roundtrip(&data);
        assert!(clen < 200, "clen={clen}");
    }

    #[test]
    fn random_bytes_stored_or_near_raw() {
        let mut x = 77u64;
        let data: Vec<u8> = (0..10_000)
            .map(|_| {
                x = x.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
                (x >> 56) as u8
            })
            .collect();
        let clen = roundtrip(&data);
        // Incompressible: must not expand much (stored fallback).
        assert!(clen <= data.len() + 64, "clen={clen}");
    }

    #[test]
    fn genome_like_data() {
        let mut x = 5u64;
        let alphabet = b"ACGTN";
        let data: Vec<u8> = (0..50_000)
            .map(|i| {
                x = x.wrapping_mul(2862933555777941757).wrapping_add(3037000493);
                if i % 1000 < 30 {
                    b'N'
                } else {
                    alphabet[((x >> 33) % 4) as usize]
                }
            })
            .collect();
        let clen = roundtrip(&data);
        // ~2 bits/base plus structure: at least 2.5x compression.
        assert!(clen < data.len() * 2 / 5, "clen={clen}");
    }

    #[test]
    fn structured_binary_data() {
        let mut data = Vec::new();
        for i in 0..20_000u32 {
            data.extend_from_slice(&(i % 100).to_le_bytes());
        }
        let clen = roundtrip(&data);
        assert!(clen < data.len() / 8);
    }

    #[test]
    fn all_byte_values() {
        let data: Vec<u8> = (0..=255u8).cycle().take(4096).collect();
        roundtrip(&data);
    }

    #[test]
    fn long_distance_matches() {
        // Identical 1 KiB blocks 30 KiB apart (within window).
        let mut x = 1u64;
        let block: Vec<u8> = (0..1024)
            .map(|_| {
                x = x.wrapping_mul(6364136223846793005).wrapping_add(1);
                (x >> 56) as u8
            })
            .collect();
        let mut mid: Vec<u8> = (0..30_000)
            .map(|_| {
                x = x.wrapping_mul(6364136223846793005).wrapping_add(1);
                (x >> 56) as u8
            })
            .collect();
        let mut data = block.clone();
        data.append(&mut mid);
        data.extend_from_slice(&block);
        roundtrip(&data);
    }

    #[test]
    fn corrupt_streams_rejected_not_panicking() {
        let data = "compressible compressible compressible".repeat(50);
        let comp = compress(data.as_bytes()).unwrap();
        // Flip every byte one at a time; must never panic, and either
        // error out or produce output (checksum-free format can't always
        // detect corruption, but it must stay memory-safe).
        for i in 0..comp.len().min(64) {
            let mut bad = comp.clone();
            bad[i] ^= 0xFF;
            let _ = decompress_chunk(CodecKind::Deflate, &bad, data.len());
        }
        // Truncations must error.
        for cut in [1usize, comp.len() / 2, comp.len() - 1] {
            assert!(decompress_chunk(CodecKind::Deflate, &comp[..cut], data.len()).is_err());
        }
    }
}
