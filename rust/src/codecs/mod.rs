//! The encoding techniques the paper evaluates — ORC RLE v1, ORC RLE
//! v2, DEFLATE (§II-A, §V-A) — plus the LZSS byte-match codec (GPULZ,
//! arXiv 2304.07342), behind an object-safe [`Codec`] registry.
//!
//! Every decoder is written once against the CODAG
//! [`OutputStream`](crate::decomp::OutputStream) abstraction and is
//! reused unchanged by:
//!
//! * the plain CPU decompression path ([`decompress_chunk`]),
//! * the GPU-simulator tracing engines ([`crate::decomp::codag_engine`],
//!   [`crate::decomp::block_engine`]),
//! * the hybrid PJRT expand path (RLE codecs decoding to
//!   [`RunRecord`](crate::decomp::RunRecord)s).
//!
//! ## The registry
//!
//! Each codec implements the [`Codec`] trait in its own module and is
//! registered exactly once in [`CODECS`], the registry's static table.
//! Everything else — container parse, coordinator dispatch, stats
//! slots, CLI name parsing, benches — goes through [`CodecRegistry`],
//! so adding a codec is a one-file change plus one table entry.
//! [`CodecKind`] survives as the wire-id newtype stored in container
//! headers; an id the registry does not know yields
//! [`Error::UnknownCodec`](crate::Error::UnknownCodec).
//!
//! ## Chunk payload formats
//!
//! RLE chunks carry a 2-byte header — `[element_width, reserved]` —
//! followed by `n_elems` as a uvarint and the RLE byte stream. DEFLATE
//! chunks are a raw RFC 1951 bit stream. LZSS chunks are flag-grouped
//! byte tokens (see [`lzss`]). (The paper uses ORC files and zlib; we
//! keep the same encodings but a minimal framing, documented in
//! DESIGN.md.)

pub mod deflate;
pub mod lzss;
pub mod rle_v1;
pub mod rle_v2;

use crate::decomp::{ByteSink, InputStream, OutputStream, RunRecord, RunRecorder, SliceSink};
use crate::{corrupt, invalid, Error, Result};

/// A point where decode of a chunk can restart mid-stream (container v2).
///
/// Recorded at pack time at codec-chosen sub-block boundaries: for the
/// RLE codecs a group/control-unit boundary (always byte-aligned, so
/// `bit_pos % 8 == 0`), for DEFLATE a block boundary at an arbitrary bit
/// position, for LZSS a segment boundary (byte-aligned). `bit_pos`
/// counts bits from the start of the compressed chunk *including* the
/// chunk header; `out_off` is the uncompressed byte offset the restarted
/// decode produces first. The implicit first boundary `(0, 0)` is never
/// stored.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RestartPoint {
    /// Bit position in the compressed chunk where decode may resume.
    pub bit_pos: u64,
    /// Uncompressed byte offset produced by decode from `bit_pos`.
    pub out_off: u64,
}

/// Encoder-side restart recorder: encoders `offer` every decode boundary
/// they emit and the recorder keeps the first one at or past each
/// `interval`-byte threshold of uncompressed output. `interval == 0`
/// disables recording; boundaries at offset 0 or at the end of the chunk
/// are never stored (they are implicit).
pub(crate) struct RestartRec {
    interval: u64,
    next: u64,
    total: u64,
    width: u64,
    pub(crate) points: Vec<RestartPoint>,
}

impl RestartRec {
    pub(crate) fn new(interval: usize, total_out_bytes: u64, width: u8) -> Self {
        let interval = interval as u64;
        RestartRec {
            interval,
            next: interval,
            total: total_out_bytes,
            width: width as u64,
            points: Vec::new(),
        }
    }

    /// Offer a boundary: `elems_done` elements decode from the first
    /// `stream_bytes` bytes of the stream being built.
    pub(crate) fn offer(&mut self, stream_bytes: usize, elems_done: u64) {
        if self.interval == 0 {
            return;
        }
        let out_off = elems_done.saturating_mul(self.width);
        if out_off == 0 || out_off >= self.total {
            return;
        }
        if out_off >= self.next {
            self.points.push(RestartPoint { bit_pos: stream_bytes as u64 * 8, out_off });
            self.next = out_off.saturating_add(self.interval);
        }
    }
}

/// The wire-format codec id stored in a container header (and, for
/// mixed containers, per chunk).
///
/// A plain newtype over the on-disk `u32`: the set of *known* ids lives
/// in the [`CodecRegistry`], not here, so a new codec never adds a
/// match arm to this type. The associated constants keep the familiar
/// `CodecKind::Deflate`-style spelling working everywhere.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct CodecKind(pub u32);

#[allow(non_upper_case_globals)]
impl CodecKind {
    /// ORC run-length encoding v1 (byte RLE for width-1, integer RLE else).
    pub const RleV1: CodecKind = CodecKind(1);
    /// ORC run-length encoding v2 (short-repeat / direct / patched-base /
    /// delta sub-encodings).
    pub const RleV2: CodecKind = CodecKind(2);
    /// DEFLATE (RFC 1951): LZ77 + fixed/dynamic Huffman.
    pub const Deflate: CodecKind = CodecKind(3);
    /// LZSS byte-match compression (flag-grouped literal runs + matches).
    pub const Lzss: CodecKind = CodecKind(4);

    /// Parse the container-format discriminant (registered ids only).
    pub fn from_u32(v: u32) -> Option<CodecKind> {
        CodecRegistry::by_id(v).map(|c| CodecKind(c.wire_id()))
    }

    /// Short lowercase name (CLI / reports); `"unknown"` for an id the
    /// registry does not know.
    pub fn name(&self) -> &'static str {
        CodecRegistry::get(*self).map_or("unknown", |c| c.name())
    }

    /// Parse a CLI name or alias via the registry.
    pub fn parse(s: &str) -> Option<CodecKind> {
        CodecRegistry::by_name(s).map(|c| CodecKind(c.wire_id()))
    }

    /// All registered codecs, in registry (reporting) order.
    pub fn all() -> [CodecKind; N_CODECS] {
        let mut out = [CodecKind(0); N_CODECS];
        for (i, c) in CODECS.iter().enumerate() {
            out[i] = CodecKind(c.wire_id());
        }
        out
    }

    /// True for the run-structured codecs eligible for the PJRT expand path.
    pub fn is_rle(&self) -> bool {
        CodecRegistry::get(*self).is_some_and(|c| c.is_rle())
    }
}

/// Valid element widths for the RLE codecs.
pub const VALID_WIDTHS: [u8; 4] = [1, 2, 4, 8];

/// An object-safe codec: one implementation per wire format, registered
/// in [`CODECS`]. All methods take `&self` on a zero-sized registrant
/// struct; dispatch everywhere is through `&'static dyn Codec`.
///
/// Contract (DESIGN.md §12): `wire_id` and `name` are stable forever;
/// `decompress_into` must be a pure function of `comp` (same bytes in,
/// same bytes out, on every sink); `decode_sub_block` must fill its
/// slice exactly and report the bit position it stopped at, so the
/// parallel stitch can validate adjacency; `compress_with_restarts` may
/// only emit restart points whose suffix decodes without referencing
/// output before the point (the stitch worker writes into a disjoint
/// slice and cannot see earlier output).
pub trait Codec: Sync {
    /// Short lowercase canonical name (CLI / reports / stats rows).
    fn name(&self) -> &'static str;

    /// The stable container-format discriminant.
    fn wire_id(&self) -> u32;

    /// Extra accepted CLI spellings (lowercase).
    fn aliases(&self) -> &'static [&'static str] {
        &[]
    }

    /// True for run-structured codecs: width-aware compression and the
    /// PJRT run-record expand path apply.
    fn is_rle(&self) -> bool {
        false
    }

    /// Decode-unit width in threads for the GPU-simulator engines
    /// (paper §IV: RLE decodes in 1024-thread units, DEFLATE in 128).
    fn block_width(&self) -> u32;

    /// Compress one chunk with an explicit RLE element width (ignored
    /// by byte-oriented codecs).
    fn compress(&self, chunk: &[u8], width: u8) -> Result<Vec<u8>>;

    /// Compress one chunk, recording restart points roughly every
    /// `interval` uncompressed bytes (container v2). `interval == 0`
    /// disables recording.
    fn compress_with_restarts(
        &self,
        chunk: &[u8],
        width: u8,
        interval: usize,
    ) -> Result<(Vec<u8>, Vec<RestartPoint>)>;

    /// Decode one whole chunk into any [`OutputStream`].
    fn decompress_into(&self, comp: &[u8], out: &mut dyn OutputStream) -> Result<()>;

    /// Decode one sub-block into a bounded disjoint slice (the parallel
    /// stitch worker path, DESIGN.md §7.5). See [`decode_sub_block`].
    fn decode_sub_block(
        &self,
        comp: &[u8],
        bit_pos: u64,
        terminal: bool,
        out: &mut [u8],
    ) -> Result<u64>;

    /// Reject a chunk whose header declares a different uncompressed
    /// size than the container index expects (no-op for codecs whose
    /// length is implicit in the stream structure).
    fn check_chunk_header(&self, _comp: &[u8], _uncomp_len: u64) -> Result<()> {
        Ok(())
    }

    /// Compress auto-selecting the RLE element width (largest of
    /// 8/4/2/1 that divides the chunk length and yields the strictly
    /// smallest output — mirrors how an ORC writer picks a column's
    /// physical type). Byte-oriented codecs compress directly.
    fn compress_auto(&self, chunk: &[u8]) -> Result<Vec<u8>> {
        if !self.is_rle() {
            return self.compress(chunk, 1);
        }
        let mut best: Option<Vec<u8>> = None;
        for &w in VALID_WIDTHS.iter().rev() {
            if chunk.len() % w as usize != 0 {
                continue;
            }
            let c = self.compress(chunk, w)?;
            if best.as_ref().map_or(true, |b| c.len() < b.len()) {
                best = Some(c);
            }
        }
        best.ok_or_else(|| invalid("chunk length not divisible by any element width"))
    }

    /// Auto-width variant of
    /// [`compress_with_restarts`](Codec::compress_with_restarts) —
    /// same width selection as [`compress_auto`](Codec::compress_auto).
    fn compress_auto_with_restarts(
        &self,
        chunk: &[u8],
        interval: usize,
    ) -> Result<(Vec<u8>, Vec<RestartPoint>)> {
        if !self.is_rle() {
            return self.compress_with_restarts(chunk, 1, interval);
        }
        let mut best: Option<(Vec<u8>, Vec<RestartPoint>)> = None;
        for &w in VALID_WIDTHS.iter().rev() {
            if chunk.len() % w as usize != 0 {
                continue;
            }
            let c = self.compress_with_restarts(chunk, w, interval)?;
            if best.as_ref().map_or(true, |b| c.0.len() < b.0.len()) {
                best = Some(c);
            }
        }
        best.ok_or_else(|| invalid("chunk length not divisible by any element width"))
    }
}

/// Number of registered codecs (the length of [`CODECS`]).
pub const N_CODECS: usize = 4;

/// The registry's static table — the single registration point. Order
/// is the reporting order (stats slots, bench rows, `CodecKind::all()`)
/// and is pinned by a unit test; append only.
static CODECS: [&'static dyn Codec; N_CODECS] =
    [&rle_v1::RleV1Codec, &rle_v2::RleV2Codec, &deflate::DeflateCodec, &lzss::LzssCodec];

/// Lookup facade over [`CODECS`]: wire ids and names to
/// `&'static dyn Codec`.
pub struct CodecRegistry;

impl CodecRegistry {
    /// All registered codecs in registration (reporting) order.
    pub fn codecs() -> &'static [&'static dyn Codec] {
        &CODECS
    }

    /// Number of registered codecs.
    pub const fn len() -> usize {
        N_CODECS
    }

    /// Look up by wire id.
    pub fn by_id(id: u32) -> Option<&'static dyn Codec> {
        CODECS.iter().copied().find(|c| c.wire_id() == id)
    }

    /// Look up by canonical name or alias (case-insensitive).
    pub fn by_name(name: &str) -> Option<&'static dyn Codec> {
        let n = name.to_ascii_lowercase();
        CODECS
            .iter()
            .copied()
            .find(|c| c.name() == n || c.aliases().contains(&n.as_str()))
    }

    /// Look up by [`CodecKind`]; `None` for unregistered ids.
    pub fn get(kind: CodecKind) -> Option<&'static dyn Codec> {
        Self::by_id(kind.0)
    }

    /// Look up by [`CodecKind`], failing with the typed
    /// [`Error::UnknownCodec`] for unregistered ids.
    pub fn by_kind(kind: CodecKind) -> Result<&'static dyn Codec> {
        Self::by_id(kind.0).ok_or(Error::UnknownCodec(kind.0))
    }

    /// Registry position of a codec (the per-codec stats slot).
    pub fn slot(kind: CodecKind) -> Option<usize> {
        CODECS.iter().position(|c| c.wire_id() == kind.0)
    }

    /// Canonical names in registry order (CLI error messages).
    pub fn names() -> [&'static str; N_CODECS] {
        let mut out = [""; N_CODECS];
        for (i, c) in CODECS.iter().enumerate() {
            out[i] = c.name();
        }
        out
    }
}

/// Compress one chunk with an explicit RLE element width.
///
/// `width` must divide `chunk.len()` for RLE codecs; it is ignored for
/// the byte-oriented codecs (DEFLATE, LZSS).
pub fn compress_chunk_with(kind: CodecKind, chunk: &[u8], width: u8) -> Result<Vec<u8>> {
    CodecRegistry::by_kind(kind)?.compress(chunk, width)
}

/// Compress one chunk with an explicit RLE element width, recording
/// restart points roughly every `interval` uncompressed bytes (container
/// v2). `interval == 0` disables recording. For the RLE codecs restart
/// recording is passive — the compressed bytes are identical to
/// [`compress_chunk_with`]; DEFLATE closes a block and LZSS a segment at
/// each boundary so sub-blocks carry no cross-boundary back-references
/// (the stream stays decodable by the serial path).
pub fn compress_chunk_with_restarts(
    kind: CodecKind,
    chunk: &[u8],
    width: u8,
    interval: usize,
) -> Result<(Vec<u8>, Vec<RestartPoint>)> {
    CodecRegistry::by_kind(kind)?.compress_with_restarts(chunk, width, interval)
}

/// Auto-width variant of [`compress_chunk_with_restarts`] — mirrors
/// [`compress_chunk`]'s width selection.
pub fn compress_chunk_restarts(
    kind: CodecKind,
    chunk: &[u8],
    interval: usize,
) -> Result<(Vec<u8>, Vec<RestartPoint>)> {
    CodecRegistry::by_kind(kind)?.compress_auto_with_restarts(chunk, interval)
}

/// Decode one sub-block of a chunk into a bounded disjoint slice (the
/// parallel stitch worker path, DESIGN.md §7.5).
///
/// `bit_pos == 0` means "start of the chunk" (for headered codecs:
/// right after the chunk header); any other value must name a restart
/// point recorded at pack time. `terminal` marks the chunk's last
/// sub-block (DEFLATE verifies BFINAL falls exactly there). `out` must
/// be exactly the sub-block's uncompressed extent — the decode fills it
/// completely or returns `Corrupt`; it can never write outside it.
/// Returns the bit position where decode stopped, which stitching
/// validates against the next restart point.
pub fn decode_sub_block(
    kind: CodecKind,
    comp: &[u8],
    bit_pos: u64,
    terminal: bool,
    out: &mut [u8],
) -> Result<u64> {
    CodecRegistry::by_kind(kind)?.decode_sub_block(comp, bit_pos, terminal, out)
}

/// Shared sub-block decoder for the headered byte-aligned codecs (both
/// RLEs): positions the input at the restart byte, hands the per-element
/// decode loop a bounded budget, and verifies the slice was filled
/// exactly.
pub(crate) fn decode_rle_sub_block(
    comp: &[u8],
    bit_pos: u64,
    out: &mut [u8],
    decode: impl FnOnce(&mut InputStream<'_>, u8, u64, &mut SliceSink<'_>) -> Result<()>,
) -> Result<u64> {
    let expect = out.len() as u64;
    let mut sink = SliceSink::new(out);
    let mut header = InputStream::new(comp);
    let (width, _n_total) = read_rle_header(&mut header)?;
    let header_len = header.bytes_consumed() as usize;
    let start = if bit_pos == 0 {
        header_len
    } else {
        if bit_pos % 8 != 0 {
            return Err(corrupt("rle restart point is not byte-aligned"));
        }
        let b = (bit_pos / 8) as usize;
        if b < header_len || b > comp.len() {
            return Err(corrupt(format!(
                "rle restart point at byte {b} outside stream (header {header_len}, \
                 len {})",
                comp.len()
            )));
        }
        b
    };
    if expect % width as u64 != 0 {
        return Err(corrupt(format!(
            "restart point splits a width-{width} element ({expect} bytes)"
        )));
    }
    let budget = expect / width as u64;
    let mut input = InputStream::new(&comp[start..]);
    decode(&mut input, width, budget, &mut sink)?;
    if sink.bytes_written() != expect {
        return Err(corrupt(format!(
            "sub-block produced {} bytes, expected {expect}",
            sink.bytes_written()
        )));
    }
    Ok((start as u64 + input.bytes_consumed()) * 8)
}

/// Reject a chunk whose header declares a different uncompressed size
/// than the container index expects.
///
/// Serial decode is driven by the header's declared count; split decode
/// is driven by per-sub-block output budgets and never consults it.
/// Without this gate a corrupted count field would truncate (or fail)
/// serial decode while every bounded sub-block still decoded cleanly —
/// the divergence the stitch contract (DESIGN.md §7.5) forbids. No-op
/// for DEFLATE, whose length is implicit in the block structure.
pub fn check_chunk_header(kind: CodecKind, comp: &[u8], uncomp_len: u64) -> Result<()> {
    CodecRegistry::by_kind(kind)?.check_chunk_header(comp, uncomp_len)
}

/// Reusable element-count check for the headered RLE codecs.
pub(crate) fn check_rle_chunk_header(comp: &[u8], uncomp_len: u64) -> Result<()> {
    let mut header = InputStream::new(comp);
    let (width, n_total) = read_rle_header(&mut header)?;
    let declared = n_total.saturating_mul(width as u64);
    if declared != uncomp_len {
        return Err(corrupt(format!(
            "rle chunk header declares {declared} uncompressed bytes, index says {uncomp_len}"
        )));
    }
    Ok(())
}

/// Compress one chunk, auto-selecting the RLE element width.
pub fn compress_chunk(kind: CodecKind, chunk: &[u8]) -> Result<Vec<u8>> {
    CodecRegistry::by_kind(kind)?.compress_auto(chunk)
}

/// Decompress one chunk into a fresh buffer.
///
/// `size_hint` is the expected uncompressed size (from the container
/// index) used only for allocation; the decoded length is authoritative.
pub fn decompress_chunk(kind: CodecKind, comp: &[u8], size_hint: usize) -> Result<Vec<u8>> {
    let mut sink = ByteSink::with_capacity(size_hint);
    decode_into(kind, comp, &mut sink)?;
    Ok(sink.into_bytes())
}

/// Decode one chunk into any [`OutputStream`] — the single decode entry
/// point all engines share.
pub fn decode_into<O: OutputStream>(kind: CodecKind, comp: &[u8], out: &mut O) -> Result<()> {
    CodecRegistry::by_kind(kind)?.decompress_into(comp, out)
}

/// Decode an RLE chunk to run records (the PJRT expand path input).
/// Returns the records plus the element width.
pub fn decode_to_runs(kind: CodecKind, comp: &[u8]) -> Result<(Vec<RunRecord>, u8)> {
    if !kind.is_rle() {
        return Err(invalid(format!("{} does not decode to runs", kind.name())));
    }
    let mut rec = RunRecorder::new();
    decode_into(kind, comp, &mut rec)?;
    let width = if rec.width == 0 { 1 } else { rec.width };
    Ok((rec.runs, width))
}

/// Average compressed-symbol length (Table V's right columns): decoded
/// *elements* produced per compressed symbol, where a symbol is a run
/// header, a literal-group element, or a DEFLATE/LZSS token. For
/// byte-typed data (TPC/TPT/HRG) this is bytes per symbol, matching the
/// paper (e.g. avg 1.00 for TPC under RLE v1 = no runs); for wider
/// columns it is the average run length in elements.
pub fn avg_symbol_len(kind: CodecKind, comp: &[u8]) -> Result<f64> {
    use crate::decomp::{CountingSink, SymbolKind};

    /// Wrapper that counts `on_symbol` calls and tracks element width.
    struct SymCounter {
        inner: CountingSink,
        symbols: u64,
        width: u8,
    }
    impl OutputStream for SymCounter {
        fn write_byte(&mut self, b: u8) -> Result<()> {
            self.inner.write_byte(b)
        }
        fn write_run(&mut self, init: u64, len: u64, delta: i64, width: u8) -> Result<()> {
            self.width = width;
            self.inner.write_run(init, len, delta, width)
        }
        fn memcpy(&mut self, offset: u64, len: u64) -> Result<()> {
            self.inner.memcpy(offset, len)
        }
        fn write_slice(&mut self, bytes: &[u8]) -> Result<()> {
            self.inner.write_slice(bytes)
        }
        fn bytes_written(&self) -> u64 {
            self.inner.bytes_written()
        }
        fn on_symbol(&mut self, kind: SymbolKind, _ops: u32, _pos: u64) {
            if !matches!(
                kind,
                SymbolKind::DeflateHeader | SymbolKind::RleV2Header | SymbolKind::RleLiteralGroup
            ) {
                self.symbols += 1;
            }
        }
    }

    let mut c = SymCounter { inner: CountingSink::new(), symbols: 0, width: 1 };
    decode_into(kind, comp, &mut c)?;
    if c.symbols == 0 {
        return Ok(0.0);
    }
    let elems = c.inner.bytes_written() / c.width.max(1) as u64;
    Ok(elems as f64 / c.symbols as f64)
}

/// Read and validate the common RLE chunk header; returns
/// `(element_width, n_elems)`.
pub(crate) fn read_rle_header(input: &mut InputStream<'_>) -> Result<(u8, u64)> {
    let width = input.fetch_byte()?;
    if !VALID_WIDTHS.contains(&width) {
        return Err(corrupt(format!("bad RLE element width {width}")));
    }
    let _reserved = input.fetch_byte()?;
    let n = input.fetch_uvarint()?;
    Ok((width, n))
}

/// Write the common RLE chunk header.
pub(crate) fn write_rle_header(out: &mut Vec<u8>, width: u8, n_elems: u64) {
    out.push(width);
    out.push(0);
    crate::format::varint::write_uvarint(out, n_elems);
}

/// Split a chunk of bytes into `width`-byte little-endian elements.
pub(crate) fn bytes_to_elems(chunk: &[u8], width: u8) -> Result<Vec<u64>> {
    let w = width as usize;
    if chunk.len() % w != 0 {
        return Err(invalid(format!(
            "chunk length {} not divisible by element width {w}",
            chunk.len()
        )));
    }
    let mut v = Vec::with_capacity(chunk.len() / w);
    for e in chunk.chunks_exact(w) {
        let mut buf = [0u8; 8];
        buf[..w].copy_from_slice(e);
        v.push(u64::from_le_bytes(buf));
    }
    Ok(v)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_roundtrip() {
        for k in CodecKind::all() {
            assert_eq!(CodecKind::from_u32(k.0), Some(k));
            assert_eq!(CodecKind::parse(k.name()), Some(k));
        }
        assert_eq!(CodecKind::from_u32(99), None);
        assert_eq!(CodecKind::parse("nope"), None);
    }

    #[test]
    fn registry_order_names_and_wire_ids_pinned() {
        // The registry order IS the stats-slot and reporting order —
        // append-only. Wire ids are forever.
        let names: Vec<&str> = CodecRegistry::codecs().iter().map(|c| c.name()).collect();
        assert_eq!(names, ["rlev1", "rlev2", "deflate", "lzss"]);
        let ids: Vec<u32> = CodecRegistry::codecs().iter().map(|c| c.wire_id()).collect();
        assert_eq!(ids, [1, 2, 3, 4]);
        assert_eq!(CodecRegistry::names(), ["rlev1", "rlev2", "deflate", "lzss"]);
        for (slot, kind) in CodecKind::all().iter().enumerate() {
            assert_eq!(CodecRegistry::slot(*kind), Some(slot));
        }
        assert_eq!(CodecRegistry::slot(CodecKind(99)), None);
    }

    #[test]
    fn registry_lookup_by_name_and_alias() {
        for (name, kind) in [
            ("rlev1", CodecKind::RleV1),
            ("rle_v1", CodecKind::RleV1),
            ("rle1", CodecKind::RleV1),
            ("rlev2", CodecKind::RleV2),
            ("rle_v2", CodecKind::RleV2),
            ("rle2", CodecKind::RleV2),
            ("deflate", CodecKind::Deflate),
            ("zlib", CodecKind::Deflate),
            ("lzss", CodecKind::Lzss),
            ("lz", CodecKind::Lzss),
            ("LZSS", CodecKind::Lzss),
        ] {
            assert_eq!(CodecKind::parse(name), Some(kind), "{name}");
        }
        assert!(CodecRegistry::by_name("gzip").is_none());
    }

    #[test]
    fn unknown_codec_is_typed() {
        match CodecRegistry::by_kind(CodecKind(0x7F)) {
            Err(Error::UnknownCodec(0x7F)) => {}
            other => panic!("expected UnknownCodec, got {other:?}"),
        }
        assert!(compress_chunk(CodecKind(0x7F), b"abc").is_err());
        let mut sink = ByteSink::new();
        assert_eq!(
            decode_into(CodecKind(0x7F), b"abc", &mut sink),
            Err(Error::UnknownCodec(0x7F))
        );
    }

    #[test]
    fn elems_roundtrip() {
        let chunk: Vec<u8> = (0..32).collect();
        for w in VALID_WIDTHS {
            let elems = bytes_to_elems(&chunk, w).unwrap();
            assert_eq!(elems.len(), 32 / w as usize);
        }
        assert!(bytes_to_elems(&chunk[..3], 2).is_err());
    }

    #[test]
    fn auto_width_compress_roundtrips() {
        let mut data = Vec::new();
        for i in 0..1000u64 {
            data.extend_from_slice(&(i / 10).to_le_bytes());
        }
        for kind in [CodecKind::RleV1, CodecKind::RleV2] {
            let comp = compress_chunk(kind, &data).unwrap();
            let out = decompress_chunk(kind, &comp, data.len()).unwrap();
            assert_eq!(out, data, "{kind:?}");
            assert!(comp.len() < data.len() / 4, "{kind:?} ratio too poor");
        }
    }

    #[test]
    fn decode_to_runs_rejects_deflate() {
        assert!(decode_to_runs(CodecKind::Deflate, &[]).is_err());
        assert!(decode_to_runs(CodecKind::Lzss, &[]).is_err());
    }

    #[test]
    fn avg_symbol_len_long_runs_is_large() {
        // 4096 identical u64s -> runs cap at 130 elements, so the average
        // symbol covers ~128 elements.
        let mut data = Vec::new();
        for _ in 0..4096u64 {
            data.extend_from_slice(&42u64.to_le_bytes());
        }
        let comp = compress_chunk_with(CodecKind::RleV1, &data, 8).unwrap();
        let sym = avg_symbol_len(CodecKind::RleV1, &comp).unwrap();
        assert!(sym > 100.0, "long-run data should have long symbols: {sym}");
    }

    #[test]
    fn avg_symbol_len_literals_is_one() {
        // Alternating bytes: every symbol is a literal element.
        let data: Vec<u8> = (0..2000).map(|i| (i % 2) as u8).collect();
        let comp = compress_chunk_with(CodecKind::RleV1, &data, 1).unwrap();
        let sym = avg_symbol_len(CodecKind::RleV1, &comp).unwrap();
        assert!((sym - 1.0).abs() < 1e-9, "literal-only data: {sym}");
    }
}
