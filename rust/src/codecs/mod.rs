//! The three encoding techniques the paper evaluates, implemented from
//! scratch: ORC RLE v1, ORC RLE v2, and DEFLATE (§II-A, §V-A).
//!
//! Every decoder is written once against the CODAG
//! [`OutputStream`](crate::decomp::OutputStream) abstraction and is
//! reused unchanged by:
//!
//! * the plain CPU decompression path ([`decompress_chunk`]),
//! * the GPU-simulator tracing engines ([`crate::decomp::codag_engine`],
//!   [`crate::decomp::block_engine`]),
//! * the hybrid PJRT expand path (RLE codecs decoding to
//!   [`RunRecord`](crate::decomp::RunRecord)s).
//!
//! ## Chunk payload format
//!
//! RLE chunks carry a 2-byte header — `[element_width, reserved]` —
//! followed by `n_elems` as a uvarint and the RLE byte stream. DEFLATE
//! chunks are a raw RFC 1951 bit stream. (The paper uses ORC files and
//! zlib; we keep the same encodings but a minimal framing, documented in
//! DESIGN.md.)

pub mod deflate;
pub mod rle_v1;
pub mod rle_v2;

use crate::decomp::{ByteSink, InputStream, OutputStream, RunRecord, RunRecorder, SliceSink};
use crate::{corrupt, invalid, Result};

/// A point where decode of a chunk can restart mid-stream (container v2).
///
/// Recorded at pack time at codec-chosen sub-block boundaries: for the
/// RLE codecs a group/control-unit boundary (always byte-aligned, so
/// `bit_pos % 8 == 0`), for DEFLATE a block boundary at an arbitrary bit
/// position. `bit_pos` counts bits from the start of the compressed
/// chunk *including* the RLE chunk header; `out_off` is the uncompressed
/// byte offset the restarted decode produces first. The implicit first
/// boundary `(0, 0)` is never stored.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RestartPoint {
    /// Bit position in the compressed chunk where decode may resume.
    pub bit_pos: u64,
    /// Uncompressed byte offset produced by decode from `bit_pos`.
    pub out_off: u64,
}

/// Encoder-side restart recorder: encoders `offer` every decode boundary
/// they emit and the recorder keeps the first one at or past each
/// `interval`-byte threshold of uncompressed output. `interval == 0`
/// disables recording; boundaries at offset 0 or at the end of the chunk
/// are never stored (they are implicit).
pub(crate) struct RestartRec {
    interval: u64,
    next: u64,
    total: u64,
    width: u64,
    pub(crate) points: Vec<RestartPoint>,
}

impl RestartRec {
    pub(crate) fn new(interval: usize, total_out_bytes: u64, width: u8) -> Self {
        let interval = interval as u64;
        RestartRec {
            interval,
            next: interval,
            total: total_out_bytes,
            width: width as u64,
            points: Vec::new(),
        }
    }

    /// Offer a boundary: `elems_done` elements decode from the first
    /// `stream_bytes` bytes of the stream being built.
    pub(crate) fn offer(&mut self, stream_bytes: usize, elems_done: u64) {
        if self.interval == 0 {
            return;
        }
        let out_off = elems_done.saturating_mul(self.width);
        if out_off == 0 || out_off >= self.total {
            return;
        }
        if out_off >= self.next {
            self.points.push(RestartPoint { bit_pos: stream_bytes as u64 * 8, out_off });
            self.next = out_off.saturating_add(self.interval);
        }
    }
}

/// The codec used for a container's chunks.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum CodecKind {
    /// ORC run-length encoding v1 (byte RLE for width-1, integer RLE else).
    RleV1 = 1,
    /// ORC run-length encoding v2 (short-repeat / direct / patched-base /
    /// delta sub-encodings).
    RleV2 = 2,
    /// DEFLATE (RFC 1951): LZ77 + fixed/dynamic Huffman.
    Deflate = 3,
}

impl CodecKind {
    /// Parse the container-format discriminant.
    pub fn from_u32(v: u32) -> Option<CodecKind> {
        match v {
            1 => Some(CodecKind::RleV1),
            2 => Some(CodecKind::RleV2),
            3 => Some(CodecKind::Deflate),
            _ => None,
        }
    }

    /// Short lowercase name (CLI / reports).
    pub fn name(&self) -> &'static str {
        match self {
            CodecKind::RleV1 => "rlev1",
            CodecKind::RleV2 => "rlev2",
            CodecKind::Deflate => "deflate",
        }
    }

    /// Parse a CLI name.
    pub fn parse(s: &str) -> Option<CodecKind> {
        match s.to_ascii_lowercase().as_str() {
            "rlev1" | "rle1" | "rle_v1" => Some(CodecKind::RleV1),
            "rlev2" | "rle2" | "rle_v2" => Some(CodecKind::RleV2),
            "deflate" | "zlib" => Some(CodecKind::Deflate),
            _ => None,
        }
    }

    /// All codecs, in the paper's reporting order.
    pub fn all() -> [CodecKind; 3] {
        [CodecKind::RleV1, CodecKind::RleV2, CodecKind::Deflate]
    }

    /// True for the run-structured codecs eligible for the PJRT expand path.
    pub fn is_rle(&self) -> bool {
        matches!(self, CodecKind::RleV1 | CodecKind::RleV2)
    }
}

/// Valid element widths for the RLE codecs.
pub const VALID_WIDTHS: [u8; 4] = [1, 2, 4, 8];

/// Compress one chunk with an explicit RLE element width.
///
/// `width` must divide `chunk.len()` for RLE codecs; it is ignored for
/// DEFLATE.
pub fn compress_chunk_with(kind: CodecKind, chunk: &[u8], width: u8) -> Result<Vec<u8>> {
    match kind {
        CodecKind::RleV1 => rle_v1::compress(chunk, width),
        CodecKind::RleV2 => rle_v2::compress(chunk, width),
        CodecKind::Deflate => deflate::compress(chunk),
    }
}

/// Compress one chunk with an explicit RLE element width, recording
/// restart points roughly every `interval` uncompressed bytes (container
/// v2). `interval == 0` disables recording. For the RLE codecs restart
/// recording is passive — the compressed bytes are identical to
/// [`compress_chunk_with`]; DEFLATE closes a block at each boundary so
/// sub-blocks carry no cross-boundary back-references (the stream stays
/// a single valid RFC 1951 stream for serial decoders).
pub fn compress_chunk_with_restarts(
    kind: CodecKind,
    chunk: &[u8],
    width: u8,
    interval: usize,
) -> Result<(Vec<u8>, Vec<RestartPoint>)> {
    match kind {
        CodecKind::RleV1 => rle_v1::compress_with_restarts(chunk, width, interval),
        CodecKind::RleV2 => rle_v2::compress_with_restarts(chunk, width, interval),
        CodecKind::Deflate => deflate::compress_with_restarts(chunk, interval),
    }
}

/// Auto-width variant of [`compress_chunk_with_restarts`] — mirrors
/// [`compress_chunk`]'s width selection (widest of 8/4/2/1 dividing the
/// chunk with the strictly smallest output).
pub fn compress_chunk_restarts(
    kind: CodecKind,
    chunk: &[u8],
    interval: usize,
) -> Result<(Vec<u8>, Vec<RestartPoint>)> {
    if kind == CodecKind::Deflate {
        return deflate::compress_with_restarts(chunk, interval);
    }
    let mut best: Option<(Vec<u8>, Vec<RestartPoint>)> = None;
    for &w in VALID_WIDTHS.iter().rev() {
        if chunk.len() % w as usize != 0 {
            continue;
        }
        let c = compress_chunk_with_restarts(kind, chunk, w, interval)?;
        if best.as_ref().map_or(true, |b| c.0.len() < b.0.len()) {
            best = Some(c);
        }
    }
    best.ok_or_else(|| invalid("chunk length not divisible by any element width"))
}

/// Decode one sub-block of a chunk into a bounded disjoint slice (the
/// parallel stitch worker path, DESIGN.md §7.5).
///
/// `bit_pos == 0` means "start of the chunk" (for RLE codecs: right
/// after the chunk header); any other value must name a restart point
/// recorded at pack time. `terminal` marks the chunk's last sub-block
/// (DEFLATE verifies BFINAL falls exactly there). `out` must be exactly
/// the sub-block's uncompressed extent — the decode fills it completely
/// or returns `Corrupt`; it can never write outside it. Returns the bit
/// position where decode stopped, which stitching validates against the
/// next restart point.
pub fn decode_sub_block(
    kind: CodecKind,
    comp: &[u8],
    bit_pos: u64,
    terminal: bool,
    out: &mut [u8],
) -> Result<u64> {
    let expect = out.len() as u64;
    let mut sink = SliceSink::new(out);
    let end = match kind {
        CodecKind::Deflate => {
            deflate::inflate_sub_block(comp, bit_pos, expect, terminal, &mut sink)?
        }
        CodecKind::RleV1 | CodecKind::RleV2 => {
            let mut header = InputStream::new(comp);
            let (width, _n_total) = read_rle_header(&mut header)?;
            let header_len = header.bytes_consumed() as usize;
            let start = if bit_pos == 0 {
                header_len
            } else {
                if bit_pos % 8 != 0 {
                    return Err(corrupt("rle restart point is not byte-aligned"));
                }
                let b = (bit_pos / 8) as usize;
                if b < header_len || b > comp.len() {
                    return Err(corrupt(format!(
                        "rle restart point at byte {b} outside stream (header {header_len}, \
                         len {})",
                        comp.len()
                    )));
                }
                b
            };
            if expect % width as u64 != 0 {
                return Err(corrupt(format!(
                    "restart point splits a width-{width} element ({expect} bytes)"
                )));
            }
            let budget = expect / width as u64;
            let mut input = InputStream::new(&comp[start..]);
            match kind {
                CodecKind::RleV1 => rle_v1::decode_elems(&mut input, width, budget, &mut sink)?,
                _ => rle_v2::decode_elems(&mut input, width, budget, &mut sink)?,
            }
            (start as u64 + input.bytes_consumed()) * 8
        }
    };
    if sink.bytes_written() != expect {
        return Err(corrupt(format!(
            "sub-block produced {} bytes, expected {expect}",
            sink.bytes_written()
        )));
    }
    Ok(end)
}

/// Reject a chunk whose RLE header declares a different uncompressed
/// size than the container index expects.
///
/// Serial decode is driven by the header's element count; split decode
/// is driven by per-sub-block output budgets and never consults it.
/// Without this gate a corrupted count field would truncate (or fail)
/// serial decode while every bounded sub-block still decoded cleanly —
/// the divergence the stitch contract (DESIGN.md §7.5) forbids. No-op
/// for DEFLATE, whose length is implicit in the block structure.
pub fn check_chunk_header(kind: CodecKind, comp: &[u8], uncomp_len: u64) -> Result<()> {
    if !kind.is_rle() {
        return Ok(());
    }
    let mut header = InputStream::new(comp);
    let (width, n_total) = read_rle_header(&mut header)?;
    let declared = n_total.saturating_mul(width as u64);
    if declared != uncomp_len {
        return Err(corrupt(format!(
            "rle chunk header declares {declared} uncompressed bytes, index says {uncomp_len}"
        )));
    }
    Ok(())
}

/// Compress one chunk, auto-selecting the RLE element width (largest of
/// 8/4/2/1 that divides the chunk length and yields the smallest output —
/// mirrors how an ORC writer picks a column's physical type).
pub fn compress_chunk(kind: CodecKind, chunk: &[u8]) -> Result<Vec<u8>> {
    if kind == CodecKind::Deflate {
        return deflate::compress(chunk);
    }
    let mut best: Option<Vec<u8>> = None;
    for &w in VALID_WIDTHS.iter().rev() {
        if chunk.len() % w as usize != 0 {
            continue;
        }
        let c = compress_chunk_with(kind, chunk, w)?;
        if best.as_ref().map_or(true, |b| c.len() < b.len()) {
            best = Some(c);
        }
    }
    best.ok_or_else(|| invalid("chunk length not divisible by any element width"))
}

/// Decompress one chunk into a fresh buffer.
///
/// `size_hint` is the expected uncompressed size (from the container
/// index) used only for allocation; the decoded length is authoritative.
pub fn decompress_chunk(kind: CodecKind, comp: &[u8], size_hint: usize) -> Result<Vec<u8>> {
    let mut sink = ByteSink::with_capacity(size_hint);
    decode_into(kind, comp, &mut sink)?;
    Ok(sink.into_bytes())
}

/// Decode one chunk into any [`OutputStream`] — the single decode entry
/// point all engines share.
pub fn decode_into<O: OutputStream>(kind: CodecKind, comp: &[u8], out: &mut O) -> Result<()> {
    let mut input = InputStream::new(comp);
    match kind {
        CodecKind::RleV1 => rle_v1::decode(&mut input, out),
        CodecKind::RleV2 => rle_v2::decode(&mut input, out),
        CodecKind::Deflate => deflate::decode(&mut input, out),
    }
}

/// Decode an RLE chunk to run records (the PJRT expand path input).
/// Returns the records plus the element width.
pub fn decode_to_runs(kind: CodecKind, comp: &[u8]) -> Result<(Vec<RunRecord>, u8)> {
    if !kind.is_rle() {
        return Err(invalid(format!("{} does not decode to runs", kind.name())));
    }
    let mut rec = RunRecorder::new();
    decode_into(kind, comp, &mut rec)?;
    let width = if rec.width == 0 { 1 } else { rec.width };
    Ok((rec.runs, width))
}

/// Average compressed-symbol length (Table V's right columns): decoded
/// *elements* produced per compressed symbol, where a symbol is a run
/// header, a literal-group element, or a DEFLATE token. For byte-typed
/// data (TPC/TPT/HRG) this is bytes per symbol, matching the paper (e.g.
/// avg 1.00 for TPC under RLE v1 = no runs); for wider columns it is the
/// average run length in elements.
pub fn avg_symbol_len(kind: CodecKind, comp: &[u8]) -> Result<f64> {
    use crate::decomp::{CountingSink, SymbolKind};

    /// Wrapper that counts `on_symbol` calls and tracks element width.
    struct SymCounter {
        inner: CountingSink,
        symbols: u64,
        width: u8,
    }
    impl OutputStream for SymCounter {
        fn write_byte(&mut self, b: u8) -> Result<()> {
            self.inner.write_byte(b)
        }
        fn write_run(&mut self, init: u64, len: u64, delta: i64, width: u8) -> Result<()> {
            self.width = width;
            self.inner.write_run(init, len, delta, width)
        }
        fn memcpy(&mut self, offset: u64, len: u64) -> Result<()> {
            self.inner.memcpy(offset, len)
        }
        fn write_slice(&mut self, bytes: &[u8]) -> Result<()> {
            self.inner.write_slice(bytes)
        }
        fn bytes_written(&self) -> u64 {
            self.inner.bytes_written()
        }
        fn on_symbol(&mut self, kind: SymbolKind, _ops: u32, _pos: u64) {
            if !matches!(
                kind,
                SymbolKind::DeflateHeader | SymbolKind::RleV2Header | SymbolKind::RleLiteralGroup
            ) {
                self.symbols += 1;
            }
        }
    }

    let mut c = SymCounter { inner: CountingSink::new(), symbols: 0, width: 1 };
    decode_into(kind, comp, &mut c)?;
    if c.symbols == 0 {
        return Ok(0.0);
    }
    let elems = c.inner.bytes_written() / c.width.max(1) as u64;
    Ok(elems as f64 / c.symbols as f64)
}

/// Read and validate the common RLE chunk header; returns
/// `(element_width, n_elems)`.
pub(crate) fn read_rle_header(input: &mut InputStream<'_>) -> Result<(u8, u64)> {
    let width = input.fetch_byte()?;
    if !VALID_WIDTHS.contains(&width) {
        return Err(corrupt(format!("bad RLE element width {width}")));
    }
    let _reserved = input.fetch_byte()?;
    let n = input.fetch_uvarint()?;
    Ok((width, n))
}

/// Write the common RLE chunk header.
pub(crate) fn write_rle_header(out: &mut Vec<u8>, width: u8, n_elems: u64) {
    out.push(width);
    out.push(0);
    crate::format::varint::write_uvarint(out, n_elems);
}

/// Split a chunk of bytes into `width`-byte little-endian elements.
pub(crate) fn bytes_to_elems(chunk: &[u8], width: u8) -> Result<Vec<u64>> {
    let w = width as usize;
    if chunk.len() % w != 0 {
        return Err(invalid(format!(
            "chunk length {} not divisible by element width {w}",
            chunk.len()
        )));
    }
    let mut v = Vec::with_capacity(chunk.len() / w);
    for e in chunk.chunks_exact(w) {
        let mut buf = [0u8; 8];
        buf[..w].copy_from_slice(e);
        v.push(u64::from_le_bytes(buf));
    }
    Ok(v)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_roundtrip() {
        for k in CodecKind::all() {
            assert_eq!(CodecKind::from_u32(k as u32), Some(k));
            assert_eq!(CodecKind::parse(k.name()), Some(k));
        }
        assert_eq!(CodecKind::from_u32(99), None);
        assert_eq!(CodecKind::parse("nope"), None);
    }

    #[test]
    fn elems_roundtrip() {
        let chunk: Vec<u8> = (0..32).collect();
        for w in VALID_WIDTHS {
            let elems = bytes_to_elems(&chunk, w).unwrap();
            assert_eq!(elems.len(), 32 / w as usize);
        }
        assert!(bytes_to_elems(&chunk[..3], 2).is_err());
    }

    #[test]
    fn auto_width_compress_roundtrips() {
        let mut data = Vec::new();
        for i in 0..1000u64 {
            data.extend_from_slice(&(i / 10).to_le_bytes());
        }
        for kind in [CodecKind::RleV1, CodecKind::RleV2] {
            let comp = compress_chunk(kind, &data).unwrap();
            let out = decompress_chunk(kind, &comp, data.len()).unwrap();
            assert_eq!(out, data, "{kind:?}");
            assert!(comp.len() < data.len() / 4, "{kind:?} ratio too poor");
        }
    }

    #[test]
    fn decode_to_runs_rejects_deflate() {
        assert!(decode_to_runs(CodecKind::Deflate, &[]).is_err());
    }

    #[test]
    fn avg_symbol_len_long_runs_is_large() {
        // 4096 identical u64s -> runs cap at 130 elements, so the average
        // symbol covers ~128 elements.
        let mut data = Vec::new();
        for _ in 0..4096u64 {
            data.extend_from_slice(&42u64.to_le_bytes());
        }
        let comp = compress_chunk_with(CodecKind::RleV1, &data, 8).unwrap();
        let sym = avg_symbol_len(CodecKind::RleV1, &comp).unwrap();
        assert!(sym > 100.0, "long-run data should have long symbols: {sym}");
    }

    #[test]
    fn avg_symbol_len_literals_is_one() {
        // Alternating bytes: every symbol is a literal element.
        let data: Vec<u8> = (0..2000).map(|i| (i % 2) as u8).collect();
        let comp = compress_chunk_with(CodecKind::RleV1, &data, 1).unwrap();
        let sym = avg_symbol_len(CodecKind::RleV1, &comp).unwrap();
        assert!((sym - 1.0).abs() < 1e-9, "literal-only data: {sym}");
    }
}
