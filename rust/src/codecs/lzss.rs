//! LZSS byte-match compression (wire id 4).
//!
//! The GPULZ-style (arXiv 2304.07342) workload class the RLE codecs
//! lose on: text and binary data with multi-byte repeats but few literal
//! element runs. The decode loop maps directly onto the batched CODAG
//! sinks — literal runs are one `write_slice`, matches are one `memcpy`
//! resolved by the doubling `extend_from_within` path (DESIGN.md §7.2).
//!
//! ## Chunk payload format
//!
//! Header: `uvarint n` (uncompressed byte length), then `uvarint seg`
//! (segment size; `0` = one segment covering the whole chunk). The body
//! is a sequence of *segments*, each producing exactly
//! `min(seg, remaining)` bytes. A segment is a sequence of flag-grouped
//! tokens:
//!
//! * one **flag byte**, LSB-first: bit *i* describes token *i* of the
//!   group (`1` = match, `0` = literal run); a group holds up to 8
//!   tokens and a fresh group starts at every segment boundary;
//! * **literal run**: `uvarint len` (≥ 1) followed by `len` raw bytes;
//! * **match**: `uvarint len` (≥ [`MIN_MATCH`]) then `uvarint dist`
//!   (≥ 1); copies `len` bytes starting `dist` bytes back, `len > dist`
//!   wraps (overlapping run, Algorithm 2's special case).
//!
//! A group is cut short only by the end of its segment, and the unused
//! high flag bits must be zero (checked — they'd otherwise be dead bits
//! under the corruption sweeps). Matches never reference output before
//! their segment, so every segment boundary is a valid container-v2
//! restart point: the stitch worker decodes into a disjoint slice that
//! starts at the boundary ([`SliceSink`] cannot reach further back).

use crate::codecs::{Codec, RestartPoint, RestartRec};
use crate::decomp::{InputStream, OutputStream, SliceSink, SymbolKind};
use crate::format::varint::write_uvarint;
use crate::{corrupt, Result};

/// Minimum encodable match length (shorter repeats ship as literals).
pub const MIN_MATCH: usize = 4;

/// Hash-table bits for the encoder's 4-byte-prefix match finder.
const HASH_BITS: u32 = 15;

/// Sentinel for an empty match-finder slot.
const EMPTY: usize = usize::MAX;

/// The registry entry for LZSS (wire id 4).
pub struct LzssCodec;

impl Codec for LzssCodec {
    fn name(&self) -> &'static str {
        "lzss"
    }
    fn wire_id(&self) -> u32 {
        4
    }
    fn aliases(&self) -> &'static [&'static str] {
        &["lz"]
    }
    fn block_width(&self) -> u32 {
        128
    }
    fn compress(&self, chunk: &[u8], _width: u8) -> Result<Vec<u8>> {
        compress(chunk)
    }
    fn compress_with_restarts(
        &self,
        chunk: &[u8],
        _width: u8,
        interval: usize,
    ) -> Result<(Vec<u8>, Vec<RestartPoint>)> {
        compress_with_restarts(chunk, interval)
    }
    fn decompress_into(&self, comp: &[u8], out: &mut dyn OutputStream) -> Result<()> {
        let mut input = InputStream::new(comp);
        decode(&mut input, out)
    }
    fn decode_sub_block(
        &self,
        comp: &[u8],
        bit_pos: u64,
        _terminal: bool,
        out: &mut [u8],
    ) -> Result<u64> {
        let expect = out.len() as u64;
        let mut header = InputStream::new(comp);
        let (n, seg) = read_header(&mut header)?;
        let header_len = header.bytes_consumed() as usize;
        let start = if bit_pos == 0 {
            header_len
        } else {
            if bit_pos % 8 != 0 {
                return Err(corrupt("lzss restart point is not byte-aligned"));
            }
            let b = (bit_pos / 8) as usize;
            if b < header_len || b > comp.len() {
                return Err(corrupt(format!(
                    "lzss restart point at byte {b} outside stream (header {header_len}, \
                     len {})",
                    comp.len()
                )));
            }
            b
        };
        let seg_size = if seg == 0 { n } else { seg };
        let mut sink = SliceSink::new(out);
        let mut input = InputStream::new(&comp[start..]);
        decode_segments(&mut input, seg_size, expect, &mut sink)?;
        if sink.bytes_written() != expect {
            return Err(corrupt(format!(
                "sub-block produced {} bytes, expected {expect}",
                sink.bytes_written()
            )));
        }
        Ok((start as u64 + input.bytes_consumed()) * 8)
    }
    fn check_chunk_header(&self, comp: &[u8], uncomp_len: u64) -> Result<()> {
        let mut header = InputStream::new(comp);
        let (n, _seg) = read_header(&mut header)?;
        if n != uncomp_len {
            return Err(corrupt(format!(
                "lzss chunk header declares {n} uncompressed bytes, index says {uncomp_len}"
            )));
        }
        Ok(())
    }
}

/// Compress a chunk as a single segment.
pub fn compress(chunk: &[u8]) -> Result<Vec<u8>> {
    compress_with_restarts(chunk, 0).map(|(out, _)| out)
}

/// Compress cutting a segment every `interval` uncompressed bytes and
/// recording a container-v2 restart point at each boundary. Matches are
/// confined to their segment, so each recorded point starts an
/// independently decodable suffix (the stitch contract, DESIGN.md §7.5).
pub fn compress_with_restarts(
    chunk: &[u8],
    interval: usize,
) -> Result<(Vec<u8>, Vec<RestartPoint>)> {
    let n = chunk.len();
    let mut out = Vec::with_capacity(n / 2 + 16);
    write_uvarint(&mut out, n as u64);
    write_uvarint(&mut out, interval as u64);
    let mut rec = RestartRec::new(interval, n as u64, 1);
    let seg_size = if interval == 0 { n } else { interval };
    let mut head = vec![EMPTY; 1usize << HASH_BITS];
    let mut pos = 0usize;
    while pos < n {
        if pos > 0 {
            rec.offer(out.len(), pos as u64);
        }
        let end = (pos + seg_size).min(n);
        head.fill(EMPTY);
        encode_segment(&chunk[pos..end], &mut head, &mut out);
        pos = end;
    }
    Ok((out, rec.points))
}

/// Multiplicative hash of a 4-byte prefix (Knuth's 2654435761).
#[inline]
fn hash4(b: &[u8]) -> usize {
    let v = u32::from_le_bytes([b[0], b[1], b[2], b[3]]);
    (v.wrapping_mul(2654435761) >> (32 - HASH_BITS)) as usize
}

/// Flag-group accumulator: payloads buffer until the group's 8 tokens
/// (or the segment) complete, then the flag byte and payloads flush.
struct Group {
    flags: u8,
    n_tokens: u8,
    payload: Vec<u8>,
}

impl Group {
    fn new() -> Self {
        Group { flags: 0, n_tokens: 0, payload: Vec::new() }
    }

    fn push_literal(&mut self, bytes: &[u8], out: &mut Vec<u8>) {
        write_uvarint(&mut self.payload, bytes.len() as u64);
        self.payload.extend_from_slice(bytes);
        self.advance(out);
    }

    fn push_match(&mut self, len: usize, dist: usize, out: &mut Vec<u8>) {
        self.flags |= 1 << self.n_tokens;
        write_uvarint(&mut self.payload, len as u64);
        write_uvarint(&mut self.payload, dist as u64);
        self.advance(out);
    }

    fn advance(&mut self, out: &mut Vec<u8>) {
        self.n_tokens += 1;
        if self.n_tokens == 8 {
            self.flush(out);
        }
    }

    fn flush(&mut self, out: &mut Vec<u8>) {
        if self.n_tokens > 0 {
            out.push(self.flags);
            out.extend_from_slice(&self.payload);
            self.flags = 0;
            self.n_tokens = 0;
            self.payload.clear();
        }
    }
}

/// Greedy single-probe match finder over one segment. Deterministic —
/// the Python reference port (`gen_golden.py`) mirrors it exactly, and
/// the LZSS golden vectors are encoder-pinned.
fn encode_segment(data: &[u8], head: &mut [usize], out: &mut Vec<u8>) {
    let n = data.len();
    let mut grp = Group::new();
    let mut i = 0usize;
    let mut lit_start = 0usize;
    while i < n {
        let mut best_len = 0usize;
        let mut best_dist = 0usize;
        if i + MIN_MATCH <= n {
            let h = hash4(&data[i..]);
            let cand = head[h];
            if cand != EMPTY {
                let mut l = 0usize;
                while i + l < n && data[cand + l] == data[i + l] {
                    l += 1;
                }
                if l >= MIN_MATCH {
                    best_len = l;
                    best_dist = i - cand;
                }
            }
            head[h] = i;
        }
        if best_len > 0 {
            if lit_start < i {
                grp.push_literal(&data[lit_start..i], out);
            }
            grp.push_match(best_len, best_dist, out);
            let end = i + best_len;
            i += 1;
            while i < end && i + MIN_MATCH <= n {
                head[hash4(&data[i..])] = i;
                i += 1;
            }
            i = end;
            lit_start = i;
        } else {
            i += 1;
        }
    }
    if lit_start < n {
        grp.push_literal(&data[lit_start..n], out);
    }
    grp.flush(out);
}

/// Read and validate the chunk header; returns `(n, segment_size)`.
pub(crate) fn read_header(input: &mut InputStream<'_>) -> Result<(u64, u64)> {
    let n = input.fetch_uvarint()?;
    let seg = input.fetch_uvarint()?;
    Ok((n, seg))
}

/// Decode an LZSS chunk into `out`.
pub fn decode<O: OutputStream + ?Sized>(input: &mut InputStream<'_>, out: &mut O) -> Result<()> {
    let (n, seg) = read_header(input)?;
    let seg_size = if seg == 0 { n } else { seg };
    decode_segments(input, seg_size, n, out)
}

/// Decode `expect` bytes as a sequence of whole segments starting at the
/// cursor — shared by serial decode (`expect = n`) and the sub-block
/// restart path (cursor at a segment boundary, `expect` = the sub-block
/// extent).
fn decode_segments<O: OutputStream + ?Sized>(
    input: &mut InputStream<'_>,
    seg_size: u64,
    expect: u64,
    out: &mut O,
) -> Result<()> {
    let mut produced = 0u64;
    while produced < expect {
        let target = (expect - produced).min(seg_size);
        decode_one_segment(input, target, out)?;
        produced += target;
    }
    Ok(())
}

/// Decode exactly `target` bytes of one segment. Match distances are
/// validated against the bytes produced *within the segment*, keeping
/// serial decode (which could legally reach further back in a
/// materializing sink) byte-identical to the bounded sub-block path.
fn decode_one_segment<O: OutputStream + ?Sized>(
    input: &mut InputStream<'_>,
    target: u64,
    out: &mut O,
) -> Result<()> {
    let mut sp = 0u64;
    while sp < target {
        let flags = input.fetch_byte()?;
        let mut bit = 0u32;
        while bit < 8 {
            if sp == target {
                if flags >> bit != 0 {
                    return Err(corrupt("lzss: flag bits set past segment end"));
                }
                break;
            }
            if (flags >> bit) & 1 == 1 {
                let len = input.fetch_uvarint()?;
                let dist = input.fetch_uvarint()?;
                if len < MIN_MATCH as u64 {
                    return Err(corrupt(format!("lzss: match of {len} below minimum")));
                }
                if dist == 0 || dist > sp {
                    return Err(corrupt(format!(
                        "lzss: match distance {dist} outside segment ({sp} produced)"
                    )));
                }
                if len > target - sp {
                    return Err(corrupt("lzss: match overruns segment"));
                }
                out.on_symbol(SymbolKind::LzMatch, 160, input.bytes_consumed());
                out.memcpy(dist, len)?;
                sp += len;
            } else {
                let len = input.fetch_uvarint()?;
                if len == 0 {
                    return Err(corrupt("lzss: empty literal run"));
                }
                if len > target - sp {
                    return Err(corrupt("lzss: literal run overruns segment"));
                }
                let bytes = input.fetch_bytes(len as usize)?;
                out.on_symbol(
                    SymbolKind::LzLiteralRun,
                    20 + 3 * len as u32,
                    input.bytes_consumed(),
                );
                out.write_slice(bytes)?;
                sp += len;
            }
            bit += 1;
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codecs::{
        compress_chunk_with_restarts, decode_sub_block, decompress_chunk, CodecKind,
    };

    fn roundtrip(data: &[u8]) -> usize {
        let comp = compress(data).unwrap();
        let out = decompress_chunk(CodecKind::Lzss, &comp, data.len()).unwrap();
        assert_eq!(out, data);
        comp.len()
    }

    fn lcg_bytes(seed: u64, n: usize) -> Vec<u8> {
        let mut x = seed;
        (0..n)
            .map(|_| {
                x = x.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
                (x >> 56) as u8
            })
            .collect()
    }

    #[test]
    fn empty_and_tiny_inputs() {
        roundtrip(&[]);
        for s in ["a", "ab", "abc", "abcd", "aaaa", "hello world"] {
            roundtrip(s.as_bytes());
        }
    }

    #[test]
    fn repeated_text_compresses() {
        let data = "the quick brown fox jumps over the lazy dog. ".repeat(200);
        let clen = roundtrip(data.as_bytes());
        assert!(clen < data.len() / 5, "clen={clen} of {}", data.len());
    }

    #[test]
    fn overlapping_match_run() {
        // 100k identical bytes: one literal + wrapping matches.
        let data = vec![0x41u8; 100_000];
        let clen = roundtrip(&data);
        assert!(clen < 100, "clen={clen}");
    }

    #[test]
    fn incompressible_data_bounded_expansion() {
        let data = lcg_bytes(77, 10_000);
        let clen = roundtrip(&data);
        // Literal runs cost a flag bit + a uvarint per run.
        assert!(clen <= data.len() + 64, "clen={clen}");
    }

    #[test]
    fn segmented_stream_decodes_identically() {
        let data = "abcabcabc-segment-crossing-material-".repeat(300);
        let plain = compress(data.as_bytes()).unwrap();
        for interval in [64usize, 256, 1024, 16 * 1024] {
            let (seg, points) =
                compress_with_restarts(data.as_bytes(), interval).unwrap();
            let out = decompress_chunk(CodecKind::Lzss, &seg, data.len()).unwrap();
            assert_eq!(out.as_slice(), data.as_bytes(), "interval {interval}");
            if interval < data.len() {
                assert!(!points.is_empty(), "interval {interval} recorded no points");
            }
            for p in &points {
                assert_eq!(p.bit_pos % 8, 0);
                assert_eq!(p.out_off % interval as u64, 0);
            }
            // Segment isolation costs ratio but never correctness.
            assert!(seg.len() >= plain.len());
        }
    }

    #[test]
    fn sub_blocks_stitch_to_serial_output() {
        let data = "stitchable stitchable stitchable data ".repeat(400);
        let (comp, points) =
            compress_chunk_with_restarts(CodecKind::Lzss, data.as_bytes(), 1, 2048).unwrap();
        assert!(!points.is_empty());
        let mut out = vec![0u8; data.len()];
        let mut bounds = vec![(0u64, 0u64)];
        bounds.extend(points.iter().map(|p| (p.bit_pos, p.out_off)));
        for (i, &(bit_pos, out_off)) in bounds.iter().enumerate() {
            let end_off =
                bounds.get(i + 1).map_or(data.len() as u64, |&(_, o)| o);
            let terminal = i + 1 == bounds.len();
            let end_bit = decode_sub_block(
                CodecKind::Lzss,
                &comp,
                bit_pos,
                terminal,
                &mut out[out_off as usize..end_off as usize],
            )
            .unwrap();
            let next_bit =
                bounds.get(i + 1).map_or(comp.len() as u64 * 8, |&(b, _)| b);
            assert_eq!(end_bit, next_bit, "sub-block {i} end bit");
        }
        assert_eq!(out.as_slice(), data.as_bytes());
    }

    #[test]
    fn truncations_and_doctored_streams_are_corrupt() {
        let data = "truncate me truncate me truncate me".repeat(40);
        let comp = compress(data.as_bytes()).unwrap();
        for cut in [1usize, 2, comp.len() / 2, comp.len() - 1] {
            assert!(
                decompress_chunk(CodecKind::Lzss, &comp[..cut], data.len()).is_err(),
                "cut at {cut}"
            );
        }
        // A match with distance 0 is never emitted and always rejected.
        let mut bad = Vec::new();
        write_uvarint(&mut bad, 8);
        write_uvarint(&mut bad, 0);
        bad.push(0b0000_0010); // literal run then match
        write_uvarint(&mut bad, 4);
        bad.extend_from_slice(b"abcd");
        write_uvarint(&mut bad, 4); // match len
        write_uvarint(&mut bad, 0); // dist 0
        assert!(decompress_chunk(CodecKind::Lzss, &bad, 8).is_err());
        // Flag bits set past the end of the chunk are rejected.
        let mut tail = Vec::new();
        write_uvarint(&mut tail, 3);
        write_uvarint(&mut tail, 0);
        tail.push(0b0000_0010); // token 0 literal, token 1 claims a match
        write_uvarint(&mut tail, 3);
        tail.extend_from_slice(b"xyz");
        assert!(decompress_chunk(CodecKind::Lzss, &tail, 3).is_err());
    }

    #[test]
    fn header_length_cross_check() {
        let data = b"check the header declared length".repeat(8);
        let comp = compress(&data).unwrap();
        assert!(LzssCodec.check_chunk_header(&comp, data.len() as u64).is_ok());
        assert!(LzssCodec.check_chunk_header(&comp, data.len() as u64 + 1).is_err());
    }

    #[test]
    fn batched_sinks_match_scalar_oracle() {
        use crate::decomp::{ByteSink, ScalarSink};
        let mut data = lcg_bytes(3, 2000);
        data.extend_from_slice(&data.clone()[..1500]);
        data.extend(vec![7u8; 500]);
        let comp = compress(&data).unwrap();
        let mut batched = ByteSink::new();
        crate::codecs::decode_into(CodecKind::Lzss, &comp, &mut batched).unwrap();
        let mut scalar = ScalarSink::new();
        crate::codecs::decode_into(CodecKind::Lzss, &comp, &mut scalar).unwrap();
        assert_eq!(batched.out, data);
        assert_eq!(batched.out, scalar.out);
    }
}
