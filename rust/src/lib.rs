//! # CODAG — Characterizing and Optimizing Decompression Algorithms for GPUs
//!
//! A full reproduction of the CODAG paper (Park et al., 2023) as a
//! three-layer Rust + JAX + Pallas system:
//!
//! * **L3 (this crate)** — the decompression framework itself: codecs
//!   (ORC RLE v1 / RLE v2 / DEFLATE, all from scratch), the CODAG
//!   `input_stream` / `output_stream` abstractions (paper Tables I & II),
//!   warp-level and block-level (RAPIDS-style baseline) decompression
//!   engines, a trace-driven GPU timing simulator standing in for the
//!   A100/V100 testbed, a chunk coordinator (router + dynamic batcher +
//!   worker pool), a long-lived TCP serving daemon (`server`: wire
//!   protocol, per-dataset shard queues, decompressed-chunk LRU cache,
//!   `Busy` backpressure), dataset generators for the paper's seven
//!   evaluation datasets, and the benchmark harness regenerating every
//!   table and figure.
//! * **L2 (python/compile/model.py)** — the parallel *expand* phase of
//!   decompression (batched `write_run` + delta reconstruction) as a JAX
//!   graph, AOT-lowered to HLO text at build time.
//! * **L1 (python/compile/kernels/)** — Pallas kernels for run expansion
//!   and delta decoding, validated against pure-jnp oracles.
//!
//! Python never runs at request time: the [`runtime`] module loads the
//! AOT artifacts through the `xla` crate's PJRT CPU client and the
//! [`coordinator`] serves decompression requests from Rust only.
//!
//! ## Quick start
//!
//! (`no_run`: doctest binaries run outside the cargo rpath config that
//! locates libxla_extension's bundled libstdc++.)
//!
//! ```no_run
//! use codag::codecs::CodecKind;
//! use codag::format::container::Container;
//!
//! let data = b"aaaaabbbbbcccccaaaaabbbbb".to_vec();
//! let container = Container::compress(&data, CodecKind::Deflate, 128 * 1024).unwrap();
//! let out = container.decompress_all().unwrap();
//! assert_eq!(out, data);
//! ```

pub mod bench_harness;
pub mod codecs;
pub mod coordinator;
pub mod data;
pub mod decomp;
pub mod format;
pub mod gpu_sim;
pub mod obs;
pub mod runtime;
pub mod server;

/// Crate-wide result type (string errors keep the dependency set small and
/// the hot paths monomorphic; richer errors live at module boundaries).
pub type Result<T> = std::result::Result<T, Error>;

/// Crate-wide error type.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Error {
    /// Compressed stream is malformed (truncated, bad header, invalid code).
    Corrupt(String),
    /// Caller passed inconsistent arguments (bad chunk size, bucket, ...).
    Invalid(String),
    /// Underlying I/O failure.
    Io(String),
    /// PJRT / XLA runtime failure.
    Runtime(String),
    /// A container or request named a codec wire id the registry does
    /// not know (carries the offending id).
    UnknownCodec(u32),
    /// Decoded bytes do not match the content checksum recorded at pack
    /// time — the stream parsed, but the payload is provably corrupt.
    ChecksumMismatch(String),
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Error::Corrupt(m) => write!(f, "corrupt stream: {m}"),
            Error::Invalid(m) => write!(f, "invalid argument: {m}"),
            Error::Io(m) => write!(f, "io error: {m}"),
            Error::Runtime(m) => write!(f, "runtime error: {m}"),
            Error::UnknownCodec(id) => write!(f, "unknown codec wire id {id}"),
            Error::ChecksumMismatch(m) => write!(f, "checksum mismatch: {m}"),
        }
    }
}

impl std::error::Error for Error {}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e.to_string())
    }
}

/// Shorthand constructor for [`Error::Corrupt`].
pub fn corrupt(msg: impl Into<String>) -> Error {
    Error::Corrupt(msg.into())
}

/// Shorthand constructor for [`Error::Invalid`].
pub fn invalid(msg: impl Into<String>) -> Error {
    Error::Invalid(msg.into())
}
