//! The CODAG decompression framework core (paper §IV).
//!
//! CODAG's central abstraction is the pair of stream objects every codec
//! is written against:
//!
//! * [`input_stream`] — Table I: `fetch_bits` / `peek_bits` over the
//!   compressed chunk, with coalesced cache-line refill accounting (the
//!   shared-memory input buffer of Algorithm 1).
//! * [`output_stream`] — Table II: `write_byte`, `write_run(init, len,
//!   delta)`, and `memcpy(offset, len)` writing primitives, implemented
//!   by materializing sinks, tracing sinks (for the GPU simulator), and
//!   run-recording sinks (for the PJRT expand path).
//!
//! On top of the streams sit the two **engines** that reproduce the
//! paper's comparison:
//!
//! * [`codag_engine`] — warp-level decompression: one warp per chunk,
//!   all-thread decoding, warp-scope barriers only around coalesced
//!   on-demand reads/writes (Fig 1b).
//! * [`block_engine`] — the RAPIDS-style baseline: one thread block per
//!   chunk, a single leader decode thread, per-symbol broadcast + block
//!   barrier, and a dedicated prefetch warp (Fig 1a).
//!
//! Both engines run the *same* codec decode logic; they differ only in
//! how the decode/read/write activity is provisioned onto simulated GPU
//! resources — which is exactly the paper's claim about where the
//! performance difference comes from.

pub mod block_engine;
pub mod codag_engine;
pub mod input_stream;
pub mod output_stream;
pub mod trace;

pub use input_stream::InputStream;
pub use output_stream::{
    ByteSink, CountingSink, OutputStream, RunRecord, RunRecorder, ScalarSink, SliceSink,
    SymbolKind, TracingSink,
};
pub use trace::{BarrierScope, UnitEvent, UnitTrace};
