//! `output_stream` — the paper's Table II writing abstraction.
//!
//! Decoders are written once against the [`OutputStream`] trait and run
//! unchanged against:
//!
//! * [`ByteSink`] — materializes decompressed bytes (the correctness /
//!   CPU-throughput path).
//! * [`RunRecorder`] — records `write_run` calls as [`RunRecord`]s instead
//!   of expanding them, producing the fixed-shape input of the AOT
//!   JAX/Pallas expand kernel (the L2/L1 half of the hybrid path).
//! * [`TracingSink`] — wraps another sink and emits [`UnitEvent`]s for the
//!   GPU timing simulator: coalesced writes, barriers, broadcasts, decode
//!   bursts, and cache-line input refills.
//! * [`CountingSink`] — counts output bytes only (ratio measurements).
//!
//! The three primitives match Table II exactly: `write_byte` (single
//! literal), `write_run(init, len, delta)` (RLE/delta expansion — delta 0
//! is a plain run), and `memcpy(offset, len)` (dictionary copy, offset
//! counted back from the current end of output, as in DEFLATE).
//!
//! On top of the scalar primitives sits the **batched** op `write_slice`
//! (default-implemented in terms of `write_byte`): decoders batch
//! consecutive literals into one slice call so materializing sinks take
//! one `extend_from_slice` instead of a per-byte push, and `ByteSink`'s
//! `memcpy` resolves overlapping windows with chunked
//! `extend_from_within` copies that double the resolved region per
//! iteration (DESIGN.md §7). [`ScalarSink`] keeps the original
//! byte-at-a-time semantics as the differential-test oracle
//! (`rust/tests/prop_batched.rs`).

use crate::decomp::trace::{BarrierScope, UnitEvent};
use crate::{corrupt, Result};

/// Classification of a decoded symbol, used by instrumentation to model
/// per-symbol decode cost and the baseline's broadcast granularity.
///
/// *Descriptor* kinds (`RleRun`, `RleLiteralGroup`, `RleV2Header`,
/// `DeflateHeader`) mark points where the baseline's leader thread has
/// decoded a self-contained work item and broadcasts it to the block
/// (RAPIDS broadcasts per descriptor, and per 32-symbol batch for
/// DEFLATE — not per element).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SymbolKind {
    /// RLE v1/v2 run header (control byte + varints) — descriptor.
    RleRun,
    /// RLE v1 literal-group control byte — descriptor.
    RleLiteralGroup,
    /// One literal element within a group.
    RleLiteral,
    /// RLE v2 sub-encoding header — descriptor.
    RleV2Header,
    /// DEFLATE literal symbol (one Huffman decode).
    DeflateLiteral,
    /// DEFLATE length/distance match (two Huffman decodes + extra bits).
    DeflateMatch,
    /// DEFLATE block header (incl. dynamic Huffman table build) — descriptor.
    DeflateHeader,
    /// LZSS literal run (one flag bit + uvarint + raw bytes).
    LzLiteralRun,
    /// LZSS (len, dist) match token.
    LzMatch,
}

impl SymbolKind {
    /// True if the baseline broadcasts after decoding this symbol.
    pub fn is_descriptor(&self) -> bool {
        matches!(
            self,
            SymbolKind::RleRun
                | SymbolKind::RleLiteralGroup
                | SymbolKind::RleV2Header
                | SymbolKind::DeflateHeader
        )
    }

    /// True for DEFLATE body symbols, which the baseline batches 32 at a
    /// time through its shared-memory symbol queue before syncing.
    pub fn is_deflate_body(&self) -> bool {
        matches!(self, SymbolKind::DeflateLiteral | SymbolKind::DeflateMatch)
    }
}

/// The Table II writing abstraction plus instrumentation hooks.
///
/// `init`/`delta` are element *bit patterns* as u64; `width` is the
/// element width in bytes (1/2/4/8). Deltas wrap in the element's width
/// (matching ORC's integer overflow semantics).
pub trait OutputStream {
    /// Write one literal byte (Table II `write_byte`).
    fn write_byte(&mut self, b: u8) -> Result<()>;

    /// Write `len` elements of `width` bytes: `init, init+delta, ...`
    /// (Table II `write_run`).
    fn write_run(&mut self, init: u64, len: u64, delta: i64, width: u8) -> Result<()>;

    /// Copy `len` bytes starting `offset` bytes back from the current end
    /// of the output (Table II `memcpy`; `len > offset` wraps the window,
    /// the special case of Algorithm 2).
    fn memcpy(&mut self, offset: u64, len: u64) -> Result<()>;

    /// Batched literal write: semantically identical to calling
    /// [`write_byte`](OutputStream::write_byte) once per byte of
    /// `bytes`, in order. Decoders use this to flush runs of
    /// consecutive literals (DEFLATE literal bursts, stored blocks, RLE
    /// byte literal groups) in one call; sinks override it with a bulk
    /// implementation. The default is the scalar loop, so existing
    /// `OutputStream` implementors stay correct unchanged.
    fn write_slice(&mut self, bytes: &[u8]) -> Result<()> {
        for &b in bytes {
            self.write_byte(b)?;
        }
        Ok(())
    }

    /// Batched multi-byte-element write (DESIGN.md §7.4): semantically
    /// identical to calling `write_run(e, 1, 0, width)` once per
    /// element of `elems`, in order. The RLE decoders stage a whole
    /// bulk-unpacked group here so materializing sinks serialize the
    /// fixed-width little-endian elements in one pass instead of a
    /// `write_run` round-trip per element. The default is exactly the
    /// per-element loop, so the run-record path ([`RunRecorder`]) and
    /// the scalar oracle ([`ScalarSink`]) observe element-width-faithful
    /// unit runs with no override at all.
    fn write_elems(&mut self, elems: &[u64], width: u8) -> Result<()> {
        for &e in elems {
            self.write_run(e, 1, 0, width)?;
        }
        Ok(())
    }

    /// Bytes written so far.
    fn bytes_written(&self) -> u64;

    /// Instrumentation: one decoded symbol costing ~`ops` scalar
    /// instructions, with the decoder now `input_pos` bytes into the
    /// compressed stream. No-op unless tracing.
    #[inline]
    fn on_symbol(&mut self, _kind: SymbolKind, _ops: u32, _input_pos: u64) {}
}

/// Stack staging buffer for batched run/element serialization: 64
/// 8-byte elements per flush (one cache-line-friendly burst).
const RUN_STAGE_BYTES: usize = 512;

/// Expansion of a `write_run` into bytes, shared by sinks.
///
/// Hot path of the CPU decode (DESIGN.md §7.4): unit runs (literal
/// elements) take the early exit; **plain runs** (`delta == 0`) write
/// the element pattern once and then double it with
/// `extend_from_within` memcpys (`w, 2w, 4w, …` bytes per pass) instead
/// of looping per element; **delta runs** serialize elements into a
/// stack staging buffer and flush it in [`RUN_STAGE_BYTES`] blocks, so
/// the `Vec` bookkeeping is paid per block, not per element.
#[inline]
fn expand_run_into(out: &mut Vec<u8>, init: u64, len: u64, delta: i64, width: u8) {
    let w = width as usize;
    if len == 1 {
        let le = init.to_le_bytes();
        out.extend_from_slice(&le[..w]);
        return;
    }
    let total = len as usize * w;
    out.reserve(total);
    if delta == 0 {
        // Pattern-doubling memcpy: the copied region is itself the
        // source of the next copy, so the materialized prefix doubles
        // per pass (same shape as the §7.2 overlapping-memcpy resolve).
        let start = out.len();
        out.extend_from_slice(&init.to_le_bytes()[..w]);
        let mut have = w;
        while have < total {
            let take = (total - have).min(have);
            out.extend_from_within(start..start + take);
            have += take;
        }
        return;
    }
    // 8 bytes of slack so every element is one full-width 8-byte store
    // (narrow widths overlap into the next slot; the tail overlaps the
    // slack, never the flushed region).
    let mut stage = [0u8; RUN_STAGE_BYTES + 8];
    let per_block = RUN_STAGE_BYTES / w;
    let mut v = init;
    let d = delta as u64;
    let mut remaining = len as usize;
    while remaining > 0 {
        let m = remaining.min(per_block);
        let mut off = 0usize;
        for _ in 0..m {
            stage[off..off + 8].copy_from_slice(&v.to_le_bytes());
            off += w;
            v = v.wrapping_add(d);
        }
        out.extend_from_slice(&stage[..m * w]);
        remaining -= m;
    }
}

/// Serialize `elems` as `width`-byte little-endian values into `out` —
/// the native [`OutputStream::write_elems`] implementation shared by
/// the materializing sinks: one staging pass of overlapping 8-byte
/// stores per [`RUN_STAGE_BYTES`] block, byte-identical to the
/// per-element `write_run(e, 1, 0, width)` loop.
#[inline]
fn serialize_elems_into(out: &mut Vec<u8>, elems: &[u64], width: u8) {
    let w = width as usize;
    out.reserve(elems.len() * w);
    if w == 8 {
        for e in elems {
            out.extend_from_slice(&e.to_le_bytes());
        }
        return;
    }
    let mut stage = [0u8; RUN_STAGE_BYTES + 8];
    let per_block = RUN_STAGE_BYTES / w;
    for block in elems.chunks(per_block) {
        let mut off = 0usize;
        for e in block {
            stage[off..off + 8].copy_from_slice(&e.to_le_bytes());
            off += w;
        }
        out.extend_from_slice(&stage[..block.len() * w]);
    }
}

/// Materializing sink: collects decompressed bytes in memory.
#[derive(Debug, Default, Clone)]
pub struct ByteSink {
    /// The decompressed output.
    pub out: Vec<u8>,
}

impl ByteSink {
    /// New empty sink.
    pub fn new() -> Self {
        Self::default()
    }

    /// New sink with reserved capacity.
    pub fn with_capacity(cap: usize) -> Self {
        ByteSink { out: Vec::with_capacity(cap) }
    }

    /// Consume the sink, returning the bytes.
    pub fn into_bytes(self) -> Vec<u8> {
        self.out
    }
}

impl OutputStream for ByteSink {
    #[inline]
    fn write_byte(&mut self, b: u8) -> Result<()> {
        self.out.push(b);
        Ok(())
    }

    #[inline]
    fn write_run(&mut self, init: u64, len: u64, delta: i64, width: u8) -> Result<()> {
        expand_run_into(&mut self.out, init, len, delta, width);
        Ok(())
    }

    fn memcpy(&mut self, offset: u64, len: u64) -> Result<()> {
        let off = offset as usize;
        let n = len as usize;
        if off == 0 || off > self.out.len() {
            return Err(corrupt(format!(
                "memcpy offset {off} out of window (output len {})",
                self.out.len()
            )));
        }
        // Overlapping copy semantics: bytes written by this memcpy are
        // themselves part of the source window (`len > offset` repeats
        // the window periodically). Resolve with chunked
        // `extend_from_within` copies from a fixed source start: each
        // pass copies the whole resolved region, so the resolvable
        // prefix doubles per iteration instead of advancing one byte at
        // a time (the scalar loop `ScalarSink` keeps as the oracle).
        let start = self.out.len() - off;
        self.out.reserve(n);
        let mut remaining = n;
        while remaining > 0 {
            let take = remaining.min(self.out.len() - start);
            self.out.extend_from_within(start..start + take);
            remaining -= take;
        }
        Ok(())
    }

    #[inline]
    fn write_slice(&mut self, bytes: &[u8]) -> Result<()> {
        self.out.extend_from_slice(bytes);
        Ok(())
    }

    #[inline]
    fn write_elems(&mut self, elems: &[u64], width: u8) -> Result<()> {
        serialize_elems_into(&mut self.out, elems, width);
        Ok(())
    }

    #[inline]
    fn bytes_written(&self) -> u64 {
        self.out.len() as u64
    }
}

/// Byte-at-a-time reference sink: the pre-batching [`ByteSink`]
/// semantics kept verbatim as a differential-test oracle. `write_slice`
/// loops `write_byte` and `memcpy` copies one byte per iteration, so
/// any divergence between this sink and the vectorized [`ByteSink`] on
/// the same decode is a bug in the batched paths
/// (`rust/tests/prop_batched.rs` runs the comparison over the golden
/// corruption registry).
#[derive(Debug, Default, Clone)]
pub struct ScalarSink {
    /// The decompressed output.
    pub out: Vec<u8>,
}

impl ScalarSink {
    /// New empty sink.
    pub fn new() -> Self {
        Self::default()
    }

    /// Consume the sink, returning the bytes.
    pub fn into_bytes(self) -> Vec<u8> {
        self.out
    }
}

impl OutputStream for ScalarSink {
    #[inline]
    fn write_byte(&mut self, b: u8) -> Result<()> {
        self.out.push(b);
        Ok(())
    }

    fn write_run(&mut self, init: u64, len: u64, delta: i64, width: u8) -> Result<()> {
        // Per-element scalar expansion (no per-width monomorphic loops).
        let w = width as usize;
        let mut v = init;
        for _ in 0..len {
            self.out.extend_from_slice(&v.to_le_bytes()[..w]);
            v = v.wrapping_add(delta as u64);
        }
        Ok(())
    }

    fn memcpy(&mut self, offset: u64, len: u64) -> Result<()> {
        let off = offset as usize;
        let n = len as usize;
        if off == 0 || off > self.out.len() {
            return Err(corrupt(format!(
                "memcpy offset {off} out of window (output len {})",
                self.out.len()
            )));
        }
        let start = self.out.len() - off;
        for i in 0..n {
            let b = self.out[start + i];
            self.out.push(b);
        }
        Ok(())
    }

    // No write_slice/write_elems overrides: the trait defaults
    // (write_byte loop; per-element unit write_run loop) *are* the
    // scalar semantics under test.

    #[inline]
    fn bytes_written(&self) -> u64 {
        self.out.len() as u64
    }
}

/// Counting sink: discards data, tracks only the output length.
/// Still enforces `memcpy` window validity so corrupt streams fail.
#[derive(Debug, Default, Clone)]
pub struct CountingSink {
    len: u64,
}

impl CountingSink {
    /// New zeroed counter.
    pub fn new() -> Self {
        Self::default()
    }
}

impl OutputStream for CountingSink {
    #[inline]
    fn write_byte(&mut self, _b: u8) -> Result<()> {
        self.len += 1;
        Ok(())
    }

    #[inline]
    fn write_run(&mut self, _init: u64, len: u64, _delta: i64, width: u8) -> Result<()> {
        self.len += len * width as u64;
        Ok(())
    }

    #[inline]
    fn memcpy(&mut self, offset: u64, len: u64) -> Result<()> {
        if offset == 0 || offset > self.len {
            return Err(corrupt("memcpy offset out of window"));
        }
        self.len += len;
        Ok(())
    }

    #[inline]
    fn write_slice(&mut self, bytes: &[u8]) -> Result<()> {
        self.len += bytes.len() as u64;
        Ok(())
    }

    #[inline]
    fn write_elems(&mut self, elems: &[u64], width: u8) -> Result<()> {
        self.len += elems.len() as u64 * width as u64;
        Ok(())
    }

    #[inline]
    fn bytes_written(&self) -> u64 {
        self.len
    }
}

/// A recorded `write_run` call: the fixed-shape unit the AOT JAX/Pallas
/// expand kernel consumes (L2's `values/starts/deltas` arrays).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RunRecord {
    /// First element bit pattern.
    pub init: u64,
    /// Number of elements.
    pub len: u64,
    /// Per-element increment (0 for plain runs).
    pub delta: i64,
}

/// Records runs instead of expanding them (RLE hybrid path).
///
/// `write_byte`/`memcpy` are rejected: the PJRT expand path only applies
/// to run-structured codecs (RLE v1/v2). Literal groups decode to
/// length-1 runs, which is exactly how the expand kernel treats them.
#[derive(Debug, Default, Clone)]
pub struct RunRecorder {
    /// Recorded runs in output order.
    pub runs: Vec<RunRecord>,
    /// Element width (bytes) of the decoded column.
    pub width: u8,
    bytes: u64,
}

impl RunRecorder {
    /// New recorder; `width` is fixed on the first `write_run`.
    pub fn new() -> Self {
        Self::default()
    }

    /// Total decoded elements across all runs.
    pub fn total_elems(&self) -> u64 {
        self.runs.iter().map(|r| r.len).sum()
    }
}

impl OutputStream for RunRecorder {
    fn write_byte(&mut self, b: u8) -> Result<()> {
        // A raw byte is a width-1 length-1 run; keeps byte-RLE usable here.
        self.write_run(b as u64, 1, 0, 1)
    }

    fn write_run(&mut self, init: u64, len: u64, delta: i64, width: u8) -> Result<()> {
        if self.width == 0 {
            self.width = width;
        } else if self.width != width {
            return Err(corrupt("RunRecorder: mixed element widths in one chunk"));
        }
        // Merge with the previous run when contiguous (common after
        // literal groups decode to unit runs).
        if let Some(last) = self.runs.last_mut() {
            if last.len == 1 && len == 1 && delta == 0 {
                let implied = last.init.wrapping_add(last.delta as u64);
                if last.delta == 0 && implied == init && last.init == init {
                    last.len += 1;
                    self.bytes += width as u64;
                    return Ok(());
                }
            }
        }
        self.runs.push(RunRecord { init, len, delta });
        self.bytes += len * width as u64;
        Ok(())
    }

    fn memcpy(&mut self, _offset: u64, _len: u64) -> Result<()> {
        Err(corrupt("RunRecorder does not support memcpy (dictionary codecs)"))
    }

    fn write_slice(&mut self, bytes: &[u8]) -> Result<()> {
        // Must stay record-identical to the per-byte path: each byte is
        // a width-1 unit run, subject to the same adjacent-merge rule,
        // so the PJRT expand input does not depend on whether a decoder
        // batched its literals. (`write_elems` likewise keeps the trait
        // default — width-faithful unit runs under the same merge rule
        // — so the bulk-unpacked RLE groups record identically too.)
        for &b in bytes {
            self.write_run(b as u64, 1, 0, 1)?;
        }
        Ok(())
    }

    #[inline]
    fn bytes_written(&self) -> u64 {
        self.bytes
    }
}

/// Cache line size assumed throughout (A100/V100 L1/L2 sector line).
pub const CACHE_LINE: u64 = 128;

/// DEFLATE symbols the baseline queues in shared memory before syncing
/// (the RAPIDS gpuinflate batch buffer holds 32 LZ items).
pub const DEFLATE_BATCH: u32 = 32;

/// Wraps a sink and emits [`UnitEvent`]s modelling how a decompression
/// unit would execute on the GPU: decode bursts, coalesced cache-line
/// input refills (derived from the decoder's reported input position),
/// cache-line-buffered coalesced output writes, and the barriers /
/// broadcasts implied by the provisioning mode.
#[derive(Debug)]
pub struct TracingSink<S: OutputStream> {
    /// The wrapped sink (usually [`ByteSink`] or [`CountingSink`]).
    pub inner: S,
    /// Collected events.
    pub events: Vec<UnitEvent>,
    /// Lanes participating in writes (32 for a warp unit, block width for
    /// the baseline).
    pub write_width: u32,
    /// Baseline / single-thread-decode mode: the leader broadcasts each
    /// decoded descriptor (and each 32-symbol DEFLATE batch) and the
    /// unit synchronizes. CODAG's all-thread decoding emits neither.
    pub per_symbol_broadcast: bool,
    /// Barrier scope used around coalesced reads/writes.
    pub barrier_scope: BarrierScope,
    /// Input bytes already covered by emitted `Read` events.
    input_fetched: u64,
    /// Decode ops accumulated since the last non-decode event (merged so
    /// traces stay compact).
    pending_ops: u64,
    /// Output bytes produced but not yet flushed as write transactions
    /// (the output staging buffer of Fig 1b / RAPIDS batch buffers).
    pending_out: u64,
    /// Cache lines accumulated before a flush: 1 for CODAG (Algorithm 2
    /// writes one line per warp iteration), 8 for the baseline (RAPIDS
    /// stages ~1 KiB in its shared-memory batch buffers before the
    /// block-wide flush barrier).
    write_batch: u64,
    /// DEFLATE body symbols decoded since the last batch sync.
    deflate_batch: u32,
    /// Extra decode work fraction in 1/8ths added per symbol — the
    /// leader's decode-state save/restore and broadcast staging in
    /// single-thread decoding (§IV-D); 0 for all-thread decoding where
    /// every lane already holds the decoded state.
    pub ops_overhead_eighths: u32,
}

impl<S: OutputStream> TracingSink<S> {
    /// CODAG warp-level tracing: 32 write lanes, warp barriers, no
    /// broadcasts (all-thread decoding).
    pub fn codag(inner: S) -> Self {
        TracingSink {
            inner,
            events: Vec::new(),
            write_width: 32,
            per_symbol_broadcast: false,
            barrier_scope: BarrierScope::Warp,
            input_fetched: 0,
            pending_ops: 0,
            pending_out: 0,
            write_batch: 1,
            deflate_batch: 0,
            ops_overhead_eighths: 0,
        }
    }

    /// Baseline (RAPIDS-style) tracing: `block_width` write lanes, block
    /// barriers, a broadcast + barrier per decoded descriptor.
    pub fn baseline(inner: S, block_width: u32) -> Self {
        TracingSink {
            inner,
            events: Vec::new(),
            write_width: block_width,
            per_symbol_broadcast: true,
            barrier_scope: BarrierScope::Block,
            input_fetched: 0,
            pending_ops: 0,
            pending_out: 0,
            write_batch: 8,
            deflate_batch: 0,
            ops_overhead_eighths: 0,
        }
    }

    fn flush_ops(&mut self) {
        while self.pending_ops > 0 {
            let ops = self.pending_ops.min(u32::MAX as u64) as u32;
            self.events.push(UnitEvent::Decode { ops });
            self.pending_ops -= ops as u64;
        }
    }

    /// Account `bytes` of produced output; emit coalesced write
    /// transactions whenever full cache lines are available (the real
    /// kernels stage output and write 128 B per warp iteration —
    /// Algorithm 2's loop body).
    fn add_output(&mut self, bytes: u64) {
        self.pending_out += bytes;
        if self.pending_out >= CACHE_LINE * self.write_batch {
            self.flush_ops();
            self.events.push(UnitEvent::Barrier { scope: self.barrier_scope });
            while self.pending_out >= CACHE_LINE {
                let active = self.write_width.min(32);
                self.events.push(UnitEvent::Write { bytes: CACHE_LINE as u32, active });
                self.pending_out -= CACHE_LINE;
            }
        }
    }

    /// Finish tracing: flush pending decode ops and the write-buffer
    /// tail, and return (sink, events).
    pub fn finish(mut self) -> (S, Vec<UnitEvent>) {
        self.flush_ops();
        if self.pending_out > 0 {
            self.events.push(UnitEvent::Barrier { scope: self.barrier_scope });
            let active = ((self.pending_out + 3) / 4).min(32) as u32;
            self.events.push(UnitEvent::Write { bytes: self.pending_out as u32, active });
            self.pending_out = 0;
        }
        (self.inner, self.events)
    }
}

impl<S: OutputStream> OutputStream for TracingSink<S> {
    fn write_byte(&mut self, b: u8) -> Result<()> {
        self.inner.write_byte(b)?;
        self.add_output(1);
        Ok(())
    }

    fn write_run(&mut self, init: u64, len: u64, delta: i64, width: u8) -> Result<()> {
        self.inner.write_run(init, len, delta, width)?;
        self.add_output(len * width as u64);
        Ok(())
    }

    fn memcpy(&mut self, offset: u64, len: u64) -> Result<()> {
        self.inner.memcpy(offset, len)?;
        // Algorithm 2 reads back 2×4 B per 4 B written from the output
        // window; that read traffic hits L1/L2 (recently written lines),
        // so only the write traffic is charged to DRAM.
        self.add_output(len);
        Ok(())
    }

    fn write_slice(&mut self, bytes: &[u8]) -> Result<()> {
        // One batched accounting call per slice: byte totals (and
        // therefore the coalesced Write events `add_output` emits) are
        // identical to the per-byte path — a batch is an accounting
        // unit, not extra traffic.
        self.inner.write_slice(bytes)?;
        self.add_output(bytes.len() as u64);
        Ok(())
    }

    fn write_elems(&mut self, elems: &[u64], width: u8) -> Result<()> {
        // Same contract as `write_slice`: forward the batch to the
        // inner sink (native there), account the byte total once.
        self.inner.write_elems(elems, width)?;
        self.add_output(elems.len() as u64 * width as u64);
        Ok(())
    }

    #[inline]
    fn bytes_written(&self) -> u64 {
        self.inner.bytes_written()
    }

    fn on_symbol(&mut self, kind: SymbolKind, ops: u32, input_pos: u64) {
        let ops = ops + ops * self.ops_overhead_eighths / 8;
        self.pending_ops += ops as u64;
        if self.per_symbol_broadcast {
            let sync = kind.is_descriptor() || {
                if kind.is_deflate_body() {
                    self.deflate_batch += 1;
                    self.deflate_batch >= DEFLATE_BATCH
                } else {
                    false
                }
            };
            if sync {
                self.deflate_batch = 0;
                self.flush_ops();
                self.events.push(UnitEvent::Broadcast);
                self.events.push(UnitEvent::Barrier { scope: self.barrier_scope });
            }
        }
        // On-demand coalesced input refills (Algorithm 1): one cache line
        // per 128 B of compressed input crossed.
        while self.input_fetched < input_pos {
            self.flush_ops();
            if matches!(self.barrier_scope, BarrierScope::Warp) {
                // CODAG refills synchronize the warp (Algorithm 1 line 2/7).
                self.events.push(UnitEvent::Barrier { scope: BarrierScope::Warp });
            }
            self.events.push(UnitEvent::Read { bytes: CACHE_LINE as u32 });
            self.input_fetched += CACHE_LINE;
        }
    }
}

/// Bounded-slice sink: materializes into a caller-owned `&mut [u8]`.
///
/// The parallel-stitch sink of the container-v2 restart path (DESIGN.md
/// §7.5): each worker decodes its sub-block into a *disjoint* slice of
/// the shared scratch buffer, so every write is bounds-checked against
/// the slice and any overflow is a typed `Corrupt` — a corrupted
/// restart table can misroute a worker but can never scribble outside
/// its slice or silently produce wrong bytes that pass the
/// `bytes_written == expected` stitch check.
///
/// `memcpy` resolves entirely *within* the sub-block: restart-aware
/// encoders never emit a back-reference that crosses a restart boundary
/// (each sub-block is tokenized independently), so an offset reaching
/// before the slice start is corruption, not a window case.
#[derive(Debug)]
pub struct SliceSink<'a> {
    buf: &'a mut [u8],
    pos: usize,
}

impl<'a> SliceSink<'a> {
    /// New sink writing into `buf` from its start.
    pub fn new(buf: &'a mut [u8]) -> Self {
        SliceSink { buf, pos: 0 }
    }

    /// Remaining capacity in bytes.
    #[inline]
    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    #[inline]
    fn overflow(&self, wanted: u64) -> crate::Error {
        corrupt(format!(
            "sub-block write of {wanted} bytes overflows slice ({} of {} used)",
            self.pos,
            self.buf.len()
        ))
    }
}

impl OutputStream for SliceSink<'_> {
    #[inline]
    fn write_byte(&mut self, b: u8) -> Result<()> {
        if self.pos >= self.buf.len() {
            return Err(self.overflow(1));
        }
        self.buf[self.pos] = b;
        self.pos += 1;
        Ok(())
    }

    fn write_run(&mut self, init: u64, len: u64, delta: i64, width: u8) -> Result<()> {
        let w = width as usize;
        let total = (len as usize).checked_mul(w).filter(|&t| t <= self.remaining());
        let total = total.ok_or_else(|| self.overflow(len.saturating_mul(w as u64)))?;
        let end = self.pos + total;
        if delta == 0 {
            if w == 1 {
                self.buf[self.pos..end].fill(init as u8);
            } else {
                let le = init.to_le_bytes();
                for chunk in self.buf[self.pos..end].chunks_exact_mut(w) {
                    chunk.copy_from_slice(&le[..w]);
                }
            }
            self.pos = end;
            return Ok(());
        }
        let mut v = init;
        let d = delta as u64;
        while self.pos < end {
            let le = v.to_le_bytes();
            self.buf[self.pos..self.pos + w].copy_from_slice(&le[..w]);
            self.pos += w;
            v = v.wrapping_add(d);
        }
        Ok(())
    }

    fn memcpy(&mut self, offset: u64, len: u64) -> Result<()> {
        let off = offset as usize;
        let n = len as usize;
        if off == 0 || off > self.pos {
            return Err(corrupt(format!(
                "memcpy offset {off} out of sub-block window (slice pos {})",
                self.pos
            )));
        }
        if n > self.remaining() {
            return Err(self.overflow(len));
        }
        // Overlapping window semantics (`len > offset` repeats the
        // window): the scalar loop is the only correct order, and the
        // per-sub-block slices mean the source always lives in this
        // sink's own prefix.
        let src = self.pos - off;
        for i in 0..n {
            self.buf[self.pos + i] = self.buf[src + i];
        }
        self.pos += n;
        Ok(())
    }

    #[inline]
    fn write_slice(&mut self, bytes: &[u8]) -> Result<()> {
        if bytes.len() > self.remaining() {
            return Err(self.overflow(bytes.len() as u64));
        }
        self.buf[self.pos..self.pos + bytes.len()].copy_from_slice(bytes);
        self.pos += bytes.len();
        Ok(())
    }

    fn write_elems(&mut self, elems: &[u64], width: u8) -> Result<()> {
        let w = width as usize;
        let total = elems.len().checked_mul(w).filter(|&t| t <= self.remaining());
        if total.is_none() {
            return Err(self.overflow((elems.len() as u64).saturating_mul(w as u64)));
        }
        for e in elems {
            let le = e.to_le_bytes();
            self.buf[self.pos..self.pos + w].copy_from_slice(&le[..w]);
            self.pos += w;
        }
        Ok(())
    }

    #[inline]
    fn bytes_written(&self) -> u64 {
        self.pos as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn slice_sink_bounds_and_window() {
        let mut buf = [0u8; 8];
        let mut s = SliceSink::new(&mut buf);
        s.write_slice(b"ab").unwrap();
        s.memcpy(2, 4).unwrap(); // window repeat: abab
        assert_eq!(s.bytes_written(), 6);
        assert!(s.write_slice(b"xyz").is_err()); // 3 > 2 remaining
        assert!(s.memcpy(7, 1).is_err()); // reaches before slice start
        assert!(s.memcpy(0, 1).is_err());
        s.write_run(7, 2, 0, 1).unwrap();
        assert!(s.write_byte(0).is_err());
        drop(s);
        assert_eq!(&buf, b"ababab\x07\x07");
    }

    #[test]
    fn slice_sink_matches_byte_sink_on_runs() {
        let mut oracle = ByteSink::new();
        let mut buf = vec![0u8; 64];
        let mut s = SliceSink::new(&mut buf);
        for sink in [&mut oracle as &mut dyn OutputStream, &mut s] {
            sink.write_run(0x0102, 3, 0, 2).unwrap();
            sink.write_run(10, 4, 3, 1).unwrap();
            sink.write_elems(&[1, 2, 3], 4).unwrap();
        }
        let n = oracle.out.len();
        assert_eq!(buf[..n], oracle.out[..]);
    }

    #[test]
    fn byte_sink_run_expansion_widths() {
        let mut s = ByteSink::new();
        s.write_run(0x0102, 3, 0, 2).unwrap();
        assert_eq!(s.out, vec![0x02, 0x01, 0x02, 0x01, 0x02, 0x01]);
        let mut s = ByteSink::new();
        s.write_run(10, 4, 3, 1).unwrap();
        assert_eq!(s.out, vec![10, 13, 16, 19]);
    }

    #[test]
    fn byte_sink_run_negative_delta_wraps_in_width() {
        let mut s = ByteSink::new();
        s.write_run(1, 3, -1, 1).unwrap();
        assert_eq!(s.out, vec![1, 0, 255]);
    }

    #[test]
    fn byte_sink_memcpy_overlapping() {
        let mut s = ByteSink::new();
        for b in b"abc" {
            s.write_byte(*b).unwrap();
        }
        // offset 3, len 7 -> "abcabca" appended (wrapping window).
        s.memcpy(3, 7).unwrap();
        assert_eq!(&s.out, b"abcabcabca");
    }

    #[test]
    fn byte_sink_memcpy_matches_scalar_oracle() {
        // Sweep (offset, len) shapes across the vectorized chunked copy
        // and the byte-at-a-time oracle, including the doubling cases
        // (len >> offset) and exact window edges.
        let seed: Vec<u8> = (0u16..97).map(|i| (i * 31 % 251) as u8).collect();
        for off in [1u64, 2, 3, 7, 31, 96, 97] {
            for len in [1u64, 2, 6, 7, 8, 63, 64, 65, 500] {
                let mut v = ByteSink::new();
                let mut s = ScalarSink::new();
                v.write_slice(&seed).unwrap();
                s.write_slice(&seed).unwrap();
                v.memcpy(off, len).unwrap();
                s.memcpy(off, len).unwrap();
                assert_eq!(v.out, s.out, "off={off} len={len}");
            }
        }
    }

    #[test]
    fn write_slice_matches_per_byte_everywhere() {
        let bytes: Vec<u8> = (0u16..300).map(|i| (i % 256) as u8).collect();
        // ByteSink bulk == ScalarSink default loop.
        let mut b = ByteSink::new();
        let mut s = ScalarSink::new();
        b.write_slice(&bytes).unwrap();
        s.write_slice(&bytes).unwrap();
        assert_eq!(b.out, s.out);
        // CountingSink counts the batch.
        let mut c = CountingSink::new();
        c.write_slice(&bytes).unwrap();
        assert_eq!(c.bytes_written(), bytes.len() as u64);
        // RunRecorder: slice path and per-byte path record identically.
        let data = [7u8, 7, 7, 9, 9, 1];
        let mut sliced = RunRecorder::new();
        sliced.write_slice(&data).unwrap();
        let mut scalar = RunRecorder::new();
        for &x in &data {
            scalar.write_byte(x).unwrap();
        }
        assert_eq!(sliced.runs, scalar.runs);
        assert_eq!(sliced.bytes_written(), scalar.bytes_written());
        assert_eq!(sliced.width, scalar.width);
    }

    #[test]
    fn byte_sink_run_expansion_matches_scalar_all_shapes() {
        // The doubling-memcpy (delta 0) and block-staged (delta != 0)
        // expansions must stay byte-identical to the scalar per-element
        // oracle across widths, lengths straddling the staging block,
        // and wrapping deltas.
        for width in [1u8, 2, 4, 8] {
            for len in [1u64, 2, 3, 63, 64, 65, 511, 512, 513, 2000] {
                for delta in [0i64, 1, -1, 255, -77777, i64::MIN] {
                    let init = 0xDEAD_BEEF_CAFE_F00Du64;
                    let mut b = ByteSink::new();
                    b.write_run(init, len, delta, width).unwrap();
                    let mut s = ScalarSink::new();
                    s.write_run(init, len, delta, width).unwrap();
                    assert_eq!(b.out, s.out, "w{width} len{len} d{delta}");
                }
            }
        }
    }

    #[test]
    fn write_elems_matches_per_element_write_run_everywhere() {
        let elems: Vec<u64> = (0..300u64)
            .map(|i| i.wrapping_mul(0x9E37_79B9_7F4A_7C15))
            .collect();
        for width in [1u8, 2, 4, 8] {
            // ByteSink native == ScalarSink default loop.
            let mut b = ByteSink::new();
            b.write_elems(&elems, width).unwrap();
            let mut s = ScalarSink::new();
            s.write_elems(&elems, width).unwrap();
            assert_eq!(b.out, s.out, "w{width}");
            // CountingSink counts the batch.
            let mut c = CountingSink::new();
            c.write_elems(&elems, width).unwrap();
            assert_eq!(c.bytes_written(), elems.len() as u64 * width as u64);
            // RunRecorder: batch path records exactly what per-element
            // unit runs record (width-faithful, same merge rule).
            let mut batched = RunRecorder::new();
            batched.write_elems(&elems, width).unwrap();
            let mut scalar = RunRecorder::new();
            for &e in &elems {
                scalar.write_run(e, 1, 0, width).unwrap();
            }
            assert_eq!(batched.runs, scalar.runs, "w{width}");
            assert_eq!(batched.width, scalar.width, "w{width}");
            assert_eq!(batched.bytes_written(), scalar.bytes_written(), "w{width}");
        }
    }

    #[test]
    fn tracing_sink_elems_preserves_byte_totals() {
        let elems = vec![7u64; 333];
        let mut batched = TracingSink::codag(CountingSink::new());
        batched.write_elems(&elems, 4).unwrap();
        let (bs, bev) = batched.finish();
        let mut scalar = TracingSink::codag(CountingSink::new());
        for &e in &elems {
            scalar.write_run(e, 1, 0, 4).unwrap();
        }
        let (ss, sev) = scalar.finish();
        assert_eq!(bs.bytes_written(), ss.bytes_written());
        let write_bytes = |evs: &[UnitEvent]| -> u64 {
            evs.iter()
                .map(|e| if let UnitEvent::Write { bytes, .. } = e { *bytes as u64 } else { 0 })
                .sum()
        };
        assert_eq!(write_bytes(&bev), write_bytes(&sev));
    }

    #[test]
    fn tracing_sink_slice_preserves_byte_totals() {
        let payload = vec![42u8; 1000];
        let mut batched = TracingSink::codag(CountingSink::new());
        batched.write_slice(&payload).unwrap();
        let (bs, bev) = batched.finish();
        let mut scalar = TracingSink::codag(CountingSink::new());
        for &b in &payload {
            scalar.write_byte(b).unwrap();
        }
        let (ss, sev) = scalar.finish();
        assert_eq!(bs.bytes_written(), ss.bytes_written());
        let write_bytes = |evs: &[UnitEvent]| -> u64 {
            evs.iter()
                .map(|e| if let UnitEvent::Write { bytes, .. } = e { *bytes as u64 } else { 0 })
                .sum()
        };
        assert_eq!(write_bytes(&bev), write_bytes(&sev));
    }

    #[test]
    fn byte_sink_memcpy_bad_offset() {
        let mut s = ByteSink::new();
        s.write_byte(b'x').unwrap();
        assert!(s.memcpy(2, 1).is_err());
        assert!(s.memcpy(0, 1).is_err());
    }

    #[test]
    fn counting_sink_matches_byte_sink() {
        let mut b = ByteSink::new();
        let mut c = CountingSink::new();
        for s in [&mut b as &mut dyn OutputStream, &mut c] {
            s.write_byte(1).unwrap();
            s.write_run(5, 10, 2, 4).unwrap();
            s.memcpy(8, 20).unwrap();
        }
        assert_eq!(b.bytes_written(), c.bytes_written());
    }

    #[test]
    fn run_recorder_records_and_rejects_memcpy() {
        let mut r = RunRecorder::new();
        r.write_run(100, 50, 0, 8).unwrap();
        r.write_run(7, 1, 0, 8).unwrap();
        assert!(r.memcpy(1, 1).is_err());
        assert_eq!(r.total_elems(), 51);
        assert_eq!(r.bytes_written(), 51 * 8);
        assert_eq!(r.runs[0], RunRecord { init: 100, len: 50, delta: 0 });
    }

    #[test]
    fn run_recorder_rejects_mixed_widths() {
        let mut r = RunRecorder::new();
        r.write_run(1, 1, 0, 8).unwrap();
        assert!(r.write_run(1, 1, 0, 4).is_err());
    }

    #[test]
    fn tracing_sink_codag_no_broadcast() {
        let mut t = TracingSink::codag(ByteSink::new());
        t.on_symbol(SymbolKind::RleRun, 20, 10);
        t.write_run(5, 64, 0, 8).unwrap();
        let (sink, events) = t.finish();
        assert_eq!(sink.bytes_written(), 512);
        assert!(events.iter().all(|e| !matches!(e, UnitEvent::Broadcast)));
        assert!(events.iter().any(|e| matches!(e, UnitEvent::Read { .. })));
        assert!(events.iter().any(|e| matches!(e, UnitEvent::Write { .. })));
        assert!(events
            .iter()
            .any(|e| matches!(e, UnitEvent::Barrier { scope: BarrierScope::Warp })));
    }

    #[test]
    fn tracing_sink_baseline_broadcasts_per_symbol() {
        let mut t = TracingSink::baseline(ByteSink::new(), 1024);
        t.on_symbol(SymbolKind::RleRun, 20, 10);
        t.on_symbol(SymbolKind::RleRun, 20, 12);
        t.write_run(5, 4, 0, 1).unwrap();
        let (_, events) = t.finish();
        let bcasts = events.iter().filter(|e| matches!(e, UnitEvent::Broadcast)).count();
        assert_eq!(bcasts, 2);
        assert!(events
            .iter()
            .any(|e| matches!(e, UnitEvent::Barrier { scope: BarrierScope::Block })));
    }

    #[test]
    fn tracing_read_events_cover_input() {
        let mut t = TracingSink::codag(CountingSink::new());
        t.on_symbol(SymbolKind::RleRun, 5, 300);
        let (_, events) = t.finish();
        let read_bytes: u64 = events
            .iter()
            .map(|e| if let UnitEvent::Read { bytes } = e { *bytes as u64 } else { 0 })
            .sum();
        assert_eq!(read_bytes, 384); // ceil(300/128)*128
    }
}
