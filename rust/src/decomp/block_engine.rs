//! The RAPIDS-style baseline decompression units (paper §II-C, Fig 1a).
//!
//! One *thread block* per compressed chunk: a dedicated prefetch warp
//! fills shared-memory batch buffers, a single leader thread performs the
//! sequential decode, and after each decoded symbol the leader broadcasts
//! to the whole block and all threads synchronize on a block-wide barrier
//! before collectively writing. The paper's characterization (§III)
//! attributes the baseline's poor resource utilization to exactly this
//! provisioning; reproducing it faithfully is what lets the simulator
//! regenerate Figs 2/3/5/6.
//!
//! Block widths match the paper (§V-F): 1024 threads for RLE v1/v2,
//! 128 for Deflate (and the byte-match codecs that share its decode
//! shape). Each codec declares its own width via
//! [`Codec::block_width`](crate::codecs::Codec::block_width).

use crate::codecs::{decode_into, CodecKind, CodecRegistry};
use crate::decomp::output_stream::{ByteSink, CountingSink, OutputStream, TracingSink};
use crate::decomp::trace::UnitTrace;
use crate::Result;

/// Threads per block the baseline provisions for a codec (§V-F).
/// Unregistered ids fall back to the narrow DEFLATE-style unit.
pub fn block_width(kind: CodecKind) -> u32 {
    CodecRegistry::get(kind).map_or(128, |c| c.block_width())
}

/// Warps one baseline decompression unit occupies (the prefetch warp is
/// one of the block's warps — Fig 1a).
pub fn warps_per_unit(kind: CodecKind) -> u32 {
    block_width(kind) / 32
}

/// Decode one chunk under the baseline provisioning.
pub fn trace_chunk(kind: CodecKind, comp: &[u8], uncomp_hint: usize) -> Result<(Vec<u8>, UnitTrace)> {
    let sink = ByteSink::with_capacity(uncomp_hint);
    let mut tracer = TracingSink::baseline(sink, block_width(kind));
    decode_into(kind, comp, &mut tracer)?;
    let (sink, events) = tracer.finish();
    let out = sink.into_bytes();
    let trace = UnitTrace {
        events,
        comp_bytes: comp.len() as u64,
        uncomp_bytes: out.len() as u64,
    };
    Ok((out, trace))
}

/// Counting variant for throughput benches.
pub fn trace_chunk_counting(kind: CodecKind, comp: &[u8]) -> Result<UnitTrace> {
    let mut tracer = TracingSink::baseline(CountingSink::new(), block_width(kind));
    decode_into(kind, comp, &mut tracer)?;
    let uncomp = tracer.bytes_written();
    let (_, events) = tracer.finish();
    Ok(UnitTrace { events, comp_bytes: comp.len() as u64, uncomp_bytes: uncomp })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codecs::compress_chunk_with;
    use crate::decomp::codag_engine::{self, Variant};

    #[test]
    fn baseline_broadcasts_per_symbol() {
        let mut data = Vec::new();
        for i in 0..2048u64 {
            data.extend_from_slice(&(i / 32).to_le_bytes());
        }
        let comp = compress_chunk_with(CodecKind::RleV1, &data, 8).unwrap();
        let (out, t) = trace_chunk(CodecKind::RleV1, &comp, data.len()).unwrap();
        assert_eq!(out, data);
        assert!(t.broadcast_count() > 0);
        // Block barriers dominate.
        assert!(t.barrier_count() >= t.broadcast_count());
    }

    #[test]
    fn baseline_and_codag_same_output_different_sync() {
        let data: Vec<u8> = (0..50_000u32).map(|i| (i % 251) as u8).collect();
        let comp = crate::codecs::deflate::compress(&data).unwrap();
        let (o1, bt) = trace_chunk(CodecKind::Deflate, &comp, data.len()).unwrap();
        let (o2, ct) =
            codag_engine::trace_chunk(CodecKind::Deflate, &comp, data.len(), Variant::Codag).unwrap();
        assert_eq!(o1, o2);
        assert!(bt.broadcast_count() > ct.broadcast_count());
        // Baseline syncs are block-scope (expensive); CODAG's are all
        // warp-scope. (Counts aren't comparable: the baseline batches
        // its output flushes through shared memory.)
        use crate::decomp::trace::{BarrierScope, UnitEvent};
        assert!(bt
            .events
            .iter()
            .any(|e| matches!(e, UnitEvent::Barrier { scope: BarrierScope::Block })));
        assert!(ct
            .events
            .iter()
            .all(|e| !matches!(e, UnitEvent::Barrier { scope: BarrierScope::Block })));
    }

    #[test]
    fn widths_match_paper() {
        assert_eq!(block_width(CodecKind::RleV1), 1024);
        assert_eq!(block_width(CodecKind::RleV2), 1024);
        assert_eq!(block_width(CodecKind::Deflate), 128);
        assert_eq!(block_width(CodecKind::Lzss), 128);
        assert_eq!(warps_per_unit(CodecKind::RleV1), 32);
        assert_eq!(warps_per_unit(CodecKind::Deflate), 4);
        assert_eq!(warps_per_unit(CodecKind::Lzss), 4);
    }
}
