//! Decompression-unit event traces.
//!
//! A decompression unit (one chunk being decompressed) is summarized as a
//! sequence of [`UnitEvent`]s. The real codec decoders emit these while
//! decoding real data, so the traces carry the true per-dataset symbol
//! statistics (run lengths, symbol bit widths, memcpy lengths). The GPU
//! timing simulator ([`crate::gpu_sim`]) then replays them under either
//! the CODAG warp-level provisioning or the RAPIDS-style block-level
//! provisioning to produce the paper's characterization metrics.

/// Scope of a synchronization barrier.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BarrierScope {
    /// `__syncwarp` — cheap, warp-wide (CODAG).
    Warp,
    /// `__syncthreads` — expensive, block-wide (baseline).
    Block,
}

/// One event in a decompression unit's execution.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum UnitEvent {
    /// Sequential decode work: `ops` arithmetic/logic instructions executed
    /// by the decoding thread(s) — all lanes in CODAG's all-thread mode,
    /// one leader lane in the baseline.
    Decode { ops: u32 },
    /// Coalesced read of one cache line (128 B) of compressed input from
    /// global memory into the input buffer (Algorithm 1).
    Read { bytes: u32 },
    /// Coalesced write of decompressed output to global memory.
    /// `active` is the number of lanes with work (run length can be
    /// shorter than the unit width — paper §III notes idle write lanes).
    Write { bytes: u32, active: u32 },
    /// Synchronization barrier.
    Barrier { scope: BarrierScope },
    /// Leader-to-lanes broadcast of decoded information (baseline only;
    /// CODAG's all-thread decoding eliminates these, §IV-D).
    Broadcast,
}

/// The full event trace of one decompression unit (one chunk).
#[derive(Debug, Clone, Default)]
pub struct UnitTrace {
    /// Events in program order.
    pub events: Vec<UnitEvent>,
    /// Compressed size of the chunk (bytes).
    pub comp_bytes: u64,
    /// Uncompressed size of the chunk (bytes).
    pub uncomp_bytes: u64,
}

impl UnitTrace {
    /// Total decode ops in the trace.
    pub fn total_decode_ops(&self) -> u64 {
        self.events
            .iter()
            .map(|e| match e {
                UnitEvent::Decode { ops } => *ops as u64,
                _ => 0,
            })
            .sum()
    }

    /// Number of barrier events.
    pub fn barrier_count(&self) -> u64 {
        self.events.iter().filter(|e| matches!(e, UnitEvent::Barrier { .. })).count() as u64
    }

    /// Number of broadcast events.
    pub fn broadcast_count(&self) -> u64 {
        self.events.iter().filter(|e| matches!(e, UnitEvent::Broadcast)).count() as u64
    }

    /// Bytes moved to/from global memory.
    pub fn memory_bytes(&self) -> u64 {
        self.events
            .iter()
            .map(|e| match e {
                UnitEvent::Read { bytes } => *bytes as u64,
                UnitEvent::Write { bytes, .. } => *bytes as u64,
                _ => 0,
            })
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_aggregates() {
        let t = UnitTrace {
            events: vec![
                UnitEvent::Decode { ops: 10 },
                UnitEvent::Read { bytes: 128 },
                UnitEvent::Broadcast,
                UnitEvent::Barrier { scope: BarrierScope::Block },
                UnitEvent::Write { bytes: 256, active: 32 },
                UnitEvent::Decode { ops: 5 },
            ],
            comp_bytes: 100,
            uncomp_bytes: 400,
        };
        assert_eq!(t.total_decode_ops(), 15);
        assert_eq!(t.barrier_count(), 1);
        assert_eq!(t.broadcast_count(), 1);
        assert_eq!(t.memory_bytes(), 384);
    }
}
