//! `input_stream` — the paper's Table I reading abstraction.
//!
//! A byte/bit cursor over one compressed chunk with the two Table I
//! member functions (`fetch_bits`, `peek_bits`) plus the byte-granular
//! reads the RLE codecs use. The structure models Algorithm 1: data is
//! conceptually staged through a double-cache-line input buffer refilled
//! 128 B at a time; [`InputStream::bytes_consumed`] exposes the high-water
//! mark the tracing layer converts into coalesced `Read` events.
//!
//! The DEFLATE path needs LSB-first sub-byte access and RLE v2 needs
//! MSB-first; both conventions are provided on the same cursor (a chunk
//! uses one convention throughout, so the mixed API carries no state
//! hazards — switching convention mid-byte is a programming error caught
//! by debug assertions).

use crate::format::bitio::{LsbBitReader, MsbBitReader};
use crate::format::varint;
use crate::{corrupt, Result};

/// Cursor over a compressed chunk (Table I).
#[derive(Debug, Clone)]
pub struct InputStream<'a> {
    data: &'a [u8],
    pos: usize,
}

impl<'a> InputStream<'a> {
    /// New stream over a compressed chunk.
    pub fn new(data: &'a [u8]) -> Self {
        InputStream { data, pos: 0 }
    }

    /// Bytes consumed so far (drives cache-line refill accounting).
    #[inline]
    pub fn bytes_consumed(&self) -> u64 {
        self.pos as u64
    }

    /// Total chunk length.
    #[inline]
    pub fn len(&self) -> usize {
        self.data.len()
    }

    /// True when the cursor is at the end.
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.pos >= self.data.len()
    }

    /// Remaining bytes.
    #[inline]
    pub fn remaining(&self) -> usize {
        self.data.len() - self.pos
    }

    /// Fetch one byte.
    #[inline]
    pub fn fetch_byte(&mut self) -> Result<u8> {
        let b = *self.data.get(self.pos).ok_or_else(|| corrupt("input_stream: eof"))?;
        self.pos += 1;
        Ok(b)
    }

    /// Peek one byte without consuming.
    #[inline]
    pub fn peek_byte(&self) -> Result<u8> {
        self.data.get(self.pos).copied().ok_or_else(|| corrupt("input_stream: eof"))
    }

    /// Fetch `n` raw bytes as a slice.
    #[inline]
    pub fn fetch_bytes(&mut self, n: usize) -> Result<&'a [u8]> {
        let s = self
            .data
            .get(self.pos..self.pos + n)
            .ok_or_else(|| corrupt(format!("input_stream: wanted {n} bytes, {} left", self.remaining())))?;
        self.pos += n;
        Ok(s)
    }

    /// Fetch an unsigned LEB128 varint.
    #[inline]
    pub fn fetch_uvarint(&mut self) -> Result<u64> {
        varint::read_uvarint(self.data, &mut self.pos)
    }

    /// Fetch a zigzag signed varint.
    #[inline]
    pub fn fetch_svarint(&mut self) -> Result<i64> {
        varint::read_svarint(self.data, &mut self.pos)
    }

    /// Borrow the remainder of the chunk as an MSB-first bit reader
    /// (RLE v2 packed sections); [`commit_msb`] advances the cursor.
    pub fn msb_reader(&self) -> MsbBitReader<'a> {
        MsbBitReader::new(&self.data[self.pos..])
    }

    /// Advance the cursor past the bytes consumed by an [`MsbBitReader`]
    /// obtained from [`msb_reader`].
    pub fn commit_msb(&mut self, reader: &MsbBitReader<'_>) {
        self.pos += reader.byte_pos();
    }

    /// Borrow the remainder as an LSB-first bit reader (DEFLATE).
    pub fn lsb_reader(&self) -> LsbBitReader<'a> {
        LsbBitReader::new(&self.data[self.pos..])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::format::varint::write_uvarint;

    #[test]
    fn byte_and_varint_reads() {
        let mut buf = vec![7u8];
        write_uvarint(&mut buf, 300);
        buf.extend_from_slice(&[1, 2, 3]);
        let mut s = InputStream::new(&buf);
        assert_eq!(s.fetch_byte().unwrap(), 7);
        assert_eq!(s.fetch_uvarint().unwrap(), 300);
        assert_eq!(s.fetch_bytes(3).unwrap(), &[1, 2, 3]);
        assert!(s.is_empty());
        assert_eq!(s.bytes_consumed(), buf.len() as u64);
    }

    #[test]
    fn msb_reader_commit() {
        let buf = [0xAB, 0xCD, 0xEF];
        let mut s = InputStream::new(&buf);
        s.fetch_byte().unwrap();
        let mut r = s.msb_reader();
        assert_eq!(r.read_bits(12).unwrap(), 0xCDE);
        s.commit_msb(&r);
        // 12 bits consumed -> rounds to 2 bytes.
        assert_eq!(s.bytes_consumed(), 3);
    }

    #[test]
    fn eof_errors() {
        let buf = [1u8];
        let mut s = InputStream::new(&buf);
        s.fetch_byte().unwrap();
        assert!(s.fetch_byte().is_err());
        assert!(s.fetch_bytes(1).is_err());
        assert!(s.peek_byte().is_err());
    }
}
