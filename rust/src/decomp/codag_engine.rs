//! CODAG warp-level decompression units (paper §IV, Fig 1b).
//!
//! One warp per compressed chunk; all 32 lanes execute the sequential
//! decode redundantly (all-thread decoding, §IV-D), synchronize with
//! cheap warp barriers only around the coalesced on-demand reads
//! (Algorithm 1) and writes, and never broadcast.
//!
//! [`trace_chunk`] runs the real codec decoder over the real compressed
//! bytes and returns both the decompressed output and the [`UnitTrace`]
//! the GPU simulator schedules. [`Variant`] covers the paper's two
//! ablations: adding back a prefetch warp (§V-F) and single-thread
//! decoding (§V-E).
//!
//! Decoders emit batched `write_slice` calls on the hot path (DESIGN.md
//! §7); the tracing sink accounts a batch as one unit whose byte total
//! equals the per-byte path's, so coalesced `Write` events still cover
//! every output byte exactly once and traces stay deterministic.

use crate::codecs::{decode_into, CodecKind};
use crate::decomp::output_stream::{ByteSink, OutputStream, TracingSink};
use crate::decomp::trace::{UnitEvent, UnitTrace};
use crate::Result;

/// CODAG engine variants evaluated in the paper's ablations.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Variant {
    /// Full CODAG: warp unit, all-thread decoding, no prefetch warp.
    Codag,
    /// §V-F ablation: CODAG plus a dedicated prefetch warp per chunk
    /// (two warps scheduled per chunk).
    CodagPrefetch,
    /// §V-E ablation: warp unit but only the leader lane decodes, so a
    /// broadcast is required per decoded symbol.
    SingleThreadDecode,
    /// §IV-E configuration: the input buffer lives in registers (two
    /// 32-bit registers per lane as a double buffer) and fetches are
    /// warp shuffles instead of shared-memory loads.
    RegisterBuffer,
}

impl Variant {
    /// Warps a single decompression unit occupies.
    pub fn warps_per_unit(&self) -> u32 {
        match self {
            Variant::Codag | Variant::SingleThreadDecode | Variant::RegisterBuffer => 1,
            Variant::CodagPrefetch => 2,
        }
    }

    /// Whether input reads are overlapped by a prefetch warp.
    pub fn has_prefetch_warp(&self) -> bool {
        matches!(self, Variant::CodagPrefetch)
    }
}

/// Decode one chunk under the CODAG provisioning, returning the output
/// bytes and the unit trace.
pub fn trace_chunk(
    kind: CodecKind,
    comp: &[u8],
    uncomp_hint: usize,
    variant: Variant,
) -> Result<(Vec<u8>, UnitTrace)> {
    let sink = ByteSink::with_capacity(uncomp_hint);
    let mut tracer = TracingSink::codag(sink);
    if matches!(variant, Variant::SingleThreadDecode) {
        // Leader-only decoding re-introduces the per-descriptor
        // broadcast and the decode-state save/restore around on-demand
        // reads/writes (§IV-D) — ~1/7 extra decode instructions.
        tracer.per_symbol_broadcast = true;
        tracer.ops_overhead_eighths = 1;
    }
    decode_into(kind, comp, &mut tracer)?;
    let (sink, events) = tracer.finish();
    let out = sink.into_bytes();
    let trace = UnitTrace {
        events,
        comp_bytes: comp.len() as u64,
        uncomp_bytes: out.len() as u64,
    };
    Ok((out, trace))
}

/// Decode-only variant used by throughput benches (skips output copy).
pub fn trace_chunk_counting(
    kind: CodecKind,
    comp: &[u8],
    variant: Variant,
) -> Result<UnitTrace> {
    use crate::decomp::output_stream::CountingSink;
    let mut tracer = TracingSink::codag(CountingSink::new());
    if matches!(variant, Variant::SingleThreadDecode) {
        tracer.per_symbol_broadcast = true;
        tracer.ops_overhead_eighths = 1;
    }
    decode_into(kind, comp, &mut tracer)?;
    let uncomp = tracer.bytes_written();
    let (_, events) = tracer.finish();
    Ok(UnitTrace { events, comp_bytes: comp.len() as u64, uncomp_bytes: uncomp })
}

/// Sanity summary used by tests: (decode_ops, barriers, broadcasts).
pub fn trace_summary(t: &UnitTrace) -> (u64, u64, u64) {
    (t.total_decode_ops(), t.barrier_count(), t.broadcast_count())
}

/// True if the trace's read events cover the compressed bytes.
pub fn reads_cover_input(t: &UnitTrace) -> bool {
    let read: u64 = t
        .events
        .iter()
        .map(|e| if let UnitEvent::Read { bytes } = e { *bytes as u64 } else { 0 })
        .sum();
    read + 128 >= t.comp_bytes
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codecs::compress_chunk_with;

    fn runny_chunk() -> Vec<u8> {
        let mut data = Vec::new();
        for i in 0..4096u64 {
            data.extend_from_slice(&(i / 64).to_le_bytes());
        }
        data
    }

    #[test]
    fn codag_trace_has_no_broadcasts() {
        let data = runny_chunk();
        let comp = compress_chunk_with(CodecKind::RleV1, &data, 8).unwrap();
        let (out, trace) = trace_chunk(CodecKind::RleV1, &comp, data.len(), Variant::Codag).unwrap();
        assert_eq!(out, data);
        assert_eq!(trace.broadcast_count(), 0);
        assert!(trace.barrier_count() > 0);
        assert!(reads_cover_input(&trace));
    }

    #[test]
    fn single_thread_variant_broadcasts() {
        let data = runny_chunk();
        let comp = compress_chunk_with(CodecKind::RleV1, &data, 8).unwrap();
        let (_, st) =
            trace_chunk(CodecKind::RleV1, &comp, data.len(), Variant::SingleThreadDecode).unwrap();
        let (_, at) = trace_chunk(CodecKind::RleV1, &comp, data.len(), Variant::Codag).unwrap();
        assert!(st.broadcast_count() > 0);
        assert_eq!(at.broadcast_count(), 0);
        // Single-thread decode carries the save/restore overhead.
        assert!(st.total_decode_ops() > at.total_decode_ops());
    }

    #[test]
    fn counting_matches_materializing() {
        let data = runny_chunk();
        let comp = compress_chunk_with(CodecKind::RleV2, &data, 8).unwrap();
        let (_, t1) = trace_chunk(CodecKind::RleV2, &comp, data.len(), Variant::Codag).unwrap();
        let t2 = trace_chunk_counting(CodecKind::RleV2, &comp, Variant::Codag).unwrap();
        assert_eq!(t1.uncomp_bytes, t2.uncomp_bytes);
        assert_eq!(t1.total_decode_ops(), t2.total_decode_ops());
    }

    #[test]
    fn batched_writes_preserve_trace_byte_totals() {
        // Deflate batches literal runs into slice writes; the trace's
        // coalesced Write events must still cover every output byte
        // exactly once, for both materializing and counting sinks.
        let mut x = 5u64;
        let data: Vec<u8> = (0..4096)
            .map(|_| {
                x = x.wrapping_mul(6364136223846793005).wrapping_add(1);
                (x >> 56) as u8
            })
            .collect();
        let comp = crate::codecs::deflate::compress(&data).unwrap();
        let (out, trace) = trace_chunk(CodecKind::Deflate, &comp, data.len(), Variant::Codag).unwrap();
        assert_eq!(out, data);
        let written: u64 = trace
            .events
            .iter()
            .map(|e| if let UnitEvent::Write { bytes, .. } = e { *bytes as u64 } else { 0 })
            .sum();
        assert_eq!(written, out.len() as u64);
        let counted = trace_chunk_counting(CodecKind::Deflate, &comp, Variant::Codag).unwrap();
        assert_eq!(counted.uncomp_bytes, trace.uncomp_bytes);
        assert_eq!(counted.events, trace.events, "trace must not depend on the sink");
    }

    #[test]
    fn deflate_traces_work_too() {
        let data = b"deflate deflate deflate deflate deflate!".repeat(100);
        let comp = crate::codecs::deflate::compress(&data).unwrap();
        let (out, trace) = trace_chunk(CodecKind::Deflate, &comp, data.len(), Variant::Codag).unwrap();
        assert_eq!(out, data);
        assert!(trace.total_decode_ops() > 0);
    }
}
