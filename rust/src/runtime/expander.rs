//! The expand dispatcher: run records → bucket selection → PJRT → bytes.
//!
//! Bridges the Rust decode half and the AOT JAX/Pallas expand half:
//! pads a chunk's [`RunRecord`]s into the smallest fitting fixed-shape
//! bucket, executes through [`PjrtRuntime`], and re-serializes the i64
//! element stream to the column's byte width. Chunks whose run table
//! exceeds every bucket (degenerate literal-heavy chunks) fall back to
//! the CPU expansion — a deliberate design decision (expanding unit
//! runs on an accelerator does no useful work); the fallback is counted
//! so benches can report the split.

use crate::decomp::{ByteSink, OutputStream, RunRecord};
use crate::runtime::executor::{ArtifactKey, SharedRuntime};
use crate::{invalid, Result};
use std::sync::atomic::{AtomicU64, Ordering};

/// Dispatcher statistics.
#[derive(Debug, Default)]
pub struct ExpanderStats {
    /// Chunks expanded through PJRT.
    pub pjrt: AtomicU64,
    /// Chunks expanded on the CPU fallback path.
    pub cpu_fallback: AtomicU64,
}

/// Run-record expander with bucket dispatch.
#[derive(Debug)]
pub struct Expander<'rt> {
    runtime: Option<&'rt SharedRuntime>,
    buckets: Vec<(usize, usize)>,
    /// Dispatch statistics.
    pub stats: ExpanderStats,
}

impl<'rt> Expander<'rt> {
    /// Expander backed by a PJRT runtime.
    pub fn new(runtime: &'rt SharedRuntime) -> Expander<'rt> {
        let buckets = runtime
            .buckets()
            .into_iter()
            .filter_map(|k| match k {
                ArtifactKey::Expand { n_runs, m_out } => Some((n_runs, m_out)),
                _ => None,
            })
            .collect();
        Expander { runtime: Some(runtime), buckets, stats: ExpanderStats::default() }
    }

    /// CPU-only expander (no artifacts available).
    pub fn cpu_only() -> Expander<'static> {
        Expander { runtime: None, buckets: Vec::new(), stats: ExpanderStats::default() }
    }

    /// Smallest bucket fitting `n_runs` runs and `total` elements.
    pub fn pick_bucket(&self, n_runs: usize, total: usize) -> Option<(usize, usize)> {
        self.buckets
            .iter()
            .copied()
            .filter(|&(n, m)| n_runs <= n && total <= m)
            .min_by_key(|&(n, m)| (m, n))
    }

    /// Expand `runs` (element width `width`, `total` elements) to bytes.
    pub fn expand(&self, runs: &[RunRecord], width: u8, total: usize) -> Result<Vec<u8>> {
        if let (Some(rt), Some((bn, bm))) =
            (self.runtime, self.pick_bucket(runs.len(), total))
        {
            self.stats.pjrt.fetch_add(1, Ordering::Relaxed);
            let key = ArtifactKey::Expand { n_runs: bn, m_out: bm };
            // Pad to the bucket: starts carry i32::MAX so the kernel's
            // searchsorted never selects a padding slot.
            let mut starts = vec![i32::MAX; bn];
            let mut values = vec![0i64; bn];
            let mut deltas = vec![0i64; bn];
            let mut acc = 0u64;
            for (i, r) in runs.iter().enumerate() {
                if acc > i32::MAX as u64 {
                    return Err(invalid("chunk too large for i32 offsets"));
                }
                starts[i] = acc as i32;
                values[i] = r.init as i64;
                deltas[i] = r.delta;
                acc += r.len;
            }
            if acc as usize != total {
                return Err(invalid(format!(
                    "run records sum to {acc} elements, expected {total}"
                )));
            }
            let elems = rt.run_expand(key, &starts, &values, &deltas)?;
            Ok(elems_to_bytes(&elems[..total], width))
        } else {
            self.stats.cpu_fallback.fetch_add(1, Ordering::Relaxed);
            cpu_expand(runs, width)
        }
    }
}

/// CPU reference expansion (also the fallback path).
pub fn cpu_expand(runs: &[RunRecord], width: u8) -> Result<Vec<u8>> {
    let mut sink = ByteSink::new();
    for r in runs {
        sink.write_run(r.init, r.len, r.delta, width)?;
    }
    Ok(sink.into_bytes())
}

/// Serialize i64 elements to `width`-byte little-endian bytes.
pub fn elems_to_bytes(elems: &[i64], width: u8) -> Vec<u8> {
    let w = width as usize;
    let mut out = Vec::with_capacity(elems.len() * w);
    for &e in elems {
        let le = (e as u64).to_le_bytes();
        out.extend_from_slice(&le[..w]);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cpu_expand_matches_manual() {
        let runs = vec![
            RunRecord { init: 5, len: 3, delta: 2 },
            RunRecord { init: 100, len: 1, delta: 0 },
        ];
        let bytes = cpu_expand(&runs, 2).unwrap();
        let want: Vec<u8> = [5u16, 7, 9, 100].iter().flat_map(|v| v.to_le_bytes()).collect();
        assert_eq!(bytes, want);
    }

    #[test]
    fn elems_serialization_widths() {
        let elems = [0x1122334455667788i64, -1];
        assert_eq!(elems_to_bytes(&elems, 1), vec![0x88, 0xFF]);
        assert_eq!(elems_to_bytes(&elems, 2), vec![0x88, 0x77, 0xFF, 0xFF]);
        assert_eq!(elems_to_bytes(&elems, 8).len(), 16);
    }

    #[test]
    fn cpu_only_expander_falls_back() {
        let ex = Expander::cpu_only();
        let runs = vec![RunRecord { init: 1, len: 4, delta: 1 }];
        let bytes = ex.expand(&runs, 1, 4).unwrap();
        assert_eq!(bytes, vec![1, 2, 3, 4]);
        assert_eq!(ex.stats.cpu_fallback.load(Ordering::Relaxed), 1);
        assert_eq!(ex.stats.pjrt.load(Ordering::Relaxed), 0);
    }
}
