//! PJRT executor: loads the AOT HLO artifacts and runs them.
//!
//! Follows the reference wiring (`/opt/xla-example/load_hlo`): parse HLO
//! *text* with `HloModuleProto::from_text_file` (jax ≥ 0.5 emits protos
//! with 64-bit ids that xla_extension 0.5.1 rejects; the text parser
//! reassigns them), wrap in an `XlaComputation`, compile on the PJRT CPU
//! client, and execute with concrete literals.
//!
//! One compiled executable per bucket; compilation happens once at
//! startup (`make artifacts` output is the contract — see
//! `python/compile/model.py` BUCKETS).
//!
//! The `xla` crate is only available in PJRT-enabled builds, so the
//! runtime comes in two interchangeable backends selected by the
//! off-by-default `pjrt` cargo feature:
//!
//! * **`pjrt` on** — the real [`PjrtRuntime`]/[`SharedRuntime`] backed by
//!   the PJRT CPU client (requires the `xla` dependency; see Cargo.toml).
//! * **`pjrt` off (default, offline)** — API-identical stubs whose
//!   [`PjrtRuntime::load`] fails cleanly; every caller already handles
//!   that path by falling back to the pure-Rust
//!   [`cpu_expand`](crate::runtime::cpu_expand) expansion, so the
//!   coordinator's expand path works with zero external dependencies.
//!
//! Manifest parsing and bucket naming are backend-independent and live
//! unconditionally in this module.

use crate::{invalid, Result};

/// Key identifying one compiled artifact.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum ArtifactKey {
    /// Run expansion with `n_runs` input slots and `m_out` output elems.
    Expand { n_runs: usize, m_out: usize },
    /// Delta scan over `n` elements.
    Delta { n: usize },
}

impl ArtifactKey {
    /// Human-readable name (matches the artifact file stem).
    pub fn name(&self) -> String {
        match self {
            ArtifactKey::Expand { n_runs, m_out } => format!("expand_n{n_runs}_m{m_out}"),
            ArtifactKey::Delta { n } => format!("delta_n{n}"),
        }
    }
}

/// A parsed manifest entry.
#[derive(Debug, Clone)]
pub struct ManifestEntry {
    /// Bucket key.
    pub key: ArtifactKey,
    /// HLO text file (relative to the artifacts dir).
    pub file: String,
}

/// Parse `artifacts/manifest.txt` (`kind n m file` per line).
pub fn parse_manifest(text: &str) -> Result<Vec<ManifestEntry>> {
    let mut out = Vec::new();
    for (lno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let f: Vec<&str> = line.split_whitespace().collect();
        if f.len() != 4 {
            return Err(invalid(format!("manifest line {}: expected 4 fields", lno + 1)));
        }
        let n: usize = f[1].parse().map_err(|_| invalid("manifest: bad n"))?;
        let m: usize = f[2].parse().map_err(|_| invalid("manifest: bad m"))?;
        let key = match f[0] {
            "expand" => ArtifactKey::Expand { n_runs: n, m_out: m },
            "delta" => ArtifactKey::Delta { n },
            other => return Err(invalid(format!("manifest: unknown kind {other}"))),
        };
        out.push(ManifestEntry { key, file: f[3].to_string() });
    }
    Ok(out)
}

#[cfg(feature = "pjrt")]
mod backend {
    //! The real PJRT backend (requires the `xla` crate).

    use super::{parse_manifest, ArtifactKey};
    use crate::{invalid, Error, Result};
    use std::collections::HashMap;
    use std::path::{Path, PathBuf};
    use std::sync::Mutex;

    /// The PJRT runtime: CPU client + compiled executables per bucket.
    ///
    /// Executions are serialized behind a mutex: the CPU PJRT client runs
    /// one computation at a time anyway, and the coordinator's dynamic
    /// batcher amortizes dispatch (see `coordinator::batcher`).
    pub struct PjrtRuntime {
        client: xla::PjRtClient,
        executables: HashMap<ArtifactKey, xla::PjRtLoadedExecutable>,
        exec_lock: Mutex<()>,
        /// Artifacts dir (for diagnostics).
        pub dir: PathBuf,
        /// Cumulative executions, for metrics.
        pub dispatches: std::sync::atomic::AtomicU64,
    }

    impl std::fmt::Debug for PjrtRuntime {
        fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
            f.debug_struct("PjrtRuntime")
                .field("dir", &self.dir)
                .field("executables", &self.executables.len())
                .finish()
        }
    }

    fn xla_err(e: xla::Error) -> Error {
        Error::Runtime(e.to_string())
    }

    impl PjrtRuntime {
        /// Load every artifact in `dir` (per its manifest) and compile.
        pub fn load(dir: impl AsRef<Path>) -> Result<PjrtRuntime> {
            let dir = dir.as_ref().to_path_buf();
            let manifest_path = dir.join("manifest.txt");
            let text = std::fs::read_to_string(&manifest_path).map_err(|e| {
                Error::Runtime(format!(
                    "cannot read {} (run `make artifacts` first): {e}",
                    manifest_path.display()
                ))
            })?;
            let entries = parse_manifest(&text)?;
            let client = xla::PjRtClient::cpu().map_err(xla_err)?;
            let mut executables = HashMap::new();
            for e in &entries {
                let path = dir.join(&e.file);
                let proto = xla::HloModuleProto::from_text_file(
                    path.to_str().ok_or_else(|| invalid("non-utf8 path"))?,
                )
                .map_err(xla_err)?;
                let comp = xla::XlaComputation::from_proto(&proto);
                let exe = client.compile(&comp).map_err(xla_err)?;
                executables.insert(e.key, exe);
            }
            Ok(PjrtRuntime {
                client,
                executables,
                exec_lock: Mutex::new(()),
                dir,
                dispatches: std::sync::atomic::AtomicU64::new(0),
            })
        }

        /// Buckets available, sorted.
        pub fn buckets(&self) -> Vec<ArtifactKey> {
            let mut v: Vec<ArtifactKey> = self.executables.keys().copied().collect();
            v.sort();
            v
        }

        /// PJRT platform name (diagnostics).
        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Execute the expand bucket: `starts` (i32, padded with i32::MAX),
        /// `values`/`deltas` (i64). Returns `m_out` i64 elements.
        pub fn run_expand(
            &self,
            key: ArtifactKey,
            starts: &[i32],
            values: &[i64],
            deltas: &[i64],
        ) -> Result<Vec<i64>> {
            let (n_runs, _m) = match key {
                ArtifactKey::Expand { n_runs, m_out } => (n_runs, m_out),
                _ => return Err(invalid("run_expand wants an Expand key")),
            };
            if starts.len() != n_runs || values.len() != n_runs || deltas.len() != n_runs {
                return Err(invalid(format!(
                    "bucket {} expects {n_runs} runs, got {}/{}/{}",
                    key.name(),
                    starts.len(),
                    values.len(),
                    deltas.len()
                )));
            }
            let exe = self
                .executables
                .get(&key)
                .ok_or_else(|| invalid(format!("no executable for {}", key.name())))?;
            let s = xla::Literal::vec1(starts);
            let v = xla::Literal::vec1(values);
            let d = xla::Literal::vec1(deltas);
            let _g = self.exec_lock.lock().unwrap();
            self.dispatches.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            let result = exe.execute::<xla::Literal>(&[s, v, d]).map_err(xla_err)?[0][0]
                .to_literal_sync()
                .map_err(xla_err)?;
            let out = result.to_tuple1().map_err(xla_err)?;
            out.to_vec::<i64>().map_err(xla_err)
        }

        /// Execute the delta bucket: scalar `base` and `n` deltas (padded
        /// with zeros). Returns `base + inclusive_cumsum(deltas)`.
        pub fn run_delta(&self, key: ArtifactKey, base: i64, deltas: &[i64]) -> Result<Vec<i64>> {
            let n = match key {
                ArtifactKey::Delta { n } => n,
                _ => return Err(invalid("run_delta wants a Delta key")),
            };
            if deltas.len() != n {
                return Err(invalid(format!("bucket {} expects {n} deltas", key.name())));
            }
            let exe = self
                .executables
                .get(&key)
                .ok_or_else(|| invalid(format!("no executable for {}", key.name())))?;
            let b = xla::Literal::vec1(&[base]);
            let d = xla::Literal::vec1(deltas);
            let _g = self.exec_lock.lock().unwrap();
            self.dispatches.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            let result = exe.execute::<xla::Literal>(&[b, d]).map_err(xla_err)?[0][0]
                .to_literal_sync()
                .map_err(xla_err)?;
            let out = result.to_tuple1().map_err(xla_err)?;
            out.to_vec::<i64>().map_err(xla_err)
        }
    }

    /// Thread-shareable wrapper around [`PjrtRuntime`].
    ///
    /// The `xla` crate's client/executable handles hold non-atomic `Rc`s
    /// and raw pointers, so they are neither `Send` nor `Sync`. Every
    /// access here goes through one mutex — the runtime is constructed
    /// inside the wrapper and no handle ever escapes it — so no `Rc` clone
    /// or PJRT call can race.
    ///
    /// # Safety
    /// Soundness rests on the invariants above: exclusive access enforced
    /// by the mutex, construction and drop on whichever single thread holds
    /// the lock, and the PJRT C API itself being thread-compatible.
    pub struct SharedRuntime {
        inner: Mutex<PjrtRuntime>,
    }

    unsafe impl Send for SharedRuntime {}
    unsafe impl Sync for SharedRuntime {}

    impl std::fmt::Debug for SharedRuntime {
        fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
            f.debug_struct("SharedRuntime").finish()
        }
    }

    impl SharedRuntime {
        /// Load artifacts (see [`PjrtRuntime::load`]).
        pub fn load(dir: impl AsRef<Path>) -> Result<SharedRuntime> {
            Ok(SharedRuntime { inner: Mutex::new(PjrtRuntime::load(dir)?) })
        }

        /// Available buckets.
        pub fn buckets(&self) -> Vec<ArtifactKey> {
            self.inner.lock().unwrap().buckets()
        }

        /// PJRT platform name.
        pub fn platform(&self) -> String {
            self.inner.lock().unwrap().platform()
        }

        /// Total PJRT dispatches so far.
        pub fn dispatches(&self) -> u64 {
            self.inner.lock().unwrap().dispatches.load(std::sync::atomic::Ordering::Relaxed)
        }

        /// Execute an expand bucket (see [`PjrtRuntime::run_expand`]).
        pub fn run_expand(
            &self,
            key: ArtifactKey,
            starts: &[i32],
            values: &[i64],
            deltas: &[i64],
        ) -> Result<Vec<i64>> {
            self.inner.lock().unwrap().run_expand(key, starts, values, deltas)
        }

        /// Execute a delta bucket (see [`PjrtRuntime::run_delta`]).
        pub fn run_delta(&self, key: ArtifactKey, base: i64, deltas: &[i64]) -> Result<Vec<i64>> {
            self.inner.lock().unwrap().run_delta(key, base, deltas)
        }
    }
}

#[cfg(not(feature = "pjrt"))]
mod backend {
    //! Offline stub backend: the same public surface as the PJRT backend
    //! with `load` failing cleanly. Callers (CLI `--hybrid`, the
    //! analytics example, `ablation_batching`) already treat a load
    //! failure as "no accelerator" and use the pure-Rust
    //! [`cpu_expand`](crate::runtime::cpu_expand) fallback, so the whole
    //! crate builds and serves without the `xla` dependency.

    use super::ArtifactKey;
    use crate::{Error, Result};
    use std::path::{Path, PathBuf};

    fn unavailable(dir: &Path) -> Error {
        Error::Runtime(format!(
            "PJRT runtime unavailable: codag was built without the `pjrt` feature \
             (artifacts dir {}); the CPU expand fallback handles all requests",
            dir.display()
        ))
    }

    /// Offline stand-in for the PJRT runtime. [`PjrtRuntime::load`]
    /// always fails; the remaining methods exist for API parity with the
    /// `pjrt` backend and are unreachable in practice.
    #[derive(Debug)]
    pub struct PjrtRuntime {
        /// Artifacts dir (for diagnostics).
        pub dir: PathBuf,
        /// Cumulative executions, for metrics (always 0 offline).
        pub dispatches: std::sync::atomic::AtomicU64,
    }

    impl PjrtRuntime {
        /// Fails: PJRT support is compiled out in this build.
        pub fn load(dir: impl AsRef<Path>) -> Result<PjrtRuntime> {
            Err(unavailable(dir.as_ref()))
        }

        /// No buckets in the offline build.
        pub fn buckets(&self) -> Vec<ArtifactKey> {
            Vec::new()
        }

        /// Stub platform label.
        pub fn platform(&self) -> String {
            "offline-stub".to_string()
        }

        /// Fails: no executables exist in the offline build.
        pub fn run_expand(
            &self,
            _key: ArtifactKey,
            _starts: &[i32],
            _values: &[i64],
            _deltas: &[i64],
        ) -> Result<Vec<i64>> {
            Err(unavailable(&self.dir))
        }

        /// Fails: no executables exist in the offline build.
        pub fn run_delta(&self, _key: ArtifactKey, _base: i64, _deltas: &[i64]) -> Result<Vec<i64>> {
            Err(unavailable(&self.dir))
        }
    }

    /// Offline stand-in for the thread-shareable runtime wrapper.
    #[derive(Debug)]
    pub struct SharedRuntime {
        inner: PjrtRuntime,
    }

    impl SharedRuntime {
        /// Fails: PJRT support is compiled out in this build.
        pub fn load(dir: impl AsRef<Path>) -> Result<SharedRuntime> {
            Ok(SharedRuntime { inner: PjrtRuntime::load(dir)? })
        }

        /// No buckets in the offline build.
        pub fn buckets(&self) -> Vec<ArtifactKey> {
            self.inner.buckets()
        }

        /// Stub platform label.
        pub fn platform(&self) -> String {
            self.inner.platform()
        }

        /// Always 0 offline.
        pub fn dispatches(&self) -> u64 {
            self.inner.dispatches.load(std::sync::atomic::Ordering::Relaxed)
        }

        /// Fails (see [`PjrtRuntime::run_expand`]).
        pub fn run_expand(
            &self,
            key: ArtifactKey,
            starts: &[i32],
            values: &[i64],
            deltas: &[i64],
        ) -> Result<Vec<i64>> {
            self.inner.run_expand(key, starts, values, deltas)
        }

        /// Fails (see [`PjrtRuntime::run_delta`]).
        pub fn run_delta(&self, key: ArtifactKey, base: i64, deltas: &[i64]) -> Result<Vec<i64>> {
            self.inner.run_delta(key, base, deltas)
        }
    }
}

pub use backend::{PjrtRuntime, SharedRuntime};

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_parsing() {
        let text = "expand 512 16384 expand_n512_m16384.hlo.txt\ndelta 4096 0 delta_n4096.hlo.txt\n";
        let m = parse_manifest(text).unwrap();
        assert_eq!(m.len(), 2);
        assert_eq!(m[0].key, ArtifactKey::Expand { n_runs: 512, m_out: 16384 });
        assert_eq!(m[1].key, ArtifactKey::Delta { n: 4096 });
        assert!(parse_manifest("bogus line\n").is_err());
        assert!(parse_manifest("expand x 2 f\n").is_err());
    }

    #[test]
    fn artifact_names() {
        assert_eq!(ArtifactKey::Expand { n_runs: 512, m_out: 16384 }.name(), "expand_n512_m16384");
        assert_eq!(ArtifactKey::Delta { n: 4096 }.name(), "delta_n4096");
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn offline_stub_fails_cleanly_and_keeps_api_parity() {
        let err = SharedRuntime::load("definitely/missing").unwrap_err();
        assert!(matches!(err, crate::Error::Runtime(_)), "{err:?}");
        assert!(err.to_string().contains("pjrt"), "{err}");
    }

    // PJRT-backed tests live in rust/tests/pjrt_roundtrip.rs (they need
    // `make artifacts` to have run).
}
