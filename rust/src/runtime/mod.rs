//! PJRT runtime: load + execute the AOT JAX/Pallas artifacts from Rust.
//!
//! Python runs once at `make artifacts`; after that the coordinator's
//! request path touches only this module: [`executor::PjrtRuntime`]
//! compiles the HLO-text artifacts on the PJRT CPU client at startup,
//! and [`expander::Expander`] dispatches decoded run tables to the
//! appropriate fixed-shape bucket (padding in, truncating out).
//!
//! The PJRT half is gated behind the off-by-default `pjrt` cargo
//! feature (the `xla` crate is unavailable offline); without it,
//! [`executor`] compiles API-identical stubs and every expand request
//! takes the pure-Rust [`cpu_expand`] fallback. See DESIGN.md §Runtime.

pub mod executor;
pub mod expander;

pub use executor::{ArtifactKey, PjrtRuntime, SharedRuntime};
pub use expander::{cpu_expand, Expander};

use std::path::PathBuf;

/// Default artifacts directory: `$CODAG_ARTIFACTS` or `./artifacts`.
pub fn default_artifacts_dir() -> PathBuf {
    std::env::var_os("CODAG_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}
