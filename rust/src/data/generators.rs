//! Statistical generators for the paper's seven evaluation datasets
//! (Table IV).
//!
//! The real datasets (Fannie-Mae mortgage, NYC taxi, Criteo 1TB,
//! Twitter COO, GRCh38) total ~27 GB and are not redistributable here;
//! per the substitution rule each generator reproduces the property
//! that drives the dataset's Table V behaviour — run-length structure,
//! alphabet, value distribution — at a configurable size. The Table V
//! bench (`reproduce_paper table5`) checks our ratios land in the
//! paper's regime (and documents where framing overheads differ).
//!
//! All generators are deterministic (splitmix64 seeded per dataset), so
//! every figure regenerates bit-identically.

/// Deterministic 64-bit RNG (splitmix64).
#[derive(Debug, Clone)]
pub struct Rng(u64);

impl Default for Rng {
    /// Zero-seeded stream (the Weyl increment drives it, so seed 0 is
    /// as good as any).
    fn default() -> Rng {
        Rng::new(0)
    }
}

impl Rng {
    /// Seeded RNG.
    pub fn new(seed: u64) -> Rng {
        Rng(seed.wrapping_add(0x9E37_79B9_7F4A_7C15))
    }

    /// Next u64.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.0 = self.0.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.0;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, n).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        self.next_u64() % n.max(1)
    }

    /// Uniform float in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Geometric-ish run length with the given mean (≥ 1).
    #[inline]
    pub fn run_len(&mut self, mean: f64) -> usize {
        let u = self.f64().max(1e-12);
        ((-u.ln() * mean).round() as usize).max(1)
    }

    /// Power-law value in [1, max) with exponent ~alpha.
    #[inline]
    pub fn power_law(&mut self, max: f64, alpha: f64) -> u64 {
        let u = self.f64().max(1e-12);
        (u.powf(-1.0 / alpha)).min(max) as u64
    }
}

/// One of the paper's seven datasets.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Dataset {
    /// Mortgage Col 0 — u64 analytics column with very long runs.
    Mc0,
    /// Mortgage Col 3 — f32 column (rates) with long runs.
    Mc3,
    /// NYC Taxi Passenger Count — int8 in 1..=6, barely any runs.
    Tpc,
    /// NYC Taxi Payment Type — char in a 2–4 symbol alphabet.
    Tpt,
    /// Criteo Dense 2 — u32, zero-inflated power law.
    Cd2,
    /// Twitter COO Col 1 — u64 source vertices, power-law out-degrees
    /// (long runs of the same id, ids monotonically increasing).
    Tc2,
    /// Human Reference Genome — ACGT(N) text with repeated motifs.
    Hrg,
}

impl Dataset {
    /// All datasets in the paper's reporting order (Table IV).
    pub fn all() -> [Dataset; 7] {
        [
            Dataset::Mc0,
            Dataset::Mc3,
            Dataset::Tpc,
            Dataset::Tpt,
            Dataset::Cd2,
            Dataset::Tc2,
            Dataset::Hrg,
        ]
    }

    /// Short name as the paper abbreviates it.
    pub fn name(&self) -> &'static str {
        match self {
            Dataset::Mc0 => "MC0",
            Dataset::Mc3 => "MC3",
            Dataset::Tpc => "TPC",
            Dataset::Tpt => "TPT",
            Dataset::Cd2 => "CD2",
            Dataset::Tc2 => "TC2",
            Dataset::Hrg => "HRG",
        }
    }

    /// Parse a CLI name.
    pub fn parse(s: &str) -> Option<Dataset> {
        Dataset::all().into_iter().find(|d| d.name().eq_ignore_ascii_case(s))
    }

    /// Application category (Table IV).
    pub fn category(&self) -> &'static str {
        match self {
            Dataset::Mc0 | Dataset::Mc3 | Dataset::Tpc | Dataset::Tpt => "Analytics",
            Dataset::Cd2 => "Recommenders",
            Dataset::Tc2 => "Graph",
            Dataset::Hrg => "Genomics",
        }
    }

    /// Element dtype label (Table IV).
    pub fn dtype(&self) -> &'static str {
        match self {
            Dataset::Mc0 => "uint_64",
            Dataset::Mc3 => "fp32",
            Dataset::Tpc => "int_8",
            Dataset::Tpt => "char",
            Dataset::Cd2 => "uint_32",
            Dataset::Tc2 => "uint_64",
            Dataset::Hrg => "char",
        }
    }

    /// Element width in bytes (drives the RLE codecs).
    pub fn width(&self) -> u8 {
        match self {
            Dataset::Mc0 | Dataset::Tc2 => 8,
            Dataset::Mc3 => 4,
            Dataset::Cd2 => 4,
            Dataset::Tpc | Dataset::Tpt | Dataset::Hrg => 1,
        }
    }

    /// Original size in GB (Table IV), for the table reproduction.
    pub fn paper_size_gb(&self) -> f64 {
        match self {
            Dataset::Mc0 => 4.86,
            Dataset::Mc3 => 2.43,
            Dataset::Tpc => 3.07,
            Dataset::Tpt => 7.41,
            Dataset::Cd2 => 0.73,
            Dataset::Tc2 => 5.47,
            Dataset::Hrg => 3.1,
        }
    }

    /// Generate ~`size_bytes` of this dataset (rounded down to a whole
    /// number of elements).
    pub fn generate(&self, size_bytes: usize) -> Vec<u8> {
        let mut rng = Rng::new(0xC0DA_6000 + *self as u64);
        let mut out = Vec::with_capacity(size_bytes);
        match self {
            // Long runs of small counters: loan-level attributes repeat
            // across monthly records. Mean run ≈ 30 elements.
            Dataset::Mc0 => {
                let mut v: u64 = 100_000;
                while out.len() + 8 <= size_bytes {
                    let run = rng.run_len(30.0).min(4000);
                    // Occasionally jump, mostly small increments.
                    v = if rng.below(10) == 0 {
                        rng.below(1 << 20)
                    } else {
                        v.wrapping_add(rng.below(5))
                    };
                    for _ in 0..run {
                        if out.len() + 8 > size_bytes {
                            break;
                        }
                        out.extend_from_slice(&v.to_le_bytes());
                    }
                }
            }
            // fp32 interest-rate-like column: a handful of distinct
            // values, runs ≈ 40.
            Dataset::Mc3 => {
                let rates: Vec<f32> =
                    (0..24).map(|i| 2.0 + 0.125 * i as f32).collect();
                while out.len() + 4 <= size_bytes {
                    let run = rng.run_len(40.0).min(4000);
                    let r = rates[rng.below(rates.len() as u64) as usize];
                    for _ in 0..run {
                        if out.len() + 4 > size_bytes {
                            break;
                        }
                        out.extend_from_slice(&r.to_bits().to_le_bytes());
                    }
                }
            }
            // Passenger counts 1..=6, skewed to 1 but anti-correlated
            // (consecutive trips rarely share a count in the stream
            // order ORC sees), so runs >= 3 are rare: avg symbol length
            // ~1.0 and ratio just under 1 (Table V: 1.00 / 0.867).
            Dataset::Tpc => {
                let mut prev = 0u8;
                while out.len() < size_bytes {
                    let r = rng.f64();
                    let mut v: u8 = if r < 0.70 {
                        1
                    } else if r < 0.85 {
                        2
                    } else {
                        3 + rng.below(4) as u8
                    };
                    // Redraw once when repeating, emulating interleaved
                    // trip records.
                    if v == prev && rng.f64() < 0.72 {
                        v = 1 + rng.below(6) as u8;
                    }
                    out.push(v);
                    prev = v;
                }
            }
            // Payment type: two dominant symbols (card/cash) with short
            // alternating runs — RLE v1 gains nothing (ratio ~1, paper
            // 1.41 incl. ORC stream overheads) while Deflate crushes it.
            Dataset::Tpt => {
                let mut prev = b'1';
                while out.len() < size_bytes {
                    // Alternation-biased two-symbol stream: P(repeat) is
                    // low enough that encodable runs (>= 3) are rare.
                    let v = if rng.f64() < 0.86 {
                        if prev == b'1' { b'2' } else { b'1' }
                    } else {
                        prev
                    };
                    out.push(v);
                    prev = v;
                }
            }
            // Zero-inflated power law u32 (dense ad features).
            Dataset::Cd2 => {
                while out.len() + 4 <= size_bytes {
                    if rng.f64() < 0.55 {
                        // Zero runs.
                        let run = rng.run_len(18.0).min(2000);
                        for _ in 0..run {
                            if out.len() + 4 > size_bytes {
                                break;
                            }
                            out.extend_from_slice(&0u32.to_le_bytes());
                        }
                    } else {
                        let v = rng.power_law(4e9, 1.3) as u32;
                        out.extend_from_slice(&v.to_le_bytes());
                    }
                }
            }
            // COO source column: vertex ids ascending, each repeated
            // out-degree times (power-law degrees) — long runs of equal
            // u64s plus monotonic structure for RLE v2's delta mode.
            Dataset::Tc2 => {
                let mut vid: u64 = 1;
                while out.len() + 8 <= size_bytes {
                    vid += 1 + rng.below(3);
                    let degree = rng.power_law(10_000.0, 1.2).max(1).min(3000);
                    for _ in 0..degree {
                        if out.len() + 8 > size_bytes {
                            break;
                        }
                        out.extend_from_slice(&vid.to_le_bytes());
                    }
                }
            }
            // Genome text: 4-symbol alphabet, N-runs at assembly gaps,
            // repeated motifs (transposable elements) that only
            // dictionary codecs exploit.
            Dataset::Hrg => {
                const BASES: [u8; 4] = [b'A', b'C', b'G', b'T'];
                // A motif bank to replay (LINE/SINE-like repeats).
                let motifs: Vec<Vec<u8>> = (0..8)
                    .map(|_| {
                        (0..300)
                            .map(|_| BASES[rng.below(4) as usize])
                            .collect()
                    })
                    .collect();
                while out.len() < size_bytes {
                    let r = rng.f64();
                    if r < 0.02 {
                        // Assembly gap: a run of 'N'.
                        let run = rng.run_len(500.0).min(size_bytes - out.len());
                        out.extend(std::iter::repeat(b'N').take(run));
                    } else if r < 0.25 {
                        // Replay a motif (with light mutation).
                        let m = &motifs[rng.below(motifs.len() as u64) as usize];
                        for &b in m {
                            if out.len() >= size_bytes {
                                break;
                            }
                            let b =
                                if rng.below(50) == 0 { BASES[rng.below(4) as usize] } else { b };
                            out.push(b);
                        }
                    } else {
                        // Fresh sequence.
                        let n = (50 + rng.below(400) as usize).min(size_bytes - out.len());
                        for _ in 0..n {
                            out.push(BASES[rng.below(4) as usize]);
                        }
                    }
                }
            }
        }
        // Exact sizing for width alignment.
        let w = self.width() as usize;
        out.truncate(size_bytes / w * w);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codecs::{compress_chunk_with, CodecKind};

    fn ratio(d: Dataset, kind: CodecKind) -> f64 {
        let data = d.generate(512 * 1024);
        let comp = compress_chunk_with(kind, &data, d.width()).unwrap();
        comp.len() as f64 / data.len() as f64
    }

    #[test]
    fn deterministic() {
        for d in Dataset::all() {
            assert_eq!(d.generate(4096), d.generate(4096), "{}", d.name());
        }
    }

    #[test]
    fn sizes_and_alignment() {
        for d in Dataset::all() {
            let data = d.generate(100_000);
            assert!(data.len() <= 100_000);
            assert_eq!(data.len() % d.width() as usize, 0);
            assert!(data.len() > 90_000, "{} produced {}", d.name(), data.len());
        }
    }

    #[test]
    fn mc0_highly_compressible_rle() {
        let r = ratio(Dataset::Mc0, CodecKind::RleV1);
        assert!(r < 0.08, "MC0 RLE v1 ratio {r} (paper 0.023)");
    }

    #[test]
    fn tpc_incompressible_rle_but_deflate_works() {
        let r1 = ratio(Dataset::Tpc, CodecKind::RleV1);
        let rd = ratio(Dataset::Tpc, CodecKind::Deflate);
        assert!(r1 > 0.7, "TPC RLE v1 ratio {r1} (paper 0.867)");
        assert!(rd < 0.35, "TPC Deflate ratio {rd} (paper 0.119)");
    }

    #[test]
    fn tpt_defeats_rle_deflate_crushes() {
        let r1 = ratio(Dataset::Tpt, CodecKind::RleV1);
        let rd = ratio(Dataset::Tpt, CodecKind::Deflate);
        assert!(r1 > 0.85, "TPT RLE v1 ratio {r1} (paper 1.41 w/ ORC overheads)");
        assert!(rd < 0.12, "TPT Deflate ratio {rd} (paper 0.042)");
    }

    #[test]
    fn tc2_rle_v2_beats_v1() {
        let r1 = ratio(Dataset::Tc2, CodecKind::RleV1);
        let r2 = ratio(Dataset::Tc2, CodecKind::RleV2);
        assert!(r1 < 0.25, "TC2 RLE v1 {r1} (paper 0.087)");
        assert!(r2 <= r1 * 1.1, "TC2 v2 {r2} should be <= v1 {r1}");
    }

    #[test]
    fn hrg_rle_useless_deflate_ok() {
        let r1 = ratio(Dataset::Hrg, CodecKind::RleV1);
        let rd = ratio(Dataset::Hrg, CodecKind::Deflate);
        assert!(r1 > 0.9, "HRG RLE v1 {r1} (paper 0.975)");
        assert!(rd < 0.55, "HRG Deflate {rd} (paper 0.305)");
    }

    #[test]
    fn parse_names() {
        assert_eq!(Dataset::parse("mc0"), Some(Dataset::Mc0));
        assert_eq!(Dataset::parse("HRG"), Some(Dataset::Hrg));
        assert_eq!(Dataset::parse("xyz"), None);
    }
}
