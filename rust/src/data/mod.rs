//! Evaluation datasets (Table IV) and their statistics.
//!
//! See [`generators`] for the substitution rationale: each generator
//! reproduces the statistical property that drives its real dataset's
//! compression behaviour (Table V) so every downstream figure sees the
//! same codec regimes the paper measured.

pub mod generators;

pub use generators::{Dataset, Rng};
