//! Text exposition of the metrics registry (Prometheus-style lines).
//!
//! Grammar (pinned by DESIGN.md §10): every non-comment line is
//! `name value` or `name{label="v",...} value` where `value` is an
//! unsigned decimal integer; lines starting with `#` are comments
//! (header + slowlog dump). Labels appear in the fixed order
//! `dataset`, then `stage`; datasets render in name order and stages
//! in pipeline order, so the output is byte-stable for a given
//! registry state.
//!
//! Conservation by construction: derived lines are computed from
//! counter values loaded *once* per render —
//! `codag_cache_gets_total = hits + misses` uses the same two loads
//! that the hit/miss lines print, and
//! `codag_daemon_decoded_bytes_total` sums the per-dataset
//! `codag_decoded_bytes_total` values as printed. A scrape taken in
//! the middle of concurrent load therefore always satisfies
//! `hits + misses == gets` and `sum(per-dataset bytes) == daemon
//! bytes` exactly, with no stop-the-world snapshot.

use std::collections::HashMap;
use std::fmt::Write as _;

use super::registry::{MetricsRegistry, Stage};
use super::slowlog::SlowLog;

/// Render the full exposition: per-dataset counters + per-stage
/// histograms, daemon-wide request histogram, and the slowlog as
/// trailing comment lines.
pub fn render(reg: &MetricsRegistry, slow: &SlowLog) -> String {
    let mut out = String::with_capacity(4096);
    out.push_str("# codag metrics exposition v1\n");
    let mut daemon_decoded: u64 = 0;
    for (name, m) in reg.snapshot() {
        // One load per counter; derived lines reuse these exact values.
        let hits = m.cache_hits.get();
        let misses = m.cache_misses.get();
        let decoded = m.decoded_bytes.get();
        daemon_decoded += decoded;
        let d = name.as_str();
        let _ = writeln!(out, "codag_requests_total{{dataset=\"{d}\"}} {}", m.requests.get());
        let _ = writeln!(out, "codag_busy_total{{dataset=\"{d}\"}} {}", m.busy.get());
        let _ = writeln!(out, "codag_expired_total{{dataset=\"{d}\"}} {}", m.expired.get());
        let _ = writeln!(out, "codag_inflight{{dataset=\"{d}\"}} {}", m.inflight.get());
        let _ = writeln!(out, "codag_cache_hits_total{{dataset=\"{d}\"}} {hits}");
        let _ = writeln!(out, "codag_cache_misses_total{{dataset=\"{d}\"}} {misses}");
        let _ = writeln!(out, "codag_cache_gets_total{{dataset=\"{d}\"}} {}", hits + misses);
        let _ = writeln!(out, "codag_decoded_bytes_total{{dataset=\"{d}\"}} {decoded}");
        let _ = writeln!(
            out,
            "codag_integrity_failures_total{{dataset=\"{d}\"}} {}",
            m.integrity_failures.get()
        );
        for s in Stage::all() {
            let h = m.stage(s);
            let sn = s.name();
            let _ = writeln!(
                out,
                "codag_stage_count{{dataset=\"{d}\",stage=\"{sn}\"}} {}",
                h.count()
            );
            let _ = writeln!(
                out,
                "codag_stage_sum_us{{dataset=\"{d}\",stage=\"{sn}\"}} {}",
                h.sum_us()
            );
            let _ = writeln!(
                out,
                "codag_stage_p50_us{{dataset=\"{d}\",stage=\"{sn}\"}} {}",
                h.percentile_us(50.0)
            );
            let _ = writeln!(
                out,
                "codag_stage_p99_us{{dataset=\"{d}\",stage=\"{sn}\"}} {}",
                h.percentile_us(99.0)
            );
        }
    }
    let _ = writeln!(out, "codag_daemon_decoded_bytes_total {daemon_decoded}");
    let req = reg.request_us();
    let _ = writeln!(out, "codag_request_count {}", req.count());
    let _ = writeln!(out, "codag_request_mean_us {}", req.mean_us());
    let _ = writeln!(out, "codag_request_p50_us {}", req.percentile_us(50.0));
    let _ = writeln!(out, "codag_request_p99_us {}", req.percentile_us(99.0));
    // Network-front block (DESIGN.md §10): rendered unconditionally so
    // the name set is identical under both net models — a threaded
    // daemon simply reports ring depths of 0 and an empty loop histo.
    let net = reg.net();
    let _ = writeln!(out, "codag_connections_open {}", net.connections_open.get());
    let _ = writeln!(out, "codag_submission_ring_depth {}", net.submission_ring_depth.get());
    let _ = writeln!(out, "codag_completion_ring_depth {}", net.completion_ring_depth.get());
    let _ = writeln!(out, "codag_net_loop_count {}", net.net_loop_us.count());
    let _ = writeln!(out, "codag_net_loop_mean_us {}", net.net_loop_us.mean_us());
    let _ = writeln!(out, "codag_net_loop_p50_us {}", net.net_loop_us.percentile_us(50.0));
    let _ = writeln!(out, "codag_net_loop_p99_us {}", net.net_loop_us.percentile_us(99.0));
    for e in slow.snapshot() {
        let mut stages = String::new();
        for (i, (s, at)) in e.stages.iter().enumerate() {
            if i > 0 {
                stages.push(',');
            }
            let _ = write!(stages, "{}:{at}", s.name());
        }
        let _ = writeln!(
            out,
            "# slowlog id={} dataset=\"{}\" total_us={} stages={stages}",
            e.id, e.dataset, e.total_us
        );
    }
    out
}

/// Parse an exposition back into a `full-line-key -> value` map, where
/// the key is everything before the final space (`name` or
/// `name{labels}`). Comment and blank lines are skipped. Used by the
/// conservation tests and `loadgen --scrape` summaries.
pub fn parse(text: &str) -> HashMap<String, u64> {
    text.lines()
        .filter(|l| !l.is_empty() && !l.starts_with('#'))
        .filter_map(|l| {
            let (key, val) = l.rsplit_once(' ')?;
            Some((key.to_string(), val.parse().ok()?))
        })
        .collect()
}

/// Convenience lookup for `name{dataset="..."}` lines.
pub fn get_dataset(map: &HashMap<String, u64>, name: &str, dataset: &str) -> Option<u64> {
    map.get(&format!("{name}{{dataset=\"{dataset}\"}}")).copied()
}

/// Convenience lookup for `name{dataset="...",stage="..."}` lines.
pub fn get_stage(
    map: &HashMap<String, u64>,
    name: &str,
    dataset: &str,
    stage: Stage,
) -> Option<u64> {
    map.get(&format!("{name}{{dataset=\"{dataset}\",stage=\"{}\"}}", stage.name())).copied()
}

#[cfg(all(test, feature = "obs"))]
mod tests {
    use super::*;
    use crate::obs::slowlog::SlowEntry;

    fn sample() -> (MetricsRegistry, SlowLog) {
        let reg = MetricsRegistry::new();
        let m = reg.dataset("alpha");
        m.requests.add(10);
        m.cache_hits.add(7);
        m.cache_misses.add(3);
        m.decoded_bytes.add(4096);
        m.integrity_failures.add(2);
        m.stage(Stage::QueueWait).record_us(12);
        m.stage(Stage::DecodeSerial).record_us(200);
        let b = reg.dataset("beta");
        b.decoded_bytes.add(1024);
        reg.request_us().record_us(250);
        reg.net().connections_open.inc();
        reg.net().connections_open.inc();
        reg.net().submission_ring_depth.inc();
        reg.net().net_loop_us.record_us(40);
        let slow = SlowLog::new(4);
        slow.offer(SlowEntry {
            id: 3,
            dataset: "alpha".to_string(),
            total_us: 250,
            stages: vec![(Stage::QueueWait, 12), (Stage::ResponseWrite, 250)],
        });
        (reg, slow)
    }

    #[test]
    fn render_parse_roundtrip_and_derived_invariants() {
        let (reg, slow) = sample();
        let text = render(&reg, &slow);
        let map = parse(&text);
        assert_eq!(get_dataset(&map, "codag_requests_total", "alpha"), Some(10));
        assert_eq!(get_dataset(&map, "codag_cache_hits_total", "alpha"), Some(7));
        assert_eq!(get_dataset(&map, "codag_cache_misses_total", "alpha"), Some(3));
        // Derived: gets == hits + misses, by construction.
        assert_eq!(get_dataset(&map, "codag_cache_gets_total", "alpha"), Some(10));
        // Derived: daemon-wide decoded bytes == sum of per-dataset.
        assert_eq!(map["codag_daemon_decoded_bytes_total"], 4096 + 1024);
        // Integrity counter renders for every dataset (zero when clean).
        assert_eq!(get_dataset(&map, "codag_integrity_failures_total", "alpha"), Some(2));
        assert_eq!(get_dataset(&map, "codag_integrity_failures_total", "beta"), Some(0));
        assert_eq!(get_dataset(&map, "codag_decoded_bytes_total", "beta"), Some(1024));
        assert_eq!(
            get_stage(&map, "codag_stage_count", "alpha", Stage::DecodeSerial),
            Some(1)
        );
        assert_eq!(
            get_stage(&map, "codag_stage_p50_us", "alpha", Stage::DecodeSerial),
            Some(255), // bucket upper bound of 200
        );
        assert_eq!(map["codag_request_count"], 1);
        // Every stage of every dataset renders even at count 0 — the
        // name set is stable for scrapers/greps.
        assert_eq!(get_stage(&map, "codag_stage_count", "beta", Stage::StitchJoin), Some(0));
        // Net-front lines render under both net models (depths 0 /
        // empty histo when threaded), so their presence is pinned.
        assert_eq!(map["codag_connections_open"], 2);
        assert_eq!(map["codag_submission_ring_depth"], 1);
        assert_eq!(map["codag_completion_ring_depth"], 0);
        assert_eq!(map["codag_net_loop_count"], 1);
        assert_eq!(map["codag_net_loop_p50_us"], 63); // bucket bound of 40
    }

    #[test]
    fn output_is_stable_for_a_fixed_registry() {
        let (reg, slow) = sample();
        assert_eq!(render(&reg, &slow), render(&reg, &slow));
        // Datasets render name-sorted.
        let text = render(&reg, &slow);
        let alpha = text.find("dataset=\"alpha\"").unwrap();
        let beta = text.find("dataset=\"beta\"").unwrap();
        assert!(alpha < beta);
    }

    #[test]
    fn slowlog_renders_as_comment_lines() {
        let (reg, slow) = sample();
        let text = render(&reg, &slow);
        let line = text
            .lines()
            .find(|l| l.starts_with("# slowlog "))
            .expect("slowlog comment line");
        assert!(line.contains("id=3"));
        assert!(line.contains("dataset=\"alpha\""));
        assert!(line.contains("stages=queue_wait:12,response_write:250"));
        // Comment lines must not pollute the parsed map.
        assert!(parse(&text).keys().all(|k| !k.contains("slowlog")));
    }
}
