//! Stage-level observability for the serving stack (DESIGN.md §10).
//!
//! CODAG's central lesson is that throughput claims are only
//! trustworthy when the measurement shows *where time goes*; this
//! module gives the daemon that breakdown. It is std-only and built
//! from three layers:
//!
//! - [`histo`] — lock-free primitives: [`Counter`], [`Gauge`], and the
//!   64-slot log2-bucketed [`LatencyHisto`] (O(1) wait-free record,
//!   mergeable, allocation-free after startup).
//! - [`registry`] — [`MetricsRegistry`] keyed by `(dataset, stage)`;
//!   [`Stage`] covers the full request lifecycle from admission to
//!   response write, including the parallel-stitch fan-out/join split.
//! - [`slowlog`] + [`expo`] — a bounded ring of the N slowest requests
//!   with per-stage breakdowns, and the stable text exposition served
//!   by the wire `Metrics` request kind / `codag stat`.
//!
//! Recording is compiled out (no-op bodies, identical APIs) when the
//! default `obs` cargo feature is disabled; the measured overhead of
//! leaving it on is tracked in EXPERIMENTS.md.

pub mod expo;
pub mod histo;
pub mod registry;
pub mod slowlog;

pub use histo::{now_if_enabled, Counter, Gauge, LatencyHisto, StitchTimers, ENABLED, HISTO_BUCKETS};
pub use registry::{DatasetMetrics, MetricsRegistry, NetMetrics, Stage, STAGES};
pub use slowlog::{SlowEntry, SlowLog, SLOWLOG_CAP};
