//! Per-(dataset, stage) metrics registry.
//!
//! [`MetricsRegistry`] hands out one [`DatasetMetrics`] per dataset
//! name (get-or-create behind an `RwLock`, read-path fast once a
//! dataset is warm); each holds a fixed array of [`LatencyHisto`]s
//! indexed by [`Stage`] plus the request/cache/byte counters the
//! conservation invariants in DESIGN.md §10 are stated over. The lock
//! guards only the `HashMap` of `Arc`s — recording into a resolved
//! `Arc<DatasetMetrics>` is lock-free.

use std::collections::HashMap;
use std::sync::{Arc, RwLock};

use super::histo::{Counter, Gauge, LatencyHisto, StitchTimers};

/// Number of lifecycle stages ([`Stage::all`]).
pub const STAGES: usize = 9;

/// Request lifecycle stages, in pipeline order. Names (snake_case,
/// [`Stage::name`]) are part of the wire exposition contract — see
/// DESIGN.md §10 before renaming anything.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Stage {
    /// Request decode + validation + shard-queue enqueue in the
    /// connection reader thread.
    Admission = 0,
    /// Enqueue → shard-worker dequeue.
    QueueWait = 1,
    /// Chunk cache probe (hit or miss).
    CacheLookup = 2,
    /// Ghost-LRU admission + insert of a decoded chunk.
    CacheAdmit = 3,
    /// Positioned compressed-chunk read in `FileDataset`.
    FileRead = 4,
    /// Single-threaded whole-chunk decode.
    DecodeSerial = 5,
    /// Parallel stitch: entry → sub-block jobs carved and spawned.
    StitchFanout = 6,
    /// Parallel stitch: spawn-complete → all workers joined.
    StitchJoin = 7,
    /// Response frame write on the connection writer thread.
    ResponseWrite = 8,
}

impl Stage {
    pub fn all() -> [Stage; STAGES] {
        [
            Stage::Admission,
            Stage::QueueWait,
            Stage::CacheLookup,
            Stage::CacheAdmit,
            Stage::FileRead,
            Stage::DecodeSerial,
            Stage::StitchFanout,
            Stage::StitchJoin,
            Stage::ResponseWrite,
        ]
    }

    pub fn name(self) -> &'static str {
        match self {
            Stage::Admission => "admission",
            Stage::QueueWait => "queue_wait",
            Stage::CacheLookup => "cache_lookup",
            Stage::CacheAdmit => "cache_admit",
            Stage::FileRead => "file_read",
            Stage::DecodeSerial => "decode_serial",
            Stage::StitchFanout => "stitch_fanout",
            Stage::StitchJoin => "stitch_join",
            Stage::ResponseWrite => "response_write",
        }
    }
}

/// All metrics for one dataset: a [`LatencyHisto`] per [`Stage`] plus
/// the counters the exposition derives its conservation lines from.
#[derive(Debug, Default)]
pub struct DatasetMetrics {
    stages: [LatencyHisto; STAGES],
    /// Get requests admitted to a shard queue.
    pub requests: Counter,
    /// Get requests rejected with `Busy` (queue full / over budget).
    pub busy: Counter,
    /// Get requests dropped at dequeue because their deadline passed.
    pub expired: Counter,
    /// Chunk-cache lookups that hit.
    pub cache_hits: Counter,
    /// Chunk-cache lookups that missed (chunk was decoded).
    pub cache_misses: Counter,
    /// Uncompressed bytes produced by cache-miss decodes.
    pub decoded_bytes: Counter,
    /// Decodes whose output failed content-checksum verification
    /// (`Error::ChecksumMismatch`), including `--paranoid` re-checks of
    /// cache hits. Zero on a healthy daemon — the conservation tests
    /// pin that.
    pub integrity_failures: Counter,
    /// Requests admitted but not yet replied to.
    pub inflight: Gauge,
}

impl DatasetMetrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn stage(&self, s: Stage) -> &LatencyHisto {
        &self.stages[s as usize]
    }

    /// The fan-out/join histogram pair for the parallel stitcher.
    pub fn stitch_timers(&self) -> StitchTimers<'_> {
        StitchTimers {
            fanout: self.stage(Stage::StitchFanout),
            join: self.stage(Stage::StitchJoin),
        }
    }
}

/// Daemon-wide network-front metrics (DESIGN.md §10): these are not a
/// [`Stage`] — stages are per-(dataset, request) lifecycle phases,
/// while these describe the daemon's connection fabric as a whole —
/// so the `STAGES` array and its pinned name set stay untouched.
#[derive(Debug, Default)]
pub struct NetMetrics {
    /// Currently accepted, not-yet-closed connections (both net
    /// models).
    pub connections_open: Gauge,
    /// Requests sitting in shard submission rings, admitted but not
    /// yet dequeued by a worker (evented model only; 0 under
    /// `--net-model threads`).
    pub submission_ring_depth: Gauge,
    /// Responses sitting in shard completion rings, produced but not
    /// yet collected by the net loop (evented model only).
    pub completion_ring_depth: Gauge,
    /// Net-loop iteration processing time (poll(2) return → all ready
    /// events handled), recorded only for iterations that had ready
    /// events — idle ticks would drown the signal.
    pub net_loop_us: LatencyHisto,
}

/// Daemon-wide registry: per-dataset metrics keyed by name, plus one
/// daemon-wide end-to-end request histogram (receipt → reply built)
/// that the shutdown summary reports from, plus the network-front
/// gauges.
#[derive(Debug, Default)]
pub struct MetricsRegistry {
    datasets: RwLock<HashMap<String, Arc<DatasetMetrics>>>,
    request_us: LatencyHisto,
    net: NetMetrics,
}

impl MetricsRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    /// Get-or-create the metrics handle for `name`. Callers on hot
    /// paths should resolve once per request/batch and record through
    /// the returned `Arc`.
    pub fn dataset(&self, name: &str) -> Arc<DatasetMetrics> {
        if let Some(m) = self.datasets.read().unwrap().get(name) {
            return Arc::clone(m);
        }
        let mut w = self.datasets.write().unwrap();
        Arc::clone(w.entry(name.to_string()).or_default())
    }

    /// Daemon-wide end-to-end request latency histogram.
    pub fn request_us(&self) -> &LatencyHisto {
        &self.request_us
    }

    /// Daemon-wide network-front metrics.
    pub fn net(&self) -> &NetMetrics {
        &self.net
    }

    /// Name-sorted snapshot of every dataset's metrics handle; the
    /// exposition iterates this so output ordering is stable.
    pub fn snapshot(&self) -> Vec<(String, Arc<DatasetMetrics>)> {
        let mut v: Vec<_> = self
            .datasets
            .read()
            .unwrap()
            .iter()
            .map(|(k, m)| (k.clone(), Arc::clone(m)))
            .collect();
        v.sort_by(|a, b| a.0.cmp(&b.0));
        v
    }
}

#[cfg(all(test, feature = "obs"))]
mod tests {
    use super::*;

    #[test]
    fn stage_names_and_order_pinned() {
        let names: Vec<_> = Stage::all().iter().map(|s| s.name()).collect();
        assert_eq!(
            names,
            [
                "admission",
                "queue_wait",
                "cache_lookup",
                "cache_admit",
                "file_read",
                "decode_serial",
                "stitch_fanout",
                "stitch_join",
                "response_write",
            ]
        );
        for (i, s) in Stage::all().into_iter().enumerate() {
            assert_eq!(s as usize, i, "discriminant order");
        }
    }

    #[test]
    fn registry_returns_same_handle_per_dataset() {
        let reg = MetricsRegistry::new();
        let a1 = reg.dataset("alpha");
        let a2 = reg.dataset("alpha");
        let b = reg.dataset("beta");
        assert!(Arc::ptr_eq(&a1, &a2));
        assert!(!Arc::ptr_eq(&a1, &b));
        a1.requests.inc();
        assert_eq!(a2.requests.get(), 1, "shared handle");
        let snap = reg.snapshot();
        let names: Vec<_> = snap.iter().map(|(n, _)| n.as_str()).collect();
        assert_eq!(names, ["alpha", "beta"], "sorted snapshot");
    }

    #[test]
    fn net_metrics_live_beside_stages_not_in_them() {
        // NetMetrics must not grow the pinned Stage set; it hangs off
        // the registry directly and is shared across datasets.
        let reg = MetricsRegistry::new();
        reg.net().connections_open.inc();
        reg.net().submission_ring_depth.inc();
        reg.net().submission_ring_depth.dec();
        reg.net().net_loop_us.record_us(15);
        assert_eq!(reg.net().connections_open.get(), 1);
        assert_eq!(reg.net().submission_ring_depth.get(), 0);
        assert_eq!(reg.net().completion_ring_depth.get(), 0);
        assert_eq!(reg.net().net_loop_us.count(), 1);
        assert_eq!(Stage::all().len(), STAGES, "stage set unchanged");
    }

    #[test]
    fn stage_histograms_are_independent() {
        let m = DatasetMetrics::new();
        m.stage(Stage::QueueWait).record_us(5);
        m.stage(Stage::DecodeSerial).record_us(7);
        assert_eq!(m.stage(Stage::QueueWait).count(), 1);
        assert_eq!(m.stage(Stage::DecodeSerial).count(), 1);
        assert_eq!(m.stage(Stage::CacheLookup).count(), 0);
        let t = m.stitch_timers();
        t.fanout.record_us(1);
        t.join.record_us(2);
        assert_eq!(m.stage(Stage::StitchFanout).count(), 1);
        assert_eq!(m.stage(Stage::StitchJoin).count(), 1);
    }
}
