//! Bounded slowlog: the N slowest recent requests with per-stage
//! breakdowns.
//!
//! [`SlowLog::offer`] keeps the entries sorted descending by total
//! latency and evicts the fastest entry when full, so the ring always
//! holds the N slowest requests seen so far. Offers take a short
//! mutex — one lock per *completed request*, not per stage sample —
//! and bail without locking when the candidate cannot displace the
//! current minimum is checked under the same lock (the vector is
//! tiny, default cap 16).

use std::sync::Mutex;

use super::registry::Stage;

/// Default number of retained slowest requests.
pub const SLOWLOG_CAP: usize = 16;

/// One slow request: identity plus cumulative per-stage timestamps.
#[derive(Clone, Debug)]
pub struct SlowEntry {
    /// Wire request id.
    pub id: u64,
    pub dataset: String,
    /// End-to-end latency, receipt → reply handed to the writer.
    pub total_us: u64,
    /// `(stage, cumulative_us)` pairs in pipeline order: each value is
    /// the microsecond offset *from request receipt* at which that
    /// stage finished, so a well-formed entry is monotone
    /// non-decreasing (asserted by the conservation integration test).
    pub stages: Vec<(Stage, u64)>,
}

/// Bounded ring of the slowest requests, ordered slowest-first.
#[derive(Debug)]
pub struct SlowLog {
    cap: usize,
    entries: Mutex<Vec<SlowEntry>>,
}

impl SlowLog {
    pub fn new(cap: usize) -> Self {
        Self { cap, entries: Mutex::new(Vec::with_capacity(cap)) }
    }

    /// Offer a completed request; it is retained only if the log has
    /// room or it is slower than the current fastest retained entry.
    pub fn offer(&self, e: SlowEntry) {
        if !super::histo::ENABLED || self.cap == 0 {
            return;
        }
        let mut g = self.entries.lock().unwrap();
        if g.len() == self.cap {
            // Sorted descending: the last entry is the fastest.
            if g.last().is_some_and(|min| min.total_us >= e.total_us) {
                return;
            }
            g.pop();
        }
        let pos = g.partition_point(|x| x.total_us >= e.total_us);
        g.insert(pos, e);
    }

    /// Snapshot, slowest first.
    pub fn snapshot(&self) -> Vec<SlowEntry> {
        self.entries.lock().unwrap().clone()
    }

    pub fn len(&self) -> usize {
        self.entries.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(all(test, feature = "obs"))]
mod tests {
    use super::*;

    fn entry(id: u64, total_us: u64) -> SlowEntry {
        SlowEntry {
            id,
            dataset: "ds".to_string(),
            total_us,
            stages: vec![
                (Stage::QueueWait, total_us / 4),
                (Stage::DecodeSerial, total_us / 2),
                (Stage::ResponseWrite, total_us),
            ],
        }
    }

    #[test]
    fn keeps_the_n_slowest_sorted_descending() {
        let log = SlowLog::new(4);
        for (id, us) in [(1, 50), (2, 10), (3, 90), (4, 30), (5, 70), (6, 20)] {
            log.offer(entry(id, us));
        }
        let snap = log.snapshot();
        let totals: Vec<_> = snap.iter().map(|e| e.total_us).collect();
        assert_eq!(totals, [90, 70, 50, 30], "four slowest, slowest first");
        let ids: Vec<_> = snap.iter().map(|e| e.id).collect();
        assert_eq!(ids, [3, 5, 1, 4]);
    }

    #[test]
    fn fast_requests_do_not_displace_slow_ones() {
        let log = SlowLog::new(2);
        log.offer(entry(1, 100));
        log.offer(entry(2, 200));
        log.offer(entry(3, 50));
        let totals: Vec<_> = log.snapshot().iter().map(|e| e.total_us).collect();
        assert_eq!(totals, [200, 100]);
    }

    #[test]
    fn zero_cap_log_stays_empty() {
        let log = SlowLog::new(0);
        log.offer(entry(1, 100));
        assert!(log.is_empty());
    }

    #[test]
    fn entry_stages_are_monotone() {
        let e = entry(1, 400);
        let mut prev = 0;
        for (_, at) in &e.stages {
            assert!(*at >= prev);
            prev = *at;
        }
    }
}
