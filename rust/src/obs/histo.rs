//! Lock-free observability primitives: [`Counter`], [`Gauge`], and the
//! 64-slot log2-bucketed [`LatencyHisto`].
//!
//! Everything here is wait-free on the record path (one or two relaxed
//! atomic RMWs), allocation-free after construction, and mergeable, so
//! shard workers and connection threads record into shared registries
//! without taking a lock. When the `obs` cargo feature is disabled
//! every record method compiles to a no-op behind [`ENABLED`] — the
//! types and read APIs stay, so call sites need no `cfg` — which is
//! the "compiled-out" half of the instrumentation-overhead baseline in
//! EXPERIMENTS.md.
//!
//! Bucket layout (pinned by DESIGN.md §10): bucket 0 holds exact-zero
//! samples; bucket `i` (1 ≤ i ≤ 62) holds values in
//! `[2^(i-1), 2^i - 1]`; bucket 63 holds everything from `2^62` up.
//! [`LatencyHisto::percentile_us`] returns the *upper bound* of the
//! bucket containing the requested rank, so a reported percentile `h`
//! for a true value `v` satisfies `v <= h < 2*v` — a ≤2× resolution
//! bound, cross-checked against the exact reservoir in
//! `histo_percentiles_track_reservoir_within_bucket_resolution`.

use std::sync::atomic::{AtomicU64, Ordering::Relaxed};
use std::time::Duration;

/// Compile-time master switch: `true` iff the `obs` cargo feature
/// (default-on) is enabled. Record paths branch on this const so the
/// optimizer deletes them entirely in `--no-default-features` builds.
pub const ENABLED: bool = cfg!(feature = "obs");

/// Fixed bucket count of [`LatencyHisto`]; covers the full `u64`
/// microsecond range in powers of two.
pub const HISTO_BUCKETS: usize = 64;

/// `Some(Instant::now())` when recording is compiled in, `None`
/// otherwise — instrumentation sites branch on this so a compiled-out
/// build takes no clock reads at all.
pub fn now_if_enabled() -> Option<std::time::Instant> {
    ENABLED.then(std::time::Instant::now)
}

/// Monotonically-increasing atomic counter.
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn new() -> Self {
        Self(AtomicU64::new(0))
    }

    pub fn add(&self, n: u64) {
        if ENABLED {
            self.0.fetch_add(n, Relaxed);
        }
    }

    pub fn inc(&self) {
        self.add(1);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Relaxed)
    }
}

/// Up/down gauge (e.g. in-flight requests). `dec` saturates at zero so
/// a racing scrape can never observe a wrapped near-`u64::MAX` value.
#[derive(Debug, Default)]
pub struct Gauge(AtomicU64);

impl Gauge {
    pub fn new() -> Self {
        Self(AtomicU64::new(0))
    }

    pub fn inc(&self) {
        if ENABLED {
            self.0.fetch_add(1, Relaxed);
        }
    }

    pub fn dec(&self) {
        if ENABLED {
            // fetch_update to saturate rather than wrap on a stray
            // double-decrement.
            let _ = self.0.fetch_update(Relaxed, Relaxed, |v| Some(v.saturating_sub(1)));
        }
    }

    pub fn get(&self) -> u64 {
        self.0.load(Relaxed)
    }
}

/// Bucket index for a microsecond value: 0 for 0, else
/// `min(64 - leading_zeros(v), 63)` so bucket `i` covers
/// `[2^(i-1), 2^i - 1]`.
pub fn bucket_index(v: u64) -> usize {
    if v == 0 {
        0
    } else {
        (64 - v.leading_zeros() as usize).min(HISTO_BUCKETS - 1)
    }
}

/// Inclusive upper bound of bucket `i`, used as the reported
/// percentile value (hence the ≤2× resolution bound).
pub fn bucket_upper_bound_us(i: usize) -> u64 {
    match i {
        0 => 0,
        _ if i >= HISTO_BUCKETS - 1 => u64::MAX,
        _ => (1u64 << i) - 1,
    }
}

/// Fixed-size log2-bucketed latency histogram.
///
/// O(1) wait-free record path (a leading-zeros and three relaxed
/// `fetch_add`s), no allocation after construction, mergeable across
/// instances. Unlike `LatencyStats`' reservoir there is no sampling:
/// every recorded value lands in exactly one bucket, so counts are
/// exact and conserved — only the *value* resolution is quantized.
#[derive(Debug)]
pub struct LatencyHisto {
    buckets: [AtomicU64; HISTO_BUCKETS],
    count: AtomicU64,
    sum_us: AtomicU64,
}

impl Default for LatencyHisto {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHisto {
    pub fn new() -> Self {
        Self {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum_us: AtomicU64::new(0),
        }
    }

    /// Record one microsecond sample. No-op when `obs` is compiled out.
    pub fn record_us(&self, us: u64) {
        if ENABLED {
            self.buckets[bucket_index(us)].fetch_add(1, Relaxed);
            self.count.fetch_add(1, Relaxed);
            self.sum_us.fetch_add(us, Relaxed);
        }
    }

    /// Record an elapsed [`Duration`] (saturating to `u64` µs).
    pub fn record(&self, d: Duration) {
        self.record_us(u64::try_from(d.as_micros()).unwrap_or(u64::MAX));
    }

    pub fn count(&self) -> u64 {
        self.count.load(Relaxed)
    }

    pub fn sum_us(&self) -> u64 {
        self.sum_us.load(Relaxed)
    }

    pub fn mean_us(&self) -> u64 {
        let n = self.count();
        if n == 0 {
            0
        } else {
            self.sum_us() / n
        }
    }

    /// Fold `other`'s samples into `self` (bucket-wise atomic adds).
    pub fn merge(&self, other: &LatencyHisto) {
        for (mine, theirs) in self.buckets.iter().zip(&other.buckets) {
            let n = theirs.load(Relaxed);
            if n != 0 {
                mine.fetch_add(n, Relaxed);
            }
        }
        self.count.fetch_add(other.count.load(Relaxed), Relaxed);
        self.sum_us.fetch_add(other.sum_us.load(Relaxed), Relaxed);
    }

    /// Relaxed snapshot of the bucket counts (for exposition).
    pub fn snapshot_buckets(&self) -> [u64; HISTO_BUCKETS] {
        std::array::from_fn(|i| self.buckets[i].load(Relaxed))
    }

    /// Percentile estimate: the upper bound of the bucket holding the
    /// requested rank (same nearest-rank convention as
    /// `LatencyStats::percentile_us`). Returns 0 on an empty histogram.
    pub fn percentile_us(&self, p: f64) -> u64 {
        let counts = self.snapshot_buckets();
        let total: u64 = counts.iter().sum();
        if total == 0 {
            return 0;
        }
        let rank = ((p.clamp(0.0, 100.0) / 100.0) * ((total - 1) as f64)).round() as u64;
        let mut cum = 0u64;
        for (i, &c) in counts.iter().enumerate() {
            cum += c;
            if cum > rank {
                return bucket_upper_bound_us(i);
            }
        }
        bucket_upper_bound_us(HISTO_BUCKETS - 1)
    }
}

/// Borrowed fan-out/join histogram pair threaded into
/// `engine::decode_chunk_parallel` so the stitcher can time its two
/// phases without depending on the registry types.
#[derive(Clone, Copy)]
pub struct StitchTimers<'a> {
    /// Entry → all sub-block jobs carved and spawned (serial fallback
    /// records its whole decode loop here).
    pub fanout: &'a LatencyHisto,
    /// Spawn-complete → all stitch workers joined.
    pub join: &'a LatencyHisto,
}

#[cfg(all(test, feature = "obs"))]
mod tests {
    use super::*;
    use crate::coordinator::stats::LatencyStats;

    #[test]
    fn counter_and_gauge_basics() {
        let c = Counter::new();
        c.inc();
        c.add(41);
        assert_eq!(c.get(), 42);

        let g = Gauge::new();
        g.inc();
        g.inc();
        g.dec();
        assert_eq!(g.get(), 1);
        g.dec();
        g.dec(); // saturates, must not wrap
        assert_eq!(g.get(), 0);
    }

    #[test]
    fn bucket_index_layout_pinned() {
        assert_eq!(bucket_index(0), 0);
        assert_eq!(bucket_index(1), 1);
        assert_eq!(bucket_index(2), 2);
        assert_eq!(bucket_index(3), 2);
        assert_eq!(bucket_index(4), 3);
        assert_eq!(bucket_index(1023), 10);
        assert_eq!(bucket_index(1024), 11);
        assert_eq!(bucket_index(u64::MAX), 63);
        // Every bucket's upper bound maps back into that bucket.
        for i in 1..HISTO_BUCKETS - 1 {
            assert_eq!(bucket_index(bucket_upper_bound_us(i)), i, "bucket {i}");
            assert_eq!(bucket_index(bucket_upper_bound_us(i) + 1), i + 1, "bucket {i}+1");
        }
    }

    #[test]
    fn histo_counts_are_exact_and_mergeable() {
        let a = LatencyHisto::new();
        let b = LatencyHisto::new();
        for v in 0..1000u64 {
            a.record_us(v);
            b.record_us(v * 7);
        }
        assert_eq!(a.count(), 1000);
        assert_eq!(a.sum_us(), (0..1000).sum::<u64>());
        let merged = LatencyHisto::new();
        merged.merge(&a);
        merged.merge(&b);
        assert_eq!(merged.count(), 2000);
        assert_eq!(merged.sum_us(), a.sum_us() + b.sum_us());
        let direct: u64 = merged.snapshot_buckets().iter().sum();
        assert_eq!(direct, 2000, "bucket counts conserved under merge");
    }

    #[test]
    fn percentile_returns_bucket_upper_bound() {
        let h = LatencyHisto::new();
        assert_eq!(h.percentile_us(50.0), 0, "empty histogram");
        for _ in 0..100 {
            h.record_us(100); // bucket 7 = [64, 127]
        }
        assert_eq!(h.percentile_us(50.0), 127);
        assert_eq!(h.percentile_us(99.0), 127);
        h.record_us(0);
        assert_eq!(h.percentile_us(0.0), 0);
    }

    /// Satellite: cross-check the exact reservoir percentiles of
    /// `LatencyStats` against the histogram's bucket percentiles on a
    /// known distribution. The input count stays under the reservoir
    /// capacity so the reservoir is exact; the histogram then must
    /// bracket each reservoir percentile within its documented bucket
    /// resolution: `res <= histo < 2 * res` (upper-bound reporting).
    #[test]
    fn histo_percentiles_track_reservoir_within_bucket_resolution() {
        let mut stats = LatencyStats::new();
        let histo = LatencyHisto::new();
        // Uniform 1..=50_000 µs — under RESERVOIR_CAP (64 Ki), so the
        // reservoir holds every sample and its percentiles are exact.
        for us in 1..=50_000u64 {
            stats.record(Duration::from_micros(us), 0);
            histo.record_us(us);
        }
        assert_eq!(histo.count(), stats.count() as u64);
        for p in [50.0, 90.0, 99.0] {
            let res = stats.percentile_us(p);
            let h = histo.percentile_us(p);
            assert!(
                h >= res && h < 2 * res.max(1),
                "p{p}: reservoir={res}us histo={h}us outside [res, 2*res)"
            );
        }
    }
}
