//! Dynamic batching of expand dispatches (vLLM-router-style policy).
//!
//! PJRT dispatch has a fixed per-execution overhead; the batcher groups
//! pending chunk-expand tasks by bucket and flushes a group when it
//! reaches `max_batch` or its oldest member exceeds `max_delay`. The
//! policy knobs are exactly what `benches/ablation_batching.rs` sweeps.

use crate::decomp::RunRecord;
use crate::runtime::Expander;
use crate::Result;
use std::collections::VecDeque;
use std::time::{Duration, Instant};

/// Batching policy knobs.
#[derive(Debug, Clone, Copy)]
pub struct BatchPolicy {
    /// Flush when this many tasks are pending for one bucket.
    pub max_batch: usize,
    /// Flush any task older than this.
    pub max_delay: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_batch: 8, max_delay: Duration::from_micros(500) }
    }
}

/// One queued expand task.
#[derive(Debug)]
pub struct ExpandTask {
    /// Chunk identifier (caller-defined).
    pub id: u64,
    /// Decoded run table.
    pub runs: Vec<RunRecord>,
    /// Element width in bytes.
    pub width: u8,
    /// Total output elements.
    pub total: usize,
    /// Enqueue time.
    pub enqueued: Instant,
}

/// A completed expand result.
#[derive(Debug)]
pub struct ExpandResult {
    /// Chunk identifier.
    pub id: u64,
    /// Decompressed bytes (or the error).
    pub bytes: Result<Vec<u8>>,
}

/// The dynamic batcher. Single-threaded core (the service loop owns
/// it); thread-safety comes from the channel in front of it.
#[derive(Debug)]
pub struct Batcher {
    policy: BatchPolicy,
    queue: VecDeque<ExpandTask>,
    /// Dispatched batches, for metrics.
    pub batches: u64,
    /// Dispatched tasks, for metrics.
    pub tasks: u64,
}

impl Batcher {
    /// New batcher with `policy`.
    pub fn new(policy: BatchPolicy) -> Batcher {
        Batcher { policy, queue: VecDeque::new(), batches: 0, tasks: 0 }
    }

    /// Enqueue a task.
    pub fn push(&mut self, task: ExpandTask) {
        self.queue.push_back(task);
    }

    /// Pending task count.
    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// True if a flush is due under the policy at time `now`.
    pub fn due(&self, now: Instant) -> bool {
        if self.queue.len() >= self.policy.max_batch {
            return true;
        }
        match self.queue.front() {
            Some(t) => now.duration_since(t.enqueued) >= self.policy.max_delay,
            None => false,
        }
    }

    /// Flush up to `max_batch` tasks through the expander, returning
    /// results in task order. (The expander serializes PJRT execution;
    /// batching amortizes dispatch and keeps bucket locality.)
    pub fn flush(&mut self, expander: &Expander<'_>) -> Vec<ExpandResult> {
        let n = self.queue.len().min(self.policy.max_batch);
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            let t = self.queue.pop_front().expect("n <= len");
            let bytes = expander.expand(&t.runs, t.width, t.total);
            out.push(ExpandResult { id: t.id, bytes });
            self.tasks += 1;
        }
        if n > 0 {
            self.batches += 1;
        }
        out
    }

    /// Drain everything regardless of policy (shutdown).
    pub fn drain(&mut self, expander: &Expander<'_>) -> Vec<ExpandResult> {
        let mut out = Vec::new();
        while !self.queue.is_empty() {
            out.extend(self.flush(expander));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn task(id: u64) -> ExpandTask {
        ExpandTask {
            id,
            runs: vec![RunRecord { init: id, len: 4, delta: 1 }],
            width: 8,
            total: 4,
            enqueued: Instant::now(),
        }
    }

    #[test]
    fn flush_on_batch_size() {
        let mut b = Batcher::new(BatchPolicy { max_batch: 3, max_delay: Duration::from_secs(10) });
        b.push(task(1));
        b.push(task(2));
        assert!(!b.due(Instant::now()));
        b.push(task(3));
        assert!(b.due(Instant::now()));
        let ex = Expander::cpu_only();
        let results = b.flush(&ex);
        assert_eq!(results.len(), 3);
        assert_eq!(b.pending(), 0);
        assert_eq!(b.batches, 1);
        // Results carry the expanded bytes.
        let bytes = results[0].bytes.as_ref().unwrap();
        assert_eq!(bytes.len(), 32);
    }

    #[test]
    fn flush_on_deadline() {
        let mut b =
            Batcher::new(BatchPolicy { max_batch: 100, max_delay: Duration::from_millis(1) });
        b.push(task(1));
        std::thread::sleep(Duration::from_millis(2));
        assert!(b.due(Instant::now()));
    }

    #[test]
    fn drain_empties() {
        let mut b = Batcher::new(BatchPolicy { max_batch: 2, max_delay: Duration::from_secs(1) });
        for i in 0..7 {
            b.push(task(i));
        }
        let ex = Expander::cpu_only();
        let results = b.drain(&ex);
        assert_eq!(results.len(), 7);
        assert_eq!(b.batches, 4);
        assert_eq!(b.pending(), 0);
    }
}
