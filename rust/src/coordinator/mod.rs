//! The L3 coordinator: the serving system around the decompression
//! framework.
//!
//! * [`engine`] — parallel chunk decompression (shared-cursor worker
//!   pool = CODAG-style fine-grained units; static partitioning = the
//!   coarse baseline), with CPU and hybrid-PJRT decode paths.
//! * [`router`] — container registry, request→chunk planning,
//!   least-loaded worker selection.
//! * [`batcher`] — dynamic batching of PJRT expand dispatches.
//! * [`service`] — the request loop gluing it together.
//! * [`stats`] — latency percentiles / throughput accounting.

pub mod batcher;
pub mod engine;
pub mod router;
pub mod service;
pub mod stats;

pub use batcher::{BatchPolicy, Batcher, ExpandTask};
pub use engine::{
    decode_chunk_parallel, decode_chunk_parallel_obs, decompress_chunk_split,
    decompress_chunk_split_into, decompress_chunk_split_obs_into, decompress_hybrid,
    decompress_parallel, decompress_static_partition,
};
pub use router::{plan, plan_dims, ChunkWork, DatasetSource, LeastLoaded, Registry, Request};
pub use service::{Payload, Response, Service, ServiceConfig, SharedResponse};
pub use stats::LatencyStats;
