//! Latency/throughput statistics for the serving path.

use std::time::Duration;

/// Online latency recorder with percentile queries.
///
/// Stores microsecond samples; `percentile` sorts a snapshot (serving
/// benches take snapshots off the hot path).
#[derive(Debug, Default, Clone)]
pub struct LatencyStats {
    samples_us: Vec<u64>,
    total_bytes: u64,
}

impl LatencyStats {
    /// Empty recorder.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one request's latency and payload size.
    pub fn record(&mut self, latency: Duration, bytes: u64) {
        self.samples_us.push(latency.as_micros() as u64);
        self.total_bytes += bytes;
    }

    /// Merge another recorder (per-worker aggregation).
    pub fn merge(&mut self, other: &LatencyStats) {
        self.samples_us.extend_from_slice(&other.samples_us);
        self.total_bytes += other.total_bytes;
    }

    /// Number of samples.
    pub fn count(&self) -> usize {
        self.samples_us.len()
    }

    /// Total decompressed bytes.
    pub fn total_bytes(&self) -> u64 {
        self.total_bytes
    }

    /// p-th percentile latency in microseconds (p in [0, 100]).
    pub fn percentile_us(&self, p: f64) -> u64 {
        if self.samples_us.is_empty() {
            return 0;
        }
        let mut v = self.samples_us.clone();
        v.sort_unstable();
        let idx = ((p / 100.0) * (v.len() - 1) as f64).round() as usize;
        v[idx.min(v.len() - 1)]
    }

    /// Mean latency in microseconds.
    pub fn mean_us(&self) -> f64 {
        if self.samples_us.is_empty() {
            return 0.0;
        }
        self.samples_us.iter().sum::<u64>() as f64 / self.samples_us.len() as f64
    }

    /// Throughput given a wall-clock window.
    pub fn throughput_gbps(&self, wall: Duration) -> f64 {
        self.total_bytes as f64 / wall.as_secs_f64().max(1e-9) / 1e9
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles() {
        let mut s = LatencyStats::new();
        for i in 1..=100u64 {
            s.record(Duration::from_micros(i), 10);
        }
        assert_eq!(s.count(), 100);
        assert_eq!(s.percentile_us(0.0), 1);
        assert_eq!(s.percentile_us(100.0), 100);
        let p50 = s.percentile_us(50.0);
        assert!((49..=51).contains(&p50), "{p50}");
        assert_eq!(s.total_bytes(), 1000);
        assert!((s.mean_us() - 50.5).abs() < 1e-9);
    }

    #[test]
    fn merge_and_empty() {
        let mut a = LatencyStats::new();
        assert_eq!(a.percentile_us(50.0), 0);
        let mut b = LatencyStats::new();
        b.record(Duration::from_micros(5), 1);
        a.merge(&b);
        assert_eq!(a.count(), 1);
    }
}
