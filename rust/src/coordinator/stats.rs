//! Latency/throughput statistics for the serving path.

use crate::codecs::{CodecKind, CodecRegistry, N_CODECS};
use crate::data::Rng;
use std::time::Duration;

/// Percentile sample cap: the reservoir never grows past this, so a
/// long-lived daemon's stats stay O(1) in memory (the seed version grew
/// `samples_us` without bound).
pub const RESERVOIR_CAP: usize = 64 * 1024;

/// Online latency recorder with percentile queries.
///
/// `count`, `mean_us` and `total_bytes` are exact over every recorded
/// request; percentiles are computed from a uniform reservoir (Vitter's
/// algorithm R, capped at [`RESERVOIR_CAP`] samples) so they stay
/// accurate while memory stays bounded. `percentile_us` sorts a
/// snapshot — serving benches take snapshots off the hot path.
#[derive(Debug, Default, Clone)]
pub struct LatencyStats {
    samples_us: Vec<u64>,
    /// Requests recorded (exact, not capped).
    seen: u64,
    /// Exact sum of all latencies (µs).
    total_us: u128,
    total_bytes: u64,
    cache_hits: u64,
    cache_misses: u64,
    /// Requests refused with `Error::ChecksumMismatch` (decoded bytes
    /// failed content verification). Zero on a healthy daemon; the
    /// shutdown summary prints it when non-zero.
    integrity_failures: u64,
    /// Decoded bytes served per codec, indexed by registry slot
    /// ([`CodecRegistry::slot`]) — cheap observability for the
    /// per-codec hot paths (the `codag serve` shutdown summary prints
    /// these). Registering a codec grows this automatically; no match
    /// arm, no fixed-size array to forget.
    codec_bytes: [u64; N_CODECS],
    /// Reservoir-replacement RNG (deterministic zero-seeded stream).
    rng: Rng,
}

impl LatencyStats {
    /// Empty recorder.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one request's latency and payload size.
    pub fn record(&mut self, latency: Duration, bytes: u64) {
        let us = latency.as_micros() as u64;
        self.seen += 1;
        self.total_us += us as u128;
        self.total_bytes += bytes;
        if self.samples_us.len() < RESERVOIR_CAP {
            self.samples_us.push(us);
        } else {
            // Algorithm R: keep each of the `seen` samples with equal
            // probability CAP/seen.
            let j = self.rng.below(self.seen);
            if (j as usize) < RESERVOIR_CAP {
                self.samples_us[j as usize] = us;
            }
        }
    }

    /// Merge another recorder (per-worker / per-batch aggregation).
    /// Exact counters add exactly. When the combined reservoir
    /// overflows the cap, each side contributes slots in proportion to
    /// the *population* its reservoir represents (`seen`, not reservoir
    /// length) — repeated small merges must not make the reservoir
    /// converge to a recent-window sample.
    pub fn merge(&mut self, other: &LatencyStats) {
        let (self_seen, other_seen) = (self.seen, other.seen);
        self.seen += other_seen;
        self.total_us += other.total_us;
        self.total_bytes += other.total_bytes;
        self.cache_hits += other.cache_hits;
        self.cache_misses += other.cache_misses;
        self.integrity_failures += other.integrity_failures;
        for (a, b) in self.codec_bytes.iter_mut().zip(other.codec_bytes.iter()) {
            *a += b;
        }
        if self.samples_us.len() + other.samples_us.len() <= RESERVOIR_CAP {
            self.samples_us.extend_from_slice(&other.samples_us);
            return;
        }
        // Seen-weighted quotas (each side's reservoir is ~uniform over
        // its own population, so proportional subsampling keeps the
        // merged reservoir ~uniform over the union).
        let total = (self_seen + other_seen).max(1);
        let mut quota_self =
            ((RESERVOIR_CAP as u128 * self_seen as u128) / total as u128) as usize;
        quota_self = quota_self.min(self.samples_us.len());
        let mut quota_other = RESERVOIR_CAP - quota_self;
        if quota_other > other.samples_us.len() {
            quota_other = other.samples_us.len();
            quota_self = (RESERVOIR_CAP - quota_other).min(self.samples_us.len());
        }
        self.subsample_in_place(quota_self);
        let mut from_other = other.samples_us.clone();
        let n = from_other.len();
        for i in 0..quota_other {
            let j = i + self.rng.below((n - i) as u64) as usize;
            from_other.swap(i, j);
        }
        from_other.truncate(quota_other);
        self.samples_us.extend_from_slice(&from_other);
    }

    /// Uniformly shrink the reservoir to `k` samples (partial
    /// Fisher–Yates).
    fn subsample_in_place(&mut self, k: usize) {
        let n = self.samples_us.len();
        if k >= n {
            return;
        }
        for i in 0..k {
            let j = i + self.rng.below((n - i) as u64) as usize;
            self.samples_us.swap(i, j);
        }
        self.samples_us.truncate(k);
    }

    /// Number of requests recorded (exact).
    pub fn count(&self) -> usize {
        self.seen as usize
    }

    /// Samples currently held for percentile queries (≤ [`RESERVOIR_CAP`]).
    pub fn reservoir_len(&self) -> usize {
        self.samples_us.len()
    }

    /// Total decompressed bytes.
    pub fn total_bytes(&self) -> u64 {
        self.total_bytes
    }

    /// Add chunk-cache counters (the daemon folds `ChunkCache` atomics
    /// into its stats snapshot here).
    pub fn add_cache_counts(&mut self, hits: u64, misses: u64) {
        self.cache_hits += hits;
        self.cache_misses += misses;
    }

    /// Chunk-cache hits attributed to this recorder.
    pub fn cache_hits(&self) -> u64 {
        self.cache_hits
    }

    /// Chunk-cache misses attributed to this recorder.
    pub fn cache_misses(&self) -> u64 {
        self.cache_misses
    }

    /// Count one checksum-mismatch refusal (the daemon's shard loops
    /// call this when a decode fails content verification).
    pub fn add_integrity_failures(&mut self, n: u64) {
        self.integrity_failures += n;
    }

    /// Checksum-mismatch refusals attributed to this recorder.
    pub fn integrity_failures(&self) -> u64 {
        self.integrity_failures
    }

    /// Counter slot for `kind`: its registry position, so the counters
    /// stay in lockstep with the registration table (an unregistered
    /// kind panics here with a clear message instead of silently
    /// mis-indexing; the slot order is pinned by a registry test).
    fn codec_slot(kind: CodecKind) -> usize {
        CodecRegistry::slot(kind).expect("CodecKind missing from the codec registry")
    }

    /// Attribute `bytes` of decoded payload to `kind` (the daemon's
    /// shard loops call this alongside [`record`](Self::record)).
    pub fn add_codec_bytes(&mut self, kind: CodecKind, bytes: u64) {
        self.codec_bytes[Self::codec_slot(kind)] += bytes;
    }

    /// Decoded bytes attributed to `kind`.
    pub fn codec_bytes(&self, kind: CodecKind) -> u64 {
        self.codec_bytes[Self::codec_slot(kind)]
    }

    /// `(codec name, decoded bytes)` rows in registry (reporting)
    /// order, for the shutdown summary.
    pub fn codec_bytes_all(&self) -> [(&'static str, u64); N_CODECS] {
        let mut rows = [("", 0u64); N_CODECS];
        for (row, kind) in rows.iter_mut().zip(CodecKind::all()) {
            *row = (kind.name(), self.codec_bytes(kind));
        }
        rows
    }

    /// p-th percentile latency in microseconds (p in [0, 100]).
    pub fn percentile_us(&self, p: f64) -> u64 {
        if self.samples_us.is_empty() {
            return 0;
        }
        let mut v = self.samples_us.clone();
        v.sort_unstable();
        let idx = ((p / 100.0) * (v.len() - 1) as f64).round() as usize;
        v[idx.min(v.len() - 1)]
    }

    /// Mean latency in microseconds (exact over all recorded requests).
    pub fn mean_us(&self) -> f64 {
        if self.seen == 0 {
            return 0.0;
        }
        self.total_us as f64 / self.seen as f64
    }

    /// Throughput given a wall-clock window.
    pub fn throughput_gbps(&self, wall: Duration) -> f64 {
        self.total_bytes as f64 / wall.as_secs_f64().max(1e-9) / 1e9
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles() {
        let mut s = LatencyStats::new();
        for i in 1..=100u64 {
            s.record(Duration::from_micros(i), 10);
        }
        assert_eq!(s.count(), 100);
        assert_eq!(s.percentile_us(0.0), 1);
        assert_eq!(s.percentile_us(100.0), 100);
        let p50 = s.percentile_us(50.0);
        assert!((49..=51).contains(&p50), "{p50}");
        assert_eq!(s.total_bytes(), 1000);
        assert!((s.mean_us() - 50.5).abs() < 1e-9);
    }

    #[test]
    fn merge_and_empty() {
        let mut a = LatencyStats::new();
        assert_eq!(a.percentile_us(50.0), 0);
        let mut b = LatencyStats::new();
        b.record(Duration::from_micros(5), 1);
        a.merge(&b);
        assert_eq!(a.count(), 1);
    }

    #[test]
    fn reservoir_stays_bounded_counters_stay_exact() {
        let mut s = LatencyStats::new();
        let n = 3 * RESERVOIR_CAP as u64;
        for _ in 0..n {
            s.record(Duration::from_micros(7), 2);
        }
        assert_eq!(s.count(), n as usize);
        assert_eq!(s.reservoir_len(), RESERVOIR_CAP);
        assert_eq!(s.total_bytes(), 2 * n);
        // Every sample is 7µs, so every percentile is exact despite
        // reservoir replacement.
        assert_eq!(s.percentile_us(50.0), 7);
        assert_eq!(s.percentile_us(99.0), 7);
        assert!((s.mean_us() - 7.0).abs() < 1e-9);
    }

    #[test]
    fn merge_overflow_stays_bounded() {
        let mut a = LatencyStats::new();
        let mut b = LatencyStats::new();
        for _ in 0..RESERVOIR_CAP {
            a.record(Duration::from_micros(1), 1);
            b.record(Duration::from_micros(3), 1);
        }
        a.merge(&b);
        assert_eq!(a.count(), 2 * RESERVOIR_CAP);
        assert_eq!(a.reservoir_len(), RESERVOIR_CAP);
        // Downsampled from an equal mix of 1s and 3s: both survive.
        assert_eq!(a.percentile_us(0.0), 1);
        assert_eq!(a.percentile_us(100.0), 3);
        assert!((a.mean_us() - 2.0).abs() < 1e-9);
    }

    #[test]
    fn repeated_small_merges_keep_population_weighting() {
        // The daemon merges one small batch at a time into a full
        // recorder; history must not be washed out by recency.
        let mut a = LatencyStats::new();
        for _ in 0..RESERVOIR_CAP {
            a.record(Duration::from_micros(1), 1);
        }
        for _ in 0..100 {
            let mut b = LatencyStats::new();
            for _ in 0..8 {
                b.record(Duration::from_micros(1000), 1);
            }
            a.merge(&b);
        }
        assert_eq!(a.count(), RESERVOIR_CAP + 800);
        assert_eq!(a.reservoir_len(), RESERVOIR_CAP);
        // 800 of ~66k requests were slow: the reservoir must still be
        // dominated by the old population.
        assert_eq!(a.percentile_us(50.0), 1);
        assert_eq!(a.percentile_us(90.0), 1);
    }

    #[test]
    fn cache_counters_merge() {
        let mut a = LatencyStats::new();
        a.add_cache_counts(3, 5);
        let mut b = LatencyStats::new();
        b.add_cache_counts(2, 1);
        a.merge(&b);
        assert_eq!(a.cache_hits(), 5);
        assert_eq!(a.cache_misses(), 6);
    }

    #[test]
    fn integrity_counter_records_and_merges() {
        let mut a = LatencyStats::new();
        a.add_integrity_failures(2);
        let mut b = LatencyStats::new();
        b.add_integrity_failures(1);
        a.merge(&b);
        assert_eq!(a.integrity_failures(), 3);
        assert_eq!(LatencyStats::new().integrity_failures(), 0);
    }

    #[test]
    fn codec_counter_array_covers_every_codec() {
        // The counter array is sized by the registry (N_CODECS), so
        // registering a codec grows attribution automatically — this
        // pin catches the array and the registry ever drifting apart.
        let mut s = LatencyStats::new();
        assert_eq!(CodecKind::all().len(), s.codec_bytes.len());
        for kind in CodecKind::all() {
            s.add_codec_bytes(kind, 1);
            assert_eq!(s.codec_bytes(kind), 1);
        }
    }

    #[test]
    fn per_codec_byte_counters_record_and_merge() {
        let mut a = LatencyStats::new();
        a.add_codec_bytes(CodecKind::RleV2, 100);
        a.add_codec_bytes(CodecKind::RleV2, 20);
        a.add_codec_bytes(CodecKind::Deflate, 7);
        let mut b = LatencyStats::new();
        b.add_codec_bytes(CodecKind::RleV1, 3);
        b.add_codec_bytes(CodecKind::RleV2, 1);
        b.add_codec_bytes(CodecKind::Lzss, 9);
        a.merge(&b);
        assert_eq!(a.codec_bytes(CodecKind::RleV1), 3);
        assert_eq!(a.codec_bytes(CodecKind::RleV2), 121);
        assert_eq!(a.codec_bytes(CodecKind::Deflate), 7);
        assert_eq!(a.codec_bytes(CodecKind::Lzss), 9);
        assert_eq!(
            a.codec_bytes_all(),
            [("rlev1", 3), ("rlev2", 121), ("deflate", 7), ("lzss", 9)]
        );
    }
}
