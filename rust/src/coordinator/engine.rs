//! The parallel decompression engine — CODAG's provisioning idea on CPU.
//!
//! The paper's core move is many small independent decompression units
//! over chunks; on the host the analogue is a worker pool pulling chunks
//! from a shared atomic cursor (fine-grained, no barriers) — versus a
//! coarse "block-level" static partitioning. Both are provided so the
//! ablation benches can show the same effect the GPU simulator shows.
//!
//! Two decode paths per chunk:
//! * **CPU**: the codec decoder materializes bytes directly.
//! * **Hybrid**: RLE codecs decode to run records and the PJRT
//!   [`Expander`](crate::runtime::Expander) runs the AOT JAX/Pallas
//!   expand kernel (the L1/L2 half of the stack).

use crate::codecs::{decode_to_runs, CodecKind};
use crate::format::container::Container;
use crate::runtime::Expander;
use crate::{Error, Result};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// How chunk decode work is produced.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DecodePath {
    /// Pure-CPU codec decode.
    Cpu,
    /// Decode to runs in Rust, expand through PJRT (RLE codecs only).
    HybridPjrt,
}

/// Decompress every chunk of `container` with `n_workers` threads
/// pulling from a shared cursor (CODAG-style fine-grained units).
pub fn decompress_parallel(container: &Container, n_workers: usize) -> Result<Vec<u8>> {
    run_pool(container, n_workers, None)
}

/// Hybrid path: workers decode to run records and expand via PJRT.
pub fn decompress_hybrid(
    container: &Container,
    n_workers: usize,
    expander: &Expander<'_>,
) -> Result<Vec<u8>> {
    if !container.codec.is_rle() {
        return Err(crate::invalid("hybrid path requires an RLE codec"));
    }
    run_pool(container, n_workers, Some(expander))
}

fn run_pool(
    container: &Container,
    n_workers: usize,
    expander: Option<&Expander<'_>>,
) -> Result<Vec<u8>> {
    let n = container.n_chunks();
    if n == 0 {
        return Ok(Vec::new());
    }
    let n_workers = n_workers.max(1).min(n);
    let cursor = AtomicUsize::new(0);
    let results: Vec<Mutex<Option<Result<Vec<u8>>>>> =
        (0..n).map(|_| Mutex::new(None)).collect();
    std::thread::scope(|s| {
        for _ in 0..n_workers {
            s.spawn(|| loop {
                let i = cursor.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let out = decode_one(container, i, expander);
                *results[i].lock().unwrap() = Some(out);
            });
        }
    });
    let mut out = Vec::with_capacity(container.total_uncompressed as usize);
    for (i, cell) in results.iter().enumerate() {
        let r = cell
            .lock()
            .unwrap()
            .take()
            .unwrap_or_else(|| Err(Error::Runtime(format!("chunk {i} never decoded"))));
        out.extend_from_slice(&r?);
    }
    Ok(out)
}

/// Decode a single chunk via the selected path.
pub fn decode_one(
    container: &Container,
    i: usize,
    expander: Option<&Expander<'_>>,
) -> Result<Vec<u8>> {
    match expander {
        None => container.decompress_chunk(i),
        Some(ex) => {
            let comp = container.chunk_bytes(i)?;
            decode_chunk_hybrid(container.codec, comp, ex)
        }
    }
}

/// Hybrid decode of one compressed chunk.
pub fn decode_chunk_hybrid(
    kind: CodecKind,
    comp: &[u8],
    expander: &Expander<'_>,
) -> Result<Vec<u8>> {
    let (runs, width) = decode_to_runs(kind, comp)?;
    let total: u64 = runs.iter().map(|r| r.len).sum();
    expander.expand(&runs, width, total as usize)
}

/// Static block partitioning (the "baseline" work division): worker `w`
/// owns chunks `[w*n/W, (w+1)*n/W)`. Compared in `ablation_batching`.
pub fn decompress_static_partition(container: &Container, n_workers: usize) -> Result<Vec<u8>> {
    let n = container.n_chunks();
    if n == 0 {
        return Ok(Vec::new());
    }
    let n_workers = n_workers.max(1).min(n);
    let results: Vec<Mutex<Option<Result<Vec<u8>>>>> =
        (0..n).map(|_| Mutex::new(None)).collect();
    std::thread::scope(|s| {
        for w in 0..n_workers {
            let results = &results;
            s.spawn(move || {
                let lo = w * n / n_workers;
                let hi = (w + 1) * n / n_workers;
                for i in lo..hi {
                    let out = container.decompress_chunk(i);
                    *results[i].lock().unwrap() = Some(out);
                }
            });
        }
    });
    let mut out = Vec::with_capacity(container.total_uncompressed as usize);
    for (i, cell) in results.iter().enumerate() {
        let r = cell
            .lock()
            .unwrap()
            .take()
            .unwrap_or_else(|| Err(Error::Runtime(format!("chunk {i} never decoded"))));
        out.extend_from_slice(&r?);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Dataset;

    fn container(kind: CodecKind) -> (Vec<u8>, Container) {
        let data = Dataset::Mc0.generate(600 * 1024);
        let c = Container::compress(&data, kind, 64 * 1024).unwrap();
        (data, c)
    }

    #[test]
    fn parallel_matches_serial_all_codecs() {
        for kind in CodecKind::all() {
            let (data, c) = container(kind);
            for workers in [1, 2, 7] {
                assert_eq!(decompress_parallel(&c, workers).unwrap(), data, "{kind:?}/{workers}");
            }
        }
    }

    #[test]
    fn static_partition_matches() {
        let (data, c) = container(CodecKind::RleV2);
        assert_eq!(decompress_static_partition(&c, 3).unwrap(), data);
    }

    #[test]
    fn hybrid_cpu_fallback_matches() {
        // No PJRT runtime in unit tests: cpu_only expander still goes
        // through the run-record path.
        let (data, c) = container(CodecKind::RleV1);
        let ex = Expander::cpu_only();
        assert_eq!(decompress_hybrid(&c, 4, &ex).unwrap(), data);
        assert!(ex.stats.cpu_fallback.load(Ordering::Relaxed) > 0);
    }

    #[test]
    fn hybrid_rejects_deflate() {
        let (_, c) = container(CodecKind::Deflate);
        let ex = Expander::cpu_only();
        assert!(decompress_hybrid(&c, 2, &ex).is_err());
    }
}
