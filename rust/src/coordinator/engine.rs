//! The parallel decompression engine — CODAG's provisioning idea on CPU.
//!
//! The paper's core move is many small independent decompression units
//! over chunks; on the host the analogue is a worker pool pulling chunks
//! from a shared atomic cursor (fine-grained, no barriers) — versus a
//! coarse "block-level" static partitioning. Both are provided so the
//! ablation benches can show the same effect the GPU simulator shows.
//!
//! Two decode paths per chunk:
//! * **CPU**: the codec decoder materializes bytes directly.
//! * **Hybrid**: RLE codecs decode to run records and the PJRT
//!   [`Expander`](crate::runtime::Expander) runs the AOT JAX/Pallas
//!   expand kernel (the L1/L2 half of the stack).

use crate::codecs::{
    check_chunk_header, decode_sub_block, decode_to_runs, CodecKind, RestartPoint,
};
use crate::format::container::{validate_restart_table, ChunkEntry, Container};
use crate::obs::{now_if_enabled, StitchTimers};
use crate::runtime::Expander;
use crate::{corrupt, invalid, Error, Result};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::Instant;

/// How chunk decode work is produced.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DecodePath {
    /// Pure-CPU codec decode.
    Cpu,
    /// Decode to runs in Rust, expand through PJRT (RLE codecs only).
    HybridPjrt,
}

/// Decompress every chunk of `container` with `n_workers` threads
/// pulling from a shared cursor (CODAG-style fine-grained units).
pub fn decompress_parallel(container: &Container, n_workers: usize) -> Result<Vec<u8>> {
    run_pool(container, n_workers, None)
}

/// Hybrid path: workers decode to run records and expand via PJRT.
pub fn decompress_hybrid(
    container: &Container,
    n_workers: usize,
    expander: &Expander<'_>,
) -> Result<Vec<u8>> {
    if !container.codec.is_rle() || container.chunk_codecs.iter().any(|k| !k.is_rle()) {
        return Err(crate::invalid("hybrid path requires an RLE codec"));
    }
    run_pool(container, n_workers, Some(expander))
}

fn run_pool(
    container: &Container,
    n_workers: usize,
    expander: Option<&Expander<'_>>,
) -> Result<Vec<u8>> {
    let n = container.n_chunks();
    if n == 0 {
        return Ok(Vec::new());
    }
    let n_workers = n_workers.max(1).min(n);
    let cursor = AtomicUsize::new(0);
    let results: Vec<Mutex<Option<Result<Vec<u8>>>>> =
        (0..n).map(|_| Mutex::new(None)).collect();
    std::thread::scope(|s| {
        for _ in 0..n_workers {
            s.spawn(|| loop {
                let i = cursor.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let out = decode_one(container, i, expander);
                *results[i].lock().unwrap() = Some(out);
            });
        }
    });
    let mut out = Vec::with_capacity(container.total_uncompressed as usize);
    for (i, cell) in results.iter().enumerate() {
        let r = cell
            .lock()
            .unwrap()
            .take()
            .unwrap_or_else(|| Err(Error::Runtime(format!("chunk {i} never decoded"))));
        out.extend_from_slice(&r?);
    }
    Ok(out)
}

/// Decode a single chunk via the selected path.
pub fn decode_one(
    container: &Container,
    i: usize,
    expander: Option<&Expander<'_>>,
) -> Result<Vec<u8>> {
    match expander {
        None => container.decompress_chunk(i),
        Some(ex) => {
            let comp = container.chunk_bytes(i)?;
            decode_chunk_hybrid(container.chunk_codec(i), comp, ex)
        }
    }
}

/// Hybrid decode of one compressed chunk.
pub fn decode_chunk_hybrid(
    kind: CodecKind,
    comp: &[u8],
    expander: &Expander<'_>,
) -> Result<Vec<u8>> {
    let (runs, width) = decode_to_runs(kind, comp)?;
    let total: u64 = runs.iter().map(|r| r.len).sum();
    expander.expand(&runs, width, total as usize)
}

/// One stitch job: a sub-block's disjoint output slice plus the bit
/// range that must produce it.
struct StitchJob<'a> {
    /// Stream-order position (error reporting picks the first).
    seq: usize,
    /// Disjoint slice of the chunk's output buffer.
    out: &'a mut [u8],
    /// Restart bit position to decode from (0 = chunk start).
    bit_pos: u64,
    /// The next sub-block's restart bit position; decode must stop
    /// exactly there. `None` for the last sub-block.
    next_bit: Option<u64>,
}

impl StitchJob<'_> {
    fn run(self, kind: CodecKind, comp: &[u8]) -> Result<()> {
        let end = decode_sub_block(kind, comp, self.bit_pos, self.next_bit.is_none(), self.out)?;
        if let Some(nb) = self.next_bit {
            if end != nb {
                return Err(corrupt(format!(
                    "sub-block {} ended at bit {end}, next restart point says {nb}",
                    self.seq
                )));
            }
        }
        Ok(())
    }
}

/// Decode one chunk by splitting its restart table across `n_workers`
/// threads, each filling a disjoint slice of `out` (DESIGN.md §7.5).
///
/// `out.len()` must be the chunk's exact uncompressed length. The
/// stitched result is byte-identical to a serial
/// [`Container::decompress_chunk_into`]; on corrupt input the call may
/// fail where serial decode would fail (same `Corrupt` class) — it can
/// reject more, never silently return different bytes. An empty restart
/// table degrades to a single serial sub-block covering the chunk, so
/// v1 containers decode unchanged through this path.
pub fn decode_chunk_parallel(
    kind: CodecKind,
    comp: &[u8],
    restarts: &[RestartPoint],
    out: &mut [u8],
    n_workers: usize,
) -> Result<()> {
    decode_chunk_parallel_obs(kind, comp, restarts, out, n_workers, None)
}

/// [`decode_chunk_parallel`] with optional stitch-phase timing: entry →
/// spawn-complete lands in `fanout`, spawn-complete → workers joined in
/// `join` (DESIGN.md §10). The serial degrades (empty table, one
/// worker) record their whole decode in `fanout` and a zero `join`, so
/// both histograms stay populated whenever this path runs.
pub fn decode_chunk_parallel_obs(
    kind: CodecKind,
    comp: &[u8],
    restarts: &[RestartPoint],
    out: &mut [u8],
    n_workers: usize,
    obs: Option<StitchTimers<'_>>,
) -> Result<()> {
    let t0 = now_if_enabled().filter(|_| obs.is_some());
    let total = out.len() as u64;
    // Structural validation first: a hostile table must fail typed here,
    // before any slice arithmetic.
    let entry = ChunkEntry { comp_off: 0, comp_len: comp.len() as u64, uncomp_len: total };
    validate_restart_table(restarts, &entry)
        .map_err(|e| corrupt(format!("restart table invalid: {e}")))?;
    // Sub-block budgets come from the index, not the chunk header —
    // reject up front if the header disagrees, where serial decode
    // (header-driven) would produce a different byte count.
    check_chunk_header(kind, comp, total)?;
    if restarts.is_empty() {
        decode_sub_block(kind, comp, 0, true, out)?;
        if let (Some(t0), Some(o)) = (t0, obs) {
            o.fanout.record(t0.elapsed());
            o.join.record_us(0);
        }
        return Ok(());
    }
    // Carve the output into disjoint sub-block slices.
    let mut jobs = Vec::with_capacity(restarts.len() + 1);
    let mut rest = out;
    let mut prev_off = 0u64;
    let mut prev_bit = 0u64;
    for (k, p) in restarts.iter().enumerate() {
        let (sub, tail) = rest.split_at_mut((p.out_off - prev_off) as usize);
        jobs.push(StitchJob { seq: k, out: sub, bit_pos: prev_bit, next_bit: Some(p.bit_pos) });
        rest = tail;
        prev_off = p.out_off;
        prev_bit = p.bit_pos;
    }
    jobs.push(StitchJob { seq: restarts.len(), out: rest, bit_pos: prev_bit, next_bit: None });
    let n_jobs = jobs.len();
    if n_workers <= 1 {
        // Single worker still exercises the stitch decomposition (the
        // differential harness relies on this); run jobs in stream order.
        for job in jobs {
            job.run(kind, comp)?;
        }
        if let (Some(t0), Some(o)) = (t0, obs) {
            o.fanout.record(t0.elapsed());
            o.join.record_us(0);
        }
        return Ok(());
    }
    // Round-robin the jobs over the workers; report the first
    // stream-order error so parallel and serial agree on which
    // corruption surfaces.
    let results: Vec<Mutex<Option<Result<()>>>> = (0..n_jobs).map(|_| Mutex::new(None)).collect();
    let mut buckets: Vec<Vec<StitchJob<'_>>> =
        (0..n_workers.min(n_jobs)).map(|_| Vec::new()).collect();
    for (k, job) in jobs.into_iter().enumerate() {
        let w = k % buckets.len();
        buckets[w].push(job);
    }
    let mut spawned_at: Option<Instant> = None;
    std::thread::scope(|s| {
        for bucket in buckets {
            let results = &results;
            s.spawn(move || {
                for job in bucket {
                    let seq = job.seq;
                    let r = job.run(kind, comp);
                    *results[seq].lock().unwrap() = Some(r);
                }
            });
        }
        // Scope exit joins the workers: everything before this point is
        // fan-out (carve + spawn), everything after is join.
        spawned_at = t0.map(|_| Instant::now());
    });
    if let (Some(t0), Some(at), Some(o)) = (t0, spawned_at, obs) {
        o.fanout.record(at.duration_since(t0));
        o.join.record(at.elapsed());
    }
    for (k, cell) in results.iter().enumerate() {
        cell.lock()
            .unwrap()
            .take()
            .unwrap_or_else(|| Err(Error::Runtime(format!("sub-block {k} never decoded"))))?;
    }
    Ok(())
}

/// Decompress chunk `i` of `container` through the restart-point
/// stitcher into a caller-owned buffer (cleared and resized first).
pub fn decompress_chunk_split_into(
    container: &Container,
    i: usize,
    n_workers: usize,
    out: &mut Vec<u8>,
) -> Result<()> {
    decompress_chunk_split_obs_into(container, i, n_workers, out, None)
}

/// [`decompress_chunk_split_into`] with optional stitch-phase timing.
pub fn decompress_chunk_split_obs_into(
    container: &Container,
    i: usize,
    n_workers: usize,
    out: &mut Vec<u8>,
    obs: Option<StitchTimers<'_>>,
) -> Result<()> {
    let e = *container
        .index
        .get(i)
        .ok_or_else(|| invalid(format!("chunk {i} out of range")))?;
    let comp = container.chunk_bytes(i)?;
    out.clear();
    out.resize(e.uncomp_len as usize, 0);
    decode_chunk_parallel_obs(
        container.chunk_codec(i),
        comp,
        container.restart_table(i),
        out,
        n_workers,
        obs,
    )?;
    // Content verification happens once at the join, over the stitched
    // extent: each sub-block wrote its disjoint slice, so one CRC over
    // `out` covers every worker's output (DESIGN.md §13).
    Container::verify_chunk_content(&container.checksums, i, out)
}

/// Decompress chunk `i` through the stitcher into a fresh buffer.
pub fn decompress_chunk_split(container: &Container, i: usize, n_workers: usize) -> Result<Vec<u8>> {
    let mut out = Vec::new();
    decompress_chunk_split_into(container, i, n_workers, &mut out)?;
    Ok(out)
}

/// Static block partitioning (the "baseline" work division): worker `w`
/// owns chunks `[w*n/W, (w+1)*n/W)`. Compared in `ablation_batching`.
pub fn decompress_static_partition(container: &Container, n_workers: usize) -> Result<Vec<u8>> {
    let n = container.n_chunks();
    if n == 0 {
        return Ok(Vec::new());
    }
    let n_workers = n_workers.max(1).min(n);
    let results: Vec<Mutex<Option<Result<Vec<u8>>>>> =
        (0..n).map(|_| Mutex::new(None)).collect();
    std::thread::scope(|s| {
        for w in 0..n_workers {
            let results = &results;
            s.spawn(move || {
                let lo = w * n / n_workers;
                let hi = (w + 1) * n / n_workers;
                for i in lo..hi {
                    let out = container.decompress_chunk(i);
                    *results[i].lock().unwrap() = Some(out);
                }
            });
        }
    });
    let mut out = Vec::with_capacity(container.total_uncompressed as usize);
    for (i, cell) in results.iter().enumerate() {
        let r = cell
            .lock()
            .unwrap()
            .take()
            .unwrap_or_else(|| Err(Error::Runtime(format!("chunk {i} never decoded"))));
        out.extend_from_slice(&r?);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Dataset;

    fn container(kind: CodecKind) -> (Vec<u8>, Container) {
        let data = Dataset::Mc0.generate(600 * 1024);
        let c = Container::compress(&data, kind, 64 * 1024).unwrap();
        (data, c)
    }

    #[test]
    fn parallel_matches_serial_all_codecs() {
        for kind in CodecKind::all() {
            let (data, c) = container(kind);
            for workers in [1, 2, 7] {
                assert_eq!(decompress_parallel(&c, workers).unwrap(), data, "{kind:?}/{workers}");
            }
        }
    }

    #[test]
    fn static_partition_matches() {
        let (data, c) = container(CodecKind::RleV2);
        assert_eq!(decompress_static_partition(&c, 3).unwrap(), data);
    }

    #[test]
    fn hybrid_cpu_fallback_matches() {
        // No PJRT runtime in unit tests: cpu_only expander still goes
        // through the run-record path.
        let (data, c) = container(CodecKind::RleV1);
        let ex = Expander::cpu_only();
        assert_eq!(decompress_hybrid(&c, 4, &ex).unwrap(), data);
        assert!(ex.stats.cpu_fallback.load(Ordering::Relaxed) > 0);
    }

    #[test]
    fn hybrid_rejects_deflate() {
        let (_, c) = container(CodecKind::Deflate);
        let ex = Expander::cpu_only();
        assert!(decompress_hybrid(&c, 2, &ex).is_err());
    }

    #[test]
    fn split_decode_matches_serial_all_codecs() {
        let data = Dataset::Mc0.generate(200 * 1024);
        for kind in CodecKind::all() {
            let c = Container::compress_with_restarts(&data, kind, 64 * 1024, 4096).unwrap();
            assert!(c.restarts.iter().any(|t| !t.is_empty()), "{kind:?}");
            for i in 0..c.n_chunks() {
                let serial = c.decompress_chunk(i).unwrap();
                for workers in [1, 2, 8] {
                    let par = decompress_chunk_split(&c, i, workers).unwrap();
                    assert_eq!(par, serial, "{kind:?} chunk {i} workers {workers}");
                }
            }
        }
    }

    #[test]
    fn split_decode_verifies_content_checksum_at_join() {
        let data = Dataset::Mc0.generate(128 * 1024);
        let mut c =
            Container::compress_with_restarts(&data, CodecKind::RleV2, 128 * 1024, 4096).unwrap();
        assert!(!c.restart_table(0).is_empty());
        // Lie about the content checksum: every sub-block decodes fine,
        // but the join-time CRC over the stitched extent must fail typed.
        c.checksums[0] ^= 1;
        for workers in [1, 4] {
            match decompress_chunk_split(&c, 0, workers) {
                Err(Error::ChecksumMismatch(_)) => {}
                other => panic!("workers {workers}: expected ChecksumMismatch, got {other:?}"),
            }
        }
    }

    #[test]
    fn split_decode_without_restarts_matches_serial() {
        let data = Dataset::Mc0.generate(64 * 1024);
        for kind in CodecKind::all() {
            let c = Container::compress_with_restarts(&data, kind, 16 * 1024, 0).unwrap();
            for i in 0..c.n_chunks() {
                assert_eq!(
                    decompress_chunk_split(&c, i, 4).unwrap(),
                    c.decompress_chunk(i).unwrap(),
                    "{kind:?} chunk {i}"
                );
            }
        }
    }

    #[test]
    fn mixed_container_parallel_and_split_match_serial() {
        let data = Dataset::Mc0.generate(200 * 1024);
        let kinds = CodecKind::all();
        let chunk_size = 32 * 1024;
        let mut index = Vec::new();
        let mut restarts = Vec::new();
        let mut chunk_codecs = Vec::new();
        let mut checksums = Vec::new();
        let mut payload = Vec::new();
        for (i, chunk) in data.chunks(chunk_size).enumerate() {
            let kind = kinds[i % kinds.len()];
            let (comp, points) =
                crate::codecs::compress_chunk_restarts(kind, chunk, 4096).unwrap();
            index.push(crate::format::container::ChunkEntry {
                comp_off: payload.len() as u64,
                comp_len: comp.len() as u64,
                uncomp_len: chunk.len() as u64,
            });
            restarts.push(points);
            chunk_codecs.push(kind);
            checksums.push(crate::format::hash::crc32c(chunk));
            payload.extend_from_slice(&comp);
        }
        let c = Container {
            codec: chunk_codecs[0],
            chunk_size,
            total_uncompressed: data.len() as u64,
            index,
            restarts,
            chunk_codecs,
            checksums,
            payload,
        };
        assert!(c.is_mixed());
        assert_eq!(decompress_parallel(&c, 4).unwrap(), data);
        for i in 0..c.n_chunks() {
            let serial = c.decompress_chunk(i).unwrap();
            for workers in [1, 4] {
                assert_eq!(
                    decompress_chunk_split(&c, i, workers).unwrap(),
                    serial,
                    "chunk {i} workers {workers}"
                );
            }
        }
        // A mixed container with any non-RLE chunk is off the hybrid path.
        let ex = Expander::cpu_only();
        assert!(decompress_hybrid(&c, 2, &ex).is_err());
    }

    #[test]
    fn split_decode_rejects_doctored_tables() {
        let data = Dataset::Mc0.generate(128 * 1024);
        for kind in CodecKind::all() {
            let c = Container::compress_with_restarts(&data, kind, 128 * 1024, 4096).unwrap();
            let comp = c.chunk_bytes(0).unwrap();
            let table = c.restart_table(0);
            assert!(table.len() >= 2, "{kind:?}");
            let serial = c.decompress_chunk(0).unwrap();
            let mut out = vec![0u8; serial.len()];
            // Perturbing any coordinate of a restart point must either
            // fail typed or (never here) still match serial — silence
            // with different bytes is the one forbidden outcome.
            for (j, delta) in [(0usize, 8i64), (1, -8), (table.len() - 1, 8)] {
                let mut t = table.to_vec();
                t[j].bit_pos = t[j].bit_pos.wrapping_add_signed(delta);
                match decode_chunk_parallel(kind, comp, &t, &mut out, 4) {
                    Err(Error::Corrupt(_)) => {}
                    Err(e) => panic!("{kind:?}: wrong error class {e:?}"),
                    Ok(()) => assert_eq!(out, serial, "{kind:?}: silent divergence"),
                }
            }
        }
    }
}
