//! Request routing: datasets → chunk sources → chunk work items.
//!
//! The registry holds loaded dataset sources — in-memory containers or
//! file-backed [`FileDataset`]s whose compressed chunks stay on disk
//! until fetched (DESIGN.md §9); the router translates byte-range
//! requests into chunk lists and picks workers by least outstanding
//! work — the same shape as a serving router in front of replicated
//! engines.

use crate::codecs::CodecKind;
use crate::format::container::{ChunkEntry, Container};
use crate::server::store::FileDataset;
use crate::{invalid, Result};
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};

/// A decompression request: a byte range of a named dataset.
#[derive(Debug, Clone)]
pub struct Request {
    /// Request id (caller-assigned, echoed in the response).
    pub id: u64,
    /// Registered dataset name.
    pub dataset: String,
    /// Uncompressed byte offset.
    pub offset: u64,
    /// Uncompressed byte length (0 = to end).
    pub len: u64,
}

/// Chunk-level work derived from a request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ChunkWork {
    /// Chunk index within the container.
    pub chunk: usize,
    /// Byte range *within the decompressed chunk* to return.
    pub lo: usize,
    /// Exclusive end within the decompressed chunk.
    pub hi: usize,
}

/// One serveable dataset: an in-memory container (the synthetic /
/// bench path) or a file-backed container whose compressed chunks are
/// fetched lazily from disk (`codag serve --data-dir`, DESIGN.md §9).
/// Both expose the same header + index view, so planning and the
/// decode path are source-agnostic.
#[derive(Debug)]
pub enum DatasetSource {
    /// Fully resident container (payload in memory).
    Memory(Container),
    /// On-disk container; only header + index are resident.
    File(FileDataset),
}

impl DatasetSource {
    /// The header codec (for a mixed v3 source: chunk 0's codec — use
    /// [`chunk_codec`](Self::chunk_codec) for per-chunk dispatch).
    pub fn codec(&self) -> CodecKind {
        match self {
            DatasetSource::Memory(c) => c.codec,
            DatasetSource::File(f) => f.codec(),
        }
    }

    /// The codec chunk `i` was compressed with (`codec()` for uniform
    /// sources).
    pub fn chunk_codec(&self, i: usize) -> CodecKind {
        match self {
            DatasetSource::Memory(c) => c.chunk_codec(i),
            DatasetSource::File(f) => f.chunk_codec(i),
        }
    }

    /// Nominal uncompressed chunk size.
    pub fn chunk_size(&self) -> usize {
        match self {
            DatasetSource::Memory(c) => c.chunk_size,
            DatasetSource::File(f) => f.chunk_size(),
        }
    }

    /// Total uncompressed length.
    pub fn total_uncompressed(&self) -> u64 {
        match self {
            DatasetSource::Memory(c) => c.total_uncompressed,
            DatasetSource::File(f) => f.total_uncompressed(),
        }
    }

    /// Per-chunk index.
    pub fn index(&self) -> &[ChunkEntry] {
        match self {
            DatasetSource::Memory(c) => &c.index,
            DatasetSource::File(f) => f.index(),
        }
    }

    /// Number of chunks.
    pub fn n_chunks(&self) -> usize {
        self.index().len()
    }

    /// Translate a byte-range request into per-chunk work items.
    pub fn plan(&self, offset: u64, len: u64) -> Result<Vec<ChunkWork>> {
        plan_dims(self.total_uncompressed(), self.chunk_size(), self.index(), offset, len)
    }

    /// Borrow the compressed bytes of chunk `i`: zero-copy from a
    /// resident payload, a lazy positioned read into `scratch` for a
    /// file-backed source.
    pub fn chunk_bytes<'a>(&'a self, i: usize, scratch: &'a mut Vec<u8>) -> Result<&'a [u8]> {
        match self {
            DatasetSource::Memory(c) => c.chunk_bytes(i),
            DatasetSource::File(f) => {
                f.read_chunk_into(i, scratch)?;
                Ok(&scratch[..])
            }
        }
    }

    /// Decompress chunk `i` into a caller-owned buffer (cleared first,
    /// capacity reused — the scratch-pool contract of DESIGN.md §7.3).
    pub fn decompress_chunk_into(&self, i: usize, out: &mut Vec<u8>) -> Result<()> {
        match self {
            DatasetSource::Memory(c) => c.decompress_chunk_into(i, out),
            DatasetSource::File(f) => f.decompress_chunk_into(i, out),
        }
    }

    /// The restart table of chunk `i` (empty when the source is a v1
    /// container or the chunk has no recorded boundaries).
    pub fn restart_table(&self, i: usize) -> &[crate::codecs::RestartPoint] {
        match self {
            DatasetSource::Memory(c) => c.restart_table(i),
            DatasetSource::File(f) => f.restart_table(i),
        }
    }

    /// The packed CRC-32C of chunk `i`'s uncompressed bytes (`None` for
    /// pre-v4 sources without content checksums). The service's
    /// `--paranoid` path re-verifies cache hits against this.
    pub fn chunk_checksum(&self, i: usize) -> Option<u32> {
        match self {
            DatasetSource::Memory(c) => c.chunk_checksum(i),
            DatasetSource::File(f) => f.chunk_checksum(i),
        }
    }

    /// Decompress chunk `i` by splitting its restart table across
    /// `n_workers` threads (DESIGN.md §7.5); byte-identical to
    /// [`decompress_chunk_into`](Self::decompress_chunk_into), and
    /// degrades to serial sub-block decode when the table is empty.
    pub fn decompress_chunk_split_into(
        &self,
        i: usize,
        n_workers: usize,
        out: &mut Vec<u8>,
    ) -> Result<()> {
        self.decompress_chunk_split_obs_into(i, n_workers, out, None)
    }

    /// [`decompress_chunk_split_into`](Self::decompress_chunk_split_into)
    /// with optional stitch fan-out/join timing (DESIGN.md §10).
    pub fn decompress_chunk_split_obs_into(
        &self,
        i: usize,
        n_workers: usize,
        out: &mut Vec<u8>,
        obs: Option<crate::obs::StitchTimers<'_>>,
    ) -> Result<()> {
        match self {
            DatasetSource::Memory(c) => {
                super::engine::decompress_chunk_split_obs_into(c, i, n_workers, out, obs)
            }
            DatasetSource::File(f) => f.decompress_chunk_split_obs_into(i, n_workers, out, obs),
        }
    }
}

/// Registry of loaded dataset sources.
#[derive(Debug, Default)]
pub struct Registry {
    containers: HashMap<String, DatasetSource>,
}

impl Registry {
    /// Empty registry.
    pub fn new() -> Self {
        Self::default()
    }

    /// Register an in-memory container under `name` (replaces any
    /// previous source of that name).
    pub fn insert(&mut self, name: impl Into<String>, c: Container) {
        self.containers.insert(name.into(), DatasetSource::Memory(c));
    }

    /// Register any dataset source (e.g. a file-backed container from
    /// `codag serve --data-dir`) under `name`.
    pub fn insert_source(&mut self, name: impl Into<String>, s: DatasetSource) {
        self.containers.insert(name.into(), s);
    }

    /// Look up a dataset source.
    pub fn get(&self, name: &str) -> Result<&DatasetSource> {
        self.containers
            .get(name)
            .ok_or_else(|| invalid(format!("dataset '{name}' not registered")))
    }

    /// Registered names (sorted).
    pub fn names(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.containers.keys().map(|s| s.as_str()).collect();
        v.sort();
        v
    }

    /// Iterate every registered source (unordered) — the daemon uses
    /// this to attach per-dataset metrics handles at startup.
    pub fn sources(&self) -> impl Iterator<Item = (&str, &DatasetSource)> {
        self.containers.iter().map(|(k, v)| (k.as_str(), v))
    }
}

/// Translate a request into per-chunk work items (in-memory container
/// convenience; the daemon path goes through [`DatasetSource::plan`]).
pub fn plan(container: &Container, offset: u64, len: u64) -> Result<Vec<ChunkWork>> {
    plan_dims(container.total_uncompressed, container.chunk_size, &container.index, offset, len)
}

/// Source-agnostic request planning over a container's dimensions.
pub fn plan_dims(
    total: u64,
    chunk_size: usize,
    index: &[ChunkEntry],
    offset: u64,
    len: u64,
) -> Result<Vec<ChunkWork>> {
    if offset > total {
        return Err(invalid(format!("offset {offset} beyond dataset end {total}")));
    }
    // Saturating: offset/len come straight off the wire in the daemon
    // path, and `offset + len` must not overflow on hostile input.
    let end = if len == 0 { total } else { offset.saturating_add(len).min(total) };
    if index.is_empty() {
        return Ok(Vec::new());
    }
    let cs = chunk_size as u64;
    if cs == 0 {
        return Err(invalid("container chunk_size is zero"));
    }
    let mut work = Vec::new();
    let first = (offset / cs) as usize;
    let last = if end == offset { first } else { ((end - 1) / cs) as usize };
    for chunk in first..=last.min(index.len().saturating_sub(1)) {
        let chunk_lo = chunk as u64 * cs;
        let chunk_len = index[chunk].uncomp_len;
        let lo = offset.max(chunk_lo) - chunk_lo;
        let hi = (end.min(chunk_lo + chunk_len)) - chunk_lo;
        if hi > lo {
            work.push(ChunkWork { chunk, lo: lo as usize, hi: hi as usize });
        }
    }
    Ok(work)
}

/// Least-outstanding-work worker picker.
#[derive(Debug)]
pub struct LeastLoaded {
    outstanding: Vec<AtomicU64>,
}

impl LeastLoaded {
    /// Picker over `n` workers.
    pub fn new(n: usize) -> Self {
        LeastLoaded { outstanding: (0..n.max(1)).map(|_| AtomicU64::new(0)).collect() }
    }

    /// Pick the worker with the least outstanding bytes and charge it.
    pub fn pick(&self, bytes: u64) -> usize {
        let mut best = 0usize;
        let mut best_v = u64::MAX;
        for (i, a) in self.outstanding.iter().enumerate() {
            let v = a.load(Ordering::Relaxed);
            if v < best_v {
                best_v = v;
                best = i;
            }
        }
        self.outstanding[best].fetch_add(bytes, Ordering::Relaxed);
        best
    }

    /// Credit a worker when its work completes.
    pub fn complete(&self, worker: usize, bytes: u64) {
        self.outstanding[worker].fetch_sub(bytes.min(
            self.outstanding[worker].load(Ordering::Relaxed),
        ), Ordering::Relaxed);
    }

    /// Number of workers.
    pub fn len(&self) -> usize {
        self.outstanding.len()
    }

    /// Never empty (n clamped to ≥ 1).
    pub fn is_empty(&self) -> bool {
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codecs::CodecKind;

    fn sample_container() -> Container {
        let data: Vec<u8> = (0..10_000u32).map(|i| (i % 251) as u8).collect();
        Container::compress(&data, CodecKind::Deflate, 4096).unwrap()
    }

    #[test]
    fn plan_whole_dataset() {
        let c = sample_container();
        let w = plan(&c, 0, 0).unwrap();
        assert_eq!(w.len(), c.n_chunks());
        assert_eq!(w[0], ChunkWork { chunk: 0, lo: 0, hi: 4096 });
        assert_eq!(w[2].hi, 10_000 - 2 * 4096);
    }

    #[test]
    fn plan_sub_range_crossing_chunks() {
        let c = sample_container();
        let w = plan(&c, 4000, 300).unwrap();
        assert_eq!(w.len(), 2);
        assert_eq!(w[0], ChunkWork { chunk: 0, lo: 4000, hi: 4096 });
        assert_eq!(w[1], ChunkWork { chunk: 1, lo: 0, hi: 204 });
    }

    #[test]
    fn plan_range_within_one_chunk() {
        let c = sample_container();
        let w = plan(&c, 5000, 10).unwrap();
        assert_eq!(w, vec![ChunkWork { chunk: 1, lo: 904, hi: 914 }]);
    }

    #[test]
    fn plan_rejects_bad_offset() {
        let c = sample_container();
        assert!(plan(&c, 999_999, 1).is_err());
        assert!(plan(&c, 10_000, 0).unwrap().is_empty());
    }

    #[test]
    fn plan_clamps_hostile_len_without_overflow() {
        // Wire-reachable input: offset + len would overflow u64; the
        // plan must clamp to the dataset end, not panic or wrap.
        let c = sample_container();
        let w = plan(&c, 1, u64::MAX).unwrap();
        assert_eq!(w.len(), c.n_chunks());
        assert_eq!(w[0], ChunkWork { chunk: 0, lo: 1, hi: 4096 });
        assert_eq!(w.last().unwrap().hi, 10_000 - 2 * 4096);
    }

    #[test]
    fn registry_lookup() {
        let mut r = Registry::new();
        r.insert("taxi", sample_container());
        assert!(r.get("taxi").is_ok());
        assert!(r.get("nope").is_err());
        assert_eq!(r.names(), vec!["taxi"]);
    }

    #[test]
    fn least_loaded_balances() {
        let ll = LeastLoaded::new(3);
        let a = ll.pick(100);
        let b = ll.pick(100);
        let c = ll.pick(100);
        // Three picks land on three distinct workers.
        let mut seen = vec![a, b, c];
        seen.sort_unstable();
        seen.dedup();
        assert_eq!(seen.len(), 3);
        ll.complete(a, 100);
        assert_eq!(ll.pick(1), a);
    }
}
