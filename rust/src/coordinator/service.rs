//! The serving loop: a self-contained decompression service.
//!
//! Requests enter through an mpsc channel, a router thread plans them
//! into chunk work, a worker pool decodes (CPU or hybrid-PJRT path),
//! and responses are delivered through per-request channels. This is
//! the L3 "request path" the paper's framework sits behind in a data
//! analytics pipeline — Python is never involved.

use crate::coordinator::router::{ChunkWork, Registry, Request};
use crate::coordinator::stats::LatencyStats;
use crate::obs::{now_if_enabled, DatasetMetrics, MetricsRegistry, Stage};
use crate::runtime::Expander;
use crate::server::cache::ChunkCache;
use crate::{Error, Result};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Marker message for a request whose deadline expired before (or
/// while) its chunks were decoded. The daemon maps exactly this error
/// onto the wire `Expired` status (DESIGN.md §6.3); it is a
/// `Runtime` error so no decode-failure status can be confused with
/// cancellation.
pub const DEADLINE_EXPIRED: &str = "request deadline expired";

/// A completed response.
#[derive(Debug)]
pub struct Response {
    /// Echoed request id.
    pub id: u64,
    /// The decompressed byte range (or error).
    pub data: Result<Vec<u8>>,
    /// Service-side latency.
    pub latency: std::time::Duration,
}

/// A response payload: either an owned buffer or a borrowed span of a
/// cached decompressed chunk (`Arc<[u8]>` plus a `lo..hi` range).
///
/// The shared form is what makes the daemon's cache-hit path zero-copy
/// end to end: the bytes travel from the chunk cache to the socket
/// (one vectored write of header + payload, DESIGN.md §11) without an
/// intermediate per-response assembly buffer. Constructors uphold
/// `lo <= hi <= chunk.len()`, so `as_slice` cannot panic.
#[derive(Debug, Clone)]
pub enum Payload {
    /// An owned buffer (multi-chunk assembly, uncached decode slices).
    Owned(Vec<u8>),
    /// A span of a shared decompressed chunk.
    Shared {
        /// The full decoded chunk, shared with the cache.
        chunk: Arc<[u8]>,
        /// Span start (inclusive byte offset into `chunk`).
        lo: usize,
        /// Span end (exclusive byte offset into `chunk`).
        hi: usize,
    },
}

impl Payload {
    /// An empty owned payload.
    pub fn empty() -> Payload {
        Payload::Owned(Vec::new())
    }

    /// The payload bytes.
    pub fn as_slice(&self) -> &[u8] {
        match self {
            Payload::Owned(v) => v,
            Payload::Shared { chunk, lo, hi } => &chunk[*lo..*hi],
        }
    }

    /// Payload length in bytes.
    pub fn len(&self) -> usize {
        match self {
            Payload::Owned(v) => v.len(),
            Payload::Shared { lo, hi, .. } => hi - lo,
        }
    }

    /// True when the payload carries no bytes.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Convert into an owned `Vec` (copies only the shared form).
    pub fn into_vec(self) -> Vec<u8> {
        match self {
            Payload::Owned(v) => v,
            Payload::Shared { chunk, lo, hi } => chunk[lo..hi].to_vec(),
        }
    }

    /// Mutable access to an owned buffer, converting a shared span
    /// into an owned copy first (the multi-chunk assembly path).
    fn owned_mut(&mut self) -> &mut Vec<u8> {
        let copied = match self {
            Payload::Owned(_) => None,
            Payload::Shared { chunk, lo, hi } => Some(chunk[*lo..*hi].to_vec()),
        };
        if let Some(v) = copied {
            *self = Payload::Owned(v);
        }
        match self {
            Payload::Owned(v) => v,
            Payload::Shared { .. } => unreachable!("converted to owned above"),
        }
    }
}

impl From<Vec<u8>> for Payload {
    fn from(v: Vec<u8>) -> Payload {
        Payload::Owned(v)
    }
}

/// A completed response whose payload may borrow a cached chunk
/// ([`Payload::Shared`]) — the form the daemon's evented write path
/// consumes. [`Response`] is the owned-`Vec` compatibility view.
#[derive(Debug)]
pub struct SharedResponse {
    /// Echoed request id.
    pub id: u64,
    /// The decompressed byte range (or error).
    pub data: Result<Payload>,
    /// Service-side latency.
    pub latency: std::time::Duration,
}

/// Service configuration.
#[derive(Debug, Clone, Copy)]
pub struct ServiceConfig {
    /// Worker threads decoding chunks.
    pub workers: usize,
    /// Use the hybrid PJRT path for RLE containers when an expander is
    /// available.
    pub hybrid: bool,
    /// Re-verify content checksums on cache *hits* too (`--paranoid`):
    /// every Get re-CRCs the cached chunk against the checksum recorded
    /// at pack time, catching in-memory corruption after the fill-time
    /// verification that cache misses always get. Off by default — the
    /// hit path stays zero-cost and trusts the verified fill.
    pub paranoid: bool,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig { workers: 4, hybrid: false, paranoid: false }
    }
}

/// A synchronous decompression service over a registry of containers.
///
/// `serve_batch` processes a closed set of requests with a worker pool
/// and returns all responses plus latency statistics — the form every
/// bench and the analytics example use. The long-running daemon
/// (`server::daemon`, the CLI's `codag serve --port`) wraps this same
/// core behind per-dataset shard queues and a chunk cache.
pub struct Service<'a> {
    registry: &'a Registry,
    expander: Option<&'a Expander<'a>>,
    config: ServiceConfig,
    cache: Option<&'a ChunkCache>,
    /// Pool of reusable decode scratch buffers. Each worker checks one
    /// out for the duration of a batch and decodes every chunk into it
    /// (`Container::decompress_chunk_into`), so a long-lived service —
    /// the daemon's per-shard `Service` — allocates no per-request
    /// output `Vec` in steady state: buffers grow to the hot chunk size
    /// once and are recycled across batches.
    scratch: Mutex<Vec<Vec<u8>>>,
    /// Per-dataset stage metrics (DESIGN.md §10): cache lookup/admit
    /// timing, serial-decode vs stitch fan-out/join split, decoded-byte
    /// and hit/miss counters. `None` outside the daemon path.
    metrics: Option<Arc<MetricsRegistry>>,
}

/// Scratch buffers retained in the pool (beyond this, returned buffers
/// are dropped — a bound on idle memory, not on concurrency).
const SCRATCH_POOL_CAP: usize = 32;

impl<'a> Service<'a> {
    /// New service over `registry`.
    pub fn new(
        registry: &'a Registry,
        expander: Option<&'a Expander<'a>>,
        config: ServiceConfig,
    ) -> Self {
        Service {
            registry,
            expander,
            config,
            cache: None,
            scratch: Mutex::new(Vec::new()),
            metrics: None,
        }
    }

    /// Check a scratch buffer out of the pool (empty, capacity warm).
    fn take_scratch(&self) -> Vec<u8> {
        self.scratch.lock().unwrap().pop().unwrap_or_default()
    }

    /// Return a scratch buffer to the pool for the next batch.
    fn put_scratch(&self, mut buf: Vec<u8>) {
        buf.clear();
        let mut pool = self.scratch.lock().unwrap();
        if pool.len() < SCRATCH_POOL_CAP {
            pool.push(buf);
        }
    }

    /// Attach a decompressed-chunk cache: full chunks are looked up
    /// before decoding and inserted after (the daemon path — see
    /// `server::daemon`).
    pub fn with_cache(mut self, cache: &'a ChunkCache) -> Self {
        self.cache = Some(cache);
        self
    }

    /// Attach a metrics registry: per-dataset cache lookup/admit,
    /// serial-decode, and stitch fan-out/join stages are timed on every
    /// decode (the daemon path — DESIGN.md §10).
    pub fn with_metrics(mut self, metrics: Arc<MetricsRegistry>) -> Self {
        self.metrics = Some(metrics);
        self
    }

    /// Serve a batch of requests; returns responses (same order) and
    /// aggregate latency stats.
    pub fn serve_batch(&self, requests: &[Request]) -> (Vec<Response>, LatencyStats) {
        self.serve_batch_with(requests, |_| false)
    }

    /// [`Service::serve_batch`] with a cancellation probe: `expired(ri)`
    /// is consulted before each of request `ri`'s chunk items is
    /// decoded, so a request whose deadline lapses mid-batch stops
    /// consuming decode work between items. A cancelled request's
    /// response is `Err(Error::Runtime(`[`DEADLINE_EXPIRED`]`))`.
    pub fn serve_batch_with<F>(
        &self,
        requests: &[Request],
        expired: F,
    ) -> (Vec<Response>, LatencyStats)
    where
        F: Fn(usize) -> bool + Sync,
    {
        let (shared, stats) = self.serve_batch_shared_with(requests, expired);
        let responses = shared
            .into_iter()
            .map(|r| Response { id: r.id, data: r.data.map(Payload::into_vec), latency: r.latency })
            .collect();
        (responses, stats)
    }

    /// The core of [`Service::serve_batch_with`], returning
    /// [`SharedResponse`]s: a request whose span lives in exactly one
    /// chunk passes its payload through un-assembled, so a cache hit
    /// stays a shared `Arc` slice ([`Payload::Shared`]) all the way to
    /// the caller — the daemon's evented front writes it straight to
    /// the socket with no assembly copy. Multi-chunk requests
    /// concatenate into an owned buffer as before.
    pub fn serve_batch_shared_with<F>(
        &self,
        requests: &[Request],
        expired: F,
    ) -> (Vec<SharedResponse>, LatencyStats)
    where
        F: Fn(usize) -> bool + Sync,
    {
        // Plan every request into (request, chunk work) units.
        #[derive(Debug)]
        struct Item {
            req_idx: usize,
            work: ChunkWork,
            dataset: String,
        }
        let mut items = Vec::new();
        let mut plans: Vec<Result<usize>> = Vec::new(); // per-request chunk count
        for (ri, r) in requests.iter().enumerate() {
            match self.registry.get(&r.dataset).and_then(|c| c.plan(r.offset, r.len)) {
                Ok(work) => {
                    plans.push(Ok(work.len()));
                    for w in work {
                        items.push(Item { req_idx: ri, work: w, dataset: r.dataset.clone() });
                    }
                }
                Err(e) => plans.push(Err(e)),
            }
        }
        let started: Vec<Instant> = requests.iter().map(|_| Instant::now()).collect();
        // Decode all items with a shared-cursor pool. Single-item (or
        // single-worker) batches decode inline: the daemon's shard
        // loops call this per batch, and a thread spawn/join per
        // request would dominate small-request latency.
        let cursor = std::sync::atomic::AtomicUsize::new(0);
        let slots: Vec<Mutex<Option<Result<Payload>>>> =
            items.iter().map(|_| Mutex::new(None)).collect();
        let items = &items;
        let slots_ref = &slots;
        let expired = &expired;
        if items.len() <= 1 || self.config.workers.max(1) == 1 {
            // Inline decode leaves the worker pool idle, so hand the
            // whole worker budget to the restart-point stitcher: a
            // single hot chunk splits across `workers` threads instead
            // of decoding on one (DESIGN.md §7.5).
            let mut scratch = self.take_scratch();
            for (i, item) in items.iter().enumerate() {
                let out = if expired(item.req_idx) {
                    Err(Error::Runtime(DEADLINE_EXPIRED.into()))
                } else {
                    self.decode_item(
                        &item.dataset,
                        item.work,
                        self.config.workers.max(1),
                        &mut scratch,
                    )
                };
                *slots_ref[i].lock().unwrap() = Some(out);
            }
            self.put_scratch(scratch);
        } else {
            std::thread::scope(|s| {
                for _ in 0..self.config.workers.max(1).min(items.len()) {
                    s.spawn(|| {
                        let mut scratch = self.take_scratch();
                        loop {
                            let i = cursor.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                            if i >= items.len() {
                                break;
                            }
                            let item = &items[i];
                            let out = if expired(item.req_idx) {
                                Err(Error::Runtime(DEADLINE_EXPIRED.into()))
                            } else {
                                // The pool already saturates the
                                // workers with chunk-level parallelism;
                                // each item decodes serially.
                                self.decode_item(&item.dataset, item.work, 1, &mut scratch)
                            };
                            *slots_ref[i].lock().unwrap() = Some(out);
                        }
                        self.put_scratch(scratch);
                    });
                }
            });
        }
        // Assemble responses in request order. A single-chunk request
        // adopts its one piece unconverted (the zero-copy pass-through);
        // multi-chunk requests concatenate into an owned accumulator.
        let mut per_req: Vec<Result<Payload>> = plans
            .iter()
            .map(|p| match p {
                Ok(_) => Ok(Payload::empty()),
                Err(e) => Err(e.clone()),
            })
            .collect();
        for (i, item) in items.iter().enumerate() {
            let piece = slots[i]
                .lock()
                .unwrap()
                .take()
                .unwrap_or_else(|| Err(Error::Runtime("missing piece".into())));
            let single = matches!(plans[item.req_idx], Ok(1));
            if let Ok(acc) = per_req[item.req_idx].as_mut() {
                match piece {
                    Ok(p) if single => *acc = p,
                    Ok(p) => acc.owned_mut().extend_from_slice(p.as_slice()),
                    Err(e) => per_req[item.req_idx] = Err(e),
                }
            }
        }
        let mut stats = LatencyStats::new();
        let responses: Vec<SharedResponse> = per_req
            .into_iter()
            .enumerate()
            .map(|(ri, data)| {
                let latency = started[ri].elapsed();
                if let Ok(d) = &data {
                    stats.record(latency, d.len() as u64);
                }
                SharedResponse { id: requests[ri].id, data, latency }
            })
            .collect();
        (responses, stats)
    }

    /// Decode one chunk work item, reusing `scratch` as the decode
    /// output buffer. Chunks the cache retains are copied out of the
    /// scratch into an `Arc<[u8]>` exactly once, and both the cache-hit
    /// and the freshly-admitted paths return a shared span of that
    /// `Arc` ([`Payload::Shared`] — no per-response slice copy);
    /// uncached decodes slice the span out of the scratch.
    ///
    /// `split_workers > 1` routes the decode through the restart-point
    /// stitcher when the chunk has a restart table (container v2): the
    /// sub-blocks split across that many threads and land in disjoint
    /// slices of `scratch`, byte-identical to the serial decode before
    /// anything reaches the cache or the response.
    fn decode_item(
        &self,
        dataset: &str,
        w: ChunkWork,
        split_workers: usize,
        scratch: &mut Vec<u8>,
    ) -> Result<Payload> {
        // One registry resolve per item; all stage recording below goes
        // through this lock-free handle.
        let dm = if crate::obs::ENABLED {
            self.metrics.as_ref().map(|r| r.dataset(dataset))
        } else {
            None
        };
        if let Some(cache) = self.cache {
            let t0 = now_if_enabled();
            let found = cache.get(dataset, w.chunk);
            if let (Some(t0), Some(m)) = (t0, &dm) {
                m.stage(Stage::CacheLookup).record(t0.elapsed());
            }
            if let Some(full) = found {
                if let Some(m) = &dm {
                    m.cache_hits.inc();
                }
                if self.config.paranoid {
                    let want = self.registry.get(dataset)?.chunk_checksum(w.chunk);
                    verify_full_chunk(want, w.chunk, &full, dm.as_deref())?;
                }
                return shared_slice(&full, w);
            }
            if let Some(m) = &dm {
                m.cache_misses.inc();
            }
        }
        let c = self.registry.get(dataset)?;
        // Per-chunk codec (mixed v3 containers): the hybrid gate and the
        // decode dispatch both follow the chunk, not the header.
        let chunk_kind = c.chunk_codec(w.chunk);
        let use_hybrid = self.config.hybrid && chunk_kind.is_rle() && self.expander.is_some();
        if use_hybrid {
            // The expand path produces its own buffer (PJRT output);
            // compressed bytes borrow from the resident payload or a
            // lazy file read into a local scratch (DatasetSource).
            // This path is cold by construction (the daemon runs
            // hybrid: false), so the per-item scratch is acceptable.
            let mut comp_scratch = Vec::new();
            let t0 = now_if_enabled();
            let full = crate::coordinator::engine::decode_chunk_hybrid(
                chunk_kind,
                c.chunk_bytes(w.chunk, &mut comp_scratch)?,
                self.expander.expect("checked"),
            )?;
            if let (Some(t0), Some(m)) = (t0, &dm) {
                m.stage(Stage::DecodeSerial).record(t0.elapsed());
            }
            // The expand path bypasses Container::decompress_chunk_into,
            // so it carries its own content verification.
            verify_full_chunk(c.chunk_checksum(w.chunk), w.chunk, &full, dm.as_deref())?;
            if let Some(m) = &dm {
                m.decoded_bytes.add(full.len() as u64);
            }
            if let Some(r) = self.try_cache(dataset, w, &full, dm.as_deref()) {
                return r;
            }
            return if w.lo == 0 && w.hi == full.len() {
                Ok(Payload::Owned(full))
            } else {
                slice_chunk(&full, w)
            };
        }
        let decoded = if split_workers > 1 && !c.restart_table(w.chunk).is_empty() {
            c.decompress_chunk_split_obs_into(
                w.chunk,
                split_workers,
                scratch,
                dm.as_ref().map(|m| m.stitch_timers()),
            )
        } else {
            let t0 = now_if_enabled();
            let r = c.decompress_chunk_into(w.chunk, scratch);
            if let (Some(t0), Some(m)) = (t0, &dm) {
                m.stage(Stage::DecodeSerial).record(t0.elapsed());
            }
            r
        };
        if let Err(Error::ChecksumMismatch(_)) = &decoded {
            if let Some(m) = &dm {
                m.integrity_failures.inc();
            }
        }
        decoded?;
        if let Some(m) = &dm {
            m.decoded_bytes.add(scratch.len() as u64);
        }
        if let Some(r) = self.try_cache(dataset, w, scratch, dm.as_deref()) {
            return r;
        }
        slice_chunk(scratch, w)
    }

    /// Shared caching tail of [`Service::decode_item`]: when the
    /// admission policy retains this freshly decoded chunk (ghost-LRU:
    /// second touch of a key admits — see `server::cache`), pay the
    /// `Arc` build exactly once, insert, and return the response span
    /// as a shared slice of that `Arc` (no second copy). `None` means
    /// "not cached; slice from the decode buffer instead" — keeping
    /// both decode paths on the one documented admission protocol.
    fn try_cache(
        &self,
        dataset: &str,
        w: ChunkWork,
        full: &[u8],
        dm: Option<&DatasetMetrics>,
    ) -> Option<Result<Payload>> {
        let cache = self.cache?;
        if !cache.admit(dataset, w.chunk, full.len()) {
            return None;
        }
        // The `cache_admit` stage times only admitted inserts (the Arc
        // build + insert); declined touches cost an admission probe and
        // are not samples of this histogram.
        let t0 = now_if_enabled();
        let shared: Arc<[u8]> = Arc::from(full);
        cache.insert(dataset, w.chunk, shared.clone());
        if let (Some(t0), Some(m)) = (t0, dm) {
            m.stage(Stage::CacheAdmit).record(t0.elapsed());
        }
        Some(shared_slice(&shared, w))
    }
}

/// Re-verify a full decoded chunk against the checksum recorded at pack
/// time (`--paranoid` cache-hit re-checks and the hybrid expand path,
/// which bypasses the container's own fill-time verification). `None`
/// means the container predates v4 — nothing to check.
fn verify_full_chunk(
    want: Option<u32>,
    chunk: usize,
    full: &[u8],
    dm: Option<&DatasetMetrics>,
) -> Result<()> {
    let Some(want) = want else { return Ok(()) };
    let got = crate::format::hash::crc32c(full);
    if got == want {
        return Ok(());
    }
    if let Some(m) = dm {
        m.integrity_failures.inc();
    }
    Err(Error::ChecksumMismatch(format!(
        "chunk {chunk}: content crc32c {got:08x}, packed {want:08x}"
    )))
}

/// Copy the requested sub-range out of a decoded chunk.
fn slice_chunk(full: &[u8], w: ChunkWork) -> Result<Payload> {
    full.get(w.lo..w.hi)
        .map(|s| Payload::Owned(s.to_vec()))
        .ok_or_else(|| Error::Runtime("range outside decoded chunk".into()))
}

/// Borrow the requested sub-range of a shared decoded chunk without
/// copying (the zero-copy cache path; same bounds rule and error as
/// [`slice_chunk`]).
fn shared_slice(full: &Arc<[u8]>, w: ChunkWork) -> Result<Payload> {
    if w.lo <= w.hi && w.hi <= full.len() {
        Ok(Payload::Shared { chunk: Arc::clone(full), lo: w.lo, hi: w.hi })
    } else {
        Err(Error::Runtime("range outside decoded chunk".into()))
    }
}

/// Convenience: run requests through a fresh service via channels — the
/// daemon-shaped API (used by the CLI's serve loop).
pub fn serve_channel(
    registry: Arc<Registry>,
    config: ServiceConfig,
    rx: mpsc::Receiver<Request>,
    tx: mpsc::Sender<Response>,
) {
    // Collect until the sender closes, then serve as one batch per
    // received burst (simple store-and-forward loop; latency-sensitive
    // callers use Service::serve_batch directly). One service is built
    // up front and reused across bursts (decode threads are still
    // scoped per serve_batch call; single-item batches decode inline).
    let service = Service::new(&registry, None, config);
    while let Ok(first) = rx.recv() {
        let mut batch = vec![first];
        while let Ok(r) = rx.try_recv() {
            batch.push(r);
        }
        let (responses, _) = service.serve_batch(&batch);
        for r in responses {
            if tx.send(r).is_err() {
                return;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codecs::CodecKind;
    use crate::data::Dataset;
    use crate::format::container::Container;

    fn registry() -> (Vec<u8>, Registry) {
        let data = Dataset::Tpc.generate(300 * 1024);
        let c = Container::compress(&data, CodecKind::RleV1, 32 * 1024).unwrap();
        let mut reg = Registry::new();
        reg.insert("tpc", c);
        (data, reg)
    }

    #[test]
    fn serve_full_and_ranged_requests() {
        let (data, reg) = registry();
        let svc = Service::new(&reg, None, ServiceConfig { workers: 4, hybrid: false, paranoid: false });
        let reqs = vec![
            Request { id: 1, dataset: "tpc".into(), offset: 0, len: 0 },
            Request { id: 2, dataset: "tpc".into(), offset: 100_000, len: 5000 },
            Request { id: 3, dataset: "missing".into(), offset: 0, len: 1 },
        ];
        let (resp, stats) = svc.serve_batch(&reqs);
        assert_eq!(resp.len(), 3);
        assert_eq!(resp[0].data.as_ref().unwrap(), &data);
        assert_eq!(resp[1].data.as_ref().unwrap(), &data[100_000..105_000]);
        assert!(resp[2].data.is_err());
        assert_eq!(stats.count(), 2);
    }

    #[test]
    fn hybrid_service_matches_cpu() {
        let (data, reg) = registry();
        let ex = Expander::cpu_only();
        let svc = Service::new(&reg, Some(&ex), ServiceConfig { workers: 2, hybrid: true, paranoid: false });
        let reqs =
            vec![Request { id: 9, dataset: "tpc".into(), offset: 65_000, len: 70_000 }];
        let (resp, _) = svc.serve_batch(&reqs);
        assert_eq!(resp[0].data.as_ref().unwrap(), &data[65_000..135_000]);
    }

    #[test]
    fn cached_service_matches_and_hits() {
        let (data, reg) = registry();
        let cache = ChunkCache::new(8 << 20, 2);
        let svc = Service::new(&reg, None, ServiceConfig { workers: 2, hybrid: false, paranoid: false })
            .with_cache(&cache);
        let req = Request { id: 1, dataset: "tpc".into(), offset: 40_000, len: 8_000 };
        // Ghost-LRU admission: the first touch of a chunk key is
        // declined (recorded in the ghost), the second touch admits,
        // the third read is a cache hit.
        let (resp, _) = svc.serve_batch(std::slice::from_ref(&req));
        assert_eq!(resp[0].data.as_ref().unwrap(), &data[40_000..48_000]);
        assert!(cache.misses() >= 1);
        assert!(cache.admit_declines() >= 1, "first touch must be declined by admission");
        let (resp, _) = svc.serve_batch(std::slice::from_ref(&req));
        assert_eq!(resp[0].data.as_ref().unwrap(), &data[40_000..48_000]);
        assert!(cache.ghost_hits() >= 1, "second touch must admit via the ghost");
        let before_hits = cache.hits();
        let (resp, _) = svc.serve_batch(&[req]);
        assert_eq!(resp[0].data.as_ref().unwrap(), &data[40_000..48_000]);
        assert!(cache.hits() > before_hits, "third identical read must hit the cache");
    }

    #[test]
    fn cache_hit_passes_shared_payload_through_unassembled() {
        // The zero-copy contract (DESIGN.md §11): a single-chunk cache
        // hit must surface as a `Payload::Shared` span of the cached
        // Arc (no assembly copy), while a request spanning two chunks
        // assembles into an owned buffer. Three touches: decline,
        // admit, hit (ghost-LRU).
        let (data, reg) = registry();
        let cache = ChunkCache::new(8 << 20, 2);
        let svc = Service::new(&reg, None, ServiceConfig { workers: 2, hybrid: false, paranoid: false })
            .with_cache(&cache);
        let req = Request { id: 1, dataset: "tpc".into(), offset: 40_000, len: 8_000 };
        for _ in 0..2 {
            let (resp, _) = svc.serve_batch_shared_with(std::slice::from_ref(&req), |_| false);
            assert_eq!(resp[0].data.as_ref().unwrap().as_slice(), &data[40_000..48_000]);
        }
        // Third read: a hit, and the admitted insert means the whole
        // span is one shared slice of the cached chunk.
        let (resp, _) = svc.serve_batch_shared_with(std::slice::from_ref(&req), |_| false);
        let payload = resp[0].data.as_ref().unwrap();
        assert_eq!(payload.as_slice(), &data[40_000..48_000]);
        assert!(
            matches!(payload, Payload::Shared { .. }),
            "single-chunk cache hit must stay a shared span, got {payload:?}"
        );
        // A span crossing a 32 KiB chunk boundary assembles owned.
        let wide = Request { id: 2, dataset: "tpc".into(), offset: 30_000, len: 8_000 };
        let (resp, _) = svc.serve_batch_shared_with(std::slice::from_ref(&wide), |_| false);
        let payload = resp[0].data.as_ref().unwrap();
        assert_eq!(payload.as_slice(), &data[30_000..38_000]);
        assert!(matches!(payload, Payload::Owned(_)), "multi-chunk spans assemble owned");
    }

    #[test]
    fn paranoid_mode_recrcs_cache_hits_and_catches_poisoned_chunks() {
        let (data, reg) = registry();
        let cache = ChunkCache::new(8 << 20, 2);
        let svc = Service::new(
            &reg,
            None,
            ServiceConfig { workers: 2, hybrid: false, paranoid: true },
        )
        .with_cache(&cache);
        let req = Request { id: 1, dataset: "tpc".into(), offset: 40_000, len: 8_000 };
        // Ghost-LRU warm-up: decline, admit, hit — every read must still
        // serve correct bytes with the paranoid re-check on.
        for _ in 0..3 {
            let (resp, _) = svc.serve_batch(std::slice::from_ref(&req));
            assert_eq!(resp[0].data.as_ref().unwrap(), &data[40_000..48_000]);
        }
        // Poison the cached chunk in place (simulated memory corruption
        // after a verified fill). A default service trusts the cache and
        // serves the wrong bytes; paranoid must refuse.
        let mut bad = cache.get("tpc", 1).expect("chunk 1 cached").to_vec();
        bad[100] ^= 0x01;
        cache.insert("tpc", 1, bad.into());
        let trusting = Service::new(
            &reg,
            None,
            ServiceConfig { workers: 2, hybrid: false, paranoid: false },
        )
        .with_cache(&cache);
        let (resp, _) = trusting.serve_batch(std::slice::from_ref(&req));
        assert!(resp[0].data.is_ok(), "default hit path trusts the fill-time check");
        assert_ne!(resp[0].data.as_ref().unwrap(), &data[40_000..48_000]);
        let (resp, _) = svc.serve_batch(std::slice::from_ref(&req));
        assert!(
            matches!(resp[0].data, Err(Error::ChecksumMismatch(_))),
            "paranoid hit must fail typed, got {:?}",
            resp[0].data
        );
    }

    #[test]
    fn serve_batch_with_cancels_expired_requests() {
        let (data, reg) = registry();
        let svc = Service::new(&reg, None, ServiceConfig { workers: 2, hybrid: false, paranoid: false });
        let reqs = vec![
            Request { id: 1, dataset: "tpc".into(), offset: 0, len: 1000 },
            Request { id: 2, dataset: "tpc".into(), offset: 0, len: 1000 },
        ];
        // Request 1 is cancelled before any of its items decode.
        let (resp, stats) = svc.serve_batch_with(&reqs, |ri| ri == 1);
        assert_eq!(resp[0].data.as_ref().unwrap(), &data[..1000]);
        assert_eq!(resp[1].data, Err(Error::Runtime(DEADLINE_EXPIRED.into())));
        // Cancelled requests are not recorded as served.
        assert_eq!(stats.count(), 1);
    }

    #[test]
    fn scratch_pool_reuses_buffers_across_batches() {
        let (data, reg) = registry();
        let svc = Service::new(&reg, None, ServiceConfig { workers: 1, hybrid: false, paranoid: false });
        let req = Request { id: 1, dataset: "tpc".into(), offset: 10, len: 100 };
        for _ in 0..3 {
            let (resp, _) = svc.serve_batch(std::slice::from_ref(&req));
            assert_eq!(resp[0].data.as_ref().unwrap(), &data[10..110]);
        }
        // One inline worker -> exactly one pooled buffer, kept warm
        // (grown capacity) and reused each batch instead of a fresh
        // per-request output Vec.
        let pool = svc.scratch.lock().unwrap();
        assert_eq!(pool.len(), 1);
        assert!(pool[0].capacity() >= 32 * 1024, "scratch capacity should stay warm");
    }

    #[test]
    fn single_request_splits_across_workers_byte_identically() {
        // One request touching one big chunk with a dense restart
        // table: the inline path hands the worker budget to the
        // stitcher, and the response must be byte-identical to the
        // serial decode for every codec.
        let data = Dataset::Mc0.generate(256 * 1024);
        for codec in CodecKind::all() {
            let c =
                Container::compress_with_restarts(&data, codec, 256 * 1024, 8 * 1024).unwrap();
            assert!(!c.restart_table(0).is_empty(), "{codec:?}");
            let mut reg = Registry::new();
            reg.insert("big", c);
            let svc = Service::new(&reg, None, ServiceConfig { workers: 8, hybrid: false, paranoid: false });
            let req = Request { id: 1, dataset: "big".into(), offset: 0, len: 0 };
            let (resp, _) = svc.serve_batch(std::slice::from_ref(&req));
            assert_eq!(resp[0].data.as_ref().unwrap(), &data, "{codec:?}");
        }
    }

    #[test]
    fn channel_interface() {
        let (data, reg) = registry();
        let reg = Arc::new(reg);
        let (req_tx, req_rx) = mpsc::channel();
        let (resp_tx, resp_rx) = mpsc::channel();
        let cfg = ServiceConfig::default();
        let handle = {
            let reg = reg.clone();
            std::thread::spawn(move || serve_channel(reg, cfg, req_rx, resp_tx))
        };
        req_tx.send(Request { id: 7, dataset: "tpc".into(), offset: 0, len: 1000 }).unwrap();
        let resp = resp_rx.recv().unwrap();
        assert_eq!(resp.id, 7);
        assert_eq!(resp.data.unwrap(), data[..1000]);
        drop(req_tx);
        handle.join().unwrap();
    }
}
