//! Fig 4 reproduction: issue-slot timeline of baseline vs CODAG on a toy
//! SM (2 schedulers, 4 warp slots).
//!
//! The paper's Fig 4 is a cartoon showing pipeline bubbles between the
//! baseline's decode operations (one leader per scheduler, latency fully
//! exposed, sync bubbles before writes) versus CODAG's interleaved
//! independent warps. This module renders the same picture from the
//! actual simulator by recording per-cycle issue activity.

use crate::gpu_sim::config::GpuConfig;
use crate::gpu_sim::engine::simulate_sm;
use crate::gpu_sim::metrics::SimMetrics;
use crate::gpu_sim::segment::{compile_baseline, compile_codag, UnitProgram};
use crate::decomp::trace::{BarrierScope, UnitEvent, UnitTrace};

/// A toy chunk trace: alternating decode bursts and run writes.
fn toy_trace(symbols: u32, per_symbol_broadcast: bool) -> UnitTrace {
    let mut events = Vec::new();
    events.push(UnitEvent::Read { bytes: 128 });
    for _ in 0..symbols {
        events.push(UnitEvent::Decode { ops: 12 });
        if per_symbol_broadcast {
            events.push(UnitEvent::Broadcast);
            events.push(UnitEvent::Barrier { scope: BarrierScope::Block });
        } else {
            events.push(UnitEvent::Barrier { scope: BarrierScope::Warp });
        }
        events.push(UnitEvent::Write { bytes: 256, active: 32 });
    }
    UnitTrace { events, comp_bytes: 128, uncomp_bytes: symbols as u64 * 256 }
}

/// The toy SM configuration of Fig 4.
pub fn toy_config() -> GpuConfig {
    GpuConfig {
        name: "Fig4-toy",
        num_sms: 1,
        schedulers_per_sm: 2,
        warp_slots_per_sm: 4,
        max_threads_per_sm: 4 * 32,
        ..GpuConfig::a100()
    }
}

/// Result of the Fig 4 comparison.
#[derive(Debug, Clone)]
pub struct TimelineComparison {
    /// Baseline metrics (2 two-warp block units resident).
    pub baseline: SimMetrics,
    /// CODAG metrics (4 warp units resident).
    pub codag: SimMetrics,
}

/// Run the Fig 4 experiment: same decode work, two provisionings.
pub fn fig4() -> TimelineComparison {
    let cfg = toy_config();
    // Baseline: a 64-thread block (2 warps) per unit -> 2 units resident.
    let base_units: Vec<UnitProgram> = (0..2)
        .map(|_| compile_baseline(&toy_trace(24, true), 64))
        .collect();
    // CODAG: 4 warp-level units.
    let codag_units: Vec<UnitProgram> =
        (0..4).map(|_| compile_codag(&toy_trace(24, false), false)).collect();
    TimelineComparison {
        baseline: simulate_sm(&cfg, &base_units),
        codag: simulate_sm(&cfg, &codag_units),
    }
}

/// Render an ASCII summary of the Fig 4 comparison.
pub fn render(cmp: &TimelineComparison) -> String {
    let cfg = toy_config();
    let bar = |pct: f64| {
        let n = (pct / 2.0).round() as usize;
        format!("{}{}", "#".repeat(n.min(50)), ".".repeat(50usize.saturating_sub(n)))
    };
    let mut s = String::new();
    s.push_str("Fig 4 — issue-slot utilization, toy SM (2 schedulers, 4 warp slots)\n");
    for (name, m) in [("baseline", &cmp.baseline), ("CODAG   ", &cmp.codag)] {
        s.push_str(&format!(
            "{name}  issue%={:5.1} [{}] cycles={}\n",
            m.compute_pct(&cfg),
            bar(m.compute_pct(&cfg)),
            m.cycles
        ));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn codag_fills_more_issue_slots() {
        let cmp = fig4();
        let cfg = toy_config();
        assert!(
            cmp.codag.compute_pct(&cfg) > cmp.baseline.compute_pct(&cfg) * 1.5,
            "CODAG {:.1}% vs baseline {:.1}%",
            cmp.codag.compute_pct(&cfg),
            cmp.baseline.compute_pct(&cfg)
        );
    }

    #[test]
    fn codag_finishes_more_work_per_cycle() {
        let cmp = fig4();
        // CODAG decompresses 2x the chunks; it must not take 2x the time.
        assert!(cmp.codag.cycles < cmp.baseline.cycles * 2);
        assert_eq!(cmp.codag.units_done, 4);
        assert_eq!(cmp.baseline.units_done, 2);
    }

    #[test]
    fn render_is_nonempty() {
        let out = render(&fig4());
        assert!(out.contains("CODAG"));
        assert!(out.contains("baseline"));
    }
}
