//! Compilation of [`UnitTrace`]s into per-warp instruction segments.
//!
//! A decompression unit's event trace is provisioning-agnostic; this
//! module lowers it onto warps according to the strategy under test:
//!
//! * **CODAG** (Fig 1b): one warp executes everything — decode ops,
//!   warp barriers, coalesced reads and writes.
//! * **Baseline** (Fig 1a): a leader warp executes decode ops and
//!   broadcasts; `Read` events go to the dedicated prefetch warp;
//!   `Write` events fan out over the block's warps; every broadcast and
//!   write is bracketed by block-wide barriers that *all* warps must
//!   join — which is how the paper's §III barrier-stall numbers arise.

use crate::decomp::trace::{BarrierScope, UnitEvent, UnitTrace};

/// One warp-level instruction (or synchronization token).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Instr {
    /// `n` back-to-back dependent ALU warp-instructions.
    Alu { n: u32 },
    /// A dependent shared-memory load (input-buffer byte fetch).
    Smem,
    /// A warp shuffle broadcast (register-based input buffer, §IV-E):
    /// same dependency latency class as Smem but does not occupy the
    /// LSU pipe.
    Shfl,
    /// A data-dependent branch (decode control flow).
    Branch,
    /// Global memory transaction of `bytes` (read or write). Reads stall
    /// the warp for the full DRAM latency (scoreboard); writes only wait
    /// for queue admission (fire-and-forget stores).
    Mem { bytes: u32, read: bool },
    /// Warp-scope sync (`__syncwarp`).
    WarpBar,
    /// Block-scope barrier: wait for all warps of the unit at `seq`.
    BlockBar { seq: u32 },
    /// Leader's shared-memory broadcast publish.
    Broadcast,
}

/// A warp's full program: instruction list (executed in order).
pub type WarpProgram = Vec<Instr>;

/// A decompression unit lowered to warps.
#[derive(Debug, Clone)]
pub struct UnitProgram {
    /// Per-warp instruction streams; index 0 is the leader.
    pub warps: Vec<WarpProgram>,
    /// Uncompressed bytes this unit produces (for throughput).
    pub uncomp_bytes: u64,
    /// Number of block-barrier sequence points (for sanity checks).
    pub n_block_barriers: u32,
}

/// Decode-op mix per 8 ops: 1 branch (paper Fig 2 shows up to 20%
/// branch-resolve stalls for the baseline) ...
pub const BRANCH_EVERY: u32 = 8;
/// ... and 2 shared-memory input-buffer loads (`fetch_bits` reads bytes
/// from the staging buffer; dependent smem loads are what make a lone
/// leader thread latency-bound on real hardware).
pub const SMEM_EVERY: u32 = 4;

/// Split `ops` decode operations into Alu bursts, Smem loads, and
/// Branches according to the fixed mix.
fn push_decode(prog: &mut WarpProgram, ops: u32) {
    let branches = ops / BRANCH_EVERY;
    let smems = ops / SMEM_EVERY;
    let alus = ops - branches - smems;
    if branches == 0 && smems == 0 {
        if ops > 0 {
            prog.push(Instr::Alu { n: ops });
        }
        return;
    }
    // Interleave: emit groups of (alu burst, smem[, branch]).
    let groups = smems.max(1);
    let alu_per = alus / groups;
    let mut alu_rem = alus % groups;
    let mut branches_left = branches;
    for g in 0..groups {
        let n = alu_per + if alu_rem > 0 { alu_rem -= 1; 1 } else { 0 };
        if n > 0 {
            prog.push(Instr::Alu { n });
        }
        prog.push(Instr::Smem);
        // A branch every other group keeps the 1:2 branch:smem ratio.
        if branches_left > 0 && g % 2 == 1 {
            prog.push(Instr::Branch);
            branches_left -= 1;
        }
    }
    for _ in 0..branches_left {
        prog.push(Instr::Branch);
    }
}

/// Lower a CODAG unit whose input buffer lives in registers (§IV-E
/// "Using Registers"): every input-buffer fetch is a warp shuffle
/// broadcast from the lane holding the requested bytes instead of a
/// shared-memory load.
pub fn compile_codag_regbuf(trace: &UnitTrace) -> UnitProgram {
    let mut p = compile_codag(trace, false);
    for w in &mut p.warps {
        for i in w.iter_mut() {
            if matches!(i, Instr::Smem) {
                *i = Instr::Shfl;
            }
        }
    }
    p
}

/// Lower a CODAG warp-level unit: a single warp runs the whole trace.
pub fn compile_codag(trace: &UnitTrace, prefetch_warp: bool) -> UnitProgram {
    let mut main: WarpProgram = Vec::with_capacity(trace.events.len());
    let mut prefetch: WarpProgram = Vec::new();
    for e in &trace.events {
        match *e {
            UnitEvent::Decode { ops } => push_decode(&mut main, ops),
            UnitEvent::Read { bytes } => {
                if prefetch_warp {
                    // §V-F ablation: reads run ahead on the prefetch warp.
                    prefetch.push(Instr::Mem { bytes, read: true });
                } else {
                    main.push(Instr::Mem { bytes, read: true });
                }
            }
            UnitEvent::Write { bytes, .. } => main.push(Instr::Mem { bytes, read: false }),
            UnitEvent::Barrier { scope: BarrierScope::Warp } => main.push(Instr::WarpBar),
            UnitEvent::Barrier { scope: BarrierScope::Block } => main.push(Instr::WarpBar),
            UnitEvent::Broadcast => main.push(Instr::Broadcast),
        }
    }
    let warps = if prefetch_warp { vec![main, prefetch] } else { vec![main] };
    UnitProgram { warps, uncomp_bytes: trace.uncomp_bytes, n_block_barriers: 0 }
}

/// Lower a baseline block-level unit of `block_width` threads: leader
/// decodes, everyone synchronizes, the block writes collectively. The
/// prefetch warp is one of the block's warps (Fig 1a — it lives in the
/// same thread block and shares its shared-memory batch buffers), so a
/// 1024-thread block is 32 warps: 31 compute + 1 prefetch. The prefetch
/// warp polls shared state rather than joining `__syncthreads`, letting
/// it run ahead of the decoders (as RAPIDS does).
pub fn compile_baseline(trace: &UnitTrace, block_width: u32) -> UnitProgram {
    let total_warps = (block_width / 32).max(2) as usize;
    let compute_warps = total_warps - 1; // last warp prefetches
    let mut warps: Vec<WarpProgram> = vec![Vec::new(); total_warps];
    let mut bar_seq = 0u32;
    // Pending coalesced-write transactions distributed on the next
    // barrier: each entry is one transaction's bytes.
    let mut pending_writes: Vec<u32> = Vec::new();
    for e in &trace.events {
        match *e {
            UnitEvent::Decode { ops } => push_decode(&mut warps[0], ops),
            UnitEvent::Read { bytes } => {
                warps[compute_warps].push(Instr::Mem { bytes, read: true })
            }
            UnitEvent::Write { bytes, .. } => pending_writes.push(bytes),
            UnitEvent::Broadcast => warps[0].push(Instr::Broadcast),
            UnitEvent::Barrier { .. } => {
                // Block barrier: all compute warps join; distribute any
                // pending writes across the block's warps afterwards.
                for w in warps.iter_mut().take(compute_warps) {
                    w.push(Instr::BlockBar { seq: bar_seq });
                }
                bar_seq += 1;
                for (i, &bytes) in pending_writes.iter().enumerate() {
                    warps[i % compute_warps].push(Instr::Mem { bytes, read: false });
                }
                pending_writes.clear();
            }
        }
    }
    for (i, &bytes) in pending_writes.iter().enumerate() {
        warps[i % compute_warps].push(Instr::Mem { bytes, read: false });
    }
    UnitProgram {
        warps,
        uncomp_bytes: trace.uncomp_bytes,
        n_block_barriers: bar_seq,
    }
}

impl UnitProgram {
    /// Total warp-instructions across all warps.
    pub fn total_instrs(&self) -> u64 {
        self.warps
            .iter()
            .flat_map(|w| w.iter())
            .map(|i| match i {
                Instr::Alu { n } => *n as u64,
                _ => 1,
            })
            .sum()
    }

    /// Number of warps this unit occupies.
    pub fn n_warps(&self) -> u32 {
        self.warps.len() as u32
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::decomp::trace::{BarrierScope, UnitEvent, UnitTrace};

    fn sample_trace() -> UnitTrace {
        UnitTrace {
            events: vec![
                UnitEvent::Read { bytes: 128 },
                UnitEvent::Decode { ops: 20 },
                UnitEvent::Broadcast,
                UnitEvent::Barrier { scope: BarrierScope::Block },
                UnitEvent::Write { bytes: 512, active: 128 },
                UnitEvent::Decode { ops: 17 },
                UnitEvent::Barrier { scope: BarrierScope::Warp },
            ],
            comp_bytes: 100,
            uncomp_bytes: 512,
        }
    }

    #[test]
    fn codag_single_warp() {
        let p = compile_codag(&sample_trace(), false);
        assert_eq!(p.n_warps(), 1);
        assert_eq!(p.n_block_barriers, 0);
        // Reads stay on the main warp.
        assert!(p.warps[0].iter().any(|i| matches!(i, Instr::Mem { read: true, .. })));
    }

    #[test]
    fn codag_prefetch_moves_reads() {
        let p = compile_codag(&sample_trace(), true);
        assert_eq!(p.n_warps(), 2);
        assert!(p.warps[0].iter().all(|i| !matches!(i, Instr::Mem { read: true, .. })));
        assert!(p.warps[1].iter().all(|i| matches!(i, Instr::Mem { read: true, .. })));
    }

    #[test]
    fn baseline_structure() {
        let p = compile_baseline(&sample_trace(), 1024);
        assert_eq!(p.n_warps(), 32); // 31 compute + 1 prefetch
        // Every compute warp holds the same number of block barriers.
        for w in 0..31 {
            let bars = p.warps[w]
                .iter()
                .filter(|i| matches!(i, Instr::BlockBar { .. }))
                .count();
            assert_eq!(bars as u32, p.n_block_barriers, "warp {w}");
        }
        // Leader holds the decode ops and the broadcast.
        assert!(p.warps[0].iter().any(|i| matches!(i, Instr::Alu { .. })));
        assert!(p.warps[0].iter().any(|i| matches!(i, Instr::Broadcast)));
        assert!(p.warps[1].iter().all(|i| !matches!(i, Instr::Alu { .. })));
        // Prefetch warp got the read and no barriers.
        assert!(p.warps[31].iter().any(|i| matches!(i, Instr::Mem { read: true, .. })));
        assert!(p.warps[31].iter().all(|i| !matches!(i, Instr::BlockBar { .. })));
    }

    #[test]
    fn decode_mix_preserves_op_count() {
        let mut prog = Vec::new();
        push_decode(&mut prog, 40);
        let branches = prog.iter().filter(|i| matches!(i, Instr::Branch)).count() as u32;
        let smems = prog.iter().filter(|i| matches!(i, Instr::Smem)).count() as u32;
        let alus: u32 = prog
            .iter()
            .map(|i| if let Instr::Alu { n } = i { *n } else { 0 })
            .sum();
        assert_eq!(branches, 40 / BRANCH_EVERY);
        assert_eq!(smems, 40 / SMEM_EVERY);
        assert_eq!(alus + branches + smems, 40);
        // Small bursts stay pure ALU.
        let mut small = Vec::new();
        push_decode(&mut small, 3);
        assert_eq!(small, vec![Instr::Alu { n: 3 }]);
    }
}
