//! Simulator metrics mirroring the Nsight counters the paper reports.

use crate::gpu_sim::config::GpuConfig;

/// Why a scheduler failed to issue in a given cycle — the categories of
/// the paper's stalled-instruction distributions (Figs 2, 3, 5).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum StallReason {
    /// Warps waiting at a barrier / for the leader (paper "Barrier"/"SB").
    Barrier,
    /// Ready warps throttled by an oversubscribed math pipe ("MPT").
    MathPipeThrottle,
    /// Fixed-latency execution dependency ("Wait").
    Wait,
    /// Waiting for a branch target to resolve ("Branch Resolve").
    BranchResolve,
    /// Waiting on a global-memory access ("Long Scoreboard" / DRAM).
    LongScoreboard,
    /// No resident work (tail effects / under-occupancy).
    Idle,
}

impl StallReason {
    /// All categories, in reporting order.
    pub const ALL: [StallReason; 6] = [
        StallReason::Barrier,
        StallReason::MathPipeThrottle,
        StallReason::Wait,
        StallReason::BranchResolve,
        StallReason::LongScoreboard,
        StallReason::Idle,
    ];

    /// Display label matching the paper's figures.
    pub fn label(&self) -> &'static str {
        match self {
            StallReason::Barrier => "Barrier(SB)",
            StallReason::MathPipeThrottle => "MPT",
            StallReason::Wait => "Wait",
            StallReason::BranchResolve => "BranchResolve",
            StallReason::LongScoreboard => "LongScoreboard",
            StallReason::Idle => "Idle",
        }
    }
}

/// Counters collected by one SM simulation.
///
/// All fields are integers, so `==` is exact: the determinism tests
/// (`tests/prop_sim.rs`) compare whole metric sets across repeated runs.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct SimMetrics {
    /// Total simulated cycles.
    pub cycles: u64,
    /// Warp-instructions issued.
    pub issued: u64,
    /// Cycles each scheduler's ALU pipe was busy (summed over schedulers).
    pub alu_busy: u64,
    /// Cycles each scheduler's FMA pipe was busy.
    pub fma_busy: u64,
    /// Cycles each scheduler's LSU pipe was busy.
    pub lsu_busy: u64,
    /// Bytes read from DRAM.
    pub bytes_read: u64,
    /// Bytes written to DRAM.
    pub bytes_written: u64,
    /// Scheduler-cycles with no issue, by reason.
    pub stalls: [u64; 6],
    /// Uncompressed bytes produced by the simulated units.
    pub uncomp_bytes: u64,
    /// Units completed.
    pub units_done: u64,
}

impl SimMetrics {
    /// Record a stall.
    #[inline]
    pub fn stall(&mut self, r: StallReason, n: u64) {
        let idx = StallReason::ALL.iter().position(|x| *x == r).unwrap();
        self.stalls[idx] += n;
    }

    /// Total scheduler-cycles (issue opportunities).
    pub fn scheduler_cycles(&self, cfg: &GpuConfig) -> u64 {
        self.cycles * cfg.schedulers_per_sm as u64
    }

    /// Compute (issue) throughput as % of peak — paper "Compute %".
    pub fn compute_pct(&self, cfg: &GpuConfig) -> f64 {
        100.0 * self.issued as f64 / self.scheduler_cycles(cfg).max(1) as f64
    }

    /// Memory throughput as % of the SM's DRAM bandwidth share.
    pub fn memory_pct(&self, cfg: &GpuConfig) -> f64 {
        let peak = self.cycles as f64 * cfg.bytes_per_cycle_per_sm();
        100.0 * (self.bytes_read + self.bytes_written) as f64 / peak.max(1.0)
    }

    /// ALU pipe utilization % (paper Fig 3 right).
    pub fn alu_pct(&self, cfg: &GpuConfig) -> f64 {
        100.0 * self.alu_busy as f64 / self.scheduler_cycles(cfg).max(1) as f64
    }

    /// FMA pipe utilization %.
    pub fn fma_pct(&self, cfg: &GpuConfig) -> f64 {
        100.0 * self.fma_busy as f64 / self.scheduler_cycles(cfg).max(1) as f64
    }

    /// LSU pipe utilization %.
    pub fn lsu_pct(&self, cfg: &GpuConfig) -> f64 {
        100.0 * self.lsu_busy as f64 / self.scheduler_cycles(cfg).max(1) as f64
    }

    /// Stall distribution (% of stalled scheduler-cycles per reason).
    pub fn stall_distribution(&self) -> Vec<(StallReason, f64)> {
        let total: u64 = self.stalls.iter().sum();
        StallReason::ALL
            .iter()
            .enumerate()
            .map(|(i, &r)| (r, 100.0 * self.stalls[i] as f64 / total.max(1) as f64))
            .collect()
    }

    /// Fraction of stalled cycles attributed to `r`.
    pub fn stall_pct(&self, r: StallReason) -> f64 {
        let total: u64 = self.stalls.iter().sum();
        let idx = StallReason::ALL.iter().position(|x| *x == r).unwrap();
        100.0 * self.stalls[idx] as f64 / total.max(1) as f64
    }

    /// End-to-end decompression throughput in GB/s when this SM's work
    /// is replicated over the whole GPU (units are homogeneous and SMs
    /// independent — §IV-C).
    pub fn throughput_gbps(&self, cfg: &GpuConfig) -> f64 {
        let secs = self.cycles as f64 / cfg.clock_hz();
        self.uncomp_bytes as f64 * cfg.num_sms as f64 / secs.max(1e-12) / 1e9
    }

    /// Wall-clock the simulated SM spent, in seconds.
    pub fn sim_seconds(&self, cfg: &GpuConfig) -> f64 {
        self.cycles as f64 / cfg.clock_hz()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentages_bounded() {
        let cfg = GpuConfig::a100();
        let mut m = SimMetrics::default();
        m.cycles = 1000;
        m.issued = 2000;
        m.alu_busy = 1500;
        m.bytes_read = 10_000;
        m.uncomp_bytes = 1 << 20;
        assert!(m.compute_pct(&cfg) <= 100.0 * 1.0 + 1e-9);
        assert!(m.alu_pct(&cfg) <= 100.0);
        assert!(m.throughput_gbps(&cfg) > 0.0);
    }

    #[test]
    fn stall_distribution_sums_to_100() {
        let mut m = SimMetrics::default();
        m.stall(StallReason::Barrier, 80);
        m.stall(StallReason::Wait, 15);
        m.stall(StallReason::BranchResolve, 5);
        let total: f64 = m.stall_distribution().iter().map(|(_, p)| p).sum();
        assert!((total - 100.0).abs() < 1e-9);
        assert!((m.stall_pct(StallReason::Barrier) - 80.0).abs() < 1e-9);
    }
}
