//! GPU hardware configurations (paper Table III: A100 and V100).
//!
//! Parameters are drawn from the public architecture whitepapers the
//! paper cites ([20], [21]) plus well-known microbenchmark numbers
//! (instruction latencies, barrier costs). The simulator is a *timing
//! model*, not an RTL model: what matters for reproducing the paper is
//! the ratio structure — warps per SM, schedulers per SM, ALU issue
//! intervals, DRAM latency vs. bandwidth — because the paper's entire
//! argument is about how many independent instruction streams are
//! available to each scheduler.

/// One GPU model's timing/occupancy parameters.
#[derive(Debug, Clone, PartialEq)]
pub struct GpuConfig {
    /// Display name ("A100", "V100").
    pub name: &'static str,
    /// Number of streaming multiprocessors.
    pub num_sms: u32,
    /// Warp schedulers per SM (A100/V100: 4).
    pub schedulers_per_sm: u32,
    /// Resident warp slots per SM (A100: 64, V100: 64).
    pub warp_slots_per_sm: u32,
    /// Max resident threads per SM (A100: 2048, V100: 2048).
    pub max_threads_per_sm: u32,
    /// Core clock in GHz (boost locked, §V-A "lock the GPU's clock").
    pub clock_ghz: f64,
    /// HBM bandwidth in GB/s.
    pub mem_bw_gbps: f64,
    /// DRAM access latency in cycles.
    pub mem_latency: u32,
    /// ALU (INT32) dependent-issue latency in cycles.
    pub alu_latency: u32,
    /// Cycles an ALU warp-instruction occupies its scheduler's issue
    /// pipe (A100: 32 lanes / 16 INT32 units per partition = 2).
    pub alu_issue_interval: u32,
    /// Branch resolve latency in cycles.
    pub branch_latency: u32,
    /// Shared-memory load-to-use latency in cycles.
    pub smem_latency: u32,
    /// Warp shuffle (`__shfl_sync`) latency in cycles (§IV-E register
    /// input buffer).
    pub shuffle_latency: u32,
    /// Store queue-admission cost in cycles (stores are fire-and-forget;
    /// the warp does not wait for DRAM completion).
    pub store_cost: u32,
    /// Cycles a memory warp-instruction occupies the LSU issue pipe.
    pub lsu_issue_interval: u32,
    /// `__syncwarp` cost in cycles (cheap: converged warps ~ 1 issue).
    pub warp_barrier_cycles: u32,
    /// `__syncthreads` release overhead in cycles after the last warp
    /// arrives (block-wide barriers cost tens of cycles).
    pub block_barrier_cycles: u32,
    /// Shared-memory broadcast (leader publish + read back) in cycles.
    pub broadcast_cycles: u32,
}

impl GpuConfig {
    /// NVIDIA A100 (SXM4 40 GB), paper Table III GPU 2.
    pub fn a100() -> GpuConfig {
        GpuConfig {
            name: "A100",
            num_sms: 108,
            schedulers_per_sm: 4,
            warp_slots_per_sm: 64,
            max_threads_per_sm: 2048,
            clock_ghz: 1.41,
            mem_bw_gbps: 1555.0,
            mem_latency: 470,
            alu_latency: 4,
            alu_issue_interval: 2,
            branch_latency: 12,
            smem_latency: 24,
            shuffle_latency: 22,
            store_cost: 4,
            lsu_issue_interval: 4,
            warp_barrier_cycles: 2,
            block_barrier_cycles: 30,
            broadcast_cycles: 25,
        }
    }

    /// NVIDIA Tesla V100 (HBM2 32 GB), paper Table III GPU 1.
    pub fn v100() -> GpuConfig {
        GpuConfig {
            name: "V100",
            num_sms: 80,
            schedulers_per_sm: 4,
            warp_slots_per_sm: 64,
            max_threads_per_sm: 2048,
            clock_ghz: 1.38,
            mem_bw_gbps: 900.0,
            mem_latency: 440,
            alu_latency: 4,
            alu_issue_interval: 2,
            branch_latency: 14,
            smem_latency: 28,
            shuffle_latency: 26,
            store_cost: 4,
            lsu_issue_interval: 4,
            warp_barrier_cycles: 2,
            block_barrier_cycles: 34,
            broadcast_cycles: 28,
        }
    }

    /// Look up by name (CLI).
    pub fn by_name(name: &str) -> Option<GpuConfig> {
        match name.to_ascii_lowercase().as_str() {
            "a100" => Some(GpuConfig::a100()),
            "v100" => Some(GpuConfig::v100()),
            _ => None,
        }
    }

    /// DRAM bytes per core-clock cycle available to one SM (the
    /// simulator models each SM's fair bandwidth share).
    pub fn bytes_per_cycle_per_sm(&self) -> f64 {
        self.mem_bw_gbps / self.clock_ghz / self.num_sms as f64
    }

    /// Peak issue slots per SM per cycle.
    pub fn issue_slots(&self) -> u32 {
        self.schedulers_per_sm
    }

    /// Core clock in Hz.
    pub fn clock_hz(&self) -> f64 {
        self.clock_ghz * 1e9
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_sane() {
        for cfg in [GpuConfig::a100(), GpuConfig::v100()] {
            assert!(cfg.num_sms > 0);
            assert!(cfg.bytes_per_cycle_per_sm() > 1.0, "{}", cfg.name);
            assert!(cfg.warp_slots_per_sm >= 64);
            assert!(cfg.alu_latency >= 1);
        }
        // A100 strictly more capable than V100.
        let (a, v) = (GpuConfig::a100(), GpuConfig::v100());
        assert!(a.num_sms > v.num_sms);
        assert!(a.mem_bw_gbps > v.mem_bw_gbps);
    }

    #[test]
    fn lookup_by_name() {
        assert_eq!(GpuConfig::by_name("A100").unwrap().name, "A100");
        assert_eq!(GpuConfig::by_name("v100").unwrap().name, "V100");
        assert!(GpuConfig::by_name("h100").is_none());
    }
}
