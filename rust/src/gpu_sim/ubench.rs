//! The §IV-D micro-benchmark: single-thread vs all-thread decoding ALU
//! throughput.
//!
//! The paper varies arithmetic operations per global memory access from
//! 1 to 100,000 and shows the achieved ALU compute throughput of the two
//! decoding techniques never differs by more than 0.1%: redundant
//! all-lane execution is free because a warp instruction occupies the
//! ALU pipe identically whether 1 or 32 lanes carry useful values.

use crate::decomp::trace::{UnitEvent, UnitTrace};
use crate::gpu_sim::config::GpuConfig;
use crate::gpu_sim::engine::simulate_sm;
use crate::gpu_sim::segment::compile_codag;

/// Result row: ops-per-access vs achieved ALU utilization for both modes.
#[derive(Debug, Clone, Copy)]
pub struct UbenchRow {
    /// Arithmetic ops per global memory access.
    pub ops_per_access: u32,
    /// ALU pipe utilization %, single-thread decoding.
    pub single_thread_pct: f64,
    /// ALU pipe utilization %, all-thread decoding.
    pub all_thread_pct: f64,
}

/// Build the micro-benchmark trace: `n_accesses` rounds of
/// (decode `ops`, read one cache line).
fn ubench_trace(ops: u32, n_accesses: u32) -> UnitTrace {
    let mut events = Vec::with_capacity(2 * n_accesses as usize);
    for _ in 0..n_accesses {
        events.push(UnitEvent::Decode { ops });
        events.push(UnitEvent::Read { bytes: 128 });
    }
    UnitTrace { events, comp_bytes: 128 * n_accesses as u64, uncomp_bytes: 0 }
}

/// Run the sweep on a full complement of warps.
///
/// In the simulator (as on the GPU), a warp ALU instruction costs the
/// same pipe cycles regardless of how many lanes compute redundant
/// values, so "single-thread" and "all-thread" decoding differ only in
/// the broadcast/sync the single-thread variant needs — which this
/// micro-benchmark (like the paper's) omits to isolate pure ALU
/// throughput. Both columns should therefore be ~identical.
pub fn run_sweep(cfg: &GpuConfig, ops_points: &[u32]) -> Vec<UbenchRow> {
    ops_points
        .iter()
        .map(|&ops| {
            let n_acc = (200_000 / (ops + 1)).clamp(4, 2000);
            let units_all: Vec<_> = (0..cfg.warp_slots_per_sm)
                .map(|_| compile_codag(&ubench_trace(ops, n_acc), false))
                .collect();
            // Single-thread decoding: identical instruction stream — one
            // lane computing vs 32 lanes computing is invisible to the
            // issue pipe. (The difference the paper's §V-E *end-to-end*
            // ablation measures comes from broadcasts, not ALU cost.)
            let units_single = units_all.clone();
            let m_all = simulate_sm(cfg, &units_all);
            let m_single = simulate_sm(cfg, &units_single);
            UbenchRow {
                ops_per_access: ops,
                single_thread_pct: m_single.alu_pct(cfg) + m_single.fma_pct(cfg),
                all_thread_pct: m_all.alu_pct(cfg) + m_all.fma_pct(cfg),
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_thread_decoding_is_free() {
        let cfg = GpuConfig::a100();
        let rows = run_sweep(&cfg, &[1, 10, 100, 1000]);
        for r in &rows {
            let diff = (r.single_thread_pct - r.all_thread_pct).abs();
            assert!(diff < 0.1, "ops={} diff={diff}", r.ops_per_access);
        }
    }

    #[test]
    fn compute_bound_at_high_intensity() {
        let cfg = GpuConfig::a100();
        let rows = run_sweep(&cfg, &[1, 10000]);
        assert!(
            rows[1].all_thread_pct > rows[0].all_thread_pct,
            "higher arithmetic intensity must raise ALU utilization ({} vs {})",
            rows[1].all_thread_pct,
            rows[0].all_thread_pct
        );
        assert!(rows[1].all_thread_pct > 50.0, "{}", rows[1].all_thread_pct);
    }
}
