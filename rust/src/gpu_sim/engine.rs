//! The SM timing simulator.
//!
//! A cycle-stepped model of one streaming multiprocessor: warp contexts
//! hold per-warp instruction streams ([`super::segment`]), each of the
//! SM's schedulers issues at most one warp-instruction per cycle from its
//! statically-assigned warps, math/LSU pipes have issue intervals, DRAM
//! is a shared FIFO with a bandwidth share and a fixed latency, and
//! block barriers gate whole units. Empty stretches are fast-forwarded,
//! with stall cycles attributed in bulk, so simulating hundreds of
//! chunks stays cheap.
//!
//! This is the substrate that stands in for the paper's A100/V100: every
//! characterization figure (2, 3, 5, 6) and throughput figure (7, 8) is
//! produced by replaying real decoder traces through this model under
//! the two provisioning strategies.

use crate::gpu_sim::config::GpuConfig;
use crate::gpu_sim::metrics::{SimMetrics, StallReason};
use crate::gpu_sim::segment::{Instr, UnitProgram};

/// Every `FMA_EVERY`-th ALU op is routed to the FMA pipe (address and
/// length arithmetic uses IMAD on NVIDIA GPUs; Fig 3 shows ~35% FMA
/// utilization during Deflate decode).
const FMA_EVERY: u64 = 3;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum WarpState {
    /// May issue at `ready_at`.
    Ready,
    /// Parked at a block barrier, waiting for the unit.
    AtBarrier,
    /// Program finished.
    Done,
}

#[derive(Debug)]
struct WarpCtx {
    /// Instruction stream (index into the unit's program).
    prog: Vec<Instr>,
    pc: usize,
    /// Remaining ops in the current `Alu` burst.
    burst_left: u32,
    ready_at: u64,
    state: WarpState,
    /// Why the warp is not ready (attribution for stall cycles).
    stall: StallReason,
    unit: usize,
}

impl WarpCtx {
    fn current(&self) -> Option<Instr> {
        self.prog.get(self.pc).copied()
    }
}

#[derive(Debug)]
struct UnitCtx {
    /// Warp ids resident for this unit.
    warps: Vec<usize>,
    /// Warps expected at block barriers (compute warps).
    barrier_width: u32,
    arrived: u32,
    warps_done: u32,
    uncomp_bytes: u64,
}

/// Simulate `units` on one SM of `cfg`. Units are admitted in order as
/// warp slots and thread slots free up (GPU thread-block scheduler).
///
/// `threads_per_warp_slot` is 32; a unit occupies `n_warps` slots and
/// `n_warps * 32` threads.
pub fn simulate_sm(cfg: &GpuConfig, units: &[UnitProgram]) -> SimMetrics {
    let mut m = SimMetrics::default();
    if units.is_empty() {
        return m;
    }
    let mut warps: Vec<WarpCtx> = Vec::new();
    let mut unit_ctxs: Vec<UnitCtx> = Vec::new();
    let mut next_unit = 0usize;
    let mut free_warp_slots = cfg.warp_slots_per_sm;
    let mut free_threads = cfg.max_threads_per_sm;
    // Scheduler state. Each scheduler keeps an *active list* of its
    // resident, non-parked warps — the cycle scan never touches retired
    // or barrier-parked warps (the difference between O(resident) and
    // O(all warps ever created) per cycle).
    let nsched = cfg.schedulers_per_sm as usize;
    let mut alu_free = vec![0u64; nsched];
    let mut fma_free = vec![0u64; nsched];
    let mut lsu_free = vec![0u64; nsched];
    let mut rr = vec![0usize; nsched]; // round-robin pointers
    let mut active: Vec<Vec<usize>> = vec![Vec::new(); nsched];
    let mut in_active: Vec<bool> = Vec::new();
    let mut parked = vec![0u64; nsched]; // AtBarrier warps per scheduler
    let mut dram_free: u64 = 0;
    let bpc = cfg.bytes_per_cycle_per_sm();
    let mut alu_op_count: u64 = 0;

    let mut cycle: u64 = 0;
    let mut live_warps = 0usize;
    let mut units_done = 0usize;

    // Admit as many units as fit.
    macro_rules! admit {
        () => {
            while next_unit < units.len() {
                let u = &units[next_unit];
                let nw = u.warps.len() as u32;
                if nw > free_warp_slots || nw * 32 > free_threads {
                    break;
                }
                free_warp_slots -= nw;
                free_threads -= nw * 32;
                let uid = unit_ctxs.len();
                let mut ids = Vec::with_capacity(u.warps.len());
                for prog in &u.warps {
                    let wi = warps.len();
                    ids.push(wi);
                    let done = prog.is_empty();
                    warps.push(WarpCtx {
                        prog: prog.clone(),
                        pc: 0,
                        burst_left: 0,
                        ready_at: cycle,
                        state: if done { WarpState::Done } else { WarpState::Ready },
                        stall: StallReason::Wait,
                        unit: uid,
                    });
                    in_active.push(!done);
                    if !done {
                        live_warps += 1;
                        active[wi % nsched].push(wi);
                    }
                }
                let empty = u.warps.iter().filter(|p| p.is_empty()).count() as u32;
                // Compute warps = those that contain block barriers.
                let bw = if u.n_block_barriers > 0 {
                    u.warps
                        .iter()
                        .filter(|p| p.iter().any(|i| matches!(i, Instr::BlockBar { .. })))
                        .count() as u32
                } else {
                    0
                };
                unit_ctxs.push(UnitCtx {
                    warps: ids,
                    barrier_width: bw,
                    arrived: 0,
                    warps_done: empty,
                    uncomp_bytes: u.uncomp_bytes,
                });
                if empty as usize == u.warps.len() {
                    // Degenerate all-empty unit: retire immediately.
                    units_done += 1;
                    m.units_done += 1;
                    m.uncomp_bytes += u.uncomp_bytes;
                    free_warp_slots += nw;
                    free_threads += nw * 32;
                }
                next_unit += 1;
            }
        };
    }

    admit!();
    // Safety valve: a unit that cannot ever fit would deadlock the loop.
    if unit_ctxs.is_empty() {
        return m;
    }

    // Retire `wi` if its program is exhausted (runs after the final
    // instruction issues, and after a barrier release when the barrier
    // was the warp's last instruction).
    macro_rules! retire_if_done {
        ($wi:expr) => {{
            let wi = $wi;
            let w = &mut warps[wi];
            if w.state == WarpState::Ready && w.pc >= w.prog.len() && w.burst_left == 0 {
                w.state = WarpState::Done;
                live_warps -= 1;
                let uid = w.unit;
                let u = &mut unit_ctxs[uid];
                u.warps_done += 1;
                if u.warps_done as usize == u.warps.len() {
                    units_done += 1;
                    m.units_done += 1;
                    m.uncomp_bytes += u.uncomp_bytes;
                    free_warp_slots += u.warps.len() as u32;
                    free_threads += u.warps.len() as u32 * 32;
                }
            }
        }};
    }

    while units_done < unit_ctxs.len() || next_unit < units.len() {
        let mut issued_this_cycle = false;
        for s in 0..nsched {
            // Lazily drop retired/parked warps from the active list.
            {
                let warps_ref = &warps;
                let in_active_ref = &mut in_active;
                active[s].retain(|&wi| {
                    let keep = warps_ref[wi].state == WarpState::Ready;
                    if !keep {
                        in_active_ref[wi] = false;
                    }
                    keep
                });
            }
            let part = active[s].len();
            let mut best: Option<usize> = None;
            let mut saw_ready_pipe_blocked = false;
            let mut reason_counts = [0u64; 6];
            reason_counts[0] += parked[s]; // barrier-parked warps
            for k in 0..part {
                let slot = (rr[s] + k) % part;
                let wi = active[s][slot];
                let w = &warps[wi];
                debug_assert_eq!(w.state, WarpState::Ready);
                if w.ready_at > cycle {
                    let ri = StallReason::ALL.iter().position(|x| *x == w.stall).unwrap();
                    reason_counts[ri] += 1;
                    continue;
                }
                // Ready: check pipe availability.
                let pipe_ok = match w.current() {
                    Some(Instr::Alu { .. }) => {
                        let is_fma = (alu_op_count + 1) % FMA_EVERY == 0;
                        if is_fma { fma_free[s] <= cycle } else { alu_free[s] <= cycle }
                    }
                    Some(Instr::Mem { .. }) | Some(Instr::Smem) => lsu_free[s] <= cycle,
                    Some(Instr::Shfl) => true, // shuffle unit, not LSU
                    _ => true,
                };
                if !pipe_ok {
                    saw_ready_pipe_blocked = true;
                    continue;
                }
                best = Some(wi);
                rr[s] = (slot + 1) % part;
                break;
            }
            match best {
                Some(wi) => {
                    issued_this_cycle = true;
                    m.issued += 1;
                    let unit_id = warps[wi].unit;
                    let instr = warps[wi].current().expect("ready warp has an instr");
                    match instr {
                        Instr::Alu { n } => {
                            alu_op_count += 1;
                            let is_fma = alu_op_count % FMA_EVERY == 0;
                            if is_fma {
                                fma_free[s] = cycle + cfg.alu_issue_interval as u64;
                                m.fma_busy += cfg.alu_issue_interval as u64;
                            } else {
                                alu_free[s] = cycle + cfg.alu_issue_interval as u64;
                                m.alu_busy += cfg.alu_issue_interval as u64;
                            }
                            let w = &mut warps[wi];
                            if w.burst_left == 0 {
                                w.burst_left = n;
                            }
                            w.burst_left -= 1;
                            w.ready_at = cycle + cfg.alu_latency as u64;
                            w.stall = StallReason::Wait;
                            if w.burst_left == 0 {
                                w.pc += 1;
                            }
                        }
                        Instr::Branch => {
                            let w = &mut warps[wi];
                            w.ready_at = cycle + cfg.branch_latency as u64;
                            w.stall = StallReason::BranchResolve;
                            w.pc += 1;
                        }
                        Instr::Smem => {
                            lsu_free[s] = cycle + cfg.lsu_issue_interval as u64;
                            m.lsu_busy += cfg.lsu_issue_interval as u64;
                            let w = &mut warps[wi];
                            w.ready_at = cycle + cfg.smem_latency as u64;
                            w.stall = StallReason::Wait;
                            w.pc += 1;
                        }
                        Instr::Shfl => {
                            // Warp shuffle: similar dependency latency,
                            // no LSU pipe pressure (§IV-E).
                            let w = &mut warps[wi];
                            w.ready_at = cycle + cfg.shuffle_latency as u64;
                            w.stall = StallReason::Wait;
                            w.pc += 1;
                        }
                        Instr::Mem { bytes, read } => {
                            lsu_free[s] = cycle + cfg.lsu_issue_interval as u64;
                            m.lsu_busy += cfg.lsu_issue_interval as u64;
                            let service = (bytes as f64 / bpc).ceil() as u64;
                            let start = dram_free.max(cycle);
                            dram_free = start + service;
                            let w = &mut warps[wi];
                            if read {
                                // Loads stall on the scoreboard until the
                                // data returns.
                                w.ready_at = start + service + cfg.mem_latency as u64;
                                w.stall = StallReason::LongScoreboard;
                                m.bytes_read += bytes as u64;
                            } else {
                                // Stores retire once the queue admits them;
                                // back-pressure only under DRAM saturation.
                                w.ready_at = start + cfg.store_cost as u64;
                                w.stall = if start > cycle {
                                    StallReason::LongScoreboard
                                } else {
                                    StallReason::Wait
                                };
                                m.bytes_written += bytes as u64;
                            }
                            w.pc += 1;
                        }
                        Instr::WarpBar => {
                            let w = &mut warps[wi];
                            w.ready_at = cycle + cfg.warp_barrier_cycles as u64;
                            w.stall = StallReason::Barrier;
                            w.pc += 1;
                        }
                        Instr::Broadcast => {
                            let w = &mut warps[wi];
                            w.ready_at = cycle + cfg.broadcast_cycles as u64;
                            w.stall = StallReason::Barrier;
                            w.pc += 1;
                        }
                        Instr::BlockBar { .. } => {
                            warps[wi].pc += 1;
                            warps[wi].state = WarpState::AtBarrier;
                            parked[wi % nsched] += 1;
                            let u = &mut unit_ctxs[unit_id];
                            u.arrived += 1;
                            if u.arrived >= u.barrier_width {
                                // Release everyone (and retire warps whose
                                // program ended on this barrier).
                                u.arrived = 0;
                                let release = cycle + cfg.block_barrier_cycles as u64;
                                let ids = u.warps.clone();
                                for owi in ids {
                                    if warps[owi].state == WarpState::AtBarrier {
                                        warps[owi].state = WarpState::Ready;
                                        warps[owi].ready_at = release;
                                        warps[owi].stall = StallReason::Barrier;
                                        parked[owi % nsched] -= 1;
                                        retire_if_done!(owi);
                                        if warps[owi].state == WarpState::Ready
                                            && !in_active[owi]
                                        {
                                            in_active[owi] = true;
                                            active[owi % nsched].push(owi);
                                        }
                                    }
                                }
                            }
                        }
                    }
                    retire_if_done!(wi);
                }
                None => {
                    // No issue this scheduler-cycle: attribute.
                    let r = if saw_ready_pipe_blocked {
                        StallReason::MathPipeThrottle
                    } else if part == 0 && parked[s] == 0 {
                        StallReason::Idle
                    } else {
                        // Majority reason among this scheduler's waiting
                        // warps (Barrier inflated by parked warps — the
                        // Nsight SB semantics).
                        let mut max_i = 5; // Idle
                        let mut max_v = 0u64;
                        for (i, &v) in reason_counts.iter().enumerate() {
                            if v > max_v {
                                max_v = v;
                                max_i = i;
                            }
                        }
                        StallReason::ALL[max_i]
                    };
                    m.stall(r, 1);
                }
            }
        }
        cycle += 1;
        admit!();
        // Fast-forward across globally idle stretches.
        if !issued_this_cycle {
            let mut next_ready = u64::MAX;
            for lst in &active {
                for &wi in lst {
                    let w = &warps[wi];
                    // Clamp to `cycle`: a warp that became ready in the
                    // past (it was pipe-blocked when last scanned) must
                    // keep the loop alive so the next scan issues it.
                    if w.state == WarpState::Ready {
                        next_ready = next_ready.min(w.ready_at.max(cycle));
                    }
                }
            }
            // Pipes could also be the gate (MPT with everything ready).
            for s in 0..nsched {
                for t in [alu_free[s], fma_free[s], lsu_free[s]] {
                    if t > cycle {
                        next_ready = next_ready.min(t);
                    }
                }
            }
            if next_ready != u64::MAX && next_ready > cycle {
                let skip = next_ready - cycle;
                // Attribute the skipped scheduler-cycles in bulk.
                let mut reason_counts = [0u64; 6];
                reason_counts[0] += parked.iter().sum::<u64>();
                for lst in &active {
                    for &wi in lst {
                        let w = &warps[wi];
                        if w.state == WarpState::Ready && w.ready_at > cycle {
                            let ri =
                                StallReason::ALL.iter().position(|x| *x == w.stall).unwrap();
                            reason_counts[ri] += 1;
                        }
                    }
                }
                let mut max_i = 5;
                let mut max_v = 0u64;
                for (i, &v) in reason_counts.iter().enumerate() {
                    if v > max_v {
                        max_v = v;
                        max_i = i;
                    }
                }
                m.stall(StallReason::ALL[max_i], skip * nsched as u64);
                cycle = next_ready;
            } else if next_ready == u64::MAX && units_done == unit_ctxs.len() && next_unit >= units.len() {
                break;
            } else if next_ready == u64::MAX {
                // Nothing can ever become ready: deadlock guard.
                if std::env::var_os("CODAG_SIM_DEBUG").is_some() {
                    eprintln!(
                        "deadlock @cycle {cycle}: units_done={units_done}/{} next_unit={next_unit}/{} live={live_warps}",
                        unit_ctxs.len(), units.len()
                    );
                    for (i, w) in warps.iter().enumerate() {
                        if w.state != WarpState::Done {
                            eprintln!(
                                "  warp {i}: state={:?} ready_at={} pc={}/{} burst={} stall={:?} unit={} in_active={}",
                                w.state, w.ready_at, w.pc, w.prog.len(), w.burst_left, w.stall, w.unit, in_active[i]
                            );
                        }
                    }
                }
                debug_assert!(false, "simulator deadlock");
                break;
            }
        }
        if live_warps == 0 && next_unit >= units.len() && units_done == unit_ctxs.len() {
            break;
        }
    }
    m.cycles = cycle.max(1);
    m
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu_sim::segment::compile_codag;
    use crate::decomp::trace::{UnitEvent, UnitTrace};

    fn alu_trace(ops: u32, uncomp: u64) -> UnitTrace {
        UnitTrace {
            events: vec![UnitEvent::Decode { ops }],
            comp_bytes: 10,
            uncomp_bytes: uncomp,
        }
    }

    #[test]
    fn single_warp_exposes_latency() {
        let cfg = GpuConfig::a100();
        let unit = compile_codag(&alu_trace(1000, 1), false);
        let m = simulate_sm(&cfg, &[unit]);
        // One warp, dependent ALU chain: ~alu_latency cycles per op.
        assert!(m.cycles >= 1000 * (cfg.alu_latency as u64 - 1), "cycles {}", m.cycles);
        assert!(m.compute_pct(&cfg) < 15.0);
    }

    #[test]
    fn many_warps_hide_latency() {
        let cfg = GpuConfig::a100();
        let units: Vec<_> = (0..64).map(|_| compile_codag(&alu_trace(1000, 1), false)).collect();
        let m = simulate_sm(&cfg, &units);
        // 64 independent warps: schedulers should be mostly busy (the
        // ALU issue interval of 2 caps per-scheduler issue at ~50%, and
        // the FMA split raises the ceiling).
        assert!(m.compute_pct(&cfg) > 45.0, "compute% {}", m.compute_pct(&cfg));
        let single = simulate_sm(&cfg, &[compile_codag(&alu_trace(1000, 1), false)]);
        // Throughput scaling: 64 units in much less than 64x the time.
        assert!(m.cycles < single.cycles * 8, "{} vs {}", m.cycles, single.cycles);
    }

    #[test]
    fn memory_requests_consume_bandwidth_and_latency() {
        let cfg = GpuConfig::a100();
        let t = UnitTrace {
            events: vec![UnitEvent::Read { bytes: 128 }, UnitEvent::Decode { ops: 1 }],
            comp_bytes: 128,
            uncomp_bytes: 128,
        };
        let m = simulate_sm(&cfg, &[compile_codag(&t, false)]);
        assert!(m.cycles >= cfg.mem_latency as u64);
        assert_eq!(m.bytes_read, 128);
    }

    #[test]
    fn units_complete_and_count_bytes() {
        let cfg = GpuConfig::a100();
        let units: Vec<_> =
            (0..100).map(|_| compile_codag(&alu_trace(50, 4096), false)).collect();
        let m = simulate_sm(&cfg, &units);
        assert_eq!(m.units_done, 100);
        assert_eq!(m.uncomp_bytes, 100 * 4096);
        assert!(m.throughput_gbps(&cfg) > 0.0);
    }

    #[test]
    fn admission_respects_occupancy() {
        let cfg = GpuConfig::a100();
        // 200 single-warp units: only 64 resident at once, all finish.
        let units: Vec<_> = (0..200).map(|_| compile_codag(&alu_trace(100, 1), false)).collect();
        let m = simulate_sm(&cfg, &units);
        assert_eq!(m.units_done, 200);
    }
}
