//! Trace-driven GPU timing simulator — the testbed substrate (Table III).
//!
//! The paper's evaluation runs CUDA kernels on A100/V100 and reads Nsight
//! counters; this environment has neither, so per the substitution rule
//! the GPU itself is built here. Decoders emit per-chunk event traces
//! ([`crate::decomp::trace`]) from *real* compressed data; this module
//! lowers them onto warps per provisioning strategy ([`segment`]),
//! schedules them on a cycle-level SM model ([`engine`]), and reports
//! Nsight-shaped metrics ([`metrics`]): stall distributions, pipe
//! utilizations, compute/memory throughput percentages, and end-to-end
//! decompression throughput.
//!
//! What makes the reproduction valid: the paper's effect is *scheduling*
//! (how many independent instruction streams each SM scheduler can pick
//! from, and how often they synchronize). That is precisely what a
//! trace-driven timing model captures; no ISA emulation is needed.

pub mod config;
pub mod engine;
pub mod metrics;
pub mod segment;
pub mod timeline;
pub mod ubench;

pub use config::GpuConfig;
pub use metrics::{SimMetrics, StallReason};

use crate::codecs::CodecKind;
use crate::decomp::codag_engine::{self, Variant};
use crate::decomp::{block_engine, UnitTrace};
use crate::format::container::Container;
use crate::Result;

/// Which decompressor architecture to provision (paper Fig 1).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Provisioning {
    /// CODAG warp-level units (optionally one of the ablation variants).
    Codag(Variant),
    /// RAPIDS-style block-level units.
    Baseline,
}

impl Provisioning {
    /// Short label for reports.
    pub fn label(&self) -> &'static str {
        match self {
            Provisioning::Codag(Variant::Codag) => "CODAG",
            Provisioning::Codag(Variant::CodagPrefetch) => "CODAG+prefetch",
            Provisioning::Codag(Variant::SingleThreadDecode) => "CODAG-single-thread",
            Provisioning::Codag(Variant::RegisterBuffer) => "CODAG-regbuf",
            Provisioning::Baseline => "RAPIDS-baseline",
        }
    }
}

/// Generate a unit trace for one chunk under `prov`.
pub fn trace_for(prov: Provisioning, kind: CodecKind, comp: &[u8]) -> Result<UnitTrace> {
    match prov {
        Provisioning::Codag(v) => codag_engine::trace_chunk_counting(kind, comp, v),
        Provisioning::Baseline => block_engine::trace_chunk_counting(kind, comp),
    }
}

/// Lower a unit trace per `prov`.
pub fn compile_for(prov: Provisioning, kind: CodecKind, t: &UnitTrace) -> segment::UnitProgram {
    match prov {
        Provisioning::Codag(Variant::RegisterBuffer) => segment::compile_codag_regbuf(t),
        Provisioning::Codag(v) => segment::compile_codag(t, v.has_prefetch_warp()),
        Provisioning::Baseline => segment::compile_baseline(t, block_engine::block_width(kind)),
    }
}

/// Simulate decompressing `container`'s chunks on one SM of `cfg` under
/// `prov`, sampling at most `max_chunks` chunks (round-robin stride) —
/// units are homogeneous so one SM with a representative sample predicts
/// the full GPU (§IV-C); [`SimMetrics::throughput_gbps`] scales by SM
/// count.
pub fn simulate_container(
    cfg: &GpuConfig,
    prov: Provisioning,
    container: &Container,
    max_chunks: usize,
) -> Result<SimMetrics> {
    let n = container.n_chunks();
    if n == 0 {
        return Ok(SimMetrics::default());
    }
    let stride = (n + max_chunks - 1) / max_chunks.max(1);
    let mut units = Vec::new();
    for i in (0..n).step_by(stride.max(1)) {
        let comp = container.chunk_bytes(i)?;
        let t = trace_for(prov, container.codec, comp)?;
        units.push(compile_for(prov, container.codec, &t));
    }
    Ok(engine::simulate_sm(cfg, &units))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codecs::CodecKind;

    fn runny_container(codec: CodecKind) -> Container {
        let mut data = Vec::new();
        for i in 0..(2 * 1024 * 1024u64 / 8) {
            data.extend_from_slice(&(i / 48).to_le_bytes());
        }
        Container::compress(&data, codec, 128 * 1024).unwrap()
    }

    #[test]
    fn codag_beats_baseline_on_rle() {
        let cfg = GpuConfig::a100();
        let c = runny_container(CodecKind::RleV1);
        let base = simulate_container(&cfg, Provisioning::Baseline, &c, 16).unwrap();
        let codag =
            simulate_container(&cfg, Provisioning::Codag(Variant::Codag), &c, 16).unwrap();
        let speedup = codag.throughput_gbps(&cfg) / base.throughput_gbps(&cfg);
        assert!(
            speedup > 4.0,
            "warp-level must be much faster on RLE v1: {speedup:.2}x ({} vs {} GB/s)",
            codag.throughput_gbps(&cfg),
            base.throughput_gbps(&cfg)
        );
    }

    #[test]
    fn baseline_stalls_dominated_by_barrier() {
        let cfg = GpuConfig::a100();
        let c = runny_container(CodecKind::RleV1);
        let m = simulate_container(&cfg, Provisioning::Baseline, &c, 8).unwrap();
        let sb = m.stall_pct(StallReason::Barrier);
        assert!(sb > 40.0, "baseline SB% should dominate, got {sb:.1}");
    }

    #[test]
    fn codag_reduces_barrier_stalls() {
        let cfg = GpuConfig::a100();
        let c = runny_container(CodecKind::RleV1);
        let b = simulate_container(&cfg, Provisioning::Baseline, &c, 8).unwrap();
        let g = simulate_container(&cfg, Provisioning::Codag(Variant::Codag), &c, 8).unwrap();
        assert!(
            g.stall_pct(StallReason::Barrier) < b.stall_pct(StallReason::Barrier),
            "CODAG {:.1}% vs baseline {:.1}%",
            g.stall_pct(StallReason::Barrier),
            b.stall_pct(StallReason::Barrier)
        );
    }

    #[test]
    fn a100_scales_better_than_v100_for_codag() {
        let c = runny_container(CodecKind::RleV1);
        let a = simulate_container(&GpuConfig::a100(), Provisioning::Codag(Variant::Codag), &c, 8)
            .unwrap();
        let v = simulate_container(&GpuConfig::v100(), Provisioning::Codag(Variant::Codag), &c, 8)
            .unwrap();
        assert!(
            a.throughput_gbps(&GpuConfig::a100()) > v.throughput_gbps(&GpuConfig::v100()),
            "A100 should be faster"
        );
    }
}
