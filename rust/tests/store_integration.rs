//! End-to-end gates for the hardened serving path (DESIGN.md §6 v2 +
//! §8):
//!
//! * a `codag pack`-shaped container *file* served via `--data-dir`
//!   plumbing (`DatasetSource::File`) returns byte-identical chunks
//!   over loopback TCP,
//! * a request whose deadline is already past at dequeue returns
//!   `Expired` without consuming a decode slot (stats count only the
//!   decoded requests),
//! * hand-built protocol-v1 frames (no deadline field) are still
//!   accepted and served,
//! * an auto-packed mixed (v3) container serves byte-identically to
//!   every forced-codec container, from disk and from memory.

use codag::codecs::CodecKind;
use codag::coordinator::{DatasetSource, Registry};
use codag::data::Rng;
use codag::format::container::Container;
use codag::server::daemon::{start, DaemonConfig};
use codag::server::proto::{
    decode_response, encode_request, read_frame_blocking, write_frame, FrameReader, Status,
    WireRequest, WireResponse,
};
use codag::server::store::FileDataset;
use std::collections::HashMap;
use std::net::{SocketAddr, TcpStream};
use std::path::PathBuf;
use std::sync::Arc;

/// Deterministic mildly-compressible payload.
fn payload(len: usize, seed: u64) -> Vec<u8> {
    let mut rng = Rng::new(seed);
    let mut out = Vec::with_capacity(len);
    while out.len() < len {
        let run = 1 + rng.below(32) as usize;
        let b = (rng.below(7) * 31) as u8;
        for _ in 0..run.min(len - out.len()) {
            out.push(b);
        }
    }
    out
}

/// Unique temp path per test.
fn tmp_path(tag: &str) -> PathBuf {
    use std::sync::atomic::{AtomicU64, Ordering};
    static N: AtomicU64 = AtomicU64::new(0);
    let n = N.fetch_add(1, Ordering::Relaxed);
    std::env::temp_dir().join(format!("codag-storeint-{}-{tag}-{n}", std::process::id()))
}

struct Client {
    stream: TcpStream,
    reader: FrameReader,
}

impl Client {
    fn connect(addr: SocketAddr) -> Client {
        Client { stream: TcpStream::connect(addr).expect("connect"), reader: FrameReader::new() }
    }

    fn send(&mut self, req: &WireRequest) {
        let body = encode_request(req).expect("encode");
        write_frame(&mut self.stream, &body).expect("send frame");
    }

    fn send_raw(&mut self, body: &[u8]) {
        write_frame(&mut self.stream, body).expect("send raw frame");
    }

    fn recv(&mut self) -> WireResponse {
        let frame = read_frame_blocking(&mut self.reader, &mut self.stream)
            .expect("read frame")
            .expect("connection open");
        decode_response(&frame).expect("decode response")
    }

    fn rpc(&mut self, req: &WireRequest) -> WireResponse {
        self.send(req);
        self.recv()
    }
}

#[test]
fn file_backed_dataset_serves_byte_identical_chunks() {
    // Pack: exactly what `codag pack` writes — a container file.
    let data = payload(300 * 1024, 11);
    let container = Container::compress(&data, CodecKind::RleV2, 32 * 1024).unwrap();
    let path = tmp_path("filebacked").with_extension("codag");
    std::fs::write(&path, container.to_bytes()).unwrap();
    // Serve: open file-backed (payload stays on disk) next to the same
    // dataset in memory; responses must agree with each other and with
    // the original data.
    let fd = FileDataset::open(&path).unwrap();
    let mut reg = Registry::new();
    reg.insert_source("fb", DatasetSource::File(fd));
    reg.insert("mem", container);
    let cfg = DaemonConfig { shards: 2, ..DaemonConfig::default() };
    let handle = start(Arc::new(reg), cfg, "127.0.0.1:0").expect("bind");
    let mut conn = Client::connect(handle.addr());
    let mut rng = Rng::new(0xF11E);
    for r in 0..40u64 {
        let total = data.len() as u64;
        let offset = rng.below(total);
        let len = 1 + rng.below((total - offset).min(90_000));
        let want = &data[offset as usize..(offset + len) as usize];
        for (base, name) in [(0u64, "fb"), (1 << 16, "mem")] {
            let resp = conn.rpc(&WireRequest::Get {
                id: base | r,
                dataset: name.into(),
                offset,
                len,
                deadline_ms: 0,
            });
            assert_eq!(resp.status, Status::Ok, "{}", String::from_utf8_lossy(&resp.payload));
            assert_eq!(resp.payload, want, "{name} [{offset}+{len}]");
        }
    }
    // Stat sees the on-disk dataset's true dimensions.
    let resp = conn.rpc(&WireRequest::Stat { id: 7, dataset: "fb".into() });
    assert_eq!(resp.status, Status::Ok);
    assert_eq!(&resp.payload[0..8], &(data.len() as u64).to_le_bytes());
    handle.join().expect("clean join");
    std::fs::remove_file(&path).ok();
}

#[test]
fn expired_deadline_returns_expired_without_decode_slot() {
    // One shard, one worker, no cache: full-range decodes serialize,
    // so a 1 ms deadline queued behind them is guaranteed stale by
    // dequeue (or by the between-items check if it lands in the same
    // batch).
    let data = payload(2 * 1024 * 1024, 12);
    let container = Container::compress(&data, CodecKind::Deflate, 128 * 1024).unwrap();
    let mut reg = Registry::new();
    reg.insert("big", container);
    let cfg = DaemonConfig {
        shards: 1,
        workers_per_shard: 1,
        cache_bytes: 0,
        ..DaemonConfig::default()
    };
    let handle = start(Arc::new(reg), cfg, "127.0.0.1:0").expect("bind");
    let mut conn = Client::connect(handle.addr());
    const HEAD: u64 = 3;
    for id in 0..HEAD {
        conn.send(&WireRequest::Get {
            id,
            dataset: "big".into(),
            offset: 0,
            len: 0,
            deadline_ms: 0,
        });
    }
    conn.send(&WireRequest::Get {
        id: HEAD,
        dataset: "big".into(),
        offset: 0,
        len: 0,
        deadline_ms: 1,
    });
    let mut statuses: HashMap<u64, Status> = HashMap::new();
    for _ in 0..=HEAD {
        let resp = conn.recv();
        statuses.insert(resp.id, resp.status);
    }
    for id in 0..HEAD {
        assert_eq!(statuses[&id], Status::Ok, "head request {id}");
    }
    assert_eq!(statuses[&HEAD], Status::Expired, "stale deadline must expire, not decode");
    // The connection survives an Expired response.
    let resp = conn.rpc(&WireRequest::Get {
        id: 99,
        dataset: "big".into(),
        offset: 10,
        len: 100,
        deadline_ms: 0,
    });
    assert_eq!(resp.status, Status::Ok);
    assert_eq!(resp.payload, &data[10..110]);
    // Expired requests never consumed a decode slot: only the decoded
    // requests are recorded.
    let stats = handle.join().expect("clean join");
    assert_eq!(stats.count() as u64, HEAD + 1);
}

#[test]
fn auto_packed_mixed_container_serves_identically_to_forced() {
    // `codag pack --codec auto` shape: chunks engineered so per-chunk
    // selection disagrees — an arithmetic u64 sequence (RLE v2 delta
    // territory: ~13 B vs kilobytes for the LZ codecs, measured via the
    // gen_golden.py ports), repeated text (LZ territory), and
    // near-random bytes — giving a mixed v3 file. Served responses must
    // be byte-identical to every forced-codec container over the same
    // data, from disk and from memory alike.
    const CHUNK: usize = 8 * 1024;
    let mut data = Vec::with_capacity(3 * CHUNK);
    for i in 0..(CHUNK / 8) as u64 {
        data.extend_from_slice(&i.to_le_bytes());
    }
    let motif = b"the quick brown fox jumps over the lazy dog. ";
    while data.len() < 2 * CHUNK {
        data.extend_from_slice(motif);
    }
    data.truncate(2 * CHUNK);
    let mut rng = Rng::new(0xA070);
    while data.len() < 3 * CHUNK {
        data.push(rng.next_u64() as u8);
    }
    let auto = Container::compress_auto(&data, CHUNK).unwrap();
    assert!(
        auto.is_mixed(),
        "auto pack chose one codec for all chunks — differential is vacuous"
    );
    let path = tmp_path("auto").with_extension("codag");
    std::fs::write(&path, auto.to_bytes()).unwrap();
    let fd = FileDataset::open(&path).unwrap();
    let mut reg = Registry::new();
    reg.insert_source("auto-file", DatasetSource::File(fd));
    reg.insert("auto-mem", auto);
    for (i, kind) in CodecKind::all().into_iter().enumerate() {
        let forced = Container::compress(&data, kind, CHUNK).unwrap();
        reg.insert(format!("forced-{i}"), forced);
    }
    let handle = start(Arc::new(reg), DaemonConfig::default(), "127.0.0.1:0").expect("bind");
    let mut conn = Client::connect(handle.addr());
    let mut rng = Rng::new(0xA071);
    let names: Vec<String> = ["auto-file".to_string(), "auto-mem".to_string()]
        .into_iter()
        .chain((0..CodecKind::all().len()).map(|i| format!("forced-{i}")))
        .collect();
    for r in 0..24u64 {
        let total = data.len() as u64;
        let offset = rng.below(total);
        let len = 1 + rng.below((total - offset).min(20_000));
        let want = &data[offset as usize..(offset + len) as usize];
        for (b, name) in names.iter().enumerate() {
            let resp = conn.rpc(&WireRequest::Get {
                id: (b as u64) << 32 | r,
                dataset: name.clone(),
                offset,
                len,
                deadline_ms: 0,
            });
            assert_eq!(resp.status, Status::Ok, "{}", String::from_utf8_lossy(&resp.payload));
            assert_eq!(resp.payload, want, "{name} [{offset}+{len}]");
        }
    }
    handle.join().expect("clean join");
    std::fs::remove_file(&path).ok();
}

/// Hand-build a v1 request body (32-byte header, no deadline field;
/// the magic literal is itself part of the layout pin).
fn encode_request_v1(kind: u8, id: u64, dataset: &str, offset: u64, len: u64) -> Vec<u8> {
    let name = dataset.as_bytes();
    let mut out = Vec::with_capacity(32 + name.len());
    out.extend_from_slice(&0xC0DA_5E01u32.to_le_bytes());
    out.extend_from_slice(&1u16.to_le_bytes());
    out.push(kind);
    out.push(name.len() as u8);
    out.extend_from_slice(&id.to_le_bytes());
    out.extend_from_slice(&offset.to_le_bytes());
    out.extend_from_slice(&len.to_le_bytes());
    out.extend_from_slice(name);
    out
}

/// Hand-build a v2 request body (40-byte header: v1 + deadline_ms, no
/// flags field). The library encoder now emits v3, so keeping real v2
/// clients served requires this independent layout pin.
fn encode_request_v2(
    kind: u8,
    id: u64,
    dataset: &str,
    offset: u64,
    len: u64,
    deadline_ms: u64,
) -> Vec<u8> {
    let name = dataset.as_bytes();
    let mut out = Vec::with_capacity(40 + name.len());
    out.extend_from_slice(&0xC0DA_5E01u32.to_le_bytes());
    out.extend_from_slice(&2u16.to_le_bytes());
    out.push(kind);
    out.push(name.len() as u8);
    out.extend_from_slice(&id.to_le_bytes());
    out.extend_from_slice(&offset.to_le_bytes());
    out.extend_from_slice(&len.to_le_bytes());
    out.extend_from_slice(&deadline_ms.to_le_bytes());
    out.extend_from_slice(name);
    out
}

#[test]
fn v1_clients_are_still_served() {
    let data = payload(96 * 1024, 13);
    let container = Container::compress(&data, CodecKind::RleV1, 16 * 1024).unwrap();
    let mut reg = Registry::new();
    reg.insert("d", container);
    let handle = start(Arc::new(reg), DaemonConfig::default(), "127.0.0.1:0").expect("bind");
    let mut conn = Client::connect(handle.addr());
    // v1 Get: decoded with deadline 0 and served normally — and the
    // response frame is stamped v1 (a real v1 client rejects v2), so
    // inspect the raw body before decoding it.
    conn.send_raw(&encode_request_v1(1, 21, "d", 5_000, 2_000));
    let frame = read_frame_blocking(&mut conn.reader, &mut conn.stream)
        .expect("read frame")
        .expect("connection open");
    assert_eq!(&frame[4..6], &1u16.to_le_bytes(), "v1 request must get a v1-stamped reply");
    let resp = decode_response(&frame).expect("decode v1-stamped response");
    assert_eq!(resp.status, Status::Ok);
    assert_eq!(resp.id, 21);
    assert_eq!(resp.payload, &data[5_000..7_000]);
    // v1 Stat: a strict v1 client requires *exactly* the 24-byte
    // payload it knows (the cache counters are v2-only).
    conn.send_raw(&encode_request_v1(2, 22, "d", 0, 0));
    let resp = conn.recv();
    assert_eq!(resp.status, Status::Ok);
    assert_eq!(resp.payload.len(), 24);
    assert_eq!(&resp.payload[0..8], &(data.len() as u64).to_le_bytes());
    // Interleaving a hand-built v2 frame (no flags field) on the same
    // connection keeps working, and gets a v2-stamped reply.
    conn.send_raw(&encode_request_v2(1, 23, "d", 0, 64, 0));
    let frame = read_frame_blocking(&mut conn.reader, &mut conn.stream)
        .expect("read frame")
        .expect("connection open");
    assert_eq!(&frame[4..6], &2u16.to_le_bytes(), "v2 request must get a v2-stamped reply");
    let resp = decode_response(&frame).expect("decode response");
    assert_eq!(resp.status, Status::Ok);
    assert_eq!(resp.payload, &data[..64]);
    // The library encoder emits v3 (flags = 0): same connection, served
    // normally, v3-stamped reply with no CRC trailer.
    conn.send(&WireRequest::Get {
        id: 24,
        dataset: "d".into(),
        offset: 0,
        len: 64,
        deadline_ms: 0,
    });
    let frame = read_frame_blocking(&mut conn.reader, &mut conn.stream)
        .expect("read frame")
        .expect("connection open");
    assert_eq!(&frame[4..6], &3u16.to_le_bytes(), "v3 request must get a v3-stamped reply");
    let resp = decode_response(&frame).expect("decode response");
    assert_eq!(resp.status, Status::Ok);
    assert_eq!(resp.payload, &data[..64]);
    handle.join().expect("clean join");
}

/// The pack→flip→serve acceptance gate (DESIGN.md §13): for every
/// codec, flip a payload byte that provably corrupts decoded content
/// and require `Status::ChecksumMismatch` over the wire — from the
/// file-backed store and the in-memory source, through the serial
/// (1 worker/shard) and split-stitch (4 workers/shard) decode paths.
/// Wrong bytes with `Ok` would fail the assertions outright; healthy
/// chunks in the same corrupted file keep serving.
#[test]
fn payload_corruption_surfaces_checksum_mismatch_on_every_decode_path() {
    const CHUNK: usize = 32 * 1024;
    for kind in CodecKind::all() {
        let data = payload(160 * 1024, 14);
        let c = Container::compress_with_restarts(&data, kind, CHUNK, 128).unwrap();
        assert!(
            (0..c.n_chunks()).all(|i| !c.restart_table(i).is_empty()),
            "{}: sweep needs restart tables so 4 workers take the split path",
            kind.name()
        );
        let bytes = c.to_bytes();
        let payload_at = bytes.len() - c.payload.len();
        // Find a flip that provably corrupts content (skip format-slack
        // flips that decode back to identical bytes).
        let mut corrupted: Option<(Vec<u8>, usize)> = None;
        'search: for i in 0..c.payload.len() {
            let chunk = c
                .index
                .iter()
                .position(|e| (i as u64) >= e.comp_off && (i as u64) < e.comp_off + e.comp_len)
                .unwrap();
            for mask in [0x01u8, 0x80] {
                let mut bad = bytes.clone();
                bad[payload_at + i] ^= mask;
                let parsed = Container::from_bytes(&bad).unwrap();
                if matches!(
                    parsed.decompress_chunk(chunk),
                    Err(codag::Error::ChecksumMismatch(_))
                ) {
                    corrupted = Some((bad, chunk));
                    break 'search;
                }
            }
        }
        let (bad, chunk) = corrupted
            .unwrap_or_else(|| panic!("{}: no payload flip corrupts content?", kind.name()));
        let healthy = (0..c.n_chunks()).find(|&i| i != chunk).unwrap();
        let path = tmp_path(&format!("crcflip-{}", kind.name())).with_extension("codag");
        std::fs::write(&path, &bad).unwrap();
        for workers in [1usize, 4] {
            let mut reg = Registry::new();
            reg.insert_source("file", DatasetSource::File(FileDataset::open(&path).unwrap()));
            reg.insert("mem", Container::from_bytes(&bad).unwrap());
            let cfg = DaemonConfig {
                shards: 1,
                workers_per_shard: workers,
                ..DaemonConfig::default()
            };
            let handle = start(Arc::new(reg), cfg, "127.0.0.1:0").expect("bind");
            let mut conn = Client::connect(handle.addr());
            for (b, name) in ["file", "mem"].iter().enumerate() {
                let resp = conn.rpc(&WireRequest::Get {
                    id: (b as u64) << 32 | workers as u64,
                    dataset: (*name).into(),
                    offset: (chunk * CHUNK) as u64,
                    len: 1024,
                    deadline_ms: 0,
                });
                assert_eq!(
                    resp.status,
                    Status::ChecksumMismatch,
                    "{} {name} ({workers} workers): corrupted chunk {chunk} returned {:?}: {}",
                    kind.name(),
                    resp.status,
                    String::from_utf8_lossy(&resp.payload)
                );
                // The healthy chunk still serves byte-identically on the
                // same connection.
                let lo = healthy * CHUNK;
                let resp = conn.rpc(&WireRequest::Get {
                    id: (b as u64) << 32 | 0xFF00 | workers as u64,
                    dataset: (*name).into(),
                    offset: lo as u64,
                    len: 1024,
                    deadline_ms: 0,
                });
                assert_eq!(resp.status, Status::Ok, "{} {name}: healthy chunk", kind.name());
                assert_eq!(resp.payload, &data[lo..lo + 1024]);
            }
            handle.join().expect("clean join");
        }
        std::fs::remove_file(&path).ok();
    }
}
