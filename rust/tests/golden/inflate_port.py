"""Python port of the crate's DEFLATE decoder (codecs/deflate/inflate.rs
+ huffman.rs + bitio.rs LSB reader), used by gen_golden.py's corruption
sweep to validate, bit-for-bit on the checked-in fixtures, which flip
positions the Rust decoder can legitimately not detect (final-byte
padding) before the Rust property tests hard-code that allowance.

Error behaviour mirrors the Rust decoder: any condition that returns
`Error::Corrupt` there raises `Corrupt` here.
"""

LENGTH_BASE = [
    3, 4, 5, 6, 7, 8, 9, 10, 11, 13, 15, 17, 19, 23, 27, 31, 35, 43, 51, 59,
    67, 83, 99, 115, 131, 163, 195, 227, 258,
]
LENGTH_EXTRA = [
    0, 0, 0, 0, 0, 0, 0, 0, 1, 1, 1, 1, 2, 2, 2, 2, 3, 3, 3, 3, 4, 4, 4, 4,
    5, 5, 5, 5, 0,
]
DIST_BASE = [
    1, 2, 3, 4, 5, 7, 9, 13, 17, 25, 33, 49, 65, 97, 129, 193, 257, 385, 513,
    769, 1025, 1537, 2049, 3073, 4097, 6145, 8193, 12289, 16385, 24577,
]
DIST_EXTRA = [
    0, 0, 0, 0, 1, 1, 2, 2, 3, 3, 4, 4, 5, 5, 6, 6, 7, 7, 8, 8, 9, 9, 10, 10,
    11, 11, 12, 12, 13, 13,
]
CLC_ORDER = [16, 17, 18, 0, 8, 7, 9, 6, 10, 5, 11, 4, 12, 3, 13, 2, 14, 1, 15]
MAX_BITS = 15


class Corrupt(Exception):
    pass


class LsbReader:
    def __init__(self, data: bytes) -> None:
        self.data = data
        self.pos = 0
        self.acc = 0
        self.nbits = 0

    def _refill(self) -> None:
        while self.nbits <= 56 and self.pos < len(self.data):
            self.acc |= self.data[self.pos] << self.nbits
            self.pos += 1
            self.nbits += 8

    def fetch_bits(self, n: int) -> int:
        self._refill()
        if self.nbits < n:
            raise Corrupt("bit stream exhausted")
        v = self.acc & ((1 << n) - 1)
        self.acc >>= n
        self.nbits -= n
        return v

    def align_byte(self) -> None:
        drop = self.nbits % 8
        self.acc >>= drop
        self.nbits -= drop


class HuffmanDecoder:
    """Canonical count/offset decoder (port of HuffmanDecoder)."""

    def __init__(self, lens) -> None:
        count = [0] * (MAX_BITS + 1)
        for l in lens:
            if l > MAX_BITS:
                raise Corrupt("code length > 15")
            count[l] += 1
        count[0] = 0
        if sum(1 for l in lens if l > 0) == 0:
            raise Corrupt("empty code")
        left = 1
        for bits in range(1, MAX_BITS + 1):
            left = (left << 1) - count[bits]
            if left < 0:
                raise Corrupt("over-subscribed lengths")
        first_code = [0] * (MAX_BITS + 1)
        first_sym = [0] * (MAX_BITS + 1)
        code = 0
        sym_base = 0
        self.max_len = 0
        for bits in range(1, MAX_BITS + 1):
            code = (code + count[bits - 1]) << 1
            first_code[bits] = code
            first_sym[bits] = sym_base
            sym_base += count[bits]
            if count[bits] > 0:
                self.max_len = bits
        offs = first_sym[:]
        symbols = [0] * sym_base
        for sym, l in enumerate(lens):
            if l > 0:
                symbols[offs[l]] = sym
                offs[l] += 1
        self.count = count
        self.first_code = first_code
        self.first_sym = first_sym
        self.symbols = symbols

    def decode(self, r: LsbReader) -> int:
        code = 0
        length = 0
        while True:
            code = (code << 1) | r.fetch_bits(1)
            length += 1
            fc = self.first_code[length]
            cnt = self.count[length]
            if fc <= code < fc + cnt:
                return self.symbols[self.first_sym[length] + (code - fc)]
            if length >= self.max_len:
                raise Corrupt("invalid code")


def fixed_lit_decoder() -> HuffmanDecoder:
    return HuffmanDecoder([8] * 144 + [9] * 112 + [7] * 24 + [8] * 8)


def fixed_dist_decoder() -> HuffmanDecoder:
    return HuffmanDecoder([5] * 30)


def _read_dynamic_tables(r: LsbReader):
    hlit = r.fetch_bits(5) + 257
    hdist = r.fetch_bits(5) + 1
    hclen = r.fetch_bits(4) + 4
    if hlit > 286 or hdist > 30:
        raise Corrupt("bad table sizes")
    clc_lens = [0] * 19
    for idx in CLC_ORDER[:hclen]:
        clc_lens[idx] = r.fetch_bits(3)
    clc = HuffmanDecoder(clc_lens)
    total = hlit + hdist
    lens: list[int] = []
    while len(lens) < total:
        sym = clc.decode(r)
        if sym <= 15:
            lens.append(sym)
        elif sym == 16:
            if not lens:
                raise Corrupt("repeat with no prior length")
            lens.extend([lens[-1]] * (3 + r.fetch_bits(2)))
        elif sym == 17:
            lens.extend([0] * (3 + r.fetch_bits(3)))
        else:
            lens.extend([0] * (11 + r.fetch_bits(7)))
    if len(lens) != total:
        raise Corrupt("code-length run overflows table")
    if lens[256] == 0:
        raise Corrupt("end-of-block symbol has no code")
    lit = HuffmanDecoder(lens[:hlit])
    dist_lens = lens[hlit:]
    dist = HuffmanDecoder([1]) if all(l == 0 for l in dist_lens) else HuffmanDecoder(dist_lens)
    return lit, dist


def inflate(data: bytes) -> bytes:
    r = LsbReader(data)
    out = bytearray()
    while True:
        bfinal = r.fetch_bits(1)
        btype = r.fetch_bits(2)
        if btype == 0:
            r.align_byte()
            length = r.fetch_bits(16)
            nlen = r.fetch_bits(16)
            if length != (~nlen & 0xFFFF):
                raise Corrupt("stored LEN/NLEN mismatch")
            for _ in range(length):
                out.append(r.fetch_bits(8))
        elif btype in (1, 2):
            lit, dist = (
                (fixed_lit_decoder(), fixed_dist_decoder())
                if btype == 1
                else _read_dynamic_tables(r)
            )
            while True:
                sym = lit.decode(r)
                if sym < 256:
                    out.append(sym)
                elif sym == 256:
                    break
                elif sym <= 285:
                    li = sym - 257
                    length = LENGTH_BASE[li] + r.fetch_bits(LENGTH_EXTRA[li])
                    dsym = dist.decode(r)
                    if dsym >= 30:
                        raise Corrupt("bad distance symbol")
                    d = DIST_BASE[dsym] + r.fetch_bits(DIST_EXTRA[dsym])
                    if d == 0 or d > len(out):
                        raise Corrupt("memcpy offset out of window")
                    start = len(out) - d
                    for k in range(length):
                        out.append(out[start + k])
                else:
                    raise Corrupt("bad literal/length symbol")
        else:
            raise Corrupt("reserved block type")
        if bfinal == 1:
            return bytes(out)
