//! Direct coverage for exported coordinator building blocks that the
//! integration stack only exercises implicitly: `router::LeastLoaded`
//! selection and `batcher::BatchPolicy` flush behavior.

use codag::coordinator::{BatchPolicy, Batcher, ExpandTask, LeastLoaded};
use codag::decomp::RunRecord;
use codag::runtime::expander::elems_to_bytes;
use codag::runtime::Expander;
use std::time::{Duration, Instant};

#[test]
fn least_loaded_spreads_then_prefers_credited_worker() {
    let ll = LeastLoaded::new(3);
    assert_eq!(ll.len(), 3);
    assert!(!ll.is_empty());
    let a = ll.pick(100);
    let b = ll.pick(100);
    let c = ll.pick(100);
    let mut seen = vec![a, b, c];
    seen.sort_unstable();
    seen.dedup();
    assert_eq!(seen.len(), 3, "equal-cost picks land on distinct workers");
    // Credit worker `b` fully: it must win the next pick.
    ll.complete(b, 100);
    assert_eq!(ll.pick(10), b);
}

#[test]
fn least_loaded_clamps_to_one_worker_and_overcredit() {
    let ll = LeastLoaded::new(0);
    assert_eq!(ll.len(), 1, "worker count is clamped to >= 1");
    assert_eq!(ll.pick(42), 0);
    // Crediting more bytes than outstanding clamps at zero rather than
    // wrapping, so the worker stays pickable.
    ll.complete(0, 9999);
    assert_eq!(ll.pick(1), 0);
}

#[test]
fn least_loaded_weights_by_bytes_not_count() {
    let ll = LeastLoaded::new(2);
    let heavy = ll.pick(1000);
    // Three light picks all fit on the other worker before it catches
    // up with the heavy one.
    for _ in 0..3 {
        let w = ll.pick(100);
        assert_ne!(w, heavy, "light work routes around the loaded worker");
    }
}

fn task(id: u64, init: u64, len: u64, delta: i64) -> ExpandTask {
    ExpandTask {
        id,
        runs: vec![RunRecord { init, len, delta }],
        width: 8,
        total: len as usize,
        enqueued: Instant::now(),
    }
}

#[test]
fn batch_policy_default_knobs() {
    let p = BatchPolicy::default();
    assert_eq!(p.max_batch, 8);
    assert_eq!(p.max_delay, Duration::from_micros(500));
}

#[test]
fn batcher_not_due_when_empty_or_fresh() {
    let policy = BatchPolicy { max_batch: 2, max_delay: Duration::from_secs(60) };
    let mut b = Batcher::new(policy);
    assert!(!b.due(Instant::now()), "empty batcher is never due");
    b.push(task(1, 5, 4, 0));
    assert!(!b.due(Instant::now()), "one fresh task under max_batch is not due");
    b.push(task(2, 5, 4, 0));
    assert!(b.due(Instant::now()), "max_batch reached");
}

#[test]
fn batcher_deadline_makes_single_task_due() {
    let policy = BatchPolicy { max_batch: 1000, max_delay: Duration::from_millis(1) };
    let mut b = Batcher::new(policy);
    b.push(task(1, 0, 4, 1));
    std::thread::sleep(Duration::from_millis(3));
    assert!(b.due(Instant::now()), "oldest task past max_delay forces a flush");
}

#[test]
fn batcher_flush_caps_at_max_batch_and_preserves_order() {
    let policy = BatchPolicy { max_batch: 3, max_delay: Duration::from_secs(60) };
    let mut b = Batcher::new(policy);
    for id in 0..5u64 {
        b.push(task(id, id * 10, 2, 1));
    }
    let ex = Expander::cpu_only();
    let first = b.flush(&ex);
    assert_eq!(first.len(), 3, "flush dispatches at most max_batch tasks");
    assert_eq!(b.pending(), 2);
    assert_eq!(b.batches, 1);
    assert_eq!(b.tasks, 3);
    let ids: Vec<u64> = first.iter().map(|r| r.id).collect();
    assert_eq!(ids, vec![0, 1, 2], "FIFO task order is preserved");
    // Each result carries the run expansion (init, init+delta, ...).
    for r in &first {
        let bytes = r.bytes.as_ref().unwrap();
        let init = r.id * 10;
        assert_eq!(bytes, &elems_to_bytes(&[init as i64, init as i64 + 1], 8));
    }
    // Draining finishes the remainder under the same policy cap.
    let rest = b.drain(&ex);
    assert_eq!(rest.len(), 2);
    assert_eq!(b.pending(), 0);
    assert_eq!(b.batches, 2);
    assert_eq!(b.tasks, 5);
}

#[test]
fn batcher_flush_on_empty_is_a_noop() {
    let mut b = Batcher::new(BatchPolicy::default());
    let ex = Expander::cpu_only();
    assert!(b.flush(&ex).is_empty());
    assert_eq!(b.batches, 0, "empty flush must not count a batch");
    assert!(b.drain(&ex).is_empty());
}
