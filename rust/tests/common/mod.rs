//! Shared golden-vector registry for the conformance and corruption
//! suites — one list, consumed by both `conformance_golden.rs` and
//! `prop_codecs.rs`, so a new fixture automatically joins every sweep.
//!
//! Fixture bytes live in `tests/golden/`; see that directory's README
//! and `gen_golden.py` for how they are generated and independently
//! verified (Python codec ports, zlib, inflate_port.py, and the
//! `expand_runs_ref` oracle in python/compile/kernels/ref.py).

use codag::codecs::CodecKind;

/// One pinned wire-format vector.
#[allow(dead_code)] // each consuming test binary uses a subset of fields
pub struct GoldenVector {
    pub name: &'static str,
    pub kind: CodecKind,
    /// RLE element width; 1 for DEFLATE (which ignores it).
    pub width: u8,
    /// When true, the Rust encoder must reproduce `comp` byte-for-byte.
    pub encoder_pinned: bool,
    pub input: &'static [u8],
    pub comp: &'static [u8],
    /// Dead bits for the exhaustive flip sweep, beyond the universal
    /// RLE allowance (the reserved header byte at offset 1): `(byte
    /// index, mask)` pairs naming the only bits a silent — undetected,
    /// payload-identical — flip may touch. Every mask was measured
    /// exhaustively against the Python decoder ports (gen_golden.py +
    /// inflate_port.py); positions fall into three classes: MSB
    /// bit-pack padding (RLE v2), DEFLATE alignment/final padding, and
    /// DEFLATE back-references that copy identical bytes from another
    /// window position (df_dynamic_genome).
    pub dead: &'static [(usize, u8)],
}

macro_rules! golden {
    ($name:literal, $kind:expr, $width:literal, $pinned:literal, $dead:expr) => {
        GoldenVector {
            name: $name,
            kind: $kind,
            width: $width,
            encoder_pinned: $pinned,
            input: include_bytes!(concat!("../golden/", $name, ".input.bin")),
            comp: include_bytes!(concat!("../golden/", $name, ".comp.bin")),
            dead: $dead,
        }
    };
}

/// Every golden vector, in fixture order.
#[allow(non_upper_case_globals)]
pub fn vectors() -> Vec<GoldenVector> {
    // Associated consts can't be `use`-imported; local aliases keep the
    // vector list readable.
    const RleV1: CodecKind = CodecKind::RleV1;
    const RleV2: CodecKind = CodecKind::RleV2;
    const Deflate: CodecKind = CodecKind::Deflate;
    const Lzss: CodecKind = CodecKind::Lzss;
    vec![
        // ORC RLE v1: byte RLE (width 1) and integer RLE (widths 2/4/8).
        golden!("v1_byte_runs_w1", RleV1, 1, true, &[]),
        golden!("v1_byte_literals_w1", RleV1, 1, true, &[]),
        golden!("v1_int_delta_w4", RleV1, 4, true, &[]),
        golden!("v1_int_literals_w8", RleV1, 8, true, &[]),
        golden!("v1_int_mixed_w2", RleV1, 2, true, &[]),
        // ORC RLE v2: one vector per sub-encoding.
        golden!("v2_short_repeat_w8", RleV2, 8, true, &[]),
        golden!("v2_fixed_delta_w4", RleV2, 4, true, &[]),
        golden!("v2_equal_long_w1", RleV2, 1, true, &[]),
        golden!("v2_direct_w2", RleV2, 2, true, &[]),
        golden!("v2_empty_w8", RleV2, 8, true, &[]),
        // Packed-section padding: 4 trailing bits of the delta bit-pack,
        // 6 trailing bits of the patch-list bit-pack.
        golden!("v2_delta_packed_w8", RleV2, 8, false, &[(9, 0x0F)]),
        golden!("v2_patched_base_w8", RleV2, 8, false, &[(19, 0x3F)]),
        // Bulk bit-unpack gates (ISSUE 5): a max-width (64-bit) DIRECT
        // group — 7 × 64 bits is an exact byte count, so no pack
        // padding and no dead bits — and a PATCHED_BASE group at the
        // max patch width (code 31 = 64 bits over 1-bit packed values).
        // Its dead bits: the 4 trailing pack-padding bits of the
        // 20×1-bit reduced section (byte 10), and the MSB of the 64-bit
        // patch-high field (byte 12), which shifts past bit 63 when the
        // patch is applied at `high << 1`.
        golden!("rle2_direct_w64", RleV2, 8, true, &[]),
        golden!("rle2_patched_maxpatch", RleV2, 8, true, &[(10, 0x0F), (12, 0x80)]),
        // DEFLATE: stored (5 alignment-padding bits after BFINAL/BTYPE),
        // fixed-Huffman, dynamic-Huffman (final-byte padding), a
        // genome-like dynamic stream (five single-bit flips reach
        // equivalent back-references copying identical bytes), and a
        // multi-block stream with a Z_FULL_FLUSH empty stored block
        // (mid-stream alignment padding).
        golden!("df_stored", Deflate, 1, false, &[(0, 0xF8)]),
        golden!("df_fixed_match", Deflate, 1, false, &[(6, 0xC0)]),
        golden!("df_dynamic_text", Deflate, 1, false, &[(63, 0xF0)]),
        golden!(
            "df_dynamic_genome",
            Deflate,
            1,
            false,
            &[(192, 0x40), (194, 0x80), (353, 0x20), (765, 0x40), (783, 0x10)]
        ),
        golden!("df_multiblock", Deflate, 1, false, &[(37, 0xF0), (99, 0xFE)]),
        // Max-depth dynamic table: a complete literal code with two
        // 15-bit codes (slow-path gate for HuffmanDecoder, codes >
        // FAST_BITS). Dead bits: bytes 21–22 hold the 4-bit CLC code of
        // the single zero-length distance entry — single-bit flips turn
        // it into another code-length symbol whose one-entry distance
        // table the decoder legally accepts (the stream has no matches,
        // so the payload is unchanged); byte 222 is final padding.
        golden!(
            "df_dynamic_maxdepth",
            Deflate,
            1,
            false,
            &[(21, 0xE0), (22, 0x01), (222, 0xFE)]
        ),
        // LZSS (wire id 4): all encoder-pinned — gen_golden.py carries a
        // line-for-line port of the greedy single-probe encoder. No dead
        // bits: the uvarint header, flag-group zero-padding check, and
        // strict segment accounting make every single-bit flip either a
        // decode error or a payload change (measured exhaustively
        // against the Python lzss_decode port, like the sets above).
        golden!("lz_literal_only", Lzss, 1, true, &[]),
        golden!("lz_match_heavy", Lzss, 1, true, &[]),
        golden!("lz_overlap_match", Lzss, 1, true, &[]),
        golden!("lz_max_offset", Lzss, 1, true, &[]),
    ]
}
